"""Paper Figure 6: end-to-end convergence, Vanilla vs FedBCD vs CELU-VFL,
plus the pipeline-depth convergence study (``--depth-sweep``).

Wall-clock is modelled by ``repro.launch.wan.WANClock`` (paper §2.1's
300 Mbps / gateway-proxied WAN; this container has no real WAN):
per-direction bandwidth + RTT, and SCHEDULE-AWARE round latency — the
sequential engine pays ``exchange_compute + wire + local_compute`` per
round, the depth-D pipelined engine pays the D-deep ``max`` schedule
(``WANClock.round_seconds``; depth 1 = paper §4.1's two-worker
``max(exchange + wire, local)``).  Speedups are reported on the
time-to-target metric like the paper's 2.65-6.27x table.

``--depth-sweep`` runs the same celu config at queue depths {0, 1, 2, 4}
and emits a machine-readable ``results/BENCH_pipeline_depth.json``: the
convergence study (rounds-to-target and WAN-clock time-to-target against
the DEPTH-0 target loss) that gates exposing the depth knob — CI's
nightly lane runs it with ``--check``, which exits non-zero if any
exposed depth misses the target.
"""
from __future__ import annotations

import json
import os

from repro.launch.wan import WANClock

from .common import (csv_row, default_workload, rounds_to, rounds_to_loss,
                     run_protocol, smoothed)

ROUNDS = 1200
LR = 0.003
CLOCK = WANClock()           # paper §2.1: 300 Mbps each way, 10 ms/leg

SWEEP_DEPTHS = (0, 1, 2, 4)
SWEEP_ROUNDS = 400
BENCH_PIPE = os.path.join(os.path.dirname(__file__), "..", "results",
                          "BENCH_pipeline_depth.json")


# The convergence dynamics are measured at miniature geometry (Z_A dim 32,
# B=256 — 65 KB/round); the WALL-CLOCK model uses the paper's deployment
# geometry (Z_A dim 256, B=4096 -> 2 x 4 MB = 244 ms/round at 300 Mbps,
# §2.1) with cross-silo CPU-party compute.  COMPUTE_PER_UPDATE is set to
# the paper's own operating regime (Fig. 4: the R local updates of one
# round roughly fill the WAN window of the next exchange — that is what
# makes the two-worker pipeline worth building): R=5 updates x ~40 ms
# ≈ one 244 ms exchange.
PAPER_Z_SHAPE = (4096, 256)          # the paper's per-message geometry
PAPER_Z_BYTES = 2 * 4096 * 256 * 4   # the paper's per-round messages
COMPUTE_PER_UPDATE = 0.04            # s/model-update, CPU-party scale


def paper_round_updown(compression: str = ""):
    """Per-round (uplink, downlink) wire bytes at the paper's deployment
    geometry for a given wire codec ('' = the plain fp32 wire)."""
    from repro.configs.base import CELUConfig
    from repro.core import engine
    tp = engine.make_transport(CELUConfig(), compression)
    return (tp.uplink_bytes(PAPER_Z_SHAPE),
            tp.downlink_bytes(PAPER_Z_SHAPE))


def paper_round_bytes(compression: str = "") -> int:
    """Per-round wire bytes at the paper's deployment geometry for a given
    wire codec ('' = the plain fp32 wire -> PAPER_Z_BYTES)."""
    up, down = paper_round_updown(compression)
    return up + down


def sim_time(rounds: int, updown, local_ratio: float,
             pipeline_depth: int = 0,
             compute_per_update: float = COMPUTE_PER_UPDATE) -> float:
    """Overlap-aware simulated time-to-target: ``updown`` is the
    PAPER-geometry per-round (uplink, downlink) wire split (see
    ``paper_round_updown`` — compressed wires shrink it), ``local_ratio``
    the local updates funded per exchange (R)."""
    up, down = updown
    return CLOCK.time_to_target(
        rounds, up, down,
        exchange_compute_s=compute_per_update,
        local_compute_s=local_ratio * compute_per_update,
        pipeline_depth=pipeline_depth)


def hard_workload(model: str, dataset: str, seed: int = 0):
    """Far-from-convergence regime like the paper's 41M-row stream: 4x the
    hash vocabulary and 4x the rows, so each embedding row is updated
    rarely and 1200 rounds stay mid-curve."""
    import dataclasses
    from repro.data import synthetic as synth
    from repro.models.tabular import DLRMConfig
    spec = dataclasses.replace(synth.TABULAR_SPECS[dataset], vocab=512,
                               n_train=131072, n_test=8192)
    data = synth.make_tabular(spec, seed=seed)
    cfg = DLRMConfig(model, spec.fields_a, spec.fields_b, vocab=512,
                     embed_dim=8, z_dim=32, hidden=(64, 32))
    return spec, data, cfg


def run_one(dataset: str, model: str, protocols=("vanilla", "fedbcd",
                                                 "celu"), rounds=ROUNDS,
            compression: str = ""):
    """All rounds are constructed through the K-party engine (the vanilla
    baseline always runs — it calibrates the shared target AUC).  The celu
    preset runs the SAME config under both schedules — depth-0 sequential
    and depth-1 pipelined — and the table charges each at its own
    overlap-aware latency.  With ``compression``, a celu run over the
    compressed wire joins the table: its sim-WAN time is charged at the
    CODEC's paper-geometry bytes, so the speedup composes round savings x
    wire savings x overlap."""
    spec, data, cfg = hard_workload(model, dataset)
    base = run_protocol("vanilla", data, cfg, rounds=rounds, lr=LR,
                        eval_every=50)
    target = 0.97 * base["best_auc"]
    csv_row(f"# end_to_end {model}/{dataset}: target AUC {target:.4f}")
    csv_row("protocol", "rounds_to_target", "sim_wan_s", "speedup_vs_vanilla",
            "final_auc")

    rows = {}
    b_rounds = rounds_to(base["curve"], target) or rounds
    zb = paper_round_updown()
    t_van = sim_time(b_rounds, zb, 0.0)
    rows["vanilla"] = (b_rounds, t_van, base["final_auc"])

    if "fedbcd" in protocols:
        fb = run_protocol("fedbcd", data, cfg, R=5, rounds=rounds, lr=LR,
                          eval_every=50, target_auc=target)
        fb_rounds = fb["rounds_to_target"] or rounds
        rows["fedbcd(R=5)"] = (fb_rounds, sim_time(fb_rounds, zb, 5.0),
                               fb["final_auc"])

    if "celu" in protocols:
        for R in (5, 8):
            ce = run_protocol("celu", data, cfg, R=R, W=5, xi=60.0,
                              rounds=rounds, lr=LR, eval_every=50,
                              target_auc=target)
            ce_rounds = ce["rounds_to_target"] or rounds
            rows[f"celu(R={R})"] = (ce_rounds,
                                    sim_time(ce_rounds, zb, float(R)),
                                    ce["final_auc"])
        # the same celu config under the depth-1 two-worker pipeline:
        # round t+1's exchange overlaps round t's local updates, so each
        # round costs max(exchange, local) instead of their sum
        # the int8-at-rest workset cache: same wire, ~4x smaller table and
        # a single-pass sample kernel — must reach the same target as the
        # fp32 cache (Algorithm-2 weights tolerate the SR quantization)
        c8 = run_protocol("celu", data, cfg, R=5, W=5, xi=60.0,
                          rounds=rounds, lr=LR, eval_every=50,
                          target_auc=target, cache_dtype="int8")
        c8_rounds = c8["rounds_to_target"] or rounds
        rows["celu(R=5,int8cache)"] = (c8_rounds,
                                       sim_time(c8_rounds, zb, 5.0),
                                       c8["final_auc"])
        csv_row(f"# int8 workset cache: {c8['stat_cache_bytes']} stat "
                f"bytes vs {ce['stat_cache_bytes']} fp32 "
                f"({ce['stat_cache_bytes'] / c8['stat_cache_bytes']:.2f}x "
                f"smaller), target reached at round "
                f"{c8_rounds} (fp32: {rows['celu(R=5)'][0]})")
        cp = run_protocol("celu", data, cfg, R=5, W=5, xi=60.0,
                          rounds=rounds, lr=LR, eval_every=50,
                          target_auc=target, pipeline_depth=1)
        cp_rounds = cp["rounds_to_target"] or rounds
        t_pipe = sim_time(cp_rounds, zb, 5.0, pipeline_depth=1)
        rows["celu(R=5,pipe=1)"] = (cp_rounds, t_pipe, cp["final_auc"])
        t_seq = rows["celu(R=5)"][1]
        csv_row(f"# pipeline overlap: depth-1 time-to-target "
                f"{t_pipe:.1f}s vs depth-0 {t_seq:.1f}s -> "
                f"{t_seq / t_pipe:.2f}x lower")
        if compression:
            cc = run_protocol("celu", data, cfg, R=5, W=5, xi=60.0,
                              rounds=rounds, lr=LR, eval_every=50,
                              target_auc=target, compression=compression)
            cc_rounds = cc["rounds_to_target"] or rounds
            czb = paper_round_updown(compression)
            rows[f"celu(R=5,{compression})"] = (
                cc_rounds, sim_time(cc_rounds, czb, 5.0), cc["final_auc"])

    for name, (r, t, a) in rows.items():
        csv_row(name, r, f"{t:.1f}", f"{t_van / t:.2f}x", f"{a:.4f}")


def _sweep_runs_fleet(data, cfg, rounds: int, depths) -> tuple:
    """All sweep depths as ONE fleet call: the depth knob is static, so
    the specs partition into ``len(depths)`` compiled cohorts — each a
    single ``jit(scan + flush)`` — instead of ``len(depths) * rounds``
    host-side stage dispatches.  Loss curves are bit-exact to the
    ``PipelinedEngine`` host loop (the fleet scheduler's golden gate in
    tests/test_fleet.py), so the convergence verdicts are unchanged;
    final AUC is evaluated on the post-drain params."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import CELUConfig
    from repro.core import engine
    from repro.data import synthetic as synth
    from repro.fleet import FleetWorkload, JobSpec, run_fleet
    from repro.models.tabular import auc, make_dlrm

    init_fn, task, predict = make_dlrm(cfg)
    etask = engine.lift_two_party(task)
    asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}

    def params_for(seed):
        return engine.lift_two_party_params(
            init_fn(jax.random.PRNGKey(seed), cfg))

    def batch_stream():
        for bi, ba, bb in synth.aligned_batches(data["train"], 256,
                                                seed=0):
            yield bi, [asj(ba)], asj(bb)

    ccfg, nloc = engine.preset_config(
        "celu", CELUConfig(R=5, W=5, xi_degrees=60.0))
    specs = [JobSpec(celu=ccfg, local_steps=nloc, lr=LR, depth=d)
             for d in depths]
    res = run_fleet(specs, rounds,
                    workload=FleetWorkload(etask, params_for,
                                           batch_stream))

    te = data["test"]
    tea = {"x_a": jnp.asarray(te["x_a"])}
    teb = {"x_b": jnp.asarray(te["x_b"]), "y": jnp.asarray(te["y"])}
    runs = {}
    for j, d in enumerate(depths):
        logits = np.asarray(
            predict(engine.unlift_params(res.final_state(j)["params"]),
                    cfg, tea, teb), np.float64)
        runs[d] = {"loss_curve": [float(x) for x in res.losses[j]],
                   "final_auc": auc(logits, te["y"])}
    return res, runs


def depth_sweep(rounds: int = SWEEP_ROUNDS, depths=SWEEP_DEPTHS,
                check: bool = False, out: str = BENCH_PIPE,
                host_loop: bool = False) -> dict:
    """The pipeline-depth convergence study: the SAME celu config under
    exchange-queue depths ``depths``, scored against the depth-0 run's
    target loss.  Depths 0/1 are the golden-pinned schedules; D >= 2 pays
    per-slot staleness (attenuated weights + eta/(1+c*s) damping) to buy
    the D-deep WAN overlap — the study quantifies the trade:
    rounds-to-target rises with D while the WAN clock's time-to-target
    falls as long as the extra rounds stay cheaper than the hidden wire.
    Runs all depths as ONE compiled fleet call by default
    (``repro.fleet``; ``host_loop=True`` keeps the legacy per-round
    ``run_protocol`` loop — the two paths are loss-curve bit-exact).
    Writes ``results/BENCH_pipeline_depth.json``; with ``check`` the run
    exits non-zero if any exposed depth misses the depth-0 target (the CI
    nightly gate)."""
    spec, data, cfg = default_workload("wdl", "criteo")
    csv_row(f"# pipeline depth sweep: celu R=5 W=5 on wdl/criteo, "
            f"{rounds} rounds, target = depth-0 smoothed tail x 1.02")
    csv_row("depth", "reached", "rounds_to_target", "time_to_target_s",
            "speedup_vs_depth0", "final_loss", "final_auc")
    if host_loop:
        runs = {d: run_protocol("celu", data, cfg, R=5, W=5, xi=60.0,
                                rounds=rounds, lr=LR, eval_every=50,
                                pipeline_depth=d) for d in depths}
    else:
        fres, runs = _sweep_runs_fleet(data, cfg, rounds, depths)
        csv_row(f"# fleet path: {len(depths)} depths as "
                f"{fres.n_cohorts} compiled cohorts in one call, "
                f"wall {fres.wall_s:.1f}s "
                f"(+{fres.compile_s:.1f}s compile)")
    base_smooth = smoothed(runs[depths[0]]["loss_curve"])
    # 2% slack over the depth-0 tail: the bar every exposed depth must hit
    target = round(base_smooth[-1] * 1.02, 6)
    zb = paper_round_updown()
    table, t0 = {}, None
    for d in depths:
        smooth = smoothed(runs[d]["loss_curve"])
        r2t = rounds_to_loss(smooth, target)
        reached = r2t is not None
        warmup = max(d - 1, 0)
        # r2t indexes MERGED rounds (the smoothed curve drops the NaN
        # warmup entries), but the scheduler also spent the D-1
        # queue-filling rounds — charge them, or deep queues get free
        # WAN time.  A run that never reaches the target is charged its
        # full `rounds` scheduler steps (warmup included).
        charged = (r2t + warmup) if reached else rounds
        t = sim_time(charged, zb, 5.0, pipeline_depth=d)
        if t0 is None:
            t0 = t
        table[str(d)] = {
            "pipeline_depth": d,
            "reached_target_loss": reached,
            "rounds_to_target_loss": r2t,
            "rounds_charged": charged,
            "time_to_target_s": round(t, 2),
            "speedup_vs_depth0": round(t0 / t, 3),
            "final_loss_smoothed": round(smooth[-1], 6),
            "final_auc": round(runs[d]["final_auc"], 4),
            "warmup_rounds": warmup,
        }
        csv_row(d, reached, r2t, f"{t:.1f}", f"{t0 / t:.2f}x",
                f"{smooth[-1]:.4f}", f"{runs[d]['final_auc']:.4f}")
    result = {
        "geometry": {"model": "wdl", "dataset": "criteo", "R": 5, "W": 5,
                     "rounds": rounds, "lr": LR, "batch": 256,
                     "n_train": spec.n_train,
                     "wan": "paper §2.1 geometry (4096x256 fp32, "
                            "300 Mbps, 10 ms/leg)"},
        "target_loss": target,
        "depths": table,
    }
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    csv_row(f"# wrote {os.path.normpath(out)}")
    missed = [d for d, row in table.items()
              if not row["reached_target_loss"]]
    if missed:
        csv_row(f"# MISSED the depth-0 target loss at depth(s): "
                f"{', '.join(missed)}")
        if check:
            raise SystemExit(
                f"depth sweep: depth(s) {missed} missed the depth-0 "
                f"target loss {target} — the convergence gate fails")
    return result


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--protocol", default="all",
                    choices=("all", "vanilla", "fedbcd", "celu"))
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--dataset", default="all",
                    choices=("all", "criteo", "avazu"))
    ap.add_argument("--compression", default="", metavar="CODEC",
                    help="also run celu over this wire codec (e.g. "
                         "int8_topk; see repro.core.compression.CODEC_SPECS)")
    ap.add_argument("--depth-sweep", action="store_true",
                    help="run ONLY the pipeline-depth convergence study "
                         "(depths {0,1,2,4}) and emit "
                         "results/BENCH_pipeline_depth.json")
    ap.add_argument("--sweep-rounds", type=int, default=SWEEP_ROUNDS,
                    help="communication rounds per depth in the sweep")
    ap.add_argument("--check", action="store_true",
                    help="with --depth-sweep: exit non-zero if any depth "
                         "misses the depth-0 target loss (the nightly CI "
                         "gate)")
    ap.add_argument("--host-loop", action="store_true",
                    help="with --depth-sweep: run the legacy per-round "
                         "host loop instead of the one-call fleet path "
                         "(loss-curve bit-exact either way)")
    args = ap.parse_args(argv)
    if args.depth_sweep:
        depth_sweep(rounds=args.sweep_rounds, check=args.check,
                    host_loop=args.host_loop)
        return
    protocols = ("vanilla", "fedbcd", "celu") if args.protocol == "all" \
        else (args.protocol,)
    if args.compression and "celu" not in protocols:
        import sys
        sys.exit("--compression measures the celu preset over the "
                 "compressed wire: rerun with --protocol celu (or all)")
    if args.dataset in ("all", "criteo"):
        run_one("criteo", "wdl", protocols, args.rounds, args.compression)
    if args.dataset in ("all", "avazu"):
        run_one("avazu", "dssm", protocols, args.rounds, args.compression)


if __name__ == "__main__":
    main()
