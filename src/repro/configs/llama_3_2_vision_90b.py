"""llama-3.2-vision-90b — VLM, cross-attn image layers every 5th layer
[hf:meta-llama/Llama-3.2-11B-Vision].  Vision frontend is a stub: the batch
carries precomputed patch embeddings (DESIGN §5)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5, n_patches=1024, d_frontend=1152,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
