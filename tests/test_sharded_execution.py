"""Sharded execution correctness: the SAME reduced train step, run (a) on
one device and (b) pjit-sharded over a 2x2 mesh with the production
sharding rules, must produce the same loss — proving the PartitionSpecs
are semantics-preserving, not just compilable."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.launch.steps import concrete_batch, make_train_step
from repro.models import vfl
from repro.models.layers import set_batch_axes
from repro.optim import adagrad
from repro.sharding.rules import batch_pspec, params_pspecs

cfg = get_config("{arch}").reduced()
shape = ShapeConfig("smoke", seq_len=64, global_batch=4, kind="train")
params = vfl.init_all(jax.random.PRNGKey(0), cfg)
batch = concrete_batch(cfg, shape, seed=1)
opt = adagrad(0.01)
opt_state = opt.init(params)
step = make_train_step(cfg, opt)

# single device reference
p1, o1, loss_ref = jax.jit(step)(params, opt_state, batch)

# sharded over a 2x2 (data, model) mesh
mesh = jax.make_mesh((2, 2), ("data", "model"))
set_batch_axes(("data",), 2, vocab_axis="model", vocab_size=2)
pspecs = params_pspecs(params, mesh, fsdp_axis="data")
ns = lambda t: jax.tree_util.tree_map(
    lambda s: jax.sharding.NamedSharding(mesh, s), t,
    is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
in_sh = (ns(pspecs), {{"accum": ns(pspecs)}},
         jax.tree_util.tree_map(
             lambda l: jax.sharding.NamedSharding(
                 mesh, batch_pspec(l.shape, mesh)), batch))
with mesh:
    p2, o2, loss_sh = jax.jit(step, in_shardings=in_sh)(
        params, opt_state, batch)
set_batch_axes(None)

print("REF", float(loss_ref), "SHARDED", float(loss_sh))
assert abs(float(loss_ref) - float(loss_sh)) < 5e-3, (loss_ref, loss_sh)
# updated params agree too
for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
    d = np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)).max()
    assert d < 0.05, d
print("SHARDED_EXECUTION_OK")
"""


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["smollm-360m", "granite-moe-3b-a800m"])
def test_sharded_matches_single_device(arch):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", CODE.format(arch=arch)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert "SHARDED_EXECUTION_OK" in r.stdout, \
        (r.stdout[-500:], r.stderr[-2000:])
