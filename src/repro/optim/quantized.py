"""Quantized optimizer state: bf16 / int8 AdaGrad accumulators and an
SM3-style factored accumulator.

At LLM geometry the fp32 AdaGrad accumulator doubles a party's param
memory — the second memory wall after the workset cache.  Three at-rest
options, all preserving the fp32 update math:

  * ``bfloat16`` — the accumulator is stored bf16 and upcast around the
    fused fp32 kernel (half the state; coarse but simple — sub-LSB g²
    increments can round away, acceptable for AdaGrad's monotone sums);
  * ``int8`` — 8-bit-optimizer style: int8 codes in [0, 127] plus one
    fp32 *master scale* per row, stored in the fused kernel's padded
    (R, C) tiling.  Codes live in sqrt-space (accumulator value =
    (code·scale)²), squaring the representable dynamic range — the
    nonuniform-quantization trick 8-bit optimizers rely on, for free
    because the kernel computes sqrt(a) anyway.  The step runs through
    ``kernels.ops.fused_adagrad_q8`` — dequantize, accumulate g², emit
    the update, re-derive the row scale, stochastically requantize — in
    ONE VMEM pass, so the fp32 accumulator never exists in HBM.  ~4x
    smaller state (+4/C per row for the scale).  Requantization uses
    stochastic rounding seeded from the step counter (deterministic →
    bit-consistent checkpoint resume);
  * ``sm3`` — the factored accumulator (Anil et al.): an (r, c) matrix
    keeps one row vector (r,) and one column vector (c,) of running
    maxima instead of the full (r, c) accumulator — O(r + c) state, the
    cover estimate ``min(row_i, col_j)`` upper-bounds the AdaGrad sum so
    steps are never larger than AdaGrad's.  1-D leaves (biases, norms)
    keep the exact diagonal accumulator (it is already tiny).

State layout: ``{"accum": (per-leaf leaves in grad-flatten order...),
"t": step}`` — a tuple, not a mirrored tree, because the per-leaf state
(:class:`QuantAccum`, SM3's row/col dict) does not share the param
leaf's structure.  Everything is a registered pytree, so the state jits,
donates, and checkpoints (packed int8 codes + fp32 scales land in the
.npz natively — no fp32 round-trip).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..kernels.fused_adagrad import BLOCK, ROWS

# Deterministic SR stream for the requantization noise: folded with the
# step counter and the leaf index, so resume-from-checkpoint replays the
# exact same rounding decisions.
_SR_KEY = 0xAD49


@jax.tree_util.register_pytree_node_class
class QuantAccum:
    """int8-at-rest AdaGrad accumulator for ONE param leaf.

    ``q``: (R, C) int8 sqrt-space codes in [0, 127] (accumulator value =
    (code·scale)²); ``scale``: (R, 1) fp32 master scales — the fused
    kernel's padded tiling.  ``shape`` remembers the param leaf so
    :meth:`dequant` (debug/inspection only — the hot path never calls
    it) can restore the logical accumulator."""

    __slots__ = ("q", "scale", "shape")

    def __init__(self, q, scale, shape):
        self.q = q
        self.scale = scale
        self.shape = tuple(shape)

    def tree_flatten(self):
        return (self.q, self.scale), (self.shape,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])

    @property
    def nbytes(self) -> int:
        return int(self.q.nbytes) + int(self.scale.nbytes)

    def dequant(self):
        n = int(math.prod(self.shape)) if self.shape else 1
        r = self.q.astype(jnp.float32) * self.scale
        return (r * r).reshape(-1)[:n].reshape(self.shape)


def _tiling(n: int) -> Tuple[int, int]:
    """Element count -> the fused kernel's padded (R, C).

    The kernel needs R % ROWS == 0, so small leaves pick C ≈ n/ROWS to
    spread across the mandatory ROWS rows instead of padding 8x (a bias
    vector must not cost more quantized than fp32).  Leaves ≥ ROWS*BLOCK
    elements land on the lane-aligned C = BLOCK."""
    cols = max(min(BLOCK, -(-max(n, 1) // ROWS)), 1)
    n_rows = -(-max(n, 1) // cols)
    return -(-n_rows // ROWS) * ROWS, cols


def _to2d(x, R: int, C: int):
    n = x.size
    return jnp.zeros((R * C,), jnp.float32).at[:n].set(
        x.reshape(-1).astype(jnp.float32)).reshape(R, C)


def quant_accum_init(p) -> QuantAccum:
    R, C = _tiling(p.size)
    return QuantAccum(jnp.zeros((R, C), jnp.int8),
                      jnp.zeros((R, 1), jnp.float32), p.shape)


def adagrad_quantized(lr: float, eps: float = 1e-10, *,
                      state_dtype: str = "int8",
                      use_pallas: bool = True):
    """AdaGrad with a quantized at-rest accumulator (see module
    docstring).  ``state_dtype``: "int8" | "bfloat16"."""
    from . import Optimizer

    if state_dtype not in ("int8", "bfloat16"):
        raise ValueError(f"state_dtype must be int8|bfloat16, "
                         f"got {state_dtype!r}")

    if state_dtype == "bfloat16":
        def init(params):
            return {"accum": jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)}

        def update(grads, state, params=None):
            def one(g, a):
                if use_pallas:
                    from ..kernels import ops as kops
                    u, a_new = kops.fused_adagrad(g, a.astype(jnp.float32),
                                                  lr, eps)
                else:
                    gf = g.astype(jnp.float32)
                    a_new = a.astype(jnp.float32) + gf * gf
                    u = -lr * gf / (jnp.sqrt(a_new) + eps)
                return u, a_new.astype(jnp.bfloat16)
            out = jax.tree_util.tree_map(one, grads, state["accum"])
            is_pair = lambda x: isinstance(x, tuple)  # noqa: E731
            upd = jax.tree_util.tree_map(lambda o: o[0], out,
                                         is_leaf=is_pair)
            acc = jax.tree_util.tree_map(lambda o: o[1], out,
                                         is_leaf=is_pair)
            return upd, {"accum": acc}

        return Optimizer(init, update)

    def init(params):
        leaves = jax.tree_util.tree_leaves(params)
        return {"accum": tuple(quant_accum_init(p) for p in leaves),
                "t": jnp.int32(0)}

    def update(grads, state, params=None):
        t = state["t"]
        rng = jax.random.fold_in(jax.random.PRNGKey(_SR_KEY), t)
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        new_acc, upds = [], []
        for i, (g, acc) in enumerate(zip(leaves, state["accum"])):
            R, C = acc.q.shape
            g2d = _to2d(g, R, C)
            u_noise = jax.random.uniform(jax.random.fold_in(rng, i),
                                         (R, C), jnp.float32)
            if use_pallas:
                from ..kernels import ops as kops
                upd2d, q_new, s_new = kops.fused_adagrad_q8(
                    g2d, acc.q, acc.scale, u_noise, lr, eps)
            else:
                from ..kernels.ref import fused_adagrad_q8_ref
                upd2d, q_new, s_new = fused_adagrad_q8_ref(
                    g2d, acc.q, acc.scale, u_noise, lr, eps)
            n = g.size
            upds.append(upd2d.reshape(-1)[:n].reshape(g.shape))
            new_acc.append(QuantAccum(q_new, s_new, acc.shape))
        return (jax.tree_util.tree_unflatten(treedef, upds),
                {"accum": tuple(new_acc), "t": t + 1})

    return Optimizer(init, update)


def sm3(lr: float, eps: float = 1e-10):
    """SM3-style factored AdaGrad: O(r + c) accumulator state for (r, c)
    leaves via running row/column maxima; exact diagonal AdaGrad for 1-D
    leaves.  The cover ``min(row_i, col_j)`` upper-bounds the true
    accumulated sum, so every step is at most the AdaGrad step —
    conservative, never optimistic."""
    from . import Optimizer

    def _factored(p) -> bool:
        return p.ndim >= 2

    def _rc(p) -> Tuple[int, int]:
        return int(p.shape[0]), int(math.prod(p.shape[1:]))

    def init(params):
        leaves = jax.tree_util.tree_leaves(params)
        acc = []
        for p in leaves:
            if _factored(p):
                r, c = _rc(p)
                acc.append({"row": jnp.zeros((r,), jnp.float32),
                            "col": jnp.zeros((c,), jnp.float32)})
            else:
                acc.append({"full": jnp.zeros(p.shape, jnp.float32)})
        return {"accum": tuple(acc)}

    def update(grads, state, params=None):
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        new_acc, upds = [], []
        for g, acc in zip(leaves, state["accum"]):
            gf = g.astype(jnp.float32)
            if "full" in acc:
                a_new = acc["full"] + gf * gf
                upds.append(-lr * gf / (jnp.sqrt(a_new) + eps))
                new_acc.append({"full": a_new})
                continue
            r, c = _rc(g)
            g2 = (gf * gf).reshape(r, c)
            v = jnp.minimum(acc["row"][:, None], acc["col"][None, :]) + g2
            upds.append((-lr * gf.reshape(r, c)
                         / (jnp.sqrt(v) + eps)).reshape(g.shape))
            new_acc.append({"row": jnp.max(v, axis=1),
                            "col": jnp.max(v, axis=0)})
        return (jax.tree_util.tree_unflatten(treedef, upds),
                {"accum": tuple(new_acc)})

    return Optimizer(init, update)


def opt_state_nbytes(opt, params) -> int:
    """EXACT device bytes of ``opt.init(params)`` WITHOUT materializing
    it (eval_shape) — the benchmark/HBM-budget counter."""
    shapes = jax.eval_shape(opt.init, params)
    return sum(int(leaf.size) * jnp.dtype(leaf.dtype).itemsize
               for leaf in jax.tree_util.tree_leaves(shapes))
