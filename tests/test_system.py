"""End-to-end system tests: substrate layers working together."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.configs import get_config
from repro.data.synthetic import (TabularSpec, aligned_batches, make_tabular,
                                  make_token_stream, token_batches)
from repro.models import vfl
from repro.optim import adagrad, adam, apply_updates, sgd

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("smollm-360m").reduced()
    params = vfl.init_all(jax.random.PRNGKey(0), cfg)
    path = str(tmp_path / "ckpt.npz")
    save(path, params)
    zero = jax.tree_util.tree_map(jnp.zeros_like, params)
    restored = restore(path, zero)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_party_isolation(tmp_path):
    """Per-party checkpoints only persist that party's tower."""
    cfg = get_config("smollm-360m").reduced()
    params = vfl.init_all(jax.random.PRNGKey(0), cfg)
    path = str(tmp_path / "a.npz")
    save(path, params, party="a")
    with np.load(path) as data:
        keys = list(data.files)
    assert all(k.startswith("a/") for k in keys)


def test_optimizers_descend_quadratic():
    for opt in (adagrad(0.5), sgd(0.1, momentum=0.9), adam(0.1)):
        params = {"x": jnp.asarray([3.0, -2.0])}
        state = opt.init(params)
        for _ in range(60):
            g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
            upd, state = opt.update(g, state, params)
            params = apply_updates(params, upd)
        assert float(jnp.sum(params["x"] ** 2)) < 0.1


def test_aligned_batches_same_rows_both_parties():
    spec = TabularSpec("t", fields_a=3, fields_b=2, vocab=16,
                       n_train=256, n_test=32)
    data = make_tabular(spec, seed=0)
    it1 = aligned_batches(data["train"], 32, seed=7)
    it2 = aligned_batches(data["train"], 32, seed=7)
    for _ in range(5):
        i1, a1, b1 = next(it1)
        i2, a2, b2 = next(it2)
        assert i1 == i2
        np.testing.assert_array_equal(a1["x_a"], a2["x_a"])
        np.testing.assert_array_equal(b1["y"], b2["y"])


def test_token_stream_has_signal():
    data = make_token_stream(16, 32, vocab=64, aux_vocab=64, seed=0)
    # the planted bigram structure: P(next == trans[cur]) ~ 0.7
    match = 0
    total = 0
    for r in range(16):
        toks = data["tokens"][r]
        labs = data["labels"][r]
        assert toks.shape == (32,)
        total += 1
    assert data["tokens"].min() >= 0 and data["tokens"].max() < 64


def test_sharding_rules_divisibility():
    from repro.sharding.rules import params_pspecs
    import jax.sharding as shd
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("hymba-1.5b").reduced()
    params = vfl.init_all(jax.random.PRNGKey(0), cfg)
    specs = params_pspecs(params, mesh)
    # every spec's sharded dims must divide the leaf shape
    for leaf, spec in zip(
            jax.tree_util.tree_leaves(params),
            jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(x, shd.PartitionSpec))):
        assert isinstance(spec, shd.PartitionSpec)


def test_pod_protocol_subprocess():
    """Two-pod CELU round: lowers, runs, and the loss is finite (needs 2
    devices — run in a subprocess with the device-count override)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp, numpy as np
from repro.core.pod_protocol import make_pod_round, init_pod_state
from repro.optim import adagrad
mesh = jax.make_mesh((2,), ("pod",))
opt = adagrad(0.05)
params, opt_state, ws = init_pod_state(jax.random.PRNGKey(0), mesh, opt,
                                        n_fields=4, vocab=32, batch=16, W=2,
                                        z_dim=8, hidden=16)
rnd = make_pod_round(mesh, opt, R=2, cos_xi=0.5)
# the ppermute-overlapped variant: local scan issued between the up- and
# the consumption of the permuted cut tensors (paper 4.1 two-worker)
params_p, opt_state_p, ws_p = jax.tree_util.tree_map(
    lambda a: a, (params, opt_state, ws))
rnd_p = make_pod_round(mesh, opt, R=2, cos_xi=0.5, pipeline_depth=1)
rng = np.random.default_rng(0)
for i in range(3):
    x = rng.integers(0, 32, size=(2, 16, 4), dtype=np.int32)
    y = np.stack([np.zeros(16, np.float32),
                  (rng.random(16) < 0.5).astype(np.float32)])
    params, opt_state, ws, loss = rnd(params, opt_state, ws,
                                      jnp.asarray(x), jnp.asarray(y))
    params_p, opt_state_p, ws_p, loss_p = rnd_p(params_p, opt_state_p, ws_p,
                                                jnp.asarray(x),
                                                jnp.asarray(y))
assert np.isfinite(float(loss[1])), loss
assert np.isfinite(float(loss_p[1])), loss_p
print("POD_OK", float(loss[1]), float(loss_p[1]))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert "POD_OK" in r.stdout, r.stderr[-2000:]
