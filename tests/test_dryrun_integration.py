"""Integration: the 512-device production-mesh dry-run actually lowers,
compiles, and reports roofline terms (one cheap arch x shape per mesh —
the full 10x4x2 matrix lives in results/dryrun_*.jsonl)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        capture_output=True, text=True, env=env, timeout=timeout)
    recs = [json.loads(l) for l in r.stdout.splitlines()
            if l.startswith("{")]
    assert recs, r.stderr[-2000:]
    return recs


@pytest.mark.slow
def test_dryrun_single_pod_long_context():
    (rec,) = _run_dryrun(["--arch", "xlstm-125m", "--shape", "long_500k"])
    assert rec["ok"], rec.get("error")
    assert rec["chips"] == 256
    assert rec["roofline"]["memory_s"] >= 0
    assert rec["dominant"] in ("compute_s", "memory_s", "collective_s")


@pytest.mark.slow
def test_dryrun_multi_pod_train():
    (rec,) = _run_dryrun(["--arch", "smollm-360m", "--shape", "train_4k",
                          "--multi-pod"])
    assert rec["ok"], rec.get("error")
    assert rec["chips"] == 512 and rec["mesh"] == "2x16x16"
    # gradient sync must produce collectives on the production mesh
    assert rec["collective_bytes_per_dev"] > 0
