"""Selective SSM (Mamba-style) block, TPU-adapted.

The CUDA selective-scan kernel is replaced by a *chunked associative scan*:
``lax.scan`` over sequence chunks with ``lax.associative_scan`` inside each
chunk — the memory-optimal TPU formulation (working set O(B * chunk * d * N)
instead of O(B * S * d * N)), mapping the recurrence onto the VPU instead of
porting warp-level primitives (DESIGN §2).

Recurrence (diagonal A):   h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * x_t
                           y_t = C_t · h_t + D * x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import SSMConfig
from .initializers import dense_init, ones_init, zeros_init

SCAN_CHUNK = 256


def mamba_init(rng, d_model: int, cfg: SSMConfig):
    di = cfg.expand * d_model
    N = cfg.state_dim
    r = max(16, d_model // 16)
    ks = jax.random.split(rng, 8)
    a = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * di),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_dim, di), jnp.float32)
                   * 0.1).astype(jnp.bfloat16),
        "w_b": dense_init(ks[2], di, N),
        "w_c": dense_init(ks[3], di, N),
        "w_dt1": dense_init(ks[4], di, r),
        "w_dt2": dense_init(ks[5], r, di),
        "dt_bias": zeros_init((di,), jnp.float32),
        "A_log": jnp.log(a),
        "D": ones_init((di,), jnp.float32),
        "out_proj": dense_init(ks[6], di, d_model),
    }


def _causal_conv(x, w):
    """Depthwise causal conv.  x: (B, S, di); w: (K, di)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for j in range(K):
        out = out + xp[:, j:j + x.shape[1], :].astype(jnp.float32) * \
            w[K - 1 - j].astype(jnp.float32)
    return out.astype(x.dtype)


def _ssm_scan(decay, inc):
    """Associative scan of h_t = decay_t * h_{t-1} + inc_t over axis 1."""
    def combine(a, b):
        return (a[0] * b[0], b[0] * a[1] + b[1])
    d, h = jax.lax.associative_scan(combine, (decay, inc), axis=1)
    return d, h


def _selective_ssm(xc, dt, B_t, C_t, A, h0):
    """xc/dt: (B,S,di); B_t/C_t: (B,S,N); A: (di,N); h0: (B,di,N)."""
    Bsz, S, di = xc.shape
    N = A.shape[1]
    chunk = min(SCAN_CHUNK, S)
    n_chunks = S // chunk
    assert S % chunk == 0, (S, chunk)

    def step(h, idx):
        from .layers import shard_batch_dim
        h = shard_batch_dim(h)
        sl = lambda a: shard_batch_dim(
            jax.lax.dynamic_slice_in_dim(a, idx * chunk, chunk, 1))
        xcs, dts, Bs, Cs = sl(xc), sl(dt), sl(B_t), sl(C_t)
        decay = jnp.exp(dts[..., None] * A[None, None])        # (B,c,di,N)
        inc = (dts * xcs)[..., None] * Bs[:, :, None, :]       # (B,c,di,N)
        cum_decay, h_local = _ssm_scan(decay, inc)
        h_all = h_local + cum_decay * h[:, None]               # add carry
        y = jnp.einsum("bcdn,bcn->bcd", h_all, Cs)
        return h_all[:, -1], y

    h_last, ys = jax.lax.scan(step, h0, jnp.arange(n_chunks))
    y = ys.transpose(1, 0, 2, 3).reshape(Bsz, S, di)
    return y, h_last


def _precompute(params, x):
    di = params["D"].shape[0]
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    x_in, z = xz[..., :di], xz[..., di:]
    return x_in, z


def _dtbc(params, xc):
    dt_pre = jnp.einsum("bsd,dr,re->bse", xc.astype(jnp.float32),
                        params["w_dt1"].astype(jnp.float32),
                        params["w_dt2"].astype(jnp.float32))
    dt = jax.nn.softplus(dt_pre + params["dt_bias"])
    B_t = jnp.einsum("bsd,dn->bsn", xc, params["w_b"]).astype(jnp.float32)
    C_t = jnp.einsum("bsd,dn->bsn", xc, params["w_c"]).astype(jnp.float32)
    return dt, B_t, C_t


def mamba_apply(params, x, cfg: SSMConfig):
    """Full-sequence forward.  x: (B, S, d)."""
    x_in, z = _precompute(params, x)
    xc = jax.nn.silu(_causal_conv(x_in, params["conv_w"])
                     .astype(jnp.float32)).astype(x.dtype)
    dt, B_t, C_t = _dtbc(params, xc)
    A = -jnp.exp(params["A_log"])
    h0 = jnp.zeros((x.shape[0], A.shape[0], A.shape[1]), jnp.float32)
    y, _ = _selective_ssm(xc.astype(jnp.float32), dt, B_t, C_t, A, h0)
    y = y + params["D"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.sigmoid(
        z.astype(jnp.float32)).astype(x.dtype) * z  # silu(z) gate
    return jnp.einsum("bsd,de->bse", y, params["out_proj"])


def make_ssm_cache(batch: int, d_model: int, cfg: SSMConfig,
                   dtype=jnp.float32):
    di = cfg.expand * d_model
    return {
        "h": jnp.zeros((batch, di, cfg.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_dim - 1, di), dtype),
    }


def mamba_decode(params, x, cache, cfg: SSMConfig):
    """One-token decode.  x: (B, 1, d) -> (y, cache)."""
    x_in, z = _precompute(params, x)
    window = jnp.concatenate([cache["conv"], x_in.astype(cache["conv"].dtype)],
                             axis=1)                       # (B, K, di)
    w = params["conv_w"].astype(jnp.float32)
    xc = jnp.einsum("bkd,kd->bd", window.astype(jnp.float32), w)[:, None]
    xc = jax.nn.silu(xc).astype(x.dtype)
    dt, B_t, C_t = _dtbc(params, xc)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt[:, 0, :, None] * A[None])           # (B,di,N)
    inc = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * \
        B_t[:, 0, None, :]
    h = decay * cache["h"] + inc
    y = jnp.einsum("bdn,bn->bd", h, C_t[:, 0])[:, None]
    y = y + params["D"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.sigmoid(
        z.astype(jnp.float32)).astype(x.dtype) * z
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"])
    return out, {"h": h, "conv": window[:, 1:]}
