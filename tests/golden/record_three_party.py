"""Regenerate ``three_party_trace.json`` from the engine's K=3 path.

The trace pins the K=2-feature-party (three parties total) round loop of
``repro.core.engine`` bit-for-bit — run this ONLY when an intentional
numeric change invalidates the golden, and say so in the commit message.

    PYTHONPATH=src python tests/golden/record_three_party.py
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from test_engine import _run_three_party_trace  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "three_party_trace.json")


def main():
    rows = _run_three_party_trace(rounds=20)
    with open(OUT, "w") as f:
        json.dump({"celu": rows}, f, indent=1)
    print(f"wrote {OUT}: {len(rows) - 1} rounds")
    print("first:", rows[0])
    print("tail: ", rows[-1])


if __name__ == "__main__":
    main()
