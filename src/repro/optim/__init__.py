"""Optimizers as pure pytree transforms (no optax offline).

``Optimizer(init, update)``:
  * ``init(params) -> opt_state``
  * ``update(grads, opt_state, params) -> (updates, opt_state)``; updates are
    ADDED to params by ``apply_updates``.

Accumulators are kept in fp32 regardless of the (bf16) param dtype — the
standard mixed-precision discipline.  The paper trains with AdaGrad
(Duchi et al.), which is the default throughout.

``adagrad(..., use_pallas=True)`` routes the element-wise accumulate+scale
through the fused Pallas kernel (kernels/fused_adagrad.py) — one VMEM pass
over (grad, accum, param) instead of three HBM round-trips.

``adagrad(..., state_dtype=...)`` selects the AT-REST accumulator storage:
"float32" (default, bit-identical to before), "bfloat16", or "int8"
(8-bit-optimizer style codes + per-row fp32 master scale, fused
dequant→accumulate→scale→requant kernel).  ``make_optimizer("sm3", ...)``
is the factored O(r + c) accumulator.  See ``optim.quantized``.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def _zeros_like_f32(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


OPT_STATE_DTYPES = ("float32", "bfloat16", "int8")


def adagrad(lr: float, eps: float = 1e-10, *,
            use_pallas: bool = False,
            state_dtype: str = "float32") -> Optimizer:
    if state_dtype not in OPT_STATE_DTYPES:
        raise ValueError(f"state_dtype must be one of {OPT_STATE_DTYPES}, "
                         f"got {state_dtype!r}")
    if state_dtype != "float32":
        from .quantized import adagrad_quantized
        return adagrad_quantized(lr, eps, state_dtype=state_dtype,
                                 use_pallas=use_pallas)

    def init(params):
        return {"accum": _zeros_like_f32(params)}

    def update(grads, state, params=None):
        if use_pallas:
            from ..kernels import ops as kops

            def one(g, a):
                return kops.fused_adagrad(g, a, lr, eps)
            out = jax.tree_util.tree_map(one, grads, state["accum"])
            upd = jax.tree_util.tree_map(lambda o: o[0], out,
                                         is_leaf=lambda x: isinstance(x, tuple))
            acc = jax.tree_util.tree_map(lambda o: o[1], out,
                                         is_leaf=lambda x: isinstance(x, tuple))
            return upd, {"accum": acc}

        def one(g, a):
            gf = g.astype(jnp.float32)
            a_new = a + gf * gf
            return (-lr * gf / (jnp.sqrt(a_new) + eps)), a_new
        flat = jax.tree_util.tree_map(one, grads, state["accum"])
        upd = jax.tree_util.tree_map(lambda o: o[0], flat,
                                     is_leaf=lambda x: isinstance(x, tuple))
        acc = jax.tree_util.tree_map(lambda o: o[1], flat,
                                     is_leaf=lambda x: isinstance(x, tuple))
        return upd, {"accum": acc}

    return Optimizer(init, update)


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum:
            return {"mom": _zeros_like_f32(params)}
        return {}

    def update(grads, state, params=None):
        if momentum:
            mom = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state["mom"], grads)
            upd = jax.tree_util.tree_map(lambda m: -lr * m, mom)
            return upd, {"mom": mom}
        upd = jax.tree_util.tree_map(
            lambda g: -lr * g.astype(jnp.float32), grads)
        return upd, state

    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    def init(params):
        return {"m": _zeros_like_f32(params), "v": _zeros_like_f32(params),
                "t": jnp.int32(0)}

    def update(grads, state, params=None):
        t = state["t"] + 1
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(
                g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        upd = jax.tree_util.tree_map(
            lambda m_, v_: -lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps),
            m, v)
        return upd, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


def make_optimizer(name: str, lr: float, **kw) -> Optimizer:
    from .quantized import sm3
    return {"adagrad": adagrad, "sgd": sgd, "adam": adam,
            "sm3": sm3}[name](lr, **kw)
