"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Both are implemented in the exact stabilized *recurrent* form of the xLSTM
paper (arXiv:2405.04517) via ``lax.scan`` over the sequence; the per-step
state update is identical to the decode path, so train and decode share the
cell code.  Projections are batched matmuls outside the scan (MXU-friendly);
only the state recurrence lives inside the scan body.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import XLSTMConfig
from .initializers import dense_init, zeros_init


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------
def mlstm_init(rng, d_model: int, n_heads: int):
    dh = d_model // n_heads
    ks = jax.random.split(rng, 7)
    return {
        "wq": dense_init(ks[0], d_model, d_model).reshape(d_model, n_heads, dh),
        "wk": dense_init(ks[1], d_model, d_model).reshape(d_model, n_heads, dh),
        "wv": dense_init(ks[2], d_model, d_model).reshape(d_model, n_heads, dh),
        "w_i": dense_init(ks[3], d_model, n_heads, jnp.float32),
        "w_f": dense_init(ks[4], d_model, n_heads, jnp.float32),
        "f_bias": jnp.full((n_heads,), 3.0, jnp.float32),  # open forget gates
        "w_o": dense_init(ks[5], d_model, d_model),
        "out_proj": dense_init(ks[6], d_model, d_model),
    }


def make_mlstm_state(batch: int, n_heads: int, dh: int):
    return {
        "C": jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, n_heads, dh), jnp.float32),
        "m": jnp.zeros((batch, n_heads), jnp.float32),
    }


def _mlstm_cell(state, qkvif):
    """One step.  q,k,v: (B,H,dh); i,f: (B,H) pre-activations."""
    q, k, v, i_pre, f_pre = qkvif
    dh = q.shape[-1]
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state["m"], i_pre)
    f_act = jnp.exp(logf + state["m"] - m_new)
    i_act = jnp.exp(i_pre - m_new)
    kf = k.astype(jnp.float32) / jnp.sqrt(jnp.float32(dh))
    vf = v.astype(jnp.float32)
    C = f_act[..., None, None] * state["C"] + \
        i_act[..., None, None] * (kf[..., :, None] * vf[..., None, :])
    n = f_act[..., None] * state["n"] + i_act[..., None] * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), 1.0)
    h = num / den[..., None]
    return {"C": C, "n": n, "m": m_new}, h


def _mlstm_proj(params, x):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    i_pre = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), params["w_i"])
    f_pre = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32),
                       params["w_f"]) + params["f_bias"]
    o = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, params["w_o"])
                       .astype(jnp.float32))
    return q, k, v, i_pre, f_pre, o


# Sequence chunk for the nested-scan layout: the outer scan carries state at
# chunk boundaries only (the backward pass stores O(S/CHUNK) matrix states
# instead of O(S)); the remat'd inner scan recomputes within-chunk carries.
CHUNK = 256


def _chunked_cell_scan(cell, state, xs_seq):
    """xs_seq: tuple of (S, ...)-leading arrays.  Scan of remat'd chunks."""
    S = xs_seq[0].shape[0]
    c = min(CHUNK, S)
    if S % c != 0:               # fall back to flat scan for odd lengths
        return jax.lax.scan(lambda st, xs: cell(st, xs), state, xs_seq)
    n = S // c
    xs_c = tuple(a.reshape((n, c) + a.shape[1:]) for a in xs_seq)

    @jax.checkpoint
    def chunk_body(st, xs_chunk):
        from .layers import shard_batch_dim
        st = jax.tree_util.tree_map(shard_batch_dim, st)
        return jax.lax.scan(lambda s_, x_: cell(s_, x_), st, xs_chunk)

    state, hs = jax.lax.scan(chunk_body, state, xs_c)
    return state, hs.reshape((S,) + hs.shape[2:])


def mlstm_apply(params, x, state=None):
    """x: (B, S, d) -> (y, state)."""
    B, S, d = x.shape
    H = params["wq"].shape[1]
    q, k, v, i_pre, f_pre, o = _mlstm_proj(params, x)
    if state is None:
        state = make_mlstm_state(B, H, d // H)

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (q, k, v)) + \
        tuple(a.transpose(1, 0, 2) for a in (i_pre, f_pre))
    state, hs = _chunked_cell_scan(_mlstm_cell, state, xs)
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    y = h * o.astype(x.dtype)
    return jnp.einsum("bsd,de->bse", y, params["out_proj"]), state


def mlstm_decode(params, x, state):
    y, state = mlstm_apply(params, x, state)
    return y, state


# --------------------------------------------------------------------------
# Chunkwise-PARALLEL mLSTM (beyond-paper TPU adaptation; EXPERIMENTS §Perf)
#
# The token-sequential scan maps one tiny (B,H,dh,dh) update per step onto
# the VPU; the chunkwise form computes L tokens per step with (L,L) masked
# matmuls on the MXU and carries (C, n, m) across chunks.  Mathematically
# EXACT (same stabilized recurrence, reassociated):
#
#   b_t   = Σ_{s≤t} log σ(f_s)                      (within-chunk cum-decay)
#   m_t   = max(b_t + m_0, max_{s≤t}(b_t - b_s + i_s))
#   C̃_t  = e^{b_t+m_0-m_t} C_0 + Σ_{s≤t} e^{b_t-b_s+i_s-m_t} k̂_s v_sᵀ
#   h_t   = (q_t·C̃_t) / max(|q_t·ñ_t|, 1)          (k̂ = k/√dh)
#
# Equivalence vs the sequential cell is asserted in tests (atol 1e-4).
# --------------------------------------------------------------------------
PARALLEL_CHUNK = 64


def mlstm_apply_chunked(params, x, state=None, chunk: int = PARALLEL_CHUNK):
    B, S, d = x.shape
    H = params["wq"].shape[1]
    dh = d // H
    if state is None:
        state = make_mlstm_state(B, H, dh)
    if S % chunk != 0 or S < chunk:
        return mlstm_apply(params, x, state)

    q, k, v, i_pre, f_pre, o = _mlstm_proj(params, x)
    logf = jax.nn.log_sigmoid(f_pre)                   # (B,S,H)
    NC, L = S // chunk, chunk

    def c4(a):   # (B,S,H,dh) -> (NC,B,L,H,dh)
        return a.reshape(B, NC, L, H, -1).transpose(1, 0, 2, 3, 4)

    def c3(a):   # (B,S,H) -> (NC,B,L,H)
        return a.reshape(B, NC, L, H).transpose(1, 0, 2, 3)

    qs, ks, vs = c4(q.astype(jnp.float32)), c4(k.astype(jnp.float32)), \
        c4(v.astype(jnp.float32))
    is_, lf = c3(i_pre), c3(logf)
    tri = jnp.tril(jnp.ones((L, L), bool))             # s <= t

    @jax.checkpoint
    def chunk_step(carry, xs):
        C0, n0, m0 = carry                             # (B,H,dh,dh) etc.
        qL, kL, vL, iL, fL = xs                        # (B,L,H,*)
        kL = kL / jnp.sqrt(jnp.float32(dh))
        b = jnp.cumsum(fL, axis=1)                     # (B,L,H)
        # log-weights D[t,s] = b_t - b_s + i_s (s<=t), else -inf
        D = b[:, :, None, :] - b[:, None, :, :] + iL[:, None, :, :]
        D = jnp.where(tri[None, :, :, None], D, -jnp.inf)   # (B,L,L,H)
        m_intra = jnp.max(D, axis=2)                   # (B,L,H)
        m_t = jnp.maximum(b + m0[:, None, :], m_intra)
        # intra-chunk attention
        w = jnp.exp(D - m_t[:, :, None, :])            # (B,L,L,H)
        scores = jnp.einsum("blhd,bshd->blsh", qL, kL)
        num = jnp.einsum("blsh,bshd->blhd", w * scores, vL)
        den = jnp.sum(w * scores, axis=2)              # (B,L,H)
        # inter-chunk contribution
        scale0 = jnp.exp(b + m0[:, None, :] - m_t)     # (B,L,H)
        num = num + scale0[..., None] * jnp.einsum(
            "blhd,bhde->blhe", qL, C0)
        den = den + scale0 * jnp.einsum("blhd,bhd->blh", qL, n0)
        h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        # carry update at the chunk end
        bL = b[:, -1]                                  # (B,H)
        dm = bL[:, None, :] - b + iL                   # (B,L,H)
        m_new = jnp.maximum(bL + m0, jnp.max(dm, axis=1))
        wc = jnp.exp(dm - m_new[:, None, :])           # (B,L,H)
        C_new = jnp.exp(bL + m0 - m_new)[..., None, None] * C0 + \
            jnp.einsum("blh,blhd,blhe->bhde", wc, kL, vL)
        n_new = jnp.exp(bL + m0 - m_new)[..., None] * n0 + \
            jnp.einsum("blh,blhd->bhd", wc, kL)
        return (C_new, n_new, m_new), h

    (C, n, m), hs = jax.lax.scan(
        chunk_step, (state["C"], state["n"], state["m"]),
        (qs, ks, vs, is_, lf))
    # hs: (NC,B,L,H,dh) -> (B,S,d)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, d).astype(x.dtype)
    y = h * o.astype(x.dtype)
    return (jnp.einsum("bsd,de->bse", y, params["out_proj"]),
            {"C": C, "n": n, "m": m})


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------
def slstm_init(rng, d_model: int, n_heads: int):
    dh = d_model // n_heads
    ks = jax.random.split(rng, 3)
    return {
        # input gates pre-acts for (z, i, f, o), computed outside the scan
        "w_x": dense_init(ks[0], d_model, 4 * d_model, jnp.float32),
        # recurrent, head-block-diagonal: (H, dh, 4*dh)
        "r_h": (jax.random.normal(ks[1], (n_heads, dh, 4 * dh), jnp.float32)
                / jnp.sqrt(dh)),
        "bias": zeros_init((4 * d_model,), jnp.float32),
        "f_bias": jnp.full((n_heads, dh), 3.0, jnp.float32),
        "out_proj": dense_init(ks[2], d_model, d_model),
    }


def make_slstm_state(batch: int, n_heads: int, dh: int):
    z = jnp.zeros((batch, n_heads, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}


def _slstm_cell(params, state, wx_t, n_heads, dh):
    """wx_t: (B, 4*d) precomputed input contribution for this step."""
    rec = jnp.einsum("bhd,hde->bhe", state["h"], params["r_h"])  # (B,H,4dh)
    gates = wx_t.reshape(-1, n_heads, 4 * dh) + rec
    z_pre, i_pre, f_pre, o_pre = jnp.split(gates, 4, axis=-1)
    f_pre = f_pre + params["f_bias"]
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state["m"], i_pre)
    f_act = jnp.exp(logf + state["m"] - m_new)
    i_act = jnp.exp(i_pre - m_new)
    c = f_act * state["c"] + i_act * z
    n = f_act * state["n"] + i_act
    h = o * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_apply(params, x, state=None):
    """x: (B, S, d) -> (y, state)."""
    B, S, d = x.shape
    H = params["r_h"].shape[0]
    dh = d // H
    wx = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                    params["w_x"]) + params["bias"]
    if state is None:
        state = make_slstm_state(B, H, dh)

    def step(st, xs):
        (wx_t,) = xs
        st = _slstm_cell(params, st, wx_t, H, dh)
        return st, st["h"]

    state, hs = _chunked_cell_scan(step, state, (wx.transpose(1, 0, 2),))
    y = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    return jnp.einsum("bsd,de->bse", y, params["out_proj"]), state


def slstm_decode(params, x, state):
    y, state = slstm_apply(params, x, state)
    return y, state
