"""CELU-VFL core: K-party round engine, workset table, instance weighting,
protocol presets."""
from . import engine, protocol, weighting, workset  # noqa: F401
from .engine import (KPartyTask, PodTransport, SimWANTransport,  # noqa: F401
                     preset_config)
from .protocol import VFLTask, init_state, make_round, protocol_config  # noqa: F401
