"""Differential-privacy noise on the exchanged statistics (paper §4.2).

The paper argues CELU-VFL *strengthens* privacy because fewer messages
cross the boundary.  This module makes the complementary mechanism
first-class: per-round Gaussian noise on the wire tensors (Z_A uplink,
∇Z_A downlink) after L2 clipping — the standard Gaussian mechanism applied
to the cut tensors, so each party bounds what the other can infer per
message.  Composable with the workset: NOISED statistics are what gets
cached, so local updates add NO additional privacy cost (they reuse
already-released messages — the paper's communication reduction is also an
ε reduction under sequential composition).

``benchmarks.beyond`` sweeps sigma to chart the privacy/utility tradeoff.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class DPConfig(NamedTuple):
    clip: float = 1.0        # per-instance L2 clip of the message rows
    sigma: float = 0.0       # noise stddev as a multiple of clip (0 = off)


def clip_rows(x, clip: float):
    """Per-instance L2 clipping over flattened non-batch dims."""
    B = x.shape[0]
    flat = x.reshape(B, -1).astype(jnp.float32)
    n = jnp.linalg.norm(flat, axis=1, keepdims=True)
    scale = jnp.minimum(1.0, clip / jnp.maximum(n, 1e-12))
    return (flat * scale).reshape(x.shape).astype(x.dtype)


def wire_noise(rng, y, cfg: DPConfig):
    """The Gaussian-mechanism noise ALONE — ``y`` must already be clipped
    (sensitivity = cfg.clip).  Split out of :func:`privatize` so the
    compressed transport can add the noise to the DECODED wire value (after
    the codec, with the error-feedback residual already taken noise-free)
    and so the static auditor can mark exactly this op as the DP stage."""
    if cfg.sigma <= 0.0:
        return y
    noise = cfg.sigma * cfg.clip * jax.random.normal(
        rng, y.shape, jnp.float32)
    return (y.astype(jnp.float32) + noise).astype(y.dtype)


def privatize(rng, x, cfg: DPConfig):
    """Clip + add Gaussian noise (the released message)."""
    if cfg.sigma <= 0.0:
        return x
    return wire_noise(rng, clip_rows(x, cfg.clip), cfg)


def epsilon_per_release(cfg: DPConfig, delta: float = 1e-5) -> float:
    """Classic Gaussian-mechanism bound per released message (sensitivity =
    clip, both neighboring rows clipped): eps = sqrt(2 ln(1.25/delta))/sigma.
    CELU releases 1/(1+R) as many messages per model update as vanilla, so
    under sequential composition the per-update budget shrinks the same way
    the communication does."""
    import math
    if cfg.sigma <= 0:
        return float("inf")
    return math.sqrt(2 * math.log(1.25 / delta)) / cfg.sigma
