from .checkpoint import restore, save  # noqa: F401
