"""Production-mesh dry-run for one (arch x shape): lower + compile on the
2x16x16 multi-pod mesh and print the roofline terms.  No device allocation;
runs on any host.

    python examples/multipod_dryrun.py [--arch hymba-1.5b --shape train_4k]
"""
import argparse
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)  # dryrun.py sets the 512-device override
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", args.arch,
         "--shape", args.shape, "--multi-pod"],
        capture_output=True, text=True, env=env, timeout=3600)
    for line in out.stdout.splitlines():
        if line.startswith("{"):
            r = json.loads(line)
            print(f"arch={r['arch']} shape={r['shape']} mesh={r['mesh']} "
                  f"ok={r['ok']}")
            if r["ok"]:
                print(f"  roofline: " + ", ".join(
                    f"{k}={v:.4f}s" for k, v in r["roofline"].items()))
                print(f"  dominant: {r['dominant']}  "
                      f"temp={r['memory']['temp_bytes']/1e9:.1f} GB/device")
                print(f"  collectives: {r['collectives']}")
    if out.returncode != 0:
        print(out.stderr[-1000:])


if __name__ == "__main__":
    main()
