"""Serving throughput/latency: continuous batching over the party
boundary vs the sequential per-request loop -> ``results/BENCH_serve.json``.

The claim behind ``repro.serve``: one vmapped decode step over a
fixed-capacity lane array amortizes per-token dispatch across every
in-flight request, and the quantized activation ring + compressed uplink
shrink what crosses the party boundary per token — without changing the
greedy output (bit-exact at fp32, greedy-matched at int8).  The table
measures, at reduced smollm-360m geometry on the seeded open-loop load:

  * ``speedup_vs_sequential`` — closed-burst engine wall vs the SAME
    requests run one-at-a-time through the jitted monolithic
    prefill+decode loop (both sides honestly warmed: every jitted
    function is compiled AND executed untimed before the clock starts).
    Gated by ``benchmarks.compare`` as a wall metric (drift DOWN fails);
    the ``--check`` gate (CI) requires >= {MIN_SPEEDUP}x at capacity 8.
  * ``requests_per_sec`` / ``tokens_per_sec`` — absolute throughput,
    informational only (tracks the runner, not the code).
  * ``p50_token_latency_ms`` / ``p99_token_latency_ms`` — per-token
    latency percentiles under the open-loop Poisson load (arrival ->
    first token, then inter-token gaps), informational.
  * ``*_wire_bytes`` — exact per-message serving wire bytes (prefill
    uplink, per-token uplink, per-token downlink, whole-run total):
    deterministic counters, ANY increase fails the gate.
  * ``greedy_match_rate`` — fraction of generated tokens identical to
    the fp32 sequential reference (reported, not gated: an argmax near a
    tie may flip under quantization noise at random-init geometry).

``wire_full_*`` variants publish the analytic uplink bytes at FULL
smollm-360m geometry (d_model 960) — pure ``wire_bytes()`` math, no
model is instantiated.

    PYTHONPATH=src python -m benchmarks.serve [--check] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.compression import IdentityCodec, StochasticQuantCodec
from repro.models import vfl
from repro.serve import (LoadSpec, Request, ServeConfig, ServeEngine,
                         make_naive_fns, naive_generate, synth_requests)

from .common import csv_row

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "BENCH_serve.json")

ARCH = "smollm-360m"
CAPACITY = 8
PROMPT_LEN = 16
MAX_NEW = 16
N_REQUESTS = 32
PARAM_SEED = 2
MIN_SPEEDUP = 2.0          # --check floor on speedup_vs_sequential @ cap 8
# Why 2.0: the engine's decode step does CAPACITY lanes of work per
# dispatch where the sequential loop pays one dispatch per token per
# request; at capacity 8 on a single-core dev box the measured win is
# ~4-5x (both sides compile-free), so 2.0 asserts "genuinely faster"
# with headroom for runner variance.  The compare gate's 25% drift
# tolerance vs the committed baseline does the fine-grained ratcheting.


def _requests(cfg, rate: float):
    spec = LoadSpec(n_requests=N_REQUESTS, rate=rate,
                    prompt_len=PROMPT_LEN, max_new_tokens=MAX_NEW,
                    min_new_tokens=4, seed=0)
    return synth_requests(spec, cfg)


def sequential_baseline(params, cfg, requests):
    """Wall of serving the burst one request at a time through the
    jitted monolithic loop (compiled + run once untimed first), plus the
    per-request fp32 greedy references."""
    fns = make_naive_fns(cfg, PROMPT_LEN + MAX_NEW)
    batch = lambda r: {"tokens": jnp.asarray(r.prompt[None]),
                       "tokens_a": jnp.asarray(r.prompt_a[None])}
    warm = naive_generate(params, cfg, batch(requests[0]), MAX_NEW,
                          fns=fns)
    jax.block_until_ready(warm)
    refs = {}
    t0 = time.perf_counter()
    for r in requests:
        toks = naive_generate(params, cfg, batch(r), r.max_new_tokens,
                              fns=fns)
        refs[r.req_id] = np.asarray(toks)[0]
    wall = time.perf_counter() - t0
    return wall, refs


def run_engine_variant(name, params, cfg, scfg, refs, seq_wall, variants):
    eng = ServeEngine(params, cfg, scfg)
    t0 = time.perf_counter()
    eng.warm()
    compile_s = time.perf_counter() - t0

    # closed burst: throughput + exact byte counters
    burst = [Request(r.req_id, r.prompt, r.prompt_a, r.max_new_tokens)
             for r in _requests(cfg, rate=0.0)]
    comps, stats = eng.run(burst)
    wall = stats["virtual_duration_s"]
    total_tokens = stats["total_tokens"]
    matched = sum(int(np.sum(refs[c.req_id][:len(c.tokens)] == c.tokens))
                  for c in comps)

    # open loop at ~70% of measured throughput: latency percentiles
    rate = 0.7 * len(comps) / wall
    open_reqs = _requests(cfg, rate=rate)
    eng2 = ServeEngine(params, cfg, scfg).warm()
    comps2, _ = eng2.run(open_reqs)
    lats = []
    for c in comps2:
        prev = c.arrival
        for t in c.token_times:
            lats.append(t - prev)
            prev = t
    lats_ms = 1e3 * np.asarray(lats)

    row = {
        "capacity": scfg.capacity,
        "n_requests": len(comps),
        "total_tokens": total_tokens,
        "compression": scfg.compression or "fp32",
        "cache_dtype": scfg.cache_dtype,
        "refresh_every": scfg.refresh_every,
        "engine_wall_s": round(wall, 4),
        "sequential_wall_s": round(seq_wall, 4),
        "speedup_vs_sequential": round(seq_wall / wall, 2),
        "requests_per_sec": round(len(comps) / wall, 2),
        "tokens_per_sec": round(total_tokens / wall, 1),
        "p50_token_latency_ms": round(float(np.percentile(lats_ms, 50)), 3),
        "p99_token_latency_ms": round(float(np.percentile(lats_ms, 99)), 3),
        "openloop_rate_req_s": round(rate, 2),
        "prefill_up_wire_bytes": eng.prefill_up_bytes,
        "decode_token_up_wire_bytes": eng.step_up_bytes,
        "token_down_wire_bytes": eng.token_down_bytes,
        "run_wire_bytes": stats["wire_up_bytes"] + stats["wire_down_bytes"],
        "greedy_match_rate": round(matched / total_tokens, 4),
        "indicative_compile_s": round(compile_s, 2),
    }
    variants[name] = row
    csv_row(name, f"{row['speedup_vs_sequential']}x",
            row["requests_per_sec"], row["tokens_per_sec"],
            row["p50_token_latency_ms"], row["p99_token_latency_ms"],
            row["decode_token_up_wire_bytes"], row["greedy_match_rate"])
    return row


def wire_math_variant(name, d_model, prompt_len, codec, variants):
    """Analytic uplink accounting at FULL geometry: bytes for the prompt's
    (S, d) crossing and each decode token's (d,) row — ``wire_bytes()``
    only, nothing instantiated."""
    row = {
        "d_model": d_model,
        "prompt_len": prompt_len,
        "codec": type(codec).__name__,
        "prefill_up_wire_bytes": int(codec.wire_bytes((prompt_len, d_model),
                                                      jnp.float32)),
        "decode_token_up_wire_bytes": int(codec.wire_bytes((d_model,),
                                                           jnp.float32)),
    }
    variants[name] = row
    csv_row(name, "-", "-", "-", "-", "-",
            row["decode_token_up_wire_bytes"], "-")
    return row


def run_table():
    cfg = get_config(ARCH).reduced()
    params = vfl.init_all(jax.random.PRNGKey(PARAM_SEED), cfg)
    requests = _requests(cfg, rate=0.0)
    seq_wall, refs = sequential_baseline(params, cfg, requests)
    n_tok = sum(r.max_new_tokens for r in requests)
    csv_row(f"# serve: {N_REQUESTS} requests x <= {MAX_NEW} tokens "
            f"({n_tok} total), capacity {CAPACITY}, sequential baseline "
            f"{seq_wall:.2f} s (warmed)")
    csv_row("variant", "speedup", "req/s", "tok/s", "p50_ms", "p99_ms",
            "up_B/tok", "greedy_match")

    variants = {}
    base = dict(capacity=CAPACITY, prompt_len=PROMPT_LEN,
                max_new_tokens=MAX_NEW, ring_slots=4, seed=0)
    run_engine_variant(
        "serve_cb8_fp32", params, cfg,
        ServeConfig(compression="", cache_dtype="float32", **base),
        refs, seq_wall, variants)
    run_engine_variant(
        "serve_cb8_int8", params, cfg,
        ServeConfig(compression="int8", cache_dtype="int8", **base),
        refs, seq_wall, variants)
    run_engine_variant(
        "serve_cb8_int8_stale2", params, cfg,
        ServeConfig(compression="int8", cache_dtype="int8",
                    refresh_every=2, **base),
        refs, seq_wall, variants)

    full = get_config(ARCH)
    wire_math_variant("wire_full_smollm360m_fp32", full.d_model, 128,
                      IdentityCodec(), variants)
    wire_math_variant("wire_full_smollm360m_int8", full.d_model, 128,
                      StochasticQuantCodec(bits=8), variants)

    return {
        "geometry": {"arch": ARCH, "reduced": True, "capacity": CAPACITY,
                     "prompt_len": PROMPT_LEN, "max_new_tokens": MAX_NEW,
                     "n_requests": N_REQUESTS, "param_seed": PARAM_SEED},
        "load": {"generator": "seeded open-loop Poisson "
                              "(repro.serve.loadgen)",
                 "burst_note": "throughput + byte counters from the "
                               "closed burst (rate=0); latency "
                               "percentiles from an open-loop run at "
                               "~70% of measured throughput"},
        "variants": variants,
    }


def smoke() -> int:
    """CI fast-lane smoke: admit + 2 decode steps at reduced geometry
    through the int8 wire/ring, finite tokens out."""
    cfg = get_config(ARCH).reduced()
    params = vfl.init_all(jax.random.PRNGKey(PARAM_SEED), cfg)
    scfg = ServeConfig(capacity=4, prompt_len=8, max_new_tokens=3,
                       compression="int8", cache_dtype="int8",
                       ring_slots=2)
    eng = ServeEngine(params, cfg, scfg).warm()
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 8, dtype=np.int32),
                    rng.integers(0, cfg.aux_vocab_size, 8,
                                 dtype=np.int32), 3)
            for i in range(4)]
    comps, stats = eng.run(reqs)
    ok = (len(comps) == 4 and stats["total_tokens"] == 12
          and all(np.all((c.tokens >= 0) & (c.tokens < cfg.vocab_size))
                  for c in comps))
    csv_row(f"# serve smoke: 4 requests x 3 tokens (2 decode steps), "
            f"int8 wire+ring -> {'OK' if ok else 'BAD TOKENS'}")
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help=f"exit non-zero if speedup_vs_sequential at "
                         f"capacity {CAPACITY} drops below {MIN_SPEEDUP}x")
    ap.add_argument("--smoke", action="store_true",
                    help="run ONLY the 2-decode-step smoke and exit")
    args = ap.parse_args(argv)
    if args.smoke:
        return smoke()

    out = run_table()
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    csv_row(f"# wrote {os.path.normpath(RESULTS)}")

    if args.check:
        key = "serve_cb8_fp32"
        sp = out["variants"][key]["speedup_vs_sequential"]
        if sp < MIN_SPEEDUP:
            print(f"[FAIL] {key}.speedup_vs_sequential = {sp}x < "
                  f"{MIN_SPEEDUP}x floor")
            return 1
        print(f"serve gate: OK ({key} {sp}x >= {MIN_SPEEDUP}x)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
