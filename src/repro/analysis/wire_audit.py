"""Static byte accounting for the transport boundary.

Three ledgers must agree, per direction and per feature party:

  * **measured** — the bytes the wire PHYSICALLY carries: the payload
    avals ``codec.encode`` produces under ``jax.eval_shape`` (codes,
    scales, top-k indices, chained-stage payloads...), summed as
    ``prod(shape) * itemsize``.  For exact codecs (and the plain
    SimWAN transport) the wire carries the value itself at the wire
    dtype.
  * **claimed** — what the codec's ``wire_bytes()`` promises.
  * **reported** — what the transport's ``uplink_bytes`` /
    ``downlink_bytes`` / ``round_bytes`` counters feed the WAN clock,
    the pipeline scheduler's occupancy model, and every results table.

A codec that under-counts (compresses less than it reports) silently
inflates every communication-efficiency claim downstream — the audit
turns that into a named CI failure.  The trace cross-check closes the
other hole: every boundary mark the jaxpr contains must be one of the
accounted ``K`` up + ``K`` down crossings per exchange dispatch, so a
code path that sends MORE than the ledger (an extra sync, a debug
send) is also caught.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

import numpy as np

from .report import Finding
from .taint import TraceAudit


def payload_nbytes(codec, shape) -> int:
    """Wire bytes of one encoded message: sum of the payload leaf avals
    (shape inference only — nothing is executed)."""
    import jax
    import jax.numpy as jnp

    out = jax.eval_shape(
        lambda x: codec.encode(jax.random.PRNGKey(0), x),
        jax.ShapeDtypeStruct(tuple(shape), jnp.float32))
    return sum(int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
               for leaf in jax.tree_util.tree_leaves(out))


def _measured_bytes(tp, direction: str, shape) -> Tuple[int, str]:
    """(true wire bytes, codec label) for one message on this transport."""
    from ..core.engine import CompressedWANTransport

    if isinstance(tp, CompressedWANTransport):
        codec = tp.codecs[direction]
        if not getattr(codec, "exact", False):
            return payload_nbytes(codec, shape), type(codec).__name__
        return (int(np.prod(shape)) * tp.wire.itemsize,
                type(codec).__name__)
    return int(np.prod(shape)) * tp.wire.itemsize, type(tp).__name__


def audit_wire(tp, celu, z_shapes: Sequence[Tuple[int, ...]],
               trace: TraceAudit, n_computes: int, case: str,
               jobs: int = 0) -> Tuple[List[Finding], Dict[str, Any]]:
    """Cross-check measured vs claimed vs reported bytes, and reconcile
    the ledger against the boundary crossings the trace actually has.

    ``jobs > 0`` audits a BATCHED (vmapped fleet) trace: the byte ledger
    is still per job — ``z_shapes`` stay unbatched and every
    measured/claimed/reported check is unchanged — but each boundary
    crossing in the jaxpr must carry the leading ``(jobs,)`` axis (one
    mark moves the whole fleet's messages; a per-job mark count would
    mean the job axis was unrolled and the fleet compiles N programs)."""
    from ..core.engine import CompressedWANTransport

    findings: List[Finding] = []
    stats: Dict[str, Any] = {}

    def add(code, where, detail):
        findings.append(Finding(code=code, severity="error", where=where,
                                detail=detail, case=case))

    up_total = down_total = 0
    for i, shape in enumerate(z_shapes):
        for direction in ("up", "down"):
            reported = (tp.uplink_bytes(shape) if direction == "up"
                        else tp.downlink_bytes(shape))
            measured, codec_name = _measured_bytes(tp, direction, shape)
            where = f"{codec_name}[{direction}] party {i} z{tuple(shape)}"
            if measured != reported:
                add("wire.bytes-mismatch", where,
                    f"transport reports {reported} B/message but the "
                    f"encoded payload avals measure {measured} B — the "
                    f"WAN clock and every efficiency table are "
                    f"{'under' if reported < measured else 'over'}-counting "
                    f"by {abs(measured - reported)} B")
            if isinstance(tp, CompressedWANTransport):
                claimed = tp.codecs[direction].wire_bytes(shape, tp.wire)
                if claimed != measured and \
                        not getattr(tp.codecs[direction], "exact", False):
                    add("wire.bytes-mismatch", where,
                        f"codec.wire_bytes claims {claimed} B but encode "
                        f"emits {measured} B of payload")
            if direction == "up":
                up_total += measured
            else:
                down_total += measured

    round_reported = tp.round_bytes(z_shapes)
    if round_reported != up_total + down_total:
        add("wire.round-bytes", f"{type(tp).__name__}.round_bytes",
            f"round_bytes reports {round_reported} B but per-message "
            f"payloads sum to {up_total + down_total} B")

    # ledger vs trace: every boundary crossing in the jaxpr is accounted
    K = len(z_shapes)
    by_dir: Dict[str, list] = {"up": [], "down": []}
    for rec in trace.boundaries.values():
        by_dir.setdefault(rec.direction, []).append(rec)
    for direction in ("up", "down"):
        recs = by_dir[direction]
        expect = K * n_computes
        if len(recs) != expect:
            add("wire.unaccounted-boundary",
                f"{direction} boundary",
                f"trace contains {len(recs)} {direction} boundary "
                f"crossings but the byte ledger accounts "
                f"{expect} ({K} parties x {n_computes} exchange "
                f"dispatch(es)) — an unaccounted send would move bytes "
                f"the WAN clock never sees")
        for rec in recs:
            want = ((jobs,) if jobs else ()) \
                + tuple(z_shapes[rec.party % K])
            if rec.shape != want:
                add("wire.boundary-shape",
                    f"{direction}:{rec.party}",
                    f"boundary crossing has shape {rec.shape} but the "
                    f"accounted message for party {rec.party % K} is "
                    f"{want}")

    stats["uplink_bytes"] = up_total
    stats["downlink_bytes"] = down_total
    stats["round_bytes"] = round_reported
    stats["boundaries"] = len(trace.boundaries)
    if jobs:
        stats["jobs"] = jobs
    return findings, stats
