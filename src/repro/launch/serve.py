"""Serving driver: co-served split model, batched prefill + decode on CPU.

The party boundary survives as a module boundary (Party A's tower only sees
its inputs); decode shapes in the assignment lower this module's
``serve_step`` on the production mesh (launch.dryrun), while this driver
demonstrates the real loop on a REDUCED config:

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --prompt-len 32 --gen 16 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import vfl
from ..launch.steps import concrete_batch
from ..configs.base import ShapeConfig


def serve(args):
    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    B, S = args.batch, args.prompt_len
    shape = ShapeConfig("serve", S, B, "prefill")
    params = vfl.init_all(jax.random.PRNGKey(args.seed), cfg)
    batch = concrete_batch(cfg, shape, seed=args.seed)

    prefill = jax.jit(lambda p, b: vfl.prefill(p, cfg, b,
                                               total_len=S + args.gen))
    decode = jax.jit(lambda p, c, sb, pos: vfl.decode_step(p, cfg, c, sb,
                                                           pos))
    t0 = time.time()
    logits, caches = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    rng = np.random.default_rng(args.seed)
    outs = [np.asarray(toks)]
    t0 = time.time()
    for i in range(args.gen):
        step_batch = {"token": toks}
        if cfg.family not in ("vlm", "audio"):
            step_batch["token_a"] = jnp.asarray(rng.integers(
                0, cfg.aux_vocab_size, size=(B, 1), dtype=np.int32))
        logits, caches = decode(params, caches, step_batch,
                                jnp.int32(S + i))
        toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        outs.append(np.asarray(toks))
    jax.block_until_ready(logits)
    t_decode = time.time() - t0
    gen = np.concatenate(outs, axis=1)
    print(f"arch={cfg.name} B={B} prompt={S} gen={args.gen}")
    print(f"prefill {t_prefill*1e3:.1f} ms | decode "
          f"{t_decode/max(args.gen,1)*1e3:.1f} ms/token")
    print("generated token ids (first row):", gen[0][:16])
    return gen


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="full config (do NOT use on CPU)")
    args = ap.parse_args(argv)
    serve(args)


if __name__ == "__main__":
    main()
