"""Benchmark harness: one block per paper table/figure + roofline report.

  python -m benchmarks.run [--only ablation|end_to_end|roofline|micro]

Emits CSV blocks (``# name`` headers).  REPRO_BENCH_FULL=1 scales up.
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=("ablation", "end_to_end", "roofline", "micro",
                             "beyond", "local_scan", "pipeline_depth",
                             "chaos", "llm", "fleet"))
    args = ap.parse_args()

    from . import (ablation, beyond, chaos, end_to_end, fleet, llm,
                   local_scan, microbench, roofline)
    blocks = {
        "micro": microbench.main,
        "local_scan": local_scan.main,     # emits BENCH_local_scan.json
        # emits BENCH_llm.json (exact per-party HBM at full LLM geometry
        # + the at-rest quantization ladder; the fast CI lane runs it
        # --reduced --check, the nightly lane adds the convergence leg)
        "llm": llm.main,
        "roofline": roofline.main,
        "end_to_end": end_to_end.main,
        # emits BENCH_pipeline_depth.json (the depth-knob convergence
        # study; the nightly CI lane runs it with --check)
        "pipeline_depth": end_to_end.depth_sweep,
        "ablation": ablation.main,
        # emits BENCH_chaos.json (convergence under the seeded fault
        # matrix; the nightly chaos CI lane runs it with --check)
        "chaos": chaos.main,
        # emits BENCH_fleet.json (N jobs as one compiled vmapped program
        # vs the sequential host loop; the fast CI lane gates jobs/sec
        # drift via benchmarks.compare and the >=5x speedup floor)
        "fleet": fleet.main,
        "beyond": beyond.main,
    }
    picked = [args.only] if args.only else list(blocks)
    for name in picked:
        print(f"\n#### {name} " + "#" * 40, flush=True)
        t0 = time.time()
        blocks[name]()
        print(f"#### {name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == '__main__':
    main()
