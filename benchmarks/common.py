"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import dataclasses
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import CELUConfig  # noqa: E402
from repro.core import engine  # noqa: E402
from repro.data import synthetic as synth  # noqa: E402
from repro.models.tabular import DLRMConfig, auc, make_dlrm  # noqa: E402
from repro.optim import make_optimizer  # noqa: E402

# Smaller-than-paper but non-trivial default workload (paper: 41M rows,
# B=4096, z=256; here scaled to CPU).  REPRO_BENCH_FULL=1 doubles scale.
FULL = os.environ.get("REPRO_BENCH_FULL", "") == "1"


def default_workload(model: str = "wdl", spec_name: str = "criteo",
                     seed: int = 0):
    spec0 = synth.TABULAR_SPECS[spec_name]
    n_train = 65536 if FULL else 32768
    spec = dataclasses.replace(spec0, vocab=128, n_train=n_train,
                               n_test=8192)
    data = synth.make_tabular(spec, seed=seed)
    cfg = DLRMConfig(model, spec.fields_a, spec.fields_b, vocab=spec.vocab,
                     embed_dim=8, z_dim=32, hidden=(64, 32))
    return spec, data, cfg


def run_protocol(protocol: str, data, cfg, *, R=5, W=5, xi=60.0,
                 weighting=True, sampling=None, rounds=400, batch=256,
                 lr=0.01, optimizer="adagrad", seed=0, eval_every=25,
                 target_auc: Optional[float] = None,
                 fused_weighting: bool = True,
                 compression: Optional[str] = None,
                 pipeline_depth: int = 0,
                 pipeline_lr_damping: float = 0.25,
                 cache_dtype: str = "float32", cache_fused: bool = True,
                 opt_state_dtype: str = "float32",
                 transport=None, transport_hook=None, fault_plan=None
                 ) -> Dict[str, object]:
    """Train with one protocol preset of the K-party round engine; return
    the AUC-vs-round curve and (if target_auc given) the first round
    reaching it.  ``compression`` selects a wire codec
    (``core.compression.CODEC_SPECS``) for the simulated WAN (or pass an
    explicit ``transport``).  ``pipeline_depth=1`` runs the two-worker
    pipelined schedule (``engine.PipelinedEngine``): round t+1's exchange
    overlaps round t's local updates; ``pipeline_depth >= 2`` keeps a
    D-deep exchange queue with per-slot staleness damping
    (``pipeline_lr_damping`` is its eta/(1+c*s) coefficient; the first
    D-1 rounds fill the queue and report a NaN loss).  ``transport_hook(transport,
    val_loss) -> transport|None`` is the host-side control plane,
    consulted at every eval point — returning a NEW transport (e.g. an
    adaptive top-k ratio step) rebuilds the jitted round around it; the
    error-feedback residuals in the round state carry over.  The hook is
    fed the VALIDATION log-loss (computed from the test-set logits the
    eval already produces for AUC), not the smoothed train loss: the
    adaptive-sparsity schedule should loosen on a generalization plateau,
    and a depth-D pipeline's train-loss stream opens with D-1 NaNs.
    Rebuilds are pipeline-safe — the in-flight queue and residuals are
    dense data, independent of the codec's static shapes — so the hook
    now composes with ``pipeline_depth >= 1``.  ``fault_plan`` (a
    ``configs.base.FaultPlan``) runs the round schedule under the chaos
    engine (``core.faults.ChaosEngine``): seeded exchange drops with
    retry, stragglers, and party-dropout spans; telemetry lands in the
    result dict and wire bytes are charged per ATTEMPT."""
    init_fn, task, predict = make_dlrm(cfg)
    base = CELUConfig(R=R, W=W, xi_degrees=xi, weighting=weighting,
                      sampling=sampling or "round_robin",
                      pipeline_depth=pipeline_depth,
                      pipeline_lr_damping=pipeline_lr_damping,
                      cache_dtype=cache_dtype, cache_fused=cache_fused)
    ccfg, nloc = engine.preset_config(protocol, base)
    if sampling is not None and protocol == "celu":
        ccfg = dataclasses.replace(ccfg, sampling=sampling)
    params = init_fn(jax.random.PRNGKey(seed), cfg)
    opt_kw = {} if opt_state_dtype == "float32" \
        else {"state_dtype": opt_state_dtype}
    opt = make_optimizer(optimizer, lr, **opt_kw)
    it = synth.aligned_batches(data["train"], batch, seed=seed)
    _, ba, bb = next(it)
    asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
    etask = engine.lift_two_party(task)
    if transport is None:
        transport = engine.make_transport(ccfg, compression)
    state = engine.init_state(etask, engine.lift_two_party_params(params),
                              opt, ccfg, [asj(ba)], asj(bb),
                              transport=transport)
    z_shapes = [(batch, cfg.z_dim)]
    chaos = fault_plan is not None

    def build(tp, old=None):
        if chaos:
            from repro.core.faults import ChaosEngine
            pe = ChaosEngine(etask, opt, ccfg, plan=fault_plan,
                             depth=pipeline_depth, local_steps=nloc,
                             transport=tp,
                             fused_weighting=fused_weighting)
            if old is not None:   # transport_hook rebuild mid-run: the
                pe.load_host_state(old.host_state())  # fault clock carries
                pe.events, pe.counters = old.events, old.counters
            return pe
        if pipeline_depth:
            return engine.make_pipeline(etask, opt, ccfg,
                                        depth=pipeline_depth,
                                        local_steps=nloc, transport=tp,
                                        fused_weighting=fused_weighting)
        return engine.make_round(etask, opt, ccfg, local_steps=nloc,
                                 transport=tp,
                                 fused_weighting=fused_weighting,
                                 donate=transport_hook is None)

    pipelined = bool(pipeline_depth) or chaos
    drv = build(transport)
    if pipelined:
        rs = drv.init(state)
    it = synth.aligned_batches(data["train"], batch, seed=seed)

    te = data["test"]
    tea = {"x_a": jnp.asarray(te["x_a"])}
    teb = {"x_b": jnp.asarray(te["x_b"]), "y": jnp.asarray(te["y"])}
    curve: List[Tuple[int, float]] = []
    losses: List[float] = []
    bytes_total = 0
    bytes_curve: List[Tuple[int, int]] = []
    val_curve: List[Tuple[int, float]] = []
    reached = None
    prev_attempts = 0
    t0 = time.time()
    for i in range(rounds):
        bi, ba, bb = next(it)
        if pipelined:
            rs, m = drv.step(rs, [asj(ba)], asj(bb), bi)
        else:
            state, m = drv(state, [asj(ba)], asj(bb), bi)
        losses.append(m["loss"])       # device array: no per-round sync
        if chaos:
            # charge the wire per ATTEMPT: retried exchanges re-send,
            # dropped/stalled/dropout rounds send their true byte count
            att = drv.counters["wire_attempts"]
            bytes_total += (att - prev_attempts) \
                * transport.round_bytes(z_shapes)
            prev_attempts = att
        else:
            bytes_total += transport.round_bytes(z_shapes)
        if (i + 1) % eval_every == 0 or i + 1 == rounds:
            cur = rs.params if pipelined else state["params"]
            logits = np.asarray(predict(engine.unlift_params(cur),
                                        cfg, tea, teb), np.float64)
            a = auc(logits, te["y"])
            y = np.asarray(te["y"], np.float64)
            val_loss = float(np.mean(np.maximum(logits, 0.0)
                                     - logits * y
                                     + np.log1p(np.exp(-np.abs(logits)))))
            curve.append((i + 1, a))
            val_curve.append((i + 1, val_loss))
            bytes_curve.append((i + 1, bytes_total))
            if target_auc and reached is None and a >= target_auc:
                reached = i + 1
            if transport_hook is not None:
                new_tp = transport_hook(transport, val_loss)
                if new_tp is not None and new_tp is not transport:
                    transport = new_tp
                    drv = build(transport, drv if chaos else None)
    if pipelined:
        rs, _ = drv.flush(rs)
        state = drv.finalize(rs)
    up_b = sum(transport.uplink_bytes(s) for s in z_shapes)
    down_b = sum(transport.downlink_bytes(s) for s in z_shapes)
    from repro.core.workset import QUANT_KEYS, workset_nbytes
    tables = list(state["ws"]["a"]) + [state["ws"]["b"]]
    return {
        "protocol": protocol, "R": R, "W": W, "xi": xi,
        "cache_dtype": cache_dtype, "cache_fused": cache_fused,
        "opt_state_dtype": opt_state_dtype,
        "cache_bytes": sum(workset_nbytes(w) for w in tables),
        "stat_cache_bytes": sum(workset_nbytes(w, QUANT_KEYS)
                                for w in tables),
        "weighting": weighting, "curve": curve,
        "val_curve": val_curve,
        "final_auc": curve[-1][1], "best_auc": max(a for _, a in curve),
        "rounds_to_target": reached, "wall_s": time.time() - t0,
        "loss_curve": [float(x) for x in losses],
        "fault_telemetry": drv.telemetry() if chaos else None,
        "compression": compression or "",
        "pipeline_depth": pipeline_depth,
        "z_bytes_per_round": transport.round_bytes(z_shapes),
        "uplink_bytes_per_round": up_b,
        "downlink_bytes_per_round": down_b,
        "bytes_total": bytes_total,
        "bytes_curve": bytes_curve,
    }


def rounds_to(curve, target):
    """First eval round whose AUC >= target (None if never)."""
    for s, a in curve:
        if a >= target:
            return s
    return None


def smoothed(losses, k=25):
    """Trailing-k running mean over the finite entries of a loss curve
    (the depth-D pipeline's first D-1 rounds report NaN while the queue
    fills)."""
    xs = [x for x in losses if np.isfinite(x)]
    out = []
    for i in range(len(xs)):
        out.append(float(np.mean(xs[max(0, i - k + 1):i + 1])))
    return out


def rounds_to_loss(smoothed_curve, target):
    """First (1-based) smoothed round at or below the target loss."""
    for i, x in enumerate(smoothed_curve):
        if x <= target:
            return i + 1
    return None


def csv_row(*cols):
    print(",".join(str(c) for c in cols), flush=True)
