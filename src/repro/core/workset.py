"""The workset table: a device-resident ring buffer of cached statistics.

Paper §3.1: the table caches ``⟨i, Z_A^(i), ∇Z_A^(i), j⟩`` entries with two
clocks per entry — the insertion timestamp ``i`` (the communication round
that produced it) and the use count ``j``.  Eviction rules:

  * capacity: during the insertion at time ``i``, entries inserted before
    ``i - W + 1`` are dead (the ring buffer overwrites slot ``i mod W``, and
    the validity predicate ``insert_time > time - W`` retires the rest);
  * exhaustion: entries that reach ``R`` uses are dead.

Everything is a fixed-shape pytree of jnp arrays, so insert / sample /
tick are all jittable (``lax.dynamic_*`` only — no Python in the step) and
the table shards like any other training-state leaf (batch dim over the
``data`` mesh axis).

Each party owns its own table.  Besides the exchanged statistics, a party
caches its OWN features for the batch (Party A: ``X_A``; Party B: ``X_B, y``)
so local updates never touch the host — callers pass those through the
generic ``aux`` pytree.

Round-robin sampling (paper §3.2): a cursor walks slots in insertion order;
a slot cannot be re-sampled within ``W-1`` local steps by construction.
Consecutive sampling (FedBCD / the ``W=1`` degenerate case) always returns
the most recently inserted slot.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

INT_MIN = -(2 ** 30)


def workset_init(W: int, entry_example: Dict[str, Any]) -> Dict[str, Any]:
    """Create an empty table.  ``entry_example`` is a pytree of arrays with
    the per-batch shapes (e.g. {"z_a": (B,S,d), "dz_a": (B,S,d),
    "x": ..., "y": ...}); the table stacks a leading W axis."""
    buf = jax.tree_util.tree_map(
        lambda a: jnp.zeros((W,) + a.shape, a.dtype), entry_example)
    return {
        "buf": buf,
        "insert_time": jnp.full((W,), INT_MIN, jnp.int32),
        "use_count": jnp.zeros((W,), jnp.int32),
        "batch_idx": jnp.full((W,), -1, jnp.int32),
        "cursor": jnp.int32(0),
        "time": jnp.int32(0),      # communication rounds so far
    }


def workset_insert(ws: Dict[str, Any], entry: Dict[str, Any],
                   batch_idx) -> Dict[str, Any]:
    """Insert a fresh entry at ring slot ``time mod W``; bump the clock."""
    W = ws["insert_time"].shape[0]
    t = ws["time"]
    slot = jnp.mod(t, W)
    buf = jax.tree_util.tree_map(
        lambda b, e: jax.lax.dynamic_update_index_in_dim(b, e.astype(b.dtype),
                                                         slot, 0),
        ws["buf"], entry)
    return {
        "buf": buf,
        "insert_time": ws["insert_time"].at[slot].set(t),
        "use_count": ws["use_count"].at[slot].set(0),
        "batch_idx": ws["batch_idx"].at[slot].set(jnp.int32(batch_idx)),
        "cursor": ws["cursor"],
        "time": t + 1,
    }


def _valid_mask(ws: Dict[str, Any], R: int,
                pipeline_staleness: int = 0) -> jnp.ndarray:
    """(W,) bool — alive entries: inserted, not expired, not exhausted.

    ``pipeline_staleness`` tightens the expiry window: under a depth-D
    pipelined schedule every cached entry is D exchanges older by the time
    its sampled round completes, so the oldest D ring slots are retired
    early to keep the paper's max-staleness bound W."""
    t = ws["time"]
    W = ws["insert_time"].shape[0]
    # not expired (the ring overwrite also enforces this at staleness 0)
    alive = ws["insert_time"] >= t - W + pipeline_staleness
    alive &= ws["insert_time"] > INT_MIN    # ever inserted
    alive &= ws["use_count"] < R            # not exhausted
    return alive


def workset_sample(ws: Dict[str, Any], R: int, strategy: str, *,
                   rng=None, pipeline_staleness: int = 0
                   ) -> Tuple[Dict[str, Any], Dict[str, Any], jnp.ndarray,
                              jnp.ndarray]:
    """Draw one entry for a local update.

    strategy: "round_robin" — advance the cursor to the next alive slot
    (uniform over the table); "consecutive" — always the freshest slot
    (FedBCD); "uniform" — an independent uniform draw over the alive slots
    (requires ``rng``; the paper's §3.2 fair-sampling property holds per
    draw instead of per W-cycle).  Returns (new_ws, entry, batch_idx,
    valid) where ``valid`` is a bool scalar (False -> caller must no-op
    the update).
    """
    W = ws["insert_time"].shape[0]
    alive = _valid_mask(ws, R, pipeline_staleness)
    if strategy == "consecutive":
        slot = jnp.mod(ws["time"] - 1, W)
        valid = alive[slot]
        new_cursor = ws["cursor"]
    elif strategy == "uniform":
        if rng is None:
            raise ValueError("uniform sampling needs an rng key")
        # uniform over alive slots; with none alive the draw is degenerate
        # and ``valid`` masks it into a no-op
        logits = jnp.where(alive, 0.0, -jnp.inf)
        logits = jnp.where(jnp.any(alive), logits, jnp.zeros((W,)))
        slot = jax.random.categorical(rng, logits)
        valid = alive[slot]
        new_cursor = ws["cursor"]
    elif strategy == "round_robin":
        # STRICT cycle (paper §3.2 / Fig 4): the cursor advances by exactly
        # one per draw, so a slot cannot be re-sampled within W-1 draws.
        # Dead/empty slots yield an invalid (no-op) draw — the "bubbles" the
        # paper accepts in the first W-1 rounds.  Skipping dead slots
        # instead would collapse the schedule back to consecutive reuse of
        # the freshest batch (measured: identical curves for all W).
        slot = jnp.mod(ws["cursor"], W)
        valid = alive[slot]
        new_cursor = jnp.mod(slot + 1, W)
    else:
        raise ValueError(strategy)

    entry = jax.tree_util.tree_map(lambda b: b[slot], ws["buf"])
    new_ws = dict(ws)
    new_ws["use_count"] = ws["use_count"].at[slot].add(
        jnp.where(valid, 1, 0))
    if strategy == "round_robin":
        new_ws["cursor"] = new_cursor          # advance even on a bubble
    else:
        new_ws["cursor"] = jnp.where(valid, new_cursor, ws["cursor"])
    return new_ws, entry, ws["batch_idx"][slot], valid


def workset_stats(ws: Dict[str, Any], R: int) -> Dict[str, jnp.ndarray]:
    alive = _valid_mask(ws, R)
    return {
        "n_alive": jnp.sum(alive),
        "total_uses": jnp.sum(jnp.where(alive, ws["use_count"], 0)),
        "time": ws["time"],
    }
