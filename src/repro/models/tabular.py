"""Deep-learning recommendation models — the paper's own workloads (§5.1).

Two DLRMs over vertically-partitioned categorical fields:

  * **WDL** (Wide & Deep): each party embeds its fields; Party A's deep MLP
    emits ``Z_A`` (dim 256, the paper's exchanged dimensionality); Party B
    fuses ``[Z_A ‖ Z_B]`` through the top MLP and adds its own wide (linear)
    term.
  * **DSSM**: two symmetric towers; the "top model" is the scaled dot
    interaction between the tower embeddings (owned by Party B).

Both expose the :class:`repro.core.protocol.VFLTask` interface with a
logistic per-instance loss, plus ``predict_logits`` for AUC evaluation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence

import jax
import jax.numpy as jnp

from ..core.protocol import VFLTask
from .initializers import dense_init, zeros_init


@dataclass(frozen=True)
class DLRMConfig:
    model: str                  # wdl | dssm
    fields_a: int
    fields_b: int
    vocab: int = 1024
    embed_dim: int = 16
    z_dim: int = 256            # paper: output dimensionality of Z_A = 256
    hidden: Sequence[int] = (512, 256)


# --------------------------------------------------------------------------
def _mlp_init(rng, dims):
    ks = jax.random.split(rng, len(dims) - 1)
    return [{"w": dense_init(k, i, o, jnp.float32), "b": zeros_init((o,),
                                                                    jnp.float32)}
            for k, i, o in zip(ks, dims[:-1], dims[1:])]


def _mlp(params, x, final_act: bool = False):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def _tower_init(rng, cfg: DLRMConfig, n_fields: int, out_dim: int):
    ke, km = jax.random.split(rng)
    emb = jax.random.normal(ke, (n_fields, cfg.vocab, cfg.embed_dim),
                            jnp.float32) * 0.01
    dims = [n_fields * cfg.embed_dim, *cfg.hidden, out_dim]
    return {"embed": emb, "mlp": _mlp_init(km, dims)}


def _tower(params, x_fields):
    """x_fields: (B, F) int32 -> (B, out_dim)."""
    B, F = x_fields.shape
    f_idx = jnp.arange(F)
    e = params["embed"][f_idx[None, :], x_fields]    # (B, F, E)
    return _mlp(params["mlp"], e.reshape(B, -1))


# --------------------------------------------------------------------------
# WDL
# --------------------------------------------------------------------------
def wdl_init(rng, cfg: DLRMConfig):
    ka, kb, kt, kw = jax.random.split(rng, 4)
    return {
        "a": {"tower": _tower_init(ka, cfg, cfg.fields_a, cfg.z_dim)},
        "b": {"tower": _tower_init(kb, cfg, cfg.fields_b, cfg.z_dim),
              "top": _mlp_init(kt, [2 * cfg.z_dim, cfg.hidden[-1], 1]),
              "wide": jax.random.normal(
                  kw, (cfg.fields_b, cfg.vocab), jnp.float32) * 0.01,
              "bias": zeros_init((), jnp.float32)},
    }


def _wdl_task(cfg: DLRMConfig) -> VFLTask:
    def forward_a(pa, batch_a):
        return _tower(pa["tower"], batch_a["x_a"])

    def loss_b(pb, z_a, batch_b):
        z_b = _tower(pb["tower"], batch_b["x_b"])
        h = jnp.concatenate([z_a.astype(jnp.float32), z_b], axis=-1)
        logit = _mlp(pb["top"], h)[:, 0]
        F = batch_b["x_b"].shape[1]
        wide = pb["wide"][jnp.arange(F)[None, :], batch_b["x_b"]].sum(axis=1)
        logit = logit + wide + pb["bias"]
        y = batch_b["y"]
        li = jnp.maximum(logit, 0) - logit * y + jnp.log1p(
            jnp.exp(-jnp.abs(logit)))
        return li, jnp.float32(0.0)

    return VFLTask(forward_a, loss_b)


def wdl_predict(params, cfg: DLRMConfig, batch_a, batch_b):
    z_a = _tower(params["a"]["tower"], batch_a["x_a"])
    z_b = _tower(params["b"]["tower"], batch_b["x_b"])
    h = jnp.concatenate([z_a, z_b], axis=-1)
    logit = _mlp(params["b"]["top"], h)[:, 0]
    F = batch_b["x_b"].shape[1]
    wide = params["b"]["wide"][jnp.arange(F)[None, :],
                               batch_b["x_b"]].sum(axis=1)
    return logit + wide + params["b"]["bias"]


# --------------------------------------------------------------------------
# DSSM
# --------------------------------------------------------------------------
def dssm_init(rng, cfg: DLRMConfig):
    ka, kb = jax.random.split(rng)
    return {
        "a": {"tower": _tower_init(ka, cfg, cfg.fields_a, cfg.z_dim)},
        "b": {"tower": _tower_init(kb, cfg, cfg.fields_b, cfg.z_dim),
              "scale": jnp.float32(1.0), "bias": zeros_init((), jnp.float32)},
    }


def _dssm_logit(pb, z_a, z_b):
    # smooth normalization: sqrt(|x|^2 + eps) — NOT max(norm, eps), whose
    # gradient is 0 * d(sqrt)/dx = NaN at x = 0 (zero vectors occur for
    # round-robin "bubble" workset entries)
    def nrm(x):
        return x * jax.lax.rsqrt(
            jnp.sum(x * x, axis=-1, keepdims=True) + 1e-12)
    za = nrm(z_a.astype(jnp.float32))
    zb = nrm(z_b)
    return pb["scale"] * 10.0 * jnp.sum(za * zb, axis=-1) + pb["bias"]


def _dssm_task(cfg: DLRMConfig) -> VFLTask:
    def forward_a(pa, batch_a):
        return _tower(pa["tower"], batch_a["x_a"])

    def loss_b(pb, z_a, batch_b):
        z_b = _tower(pb["tower"], batch_b["x_b"])
        logit = _dssm_logit(pb, z_a, z_b)
        y = batch_b["y"]
        li = jnp.maximum(logit, 0) - logit * y + jnp.log1p(
            jnp.exp(-jnp.abs(logit)))
        return li, jnp.float32(0.0)

    return VFLTask(forward_a, loss_b)


def dssm_predict(params, cfg: DLRMConfig, batch_a, batch_b):
    z_a = _tower(params["a"]["tower"], batch_a["x_a"])
    z_b = _tower(params["b"]["tower"], batch_b["x_b"])
    return _dssm_logit(params["b"], z_a, z_b)


# --------------------------------------------------------------------------
def make_dlrm(cfg: DLRMConfig):
    """-> (init_fn, task, predict_fn)."""
    if cfg.model == "wdl":
        return wdl_init, _wdl_task(cfg), wdl_predict
    if cfg.model == "dssm":
        return dssm_init, _dssm_task(cfg), dssm_predict
    raise ValueError(cfg.model)


def auc(logits, labels) -> float:
    """Rank-based AUC (ties handled by average rank)."""
    import numpy as np
    s = np.asarray(logits, np.float64)
    y = np.asarray(labels)
    order = np.argsort(s)
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(s) + 1)
    # average ranks for ties
    ss = s[order]
    i = 0
    while i < len(ss):
        j = i
        while j + 1 < len(ss) and ss[j + 1] == ss[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = (i + j) / 2.0 + 1.0
        i = j + 1
    n_pos = float(y.sum())
    n_neg = float(len(y) - n_pos)
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[y > 0.5].sum() - n_pos * (n_pos + 1) / 2)
                 / (n_pos * n_neg))
