"""VFL split model: Party A bottom tower, Party B bottom+top towers.

See DESIGN §3.  The split keeps the paper's information-flow discipline at
the module boundary: ``forward_a`` only touches Party A params/features and
produces the exchanged activation ``Z_A``; ``loss_b`` consumes ``Z_A`` as an
explicit argument so the protocol layer can take ``∇Z_A = ∂loss/∂Z_A``
without ever handing Party B's params (or labels) to Party A.

Families:
  text  (dense/moe/hybrid/ssm): Party A has a token-aligned auxiliary
        feature stream; fusion = add (projected).
  vlm   : Party A = vision owner (patch embeddings -> projector);
          fusion = cross-attention (every ``cross_attn_every``-th layer).
  audio : Party A = audio encoder over frame embeddings;
          fusion = per-layer cross-attention in the decoder.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from .initializers import PARAM_DTYPE, dense_init, embed_init
from . import layers as L
from .backbone import (Ctx, tower_apply, tower_decode, tower_init,
                       tower_make_cache, tower_prefill, tower_stages)


# --------------------------------------------------------------------------
def _role(cfg: ArchConfig) -> str:
    return {"vlm": "vlm", "audio": "audio_dec"}.get(cfg.family, "text")


def stages_a(cfg: ArchConfig):
    if cfg.family == "vlm":
        return []                       # projector only
    if cfg.family == "audio":
        return tower_stages(cfg, cfg.vfl_split.layers_a, "enc")
    return tower_stages(cfg, cfg.vfl_split.layers_a, "text")


def stages_b(cfg: ArchConfig):
    role = _role(cfg)
    return tower_stages(cfg, cfg.vfl_split.layers_b, role)


def stages_top(cfg: ArchConfig):
    role = _role(cfg)
    return tower_stages(cfg, cfg.vfl_split.layers_top, role)


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------
def init_party_a(rng, cfg: ArchConfig):
    ks = jax.random.split(rng, 4)
    if cfg.family == "vlm":
        return {"proj1": dense_init(ks[0], cfg.d_frontend, cfg.d_model),
                "proj2": dense_init(ks[1], cfg.d_model, cfg.d_model)}
    if cfg.family == "audio":
        return {"proj": dense_init(ks[0], cfg.d_frontend, cfg.d_model),
                "tower": tower_init(ks[1], cfg, stages_a(cfg)),
                "ln": L.rmsnorm_init(cfg.d_model)}
    vocab_a = ((cfg.aux_vocab_size + 255) // 256) * 256
    return {"embed": embed_init(ks[0], vocab_a, cfg.d_model),
            "tower": tower_init(ks[1], cfg, stages_a(cfg))}


def init_party_b(rng, cfg: ArchConfig):
    ks = jax.random.split(rng, 6)
    p = {"embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model),
         "bottom": tower_init(ks[1], cfg, stages_b(cfg)),
         "top": tower_init(ks[2], cfg, stages_top(cfg)),
         "ln_f": L.rmsnorm_init(cfg.d_model),
         "head": dense_init(ks[3], cfg.d_model, cfg.padded_vocab)}
    if cfg.vfl_split.fusion == "add":
        p["fuse_proj"] = dense_init(ks[4], cfg.d_model, cfg.d_model)
    return p


def init_all(rng, cfg: ArchConfig):
    ka, kb = jax.random.split(rng)
    return {"a": init_party_a(ka, cfg), "b": init_party_b(kb, cfg)}


# --------------------------------------------------------------------------
# Party A forward
# --------------------------------------------------------------------------
def forward_a(params_a, cfg: ArchConfig, batch: Dict[str, Any],
              train: bool = False, remat: bool = True):
    """-> Z_A.  text: (B,S,d); vlm: (B,P,d); audio: (B,S_a,d)."""
    if cfg.family == "vlm":
        h = jax.nn.silu(jnp.einsum(
            "bpf,fd->bpd", batch["patches"].astype(PARAM_DTYPE),
            params_a["proj1"]).astype(jnp.float32)).astype(PARAM_DTYPE)
        return jnp.einsum("bpd,de->bpe", h, params_a["proj2"])
    if cfg.family == "audio":
        x = jnp.einsum("bsf,fd->bsd", batch["frames"].astype(PARAM_DTYPE),
                       params_a["proj"])
        S = x.shape[1]
        ctx = Ctx(cfg, positions=jnp.arange(S, dtype=jnp.int32),
                  causal=False, train=train, remat=remat,
                  window=cfg.sliding_window)
        x, _ = tower_apply(params_a["tower"], x, cfg, stages_a(cfg), ctx)
        return L.rmsnorm(params_a["ln"], x, cfg.norm_eps)
    x = params_a["embed"][batch["tokens_a"]]
    S = x.shape[1]
    ctx = Ctx(cfg, positions=jnp.arange(S, dtype=jnp.int32), train=train,
              remat=remat, window=cfg.sliding_window)
    x, _ = tower_apply(params_a["tower"], x, cfg, stages_a(cfg), ctx)
    return x


# --------------------------------------------------------------------------
# Party B forward / loss
# --------------------------------------------------------------------------
def _logits(h, params_b, cfg: ArchConfig):
    h = L.rmsnorm(params_b["ln_f"], h, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params_b["head"]).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad = cfg.padded_vocab - cfg.vocab_size
        mask = jnp.concatenate([jnp.zeros((cfg.vocab_size,), jnp.float32),
                                jnp.full((pad,), -1e30, jnp.float32)])
        logits = logits + mask
    return L.shard_logits(logits)


def forward_b(params_b, cfg: ArchConfig, z_a, batch: Dict[str, Any],
              train: bool = False, remat: bool = True):
    """-> (logits, aux).  z_a enters via the fusion declared by the split."""
    x = params_b["embed"][batch["tokens"]]
    S = x.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)
    fusion = cfg.vfl_split.fusion
    mem = z_a if fusion == "cross_attn" else None
    ctx = Ctx(cfg, positions=pos, memory=mem, train=train, remat=remat,
              window=cfg.sliding_window)
    x, aux1 = tower_apply(params_b["bottom"], x, cfg, stages_b(cfg), ctx)
    if fusion == "add":
        x = x + jnp.einsum("bsd,de->bse", z_a, params_b["fuse_proj"])
    x, aux2 = tower_apply(params_b["top"], x, cfg, stages_top(cfg), ctx)
    return _logits(x, params_b, cfg), aux1 + aux2


def per_instance_loss(params_b, cfg: ArchConfig, z_a, batch,
                      train: bool = True, remat: bool = True):
    """Cross-entropy per instance (B,) + aux scalar — Party B's objective."""
    logits, aux = forward_b(params_b, cfg, z_a, batch, train=train,
                            remat=remat)
    labels = batch["labels"]
    # Sharding-friendly cross-entropy: logsumexp + one-hot-reduction both
    # lower to vocab-dim-local reductions + psum when the vocab is sharded
    # over `model` (take_along_axis would all-gather the logits instead).
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = labels[..., None] == jnp.arange(logits.shape[-1],
                                             dtype=labels.dtype)
    label_logit = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = lse - label_logit
    return jnp.mean(nll, axis=-1), aux


def joint_loss(params, cfg: ArchConfig, batch, train: bool = True):
    """Vanilla VFL objective (both parties in one program)."""
    z_a = forward_a(params["a"], cfg, batch, train=train)
    li, aux = per_instance_loss(params["b"], cfg, z_a, batch, train=train)
    return jnp.mean(li) + aux


# --------------------------------------------------------------------------
# Serving (co-served split model; party boundary = module boundary)
# --------------------------------------------------------------------------
def serve_capacity(cfg: ArchConfig, seq_len: int) -> int:
    return min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len


def make_serve_cache(cfg: ArchConfig, batch: int, seq_len: int,
                     memory_len: int = 0):
    cap = serve_capacity(cfg, seq_len)
    cache = {
        "b": tower_make_cache(cfg, stages_b(cfg), batch, cap, memory_len),
        "top": tower_make_cache(cfg, stages_top(cfg), batch, cap, memory_len),
    }
    if cfg.family not in ("vlm", "audio"):
        cache["a"] = tower_make_cache(cfg, stages_a(cfg), batch, cap)
    return cache


def prefill_a(params_a, cfg: ArchConfig, batch, total_len: int = 0):
    """Party A's half of prefill -> (z_a, cache_a).

    z_a is the activation that crosses the party boundary (the ONLY thing
    Party B may see); cache_a is Party A's private decode KV state (None
    for cross-attn families, whose memory crosses once at prefill and is
    cached inside Party B's towers)."""
    if cfg.family in ("vlm", "audio"):
        return forward_a(params_a, cfg, batch), None
    S = batch["tokens_a"].shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)
    cap = serve_capacity(cfg, max(total_len, S))
    xa = params_a["embed"][batch["tokens_a"]]
    ctx_a = Ctx(cfg, positions=pos, window=cfg.sliding_window)
    z_a, _, cache_a = tower_prefill(params_a["tower"], xa, cfg,
                                    stages_a(cfg), ctx_a, cap)
    return z_a, cache_a


def prefill_b(params_b, cfg: ArchConfig, z_a, batch, total_len: int = 0):
    """Party B's half of prefill: consumes the exchanged z_a, returns
    (last-position logits, {"b","top"} caches).  Party A's params never
    enter this function — the party boundary is the argument list."""
    S = batch["tokens"].shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)
    cap = serve_capacity(cfg, max(total_len, S))
    caches: Dict[str, Any] = {}
    x = params_b["embed"][batch["tokens"]]
    fusion = cfg.vfl_split.fusion
    mem = z_a if fusion == "cross_attn" else None
    ctx = Ctx(cfg, positions=pos, memory=mem, window=cfg.sliding_window)
    x, _, caches["b"] = tower_prefill(params_b["bottom"], x, cfg,
                                      stages_b(cfg), ctx, cap)
    if fusion == "add":
        x = x + jnp.einsum("bsd,de->bse", z_a, params_b["fuse_proj"])
    x, _, caches["top"] = tower_prefill(params_b["top"], x, cfg,
                                        stages_top(cfg), ctx, cap)
    logits = _logits(x[:, -1:], params_b, cfg)
    return logits, caches


def prefill(params, cfg: ArchConfig, batch, total_len: int = 0):
    """Full-context forward producing last-position logits + decode caches.

    ``total_len``: prompt + expected generation length — sizes the KV ring
    buffer so full-attention archs don't silently evict the oldest tokens
    during decode (sliding-window archs cap at the window regardless).
    Composed from the per-party halves (prefill_a / prefill_b)."""
    z_a, cache_a = prefill_a(params["a"], cfg, batch, total_len)
    caches: Dict[str, Any] = {}
    if cache_a is not None:
        caches["a"] = cache_a
    logits, caches_b = prefill_b(params["b"], cfg, z_a, batch, total_len)
    caches.update(caches_b)
    return logits, caches


def decode_step_a(params_a, cfg: ArchConfig, cache_a, token_a, pos):
    """Party A's half of one-token decode -> (z_a_t (B,1,d), new_cache_a).

    z_a_t is the per-step boundary activation: on the serving wire it is
    what the up-codec encodes and the decode activation ring stores."""
    ctx = Ctx(cfg, pos=pos, window=cfg.sliding_window)
    xa = params_a["embed"][token_a]
    z_a_t, _, new_cache_a = tower_decode(params_a["tower"], xa, cfg,
                                         stages_a(cfg), ctx, cache_a)
    return z_a_t, new_cache_a


def decode_step_b(params_b, cfg: ArchConfig, caches, token, z_a_t, pos):
    """Party B's half of one-token decode.  caches: {"b","top"}; z_a_t is
    the (possibly cache-served, possibly dequantized) Party-A activation
    (None for cross-attn families).  -> (logits (B,1,V), new caches)."""
    ctx = Ctx(cfg, pos=pos, window=cfg.sliding_window)
    new_caches = dict(caches)
    x = params_b["embed"][token]
    x, _, new_caches["b"] = tower_decode(params_b["bottom"], x, cfg,
                                         stages_b(cfg), ctx, caches["b"])
    if cfg.vfl_split.fusion == "add":
        x = x + jnp.einsum("bsd,de->bse", z_a_t, params_b["fuse_proj"])
    x, _, new_caches["top"] = tower_decode(params_b["top"], x, cfg,
                                           stages_top(cfg), ctx,
                                           caches["top"])
    logits = _logits(x, params_b, cfg)
    return logits, new_caches


def decode_step(params, cfg: ArchConfig, caches, step_batch, pos):
    """One-token decode.  step_batch: {"token": (B,1)[, "token_a": (B,1)]}.

    pos: scalar int32 absolute position of the new token.  Returns
    (logits (B,1,V), new_caches).  Composed from the per-party halves."""
    new_caches = dict(caches)
    if cfg.family in ("vlm", "audio"):
        z_a_t = None
    else:
        z_a_t, new_caches["a"] = decode_step_a(
            params["a"], cfg, caches["a"], step_batch["token_a"], pos)
    logits, caches_b = decode_step_b(
        params["b"], cfg, {"b": caches["b"], "top": caches["top"]},
        step_batch["token"], z_a_t, pos)
    new_caches["b"] = caches_b["b"]
    new_caches["top"] = caches_b["top"]
    return logits, new_caches
