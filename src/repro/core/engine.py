"""The K-party CELU-VFL round engine — the ONE implementation of the
paper's round structure (arXiv:2207.14628, Algorithms 1-2).

A *round* is: exchange ⟨Z_i, ∇Z_i⟩ once for every feature party A_i
(i = 1..K), apply the fresh SGD step to all parties, insert the released
statistics into each party's device-resident workset table, then run up to
``R`` staleness-weighted local updates per party from that table.  The
named protocols are presets of this one structure:

  * Vanilla  = ``local_steps=0`` (exchange every model update);
  * FedBCD   = ``W=1`` consecutive sampling, no weighting;
  * CELU-VFL = round-robin sampling over W slots + Algorithm-2 weighting.

Two axes of parameterization:

**K feature parties.**  ``K`` is inferred from ``state["params"]["a"]`` (a
list of per-party pytrees).  ``K=1`` is the paper's two-party setting and
reproduces the historical ``core.protocol`` implementation bit-for-bit
(``tests/test_engine.py`` pins this against golden traces recorded from the
seed implementation).  ``K>=2`` is the multi-party extension the paper
defers to future work (§6): Party B weights each cached instance by the
MINIMUM per-party derivative cosine — an instance is only trusted if it is
fresh w.r.t. EVERY party's cut tensor.

**Transport.**  How the cut tensors move between parties is pluggable:

  * :class:`SimWANTransport` — in-process simulated WAN: wire-dtype
    quantization (bf16 wire halves bytes), optional Gaussian-mechanism DP
    noise, and byte accounting.  Subsumes the old ``protocol`` /
    ``multiparty`` paths.
  * :class:`CompressedWANTransport` — SimWAN plus a pluggable wire codec
    per direction (``core.compression``): top-k sparsification and/or
    int8/int4 stochastic-rounding quantization of every released message,
    with per-direction error-feedback residuals carried in the round
    state.
  * :class:`PodTransport` — ``lax.ppermute`` over the pod mesh axis for
    the SPMD party-to-pod mapping (:func:`make_pod_round`); the slow
    inter-pod DCN link plays the WAN.  Subsumes the old ``pod_protocol``
    exchange.

**Transports & compression.**  A transport exposes
``send(rng, x, res, direction) -> (wire_value, new_res)`` plus byte
accounting split by direction — ``uplink_bytes(shape)`` (Z_i, A_i -> B),
``downlink_bytes(shape)`` (∇Z_i, B -> A_i) and ``round_bytes(z_shapes) =
Σ_i up_i + down_i`` — so asymmetric wires (sparse top-k sketches up, dense
low-bit down) account exactly.  Codec selection: set
``CELUConfig.compression`` (or pass ``compression=`` to
:func:`make_round`) to a spec from ``core.compression.CODEC_SPECS``
("int8", "int4", "topk", "int8_topk" = top-k+int8 up / dense int8 down,
"up/down" picks each direction) and build the transport with
:func:`make_transport`.  Lossy codecs keep one error-feedback residual
per feature party per direction in ``state["transport"]`` (zeros from
``init_state(..., transport=...)``): each round the transport sends
``decode(encode(x + r))`` and carries ``r' = (x + r) - decoded`` forward,
so the decoded messages telescope to the uncompressed sum and compression
error is a one-round delay, not a loss.  The identity codec is
bit-identical to plain :class:`SimWANTransport` (golden-trace pinned).

The Algorithm-2 weighting hot path routes through the fused Pallas kernel
``kernels.ops.weighted_cotangent`` (cosine + threshold + cotangent scale in
one VMEM pass; bit-exact with the reference composition).  Pass
``fused_weighting=False`` to pin the pure-jnp reference path (the parity
oracle).

**Workset cache precision & the fused sample path.**  The ring buffers
behind the R-per-round local updates are built with
``CELUConfig.cache_dtype`` (``core.workset`` storage codec): "float32"
(verbatim, golden-pinned), "bfloat16", or "int8" (SR-quantized codes +
one fp32 scale per instance row — ~4x smaller; the table dominates
training-state memory at realistic W).  With ``CELUConfig.cache_fused``
(default on) each party-A local update consumes the sampled slot through
the gather→dequant→weight megakernel (``kernels/fused_sample.py``,
scalar-prefetched slot index): the stale ⟨Z, ∇Z⟩ rows are read once, in
storage precision, straight into the cosine/threshold/cotangent pass —
no full-precision entry copy is ever materialized in HBM.  The fp32
fused path is bit-identical to materialize-then-weight (the golden traces
run it); ``cache_fused=False`` pins the materializing reference.

The whole round is ONE jitted function (exchange + ``lax.scan`` over local
steps) so XLA's latency-hiding scheduler can overlap the cross-party
transfer with the local-update chain — the SPMD analogue of the paper's
background communication worker.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, \
    Sequence, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import CELUConfig, validate_pipeline_depth
from ..optim import Optimizer, apply_updates
from .weighting import (instance_weights, pipeline_attenuation,
                        static_staleness, xi_to_cos)
from .workset import (CastLeaf, Quant4Leaf, QuantLeaf, decode_entry,
                      workset_draw, workset_entry, workset_init,
                      workset_insert,
                      workset_sample)  # noqa: F401  (workset_sample re-exported: historical import site)


class KPartyTask(NamedTuple):
    """K-party split-model interface (information-flow discipline at
    function granularity — no function sees two parties' raw features):

        forward_a(params_a_i, batch_a_i) -> Z_i
        loss_b(params_b, [Z_1..Z_K], batch_b) -> (per-instance loss, aux)
    """
    forward_a: Callable[[Any, Any], jnp.ndarray]
    loss_b: Callable[[Any, Sequence[jnp.ndarray], Any],
                     Tuple[jnp.ndarray, jnp.ndarray]]


def lift_two_party(task) -> KPartyTask:
    """Adapt a two-party task (``loss_b`` over one Z_A) to the K-party
    interface (``loss_b`` over ``[Z_1..Z_K]``, K=1)."""
    return KPartyTask(
        task.forward_a,
        lambda pb, z_list, batch_b: task.loss_b(pb, z_list[0], batch_b))


def lift_two_party_params(params: Dict[str, Any]) -> Dict[str, Any]:
    """{"a": pa, "b": pb} -> the engine's {"a": [pa], "b": pb}."""
    return {"a": [params["a"]], "b": params["b"]}


def unlift_params(params: Dict[str, Any]) -> Dict[str, Any]:
    """Engine {"a": [pa], "b": pb} -> the two-party {"a": pa, "b": pb}."""
    (pa,) = params["a"]
    return {"a": pa, "b": params["b"]}


# --------------------------------------------------------------------------
# Transports
# --------------------------------------------------------------------------
class SimWANTransport:
    """In-process slow link: each released message is round-tripped through
    the wire dtype (simulating quantized transmission) after optional
    DP noising; byte accounting follows the wire precision.

    The noised + quantized value is what BOTH sides see and what gets
    cached, so local updates reuse already-released messages at no extra
    privacy cost."""

    def __init__(self, celu: CELUConfig):
        self.celu = celu
        self.wire = jnp.dtype(celu.wire_dtype)

    @property
    def stateful_directions(self):
        """Directions ("up"/"down") whose per-round state must exist in
        ``state["transport"]`` (none: this transport is stateless)."""
        return ()

    def init_state(self, z_examples: Sequence) -> Dict[str, Any]:
        """Per-round transport state (empty: this transport is stateless)."""
        return {}

    def _wire_cast(self, x):
        """Round-trip through the wire dtype (the simulated quantized
        transmission).  A separate method so every send path shares one
        wire stage — and so the static auditor
        (:mod:`repro.analysis`) can mark exactly this op as the
        registered wire crossing."""
        if x.dtype != self.wire:
            x = x.astype(self.wire).astype(x.dtype)
        return x

    def send(self, rng, x, res=None, direction: str = "up"):
        """The message actually released across the link.  ``res`` is the
        per-message error-feedback residual (unused here — threaded through
        for stateful transports).  -> (wire value, new residual)."""
        if self.celu.dp_sigma > 0.0:
            from .privacy import DPConfig, privatize
            x = privatize(rng, x, DPConfig(clip=self.celu.dp_clip,
                                           sigma=self.celu.dp_sigma))
        return self._wire_cast(x), res

    def message_bytes(self, z_shape) -> int:
        import numpy as np
        return int(np.prod(z_shape)) * self.wire.itemsize

    def uplink_bytes(self, z_shape) -> int:
        """Bytes of one released Z_i (feature party -> label party)."""
        return self.message_bytes(z_shape)

    def downlink_bytes(self, z_shape) -> int:
        """Bytes of one released ∇Z_i (label party -> feature party)."""
        return self.message_bytes(z_shape)

    def round_bytes(self, z_shapes: Sequence) -> int:
        """Bytes per communication round: the message count is explicit —
        one uplink (Z_i) plus one downlink (∇Z_i) per feature party —
        so transports with asymmetric up/down payloads account correctly."""
        return sum(self.uplink_bytes(s) + self.downlink_bytes(s)
                   for s in z_shapes)

    def recover_dropped(self, fresh: Dict[str, Any]) -> Dict[str, Any]:
        """Transport state to resume from when ``fresh``'s wire transfer
        is LOST (the chaos engine abandons an exchange after its retry
        budget).  A stateless transport has nothing to recover — the
        update the dropped messages carried is simply gone (graceful
        degradation: the local scan keeps running on cached statistics).
        Stateful transports override this to fold the lost messages back
        into their error-feedback residuals."""
        return fresh["tstate"]


class CompressedWANTransport(SimWANTransport):
    """Compressed wire (Compressed-VFL): every released message passes the
    SimWAN pipeline (DP noise + wire dtype) and then a per-direction codec
    from :mod:`repro.core.compression` under error feedback.

    Lossy directions carry one residual per feature party in the engine's
    ``state["transport"]`` (``{"up": [r_1..r_K], "down": [...]}`` — built
    by :meth:`init_state`); each send compresses ``x + r`` and keeps the
    compression error as the next round's residual.  With the identity
    codec the pipeline is bit-identical to plain :class:`SimWANTransport`
    and no residual state is kept."""

    def __init__(self, celu: CELUConfig, up_codec=None, down_codec=None):
        super().__init__(celu)
        from .compression import IdentityCodec
        up = up_codec if up_codec is not None else IdentityCodec()
        self.codecs = {"up": up,
                       "down": down_codec if down_codec is not None else up}

    @property
    def stateful_directions(self):
        return tuple(d for d, c in self.codecs.items() if not c.lossless)

    def init_state(self, z_examples: Sequence) -> Dict[str, Any]:
        """Zero error-feedback residuals, one per party per lossy
        direction; ``z_examples`` are the K cut-tensor avals."""
        return {d: [jnp.zeros(z.shape, jnp.float32) for z in z_examples]
                for d in self.stateful_directions}

    def send(self, rng, x, res=None, direction: str = "up"):
        codec = self.codecs[direction]
        exact = getattr(codec, "exact", False)
        if self.celu.dp_sigma > 0.0 and not exact:
            # DP over a LOSSY codec: the noise must ride the ENCODED
            # value, not the pre-compression one.  Noising before encode
            # would (a) spend wire bits and top-k slots on transmitting
            # noise and (b) leak the noise into the error-feedback
            # residual, whose next-round retransmission CANCELS it —
            # error feedback would silently undo the privacy mechanism.
            # So: clip -> wire cast -> +residual -> encode/decode ->
            # noise-free residual -> Gaussian noise on the decoded wire
            # value.  The residual never sees (and never repays) the
            # noise; sensitivity is still dp_clip because clipping
            # happens before everything the other party observes.
            from .privacy import DPConfig, clip_rows, wire_noise
            cfg = DPConfig(clip=self.celu.dp_clip,
                           sigma=self.celu.dp_sigma)
            xc = self._wire_cast(clip_rows(x, cfg.clip))
            e = xc.astype(jnp.float32)
            if res is not None:
                e = e + res
            payload = codec.encode(jax.random.fold_in(rng, 1), e)
            y = codec.decode(payload, e)
            new_res = None if res is None else e - y
            y = wire_noise(jax.random.fold_in(rng, 2), y, cfg)
            return y.astype(x.dtype), new_res
        x, _ = super().send(rng, x, None, direction)
        if exact:
            # bitwise round-trip (identity): nothing to encode — this is
            # what keeps the identity wire golden-trace-identical to
            # SimWANTransport.  Merely-lossless codecs (fp32-rounding
            # round-trips like a chain ending in identity) still run
            # encode/decode so the wire matches the byte accounting.
            return x, res
        e = x.astype(jnp.float32)
        if res is not None:
            e = e + res
        payload = codec.encode(jax.random.fold_in(rng, 1), e)
        y = codec.decode(payload, e)
        return y.astype(x.dtype), None if res is None else e - y

    def uplink_bytes(self, z_shape) -> int:
        return self.codecs["up"].wire_bytes(z_shape, self.wire)

    def downlink_bytes(self, z_shape) -> int:
        return self.codecs["down"].wire_bytes(z_shape, self.wire)

    def recover_dropped(self, fresh: Dict[str, Any]) -> Dict[str, Any]:
        """Error-feedback recovery of a LOST exchange: fold each dropped
        decoded message back into its direction's residual.

        The send computed ``y = decode(encode(x + r))`` and carried
        ``r' = (x + r) - y`` forward; if ``y`` never arrives, setting
        ``r'' = r' + y = x + r`` makes the NEXT successful send transmit
        the accumulated ``x + r`` in full — the telescoping invariant
        (decoded messages sum to the uncompressed signal) survives the
        drop as a delay instead of a loss.  Under DP the dropped ``y``
        includes its noise draw, so the recovered residual carries that
        noise into the next release — conservative (the eventually
        delivered value is noisier than required), never under-noised,
        and the dropped noise was never observed so no budget is
        double-spent.  Lossless directions keep no residual and degrade
        like the stateless base."""
        ts = dict(fresh["tstate"])
        for d in self.stateful_directions:
            vals = fresh["zs"] if d == "up" else fresh["dzs"]
            ts[d] = [r + v.astype(jnp.float32)
                     for r, v in zip(ts[d], vals)]
        return ts

    def scheduled(self, loss) -> "CompressedWANTransport":
        """Host-side control plane: offer one (smoothed) loss observation
        to each direction codec's adaptive hook (e.g. the top-k
        ``ratio_schedule``).  Returns ``self`` when nothing fired, else a
        new transport around the re-ratioed codecs — rebuild the jitted
        round with it; the error-feedback residuals in the round state are
        dense and carry over unchanged."""
        # consult each DISTINCT codec once: with a symmetric wire both
        # directions alias one codec object, and double-consulting would
        # halve the schedule's patience and let the directions diverge
        seen: Dict[int, Any] = {}
        for c in self.codecs.values():
            if id(c) not in seen:
                seen[id(c)] = c.scheduled(loss) if hasattr(c, "scheduled") \
                    else c
        new = {d: seen[id(c)] for d, c in self.codecs.items()}
        if all(new[d] is self.codecs[d] for d in self.codecs):
            return self
        return CompressedWANTransport(self.celu, new["up"], new["down"])


def make_transport(celu: CELUConfig, compression: Optional[str] = None):
    """Transport factory for the simulated WAN.  ``compression`` (falling
    back to ``celu.compression``) is a codec spec from
    ``core.compression.CODEC_SPECS``; empty -> plain SimWANTransport."""
    name = celu.compression if compression is None else compression
    if not name:
        return SimWANTransport(celu)
    from .compression import make_codec_pair
    up, down = make_codec_pair(name)
    return CompressedWANTransport(celu, up, down)


class PodTransport:
    """Cut-tensor exchange as ``lax.ppermute`` over the pod mesh axis (the
    ONLY collectives crossing the slow inter-pod link).  Party A lives on
    pod 0, Party B on pod 1 by default."""

    def __init__(self, axis: str = "pod",
                 up: Sequence[Tuple[int, int]] = ((0, 1), (1, 0)),
                 down: Sequence[Tuple[int, int]] = ((1, 0), (0, 1))):
        self.axis = axis
        self.up = [tuple(p) for p in up]
        self.down = [tuple(p) for p in down]

    def send_up(self, z):
        """Z_A: feature pod -> label pod."""
        return jax.lax.ppermute(z, self.axis, self.up)

    def send_down(self, dz):
        """∇Z_A: label pod -> feature pod."""
        return jax.lax.ppermute(dz, self.axis, self.down)


# --------------------------------------------------------------------------
# Algorithm-2 weighting (the shared hot path)
# --------------------------------------------------------------------------
def _bcast(w, like):
    """(B,) weights -> broadcastable to ``like``'s shape."""
    return w.reshape(w.shape + (1,) * (like.ndim - 1)).astype(jnp.float32)


def _fusable(x) -> bool:
    """The Pallas kernel tiles the batch dim at BLOCK_B; odd batch sizes
    fall back to the reference composition."""
    from ..kernels.cosine_weight import BLOCK_B
    B = x.shape[0]
    return B % min(BLOCK_B, B) == 0


def staleness_weights(ad_hoc, stale, cos_xi: float, *,
                      fused: bool = False) -> jnp.ndarray:
    """Algorithm-2 ``InsWeight``: per-instance cosine floored at cos ξ.

    NOTE: the pipeline-staleness discount is NOT applied here — callers
    that need it (``local_grad_b`` after its K-party minimum,
    ``weighted_cotangent`` for the feature-party path) apply
    :func:`repro.core.weighting.pipeline_attenuation` exactly once."""
    if fused and _fusable(ad_hoc):
        from ..kernels import ops as kops
        return kops.cosine_weight(ad_hoc, stale, cos_xi)
    return instance_weights(ad_hoc, stale, cos_xi)


def _attenuate_post_scale(w, cot, staleness):
    """Compose the depth-s pipeline discount onto a fused kernel's
    (w, w ⊙ ∇Z): -> (w^(1+s), w^s ⊙ (w ⊙ ∇Z)) — the same law as
    :func:`repro.core.weighting.pipeline_attenuation`, applied so the
    discounted weight still multiplies the cotangent exactly once.

    ``staleness`` may be a static Python int (depths 0/1 — 0 skips the
    post-scale entirely, preserving the golden-pinned bitstream) or a jnp
    int scalar: the depth-D queue's PER-SLOT offset, traced through the
    jitted scan.  The dynamic path always applies the scale — ``w ** 0``
    is exactly 1 (also at w = 0), so runtime s = 0 is still the
    identity."""
    if static_staleness(staleness) and not staleness:
        return w, cot
    extra = w ** staleness
    w = w * extra
    cot = cot * _bcast(extra, cot)
    return w, cot


def weighted_cotangent(ad_hoc, stale, dz, cos_xi: float, *,
                       fused: bool = True, pipeline_staleness=0
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """InsWeight + weights ⊙ ∇Z -> (weights (B,), fp32 weighted cotangent).

    ``fused=True`` runs the single-VMEM-pass Pallas kernel; the reference
    composition is its bit-exact oracle.  ``pipeline_staleness`` (static
    int or a traced per-slot jnp scalar) composes with the fused kernel as
    a cheap post-scale (see :func:`_attenuate_post_scale`)."""
    if fused and _fusable(ad_hoc):
        from ..kernels import ops as kops
        w, cot = kops.weighted_cotangent(ad_hoc, stale,
                                         dz.astype(jnp.float32), cos_xi)
        return _attenuate_post_scale(w, cot, pipeline_staleness)
    w = instance_weights(ad_hoc, stale, cos_xi)
    w = pipeline_attenuation(w, pipeline_staleness)
    return w, _bcast(w, dz) * dz.astype(jnp.float32)


# --------------------------------------------------------------------------
# Local-update gradients (Algorithm 2) — shared by every protocol shape
# --------------------------------------------------------------------------
def _grad_a_tail(z_new, vjp, stale_z, stale_dz, cos_xi: float, *,
                 weighting: bool, fused: bool, mask,
                 pipeline_staleness):
    """Shared tail of the feature-party local update once the stale
    statistics are materialized: InsWeight + cotangent scale + backward."""
    if weighting:
        w, cot = weighted_cotangent(z_new, stale_z, stale_dz, cos_xi,
                                    fused=fused,
                                    pipeline_staleness=pipeline_staleness)
    else:
        w = jnp.ones((z_new.shape[0],), jnp.float32)
        cot = _bcast(w, z_new) * stale_dz.astype(jnp.float32)
    if mask is not None:
        w = w * mask
        cot = cot * mask
    (g,) = vjp(cot.astype(z_new.dtype))
    return g, w


def local_grad_a(forward_a, params_a, entry, cos_xi: float, *,
                 weighting: bool = True, fused: bool = True, mask=None,
                 pipeline_staleness=0):
    """Feature-party local update: ad-hoc forward on the cached batch,
    stale cotangent ∇Z^(i) weighted by cos(Z^(i,j), Z^(i)).

    ``entry`` is a workset row {"z": stale Z, "dz": stale ∇Z, "batch": own
    features}.  ``mask`` (scalar 0/1, optional) zeroes the whole draw (a
    round-robin bubble).  Returns (grads, weights)."""
    z_new, vjp = jax.vjp(lambda p: forward_a(p, entry["batch"]), params_a)
    return _grad_a_tail(z_new, vjp, entry["z"], entry["dz"], cos_xi,
                        weighting=weighting, fused=fused, mask=mask,
                        pipeline_staleness=pipeline_staleness)


def _ring_view(store):
    """Storage leaf -> the raw full-precision-or-bf16 ring array (QuantLeaf
    handled separately by the q8 kernel)."""
    return store.v if isinstance(store, CastLeaf) else store


def _fused_ring_sample(slot, z_new, z_store, dz_store, cos_xi: float):
    """One-VMEM-pass sample: gather slot from the (possibly quantized)
    ring, dequantize, row-cosine vs the ad-hoc z, threshold, scale the
    stale cotangent.  -> (weights (B,), fp32 weighted cotangent)."""
    from ..kernels import ops as kops
    if isinstance(z_store, Quant4Leaf):
        return kops.fused_gather_weight_q4(
            slot, z_new.astype(jnp.float32), z_store.q, z_store.scale,
            dz_store.q, dz_store.scale, cos_xi)
    if isinstance(z_store, QuantLeaf):
        return kops.fused_gather_weight_q8(
            slot, z_new.astype(jnp.float32), z_store.q, z_store.scale,
            dz_store.q, dz_store.scale, cos_xi)
    return kops.fused_gather_weight(slot, z_new, _ring_view(z_store),
                                    _ring_view(dz_store), cos_xi)


def local_grad_a_cached(forward_a, params_a, ws, slot, cos_xi: float, *,
                        weighting: bool = True, fused: bool = True,
                        cache_fused: bool = True, mask=None,
                        pipeline_staleness=0):
    """Feature-party local update straight off the workset ring — the
    single-pass hot path.  Only the party's OWN cached features are
    gathered (the forward needs them); the cut statistics ⟨Z, ∇Z⟩ are
    consumed by the fused gather→dequant→weight megakernel
    (``kernels/fused_sample.py``) without ever materializing a
    full-precision entry copy in HBM.  ``cache_fused=False`` (or an
    unfusable batch tiling, or ``weighting``/``fused`` off) falls back to
    materialize-then-weight — the bit-exact reference composition.
    Returns (grads, weights)."""
    buf = ws["buf"]
    batch = jax.tree_util.tree_map(lambda b: b[slot], buf["batch"])
    z_new, vjp = jax.vjp(lambda p: forward_a(p, batch), params_a)
    if weighting and fused and cache_fused and _fusable(z_new):
        w, cot = _fused_ring_sample(slot, z_new, buf["z"], buf["dz"],
                                    cos_xi)
        w, cot = _attenuate_post_scale(w, cot, pipeline_staleness)
        if mask is not None:
            w = w * mask
            cot = cot * mask
        (g,) = vjp(cot.astype(z_new.dtype))
        return g, w
    entry = workset_entry(ws, slot)
    return _grad_a_tail(z_new, vjp, entry["z"], entry["dz"], cos_xi,
                        weighting=weighting, fused=fused, mask=mask,
                        pipeline_staleness=pipeline_staleness)


def local_grad_b(loss_b, params_b, entry, cos_xi: float, *,
                 weighting: bool = True, fused: bool = True, mask=None,
                 pipeline_staleness=0):
    """Label-party local update: stale Z_i's + ad-hoc own features; the
    ad-hoc ∇Z_i^(i,j) is computed only to measure staleness (paper
    footnote 2), then the weighted per-instance losses drive the backward
    pass.  K>1 composes conservatively: the instance weight is the MINIMUM
    cosine over parties (the pipeline discount is applied once, after the
    minimum).  Returns (grads, weights)."""
    zs, dzs, batch_b = entry["z"], entry["dz"], entry["batch"]
    if weighting:
        dz_new = jax.grad(
            lambda zl: jnp.mean(loss_b(params_b, zl, batch_b)[0]))(
            [z.astype(jnp.float32) for z in zs])
        w = staleness_weights(dz_new[0], dzs[0], cos_xi, fused=fused)
        for i in range(1, len(zs)):
            w = jnp.minimum(
                w, staleness_weights(dz_new[i], dzs[i], cos_xi, fused=fused))
        w = pipeline_attenuation(w, pipeline_staleness)
    else:
        w = jnp.ones((zs[0].shape[0],), jnp.float32)
    if mask is not None:
        w = w * mask

    def weighted(p):
        li, aux = loss_b(p, zs, batch_b)
        return jnp.mean(w * li) + aux

    g = jax.grad(weighted)(params_b)
    return g, w


def _fused_ring_weights(slot, dz_new, dz_store, cos_xi: float):
    """Weights-only fused sample for Party B: gather the slot's stale
    ∇Z_i straight from the (possibly quantized) ring and row-cosine it
    against the ad-hoc derivative in one VMEM pass.  Reuses the sample
    megakernel with the ∇Z ring in both operand positions — the weight
    output is bit-identical to ``cosine_weight`` over the materialized
    row (same reduction order, same blocks); the cotangent output rides
    along unused."""
    from ..kernels import ops as kops
    if isinstance(dz_store, Quant4Leaf):
        w, _ = kops.fused_gather_weight_q4(
            slot, dz_new.astype(jnp.float32), dz_store.q, dz_store.scale,
            dz_store.q, dz_store.scale, cos_xi)
        return w
    if isinstance(dz_store, QuantLeaf):
        w, _ = kops.fused_gather_weight_q8(
            slot, dz_new.astype(jnp.float32), dz_store.q, dz_store.scale,
            dz_store.q, dz_store.scale, cos_xi)
        return w
    ring = _ring_view(dz_store)
    w, _ = kops.fused_gather_weight(slot, dz_new, ring, ring, cos_xi)
    return w


def local_grad_b_cached(loss_b, params_b, ws, slot, cos_xi: float, *,
                        weighting: bool = True, fused: bool = True,
                        cache_fused: bool = True, mask=None,
                        pipeline_staleness=0):
    """Label-party local update straight off the workset ring.  The loss
    CONSUMES the decoded Z list, so the K ``z`` entries must still be
    materialized — but the K ``dz`` entries' only consumer is the
    Algorithm-2 cosine, so the fused path reads them in storage precision
    through the gather→dequant→weight megakernel and never materializes
    the decoded ∇Z list in HBM.  ``cache_fused=False`` (or an unfusable
    batch tiling, or ``weighting``/``fused`` off) falls back to
    materialize-then-weight — the bit-exact reference composition.
    Returns (grads, weights)."""
    buf = ws["buf"]
    batch_b = jax.tree_util.tree_map(lambda b: b[slot], buf["batch"])
    zs = decode_entry(jax.tree_util.tree_map(lambda b: b[slot], buf["z"]))
    K = len(zs)
    if weighting:
        dz_new = jax.grad(
            lambda zl: jnp.mean(loss_b(params_b, zl, batch_b)[0]))(
            [z.astype(jnp.float32) for z in zs])
        if fused and cache_fused and _fusable(dz_new[0]):
            w = _fused_ring_weights(slot, dz_new[0], buf["dz"][0], cos_xi)
            for i in range(1, K):
                w = jnp.minimum(w, _fused_ring_weights(
                    slot, dz_new[i], buf["dz"][i], cos_xi))
        else:
            dzs = decode_entry(jax.tree_util.tree_map(
                lambda b: b[slot], buf["dz"]))
            w = staleness_weights(dz_new[0], dzs[0], cos_xi, fused=fused)
            for i in range(1, K):
                w = jnp.minimum(w, staleness_weights(
                    dz_new[i], dzs[i], cos_xi, fused=fused))
        w = pipeline_attenuation(w, pipeline_staleness)
    else:
        w = jnp.ones((zs[0].shape[0],), jnp.float32)
    if mask is not None:
        w = w * mask

    def weighted(p):
        li, aux = loss_b(p, zs, batch_b)
        return jnp.mean(w * li) + aux

    g = jax.grad(weighted)(params_b)
    return g, w


# --------------------------------------------------------------------------
# State
# --------------------------------------------------------------------------
def init_state(task: KPartyTask, params: Dict[str, Any], opt: Optimizer,
               celu: CELUConfig, batches_a: Sequence[Any], batch_b,
               transport=None, compression: Optional[str] = None):
    """Build the K-party training state.

    ``params = {"a": [pa_1..pa_K], "b": pb}``; ``batches_a`` are K example
    batches (abstract ok) used to size the workset ring buffers.
    ``transport``/``compression`` must mirror what :func:`make_round` gets
    (both default to :func:`make_transport` over ``celu``): the transport
    sizes the per-direction error-feedback residuals carried in
    ``state["transport"]`` (empty for stateless transports)."""
    K = len(params["a"])
    zs = [jax.eval_shape(task.forward_a, params["a"][i], batches_a[i])
          for i in range(K)]
    z_like = [jnp.zeros(z.shape, z.dtype) for z in zs]
    ws_a = [workset_init(celu.W, {"z": z_like[i], "dz": z_like[i],
                                  "batch": batches_a[i]},
                         cache_dtype=celu.cache_dtype)
            for i in range(K)]
    ws_b = workset_init(celu.W, {"z": list(z_like), "dz": list(z_like),
                                 "batch": batch_b},
                        cache_dtype=celu.cache_dtype)
    return {
        "params": {"a": list(params["a"]), "b": params["b"]},
        "opt": {"a": [opt.init(p) for p in params["a"]],
                "b": opt.init(params["b"])},
        "ws": {"a": ws_a, "b": ws_b},
        "steps": {"a": [jnp.int32(0) for _ in range(K)], "b": jnp.int32(0)},
        "comm_rounds": jnp.int32(0),
        "transport": (transport if transport is not None
                      else make_transport(celu, compression)
                      ).init_state(z_like),
    }


# --------------------------------------------------------------------------
# The two round stages (exchange / local updates) — shared by the
# sequential round and the pipelined scheduler
# --------------------------------------------------------------------------
def _make_stages(task: KPartyTask, opt: Optimizer, celu: CELUConfig, *,
                 n_local: int, tp, fused: bool, pipeline_staleness=0,
                 lr_damping: float = 0.0, cos_xi=None, rng_keys=None):
    """Build the round's two first-class stages over the shared state
    layout:

      * ``exchange_compute(params, tstate, batches_a, batch_b,
        comm_rounds)`` — everything the paper's background communication
        worker does WITHOUT mutating training state: party forward passes,
        transport send up (Z_i) and down (∇Z_i), Party B's loss, and all
        fresh gradients.  Returns the in-flight exchange payload (wire
        values + gradients + updated transport residuals) — the
        double-buffered workset slot the pipeline carries while round t's
        local updates run.
      * ``exchange_apply(state, fresh, batches_a, batch_b, batch_idx)`` —
        merge an in-flight exchange into the round state: optimizer steps
        from the fresh gradients, workset inserts, counters, transport
        residual adoption.
      * ``local_scan(state)`` — the R staleness-weighted local updates per
        party sampled from the workset (Algorithm 2).

    :func:`make_round` composes compute -> apply -> scan inside ONE jit
    (today's sequential semantics, golden-trace pinned);
    :class:`PipelinedEngine` jits each stage separately so round t+1's
    exchange can be dispatched while round t's local scan runs.

    ``pipeline_staleness`` (the scheduler's depth) tightens the workset
    validity window and attenuates Algorithm-2 instance weights: under a
    depth-D pipeline every cached entry is D exchanges older (relative to
    the params it is used against) than the sequential schedule would make
    it.  Both ``local_scan`` and ``exchange_apply`` additionally accept an
    optional traced ``staleness`` scalar — the depth-D queue's PER-SLOT
    offset (in-flight count at scan time / merged exchange's age), which
    overrides the static depth so warmup and drain phases are charged
    their actual staleness, not the steady-state bound.  When a dynamic
    staleness is supplied and ``lr_damping`` (the ``c`` of the
    ``eta / (1 + c*s)`` schedule) is positive, the optimizer updates that
    stage produces are damped accordingly — the FedBCD-style guard that
    keeps the sub-linear rate as queued staleness grows.  Depths 0/1 never
    pass a dynamic staleness, so their golden-pinned numerics are
    untouched.

    ``cos_xi`` and ``rng_keys`` widen the stages to per-job TRACED
    hyper-parameters for the vmapped fleet runner (``repro.fleet``):
    ``cos_xi`` overrides the Algorithm-2 threshold (default: the static
    ``xi_to_cos(celu.xi_degrees)``, bit-for-bit the historical constant)
    and ``rng_keys`` is a ``{"exchange", "insert", "draw"}`` dict of PRNG
    keys replacing the engine's fixed bases — a job with the default keys
    reproduces the scalar engine's rng chain exactly, a job with
    seed-folded keys draws an independent stream.  Both may be tracers
    (closed over during a jit/vmap trace of the caller)."""
    if cos_xi is None:
        cos_xi = xi_to_cos(celu.xi_degrees)
    if rng_keys is None:
        rng_keys = {"exchange": jax.random.PRNGKey(17),
                    "insert": jax.random.PRNGKey(0xCE1),
                    "draw": jax.random.PRNGKey(29)}
    s_pipe = int(pipeline_staleness)
    uniform = celu.sampling == "uniform"

    def _damp(staleness):
        """1 / (1 + c*s) update scale; None when the static path (or a
        zero coefficient) should leave the updates untouched."""
        if staleness is None or lr_damping <= 0.0:
            return None
        return jnp.float32(1.0) / (
            1.0 + jnp.float32(lr_damping)
            * jnp.asarray(staleness).astype(jnp.float32))

    def exchange_compute(params, tstate, batches_a, batch_b, comm_rounds):
        pas, pb = params["a"], params["b"]
        K = len(pas)
        rng = jax.random.fold_in(rng_keys["exchange"], comm_rounds)
        keys = jax.random.split(rng, 2 * K)
        missing = [d for d in getattr(tp, "stateful_directions", ())
                   if d not in tstate]
        if missing:
            raise ValueError(
                f"transport keeps error-feedback residuals for "
                f"{missing} but the round state has none — pass the same "
                f"transport (or compression spec) to init_state")
        up_res = list(tstate["up"]) if "up" in tstate else [None] * K
        down_res = list(tstate["down"]) if "down" in tstate else [None] * K

        # uplinks: every A_i's forward -> Z_i, released in wire precision
        zs, vjps = [], []
        for i in range(K):
            z, vjp = jax.vjp(
                lambda p, i=i: task.forward_a(p, batches_a[i]), pas[i])
            z, up_res[i] = tp.send(keys[2 * i], z, up_res[i], "up")
            zs.append(z)
            vjps.append(vjp)

        # Party B: loss + grads wrt (params_b, all Z_i); ∇Z_i are downlinks
        def mean_loss(p, z_list):
            li, aux = task.loss_b(p, z_list, batch_b)
            return jnp.mean(li) + aux
        loss, (g_b, dzs) = jax.value_and_grad(
            mean_loss, argnums=(0, 1))(pb, zs)
        dzs = list(dzs)
        for i in range(K):
            dzs[i], down_res[i] = tp.send(keys[2 * i + 1], dzs[i],
                                          down_res[i], "down")
        new_tstate = dict(tstate)
        if "up" in tstate:
            new_tstate["up"] = up_res
        if "down" in tstate:
            new_tstate["down"] = down_res

        # every A_i's backward with its (wire-precision) cotangent
        g_as = [vjps[i](dzs[i].astype(zs[i].dtype))[0] for i in range(K)]
        return {"zs": zs, "dzs": dzs, "g_as": g_as, "g_b": g_b,
                "loss": loss, "tstate": new_tstate}

    def exchange_apply(state, fresh, batches_a, batch_b, batch_idx,
                       staleness=None):
        pas, pb = state["params"]["a"], state["params"]["b"]
        K = len(pas)
        zs, dzs = fresh["zs"], fresh["dzs"]
        damp = _damp(staleness)
        new_pas, new_oas = [], []
        for i in range(K):
            upd, oa = opt.update(fresh["g_as"][i], state["opt"]["a"][i],
                                 pas[i])
            if damp is not None:
                upd = jax.tree_util.tree_map(lambda u: u * damp, upd)
            new_pas.append(apply_updates(pas[i], upd))
            new_oas.append(oa)
        upd_b, ob = opt.update(fresh["g_b"], state["opt"]["b"], pb)
        if damp is not None:
            upd_b = jax.tree_util.tree_map(lambda u: u * damp, upd_b)

        # rounding noise for quantized-at-rest caches (unused — and DCE'd —
        # by the fp32 table); per-party keys keep the SR noise independent
        ins_rng = jax.random.fold_in(rng_keys["insert"],
                                     state["comm_rounds"])
        ws_a = [workset_insert(state["ws"]["a"][i],
                               {"z": zs[i], "dz": dzs[i],
                                "batch": batches_a[i]}, batch_idx,
                               rng=jax.random.fold_in(ins_rng, i))
                for i in range(K)]
        ws_b = workset_insert(state["ws"]["b"],
                              {"z": zs, "dz": dzs, "batch": batch_b},
                              batch_idx, rng=jax.random.fold_in(ins_rng, K))
        new_state = {
            "params": {"a": new_pas, "b": apply_updates(pb, upd_b)},
            "opt": {"a": new_oas, "b": ob},
            "ws": {"a": ws_a, "b": ws_b},
            "steps": {"a": [s + 1 for s in state["steps"]["a"]],
                      "b": state["steps"]["b"] + 1},
            "comm_rounds": state["comm_rounds"] + 1,
            "transport": fresh["tstate"],
        }
        return new_state, {"loss": fresh["loss"]}

    def local_scan(state, staleness=None, party_mask=None):
        # ``party_mask`` ((K+1,) float32 — a_0..a_{K-1}, b; None = all
        # live) freezes a dropped-out party's local updates: its draw's
        # valid factor is multiplied by the mask, zeroing the weights,
        # the cotangent, and the optimizer update while the surviving
        # parties keep local-updating off their cached statistics.  The
        # masked party's ring clocks still tick (use_count, cursor) — a
        # conservative choice that drains its cache at the same rate as
        # everyone else's, so rejoin never resurrects over-aged entries.
        K = len(state["params"]["a"])
        if n_local == 0:
            zero = jnp.float32(0.0)
            return state, {"local_steps": jnp.int32(0), "w_mean": zero,
                           "w_zero_frac": zero}

        s_loc = s_pipe if staleness is None else staleness
        damp = _damp(staleness)
        scale = jnp.float32(1.0 / (K + 1))
        comm_rounds = state["comm_rounds"]
        draw_base = rng_keys["draw"]
        if staleness is not None:
            # the depth-D queue can run several scans at the SAME
            # comm_rounds (warmup: no merges yet; manual local() calls
            # between merges) — fold the per-slot staleness in so their
            # uniform draws stay independent.  (comm_rounds, s) is unique
            # per scan under every supported schedule; the static path
            # keeps the historical key chain bit-for-bit.
            draw_base = jax.random.fold_in(draw_base, s_loc)

        def body(carry, _):
            if uniform:
                pas, oas, wsas, nas, pb, ob, wsb, nb, j = carry
                draw_key = jax.random.fold_in(
                    jax.random.fold_in(draw_base, comm_rounds), j)
            else:
                pas, oas, wsas, nas, pb, ob, wsb, nb = carry
                draw_key = None
            pas, oas, wsas, nas = list(pas), list(oas), list(wsas), list(nas)
            w_means, w_zeros = [], []
            for i in range(K):
                ki = None if draw_key is None \
                    else jax.random.fold_in(draw_key, i)
                wsas[i], slot, _, valid = workset_draw(
                    wsas[i], celu.R, celu.sampling, rng=ki,
                    pipeline_staleness=s_loc)
                vf = valid.astype(jnp.float32)
                if party_mask is not None:
                    vf = vf * party_mask[i]
                g, w = local_grad_a_cached(
                    task.forward_a, pas[i], wsas[i], slot, cos_xi,
                    weighting=celu.weighting, fused=fused,
                    cache_fused=celu.cache_fused, mask=vf,
                    pipeline_staleness=s_loc)
                upd, oas[i] = opt.update(g, oas[i], pas[i])
                uf = vf if damp is None else vf * damp
                upd = jax.tree_util.tree_map(lambda u: u * uf, upd)
                pas[i] = apply_updates(pas[i], upd)
                nas[i] = nas[i] + (valid.astype(jnp.int32)
                                   if party_mask is None
                                   else (vf > 0).astype(jnp.int32))
                w_means.append(jnp.mean(w))
                w_zeros.append(jnp.mean(w == 0.0))

            kb = None if draw_key is None \
                else jax.random.fold_in(draw_key, K)
            wsb, slot_b, _, valid = workset_draw(
                wsb, celu.R, celu.sampling, rng=kb,
                pipeline_staleness=s_loc)
            vf = valid.astype(jnp.float32)
            if party_mask is not None:
                vf = vf * party_mask[K]
            g, w = local_grad_b_cached(
                task.loss_b, pb, wsb, slot_b, cos_xi,
                weighting=celu.weighting, fused=fused,
                cache_fused=celu.cache_fused, mask=vf,
                pipeline_staleness=s_loc)
            upd, ob = opt.update(g, ob, pb)
            uf = vf if damp is None else vf * damp
            upd = jax.tree_util.tree_map(lambda u: u * uf, upd)
            pb = apply_updates(pb, upd)
            nb = nb + (valid.astype(jnp.int32) if party_mask is None
                       else (vf > 0).astype(jnp.int32))
            w_means.append(jnp.mean(w))
            w_zeros.append(jnp.mean(w == 0.0))

            lm = {"w_mean": sum(w_means) * scale,
                  "w_zero_frac": sum(w_zeros) * scale}
            carry = (pas, oas, wsas, nas, pb, ob, wsb, nb)
            if uniform:
                carry = carry + (j + 1,)
            return carry, lm

        init = (state["params"]["a"], state["opt"]["a"], state["ws"]["a"],
                [jnp.int32(0) for _ in range(K)],
                state["params"]["b"], state["opt"]["b"], state["ws"]["b"],
                jnp.int32(0))
        if uniform:
            init = init + (jnp.int32(0),)
        out, lm = jax.lax.scan(body, init, None, length=n_local)
        pas, oas, wsas, nas, pb, ob, wsb, nb = out[:8]
        state = {
            "params": {"a": pas, "b": pb},
            "opt": {"a": oas, "b": ob},
            "ws": {"a": wsas, "b": wsb},
            "steps": {"a": [s + n for s, n in zip(state["steps"]["a"], nas)],
                      "b": state["steps"]["b"] + nb},
            "comm_rounds": state["comm_rounds"],
            "transport": state["transport"],
        }
        return state, {"local_steps": sum(nas) + nb,
                       "w_mean": jnp.mean(lm["w_mean"]),
                       "w_zero_frac": jnp.mean(lm["w_zero_frac"])}

    return exchange_compute, exchange_apply, local_scan


# --------------------------------------------------------------------------
# One full communication round (exchange + R local updates per party)
# --------------------------------------------------------------------------
def make_round(task: KPartyTask, opt: Optimizer, celu: CELUConfig, *,
               local_steps: int = -1, transport=None,
               compression: Optional[str] = None,
               fused_weighting: bool = True, jit: bool = True,
               donate: bool = False):
    """fn(state, batches_a: list, batch_b, batch_idx) -> (state, metrics).

    ``local_steps`` defaults to R (steady state: one fresh insert funds R
    uses); Vanilla training = ``local_steps=0``.  ``transport`` defaults to
    :func:`make_transport` over ``celu`` — i.e. :class:`SimWANTransport`
    unless ``compression`` (or ``celu.compression``) names a wire codec.

    This is the SEQUENTIAL schedule: the exchange stage and the local-update
    scan run back-to-back inside one jit (XLA may still hide some latency,
    but the simulated WAN stall serializes with compute).  For the paper's
    two-worker overlap, build the same stages through
    :func:`make_pipeline` / :class:`PipelinedEngine` instead."""
    n_local = celu.R if local_steps < 0 else local_steps
    tp = transport if transport is not None \
        else make_transport(celu, compression)
    exchange_compute, exchange_apply, local_scan = _make_stages(
        task, opt, celu, n_local=n_local, tp=tp, fused=fused_weighting)

    def round_fn(state, batches_a, batch_b, batch_idx):
        fresh = exchange_compute(state["params"], state.get("transport", {}),
                                 batches_a, batch_b, state["comm_rounds"])
        state, m = exchange_apply(state, fresh, batches_a, batch_b,
                                  batch_idx)
        state, lm = local_scan(state)
        m.update(lm)
        return state, m

    if jit:
        return jax.jit(round_fn, donate_argnums=(0,) if donate else ())
    return round_fn


# --------------------------------------------------------------------------
# The pipelined scheduler (paper §4.1 Fig. 4, generalized to a D-deep
# exchange queue)
# --------------------------------------------------------------------------
class PendingExchange(NamedTuple):
    """An in-flight exchange: one slot of the scheduler's exchange queue.

    ``fresh`` is ``exchange_compute``'s payload — wire-precision ⟨Z_i, ∇Z_i⟩
    (the statistics that will be inserted), the fresh gradients, Party B's
    loss, and the updated transport error-feedback residuals (in flight
    with the exchange: they are not adopted into the round state until the
    merge).  The batches ride along because the deferred workset insert
    needs each party's own features.  ``dispatched_at`` records
    ``comm_rounds`` (merges completed) at dispatch time — the merge uses
    it to charge the fresh gradients their actual per-slot staleness
    (``comm_rounds_at_merge - dispatched_at``, = D-1 at steady state)."""
    fresh: Dict[str, Any]
    batches_a: Sequence[Any]
    batch_b: Any
    batch_idx: Any
    dispatched_at: Any = None


class RoundState(NamedTuple):
    """Typed round state shared by the pipeline stages.

    The first six fields mirror the engine's state dict (the canonical
    wire format of :func:`init_state` — convert with :meth:`from_state` /
    :meth:`as_state`); ``pending`` is the scheduler's exchange queue: the
    in-flight :class:`PendingExchange` slots, oldest first (at most
    ``max(depth, 1)`` deep; a 1-tuple is the paper's double buffer,
    ``()`` means no exchange is in flight)."""
    params: Dict[str, Any]
    opt: Dict[str, Any]
    ws: Dict[str, Any]
    steps: Dict[str, Any]
    comm_rounds: Any
    transport: Dict[str, Any]
    pending: Tuple[PendingExchange, ...] = ()

    @classmethod
    def from_state(cls, state: Dict[str, Any],
                   pending: Tuple[PendingExchange, ...] = ()
                   ) -> "RoundState":
        return cls(params=state["params"], opt=state["opt"],
                   ws=state["ws"], steps=state["steps"],
                   comm_rounds=state["comm_rounds"],
                   transport=state.get("transport", {}), pending=pending)

    def as_state(self) -> Dict[str, Any]:
        return {"params": self.params, "opt": self.opt, "ws": self.ws,
                "steps": self.steps, "comm_rounds": self.comm_rounds,
                "transport": self.transport}


def _zero_local_metrics():
    zero = jnp.float32(0.0)
    return {"local_steps": jnp.int32(0), "w_mean": zero,
            "w_zero_frac": zero}


class PipelinedEngine:
    """Explicitly staged round scheduler: the paper's two-worker pipeline,
    generalized to a depth-D exchange queue.

    Depth 0 runs the stages sequentially — dispatch, merge, local scan —
    and is bit-identical to :func:`make_round`'s fused round on the golden
    traces.  Depth 1 dispatches round t+1's exchange and runs round t's
    local scan while it is in flight:

        dispatch(batch t+1)   # exchange_compute — async, never blocked on
        local()               # round t's R local updates (the overlap)
        merge()               # adopt the arrived exchange: opt step + insert

    Depth D >= 2 keeps a ring of up to D in-flight exchanges
    (``rs.pending``, oldest first) for the high-RTT regime where one
    exchange cannot hide behind one local scan: each step dispatches a new
    exchange, runs the local scan with the whole queue in flight, and
    merges the OLDEST exchange once the queue is full — so an exchange
    rides the wire for D local scans before its statistics land.  The
    first D-1 steps only fill the queue (no merge: their metrics carry a
    NaN ``loss``), and :meth:`flush` drains the remaining in-flight
    exchanges, alternating scan/merge so every inserted batch still gets
    its local scan.

    On the host-sim path the overlap is real at the dispatch level — the
    three stages are separate jits and nothing calls
    ``jax.block_until_ready`` between them, so XLA's async dispatch queues
    the exchange behind no host barrier while the local scan is enqueued;
    the simulated WAN clock (``repro.launch.wan.WANClock``) charges the
    D-deep ``max`` schedule per round instead of the sum.  The pipeline's
    cost is staleness, and it is accounted PER SLOT at depth >= 2: the
    local scan is passed the live in-flight count (= D at steady state,
    smaller during warmup/drain) as a traced staleness scalar — it
    tightens the workset validity window (``workset_draw``), attenuates
    the Algorithm-2 weights ``w -> w^(1+s)``
    (:func:`repro.core.weighting.pipeline_attenuation`, fused-kernel
    post-scale included), and damps the local optimizer steps by
    ``1 / (1 + c*s)`` (``CELUConfig.pipeline_lr_damping``); the merge
    charges the fresh gradients their own slot age
    (``comm_rounds - dispatched_at``).  Depths 0/1 keep the historical
    static plumbing, bit-for-bit.

    Drive it as::

        pe = make_pipeline(task, opt, celu, depth=2)
        rs = pe.init(engine.init_state(...))
        for t, (bi, ba, bb) in enumerate(batches):
            rs, m = pe.step(rs, ba, bb, bi)
        rs, m = pe.flush(rs)          # drain the in-flight queue
        state = pe.finalize(rs)
    """

    def __init__(self, task: KPartyTask, opt: Optimizer, celu: CELUConfig,
                 *, depth: Optional[int] = None, local_steps: int = -1,
                 transport=None, compression: Optional[str] = None,
                 fused_weighting: bool = True, jit: bool = True,
                 dynamic_staleness: Optional[bool] = None):
        if depth is None:
            depth = celu.pipeline_depth
        # same rule, same message as CELUConfig.__post_init__ — an
        # explicit depth= override must not bypass the capacity check
        validate_pipeline_depth(depth, celu.W)
        self.depth = depth
        self.celu = celu
        # depth >= 2 threads the PER-SLOT staleness dynamically (warmup
        # and drain see their true, smaller offsets); depths 0/1 keep the
        # static golden-pinned plumbing.  ``dynamic_staleness=True``
        # forces the dynamic path at ANY depth — the chaos engine needs
        # it to charge fault-induced extra age even at depths 0/1
        # (core/faults.py; a ``FaultPlan=None`` chaos engine keeps the
        # default so the no-fault schedule stays golden-identical).
        self.dynamic = (depth >= 2) if dynamic_staleness is None \
            else bool(dynamic_staleness)
        n_local = celu.R if local_steps < 0 else local_steps
        self.n_local = n_local
        tp = transport if transport is not None \
            else make_transport(celu, compression)
        self.transport = tp
        compute, apply_, scan = _make_stages(
            task, opt, celu, n_local=n_local, tp=tp, fused=fused_weighting,
            pipeline_staleness=depth,
            lr_damping=celu.pipeline_lr_damping if self.dynamic else 0.0)
        wrap = jax.jit if jit else (lambda f: f)
        self._compute = wrap(compute)
        self._apply = wrap(apply_)
        self._scan = wrap(scan)

    @property
    def queue_capacity(self) -> int:
        """Max in-flight exchanges (depth 0 still buffers the one exchange
        between its dispatch and its immediate merge)."""
        return max(self.depth, 1)

    # ---- stages ----------------------------------------------------------
    def init(self, state: Dict[str, Any]) -> RoundState:
        """Adopt an :func:`init_state` dict into the scheduler's state."""
        return RoundState.from_state(state)

    def dispatch(self, rs: RoundState, batches_a, batch_b,
                 batch_idx) -> RoundState:
        """Start a new exchange (the background worker): compute the wire
        statistics and fresh gradients from the CURRENT params.  Does not
        block — the result is appended to the ``rs.pending`` queue until
        its :meth:`merge`."""
        if len(rs.pending) >= self.queue_capacity:
            raise RuntimeError(
                f"{len(rs.pending)} exchange(s) already in flight — the "
                f"depth-{self.depth} queue holds at most "
                f"{self.queue_capacity}; merge() the oldest before "
                f"dispatching another")
        # The error-feedback residual chain follows DISPATCH order (the
        # encoder runs at dispatch), so a new exchange must start from the
        # newest in-flight exchange's transport state, not the
        # merged-prefix state in rs.transport — otherwise the D-1
        # intervening residual updates would be silently dropped and the
        # telescoping invariant broken.  Empty queue (depths 0/1) reduces
        # to rs.transport — golden-pinned.
        tstate = rs.pending[-1].fresh["tstate"] if rs.pending \
            else rs.transport
        # rng folds over the DISPATCH sequence number (merges completed +
        # in-flight count), not comm_rounds alone: during warmup several
        # exchanges are dispatched before the first merge advances the
        # round counter, and they must not share wire noise.
        fresh = self._compute(rs.params, tstate, batches_a, batch_b,
                              rs.comm_rounds + len(rs.pending))
        pe = PendingExchange(fresh, batches_a, batch_b, batch_idx,
                             dispatched_at=rs.comm_rounds)
        return rs._replace(pending=rs.pending + (pe,))

    def local(self, rs: RoundState, *, staleness=None, party_mask=None
              ) -> Tuple[RoundState, Dict[str, Any]]:
        """Run the R staleness-weighted local updates (the foreground
        worker) against the workset as of the last merged exchange.  At
        depth >= 2 the scan is charged the CURRENT in-flight count as its
        per-slot staleness.  ``staleness`` overrides that charge and
        ``party_mask`` ((K+1,) floats) freezes dropped-out parties — both
        are the chaos scheduler's hooks and need the dynamic stage
        plumbing."""
        if staleness is not None or party_mask is not None:
            if not self.dynamic:
                raise RuntimeError(
                    "staleness/party_mask overrides need the dynamic "
                    "stage plumbing — build the engine with "
                    "dynamic_staleness=True")
            s = jnp.int32(len(rs.pending)) if staleness is None \
                else jnp.int32(staleness)
            state, lm = self._scan(rs.as_state(), s, party_mask)
        elif self.dynamic:
            state, lm = self._scan(rs.as_state(),
                                   jnp.int32(len(rs.pending)))
        else:
            state, lm = self._scan(rs.as_state())
        return RoundState.from_state(state, rs.pending), lm

    def merge(self, rs: RoundState, *, staleness=None
              ) -> Tuple[RoundState, Dict[str, Any]]:
        """Adopt the OLDEST in-flight exchange: fresh optimizer steps
        (applied to the params as they are NOW — after any overlapped
        local updates, lr-damped by the slot's age at depth >= 2), workset
        inserts, transport residuals, counters.  ``staleness`` overrides
        the slot-age charge (the chaos scheduler passes the true
        scheduler-round age, which exceeds ``comm_rounds - dispatched_at``
        when merges were missed to faults)."""
        if not rs.pending:
            raise RuntimeError("no exchange in flight — dispatch() first")
        p, rest = rs.pending[0], rs.pending[1:]
        if staleness is not None and not self.dynamic:
            raise RuntimeError(
                "staleness override needs the dynamic stage plumbing — "
                "build the engine with dynamic_staleness=True")
        if self.dynamic:
            s = (rs.comm_rounds - p.dispatched_at) if staleness is None \
                else jnp.int32(staleness)
            state, m = self._apply(rs.as_state(), p.fresh, p.batches_a,
                                   p.batch_b, p.batch_idx, s)
        else:
            state, m = self._apply(rs.as_state(), p.fresh, p.batches_a,
                                   p.batch_b, p.batch_idx)
        return RoundState.from_state(state, rest), m

    # ---- schedules -------------------------------------------------------
    def step(self, rs: RoundState, batches_a, batch_b, batch_idx
             ) -> Tuple[RoundState, Dict[str, Any]]:
        """One communication round.  Depth 0: exchange then local scan
        (sequential).  Depth 1: the local scan of the PREVIOUS round runs
        between this round's dispatch and merge — its WAN exchange is in
        flight the whole time.  Depth D >= 2: dispatch, scan with the full
        queue in flight, then merge the oldest exchange once the queue
        holds D (the first D-1 steps only fill the queue and report a NaN
        ``loss``)."""
        rs = self.dispatch(rs, batches_a, batch_b, batch_idx)
        if self.depth == 0:
            rs, m = self.merge(rs)
            rs, lm = self.local(rs)
        elif self.depth == 1:
            rs, lm = self.local(rs)
            rs, m = self.merge(rs)
        else:
            rs, lm = self.local(rs)
            if len(rs.pending) == self.depth:
                rs, m = self.merge(rs)
            else:
                m = {"loss": jnp.float32(jnp.nan)}   # warmup: queue filling
        m.update(lm)
        return rs, m

    def flush(self, rs: RoundState) -> Tuple[RoundState, Dict[str, Any]]:
        """Drain the pipeline.  Depth 0 is a no-op; depth 1 runs the one
        local scan the last merge still owes.  Depth >= 2 alternates
        scan/merge until the queue is empty (per-slot staleness decaying
        as it drains), then scans once more over the final inserts."""
        if self.depth == 0:
            return rs, _zero_local_metrics()
        if self.depth == 1:
            return self.local(rs)
        scans = []
        while rs.pending:
            rs, lm = self.local(rs)
            scans.append(lm)
            rs, _ = self.merge(rs)
        rs, lm = self.local(rs)
        scans.append(lm)
        n = len(scans)
        return rs, {
            "local_steps": sum(l["local_steps"] for l in scans),
            "w_mean": sum(l["w_mean"] for l in scans) / n,
            "w_zero_frac": sum(l["w_zero_frac"] for l in scans) / n,
        }

    def finalize(self, rs: RoundState) -> Dict[str, Any]:
        """Back to the engine's canonical state dict."""
        if rs.pending:
            raise RuntimeError(
                f"{len(rs.pending)} exchange(s) still in flight — merge() "
                f"(or flush()) or drop them before finalizing")
        return rs.as_state()


def make_pipeline(task: KPartyTask, opt: Optimizer, celu: CELUConfig, *,
                  depth: Optional[int] = None, local_steps: int = -1,
                  transport=None, compression: Optional[str] = None,
                  fused_weighting: bool = True,
                  jit: bool = True) -> PipelinedEngine:
    """Build the staged round scheduler.  ``depth`` defaults to
    ``celu.pipeline_depth``; depth 0 reproduces :func:`make_round`'s
    sequential semantics bit-for-bit, depth 1 overlaps round t+1's WAN
    exchange with round t's local updates (paper §4.1), and depth D >= 2
    keeps a D-deep queue of in-flight exchanges with per-slot
    staleness-aware damping (see :class:`PipelinedEngine`).  ``depth``
    must stay < ``celu.W`` — the ring cannot serve a deeper queue."""
    return PipelinedEngine(task, opt, celu, depth=depth,
                           local_steps=local_steps, transport=transport,
                           compression=compression,
                           fused_weighting=fused_weighting, jit=jit)


# --------------------------------------------------------------------------
# Named protocol presets (the paper's three competitors)
# --------------------------------------------------------------------------
def preset_config(name: str, base: CELUConfig) -> Tuple[CELUConfig, int]:
    """-> (celu_cfg, local_steps) for name in {vanilla, fedbcd, celu}."""
    if name == "vanilla":
        return dataclasses.replace(base, weighting=False), 0
    if name == "fedbcd":
        return dataclasses.replace(base, W=1, weighting=False,
                                   sampling="consecutive"), base.R
    if name == "celu":
        return base, base.R
    raise ValueError(name)


# --------------------------------------------------------------------------
# SPMD party-to-pod round (PodTransport over the pod mesh axis)
# --------------------------------------------------------------------------
def make_pod_round(mesh, opt: Optimizer, *, R: int, cos_xi: float,
                   weighting: bool = True, tower_fwd=None, top_loss=None,
                   transport: Optional[PodTransport] = None,
                   fused_weighting: bool = False,
                   pipeline_depth: int = 0):
    """Build the jitted multi-pod CELU round (party p's weights live on
    pod p; the exchange is the transport's ppermute pair).

    ``tower_fwd(tower_params, x) -> Z`` and
    ``top_loss(top_params, z_a, z_b, y) -> per-instance loss`` define the
    party-stacked model (see ``core.pod_protocol`` for the WDL demo).

    ``pipeline_depth=1`` is the ppermute-overlapped schedule (paper §4.1's
    two-worker pipeline on the pod path): the round issues the up-permute,
    then runs the R local updates against the PREVIOUS rounds' workset and
    the dispatch-time params — the scan has no data dependency on the
    in-flight collective, so the XLA/Mosaic scheduler overlaps the slow
    inter-pod DCN transfer with the local compute — and only then consumes
    the permuted cut tensors (fresh update + insert, applied to the
    post-scan params).  Depth 0 is the sequential schedule (exchange,
    insert, then the scan over the just-updated workset) — bit-identical
    to the historical pod round.

    State pytree (all party-stacked, party axis over ``pod``):
      params:   {"tower": (2,...), "top": (2,...)}
      opt:      accumulators, same structure
      ws:       workset ring buffers (2, W, B_local, ...) — per-party caches
    Batch: x (2, B, F) int32 — party p's features on pod p;
           y (2, B) — labels valid on party 1's slot only.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    assert tower_fwd is not None and top_loss is not None
    if pipeline_depth not in (0, 1):
        raise ValueError(
            f"make_pod_round supports pipeline_depth 0 or 1 (got "
            f"{pipeline_depth}): the D-deep exchange queue is scheduled "
            f"on the HOST — PipelinedEngine keeps the in-flight "
            f"PendingExchange slots in ``rs.pending`` between three "
            f"separately jitted stage calls, and the pod round is ONE "
            f"jitted SPMD program with no host in the loop to carry that "
            f"queue.  A depth-D pod schedule needs the device-side "
            f"ppermute-chained queue tracked in ROADMAP.md "
            f"('Mosaic/pod — the real-TPU milestone').  Use "
            f"make_pipeline/PipelinedEngine for D >= 2, or depth 1 here "
            f"(the compiler-overlapped two-worker schedule).")
    tp = transport if transport is not None else PodTransport()
    fused = fused_weighting

    def b_loss(pb, z_list, batch):
        """Party B's towers as a K-party loss_b over pb={"top","tower"}."""
        z_b = tower_fwd(pb["tower"], batch["x"])
        return top_loss(pb["top"], z_list[0], z_b, batch["y"]), \
            jnp.float32(0.0)

    def exchange_and_local(params, opt_state, ws, x, y):
        """Runs per-pod (inside shard_map, pod axis size 2).

        Shapes here are the PER-POD view: params leaves (1, ...), x (1,B,F).
        """
        pod = jax.lax.axis_index(tp.axis)
        tower = jax.tree_util.tree_map(lambda a: a[0], params["tower"])
        top = jax.tree_util.tree_map(lambda a: a[0], params["top"])
        xb = x[0]                                   # (B, F)
        yb = y[0]                                   # (B,)

        # ---- R local updates, round-robin over the given workset ---------
        def local_scan(params, opt_state, ws):
            W = ws["z"].shape[1]

            def local_step(carry, j):
                params, opt_state, cursor = carry
                t = ws["time"][0]
                n_alive = jnp.minimum(t, W)
                slot_j = jnp.mod(cursor, jnp.maximum(n_alive, 1))
                # decode the at-rest ring precision (bf16 cache upcasts;
                # the fp32 ring is untouched — bit-identical)
                zs = ws["z"][0, slot_j].astype(jnp.float32)
                dzs = ws["dz"][0, slot_j].astype(jnp.float32)
                xs = ws["x"][0, slot_j]
                ys_ = ws["y"][0, slot_j]
                tower_j = jax.tree_util.tree_map(lambda a: a[0],
                                                 params["tower"])
                top_j = jax.tree_util.tree_map(lambda a: a[0],
                                               params["top"])

                # Party A: ad-hoc forward, cosine vs stale Z, weighted
                # stale ∇Z
                g_tower_a, _ = local_grad_a(
                    tower_fwd, tower_j, {"z": zs, "dz": dzs, "batch": xs},
                    cos_xi, weighting=weighting, fused=fused,
                    pipeline_staleness=pipeline_depth)

                # Party B: stale Z_A + ad-hoc own tower; weight by ∇Z_A
                # cosine
                g_b, _ = local_grad_b(
                    b_loss, {"top": top_j, "tower": tower_j},
                    {"z": [zs], "dz": [dzs], "batch": {"x": xs, "y": ys_}},
                    cos_xi, weighting=weighting, fused=fused,
                    pipeline_staleness=pipeline_depth)
                g_top_b, g_tower_b = g_b["top"], g_b["tower"]

                is_a_ = (pod == 0)
                g_tower_sel = jax.tree_util.tree_map(
                    lambda ga, gb: jnp.where(is_a_, ga, gb)[None],
                    g_tower_a, g_tower_b)
                g_top_sel = jax.tree_util.tree_map(
                    lambda g: jnp.where(is_a_, 0.0, g)[None], g_top_b)
                grads_j = {"tower": g_tower_sel, "top": g_top_sel}
                upd_j, opt_state = opt.update(grads_j, opt_state, params)
                params = apply_updates(params, upd_j)
                return (params, opt_state, cursor + 1), None

            (params, opt_state, _), _ = jax.lax.scan(
                local_step, (params, opt_state, jnp.int32(0)), None,
                length=R)
            return params, opt_state

        # ---- fresh exchange (the paper's communication worker) ----------
        z_mine, tower_vjp = jax.vjp(lambda tpm: tower_fwd(tpm, xb), tower)
        # Z_A: pod0 -> pod1 (pod0 receives pod1's Z_B slot, unused)
        z_a_at_b = tp.send_up(z_mine)                # on pod 1: Z_A

        if pipeline_depth:
            # Overlap window: the scan reads only the dispatch-time params
            # and the PREVIOUS rounds' workset, so it has no dependency on
            # the in-flight ppermute — the compiler is free to run the DCN
            # transfer and the R local updates concurrently.  The fresh
            # gradients below are still taken at the dispatch-time params
            # (that is the pipeline's gradient staleness) and applied to
            # the post-scan params when the stats "arrive".
            params, opt_state = local_scan(params, opt_state, ws)

        def loss_fn(top_p, z_a):
            return jnp.mean(top_loss(top_p, z_a, z_mine, yb))
        (loss, (g_top, dz_a)) = (loss_fn(top, z_a_at_b),
                                 jax.grad(loss_fn, argnums=(0, 1))(
                                     top, z_a_at_b))
        # ∇Z_A: pod1 -> pod0 (the symmetric permute)
        dz_back = tp.send_down(dz_a)

        is_a = (pod == 0)
        # Party A's tower cotangent is the received ∇Z_A; Party B's is its
        # local ∂loss/∂Z_B.  Both computed, selected by pod id.
        dz_b_local = jax.grad(
            lambda z_b: jnp.mean(top_loss(top, z_a_at_b, z_b, yb)))(z_mine)
        cot = jnp.where(is_a, dz_back, dz_b_local)
        (g_tower,) = tower_vjp(cot)
        g_top = jax.tree_util.tree_map(
            lambda g: jnp.where(is_a, 0.0, g), g_top)

        # ---- update + insert into the device-resident workset -----------
        grads = {"tower": jax.tree_util.tree_map(lambda g: g[None], g_tower),
                 "top": jax.tree_util.tree_map(lambda g: g[None], g_top)}
        upd, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, upd)

        W = ws["z"].shape[1]
        slot = jnp.mod(ws["time"][0], W)
        ws = dict(ws)
        # cache: stale z (own Z for A's weighting / Z_A for B), stale dz,
        # own features (+ labels at B)
        z_cache = jnp.where(is_a, z_mine, z_a_at_b)
        dz_cache = jnp.where(is_a, dz_back, dz_a)
        ws["z"] = jax.lax.dynamic_update_index_in_dim(
            ws["z"], z_cache[None].astype(ws["z"].dtype), slot, 1)
        ws["dz"] = jax.lax.dynamic_update_index_in_dim(
            ws["dz"], dz_cache[None].astype(ws["dz"].dtype), slot, 1)
        ws["x"] = jax.lax.dynamic_update_index_in_dim(
            ws["x"], xb[None], slot, 1)
        ws["y"] = jax.lax.dynamic_update_index_in_dim(
            ws["y"], yb[None], slot, 1)
        ws["time"] = ws["time"] + 1

        if not pipeline_depth:
            # sequential schedule: the scan runs after the insert, over the
            # just-refreshed workset and post-exchange params
            params, opt_state = local_scan(params, opt_state, ws)
        return params, opt_state, ws, loss[None]

    pp = P(tp.axis)  # every party-stacked leaf shards dim0 over pod
    fn = shard_map(
        exchange_and_local, mesh=mesh,
        in_specs=(pp, pp, pp, pp, pp),
        out_specs=(pp, pp, pp, pp),
        check_rep=False)
    return jax.jit(fn)
