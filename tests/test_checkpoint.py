"""Round-state checkpointing (checkpoint.save_round_state & friends).

The plain ``save``/``restore`` pytree round-trip is pinned in
test_system.py; this file covers what PR 7 added: native bf16 storage
(bit-exact, half the bytes), python-scalar leaves, and the FULL
scheduler-state checkpoint — params, optimizer, quantized workset rings,
transport error-feedback residuals, and the in-flight exchange queue —
restored into a fresh engine bit-consistently.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs.base import CELUConfig
from repro.core import engine
from repro.data.synthetic import TabularSpec, aligned_batches, make_tabular
from repro.models.tabular import DLRMConfig, make_dlrm
from repro.optim import make_optimizer


# --------------------------------------------------------------------------
# Leaf-level storage rules
# --------------------------------------------------------------------------
def test_bf16_stored_natively_and_bit_exact(tmp_path):
    """bf16 leaves land in the file as uint16 bit-views (half the bytes
    of the historical fp32 detour) and restore bit-exactly."""
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 8)).astype(
        jnp.bfloat16)
    path = str(tmp_path / "bf16.npz")
    ckpt.save(path, {"x": x})
    with np.load(path) as data:
        assert data["x"].dtype == np.uint16         # native storage
    got = ckpt.restore(path, {"x": jnp.zeros_like(x)})["x"]
    assert got.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(x).view(np.uint16),
                                  np.asarray(got).view(np.uint16))


def test_legacy_fp32_stored_bf16_still_restores(tmp_path):
    """Checkpoints written before native bf16 storage hold fp32 values
    under bf16 references — they restore via value cast."""
    x = jnp.asarray([1.5, -2.25, 3.0], jnp.bfloat16)
    path = str(tmp_path / "legacy.npz")
    np.savez(path, x=np.asarray(x, np.float32))     # the old format
    got = ckpt.restore(path, {"x": jnp.zeros_like(x)})["x"]
    assert got.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(x, np.float32),
                                  np.asarray(got, np.float32))


def test_python_scalar_leaves_roundtrip(tmp_path):
    tree = {"n": 7, "lr": 0.05, "on": True}
    path = str(tmp_path / "scalars.npz")
    ckpt.save(path, tree)
    got = ckpt.restore(path, {"n": 0, "lr": 0.0, "on": False})
    assert got == tree
    assert {k: type(v) for k, v in got.items()} == \
        {"n": int, "lr": float, "on": bool}


# --------------------------------------------------------------------------
# Full scheduler state
# --------------------------------------------------------------------------
def _build(depth, *, cache_dtype="int8", opt_state_dtype="float32",
           seed=0):
    spec = TabularSpec("criteo", fields_a=4, fields_b=3, vocab=32,
                       n_train=2048, n_test=512)
    data = make_tabular(spec, seed=0)
    cfg = DLRMConfig("wdl", 4, 3, vocab=32, embed_dim=4, z_dim=8,
                     hidden=(16, 8))
    init_fn, task, _ = make_dlrm(cfg)
    base = CELUConfig(R=3, W=3, xi_degrees=60.0, cache_dtype=cache_dtype)
    ccfg, nloc = engine.preset_config("celu", base)
    params = init_fn(jax.random.PRNGKey(seed), cfg)
    opt = make_optimizer("adagrad", 0.05, state_dtype=opt_state_dtype)
    asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
    etask = engine.lift_two_party(task)
    tp = engine.make_transport(ccfg, "topk_int8")
    it = aligned_batches(data["train"], 64, seed=seed)
    _, ba, bb = next(it)
    state = engine.init_state(etask, engine.lift_two_party_params(params),
                              opt, ccfg, [asj(ba)], asj(bb), transport=tp)
    pe = engine.make_pipeline(etask, opt, ccfg, depth=depth,
                              local_steps=nloc, transport=tp)
    return pe, pe.init(state), aligned_batches(data["train"], 64,
                                               seed=seed), asj


def _steps(pe, rs, it, asj, n):
    ms = []
    for _ in range(n):
        bi, ba, bb = next(it)
        rs, m = pe.step(rs, [asj(ba)], asj(bb), bi)
        ms.append(float(np.float32(m["loss"])))
    return rs, ms


def test_round_state_mid_pipeline_resume_bit_exact(tmp_path):
    """depth-2 run with an int8 workset cache and topk_int8 residuals:
    save after 4 rounds, restore into a FRESH engine (reference
    fabricated via the recorded pending depth), and the next step is
    bit-identical to the uninterrupted run — queue, QuantLeaf codes,
    residual chain and all."""
    pe0, rs0, it0, asj = _build(2)
    rs0, _ = _steps(pe0, rs0, it0, asj, 4)
    path = str(tmp_path / "mid.npz")
    ckpt.save_round_state(path, rs0, extra={"round": 4})
    rs0, l_ref = _steps(pe0, rs0, it0, asj, 1)      # uninterrupted step 5

    n = ckpt.peek_pending_len(path)
    assert n == len(rs0.pending)                     # steady state: D-1
    pe1, rs_ref, it1, asj = _build(2)
    for _ in range(n):
        bi, ba, bb = next(it1)
        rs_ref = pe1.dispatch(rs_ref, [asj(ba)], asj(bb), bi)
    rs1, extra = ckpt.restore_round_state(path, rs_ref,
                                          extra_reference={"round": 0})
    assert extra == {"round": 4}
    for _ in range(4 - n):   # position it1 at batch 4 (step 5's batch)
        next(it1)
    rs1, l_got = _steps(pe1, rs1, it1, asj, 1)       # resumed step 5
    np.testing.assert_array_equal(np.asarray(l_ref, np.float32),
                                  np.asarray(l_got, np.float32))
    for a, b in zip(jax.tree_util.tree_leaves(rs0.as_state()),
                    jax.tree_util.tree_leaves(rs1.as_state())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quantized_leaves_stored_natively(tmp_path):
    """Quant4Leaf rings and QuantAccum optimizer state land in the file
    as their packed uint8 / int8 codes + fp32 scales — no fp32 detour —
    and restore bit-exactly."""
    from repro.core.workset import Quant4Leaf
    from repro.optim.quantized import QuantAccum
    q4 = Quant4Leaf(
        jnp.asarray(np.random.default_rng(0).integers(0, 256, (3, 8, 4)),
                    jnp.uint8),
        jnp.asarray(np.random.default_rng(1).uniform(size=(3, 8)),
                    jnp.float32), (8, 8), jnp.float32)
    acc = QuantAccum(
        jnp.asarray(np.random.default_rng(2).integers(0, 128, (8, 16)),
                    jnp.int8),
        jnp.asarray(np.random.default_rng(3).uniform(size=(8, 1)),
                    jnp.float32), (128,))
    path = str(tmp_path / "quant.npz")
    ckpt.save(path, {"ring": q4, "acc": acc})
    with np.load(path) as data:
        dtypes = sorted(str(data[k].dtype) for k in data.files)
        assert dtypes == ["float32", "float32", "int8", "uint8"]
    ref = {"ring": Quant4Leaf(jnp.zeros((3, 8, 4), jnp.uint8),
                              jnp.zeros((3, 8), jnp.float32),
                              (8, 8), jnp.float32),
           "acc": QuantAccum(jnp.zeros((8, 16), jnp.int8),
                             jnp.zeros((8, 1), jnp.float32), (128,))}
    got = ckpt.restore(path, ref)
    np.testing.assert_array_equal(np.asarray(got["ring"].q),
                                  np.asarray(q4.q))
    np.testing.assert_array_equal(np.asarray(got["ring"].scale),
                                  np.asarray(q4.scale))
    np.testing.assert_array_equal(np.asarray(got["acc"].q),
                                  np.asarray(acc.q))
    np.testing.assert_array_equal(np.asarray(got["acc"].scale),
                                  np.asarray(acc.scale))


def test_round_state_resume_int4_cache_quantized_opt(tmp_path):
    """The PR-8 surfaces end to end: depth-2 pipeline over an int4
    nibble-packed workset ring with int8-at-rest AdaGrad state — saved
    mid-run, restored into a fresh engine, and the next step is
    bit-identical (the requant SR stream is seeded from the step counter,
    which rides the checkpoint)."""
    pe0, rs0, it0, asj = _build(2, cache_dtype="int4",
                                opt_state_dtype="int8")
    rs0, _ = _steps(pe0, rs0, it0, asj, 4)
    path = str(tmp_path / "mid4.npz")
    ckpt.save_round_state(path, rs0, extra={"round": 4})
    rs0, l_ref = _steps(pe0, rs0, it0, asj, 1)

    n = ckpt.peek_pending_len(path)
    pe1, rs_ref, it1, asj = _build(2, cache_dtype="int4",
                                   opt_state_dtype="int8")
    for _ in range(n):
        bi, ba, bb = next(it1)
        rs_ref = pe1.dispatch(rs_ref, [asj(ba)], asj(bb), bi)
    rs1, _ = ckpt.restore_round_state(path, rs_ref,
                                      extra_reference={"round": 0})
    for _ in range(4 - n):
        next(it1)
    rs1, l_got = _steps(pe1, rs1, it1, asj, 1)
    np.testing.assert_array_equal(np.asarray(l_ref, np.float32),
                                  np.asarray(l_got, np.float32))
    for a, b in zip(jax.tree_util.tree_leaves(rs0.as_state()),
                    jax.tree_util.tree_leaves(rs1.as_state())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_round_state_wrong_queue_depth_fails_loud(tmp_path):
    pe, rs, it, asj = _build(1)
    rs, _ = _steps(pe, rs, it, asj, 2)               # depth 1: no pending
    path = str(tmp_path / "d1.npz")
    ckpt.save_round_state(path, rs)
    assert ckpt.peek_pending_len(path) == 0
    bi, ba, bb = next(it)
    rs_bad = pe.dispatch(rs, [asj(ba)], asj(bb), bi)
    with pytest.raises(ValueError, match="in-flight"):
        ckpt.restore_round_state(path, rs_bad)


def test_plain_pytree_file_is_not_a_round_state(tmp_path):
    path = str(tmp_path / "plain.npz")
    ckpt.save(path, {"x": jnp.zeros(3)})
    with pytest.raises(KeyError, match="round-state"):
        ckpt.peek_pending_len(path)
