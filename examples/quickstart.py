"""Quickstart: CELU-VFL vs vanilla VFL vs FedBCD on the paper's WDL/Criteo
workload (synthetic, far-from-convergence regime like the paper's 41M-row
stream).

All three protocols are presets of the same K-party round engine
(``repro.core.engine``) over a ``SimWANTransport``; they get the SAME
communication budget (400 rounds = the same WAN bytes), and CELU funds
1+R model updates per round from its workset.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import run_protocol  # noqa: E402
from benchmarks.end_to_end import (COMPUTE_PER_UPDATE,  # noqa: E402
                                   hard_workload, paper_round_updown,
                                   sim_time)

ROUNDS = 400


def main():
    print("== CELU-VFL quickstart: WDL on synthetic Criteo ==")
    spec, data, cfg = hard_workload("wdl", "criteo")
    print(f"dataset: {spec.n_train} rows, fields A/B = "
          f"{spec.fields_a}/{spec.fields_b}; Z_A dim = {cfg.z_dim}; "
          f"equal budget: {ROUNDS} communication rounds each\n")

    results = {}
    for name, proto, kw in (
            ("vanilla", "vanilla", {}),
            ("fedbcd R=5", "fedbcd", dict(R=5)),
            ("celu   R=5", "celu", dict(R=5, W=5, xi=60.0)),
            # the two-worker pipeline (paper Fig. 4): round t+1's WAN
            # exchange is dispatched while round t's local updates run
            ("celu   R=5 pipe=1", "celu",
             dict(R=5, W=5, xi=60.0, pipeline_depth=1)),
            # the depth-D exchange queue (D >= 2): up to D exchanges in
            # flight for high-RTT links where one exchange cannot hide
            # behind one local scan.  Entries get D exchanges staler, so
            # weights are attenuated per slot (w -> w^(1+s)) and updates
            # lr-damped by 1/(1 + c*s) (c = pipeline_lr_damping, 0.25
            # default) — the convergence study gating this knob lives in
            # results/BENCH_pipeline_depth.json (nightly CI re-runs it)
            ("celu   R=5 pipe=2", "celu",
             dict(R=5, W=5, xi=60.0, pipeline_depth=2)),
            # the compressed wire: top-k+int8 sketches up, dense int8 down,
            # error feedback carrying the compression error between rounds
            ("celu   R=5 int8_topk", "celu",
             dict(R=5, W=5, xi=60.0, compression="int8_topk")),
            # the quantized-at-rest workset cache: stale ⟨Z, ∇Z⟩ stored as
            # int8 codes + one fp32 scale per instance row, sampled through
            # the fused gather→dequant→weight megakernel
            ("celu   R=5 int8cache", "celu",
             dict(R=5, W=5, xi=60.0, cache_dtype="int8"))):
        r = run_protocol(proto, data, cfg, rounds=ROUNDS, lr=0.003,
                         eval_every=100, **kw)
        results[name] = r
        curve = "  ".join(f"@{s}:{a:.4f}" for s, a in r["curve"])
        print(f"{name}:  {curve}")

    zb = results["vanilla"]["z_bytes_per_round"]
    czb = results["celu   R=5 int8_topk"]["z_bytes_per_round"]
    print(f"\nWAN bytes spent by the fp32 wire: {ROUNDS * zb / 1e6:.1f} MB "
          f"({zb / 1e3:.0f} KB/round); CELU extracted "
          f"{1 + 5}x the model updates from them.")
    print(f"int8_topk wire: {czb / 1e3:.1f} KB/round "
          f"({zb / czb:.1f}x fewer bytes at the same round budget); "
          "bf16 wire (CELUConfig.wire_dtype) is the lighter-touch option — "
          "see benchmarks `beyond` block.")
    # cache memory math (core/workset.py storage codec): the workset table
    # holds W batches of ⟨Z, ∇Z⟩ per party — at realistic geometry it
    # dominates training-state memory, and int8-at-rest cuts it ~4x:
    #     cache_bytes(fp32) = 2 * W * B * F * 4
    #     cache_bytes(int8) = 2 * W * B * (F + 4)    # codes + row scale
    r32, r8 = results["celu   R=5"], results["celu   R=5 int8cache"]
    print(f"\nworkset cache (this run's geometry): "
          f"{r32['stat_cache_bytes'] / 1e3:.0f} KB fp32 -> "
          f"{r8['stat_cache_bytes'] / 1e3:.0f} KB int8 "
          f"({r32['stat_cache_bytes'] / r8['stat_cache_bytes']:.2f}x "
          f"smaller, measured); at paper geometry (W=5, B=4096, z=256): "
          f"{2 * 5 * 4096 * 256 * 4 / 1e6:.1f} MB -> "
          f"{2 * 5 * 4096 * (256 + 4) / 1e6:.1f} MB per party.  "
          f"AUC parity: {r32['final_auc']:.4f} fp32 vs "
          f"{r8['final_auc']:.4f} int8.")
    # overlap-aware latency at the paper's deployment geometry: the
    # pipelined schedule pays max(exchange, local) per round, the
    # sequential one pays their sum (repro.launch.wan.WANClock)
    updown = paper_round_updown()
    t_seq = sim_time(ROUNDS, updown, 5.0, pipeline_depth=0)
    t_pipe = sim_time(ROUNDS, updown, 5.0, pipeline_depth=1)
    t_deep = sim_time(ROUNDS, updown, 5.0, pipeline_depth=2)
    print(f"pipelined schedule (pipe=1): the same {ROUNDS} rounds cost "
          f"{t_pipe:.0f}s of simulated WAN time vs {t_seq:.0f}s sequential "
          f"-> {t_seq / t_pipe:.2f}x lower latency at paper geometry "
          f"(300 Mbps, {COMPUTE_PER_UPDATE * 1e3:.0f} ms/update); the "
          f"depth-2 queue amortizes the exchange over 2 rounds -> "
          f"{t_deep:.0f}s ({t_seq / t_deep:.2f}x), bounded below by the "
          f"serial wire occupancy.")


if __name__ == "__main__":
    main()
