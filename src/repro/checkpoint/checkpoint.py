"""Pytree checkpointing to .npz (flat key paths), no external deps.

Per-party checkpoints: in a real deployment each party persists only its own
tower (privacy discipline) — ``save(path, state, party="a")`` selects the
corresponding subtree.  Restore rebuilds into the exact reference pytree, so
shapes/dtypes are validated on load.
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_key_str(p) for p in path)
        arr = np.asarray(leaf) if leaf.dtype != jnp.bfloat16 else \
            np.asarray(leaf.astype(jnp.float32))  # numpy has no bf16
        flat[key] = arr
    return flat


def _key_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return p.name
    return str(p)


def save(path: str, tree: Any, party: Optional[str] = None) -> None:
    if party is not None:
        tree = {party: tree[party]} if isinstance(tree, dict) else tree
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def restore(path: str, reference: Any) -> Any:
    """Load into the structure of ``reference`` (shape/dtype checked)."""
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    leaves_ref, treedef = jax.tree_util.tree_flatten_with_path(reference)
    out = []
    for pathkeys, ref in leaves_ref:
        key = _SEP.join(_key_str(p) for p in pathkeys)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {ref.shape}")
        out.append(jnp.asarray(arr, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(reference), out)
