"""Flash attention (forward) Pallas kernel for the tower hot-spot.

Online-softmax blockwise attention with explicit VMEM tiling:

  grid = (B * H, S / BLOCK_Q); each step owns one (BLOCK_Q, hd) query tile
  and loops the KV sequence in (BLOCK_K, hd) tiles with running
  (max, sum, acc) statistics — the classic flash recurrence, laid out for
  the MXU: both matmuls are (BLOCK_Q, hd) x (hd, BLOCK_K) and
  (BLOCK_Q, BLOCK_K) x (BLOCK_K, hd) with hd, BLOCK_* multiples of 128.

Supports causal and sliding-window masking; GQA is handled by the ops.py
wrapper (kv heads repeated before the call — regrouping inside the kernel
would only save HBM for the K/V streams, noted as a future optimization).

Causal block skipping: for query tile qi, KV tiles with ki > qi are fully
masked — the kernel loop bound is ``qi + 1`` in the causal case, halving the
work (and for sliding windows the lower bound skips tiles left of the
window).  This is the TPU analogue of the CUDA kernel's early-exit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
BLOCK_Q = 256
BLOCK_K = 256


def _kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool,
            window: int, seq_len: int):
    qi = pl.program_id(1)
    bq = q_ref.shape[0]
    hd = q_ref.shape[1]
    q = q_ref[...].astype(jnp.float32)            # (BQ, hd)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)[:, 0]

    n_kb = seq_len // block_k
    if causal:
        # tiles strictly right of the diagonal contribute nothing
        hi = jnp.minimum((qi * bq + bq + block_k - 1) // block_k, n_kb)
    else:
        hi = n_kb
    if window:
        lo = jnp.maximum((qi * bq - window) // block_k, 0)
    else:
        lo = 0

    def body(ki, carry):
        acc, m, l = carry
        ks = pl.load(k_ref, (pl.dslice(ki * block_k, block_k),
                             pl.dslice(None))).astype(jnp.float32)
        vs = pl.load(v_ref, (pl.dslice(ki * block_k, block_k),
                             pl.dslice(None))).astype(jnp.float32)
        s = jax.lax.dot_general(q, ks, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)[0]
        d = q_pos[:, None] - k_pos[None, :]
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= d >= 0
        if window:
            mask &= d < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, vs, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc_new, m_new, l_new

    init = (jnp.zeros((bq, hd), jnp.float32),
            jnp.full((bq,), NEG_INF, jnp.float32),
            jnp.zeros((bq,), jnp.float32))
    acc, m, l = jax.lax.fori_loop(lo, hi, body, init)
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    interpret: bool = True):
    """q, k, v: (B, S, H, hd) (kv already repeated to H).  -> (B, S, H, hd).

    S must be a multiple of BLOCK_Q/BLOCK_K (pad upstream if not).
    """
    B, S, H, hd = q.shape
    bq = min(BLOCK_Q, S)
    bk = min(BLOCK_K, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)

    # (B, S, H, hd) -> (B*H, S, hd): head-major grid, seq contiguous per step
    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    qf, kf, vf = fold(q), fold(k), fold(v)

    kernel = functools.partial(_kernel, block_k=bk, causal=causal,
                               window=window, seq_len=S)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, S // bq),
        in_specs=[
            pl.BlockSpec((None, bq, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, S, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, S, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
