"""CELU-VFL core: workset table, instance weighting, training protocols."""
from . import protocol, weighting, workset  # noqa: F401
from .protocol import VFLTask, init_state, make_round, protocol_config  # noqa: F401
