"""Production mesh definitions (TPU v5e target).

Single pod = 16 x 16 = 256 chips, axes (data, model).
Multi-pod  = 2 x 16 x 16 = 512 chips, axes (pod, data, model); the ``pod``
axis is the slow inter-pod link — in the CELU party-to-pod mapping it
carries the two VFL parties (core/pod_protocol.py), in the generic dry-run
it extends data parallelism.

Functions, not module constants: importing this module never touches jax
device state (device count locks on first jax init).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) for the roofline terms
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link

SINGLE_POD_SHAPE = (16, 16)
MULTI_POD_SHAPE = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever fits the current host's devices — for smoke tests."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_fleet_mesh(n_devices: int | None = None):
    """1-D ``("fleet",)`` mesh over the host's devices — the job axis of
    the vmapped fleet runner (``repro.fleet``) shards over it, one
    contiguous block of jobs per device.

    On CPU CI the device grid comes from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set in the
    ENVIRONMENT of a fresh process (before jax's first import — see
    launch/dryrun.py and the pod subprocess tests for the precedent);
    this function never mutates device state itself."""
    n = len(jax.devices()) if n_devices is None else int(n_devices)
    return jax.make_mesh((n,), ("fleet",))


def fleet_job_sharding(mesh):
    """NamedSharding splitting a leading job axis over the fleet mesh."""
    from jax.sharding import NamedSharding, PartitionSpec
    return NamedSharding(mesh, PartitionSpec("fleet"))
