"""Wire-codec and compressed-transport invariants.

Deterministic pins always run: exact byte accounting
(``wire_bytes() == sum of payload nbytes``), quantization error bounds,
top-k selection, fused-Pallas-vs-jnp quantizer parity (including the
odd-tile-count fallback, mirroring
``test_fused_weighting_odd_batch_falls_back``), stochastic-rounding
unbiasedness under vmapped keys, and error-feedback telescoping.  The
randomized sweeps at the bottom are hypothesis-guarded like
``test_property.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CELUConfig
from repro.core import compression as C
from repro.core import engine
from repro.kernels import ops as kops
from repro.kernels.ref import quantize_sr_ref

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SHAPES = [(256, 32), (64, 8), (37, 5), (1, 1), (3, 7, 11)]


def _x(shape, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def _codecs_of(spec):
    up, down = C.make_codec_pair(spec)
    return [("up", up), ("down", down)]


# --------------------------------------------------------------------------
# Byte accounting: wire_bytes is the ACTUAL payload size
# --------------------------------------------------------------------------
@pytest.mark.parametrize("spec", C.CODEC_SPECS)
@pytest.mark.parametrize("shape", SHAPES)
def test_wire_bytes_matches_payload_nbytes(spec, shape):
    rng = jax.random.PRNGKey(1)
    for _, codec in _codecs_of(spec):
        payload = codec.encode(rng, _x(shape))
        assert codec.wire_bytes(shape, jnp.float32) == \
            C.payload_nbytes(payload), (spec, shape)


def test_topk_index_dtype_shrinks_with_message():
    small = C.TopKCodec(0.25)
    p = small.encode(jax.random.PRNGKey(0), _x((64, 8)))
    assert p["idx"].dtype == jnp.int16
    big = small.encode(jax.random.PRNGKey(0), _x((1024, 64)))
    assert big["idx"].dtype == jnp.int32


# --------------------------------------------------------------------------
# Quantization: per-tile error bound + decode(encode) structure
# --------------------------------------------------------------------------
@pytest.mark.parametrize("bits", [8, 4])
def test_quant_roundtrip_error_bounded_by_tile_scale(bits):
    codec = C.StochasticQuantCodec(bits)
    x = _x((256, 32), seed=2)
    xh = codec.decode(codec.encode(jax.random.PRNGKey(3), x), x)
    # stochastic rounding moves each value by < 1 code step = tile scale
    flat = np.asarray(x).ravel()
    n, tile = flat.size, codec.tile
    T = -(-n // tile)
    pad = np.pad(flat, (0, T * tile - n)).reshape(T, tile)
    scale = np.maximum(np.abs(pad).max(axis=1), 1e-12) / codec.levels
    err = np.abs(np.asarray(xh).ravel() - flat).reshape(-1)
    bound = np.repeat(scale, tile)[:n] * (1 + 1e-6)
    assert (err <= bound).all(), (bits, err.max(), bound.min())


def test_int4_packs_two_codes_per_byte():
    codec = C.StochasticQuantCodec(4)
    x = _x((8, 32), seed=4)
    p = codec.encode(jax.random.PRNGKey(5), x)
    assert p["q"].dtype == jnp.uint8
    assert p["q"].shape[-1] == codec.tile // 2
    # wire cost is half of int8's code bytes (scales identical)
    b8 = C.StochasticQuantCodec(8).wire_bytes(x.shape, jnp.float32)
    b4 = codec.wire_bytes(x.shape, jnp.float32)
    T = -(-x.size // codec.tile)
    assert b8 - b4 == T * codec.tile // 2


def test_stochastic_rounding_unbiased_under_vmapped_keys():
    codec = C.StochasticQuantCodec(8)
    x = _x((4, 16), seed=6)
    keys = jax.random.split(jax.random.PRNGKey(7), 1024)
    dec = jax.vmap(lambda k: codec.decode(codec.encode(k, x), x))(keys)
    scale = float(jnp.max(jnp.abs(x))) / codec.levels
    bias = float(jnp.max(jnp.abs(dec.mean(axis=0) - x)))
    # SR variance per element <= scale^2/4 -> 5 sigma of the mean over
    # 1024 keys is ~0.08 * scale
    assert bias <= 0.15 * scale, (bias, scale)


# --------------------------------------------------------------------------
# Top-k: keeps exactly the k largest magnitudes
# --------------------------------------------------------------------------
def test_topk_preserves_k_largest_magnitudes():
    codec = C.TopKCodec(0.25)
    x = _x((16, 16), seed=8)
    xh = np.asarray(codec.decode(codec.encode(jax.random.PRNGKey(9), x), x))
    flat = np.asarray(x).ravel()
    k = codec.k_of(flat.size)
    top = set(np.argsort(-np.abs(flat))[:k].tolist())
    kept = set(np.nonzero(xh.ravel())[0].tolist())
    assert kept == top
    np.testing.assert_array_equal(xh.ravel()[sorted(kept)],
                                  flat[sorted(kept)])
    assert (xh.ravel()[sorted(set(range(flat.size)) - kept)] == 0).all()


def test_chain_codec_refines_single_stage():
    """Residual chaining: int4x2's reconstruction beats one int4 pass, and
    a chain ending in identity is exact (and flagged lossless)."""
    x = _x((64, 32), seed=10)
    rng = jax.random.PRNGKey(11)
    one = C.StochasticQuantCodec(4)
    two = C.ChainCodec([C.StochasticQuantCodec(4), C.StochasticQuantCodec(4)])
    e1 = float(jnp.abs(one.decode(one.encode(rng, x), x) - x).max())
    e2 = float(jnp.abs(two.decode(two.encode(rng, x), x) - x).max())
    assert e2 < e1, (e2, e1)
    # a chain ending in identity reconstructs to fp32 rounding (the
    # identity stage's payload carries the whole remaining residual)
    exact = C.ChainCodec([C.StochasticQuantCodec(4), C.IdentityCodec()])
    assert exact.lossless
    np.testing.assert_allclose(
        np.asarray(exact.decode(exact.encode(rng, x), x)), np.asarray(x),
        rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# Fused Pallas quantizer vs the jnp reference
# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(8, 16), (64, 128), (256, 64)])
@pytest.mark.parametrize("levels", [127, 7])
def test_fused_quantize_kernel_matches_ref(shape, levels):
    """Bit-exact, including multi-block grids (per-tile ops only — no
    cross-tile reassociation)."""
    x = _x(shape, seed=12)
    u = jax.random.uniform(jax.random.PRNGKey(13), shape, jnp.float32)
    qk, sk = kops.quantize_stochastic(x, u, levels)
    qr, sr = quantize_sr_ref(x, u, levels)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))


def test_fused_quantize_odd_tile_count_falls_back():
    """Tile counts the Pallas grid can't split fall back to the reference
    path inside the codec instead of failing (the quantizer analogue of
    test_fused_weighting_odd_batch_falls_back)."""
    from repro.kernels.quantize import BLOCK_T
    codec = C.StochasticQuantCodec(8)
    n = (BLOCK_T + 1) * codec.tile          # T = BLOCK_T + 1: not tileable
    x = _x((n,), seed=14)
    rng = jax.random.PRNGKey(15)
    p = codec.encode(rng, x)
    assert p["q"].shape == (BLOCK_T + 1, codec.tile)
    assert codec.wire_bytes(x.shape, jnp.float32) == C.payload_nbytes(p)
    # the fallback IS the reference: reproduce it exactly
    u = jax.random.uniform(rng, (BLOCK_T + 1, codec.tile), jnp.float32)
    qr, sr = quantize_sr_ref(x.reshape(BLOCK_T + 1, codec.tile), u, 127)
    np.testing.assert_array_equal(np.asarray(p["q"]), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(p["scale"]), np.asarray(sr))


# --------------------------------------------------------------------------
# Error feedback: decoded messages telescope to the uncompressed sum
# --------------------------------------------------------------------------
@pytest.mark.parametrize("spec", ["int8", "topk", "topk_int8"])
def test_error_feedback_residuals_telescope(spec):
    """With error feedback, sum(decoded) + final residual == sum(sent):
    compression error is delayed into later messages, never lost — so the
    decoded stream is an unbiased estimate of the identity transport's."""
    up, _ = C.make_codec_pair(spec)
    tp = engine.CompressedWANTransport(CELUConfig(), up)
    (res,) = tp.init_state([jnp.zeros((16, 8))])["up"]
    total_in = jnp.zeros((16, 8))
    total_out = jnp.zeros((16, 8))
    for t in range(12):
        x = _x((16, 8), seed=100 + t)
        y, res = tp.send(jax.random.PRNGKey(200 + t), x, res, "up")
        total_in = total_in + x
        total_out = total_out + y
    np.testing.assert_allclose(np.asarray(total_out + res),
                               np.asarray(total_in), rtol=1e-5, atol=1e-5)
    # and the residual stays bounded (error feedback is stable)
    assert float(jnp.abs(res).max()) < 10 * float(jnp.abs(total_in).max())


def test_identity_codec_send_is_bitwise_simwan():
    for wire in ("float32", "bfloat16"):
        celu = CELUConfig(wire_dtype=wire)
        plain = engine.SimWANTransport(celu)
        ident = engine.make_transport(celu, "identity")
        assert isinstance(ident, engine.CompressedWANTransport)
        assert ident.init_state([jnp.zeros((8, 4))]) == {}
        x = _x((32, 8), seed=16)
        rng = jax.random.PRNGKey(17)
        yp, _ = plain.send(rng, x, None, "up")
        yc, _ = ident.send(rng, x, None, "up")
        np.testing.assert_array_equal(np.asarray(yp), np.asarray(yc))
        assert ident.round_bytes([(32, 8)]) == plain.round_bytes([(32, 8)])


def test_plateau_ratio_schedule_steps_on_stall():
    """The schedule loosens sparsity only when the loss stops improving:
    ``patience`` consecutive non-improvements step the ratio ladder, an
    improvement resets the stall counter, and the top rung is terminal."""
    s = C.PlateauRatioSchedule(ratios=(0.1, 0.2, 0.4), patience=2,
                               min_delta=0.01)
    assert s.ratio == 0.1
    assert s.update(1.00) is None           # first obs: improves inf
    assert s.update(0.90) is None           # improving
    assert s.update(0.895) is None          # stall 1 (< min_delta better)
    assert s.update(0.896) == 0.2           # stall 2 -> step
    assert s.ratio == 0.2
    assert s.update(0.80) is None           # improvement resets
    assert s.update(0.80) is None
    assert s.update(0.80) == 0.4
    # top rung: no further steps no matter the stall
    for _ in range(5):
        assert s.update(0.80) is None
    assert s.ratio == 0.4


def test_plateau_ratio_schedule_ignores_nonfinite():
    """Regression: a depth-D pipeline reports NaN losses for its D-1
    warmup rounds, and NaN used to fall through to the stall branch
    (``NaN < best`` is False) — the ratio ladder stepped on warmup
    artifacts before the first real loss arrived.  Non-finite
    observations must be complete no-ops: no stall tick, no best update,
    no ratio step."""
    s = C.PlateauRatioSchedule(ratios=(0.1, 0.2), patience=2,
                               min_delta=0.01)
    for bad in (float("nan"), float("inf"), float("-inf"),
                jnp.float32(jnp.nan)):
        assert s.update(bad) is None
    assert (s.ratio, s.stall, s.best) == (0.1, 0, float("inf"))
    # a NaN mid-stall neither extends nor resets the stall count
    assert s.update(1.0) is None
    assert s.update(1.0) is None            # stall 1
    assert s.update(float("nan")) is None   # ignored
    assert s.stall == 1
    assert s.update(1.0) == 0.2             # stall 2 -> step
    assert s.ratio == 0.2


def test_topk_ratio_schedule_hook():
    """with_ratio / scheduled rebuild the codec around a new keep-ratio
    (larger wire) while preserving the value codec and the hook."""
    sched = C.PlateauRatioSchedule(ratios=(0.125, 0.5), patience=1,
                                   min_delta=0.01)
    codec = C.TopKCodec(0.125, value_codec=C.StochasticQuantCodec(8),
                        ratio_schedule=sched)
    shape = (256, 32)
    b0 = codec.wire_bytes(shape, jnp.float32)
    assert codec.scheduled(1.0) is codec            # improving: unchanged
    loose = codec.scheduled(1.0)                    # stall 1 -> step
    assert loose is not codec and loose.ratio == 0.5
    assert isinstance(loose.value_codec, C.StochasticQuantCodec)
    assert loose.ratio_schedule is sched
    assert loose.wire_bytes(shape, jnp.float32) > b0
    # wire accounting stays exact at the new ratio
    p = loose.encode(jax.random.PRNGKey(0), _x(shape))
    assert loose.wire_bytes(shape, jnp.float32) == C.payload_nbytes(p)
    # schedule exhausted at the top rung: no more changes
    assert loose.scheduled(1.0) is loose


def test_compressed_transport_scheduled_rebuild():
    """Transport-level hook: a fired up-codec schedule yields a NEW
    transport with the loosened uplink, same downlink, and a residual
    state structure that carries over."""
    celu = CELUConfig()
    sched = C.PlateauRatioSchedule(ratios=(0.125, 0.25), patience=1,
                                   min_delta=0.01)
    up = C.TopKCodec(0.125, value_codec=C.StochasticQuantCodec(8),
                     ratio_schedule=sched)
    down = C.StochasticQuantCodec(8)
    tp = engine.CompressedWANTransport(celu, up, down)
    assert tp.scheduled(1.0) is tp                  # improving
    tp2 = tp.scheduled(1.0)                         # plateau -> rebuild
    assert tp2 is not tp
    assert tp2.codecs["up"].ratio == 0.25
    assert tp2.codecs["down"] is down
    assert tp2.uplink_bytes((64, 8)) > tp.uplink_bytes((64, 8))
    assert tp2.downlink_bytes((64, 8)) == tp.downlink_bytes((64, 8))
    z = [jnp.zeros((64, 8))]
    assert jax.tree_util.tree_structure(tp.init_state(z)) == \
        jax.tree_util.tree_structure(tp2.init_state(z))


def test_topk_schedule_rung_syncs_to_codec_ratio():
    """A codec built at a ratio above the ladder's first rung syncs the
    schedule forward — a fired step must LOOSEN, never tighten — and a
    ratio off the ladder is rejected."""
    sched = C.PlateauRatioSchedule(ratios=(0.0625, 0.125, 0.25, 0.5),
                                   patience=1, min_delta=0.01)
    codec = C.TopKCodec(0.25, ratio_schedule=sched)
    assert sched.ratio == 0.25
    codec.scheduled(1.0)                            # improving (first obs)
    stepped = codec.scheduled(1.0)                  # stall -> step
    assert stepped.ratio == 0.5                     # up the ladder, not 0.125
    with pytest.raises(ValueError, match="ladder"):
        C.TopKCodec(0.3, ratio_schedule=C.PlateauRatioSchedule())


def test_symmetric_transport_consults_shared_codec_once():
    """With one codec object serving both directions, each loss
    observation must hit the schedule ONCE (not once per direction), and
    a fired step must keep the directions in lockstep."""
    celu = CELUConfig()
    sched = C.PlateauRatioSchedule(ratios=(0.125, 0.25), patience=2,
                                   min_delta=0.01)
    up = C.TopKCodec(0.125, ratio_schedule=sched)
    tp = engine.CompressedWANTransport(celu, up)    # down aliases up
    assert tp.codecs["down"] is tp.codecs["up"]
    tp.scheduled(1.0)                               # improving
    assert tp.scheduled(1.0) is tp                  # stall 1 of patience 2
    assert sched.stall == 1                         # consulted once, not twice
    tp2 = tp.scheduled(1.0)                         # stall 2 -> step
    assert tp2 is not tp
    assert tp2.codecs["up"] is tp2.codecs["down"]   # still in lockstep
    assert tp2.codecs["up"].ratio == 0.25


# --------------------------------------------------------------------------
# Hypothesis sweeps (guarded like test_property.py)
# --------------------------------------------------------------------------
if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(st.sampled_from(C.CODEC_SPECS), st.integers(1, 48),
           st.integers(1, 48), st.integers(0, 2 ** 31 - 1))
    def test_prop_wire_bytes_exact(spec, B, F, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
        for _, codec in _codecs_of(spec):
            p = codec.encode(jax.random.PRNGKey(seed % 997), x)
            assert codec.wire_bytes(x.shape, jnp.float32) == \
                C.payload_nbytes(p)

    @settings(max_examples=25, deadline=None)
    @given(st.sampled_from([8, 4]), st.integers(1, 40), st.integers(1, 40),
           st.integers(0, 2 ** 31 - 1))
    def test_prop_quant_error_bounded(bits, B, F, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
        codec = C.StochasticQuantCodec(bits)
        xh = codec.decode(codec.encode(jax.random.PRNGKey(seed % 997), x), x)
        flat = np.asarray(x).ravel()
        T = -(-flat.size // codec.tile)
        pad = np.pad(flat, (0, T * codec.tile - flat.size))
        scale = np.maximum(
            np.abs(pad.reshape(T, codec.tile)).max(axis=1),
            1e-12) / codec.levels
        err = np.abs(np.asarray(xh).ravel() - flat)
        bound = np.repeat(scale, codec.tile)[:flat.size] * (1 + 1e-6)
        assert (err <= bound).all()

    @settings(max_examples=25, deadline=None)
    @given(st.floats(0.05, 1.0), st.integers(2, 40), st.integers(1, 24),
           st.integers(0, 2 ** 31 - 1))
    def test_prop_topk_keeps_largest(ratio, B, F, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
        codec = C.TopKCodec(ratio)
        xh = np.asarray(
            codec.decode(codec.encode(jax.random.PRNGKey(seed % 997), x), x))
        flat = np.asarray(x).ravel()
        k = codec.k_of(flat.size)
        kept = np.nonzero(xh.ravel())[0]
        # every kept magnitude >= every dropped magnitude
        dropped = np.setdiff1d(np.arange(flat.size), kept)
        if kept.size and dropped.size:
            assert np.abs(flat[kept]).min() >= np.abs(flat[dropped]).max() \
                - 1e-7
        assert kept.size <= k
