"""The workset table: a device-resident ring buffer of cached statistics.

Paper §3.1: the table caches ``⟨i, Z_A^(i), ∇Z_A^(i), j⟩`` entries with two
clocks per entry — the insertion timestamp ``i`` (the communication round
that produced it) and the use count ``j``.  Eviction rules:

  * capacity: during the insertion at time ``i``, entries inserted before
    ``i - W + 1`` are dead (the ring buffer overwrites slot ``i mod W``, and
    the validity predicate ``insert_time > time - W`` retires the rest);
  * exhaustion: entries that reach ``R`` uses are dead.

Everything is a fixed-shape pytree of jnp arrays, so insert / sample /
tick are all jittable (``lax.dynamic_*`` only — no Python in the step) and
the table shards like any other training-state leaf (batch dim over the
``data`` mesh axis).

Each party owns its own table.  Besides the exchanged statistics, a party
caches its OWN features for the batch (Party A: ``X_A``; Party B: ``X_B, y``)
so local updates never touch the host — callers pass those through the
generic ``aux`` pytree.

Round-robin sampling (paper §3.2): a cursor walks slots in insertion order;
a slot cannot be re-sampled within ``W-1`` local steps by construction.
Consecutive sampling (FedBCD / the ``W=1`` degenerate case) always returns
the most recently inserted slot.

Storage codec (quantized-at-rest cache)
---------------------------------------
At realistic capacities the table dominates training-state memory, and the
wire statistics it caches tolerate aggressive quantization (Compressed-VFL
— the same result the compressed transport exploits on the wire).
``workset_init(..., cache_dtype=...)`` selects the at-rest precision of
the cut-statistic subtrees (the ``z``/``dz`` entry keys, ``QUANT_KEYS``):

  * ``"float32"`` — store leaves as-is (bit-identical to the historical
    table; the golden traces pin this);
  * ``"bfloat16"`` — leaves stored as bf16 (:class:`CastLeaf`), halving
    the footprint; decode upcasts back to the original dtype;
  * ``"int8"`` — leaves stored as int8 codes with one fp32 absmax scale
    per *instance row* (:class:`QuantLeaf`), quantized on insert with the
    fused Pallas stochastic-rounding kernel (``ops.quantize_stochastic``,
    unbiased: ``E[q * s] == x``).  ~4x smaller.  The row is the tile
    because Algorithm-2's cosine is a row reduction — row-granular scales
    let the fused sample kernel gather + dequantize + weight in one VMEM
    pass without re-tiling.
  * ``"int4"`` — int4 codes (levels = ±7), nibble-packed two per byte
    (:class:`Quant4Leaf`; the PR-2 wire codec's packing applied at rest).
    Same per-row fp32 scale, same SR quantizer at ``levels=7``; odd row
    widths pad one zero code before packing (the pad nibble decodes to an
    exact zero, so it contributes nothing to the cosine reductions).
    ~7x smaller than fp32 — the LLM-geometry setting, where the cache is
    a party's dominant training-state allocation.

Cache memory math (per party, ``z`` + ``dz``, scales included):

    cache_bytes(fp32) = 2 * W * B * F * 4
    cache_bytes(int8) = 2 * W * B * (F + 4)        # codes + fp32 row scale
    cache_bytes(int4) = 2 * W * B * (ceil(F/2) + 4)  # packed nibbles

    geometry                          fp32        int8     int4
    paper  W=5 B=4096 F=256         41.9 MB     10.6 MB    5.4 MB
    llm    W=5 B=256  S=64 d=128    83.9 MB     21.2 MB   10.6 MB
    smollm W=5 B=8 S=1024 d=960    1573.0 MB   399.5 MB  196.9 MB

``insert`` and ``sample`` auto-detect the table's storage form — only
``workset_init`` takes ``cache_dtype``.  ``workset_sample`` returns
decoded (full-precision) entries; the fused sample path in
``repro.core.engine`` skips that materialization entirely by handing the
ring + slot to the gather→dequant→weight megakernel
(``kernels/fused_sample.py``).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

INT_MIN = -(2 ** 30)

# Entry keys holding the exchanged cut statistics — the subtrees the
# storage codec quantizes.  Everything else (own features, labels) is
# cached verbatim.
QUANT_KEYS = ("z", "dz")

CACHE_DTYPES = ("float32", "bfloat16", "int8", "int4")


# --------------------------------------------------------------------------
# Storage containers (registered pytree nodes: traced codes/scales as
# children, static shape/dtype as aux data — jit/scan/shard-safe)
# --------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
class QuantLeaf:
    """int8-at-rest storage of one cached statistic leaf.

    ``q`` holds signed int8 codes of the leaf flattened to (B, F) rows
    (table level: (W, B, F)), ``scale`` one fp32 absmax scale per row
    ((B,) / (W, B)).  ``shape``/``dtype`` remember the original per-entry
    leaf so :meth:`dequant` can restore it."""

    __slots__ = ("q", "scale", "shape", "dtype")

    def __init__(self, q, scale, shape, dtype):
        self.q = q
        self.scale = scale
        self.shape = tuple(shape)
        self.dtype = jnp.dtype(dtype)

    def tree_flatten(self):
        return (self.q, self.scale), (self.shape, str(self.dtype))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[1])

    def dequant(self):
        """Entry-level (q (B, F), scale (B,)) -> the original leaf."""
        x = self.q.astype(jnp.float32) * self.scale[:, None]
        return x.reshape(self.shape).astype(self.dtype)


def pack_nibbles(q):
    """Signed int4 codes (..., Fp) in [-7, 7] (Fp even) -> packed uint8
    (..., Fp // 2).  Same bias-and-or layout as the PR-2 wire codec
    (``compression.StochasticQuantCodec(bits=4)``): byte j holds element
    2j in the low nibble and 2j + 1 in the high nibble, each biased by
    +8 so the zero code is the nibble value 8."""
    b = (q + 8).astype(jnp.uint8)                  # [-7, 7] -> [1, 15]
    return b[..., 0::2] | (b[..., 1::2] << 4)


def unpack_nibbles(packed):
    """Packed uint8 (..., P) -> signed int4 codes (..., 2 * P) in
    [-8, 7] fp32-safe int8 (the inverse of :func:`pack_nibbles`)."""
    lo = (packed & 0xF).astype(jnp.int8) - 8
    hi = (packed >> 4).astype(jnp.int8) - 8
    return jnp.stack([lo, hi], axis=-1).reshape(packed.shape[:-1] + (-1,))


def _pad_even(F: int) -> int:
    return F + (F & 1)


@jax.tree_util.register_pytree_node_class
class Quant4Leaf:
    """int4 nibble-packed at-rest storage of one cached statistic leaf.

    ``q`` holds packed uint8 bytes — two signed int4 codes (levels ±7)
    per byte — of the leaf flattened to (B, F) rows and F padded to even
    (entry level (B, ceil(F/2)); table level (W, B, ceil(F/2))).
    ``scale`` is one fp32 absmax scale per row ((B,) / (W, B)), exactly
    like :class:`QuantLeaf`.  The pad nibble stores code 0 so it decodes
    to an exact zero; :meth:`dequant` slices it away."""

    __slots__ = ("q", "scale", "shape", "dtype")

    def __init__(self, q, scale, shape, dtype):
        self.q = q
        self.scale = scale
        self.shape = tuple(shape)
        self.dtype = jnp.dtype(dtype)

    def tree_flatten(self):
        return (self.q, self.scale), (self.shape, str(self.dtype))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[1])

    def dequant(self):
        """Entry-level (q (B, ceil(F/2)), scale (B,)) -> the original
        leaf."""
        F = 1
        for s in self.shape[1:]:
            F *= int(s)
        codes = unpack_nibbles(self.q)[:, :max(F, 1)]
        x = codes.astype(jnp.float32) * self.scale[:, None]
        return x.reshape(self.shape).astype(self.dtype)


@jax.tree_util.register_pytree_node_class
class CastLeaf:
    """bf16-at-rest storage of one cached statistic leaf (a plain dtype
    cast; ``dtype`` remembers the original for decode)."""

    __slots__ = ("v", "dtype")

    def __init__(self, v, dtype):
        self.v = v
        self.dtype = jnp.dtype(dtype)

    def tree_flatten(self):
        return (self.v,), (str(self.dtype),)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0])

    def decode(self):
        return self.v.astype(self.dtype)


def _is_store(x) -> bool:
    return isinstance(x, (QuantLeaf, Quant4Leaf, CastLeaf))


def _row_shape(a) -> Tuple[int, int]:
    """Leaf (B, ...) -> (rows B, flattened row length F)."""
    B = int(a.shape[0])
    F = 1
    for s in a.shape[1:]:
        F *= int(s)
    return B, max(F, 1)


def _quantize_rows(rng, x2d, levels: int = 127):
    """(B, F) fp32 -> (codes int8 (B, F), fp32 row scales (B,)); the fused
    Pallas SR quantizer when the grid can tile B, its bit-identical jnp
    oracle otherwise.  ``levels`` is the max code magnitude (127 = int8 at
    rest, 7 = int4 at rest — the codes come back int8 either way; the int4
    caller nibble-packs them)."""
    from ..kernels.quantize import BLOCK_T
    B = x2d.shape[0]
    u = jax.random.uniform(rng, x2d.shape, jnp.float32)
    if B % min(BLOCK_T, B) == 0:
        from ..kernels import ops as kops
        return kops.quantize_stochastic(x2d, u, levels)
    from ..kernels.ref import quantize_sr_ref
    return quantize_sr_ref(x2d, u, levels)


def _empty_store(W: int, a, cache_dtype: str):
    """Table-level storage for one quantizable leaf."""
    if cache_dtype == "float32":
        return jnp.zeros((W,) + a.shape, a.dtype)
    if cache_dtype == "bfloat16":
        return CastLeaf(jnp.zeros((W,) + a.shape, jnp.bfloat16), a.dtype)
    B, F = _row_shape(a)
    if cache_dtype == "int4":
        # zero scales make the empty table decode to exact zeros, so the
        # packed byte value is immaterial; 0x88 (code 0 in both nibbles)
        # keeps unpack(empty) == 0 too, matching the int8 empty table.
        return Quant4Leaf(jnp.full((W, B, _pad_even(F) // 2), 0x88,
                                   jnp.uint8),
                          jnp.zeros((W, B), jnp.float32), a.shape, a.dtype)
    return QuantLeaf(jnp.zeros((W, B, F), jnp.int8),
                     jnp.zeros((W, B), jnp.float32), a.shape, a.dtype)


def _encode_leaf(store, x, rng):
    """One entry leaf -> the storage form matching the table's leaf (the
    table's shape/dtype metadata wins, like the historical ``astype`` on
    insert coerced the entry to the buffer dtype)."""
    if isinstance(store, Quant4Leaf):
        B, F = _row_shape(x)
        q, scale = _quantize_rows(rng, x.reshape(B, F).astype(jnp.float32),
                                  levels=7)
        if F & 1:                       # pad one zero code before packing
            q = jnp.pad(q, ((0, 0), (0, 1)))
        return Quant4Leaf(pack_nibbles(q), scale, store.shape, store.dtype)
    if isinstance(store, QuantLeaf):
        B, F = _row_shape(x)
        q, scale = _quantize_rows(rng, x.reshape(B, F).astype(jnp.float32))
        return QuantLeaf(q, scale, store.shape, store.dtype)
    if isinstance(store, CastLeaf):
        return CastLeaf(x.astype(jnp.bfloat16), store.dtype)
    return x


def _decode_leaf(leaf):
    if isinstance(leaf, (QuantLeaf, Quant4Leaf)):
        return leaf.dequant()
    if isinstance(leaf, CastLeaf):
        return leaf.decode()
    return leaf


def decode_entry(entry):
    """Storage-form entry -> full-precision entry (identity for fp32)."""
    return jax.tree_util.tree_map(_decode_leaf, entry, is_leaf=_is_store)


def workset_nbytes(ws: Dict[str, Any], keys=None) -> int:
    """Actual device bytes held by the table's ring buffer (codes, scales
    and raw leaves; excludes the O(W) clock vectors).  ``keys`` restricts
    the count to those entry keys — e.g. ``QUANT_KEYS`` for the cut
    statistics the storage codec compresses (the party's raw-feature cache
    is stored verbatim regardless)."""
    buf = ws["buf"] if keys is None else \
        {k: v for k, v in ws["buf"].items() if k in keys}
    return sum(int(leaf.nbytes)
               for leaf in jax.tree_util.tree_leaves(buf))


def sample_hbm_bytes(entry_example: Dict[str, Any],
                     cache_dtype: str = "float32",
                     fused: bool = True, party: str = "a") -> int:
    """Roofline counter: HBM bytes moved by ONE local-update sample over
    the cut statistics — gather from the ring, dequantize, row-cosine
    against the ad-hoc statistics, cotangent scale.  Excludes the
    forward/backward over the party model (identical across paths).

    ``party="a"`` (a feature party) — unfused: the sampled ``z``/``dz``
    rows are gathered into a full-precision entry copy (read stored +
    write fp32), then the weighting kernel re-reads ad-hoc + both copies
    and writes w + cot.  Fused: one pass — read stored z/dz + ad-hoc,
    write w + cot.

    ``party="b"`` (the label party, ``engine.local_grad_b_cached``) — the
    loss CONSUMES the dequantized Z list, so the decoded fp32 z copy is
    always materialized (read stored + write fp32) regardless of fusion;
    only the dz-side cosine weighting fuses against the stored ring (read
    stored dz + ad-hoc, write w + the kernel's ride-along cot)."""
    if cache_dtype not in CACHE_DTYPES:
        raise ValueError(f"cache_dtype must be one of {CACHE_DTYPES}, "
                         f"got {cache_dtype!r}")
    if party not in ("a", "b"):
        raise ValueError(f"party must be 'a' or 'b', got {party!r}")
    z_leaves = jax.tree_util.tree_leaves(entry_example.get("z", {}))
    dz_leaves = jax.tree_util.tree_leaves(entry_example.get("dz", {}))

    def _at_rest(B: int, F: int) -> int:
        if cache_dtype == "int4":            # packed nibbles + row scale
            return B * (_pad_even(F) // 2) + B * 4
        itemsize = {"float32": 4, "bfloat16": 2, "int8": 1}[cache_dtype]
        return B * F * itemsize + (B * 4 if cache_dtype == "int8" else 0)

    total = 0
    for a in z_leaves + dz_leaves:           # the ring reads, at rest
        B, F = _row_shape(a)
        total += _at_rest(B, F)
    if party == "a":
        for a in z_leaves:                   # per ⟨z, dz⟩ pair:
            B, F = _row_shape(a)
            f32 = B * F * 4
            if fused:
                # one pass: + read ad-hoc, write cot + w
                total += f32 + f32 + B * 4
            else:
                # gather writes a fp32 entry copy (z + dz), the weighting
                # kernel re-reads it plus the ad-hoc stats, writes cot + w
                total += 2 * f32 + (3 * f32) + f32 + B * 4
        return total
    for a in z_leaves:                       # decoded Z the loss consumes
        B, F = _row_shape(a)
        total += B * F * 4                   # fp32 copy write, both paths
    for a in dz_leaves:                      # dz-side cosine weighting
        B, F = _row_shape(a)
        f32 = B * F * 4
        if fused:
            # one pass over the stored ring: + read ad-hoc dz,
            # write w + the ride-along cot
            total += f32 + f32 + B * 4
        else:
            # gather writes a decoded fp32 dz copy, the weighting kernel
            # re-reads it plus the ad-hoc dz, writes w
            total += f32 + 2 * f32 + B * 4
    return total


# --------------------------------------------------------------------------
# Table ops
# --------------------------------------------------------------------------
def workset_init(W: int, entry_example: Dict[str, Any], *,
                 cache_dtype: str = "float32") -> Dict[str, Any]:
    """Create an empty table.  ``entry_example`` is a pytree of arrays with
    the per-batch shapes (e.g. {"z": (B,S,d), "dz": (B,S,d), "batch": ...});
    the table stacks a leading W axis.  ``cache_dtype`` selects the at-rest
    storage of the ``z``/``dz`` subtrees (see module docstring); everything
    else is cached verbatim."""
    if cache_dtype not in CACHE_DTYPES:
        raise ValueError(f"cache_dtype must be one of {CACHE_DTYPES}, "
                         f"got {cache_dtype!r}")
    buf = {}
    for k, sub in entry_example.items():
        if k in QUANT_KEYS and cache_dtype != "float32":
            buf[k] = jax.tree_util.tree_map(
                lambda a: _empty_store(W, a, cache_dtype), sub)
        else:
            buf[k] = jax.tree_util.tree_map(
                lambda a: jnp.zeros((W,) + a.shape, a.dtype), sub)
    return {
        "buf": buf,
        "insert_time": jnp.full((W,), INT_MIN, jnp.int32),
        "use_count": jnp.zeros((W,), jnp.int32),
        "batch_idx": jnp.full((W,), -1, jnp.int32),
        "cursor": jnp.int32(0),
        "time": jnp.int32(0),      # communication rounds so far
    }


def workset_insert(ws: Dict[str, Any], entry: Dict[str, Any],
                   batch_idx, *, rng=None) -> Dict[str, Any]:
    """Insert a fresh entry at ring slot ``time mod W``; bump the clock.

    The entry is encoded into the table's storage form first (int8
    stochastic rounding / bf16 cast / verbatim — auto-detected from the
    ring).  ``rng`` seeds the rounding noise for quantized tables; when
    omitted a key is derived from the table clock (deterministic)."""
    W = ws["insert_time"].shape[0]
    t = ws["time"]
    slot = jnp.mod(t, W)

    stores, treedef = jax.tree_util.tree_flatten(ws["buf"],
                                                 is_leaf=_is_store)
    values = treedef.flatten_up_to(entry)
    if rng is None and any(isinstance(s, (QuantLeaf, Quant4Leaf))
                           for s in stores):
        rng = jax.random.fold_in(jax.random.PRNGKey(0xCE1), t)
    encoded = treedef.unflatten([
        _encode_leaf(s, v, None if rng is None
                     else jax.random.fold_in(rng, i))
        for i, (s, v) in enumerate(zip(stores, values))])

    buf = jax.tree_util.tree_map(
        lambda b, e: jax.lax.dynamic_update_index_in_dim(b, e.astype(b.dtype),
                                                         slot, 0),
        ws["buf"], encoded)
    return {
        "buf": buf,
        "insert_time": ws["insert_time"].at[slot].set(t),
        "use_count": ws["use_count"].at[slot].set(0),
        "batch_idx": ws["batch_idx"].at[slot].set(jnp.int32(batch_idx)),
        "cursor": ws["cursor"],
        "time": t + 1,
    }


def _valid_mask(ws: Dict[str, Any], R: int,
                pipeline_staleness=0) -> jnp.ndarray:
    """(W,) bool — alive entries: inserted, not expired, not exhausted.

    ``pipeline_staleness`` tightens the expiry window: under a depth-D
    pipelined schedule every cached entry is D exchanges older by the time
    its sampled round completes, so the oldest D ring slots are retired
    early to keep the paper's max-staleness bound W.  It may be a static
    Python int (depths 0/1) or a traced jnp int scalar — the depth-D
    queue's PER-SLOT offset, which shrinks during warmup/drain when fewer
    exchanges are in flight.  At s >= W no draw is ever valid, which is
    why the scheduler rejects depths >= W up front."""
    t = ws["time"]
    W = ws["insert_time"].shape[0]
    # not expired (the ring overwrite also enforces this at staleness 0)
    alive = ws["insert_time"] >= t - W + pipeline_staleness
    alive &= ws["insert_time"] > INT_MIN    # ever inserted
    alive &= ws["use_count"] < R            # not exhausted
    return alive


def workset_draw(ws: Dict[str, Any], R: int, strategy: str, *,
                 rng=None, pipeline_staleness=0
                 ) -> Tuple[Dict[str, Any], jnp.ndarray, jnp.ndarray,
                            jnp.ndarray]:
    """Pick one slot for a local update WITHOUT materializing the entry.

    strategy: "round_robin" — advance the cursor to the next alive slot
    (uniform over the table); "consecutive" — always the freshest slot
    (FedBCD); "uniform" — an independent uniform draw over the alive slots
    (requires ``rng``; the paper's §3.2 fair-sampling property holds per
    draw instead of per W-cycle).  Returns (new_ws, slot, batch_idx,
    valid) where ``valid`` is a bool scalar (False -> caller must no-op
    the update).  The fused sample path hands ``slot`` straight to the
    gather→dequant→weight megakernel; :func:`workset_sample` keeps the
    materializing form."""
    W = ws["insert_time"].shape[0]
    alive = _valid_mask(ws, R, pipeline_staleness)
    if strategy == "consecutive":
        slot = jnp.mod(ws["time"] - 1, W)
        valid = alive[slot]
        new_cursor = ws["cursor"]
    elif strategy == "uniform":
        if rng is None:
            raise ValueError("uniform sampling needs an rng key")
        # uniform over alive slots; with none alive the draw is degenerate
        # and ``valid`` masks it into a no-op
        logits = jnp.where(alive, 0.0, -jnp.inf)
        logits = jnp.where(jnp.any(alive), logits, jnp.zeros((W,)))
        slot = jax.random.categorical(rng, logits)
        valid = alive[slot]
        new_cursor = ws["cursor"]
    elif strategy == "round_robin":
        # STRICT cycle (paper §3.2 / Fig 4): the cursor advances by exactly
        # one per draw, so a slot cannot be re-sampled within W-1 draws.
        # Dead/empty slots yield an invalid (no-op) draw — the "bubbles" the
        # paper accepts in the first W-1 rounds.  Skipping dead slots
        # instead would collapse the schedule back to consecutive reuse of
        # the freshest batch (measured: identical curves for all W).
        slot = jnp.mod(ws["cursor"], W)
        valid = alive[slot]
        new_cursor = jnp.mod(slot + 1, W)
    else:
        raise ValueError(strategy)

    new_ws = dict(ws)
    new_ws["use_count"] = ws["use_count"].at[slot].add(
        jnp.where(valid, 1, 0))
    if strategy == "round_robin":
        new_ws["cursor"] = new_cursor          # advance even on a bubble
    else:
        new_ws["cursor"] = jnp.where(valid, new_cursor, ws["cursor"])
    return new_ws, slot, ws["batch_idx"][slot], valid


def workset_entry(ws: Dict[str, Any], slot) -> Dict[str, Any]:
    """Materialize (gather + decode) the entry at ``slot``."""
    raw = jax.tree_util.tree_map(lambda b: b[slot], ws["buf"])
    return decode_entry(raw)


def workset_sample(ws: Dict[str, Any], R: int, strategy: str, *,
                   rng=None, pipeline_staleness=0
                   ) -> Tuple[Dict[str, Any], Dict[str, Any], jnp.ndarray,
                              jnp.ndarray]:
    """Draw one entry for a local update: :func:`workset_draw` plus the
    materialized (decoded) entry.  Returns (new_ws, entry, batch_idx,
    valid)."""
    new_ws, slot, batch_idx, valid = workset_draw(
        ws, R, strategy, rng=rng, pipeline_staleness=pipeline_staleness)
    return new_ws, workset_entry(ws, slot), batch_idx, valid


def workset_stats(ws: Dict[str, Any], R: int,
                  pipeline_staleness=0) -> Dict[str, jnp.ndarray]:
    """Table health counters.  ``pipeline_staleness`` must match the
    schedule the table serves: a depth-D pipeline retires the oldest D
    slots early (see :func:`_valid_mask`), so reporting at staleness 0
    would overcount ``n_alive`` under pipelining."""
    alive = _valid_mask(ws, R, pipeline_staleness)
    return {
        "n_alive": jnp.sum(alive),
        "total_uses": jnp.sum(jnp.where(alive, ws["use_count"], 0)),
        "time": ws["time"],
    }
