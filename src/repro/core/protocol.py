"""VFL training protocols: Vanilla, FedBCD, CELU-VFL (the paper's Section 3).

A *task* is the minimal two-party interface (information-flow discipline is
kept at function granularity — no function sees both parties' raw data):

    forward_a(params_a, batch_a) -> Z_A
    loss_b(params_b, z_a, batch_b) -> (per_instance_loss (B,), aux_scalar)

One **communication round** exchanges ⟨Z_A, ∇Z_A⟩ once (also performing a
plain SGD step — the "fresh" update) and then runs up to ``R`` *local
updates* per party from its workset table, with round-robin sampling and
staleness-aware instance weighting (Algorithms 1-2):

  * Vanilla  = rounds with R=0 (exchange every step);
  * FedBCD   = consecutive sampling (W=1 semantics) + no weighting;
  * CELU-VFL = round-robin sampling over W slots + cosine weighting.

The whole round is ONE jitted function (exchange + scan over local steps) so
XLA's latency-hiding scheduler can overlap the cross-party transfer with the
local-update chain — the SPMD analogue of the paper's background
communication worker (DESIGN §2).

Communication accounting: each round moves ``bytes(Z_A) + bytes(∇Z_A)``
across the slow link; the simulated-WAN wall-clock model used by the
benchmarks is ``t_round = bytes / bandwidth + 2 * latency`` (Section 2.1's
213 ms example reproduces with bandwidth=300 Mbps).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import CELUConfig
from ..optim import Optimizer, apply_updates
from .weighting import instance_weights, xi_to_cos
from .workset import workset_init, workset_insert, workset_sample


class VFLTask(NamedTuple):
    """Two-party split model interface (see module docstring)."""
    forward_a: Callable[[Any, Dict[str, Any]], jnp.ndarray]
    loss_b: Callable[[Any, jnp.ndarray, Dict[str, Any]],
                     Tuple[jnp.ndarray, jnp.ndarray]]


def _bcast(w, like):
    """(B,) weights -> broadcastable to ``like``'s shape."""
    return w.reshape(w.shape + (1,) * (like.ndim - 1)).astype(jnp.float32)


# --------------------------------------------------------------------------
# State
# --------------------------------------------------------------------------
def init_state(task: VFLTask, params: Dict[str, Any], opt: Optimizer,
               celu: CELUConfig, batch_a: Dict[str, Any],
               batch_b: Dict[str, Any]):
    """Build the full training state.  ``batch_a/b`` are example (abstract ok)
    batches used to size the workset ring buffers."""
    z_a = jax.eval_shape(task.forward_a, params["a"], batch_a)
    z_like = jnp.zeros(z_a.shape, z_a.dtype) if not isinstance(
        z_a, jnp.ndarray) else z_a
    entry_a = {"z_a": z_like, "dz_a": z_like, "batch": batch_a}
    entry_b = {"z_a": z_like, "dz_a": z_like, "batch": batch_b}
    return {
        "params": params,
        "opt": {"a": opt.init(params["a"]), "b": opt.init(params["b"])},
        "ws": {"a": workset_init(celu.W, entry_a),
               "b": workset_init(celu.W, entry_b)},
        "steps": {"a": jnp.int32(0), "b": jnp.int32(0)},
        "comm_rounds": jnp.int32(0),
    }


def exchange_bytes(z_shape, dtype_bytes: int = 4,
                   wire_dtype: str = "float32") -> int:
    """Bytes moved per communication round (Z_A + ∇Z_A).  The paper sends
    fp32; the beyond-paper bf16 wire halves it."""
    import numpy as np
    n = int(np.prod(z_shape))
    b = jnp.dtype(wire_dtype).itemsize if wire_dtype else dtype_bytes
    return 2 * n * b


# --------------------------------------------------------------------------
# The fresh exchange (one communication round's synchronous part)
# --------------------------------------------------------------------------
def make_exchange_step(task: VFLTask, opt: Optimizer, celu: CELUConfig):
    """Returns fn(state, batch_a, batch_b, batch_idx) -> (state, metrics).

    Computes the exact two-phase propagation (Z_A forward, ∇Z_A backward),
    applies a plain SGD step to BOTH parties, and inserts the fresh
    statistics + own features into each party's workset."""

    wire = jnp.dtype(celu.wire_dtype)

    def _quantize(x):
        """Simulate the wire: round-trip through the wire dtype."""
        if x.dtype == wire:
            return x
        return x.astype(wire).astype(x.dtype)

    def _release(x, rng):
        """The message actually released: DP-noised (optional) + wire
        precision.  The noised value is also what gets cached."""
        if celu.dp_sigma > 0.0:
            from .privacy import DPConfig, privatize
            x = privatize(rng, x, DPConfig(clip=celu.dp_clip,
                                           sigma=celu.dp_sigma))
        return _quantize(x)

    def step(state, batch_a, batch_b, batch_idx):
        pa, pb = state["params"]["a"], state["params"]["b"]
        rng = jax.random.fold_in(jax.random.PRNGKey(17),
                                 state["comm_rounds"])
        rng_up, rng_down = jax.random.split(rng)

        # Party A forward -> Z_A (the uplink message, in wire precision)
        z_a, vjp_a = jax.vjp(lambda p: task.forward_a(p, batch_a), pa)
        z_a = _release(z_a, rng_up)

        # Party B: loss + grads wrt (params_b, Z_A); ∇Z_A is the downlink
        def mean_loss(p, z):
            li, aux = task.loss_b(p, z, batch_b)
            return jnp.mean(li) + aux, li
        (loss, li), grads = jax.value_and_grad(
            mean_loss, argnums=(0, 1), has_aux=True)(pb, z_a)
        g_b, dz_a = grads
        dz_a = _release(dz_a, rng_down)

        # Party A backward with the (wire-precision) cotangent
        (g_a,) = vjp_a(dz_a.astype(z_a.dtype))

        upd_a, opt_a = opt.update(g_a, state["opt"]["a"], pa)
        upd_b, opt_b = opt.update(g_b, state["opt"]["b"], pb)

        ws_a = workset_insert(state["ws"]["a"],
                              {"z_a": z_a, "dz_a": dz_a, "batch": batch_a},
                              batch_idx)
        ws_b = workset_insert(state["ws"]["b"],
                              {"z_a": z_a, "dz_a": dz_a, "batch": batch_b},
                              batch_idx)
        new_state = {
            "params": {"a": apply_updates(pa, upd_a),
                       "b": apply_updates(pb, upd_b)},
            "opt": {"a": opt_a, "b": opt_b},
            "ws": {"a": ws_a, "b": ws_b},
            "steps": {"a": state["steps"]["a"] + 1,
                      "b": state["steps"]["b"] + 1},
            "comm_rounds": state["comm_rounds"] + 1,
        }
        return new_state, {"loss": loss}

    return step


# --------------------------------------------------------------------------
# Local updates (Algorithm 2)
# --------------------------------------------------------------------------
def make_local_step_a(task: VFLTask, opt: Optimizer, celu: CELUConfig):
    """Party A local update: ad-hoc forward on the cached batch, stale
    cotangent ``∇Z_A^(i)`` weighted by cos(Z_A^(i,j), Z_A^(i))."""
    cos_xi = xi_to_cos(celu.xi_degrees)

    def step(params_a, opt_a, ws_a, n_steps):
        ws_a, entry, _, valid = workset_sample(ws_a, celu.R, celu.sampling)
        z_new, vjp_a = jax.vjp(
            lambda p: task.forward_a(p, entry["batch"]), params_a)
        if celu.weighting:
            w = instance_weights(z_new, entry["z_a"], cos_xi)
        else:
            w = jnp.ones((z_new.shape[0],), jnp.float32)
        w = w * valid.astype(jnp.float32)
        cot = (_bcast(w, z_new) * entry["dz_a"].astype(jnp.float32))
        (g_a,) = vjp_a(cot.astype(z_new.dtype))
        upd, opt_a = opt.update(g_a, opt_a, params_a)
        # no-op if the table had nothing alive
        upd = jax.tree_util.tree_map(
            lambda u: u * valid.astype(jnp.float32), upd)
        params_a = apply_updates(params_a, upd)
        metrics = {"w_mean": jnp.mean(w), "w_zero_frac": jnp.mean(w == 0.0),
                   "valid": valid.astype(jnp.float32)}
        return params_a, opt_a, ws_a, n_steps + valid.astype(jnp.int32), \
            metrics

    return step


def make_local_step_b(task: VFLTask, opt: Optimizer, celu: CELUConfig):
    """Party B local update: stale ``Z_A^(i)`` + ad-hoc own features; the
    ad-hoc ∇Z_A^(i,j) is computed only to measure staleness (footnote 2),
    then the weighted per-instance losses drive the backward pass."""
    cos_xi = xi_to_cos(celu.xi_degrees)

    def step(params_b, opt_b, ws_b, n_steps):
        ws_b, entry, _, valid = workset_sample(ws_b, celu.R, celu.sampling)
        z_stale = entry["z_a"]
        batch_b = entry["batch"]

        if celu.weighting:
            # ad-hoc derivatives wrt the (stale) activations
            dz_new = jax.grad(
                lambda z: jnp.mean(task.loss_b(params_b, z, batch_b)[0])
            )(z_stale.astype(jnp.float32))
            w = instance_weights(dz_new, entry["dz_a"], cos_xi)
        else:
            w = jnp.ones((z_stale.shape[0],), jnp.float32)
        w = w * valid.astype(jnp.float32)

        def weighted_loss(p):
            li, aux = task.loss_b(p, z_stale, batch_b)
            return jnp.mean(w * li) + aux
        g_b = jax.grad(weighted_loss)(params_b)
        upd, opt_b = opt.update(g_b, opt_b, params_b)
        upd = jax.tree_util.tree_map(
            lambda u: u * valid.astype(jnp.float32), upd)
        params_b = apply_updates(params_b, upd)
        metrics = {"w_mean": jnp.mean(w), "w_zero_frac": jnp.mean(w == 0.0),
                   "valid": valid.astype(jnp.float32)}
        return params_b, opt_b, ws_b, n_steps + valid.astype(jnp.int32), \
            metrics

    return step


# --------------------------------------------------------------------------
# One full communication round (exchange + R local updates per party)
# --------------------------------------------------------------------------
def make_round(task: VFLTask, opt: Optimizer, celu: CELUConfig,
               *, local_steps: int = -1, jit: bool = True):
    """fn(state, batch_a, batch_b, batch_idx) -> (state, metrics).

    ``local_steps`` defaults to R (steady state: one fresh insert funds R
    uses).  Vanilla training = ``local_steps=0``."""
    n_local = celu.R if local_steps < 0 else local_steps
    exchange = make_exchange_step(task, opt, celu)
    la = make_local_step_a(task, opt, celu)
    lb = make_local_step_b(task, opt, celu)

    def round_fn(state, batch_a, batch_b, batch_idx):
        state, m = exchange(state, batch_a, batch_b, batch_idx)
        if n_local == 0:
            zero = jnp.float32(0.0)
            m.update({"local_steps": jnp.int32(0), "w_mean": zero,
                      "w_zero_frac": zero})
            return state, m

        def body(carry, _):
            pa, oa, wsa, na, pb, ob, wsb, nb = carry
            pa, oa, wsa, na, ma = la(pa, oa, wsa, na)
            pb, ob, wsb, nb, mb = lb(pb, ob, wsb, nb)
            return (pa, oa, wsa, na, pb, ob, wsb, nb), \
                {"w_mean": (ma["w_mean"] + mb["w_mean"]) * 0.5,
                 "w_zero_frac": (ma["w_zero_frac"] + mb["w_zero_frac"]) * 0.5}

        init = (state["params"]["a"], state["opt"]["a"], state["ws"]["a"],
                jnp.int32(0),
                state["params"]["b"], state["opt"]["b"], state["ws"]["b"],
                jnp.int32(0))
        (pa, oa, wsa, na, pb, ob, wsb, nb), lm = jax.lax.scan(
            body, init, None, length=n_local)
        state = {
            "params": {"a": pa, "b": pb},
            "opt": {"a": oa, "b": ob},
            "ws": {"a": wsa, "b": wsb},
            "steps": {"a": state["steps"]["a"] + na,
                      "b": state["steps"]["b"] + nb},
            "comm_rounds": state["comm_rounds"],
        }
        m.update({"local_steps": na + nb,
                  "w_mean": jnp.mean(lm["w_mean"]),
                  "w_zero_frac": jnp.mean(lm["w_zero_frac"])})
        return state, m

    return jax.jit(round_fn, donate_argnums=(0,)) if jit else round_fn


# --------------------------------------------------------------------------
# Named protocol presets (the paper's three competitors)
# --------------------------------------------------------------------------
def protocol_config(name: str, base: CELUConfig) -> Tuple[CELUConfig, int]:
    """-> (celu_cfg, local_steps) for name in {vanilla, fedbcd, celu}."""
    import dataclasses
    if name == "vanilla":
        return dataclasses.replace(base, weighting=False), 0
    if name == "fedbcd":
        return dataclasses.replace(base, W=1, weighting=False,
                                   sampling="consecutive"), base.R
    if name == "celu":
        return base, base.R
    raise ValueError(name)
