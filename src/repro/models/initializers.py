"""Parameter initialization helpers (pure pytrees, no flax).

Every layer exposes ``init(rng, ...) -> params`` (nested dict of jnp arrays)
and a pure ``apply(params, ...)`` function.  Scanned towers stack per-layer
params along a leading L axis via ``stacked_init``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Parameter dtype used across the library.  bf16 keeps the dry-run memory
# analysis honest for the TPU target; smoke tests run fine in bf16 too
# (loss/softmax internals are fp32).
PARAM_DTYPE = jnp.bfloat16


def dense_init(rng, d_in: int, d_out: int, dtype=None):
    scale = 1.0 / jnp.sqrt(d_in)
    w = jax.random.uniform(rng, (d_in, d_out), jnp.float32, -scale, scale)
    return w.astype(dtype or PARAM_DTYPE)


def embed_init(rng, vocab: int, d: int, dtype=None):
    w = jax.random.normal(rng, (vocab, d), jnp.float32) * 0.02
    return w.astype(dtype or PARAM_DTYPE)


def zeros_init(shape, dtype=None):
    return jnp.zeros(shape, dtype or PARAM_DTYPE)


def ones_init(shape, dtype=None):
    return jnp.ones(shape, dtype or PARAM_DTYPE)


def stacked_init(init_fn, rng, n: int):
    """Stack ``n`` independent layer inits along a leading axis."""
    rngs = jax.random.split(rng, n)
    return jax.vmap(init_fn)(rngs)


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def param_bytes(params) -> int:
    return sum(int(x.size) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(params))
