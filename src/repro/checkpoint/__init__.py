from .checkpoint import (peek_pending_len, restore,  # noqa: F401
                         restore_round_state, save, save_round_state)
