"""Multi-party CELU-VFL (K feature parties) and DP-on-the-wire tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CELUConfig
from repro.core import multiparty as MP
from repro.core.privacy import DPConfig, clip_rows, epsilon_per_release, \
    privatize
from repro.core import protocol as P
from repro.data.synthetic import TabularSpec, aligned_batches, make_tabular
from repro.models.tabular import DLRMConfig, auc, make_dlrm
from repro.optim import make_optimizer


# --------------------------------------------------------------------------
# 3-party WDL: parties A1, A2 (features), B (features + labels)
# --------------------------------------------------------------------------
def _three_party_setup(seed=0):
    """Split a 12-field dataset as A1: 4, A2: 4, B: 4 (+labels)."""
    spec = TabularSpec("t", fields_a=8, fields_b=4, vocab=64,
                       n_train=8192, n_test=2048)
    data = make_tabular(spec, seed=seed)
    cfg = DLRMConfig("wdl", 4, 4, vocab=64, embed_dim=4, z_dim=8,
                     hidden=(16, 8))
    init_fn, single_task, predict = make_dlrm(cfg)

    # per-party tower inits (A1, A2 identical shape; B = wdl's b-side)
    p_full_1 = init_fn(jax.random.PRNGKey(seed), cfg)
    p_full_2 = init_fn(jax.random.PRNGKey(seed + 1), cfg)
    pa1, pa2, pb = p_full_1["a"], p_full_2["a"], p_full_1["b"]
    # widen B's top to accept [Z1 | Z2 | Z_B] (3 * z_dim)
    k = jax.random.PRNGKey(seed + 2)
    from repro.models.tabular import _mlp_init
    pb = dict(pb)
    pb["top"] = _mlp_init(k, [3 * cfg.z_dim, 16, 1])

    from repro.models.tabular import _mlp, _tower

    def forward_a(pa, batch_a):
        return _tower(pa["tower"], batch_a["x_a"])

    def loss_b(pb_, z_list, batch_b):
        z_b = _tower(pb_["tower"], batch_b["x_b"])
        h = jnp.concatenate([z.astype(jnp.float32) for z in z_list]
                            + [z_b], axis=-1)
        logit = _mlp(pb_["top"], h)[:, 0]
        F = batch_b["x_b"].shape[1]
        wide = pb_["wide"][jnp.arange(F)[None, :], batch_b["x_b"]].sum(1)
        logit = logit + wide + pb_["bias"]
        y = batch_b["y"]
        li = jnp.maximum(logit, 0) - logit * y + jnp.log1p(
            jnp.exp(-jnp.abs(logit)))
        return li, jnp.float32(0.0)

    task = MP.MultiVFLTask(forward_a, loss_b)
    params = {"a": [pa1, pa2], "b": pb}
    return data, cfg, task, params, loss_b


def _split_batches(ba, bb):
    a1 = {"x_a": jnp.asarray(ba["x_a"][:, :4])}
    a2 = {"x_a": jnp.asarray(ba["x_a"][:, 4:])}
    b = {"x_b": jnp.asarray(bb["x_b"]), "y": jnp.asarray(bb["y"])}
    return [a1, a2], b


def test_three_party_celu_trains():
    data, cfg, task, params, loss_b = _three_party_setup()
    celu = CELUConfig(R=2, W=2, xi_degrees=60.0)
    opt = make_optimizer("adagrad", 0.02)
    it = aligned_batches(data["train"], 128, seed=0)
    _, ba, bb = next(it)
    bas, b = _split_batches(ba, bb)
    state = MP.init_state(task, params, opt, celu, bas, b)
    rnd = MP.make_round(task, opt, celu)
    it = aligned_batches(data["train"], 128, seed=0)
    losses = []
    for i in range(30):
        bi, ba, bb = next(it)
        bas, b = _split_batches(ba, bb)
        state, m = rnd(state, bas, b, bi)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses
    assert int(state["comm_rounds"]) == 30


def test_three_party_matches_interface_counts():
    data, cfg, task, params, loss_b = _three_party_setup()
    celu = CELUConfig(R=2, W=2)
    opt = make_optimizer("sgd", 0.05)
    it = aligned_batches(data["train"], 64, seed=0)
    _, ba, bb = next(it)
    bas, b = _split_batches(ba, bb)
    state = MP.init_state(task, params, opt, celu, bas, b)
    assert len(state["ws"]["a"]) == 2
    assert len(state["params"]["a"]) == 2


# --------------------------------------------------------------------------
# DP on the wire
# --------------------------------------------------------------------------
def test_clip_rows_bounds_norm():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(16, 32)) * 10, jnp.float32)
    y = clip_rows(x, 1.0)
    norms = np.linalg.norm(np.asarray(y).reshape(16, -1), axis=1)
    assert (norms <= 1.0 + 1e-5).all()


def test_privatize_noise_scale():
    rng = jax.random.PRNGKey(0)
    x = jnp.ones((512, 64), jnp.float32) * 0.01
    cfg = DPConfig(clip=1.0, sigma=0.5)
    y = privatize(rng, x, cfg)
    resid = np.asarray(y - clip_rows(x, 1.0))
    assert abs(resid.std() - 0.5) < 0.05


def test_epsilon_monotone_in_sigma():
    e1 = epsilon_per_release(DPConfig(sigma=0.5))
    e2 = epsilon_per_release(DPConfig(sigma=1.0))
    assert e2 < e1


def test_protocol_with_dp_still_converges():
    spec = TabularSpec("t", fields_a=4, fields_b=3, vocab=64,
                       n_train=4096, n_test=512)
    data = make_tabular(spec, seed=0)
    cfg = DLRMConfig("wdl", 4, 3, vocab=64, embed_dim=4, z_dim=8,
                     hidden=(16, 8))
    init_fn, task, predict = make_dlrm(cfg)
    celu = CELUConfig(R=2, W=2, dp_sigma=0.1, dp_clip=5.0)
    params = init_fn(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adagrad", 0.02)
    it = aligned_batches(data["train"], 64, seed=0)
    _, ba, bb = next(it)
    asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
    state = P.init_state(task, params, opt, celu, asj(ba), asj(bb))
    rnd = P.make_round(task, opt, celu)
    it = aligned_batches(data["train"], 64, seed=0)
    losses = []
    for i in range(25):
        bi, ba, bb = next(it)
        state, m = rnd(state, asj(ba), asj(bb), bi)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
