"""Serving-path gates (docs/SERVING.md).

The continuous-batching engine is held to ORACLE standards, not
vibes:

  * fp32 wire + fp32 ring => BIT-EXACT tokens vs the sequential
    monolithic loop, including mid-flight admit/evict churn (more
    requests than lanes, mixed generation lengths).
  * int8 wire + int8 ring => greedy token match at the pinned fixture
    seed (param seed 2 — random-init argmax sits near ties at other
    seeds, so the fixture pins one where quantization noise provably
    does not flip any of the 36 generated tokens).
  * The fused gather→dequant kernels match their pure-jnp oracles and
    the ring roundtrip stays within quantization tolerance.
  * Per-request wire bytes reconcile EXACTLY against the codec's own
    ``wire_bytes`` arithmetic — and two identical runs produce identical
    tokens, timelines aside (determinism).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.wire_audit import payload_nbytes
from repro.configs import get_config
from repro.core import workset as WS
from repro.core.compression import make_codec_pair
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import vfl
from repro.serve import (Request, ServeConfig, ServeEngine, make_naive_fns,
                         naive_generate)
from repro.serve.loadgen import LoadSpec, synth_requests

CFG = get_config("smollm-360m").reduced()
PROMPT = 8


def _params(seed=0):
    return vfl.init_all(jax.random.PRNGKey(seed), CFG)


def _requests(n, gens, seed=0):
    rng = np.random.default_rng(seed)
    return [
        Request(i,
                rng.integers(0, CFG.vocab_size, PROMPT, dtype=np.int32),
                rng.integers(0, CFG.aux_vocab_size, PROMPT, dtype=np.int32),
                int(gens[i]))
        for i in range(n)
    ]


def _references(params, requests, max_new):
    fns = make_naive_fns(CFG, PROMPT + max_new)
    refs = {}
    for r in requests:
        toks = naive_generate(
            params, CFG,
            {"tokens": jnp.asarray(r.prompt[None]),
             "tokens_a": jnp.asarray(r.prompt_a[None])},
            r.max_new_tokens, total_len=PROMPT + max_new, fns=fns)
        refs[r.req_id] = np.asarray(toks)[0]
    return refs


# ---------------------------------------------------------------------------
# party-split refactor: composition == monolith
# ---------------------------------------------------------------------------
def test_prefill_halves_compose_bitexact():
    params = _params()
    batch = {"tokens": jnp.arange(PROMPT, dtype=jnp.int32)[None] % CFG.vocab_size,
             "tokens_a": jnp.arange(PROMPT, dtype=jnp.int32)[None]
             % CFG.aux_vocab_size}
    total = PROMPT + 4
    logits, caches = vfl.prefill(params, CFG, batch, total)
    z, cache_a = vfl.prefill_a(params["a"], CFG, batch, total)
    logits2, caches_b = vfl.prefill_b(params["b"], CFG, z, batch, total)
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(logits2))
    for la, lb in zip(jax.tree_util.tree_leaves(caches["a"]),
                      jax.tree_util.tree_leaves(cache_a)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_decode_halves_compose_bitexact():
    params = _params()
    batch = {"tokens": jnp.zeros((1, PROMPT), jnp.int32),
             "tokens_a": jnp.zeros((1, PROMPT), jnp.int32)}
    total = PROMPT + 4
    _, caches = vfl.prefill(params, CFG, batch, total)
    sb = {"token": jnp.array([[3]], jnp.int32),
          "token_a": jnp.array([[5]], jnp.int32)}
    logits, _ = vfl.decode_step(params, CFG, caches, sb, jnp.int32(PROMPT))
    z_t, _ = vfl.decode_step_a(params["a"], CFG, caches["a"],
                               sb["token_a"], jnp.int32(PROMPT))
    logits2, _ = vfl.decode_step_b(
        params["b"], CFG, {"b": caches["b"], "top": caches["top"]},
        sb["token"], z_t, jnp.int32(PROMPT))
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(logits2))


# ---------------------------------------------------------------------------
# fp32 engine == naive loop, bit-exact through lane churn
# ---------------------------------------------------------------------------
def test_fp32_engine_bitexact_vs_naive_with_churn():
    params = _params()
    # 6 requests through 4 lanes with mixed lengths: forced mid-flight
    # admit/evict, the regime the continuous-batching claim is about
    reqs = _requests(6, gens=[6, 4, 5, 6, 4, 6])
    refs = _references(params, reqs, max_new=6)
    scfg = ServeConfig(capacity=4, prompt_len=PROMPT, max_new_tokens=6,
                       compression="", cache_dtype="float32", ring_slots=3)
    comps, stats = ServeEngine(params, CFG, scfg).run(reqs)
    assert len(comps) == 6 and stats["n_requests"] == 6
    for c in comps:
        np.testing.assert_array_equal(
            c.tokens, refs[c.req_id][:len(c.tokens)],
            err_msg=f"req {c.req_id} diverged from sequential oracle")
        assert len(c.tokens) == reqs[c.req_id].max_new_tokens


def test_int8_engine_greedy_matches_naive_at_fixture_seed():
    params = _params(seed=2)          # pinned fixture seed (see docstring)
    reqs = _requests(6, gens=[6] * 6, seed=2)
    refs = _references(params, reqs, max_new=6)
    scfg = ServeConfig(capacity=4, prompt_len=PROMPT, max_new_tokens=6,
                       compression="int8", cache_dtype="int8", ring_slots=3)
    comps, _ = ServeEngine(params, CFG, scfg).run(reqs)
    for c in comps:
        np.testing.assert_array_equal(c.tokens, refs[c.req_id])


def test_single_token_requests_complete_at_admit():
    params = _params()
    reqs = _requests(3, gens=[1, 1, 1])
    scfg = ServeConfig(capacity=2, prompt_len=PROMPT, max_new_tokens=4,
                       compression="", cache_dtype="float32")
    comps, stats = ServeEngine(params, CFG, scfg).run(reqs)
    assert [len(c.tokens) for c in comps] == [1, 1, 1]
    assert stats["decode_steps"] == 0


# ---------------------------------------------------------------------------
# determinism + stale reuse
# ---------------------------------------------------------------------------
def test_two_runs_identical():
    params = _params()
    spec = LoadSpec(n_requests=8, rate=0.0, prompt_len=PROMPT,
                    max_new_tokens=5, min_new_tokens=2, seed=3)
    scfg = ServeConfig(capacity=3, prompt_len=PROMPT, max_new_tokens=5,
                       compression="int8", cache_dtype="int8")
    runs = []
    for _ in range(2):
        comps, _ = ServeEngine(params, CFG, scfg).run(
            synth_requests(spec, CFG))
        runs.append(comps)
    for a, b in zip(*runs):
        assert a.req_id == b.req_id
        np.testing.assert_array_equal(a.tokens, b.tokens)
        assert (a.wire_up_bytes, a.wire_down_bytes) == \
            (b.wire_up_bytes, b.wire_down_bytes)


def test_refresh_every_2_halves_decode_uplink():
    params = _params()
    reqs = _requests(2, gens=[6, 6])
    mk = lambda R: ServeConfig(capacity=2, prompt_len=PROMPT,
                               max_new_tokens=6, compression="int8",
                               cache_dtype="int8", refresh_every=R)
    c1, _ = ServeEngine(params, CFG, mk(1)).run(
        [Request(r.req_id, r.prompt, r.prompt_a, r.max_new_tokens)
         for r in reqs])
    c2, _ = ServeEngine(params, CFG, mk(2)).run(reqs)
    up1 = sum(c.wire_up_bytes for c in c1)
    up2 = sum(c.wire_up_bytes for c in c2)
    assert up2 < up1                       # stale reuse skipped sends
    for c in c2:                           # ...and still decodes tokens
        assert len(c.tokens) == 6
        assert np.all((c.tokens >= 0) & (c.tokens < CFG.vocab_size))


def test_cross_attn_family_rejected_with_pointer():
    vcfg = get_config("llama-3.2-vision-90b").reduced()
    params = vfl.init_all(jax.random.PRNGKey(0), vcfg)
    with pytest.raises(ValueError, match="naive_generate"):
        ServeEngine(params, vcfg, ServeConfig(prompt_len=PROMPT))
    # the pointed-to path actually serves the family
    batch = {"tokens": jnp.zeros((1, PROMPT), jnp.int32),
             "patches": jnp.zeros((1, vcfg.n_patches, vcfg.d_frontend),
                                  jnp.float32)}
    toks = naive_generate(params, vcfg, batch, 3)
    assert toks.shape == (1, 3)


# ---------------------------------------------------------------------------
# wire-byte reconciliation: ledger == codec arithmetic
# ---------------------------------------------------------------------------
def test_wire_bytes_reconcile_per_request():
    params = _params()
    gens = [5, 3, 4, 5]
    reqs = _requests(4, gens=gens)
    scfg = ServeConfig(capacity=2, prompt_len=PROMPT, max_new_tokens=5,
                       compression="int8", cache_dtype="int8")
    eng = ServeEngine(params, CFG, scfg)
    comps, stats = eng.run(reqs)

    # the engine's per-message constants == the codec's own accounting
    up, down = make_codec_pair("int8/identity")
    d = CFG.d_model
    assert eng.prefill_up_bytes == payload_nbytes(up, (PROMPT, d))
    assert eng.step_up_bytes == payload_nbytes(up, (d,))
    assert eng.token_down_bytes == payload_nbytes(down, (1,))

    # per-request: one (S, d) prefill crossing + (G-1) decode rows up,
    # G token ids down (R=1: every decode step exchanges)
    for c in comps:
        G = gens[c.req_id]
        assert c.wire_up_bytes == eng.prefill_up_bytes \
            + (G - 1) * eng.step_up_bytes
        assert c.wire_down_bytes == G * eng.token_down_bytes
    assert stats["wire_up_bytes"] == sum(c.wire_up_bytes for c in comps)


def test_int8_wire_strictly_smaller_than_fp32():
    params = _params()
    scfg8 = ServeConfig(capacity=2, prompt_len=PROMPT, compression="int8")
    scfg32 = ServeConfig(capacity=2, prompt_len=PROMPT, compression="")
    e8 = ServeEngine(params, CFG, scfg8)
    e32 = ServeEngine(params, CFG, scfg32)
    assert e8.step_up_bytes < e32.step_up_bytes
    assert e8.prefill_up_bytes < e32.prefill_up_bytes
    assert e8.token_down_bytes == e32.token_down_bytes == 4


# ---------------------------------------------------------------------------
# activation ring: fused gather→dequant kernels + roundtrip tolerance
# ---------------------------------------------------------------------------
def _ring(cache_dtype, W=3, B=8, F=128, seed=0):
    ws = WS.workset_init(W, {"z": jnp.zeros((B, F), jnp.float32)},
                         cache_dtype=cache_dtype)
    rows = jax.random.normal(jax.random.PRNGKey(seed), (W, B, F))
    for t in range(W):
        ws = WS.workset_insert(ws, {"z": rows[t]}, batch_idx=ws["time"])
    return ws, rows


def test_fused_dequant_q8_matches_ref():
    ws, _ = _ring("int8")
    buf = ws["buf"]["z"]
    assert isinstance(buf, WS.QuantLeaf)
    for slot in range(3):
        got = kops.fused_gather_dequant_q8(jnp.int32(slot), buf.q, buf.scale)
        want = kref.fused_dequant_q8_ref(jnp.int32(slot), buf.q, buf.scale)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_dequant_q4_matches_ref():
    ws, _ = _ring("int4")
    buf = ws["buf"]["z"]
    assert isinstance(buf, WS.Quant4Leaf)
    for slot in range(3):
        got = kops.fused_gather_dequant_q4(jnp.int32(slot), buf.q,
                                           buf.scale, 128)
        want = kref.fused_dequant_q4_ref(jnp.int32(slot), buf.q,
                                         buf.scale, 128)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("cache_dtype,rtol", [
    ("float32", 0.0), ("bfloat16", 1 / 128), ("int8", 1 / 63),
    ("int4", 1 / 3.5),
])
def test_ring_roundtrip_tolerance(cache_dtype, rtol):
    from repro.serve.engine import _ring_read
    ws, rows = _ring(cache_dtype)
    got = np.asarray(_ring_read(ws["buf"]["z"], 128)(jnp.int32(2)))
    want = np.asarray(rows[2])
    if rtol == 0.0:
        np.testing.assert_array_equal(got, want)
    else:
        # per-row absmax scaling: error bounded by scale = absmax/levels
        bound = rtol * np.max(np.abs(want), axis=1, keepdims=True)
        assert np.all(np.abs(got - want) <= bound + 1e-6)


def test_ring_clear_lane_decodes_to_zero():
    from repro.serve.engine import _ring_clear_lane, _ring_read
    for cache_dtype in ("float32", "bfloat16", "int8", "int4"):
        ws, _ = _ring(cache_dtype)
        ws = _ring_clear_lane(ws, jnp.int32(3))
        for slot in range(3):
            out = np.asarray(_ring_read(ws["buf"]["z"], 128)(
                jnp.int32(slot)))
            np.testing.assert_array_equal(out[3], np.zeros(128, np.float32))
            assert np.any(out[2] != 0)     # neighbours untouched
