"""Multi-party CELU-VFL: three parties (A1, A2 feature-only + B with
labels), each A with its own workset table and Algorithm-2 weighting; B
weights instances by the MINIMUM per-party derivative cosine.

The paper defers K>1 feature parties to future work (§6); this example
runs the extension end-to-end on a 3-way vertical split, constructing the
rounds directly on the K-party engine (K=2 feature parties over a
SimWANTransport).

    PYTHONPATH=src python examples/multiparty_vfl.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import CELUConfig  # noqa: E402
from repro.core import engine  # noqa: E402
from repro.data.synthetic import TabularSpec, aligned_batches, \
    make_tabular  # noqa: E402
from repro.models.tabular import DLRMConfig, _mlp, _mlp_init, _tower, \
    auc, make_dlrm  # noqa: E402
from repro.optim import make_optimizer  # noqa: E402


def main():
    spec = TabularSpec("3party", fields_a=8, fields_b=4, vocab=128,
                       n_train=16384, n_test=4096)
    data = make_tabular(spec, seed=0)
    cfg = DLRMConfig("wdl", 4, 4, vocab=128, embed_dim=8, z_dim=16,
                     hidden=(32, 16))
    init_fn, _, _ = make_dlrm(cfg)
    pa1 = init_fn(jax.random.PRNGKey(0), cfg)["a"]
    pa2 = init_fn(jax.random.PRNGKey(1), cfg)["a"]
    pb = dict(init_fn(jax.random.PRNGKey(2), cfg)["b"])
    pb["top"] = _mlp_init(jax.random.PRNGKey(3), [3 * cfg.z_dim, 32, 1])

    def forward_a(pa, batch_a):
        return _tower(pa["tower"], batch_a["x_a"])

    def loss_b(pb_, z_list, batch_b):
        z_b = _tower(pb_["tower"], batch_b["x_b"])
        h = jnp.concatenate([z.astype(jnp.float32) for z in z_list] + [z_b],
                            axis=-1)
        logit = _mlp(pb_["top"], h)[:, 0]
        F = batch_b["x_b"].shape[1]
        wide = pb_["wide"][jnp.arange(F)[None, :], batch_b["x_b"]].sum(1)
        logit = logit + wide + pb_["bias"]
        y = batch_b["y"]
        li = jnp.maximum(logit, 0) - logit * y + jnp.log1p(
            jnp.exp(-jnp.abs(logit)))
        return li, jnp.float32(0.0)

    task = engine.KPartyTask(forward_a, loss_b)
    params = {"a": [pa1, pa2], "b": pb}
    celu = CELUConfig(R=3, W=3, xi_degrees=60.0)
    opt = make_optimizer("adagrad", 0.01)
    transport = engine.SimWANTransport(celu)

    split = lambda ba, bb: (
        [{"x_a": jnp.asarray(ba["x_a"][:, :4])},
         {"x_a": jnp.asarray(ba["x_a"][:, 4:])}],
        {"x_b": jnp.asarray(bb["x_b"]), "y": jnp.asarray(bb["y"])})
    it = aligned_batches(data["train"], 256, seed=0)
    _, ba, bb = next(it)
    bas, b = split(ba, bb)
    state = engine.init_state(task, params, opt, celu, bas, b)
    rnd = engine.make_round(task, opt, celu, transport=transport)

    it = aligned_batches(data["train"], 256, seed=0)
    print("3-party CELU-VFL (A1: 4 fields, A2: 4 fields, B: 4 + labels)")
    for i in range(120):
        bi, ba, bb = next(it)
        bas, b = split(ba, bb)
        state, m = rnd(state, bas, b, bi)
        if (i + 1) % 30 == 0:
            # Party B evaluates with fresh cut tensors (inference exchange)
            te = data["test"]
            z1 = forward_a(state["params"]["a"][0],
                           {"x_a": jnp.asarray(te["x_a"][:, :4])})
            z2 = forward_a(state["params"]["a"][1],
                           {"x_a": jnp.asarray(te["x_a"][:, 4:])})
            li, _ = loss_b(state["params"]["b"], [z1, z2],
                           {"x_b": jnp.asarray(te["x_b"]),
                            "y": jnp.asarray(te["y"])})
            z_b = _tower(state["params"]["b"]["tower"],
                         jnp.asarray(te["x_b"]))
            h = jnp.concatenate([z1, z2, z_b], axis=-1)
            logit = _mlp(state["params"]["b"]["top"], h)[:, 0]
            a = auc(np.asarray(logit), te["y"])
            print(f"  round {i+1:4d}  loss {float(m['loss']):.4f}  "
                  f"AUC {a:.4f}")
    zb = transport.round_bytes([(256, cfg.z_dim)] * 2)
    print(f"communication rounds: {int(state['comm_rounds'])} "
          f"(each funds {1 + celu.R} updates/party; "
          f"{zb / 1e3:.0f} KB/round over K=2 uplink+downlink pairs)")


if __name__ == "__main__":
    main()
