"""K-party round engine: golden-trace parity with the pre-engine seed
implementation, fused-vs-reference weighting equivalence, and transport
byte accounting.

``golden/two_party_trace.json`` was recorded from the ORIGINAL (pre-engine)
``core.protocol`` implementation at the seed commit — the engine's K=1 path
must reproduce those metrics bit-for-bit for all three protocol presets,
whether constructed through the ``core.protocol`` shim or directly on the
engine.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CELUConfig
from repro.core import engine
from repro.core import protocol as P
from repro.core.weighting import instance_weights
from repro.data.synthetic import TabularSpec, aligned_batches, make_tabular
from repro.models.tabular import DLRMConfig, make_dlrm
from repro.optim import make_optimizer

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "two_party_trace.json")
GOLDEN3 = os.path.join(os.path.dirname(__file__), "golden",
                       "three_party_trace.json")


def _workload():
    """The exact tiny workload the golden traces were recorded on."""
    spec = TabularSpec("criteo", fields_a=4, fields_b=3, vocab=32,
                       n_train=2048, n_test=512)
    data = make_tabular(spec, seed=0)
    cfg = DLRMConfig("wdl", 4, 3, vocab=32, embed_dim=4, z_dim=8,
                     hidden=(16, 8))
    return data, cfg


def _run_trace(protocol, *, via_shim, fused=True, rounds=20,
               compression=None):
    data, cfg = _workload()
    init_fn, task, predict = make_dlrm(cfg)
    base = CELUConfig(R=3, W=3, xi_degrees=60.0)
    ccfg, nloc = engine.preset_config(protocol, base)
    params = init_fn(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adagrad", 0.05)
    it = aligned_batches(data["train"], 64, seed=0)
    _, ba, bb = next(it)
    asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
    kw = {} if compression is None else \
        {"transport": engine.make_transport(ccfg, compression)}

    if via_shim:
        state = P.init_state(task, params, opt, ccfg, asj(ba), asj(bb),
                             **kw)
        rnd = P.make_round(task, opt, ccfg, local_steps=nloc,
                           fused_weighting=fused, **kw)
        step = lambda st, ba, bb, bi: rnd(st, asj(ba), asj(bb), bi)
        steps_of = lambda st: (int(st["steps"]["a"]),
                               int(st["steps"]["b"]))
    else:
        etask = engine.lift_two_party(task)
        state = engine.init_state(etask,
                                  engine.lift_two_party_params(params),
                                  opt, ccfg, [asj(ba)], asj(bb), **kw)
        rnd = engine.make_round(etask, opt, ccfg, local_steps=nloc,
                                fused_weighting=fused, **kw)
        step = lambda st, ba, bb, bi: rnd(st, [asj(ba)], asj(bb), bi)
        steps_of = lambda st: (int(st["steps"]["a"][0]),
                               int(st["steps"]["b"]))

    it = aligned_batches(data["train"], 64, seed=0)
    rows = []
    for i in range(rounds):
        bi, ba, bb = next(it)
        state, m = step(state, ba, bb, bi)
        rows.append({"loss": float(np.float32(m["loss"])),
                     "w_mean": float(np.float32(m["w_mean"])),
                     "w_zero_frac": float(np.float32(m["w_zero_frac"])),
                     "local_steps": int(m["local_steps"])})
    sa, sb = steps_of(state)
    rows.append({"steps_a": sa, "steps_b": sb,
                 "comm_rounds": int(state["comm_rounds"])})
    return rows


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


@pytest.mark.parametrize("protocol", ["vanilla", "fedbcd", "celu"])
def test_golden_trace_parity_via_protocol_shim(protocol, golden):
    """core.protocol (now a preset shim) reproduces the seed implementation
    bit-for-bit: identical loss/weight metrics over 20 rounds."""
    got = _run_trace(protocol, via_shim=True)
    assert got == golden[protocol]


@pytest.mark.parametrize("protocol", ["vanilla", "fedbcd", "celu"])
def test_golden_trace_parity_direct_engine(protocol, golden):
    """Constructing K=1 rounds directly on the engine gives the same
    trace as the shim (and hence the seed)."""
    got = _run_trace(protocol, via_shim=False)
    assert got == golden[protocol]


@pytest.mark.parametrize("via_shim", [True, False])
@pytest.mark.parametrize("protocol", ["vanilla", "celu"])
def test_identity_codec_transport_matches_golden(protocol, via_shim,
                                                 golden):
    """CompressedWANTransport with the identity codec is the SAME wire as
    plain SimWANTransport: bit-for-bit on the seed golden traces."""
    got = _run_trace(protocol, via_shim=via_shim, compression="identity")
    assert got == golden[protocol]


def test_fused_weighting_matches_reference_trace(golden):
    """The fused Pallas weighted-cotangent hot path and the pure-jnp
    reference composition produce identical training traces."""
    ref = _run_trace("celu", via_shim=False, fused=False, rounds=10)
    fused = _run_trace("celu", via_shim=False, fused=True, rounds=10)
    assert ref == fused
    # and both match the golden prefix
    assert ref[:10] == golden["celu"][:10]


def test_fused_weighting_kernel_equivalence():
    """Direct kernel-level check: engine.weighted_cotangent fused path ==
    reference composition (weights AND cotangent).  Single-tile shapes
    (B <= BLOCK_B) are bit-exact; tiled grids may reassociate the row
    reduction, so they get a float32-ulp tolerance."""
    from repro.kernels.cosine_weight import BLOCK_B
    rng = np.random.default_rng(3)
    for B, F in ((64, 8), (128, 32), (256, 16)):
        a = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
        s = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
        dz = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
        w_f, cot_f = engine.weighted_cotangent(a, s, dz, 0.5, fused=True)
        w_r, cot_r = engine.weighted_cotangent(a, s, dz, 0.5, fused=False)
        if B <= BLOCK_B:
            np.testing.assert_array_equal(np.asarray(w_f), np.asarray(w_r))
            np.testing.assert_array_equal(np.asarray(cot_f),
                                          np.asarray(cot_r))
        else:
            np.testing.assert_allclose(np.asarray(w_f), np.asarray(w_r),
                                       rtol=3e-7, atol=3e-7)
            np.testing.assert_allclose(np.asarray(cot_f), np.asarray(cot_r),
                                       rtol=3e-7, atol=3e-7)
        np.testing.assert_allclose(
            np.asarray(engine.staleness_weights(a, s, 0.5, fused=True)),
            np.asarray(instance_weights(a, s, 0.5)), rtol=3e-7, atol=3e-7)


def test_fused_weighting_odd_batch_falls_back():
    """Batch sizes the Pallas tiling can't split fall back to the
    reference path instead of failing."""
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.normal(size=(37, 8)), jnp.float32)
    s = jnp.asarray(rng.normal(size=(37, 8)), jnp.float32)
    dz = jnp.asarray(rng.normal(size=(37, 8)), jnp.float32)
    w, cot = engine.weighted_cotangent(a, s, dz, 0.5, fused=True)
    assert w.shape == (37,) and cot.shape == (37, 8)


def test_sim_wan_transport_byte_accounting():
    t32 = engine.SimWANTransport(CELUConfig(wire_dtype="float32"))
    t16 = engine.SimWANTransport(CELUConfig(wire_dtype="bfloat16"))
    # paper §2.1 geometry: Z_A (4096 x 256 fp32) -> 8 MB both ways
    assert t32.round_bytes([(4096, 256)]) == 2 * 4096 * 256 * 4
    assert t16.round_bytes([(4096, 256)]) == t32.round_bytes([(4096, 256)]) // 2
    # K feature parties: K uplink+downlink pairs
    assert t32.round_bytes([(64, 8)] * 3) == 3 * 2 * 64 * 8 * 4


def test_round_bytes_counts_asymmetric_messages():
    """Regression for the old ``2 * message_bytes`` shortcut: a transport
    with a sparse uplink (top-k indices+values) and a dense downlink must
    sum the two directions, not double one of them."""
    celu = CELUConfig()
    tp = engine.make_transport(celu, "int8_topk")
    shape = (256, 32)
    up, down = tp.uplink_bytes(shape), tp.downlink_bytes(shape)
    assert up != down                       # genuinely asymmetric
    assert tp.round_bytes([shape]) == up + down
    assert tp.round_bytes([shape] * 3) == 3 * (up + down)
    assert tp.round_bytes([shape]) != 2 * tp.message_bytes(shape)
    # symmetric transports still see one up + one down per party
    t32 = engine.SimWANTransport(celu)
    assert t32.round_bytes([shape]) == \
        t32.uplink_bytes(shape) + t32.downlink_bytes(shape) == \
        2 * t32.message_bytes(shape)


def _three_party_workload():
    """The exact K=2-feature-party workload (three parties total:
    A_1, A_2, B) the K=3 golden trace was recorded on."""
    spec = TabularSpec("t", fields_a=8, fields_b=4, vocab=64,
                       n_train=4096, n_test=512)
    data = make_tabular(spec, seed=0)
    cfg = DLRMConfig("wdl", 4, 4, vocab=64, embed_dim=4, z_dim=8,
                     hidden=(16, 8))
    init_fn, _, _ = make_dlrm(cfg)
    from repro.models.tabular import _mlp, _mlp_init, _tower
    pa1 = init_fn(jax.random.PRNGKey(0), cfg)["a"]
    pa2 = init_fn(jax.random.PRNGKey(1), cfg)["a"]
    pb = dict(init_fn(jax.random.PRNGKey(2), cfg)["b"])
    pb["top"] = _mlp_init(jax.random.PRNGKey(3), [3 * cfg.z_dim, 16, 1])

    def forward_a(pa, batch_a):
        return _tower(pa["tower"], batch_a["x_a"])

    def loss_b(pb_, z_list, batch_b):
        z_b = _tower(pb_["tower"], batch_b["x_b"])
        h = jnp.concatenate([z.astype(jnp.float32) for z in z_list] + [z_b],
                            axis=-1)
        logit = _mlp(pb_["top"], h)[:, 0]
        F = batch_b["x_b"].shape[1]
        wide = pb_["wide"][jnp.arange(F)[None, :], batch_b["x_b"]].sum(1)
        logit = logit + wide + pb_["bias"]
        y = batch_b["y"]
        li = jnp.maximum(logit, 0) - logit * y + jnp.log1p(
            jnp.exp(-jnp.abs(logit)))
        return li, jnp.float32(0.0)

    task = engine.KPartyTask(forward_a, loss_b)
    celu = CELUConfig(R=2, W=2, xi_degrees=60.0)
    opt = make_optimizer("adagrad", 0.02)
    split = lambda ba, bb: (
        [{"x_a": jnp.asarray(ba["x_a"][:, :4])},
         {"x_a": jnp.asarray(ba["x_a"][:, 4:])}],
        {"x_b": jnp.asarray(bb["x_b"]), "y": jnp.asarray(bb["y"])})
    params = {"a": [pa1, pa2], "b": pb}
    return task, celu, opt, data, split, params


def _run_three_party_trace(rounds=20, transport=None):
    """Run the K=3 workload and return golden-comparable metric rows
    (same schema as ``_run_trace``, ``steps_a`` is a per-party list)."""
    task, celu, opt, data, split, params = _three_party_workload()
    it = aligned_batches(data["train"], 64, seed=0)
    _, ba, bb = next(it)
    bas, b = split(ba, bb)
    kw = {} if transport is None else {"transport": transport}
    state = engine.init_state(task, params, opt, celu, bas, b, **kw)
    rnd = engine.make_round(task, opt, celu, **kw)
    it = aligned_batches(data["train"], 64, seed=0)
    rows = []
    for i in range(rounds):
        bi, ba, bb = next(it)
        bas, b = split(ba, bb)
        state, m = rnd(state, bas, b, bi)
        rows.append({"loss": float(np.float32(m["loss"])),
                     "w_mean": float(np.float32(m["w_mean"])),
                     "w_zero_frac": float(np.float32(m["w_zero_frac"])),
                     "local_steps": int(m["local_steps"])})
    rows.append({"steps_a": [int(s) for s in state["steps"]["a"]],
                 "steps_b": int(state["steps"]["b"]),
                 "comm_rounds": int(state["comm_rounds"])})
    return rows


def test_engine_three_party_trains_and_counts_steps():
    """K=2 feature parties on the engine: loss falls, per-party step
    counters track 1 fresh + R local updates per round."""
    n_rounds, R = 20, 2
    rows = _run_three_party_trace(rounds=n_rounds)
    losses = [r["loss"] for r in rows[:-1]]
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses
    tail = rows[-1]
    assert tail["comm_rounds"] == n_rounds
    for s in tail["steps_a"]:
        assert n_rounds < s <= n_rounds * (1 + R)
    assert n_rounds < tail["steps_b"] <= n_rounds * (1 + R)


@pytest.fixture(scope="module")
def golden3():
    with open(GOLDEN3) as f:
        return json.load(f)


def test_three_party_golden_trace(golden3):
    """The K=3 multiparty path is pinned bit-for-bit, like K=1
    (``golden/three_party_trace.json``; regenerate with
    ``tests/golden/record_three_party.py`` ONLY on intentional numeric
    changes)."""
    got = _run_three_party_trace(rounds=20)
    assert got == golden3["celu"]


def test_three_party_golden_identity_codec_transport(golden3):
    """The identity-codec compressed transport reproduces the K=3 golden
    trace bit-for-bit too (K residuals per direction collapse to none)."""
    celu = CELUConfig(R=2, W=2, xi_degrees=60.0)
    tp = engine.make_transport(celu, "identity")
    got = _run_three_party_trace(rounds=20, transport=tp)
    assert got == golden3["celu"]


def test_config_driven_compression_keeps_error_feedback():
    """``celu.compression`` alone (no explicit transport threading) must
    give init_state and make_round the SAME lossy transport: the round
    state carries live residuals, not the silent empty-dict fallback."""
    import dataclasses
    task, celu, opt, data, split, params = _three_party_workload()
    celu = dataclasses.replace(celu, compression="int8_topk")
    it = aligned_batches(data["train"], 64, seed=0)
    _, ba, bb = next(it)
    bas, b = split(ba, bb)
    state = engine.init_state(task, params, opt, celu, bas, b)
    assert sorted(state["transport"]) == ["down", "up"]
    rnd = engine.make_round(task, opt, celu)
    bi, ba, bb = next(it)
    bas, b = split(ba, bb)
    state, m = rnd(state, bas, b, bi)
    assert float(jnp.abs(state["transport"]["up"][0]).sum()) > 0.0


def test_half_threaded_lossy_transport_raises():
    """Passing a lossy transport to make_round but not init_state would
    silently drop error feedback — the round must refuse instead."""
    task, celu, opt, data, split, params = _three_party_workload()
    it = aligned_batches(data["train"], 64, seed=0)
    bi, ba, bb = next(it)
    bas, b = split(ba, bb)
    state = engine.init_state(task, params, opt, celu, bas, b)  # stateless
    tp = engine.make_transport(celu, "int8_topk")               # lossy
    rnd = engine.make_round(task, opt, celu, transport=tp)
    with pytest.raises(ValueError, match="error-feedback"):
        rnd(state, bas, b, bi)


def test_three_party_compressed_transport_trains():
    """A genuinely lossy wire (top-k+int8 up, int8 down, error feedback)
    still trains the K=3 workload: finite losses, downward trend, and one
    fp32 residual per feature party per direction in the round state."""
    celu = CELUConfig(R=2, W=2, xi_degrees=60.0)
    tp = engine.make_transport(celu, "int8_topk")
    task, _, opt, data, split, params = _three_party_workload()
    it = aligned_batches(data["train"], 64, seed=0)
    _, ba, bb = next(it)
    bas, b = split(ba, bb)
    state = engine.init_state(task, params, opt, celu, bas, b, transport=tp)
    assert sorted(state["transport"]) == ["down", "up"]
    assert len(state["transport"]["up"]) == 2
    rnd = engine.make_round(task, opt, celu, transport=tp)
    it = aligned_batches(data["train"], 64, seed=0)
    losses = []
    for i in range(20):
        bi, ba, bb = next(it)
        bas, b = split(ba, bb)
        state, m = rnd(state, bas, b, bi)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses
    # error feedback engaged: residuals are live, non-zero state
    res = state["transport"]["up"][0]
    assert res.dtype == jnp.float32 and float(jnp.abs(res).sum()) > 0.0
