"""The paper's technique at mesh level: a 2-pod CELU round where Party A
lives on pod 0 and Party B on pod 1, the cut-tensor exchange is the
engine's ``PodTransport`` (a ``ppermute`` pair over the ``pod`` axis), and
local updates hit the device-resident workset table (zero inter-pod
traffic).  The round itself is the same K-party engine logic as the
host-sim protocols — only the transport differs.

Runs on 2 simulated devices; prints the training losses and the measured
inter-pod bytes per model update for R ∈ {0, 5}.

    python examples/pod_protocol_demo.py
"""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp, numpy as np
from repro.core.engine import PodTransport
from repro.core.pod_protocol import make_pod_round, init_pod_state
from repro.optim import adagrad
from repro.launch.dryrun import collective_bytes

mesh = jax.make_mesh((2,), ("pod",))
opt = adagrad(0.05)

# --- train a few rounds ---------------------------------------------------
params, opt_state, ws = init_pod_state(jax.random.PRNGKey(0), mesh, opt,
                                        n_fields=8, vocab=64, batch=128,
                                        W=3, z_dim=16, hidden=32)
rnd = make_pod_round(mesh, opt, R=3, cos_xi=0.5,
                     transport=PodTransport(axis="pod"))
rng = np.random.default_rng(0)
teacher = rng.normal(size=(16, 64)).astype(np.float32)
print("2-pod CELU round (R=3, W=3):")
for i in range(20):
    x = rng.integers(0, 64, size=(2, 128, 8), dtype=np.int32)
    logit = teacher[np.arange(16)[None, :],
                    x.transpose(1, 0, 2).reshape(128, 16)].sum(1) / 4.0
    y = np.stack([np.zeros(128, np.float32),
                  (rng.random(128) < 1/(1+np.exp(-logit))).astype(np.float32)])
    params, opt_state, ws, loss = rnd(params, opt_state, ws,
                                      jnp.asarray(x), jnp.asarray(y))
    if (i + 1) % 5 == 0:
        print(f"  round {i+1:2d}  Party-B loss {float(loss[1]):.4f}")

# --- inter-pod bytes per update --------------------------------------------
print("inter-pod ppermute bytes per model update (B=4096, z=256):")
for R in (0, 5):
    p, o, w = init_pod_state(jax.random.PRNGKey(0), mesh, opt, n_fields=16,
                             vocab=512, batch=4096, W=5, z_dim=256,
                             hidden=256)
    r = make_pod_round(mesh, opt, R=max(R, 1), cos_xi=0.5)
    x = jax.ShapeDtypeStruct((2, 4096, 16), jnp.int32)
    y = jax.ShapeDtypeStruct((2, 4096), jnp.float32)
    txt = r.lower(p, o, w, x, y).compile().as_text()
    cp = collective_bytes(txt)["collective-permute"]
    ups = 1 + R
    print(f"  R={R}: {cp/1e6:.2f} MB/round, {ups} updates "
          f"-> {cp/ups/1e6:.2f} MB/update")
"""


def main():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    subprocess.run([sys.executable, "-c", CODE], env=env, check=True)


if __name__ == "__main__":
    main()
