from . import backbone, initializers, layers, moe, ssm, vfl, xlstm  # noqa: F401
