"""CELU protocol behaviour: workset invariants, weighting, convergence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CELUConfig
from repro.core import protocol as P
from repro.core.weighting import instance_weights, row_cosine, xi_to_cos
from repro.core.workset import (workset_init, workset_insert, workset_sample,
                                workset_stats)
from repro.data.synthetic import (TabularSpec, aligned_batches, make_tabular)
from repro.models.tabular import DLRMConfig, auc, make_dlrm
from repro.optim import make_optimizer


# --------------------------------------------------------------------------
# Workset table
# --------------------------------------------------------------------------
def _entry(v):
    return {"z_a": jnp.full((2, 3), float(v)),
            "dz_a": jnp.full((2, 3), -float(v)), "batch": {}}


def test_workset_insert_evicts_oldest():
    ws = workset_init(3, _entry(0))
    for t in range(5):
        ws = workset_insert(ws, _entry(t + 1), t)
    # capacity 3, inserted 5: slots hold entries 3,4,5
    vals = sorted(float(ws["buf"]["z_a"][i, 0, 0]) for i in range(3))
    assert vals == [3.0, 4.0, 5.0]
    assert int(workset_stats(ws, R=2)["n_alive"]) == 3


def test_round_robin_uniform_use():
    """Round-robin never reuses a slot within W-1 draws (paper §3.2)."""
    W, R = 4, 8
    ws = workset_init(W, _entry(0))
    for t in range(W):
        ws = workset_insert(ws, _entry(t), t)
    drawn = []
    for _ in range(8):
        ws, entry, bidx, valid = workset_sample(ws, R, "round_robin")
        assert bool(valid)
        drawn.append(int(bidx))
    # two full cycles over 4 slots, each visited exactly twice
    counts = {b: drawn.count(b) for b in set(drawn)}
    assert set(counts.values()) == {2}
    for i in range(len(drawn) - (W - 1)):
        window = drawn[i:i + W - 1]
        assert len(set(window)) == len(window)


def test_consecutive_always_freshest():
    ws = workset_init(3, _entry(0))
    for t in range(3):
        ws = workset_insert(ws, _entry(t), t)
    for _ in range(3):
        ws, entry, bidx, valid = workset_sample(ws, 5, "consecutive")
        assert int(bidx) == 2


def test_uniform_sampling_is_fair():
    """Paper §3.2's fair-sampling claim for the "uniform" strategy: over
    many independent keys every valid slot is drawn with equal frequency
    (chi-square goodness-of-fit against the uniform distribution)."""
    W, R, draws = 5, 10 ** 9, 4000
    ws = workset_init(W, _entry(0))
    for t in range(W):
        ws = workset_insert(ws, _entry(t), t)
    def draw(key):
        _, _, bidx, valid = workset_sample(ws, R, "uniform", rng=key)
        return bidx, valid
    bidxs, valids = jax.vmap(draw)(
        jax.random.split(jax.random.PRNGKey(0), draws))
    assert bool(jnp.all(valids))
    counts = np.bincount(np.asarray(bidxs), minlength=W)
    # chi-square statistic vs the uniform null; df = W-1 = 4, and the
    # 99.9th percentile of chi2(4) is 18.47 — a fair sampler stays under
    expected = draws / W
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    assert counts.min() > 0
    assert chi2 < 18.47, (chi2, counts.tolist())


def test_uniform_sampling_partially_filled_workset():
    """With only some slots alive, uniform draws come ONLY from the alive
    ones (empty and exhausted slots are never sampled), and an all-dead
    table yields invalid (no-op) draws."""
    W, R = 6, 2
    ws = workset_init(W, _entry(0))
    for t in range(2):                       # slots 0,1 filled; 2-5 empty
        ws = workset_insert(ws, _entry(t), t)
    def draws_on(table, n):
        def draw(key):
            _, _, bidx, valid = workset_sample(table, R, "uniform", rng=key)
            return bidx, valid
        return jax.vmap(draw)(
            jax.random.split(jax.random.PRNGKey(0), n))

    bidxs, valids = draws_on(ws, 300)
    assert bool(jnp.all(valids))
    assert set(np.asarray(bidxs).tolist()) == {0, 1}
    # exhaust slot 1: uniform must then only ever return slot 0
    ws2 = dict(ws)
    ws2["use_count"] = ws["use_count"].at[1].set(R)
    bidxs, valids = draws_on(ws2, 50)
    assert bool(jnp.all(valids))
    assert set(np.asarray(bidxs).tolist()) == {0}
    # fully dead table: the draw is a bubble, not a crash
    ws3 = dict(ws2)
    ws3["use_count"] = jnp.full((W,), R, jnp.int32)
    _, _, _, valid = workset_sample(ws3, R, "uniform",
                                    rng=jax.random.PRNGKey(0))
    assert not bool(valid)


def test_uniform_sampling_requires_rng():
    ws = workset_init(2, _entry(0))
    with pytest.raises(ValueError, match="rng"):
        workset_sample(ws, 2, "uniform")


def test_use_count_exhaustion():
    """Entries die after R uses; strict cycling turns empty/dead slots into
    no-op "bubble" draws (paper §3.2)."""
    R = 2
    ws = workset_init(2, _entry(0))
    ws = workset_insert(ws, _entry(1), 0)
    valids = []
    for _ in range(6):
        ws, _, _, valid = workset_sample(ws, R, "round_robin")
        valids.append(bool(valid))
    # slots cycle 0,1,0,1,...: slot 1 is empty (bubble); slot 0 dies after
    # R=2 uses
    assert valids == [True, False, True, False, False, False]


# --------------------------------------------------------------------------
# Weighting
# --------------------------------------------------------------------------
def test_instance_weights_threshold_and_identity():
    a = jnp.asarray(np.random.default_rng(0).normal(size=(8, 16)),
                    jnp.float32)
    w = instance_weights(a, a, xi_to_cos(60.0))
    np.testing.assert_allclose(np.asarray(w), 1.0, atol=1e-5)
    w2 = instance_weights(a, -a, xi_to_cos(60.0))
    assert (np.asarray(w2) == 0.0).all()


def test_row_cosine_scale_invariance():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    c1 = row_cosine(a, b)
    c2 = row_cosine(3.5 * a, 0.25 * b)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-5)


# --------------------------------------------------------------------------
# Protocol semantics
# --------------------------------------------------------------------------
def _tiny_setup(protocol, R=2, W=2, lr=0.05, weighting=True):
    spec = TabularSpec("criteo", fields_a=4, fields_b=3, vocab=32,
                       n_train=2048, n_test=512)
    data = make_tabular(spec, seed=0)
    cfg = DLRMConfig("wdl", 4, 3, vocab=32, embed_dim=4, z_dim=8,
                     hidden=(16, 8))
    init_fn, task, predict = make_dlrm(cfg)
    base = CELUConfig(R=R, W=W, xi_degrees=60.0, weighting=weighting)
    ccfg, nloc = P.protocol_config(protocol, base)
    params = init_fn(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adagrad", lr)
    it = aligned_batches(data["train"], 64, seed=0)
    _, ba, bb = next(it)
    asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
    state = P.init_state(task, params, opt, ccfg, asj(ba), asj(bb))
    rnd = P.make_round(task, opt, ccfg, local_steps=nloc)
    return data, cfg, predict, state, rnd, asj


def test_vanilla_equals_plain_sgd_updates():
    """Vanilla rounds do exactly one update per party per round."""
    data, cfg, predict, state, rnd, asj = _tiny_setup("vanilla")
    it = aligned_batches(data["train"], 64, seed=0)
    for i in range(3):
        bi, ba, bb = next(it)
        state, m = rnd(state, asj(ba), asj(bb), bi)
    assert int(state["steps"]["a"]) == 3
    assert int(state["steps"]["b"]) == 3
    assert int(state["comm_rounds"]) == 3


def test_celu_steps_accounting():
    """CELU does 1 + R updates per party per round (steady state)."""
    R = 3
    data, cfg, predict, state, rnd, asj = _tiny_setup("celu", R=R, W=2)
    it = aligned_batches(data["train"], 64, seed=0)
    n_rounds = 4
    for i in range(n_rounds):
        bi, ba, bb = next(it)
        state, m = rnd(state, asj(ba), asj(bb), bi)
    assert int(state["comm_rounds"]) == n_rounds
    # every local step was funded by a cached entry (<= R per insert)
    assert int(state["steps"]["a"]) <= n_rounds * (1 + R)
    assert int(state["steps"]["a"]) > n_rounds  # local updates did happen


def test_celu_trains_better_than_vanilla_per_round_sgd():
    """The paper's headline: more progress per communication round
    (robust on SGD where staleness is mild; see benchmarks for AdaGrad)."""
    results = {}
    for protocol in ("vanilla", "celu"):
        spec = TabularSpec("criteo", fields_a=6, fields_b=5, vocab=64,
                           n_train=8192, n_test=2048)
        data = make_tabular(spec, seed=0)
        cfg = DLRMConfig("wdl", 6, 5, vocab=64, embed_dim=8, z_dim=16,
                         hidden=(32, 16))
        init_fn, task, predict = make_dlrm(cfg)
        base = CELUConfig(R=3, W=3, xi_degrees=60.0)
        ccfg, nloc = P.protocol_config(protocol, base)
        params = init_fn(jax.random.PRNGKey(0), cfg)
        opt = make_optimizer("sgd", 0.1)
        it = aligned_batches(data["train"], 128, seed=0)
        _, ba, bb = next(it)
        asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
        state = P.init_state(task, params, opt, ccfg, asj(ba), asj(bb))
        rnd = P.make_round(task, opt, ccfg, local_steps=nloc)
        it = aligned_batches(data["train"], 128, seed=0)
        for i in range(60):
            bi, ba, bb = next(it)
            state, m = rnd(state, asj(ba), asj(bb), bi)
        te = data["test"]
        logits = predict(state["params"], cfg,
                         {"x_a": jnp.asarray(te["x_a"])},
                         {"x_b": jnp.asarray(te["x_b"]),
                          "y": jnp.asarray(te["y"])})
        results[protocol] = auc(np.asarray(logits), te["y"])
    assert results["celu"] > results["vanilla"] - 0.005, results


def test_weighting_zeroes_unreliable_instances():
    """With adversarially large lr the cosine filter must fire."""
    data, cfg, predict, state, rnd, asj = _tiny_setup("celu", R=3, W=3,
                                                      lr=1.0)
    it = aligned_batches(data["train"], 64, seed=0)
    zs = []
    for i in range(6):
        bi, ba, bb = next(it)
        state, m = rnd(state, asj(ba), asj(bb), bi)
        zs.append(float(m["w_zero_frac"]))
    assert max(zs) > 0.05, zs


def test_exchange_bytes_matches_paper_example():
    """Paper §2.1: Z_A (4096 x 256 fp32) -> 4 MB; round = 8 MB both ways."""
    nbytes = P.exchange_bytes((4096, 256))
    assert nbytes == 2 * 4096 * 256 * 4
    # 213 ms at 300 Mbps for the two transmissions
    t = nbytes * 8 / 300e6
    assert abs(t - 0.224) < 0.02


def test_dssm_gradients_finite_at_zero_cut_tensor():
    """Regression: grad of the DSSM normalization at Z_A = 0 (round-robin
    bubble entries) must be finite — max(norm, eps) gives 0*inf = NaN."""
    from repro.models.tabular import DLRMConfig, make_dlrm
    cfg = DLRMConfig("dssm", 4, 3, vocab=32, embed_dim=4, z_dim=8,
                     hidden=(16, 8))
    init_fn, task, predict = make_dlrm(cfg)
    params = init_fn(jax.random.PRNGKey(0), cfg)
    z0 = jnp.zeros((8, 8), jnp.float32)
    batch_b = {"x_b": jnp.zeros((8, 3), jnp.int32),
               "y": jnp.zeros((8,), jnp.float32)}
    g = jax.grad(lambda z: jnp.mean(task.loss_b(params["b"], z,
                                                batch_b)[0]))(z0)
    assert jnp.isfinite(g).all()
    gp = jax.grad(lambda p: jnp.mean(task.loss_b(p, z0, batch_b)[0]))(
        params["b"])
    for leaf in jax.tree_util.tree_leaves(gp):
        assert jnp.isfinite(leaf).all()
