"""Staleness-aware instance weighting (paper Algorithm 2).

``instance_weights(ad_hoc, stale, cos_xi)`` measures the per-instance cosine
similarity between the ad-hoc statistics (computed this local step) and the
cached stale statistics, and floors it at ``cos ξ`` (below the threshold the
instance weight is zeroed).  The cosine is taken over all non-batch axes
flattened per instance — exactly the paper's ``cos(·, ·, axis=1)`` with the
2-D flattening of footnote 3.

Rationale (paper §3.3): for an FC layer ``∇θ = z_inᵀ ∇z_out``, so
``cos(∇θ, ∇̃θ) = cos(∇z_out, ∇̃z_out)`` — row-wise similarity of the cut
tensors is a proxy for the similarity of the true and approximated gradients.

``use_pallas=True`` routes through the fused VMEM kernel in
``kernels/cosine_weight.py`` (one HBM pass instead of three); the default
pure-jnp path is its oracle and is what the TPU dry-run lowers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-12


def row_cosine(a, b):
    """Per-instance cosine similarity.  a, b: (B, ...) -> (B,) float32."""
    B = a.shape[0]
    af = a.reshape(B, -1).astype(jnp.float32)
    bf = b.reshape(B, -1).astype(jnp.float32)
    num = jnp.sum(af * bf, axis=1)
    den = jnp.sqrt(jnp.sum(af * af, axis=1) * jnp.sum(bf * bf, axis=1))
    return num / jnp.maximum(den, EPS)


def instance_weights(ad_hoc, stale, cos_xi: float, *,
                     use_pallas: bool = False):
    """Algorithm 2 ``InsWeight``: cosine similarities floored at cos ξ.

    Returns float32 weights of shape (B,); entries below the threshold are 0.
    """
    if use_pallas:
        from ..kernels import ops as kops
        return kops.cosine_weight(ad_hoc, stale, cos_xi)
    w = row_cosine(ad_hoc, stale)
    return jnp.where(w < cos_xi, 0.0, w)


def static_staleness(s) -> bool:
    """True when ``s`` is a host-side Python int (the static depth knob
    baked into the jitted stages at depths 0/1); a jnp scalar / tracer is
    the per-slot DYNAMIC staleness of the depth-D queue and takes the
    always-apply path (``w ** (1 + 0)`` is bitwise ``w``, so the dynamic
    form is still the identity at runtime s = 0)."""
    return isinstance(s, int) and not isinstance(s, bool)


def pipeline_attenuation(w, staleness):
    """Discount Algorithm-2 weights for known extra staleness.

    Under a depth-``s`` pipelined schedule a sampled entry's statistics are
    ``s`` exchanges older (relative to the params they are used against)
    than the sequential schedule that Algorithm 2's cosine measure was
    analysed on.  Model the drift per exchange as the drift the cosine
    already measured and compound it: ``w -> w^(1+s)``.  This keeps w=1
    (no measured drift) untouched, preserves zeros (below-threshold
    instances stay rejected), and shrinks borderline instances smoothly —
    no new hyper-parameter.  ``staleness=0`` is the identity.

    ``staleness`` may be a Python int (static: depths 0/1, skipped
    entirely at 0) or a jnp int scalar (the depth-D queue's per-slot
    offset, traced through the jitted local scan — warmup and drain scans
    see smaller s than the steady-state depth)."""
    if static_staleness(staleness) and staleness <= 0:
        return w
    return w ** (1 + staleness)


def xi_to_cos(xi_degrees: float) -> float:
    """Paper parameterizes the threshold as an angle ξ (e.g. 60°)."""
    import math
    return math.cos(math.radians(xi_degrees))
