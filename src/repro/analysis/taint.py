"""Cross-party information-flow analysis over traced round jaxprs.

The lattice: every traced value carries

  * ``raw``  — the set of parties whose UNRELEASED private data (features,
    labels, pre-release cut tensors, optimizer state, error-feedback
    residuals) flowed into it;
  * ``san``  — the sanitizer stages the value passed while tainted
    (``wire`` / ``encode`` / ``dp`` / ``cache``, as marked by
    :mod:`repro.analysis.markers`), with the eqn index of the latest
    application (for ordering checks);
  * ``casts`` — narrowing precision-cast sites (fp32 -> bf16/int8/int4 or
    float -> int) the value passed that no declared wire/encode/cache
    stage has vouched for yet.

Propagation is a forward walk of the jaxpr: outputs union the ``raw`` and
``casts`` of their inputs and intersect the ``san`` of their *tainted*
inputs (a value mixed from a sanitized and an unsanitized raw source is
not sanitized).  ``audit_mark`` eqns apply the semantics:

  * sanitizer marks add their stage (and clear pending casts for the
    declared stages);
  * boundary marks CHECK — raw taint present means the required stages
    must all be in ``san`` and the ordering constraints must hold — then
    release: raw taint converts to nothing (the value is now a released
    message both parties may hold).

Subjaxprs (pjit, scan, cond, custom_jvp/vjp, shard_map) are walked
recursively with 1:1 var mapping; scan runs its body to a fixed point so
carry-loop flows converge.  ``pallas_call`` is treated as an opaque
(conservative) op and recorded for the kernel-usage stats.  Collectives
are recorded with their axis names for the pod-boundary whitelist.

The host rule closes the theorem: every stage OUTPUT is declared hosted
at a party, and must carry no OTHER party's raw taint.  This is what
catches a refactor that routes a pre-release cut tensor into Party B's
loss, caches it in B's workset, or parks it in a ``PendingExchange``
queue slot — the value never reaches a transport send, so only the
output rule can see it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, FrozenSet, List, Optional, Sequence, Tuple

from .report import Finding

try:
    from jax.extend.core import Literal
except ImportError:  # pragma: no cover - older jax
    from jax.core import Literal  # type: ignore[no-redef]

# Collectives that move DATA across a mesh axis (the pod boundary);
# axis_index only reads coordinates and is always allowed.
DATA_COLLECTIVES = ("ppermute", "psum", "pmax", "pmin", "pmean",
                    "all_gather", "all_to_all", "reduce_scatter",
                    "pbroadcast", "pgather")

_NARROW_FLOATS = ("bfloat16", "float16", "float8_e4m3fn", "float8_e5m2")


# --------------------------------------------------------------------------
# The taint lattice
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class Taint:
    raw: FrozenSet[str] = frozenset()
    san: Tuple[Tuple[str, int], ...] = ()       # (stage, latest eqn idx)
    casts: FrozenSet[str] = frozenset()         # unmediated narrowing casts

    @property
    def san_names(self) -> FrozenSet[str]:
        return frozenset(n for n, _ in self.san)

    def san_idx(self, name: str) -> Optional[int]:
        for n, i in self.san:
            if n == name:
                return i
        return None

    def key(self):
        """Convergence key for scan fixed points: eqn indices shift
        between body re-walks, taint CONTENT must not."""
        return (self.raw, self.san_names, self.casts)


EMPTY = Taint()


def raw_of(party: str) -> Taint:
    return Taint(raw=frozenset({party}))


def _san_dict(t: Taint) -> Dict[str, int]:
    return dict(t.san)


def join(taints: Sequence[Taint]) -> Taint:
    """Output taint of a generic eqn over these input taints."""
    raw: FrozenSet[str] = frozenset()
    casts: FrozenSet[str] = frozenset()
    for t in taints:
        raw = raw | t.raw
        casts = casts | t.casts
    tainted = [t for t in taints if t.raw]
    if not tainted:
        return Taint(raw=raw, casts=casts)
    names = frozenset.intersection(*[t.san_names for t in tainted])
    san = tuple(sorted(
        (n, min(_san_dict(t)[n] for t in tainted)) for n in names))
    return Taint(raw=raw, san=san, casts=casts)


def sanitize(t: Taint, name: str, idx: int) -> Taint:
    san = dict(t.san)
    san[name] = idx
    casts = t.casts
    from .markers import DECLARED_CAST_STAGES
    if name in DECLARED_CAST_STAGES:
        casts = frozenset()
    return Taint(raw=t.raw, san=tuple(sorted(san.items())), casts=casts)


# --------------------------------------------------------------------------
# Trace-level evidence collected during the walk
# --------------------------------------------------------------------------
@dataclass
class BoundaryRecord:
    direction: str
    party: int
    transport: str
    shape: Tuple[int, ...]
    dtype: str
    satisfied: bool


@dataclass
class TraceAudit:
    """Everything one walk learns about one traced function."""
    case: str = ""
    findings: List[Finding] = field(default_factory=list)
    boundaries: Dict[int, BoundaryRecord] = field(default_factory=dict)
    pallas_calls: Dict[int, str] = field(default_factory=dict)
    collectives: Dict[int, Tuple[str, Tuple[str, ...]]] = \
        field(default_factory=dict)
    _seen: set = field(default_factory=set)

    def add_finding(self, code: str, severity: str, where: str,
                    detail: str) -> None:
        key = (code, where, detail)
        if key in self._seen:
            return
        self._seen.add(key)
        self.findings.append(Finding(code=code, severity=severity,
                                     where=where, detail=detail,
                                     case=self.case))


# --------------------------------------------------------------------------
# The walker
# --------------------------------------------------------------------------
def _axis_names(params: Dict[str, Any]) -> Tuple[str, ...]:
    names = []
    for k in ("axis_name", "axes", "axis"):
        v = params.get(k)
        if v is None:
            continue
        if isinstance(v, (tuple, list)):
            names.extend(str(a) for a in v)
        else:
            names.append(str(v))
    return tuple(names)


def _is_narrowing(src, dst) -> bool:
    import numpy as np
    src, dst = np.dtype(src), np.dtype(dst)
    if src.kind != "f":
        return False
    if dst.kind == "f":
        return dst.itemsize < src.itemsize or dst.name in _NARROW_FLOATS \
            and src.name == "float32" and dst.itemsize < src.itemsize
    return dst.kind in ("i", "u")


class TaintWalker:
    SUBJAXPR_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr")

    def __init__(self, audit: TraceAudit):
        self.audit = audit
        self.idx = 0

    # -- env helpers -------------------------------------------------------
    @staticmethod
    def _read(env: Dict[Any, Taint], v) -> Taint:
        if isinstance(v, Literal):
            return EMPTY
        return env.get(v, EMPTY)

    # -- entry points ------------------------------------------------------
    def walk_closed(self, closed, in_taints: Sequence[Taint]
                    ) -> List[Taint]:
        jaxpr = closed.jaxpr
        consts = [EMPTY] * len(jaxpr.constvars)
        return self.walk(jaxpr, list(in_taints), consts)

    def walk(self, jaxpr, in_taints: Sequence[Taint],
             const_taints: Sequence[Taint]) -> List[Taint]:
        assert len(in_taints) == len(jaxpr.invars), \
            (len(in_taints), len(jaxpr.invars))
        env: Dict[Any, Taint] = {}
        for v, t in zip(jaxpr.constvars, const_taints):
            env[v] = t
        for v, t in zip(jaxpr.invars, in_taints):
            env[v] = t
        for eqn in jaxpr.eqns:
            self._eqn(env, eqn)
        return [self._read(env, v) for v in jaxpr.outvars]

    # -- per-eqn semantics -------------------------------------------------
    def _eqn(self, env: Dict[Any, Taint], eqn) -> None:
        self.idx += 1
        idx = self.idx
        prim = eqn.primitive.name
        ts = [self._read(env, v) for v in eqn.invars]

        if prim == "audit_mark":
            out = self._mark(eqn, ts[0], idx)
            env[eqn.outvars[0]] = out
            return

        if prim == "convert_element_type":
            src = eqn.invars[0].aval.dtype
            dst = eqn.params.get("new_dtype", src)
            out = join(ts)
            if _is_narrowing(src, dst):
                site = f"convert {src}->{dst} (eqn #{idx})"
                out = Taint(raw=out.raw, san=out.san,
                            casts=out.casts | {site})
            env[eqn.outvars[0]] = out
            return

        if prim == "pallas_call":
            if id(eqn) not in self.audit.pallas_calls:
                name = str(eqn.params.get("name",
                                          eqn.params.get("name_and_src",
                                                         "pallas")))
                self.audit.pallas_calls[id(eqn)] = name
            self._smear(env, eqn, ts)
            return

        if prim in DATA_COLLECTIVES:
            if id(eqn) not in self.audit.collectives:
                self.audit.collectives[id(eqn)] = \
                    (prim, _axis_names(eqn.params))
            self._smear(env, eqn, ts)
            return

        if prim == "scan":
            self._scan(env, eqn, ts)
            return

        if prim == "cond":
            self._cond(env, eqn, ts)
            return

        if prim == "while":
            # no while in the audited engine; conservative smear
            self._smear(env, eqn, ts)
            return

        sub = self._subjaxpr(eqn)
        if sub is not None:
            closed, open_jaxpr = sub
            n_in = len(closed.jaxpr.invars) if closed is not None \
                else len(open_jaxpr.invars)
            if n_in == len(ts):
                if closed is not None:
                    outs = self.walk_closed(closed, ts)
                else:
                    outs = self.walk(open_jaxpr, ts,
                                     [EMPTY] * len(open_jaxpr.constvars))
                n_out = len(eqn.outvars)
                if len(outs) == n_out:
                    for v, t in zip(eqn.outvars, outs):
                        env[v] = t
                    return
            # arity mismatch: fall through to the conservative smear
        self._smear(env, eqn, ts)

    def _smear(self, env, eqn, ts) -> None:
        out = join(ts)
        for v in eqn.outvars:
            env[v] = out

    def _subjaxpr(self, eqn):
        for k in self.SUBJAXPR_KEYS:
            v = eqn.params.get(k)
            if v is None:
                continue
            if hasattr(v, "jaxpr"):          # ClosedJaxpr
                return v, None
            if hasattr(v, "eqns"):           # open Jaxpr
                return None, v
        return None

    # -- structured primitives --------------------------------------------
    def _scan(self, env, eqn, ts) -> None:
        p = eqn.params
        nc, ncar = p["num_consts"], p["num_carry"]
        body = p["jaxpr"]
        const_t = ts[:nc]
        carry_t = list(ts[nc:nc + ncar])
        xs_t = ts[nc + ncar:]
        outs: List[Taint] = []
        for _ in range(32):
            outs = self.walk_closed(body, const_t + carry_t + xs_t)
            new_carry = [join([c, o])
                         for c, o in zip(carry_t, outs[:ncar])]
            if [t.key() for t in new_carry] == \
                    [t.key() for t in carry_t]:
                carry_t = new_carry
                break
            carry_t = new_carry
        final = carry_t + outs[ncar:]
        for v, t in zip(eqn.outvars, final):
            env[v] = t

    def _cond(self, env, eqn, ts) -> None:
        branches = eqn.params["branches"]
        opts = [self.walk_closed(b, ts[1:]) for b in branches]
        for j, v in enumerate(eqn.outvars):
            env[v] = join([o[j] for o in opts])

    # -- marks -------------------------------------------------------------
    def _mark(self, eqn, t: Taint, idx: int) -> Taint:
        role = eqn.params["role"]
        name = eqn.params["name"]
        if role == "sanitizer":
            return sanitize(t, name, idx)
        assert role == "boundary", role
        meta = dict(eqn.params.get("meta", ()))
        require = tuple(meta.get("require", ()))
        order = tuple(meta.get("order", ()))
        aval = eqn.outvars[0].aval
        satisfied = True
        if t.raw:
            missing = [r for r in require if r not in t.san_names]
            if missing:
                satisfied = False
                self.audit.add_finding(
                    "taint.raw-boundary", "error",
                    f"{meta.get('transport', '?')}.send "
                    f"{name} {tuple(aval.shape)}:{aval.dtype}",
                    f"raw value tainted by part{'ies' if len(t.raw) > 1 else 'y'} "
                    f"{sorted(t.raw)} reaches the {meta.get('direction')} "
                    f"boundary without the registered "
                    f"{'/'.join(missing)} stage(s) "
                    f"(required: {list(require)}, seen: "
                    f"{sorted(t.san_names)})")
            for before, after in order:
                bi, ai = t.san_idx(before), t.san_idx(after)
                if bi is not None and ai is not None and ai <= bi:
                    satisfied = False
                    self.audit.add_finding(
                        "taint.sanitizer-order", "error",
                        f"{meta.get('transport', '?')}.send {name}",
                        f"'{after}' stage applied BEFORE '{before}' on the "
                        f"{meta.get('direction')} boundary value — with a "
                        f"lossy codec the DP noise must ride the decoded "
                        f"wire value (after encode), or error feedback "
                        f"re-transmits and cancels it")
        if id(eqn) not in self.audit.boundaries:
            self.audit.boundaries[id(eqn)] = BoundaryRecord(
                direction=str(meta.get("direction", "?")),
                party=int(meta.get("party", -1)),
                transport=str(meta.get("transport", "?")),
                shape=tuple(aval.shape), dtype=str(aval.dtype),
                satisfied=satisfied)
        # release: the value is now a sanitized public message
        return Taint(casts=t.casts)


# --------------------------------------------------------------------------
# Output host rule
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class OutTag:
    """Host declaration for one stage-output region.  ``allowed`` is the
    set of parties whose RAW taint the output may carry (None = skip the
    check: sim-level metrics that legitimately mix parties)."""
    allowed: Optional[FrozenSet[str]]
    label: str


def check_outputs(out_taints: Sequence[Taint], out_tags: Sequence[OutTag],
                  audit: TraceAudit) -> None:
    assert len(out_taints) == len(out_tags), \
        (len(out_taints), len(out_tags))
    for t, tag in zip(out_taints, out_tags):
        if t.casts:
            audit.add_finding(
                "kernel.unmediated-cast", "error", tag.label,
                f"narrowing precision cast(s) {sorted(t.casts)} reach this "
                f"output without passing a declared wire/encode/cache "
                f"stage — precision loss outside the registered codecs")
        if tag.allowed is None:
            continue
        extra = t.raw - tag.allowed
        if extra:
            audit.add_finding(
                "taint.foreign-raw-output", "error", tag.label,
                f"output hosted at {sorted(tag.allowed) or ['<public>']} "
                f"carries raw taint of part"
                f"{'ies' if len(extra) > 1 else 'y'} {sorted(extra)} — a "
                f"pre-release private value escaped into another party's "
                f"state")


def audit_trace(closed_jaxpr, in_taints: Sequence[Taint],
                out_tags: Sequence[OutTag], case: str = "") -> TraceAudit:
    """Walk one traced round function end to end: propagate taint, check
    every boundary mark, then apply the host rule to the outputs."""
    audit = TraceAudit(case=case)
    walker = TaintWalker(audit)
    outs = walker.walk_closed(closed_jaxpr, in_taints)
    check_outputs(outs, out_tags, audit)
    return audit
