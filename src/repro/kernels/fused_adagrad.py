"""Fused AdaGrad kernels: accumulate + rsqrt-scale in one VMEM pass.

The unfused optimizer reads grad, reads accum, writes accum, reads accum
again, writes update — with XLA usually fusing *some* of it but still
materializing the fp32 accumulator twice.  The kernel does

    a' = a + g²;  u = -lr * g / (sqrt(a') + eps)

with one load of (g, a) and one store of (u, a') per element — the memory-
bound optimum (3 streams in, 2 out → 2 in, 2 out).

Tiling: inputs are flattened and padded to (N/BLOCK, BLOCK) with BLOCK=1024
lanes — pure element-wise VPU work, no MXU, no cross-lane traffic.

``fused_adagrad_q8`` is the int8-at-rest variant (8-bit-optimizer style:
int8 codes + one fp32 master scale per row): dequantize the stored
accumulator, accumulate g², emit the update, re-derive the row scale
from the new row max, and stochastically requantize — all in the same
single VMEM pass, so the fp32 accumulator NEVER exists in HBM.  Codes
live in SQRT-space: the kernel already computes ``r = sqrt(a')`` for the
update, and quantizing r instead of a squares the representable dynamic
range ((1/127)² ≈ 6e-5 of the row max instead of 1/127) — the nonuniform
trick 8-bit optimizers use, with the resolution exactly where AdaGrad's
1/r step needs it.  The accumulator is non-negative and row-monotone, so
codes are in [0, 127] and the row scale only grows; stochastic rounding
(``floor(r/s + u)``, unbiased in r) keeps sub-LSB increments from
silently stalling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024
ROWS = 8
Q8_LEVELS = 127.0
EPS_SCALE = 1e-12


def _kernel(g_ref, a_ref, hyp_ref, u_ref, a_out_ref):
    g = g_ref[...].astype(jnp.float32)
    a = a_ref[...]
    lr = hyp_ref[0]
    eps = hyp_ref[1]
    a_new = a + g * g
    u_ref[...] = -lr * g / (jnp.sqrt(a_new) + eps)
    a_out_ref[...] = a_new


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_adagrad(grad, accum, lr, eps, *, interpret: bool = True):
    """grad: any shape/dtype; accum: same shape fp32.
    -> (update fp32, new_accum fp32), same shape as grad."""
    shape = grad.shape
    n = grad.size
    cols = min(BLOCK, max(n, 1))
    rows_per_block = ROWS
    n_pad = ((n + cols - 1) // cols) * cols
    n_rows = n_pad // cols
    n_rows_pad = ((n_rows + rows_per_block - 1) // rows_per_block) \
        * rows_per_block

    g = jnp.zeros((n_rows_pad * cols,), jnp.float32).at[:n].set(
        grad.reshape(-1).astype(jnp.float32)).reshape(n_rows_pad, cols)
    a = jnp.zeros((n_rows_pad * cols,), jnp.float32).at[:n].set(
        accum.reshape(-1)).reshape(n_rows_pad, cols)
    hyp = jnp.asarray([lr, eps], jnp.float32)

    u, a_new = pl.pallas_call(
        _kernel,
        grid=(n_rows_pad // rows_per_block,),
        in_specs=[
            pl.BlockSpec((rows_per_block, cols), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_block, cols), lambda i: (i, 0)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((rows_per_block, cols), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_block, cols), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_rows_pad, cols), jnp.float32),
            jax.ShapeDtypeStruct((n_rows_pad, cols), jnp.float32),
        ],
        interpret=interpret,
    )(g, a, hyp)
    return (u.reshape(-1)[:n].reshape(shape),
            a_new.reshape(-1)[:n].reshape(shape))


def _kernel_q8(g_ref, q_ref, s_ref, u_ref, hyp_ref, upd_ref, q_out_ref,
               s_out_ref):
    g = g_ref[...].astype(jnp.float32)
    r = q_ref[...].astype(jnp.float32) * s_ref[...]     # dequant sqrt-accum
    lr = hyp_ref[0]
    eps = hyp_ref[1]
    r_new = jnp.sqrt(r * r + g * g)                      # accumulate
    upd_ref[...] = -lr * g / (r_new + eps)               # scale
    s_new = jnp.maximum(jnp.max(r_new, axis=1, keepdims=True),
                        EPS_SCALE) / Q8_LEVELS
    codes = jnp.floor(r_new / s_new + u_ref[...])        # requant (SR)
    q_out_ref[...] = jnp.clip(codes, 0.0, Q8_LEVELS).astype(jnp.int8)
    s_out_ref[...] = s_new


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_adagrad_q8(grad2d, accum_q, accum_scale, u, lr, eps, *,
                     interpret: bool = True):
    """int8-at-rest AdaGrad step over the kernel's native tiling.

    grad2d: (R, C) fp32 with R % ROWS == 0 (the optimizer pads once at
    init and keeps the layout); accum_q: (R, C) int8 sqrt-space codes in
    [0, 127] (accumulator value = (code * scale)²); accum_scale: (R, 1)
    fp32 per-row master scales; u: (R, C) uniforms in [0, 1) for the
    requant stochastic rounding.
    -> (update fp32 (R, C), new codes int8, new scales (R, 1))."""
    R, C = grad2d.shape
    assert R % ROWS == 0, (R, ROWS)
    hyp = jnp.asarray([lr, eps], jnp.float32)
    return pl.pallas_call(
        _kernel_q8,
        grid=(R // ROWS,),
        in_specs=[
            pl.BlockSpec((ROWS, C), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, C), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, 1), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, C), lambda i: (i, 0)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((ROWS, C), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, C), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), jnp.float32),
            jax.ShapeDtypeStruct((R, C), jnp.int8),
            jax.ShapeDtypeStruct((R, 1), jnp.float32),
        ],
        interpret=interpret,
    )(grad2d.astype(jnp.float32), accum_q, accum_scale,
      u.astype(jnp.float32), hyp)
