"""llama4-scout-17b-a16e — MoE 16 experts top-1 + shared expert, early
fusion [hf:meta-llama/Llama-4-Scout-17B-16E].

sharding="ep": expert-parallel dispatch measured 40% less collective and
24% less memory than f-sharded TP on the 16x16 mesh (E=16 divides the
model axis — EXPERIMENTS §Perf 2.4); adopted as this arch's default.
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab_size=202048,
    moe=MoEConfig(n_experts=16, top_k=1, n_shared=1, sharding="ep"),
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
