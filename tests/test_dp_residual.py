"""Residual-aware DP accounting (satellite S1 of the boundary-auditor
PR): with ``dp_sigma > 0`` over a LOSSY codec the Gaussian noise must
ride the DECODED wire value — applied after the encode/decode round
trip, with the error-feedback residual taken from the un-noised
quantity.  Noising first means (a) the residual re-transmits the noise
in later rounds, cancelling the mechanism, and (b) wire bits are wasted
encoding noise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CELUConfig
from repro.core.compression import IdentityCodec, TopKCodec
from repro.core.engine import CompressedWANTransport
from repro.core.privacy import DPConfig, clip_rows, wire_noise


def _deterministic_codec():
    # top-k over identity values: encode/decode ignore the rng entirely,
    # so residual differences across noise keys isolate the DP path
    return TopKCodec(0.25, value_codec=IdentityCodec())


def _dp_transport(sigma=0.3, clip=0.5):
    celu = CELUConfig(dp_sigma=sigma, dp_clip=clip)
    return CompressedWANTransport(celu, _deterministic_codec(),
                                  _deterministic_codec()), celu


@pytest.fixture
def x():
    return jax.random.normal(jax.random.PRNGKey(7), (64, 8))


@pytest.fixture
def res():
    return 0.1 * jax.random.normal(jax.random.PRNGKey(8), (64, 8))


def test_residual_independent_of_noise_key(x, res):
    """THE regression: the error-feedback residual must not depend on
    the DP noise draw — noise is added after the residual is taken."""
    tp, _ = _dp_transport()
    y1, r1 = tp.send(jax.random.PRNGKey(1), x, res, "up")
    y2, r2 = tp.send(jax.random.PRNGKey(2), x, res, "up")
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    # ...while the RELEASED value is genuinely noised per key
    assert not np.allclose(np.asarray(y1), np.asarray(y2))


def test_send_matches_whitebox_pipeline(x, res):
    """Bit-exact replication of the required order: clip -> wire cast ->
    +residual -> encode -> decode -> residual out -> noise -> release."""
    tp, celu = _dp_transport()
    rng = jax.random.PRNGKey(3)
    y, r = tp.send(rng, x, res, "up")

    cfg = DPConfig(clip=celu.dp_clip, sigma=celu.dp_sigma)
    codec = tp.codecs["up"]
    e = tp._wire_cast(clip_rows(x, cfg.clip)).astype(jnp.float32) + res
    payload = codec.encode(jax.random.fold_in(rng, 1), e)
    decoded = codec.decode(payload, e)
    np.testing.assert_array_equal(np.asarray(r),
                                  np.asarray(e - decoded))
    want_y = wire_noise(jax.random.fold_in(rng, 2), decoded,
                        cfg).astype(x.dtype)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(want_y))


def test_release_noise_has_dp_scale(x, res):
    """y - decode(encode(e)) must be pure Gaussian noise at
    sigma * clip — the residual is excluded from the noised quantity."""
    sigma, clip = 0.3, 0.5
    tp, _ = _dp_transport(sigma, clip)
    rng = jax.random.PRNGKey(4)
    y, _ = tp.send(rng, x, res, "up")
    codec = tp.codecs["up"]
    e = tp._wire_cast(clip_rows(x, clip)).astype(jnp.float32) + res
    decoded = codec.decode(codec.encode(jax.random.fold_in(rng, 1), e), e)
    noise = np.asarray(y - decoded)
    assert abs(noise.std() - sigma * clip) < 0.25 * sigma * clip
    assert abs(noise.mean()) < 3 * sigma * clip / np.sqrt(noise.size)


def test_dp_zero_path_is_unnoised_error_feedback(x, res):
    """sigma = 0 keeps the historical lossy path bit-for-bit: no clip,
    no noise, residual = e - decode(encode(e))."""
    celu = CELUConfig()
    tp = CompressedWANTransport(celu, _deterministic_codec(),
                                _deterministic_codec())
    rng = jax.random.PRNGKey(5)
    y, r = tp.send(rng, x, res, "up")
    codec = tp.codecs["up"]
    e = x.astype(jnp.float32) + res
    decoded = codec.decode(codec.encode(jax.random.fold_in(rng, 1), e), e)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(decoded.astype(x.dtype)))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(e - decoded))


def test_exact_codec_passes_residual_through(x, res):
    """Exact codecs skip the residual machinery even under DP — the
    noised wire value needs no error feedback."""
    celu = CELUConfig(dp_sigma=0.3, dp_clip=0.5)
    tp = CompressedWANTransport(celu, IdentityCodec(), IdentityCodec())
    _, r = tp.send(jax.random.PRNGKey(6), x, res, "up")
    np.testing.assert_array_equal(np.asarray(r), np.asarray(res))


def test_round_with_dp_and_lossy_codec_trains():
    """Integration: a full engine round under dp + top-k+int8 produces
    finite loss and finite residual state."""
    from repro.analysis.audit import _toy_task
    from repro.core.engine import init_state, make_round, make_transport
    from repro.optim import make_optimizer

    celu = CELUConfig(R=2, W=3, dp_sigma=0.3, compression="topk_int8")
    task, params, batches_a, batch_b = _toy_task(1)
    batches_a = [{"x": jax.random.normal(jax.random.PRNGKey(0), (64, 6))}]
    batch_b = {"x": jax.random.normal(jax.random.PRNGKey(1), (64, 5)),
               "y": (jax.random.uniform(jax.random.PRNGKey(2), (64,))
                     > 0.5).astype(jnp.float32)}
    opt = make_optimizer("adagrad", 0.1)
    tp = make_transport(celu)
    state = init_state(task, params, opt, celu, batches_a, batch_b,
                       transport=tp)
    fn = make_round(task, opt, celu, transport=tp)
    for i in range(3):
        state, m = fn(state, batches_a, batch_b, jnp.int32(i))
    assert np.isfinite(float(m["loss"]))
    for d in ("up", "down"):
        for rr in state["transport"][d]:
            assert np.all(np.isfinite(np.asarray(rr)))
