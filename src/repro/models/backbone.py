"""Family backbones: blocks + scan-stacked towers for all assigned archs.

A *tower* is a list of stages; each stage is ``(pattern, repeat)`` where
``pattern`` is a tuple of block types forming a "super-block" that repeats
``repeat`` times via ``lax.scan`` over stacked params.  This keeps the HLO
O(1) in depth (one lowered super-block per stage) — essential for the
100-layer dry-runs — and lets heterogeneous layouts (xLSTM's sLSTM/mLSTM
alternation, the VLM's every-5th cross-attention) compile as scans too.

Block types:
  dense   : RMSNorm -> GQA attn -> RMSNorm -> gated MLP     (llama family)
  moe     : RMSNorm -> GQA attn -> RMSNorm -> MoE FFN
  hybrid  : RMSNorm -> (attn ∥ mamba)/2 -> RMSNorm -> MLP   (hymba)
  mlstm   : RMSNorm -> mLSTM cell                            (xlstm)
  slstm   : RMSNorm -> sLSTM cell                            (xlstm)
  cross   : RMSNorm -> self attn -> RMSNorm -> cross attn -> RMSNorm -> MLP
  enc     : RMSNorm -> bidirectional attn -> RMSNorm -> MLP  (audio encoder)

Three execution modes share block code: ``train`` (full seq, remat),
``prefill`` (full seq, emits KV/state caches), ``decode`` (1 token + cache).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .initializers import PARAM_DTYPE, dense_init, stacked_init
from . import layers as L
from . import moe as M
from . import ssm as S
from . import xlstm as X


# --------------------------------------------------------------------------
# Tower stage layouts
# --------------------------------------------------------------------------
def tower_stages(cfg: ArchConfig, n_layers: int, role: str
                 ) -> Sequence[Tuple[Tuple[str, ...], int]]:
    """role: text | vlm | enc | audio_dec."""
    if n_layers <= 0:
        return []
    if role == "enc":
        return [(("enc",), n_layers)]
    if role == "audio_dec":
        return [(("cross",), n_layers)]
    if role == "vlm":
        k = cfg.cross_attn_every
        stages = []
        n_super, rem = divmod(n_layers, k)
        if n_super:
            stages.append((("dense",) * (k - 1) + ("cross",), n_super))
        if rem:
            stages.append((("dense",), rem))
        return stages
    # text families
    if cfg.family == "ssm":  # xlstm
        k = cfg.xlstm.slstm_every if cfg.xlstm else 4
        stages = []
        n_super, rem = divmod(n_layers, k)
        if n_super:
            stages.append((("slstm",) + ("mlstm",) * (k - 1), n_super))
        if rem:
            stages.append((("mlstm",), rem))
        return stages
    btype = {"dense": "dense", "moe": "moe", "hybrid": "hybrid"}.get(
        cfg.family, "dense")
    return [((btype,), n_layers)]


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------
def block_init(rng, cfg: ArchConfig, btype: str):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(rng, 8)
    ln = lambda: L.rmsnorm_init(d)
    if btype in ("dense", "moe", "enc"):
        p = {"ln1": ln(), "ln2": ln(),
             "attn": L.attention_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                      hd, qkv_bias=cfg.qkv_bias)}
        if btype == "moe":
            p["ffn"] = M.moe_init(ks[1], d, cfg.d_ff, cfg.moe)
        else:
            p["ffn"] = L.mlp_init(ks[1], d, cfg.d_ff)
        return p
    if btype == "hybrid":
        return {"ln1": ln(), "lnm": ln(), "ln2": ln(),
                "attn": L.attention_init(ks[0], d, cfg.n_heads,
                                         cfg.n_kv_heads, hd),
                "mamba": S.mamba_init(ks[1], d, cfg.ssm),
                "ffn": L.mlp_init(ks[2], d, cfg.d_ff)}
    if btype == "cross":
        return {"ln1": ln(), "lnx": ln(), "ln2": ln(),
                "attn": L.attention_init(ks[0], d, cfg.n_heads,
                                         cfg.n_kv_heads, hd),
                "xattn": L.attention_init(ks[1], d, cfg.n_heads,
                                          cfg.n_kv_heads, hd),
                "ffn": L.mlp_init(ks[2], d, cfg.d_ff)}
    if btype == "mlstm":
        return {"ln1": ln(), "cell": X.mlstm_init(ks[0], d, cfg.n_heads)}
    if btype == "slstm":
        return {"ln1": ln(), "cell": X.slstm_init(ks[0], d, cfg.n_heads)}
    raise ValueError(btype)


@dataclass
class Ctx:
    cfg: ArchConfig
    positions: Any = None          # (S,) int32 for full/prefill
    memory: Any = None             # (B, S_mem, d) for cross blocks
    memory_positions: Any = None
    window: int = 0                # sliding window (0 = full)
    causal: bool = True
    pos: Any = None                # scalar int32, decode
    train: bool = False
    # Activation checkpointing of the per-stage scan body (train only):
    # recompute block activations in the backward pass instead of storing
    # S*d per layer — the standard trade that makes full LLM geometry fit.
    # False stores everything (faster backward, O(layers) more activation
    # HBM); surfaced as --remat/--no-remat in launch.train.
    remat: bool = True


def _ffn(params, x, cfg, btype):
    if btype == "moe":
        return M.moe_apply(params["ffn"], x, cfg.moe)
    return L.mlp_apply(params["ffn"], x), 0.0


def block_apply_full(params, x, btype: str, ctx: Ctx):
    """Full-sequence forward.  Returns (x, aux_loss)."""
    cfg = ctx.cfg
    eps = cfg.norm_eps
    if btype in ("mlstm", "slstm"):
        # mLSTM trains in the chunkwise-PARALLEL form (MXU matmuls; exact —
        # see xlstm.mlstm_apply_chunked); sLSTM is inherently sequential.
        cell = X.mlstm_apply_chunked if btype == "mlstm" else X.slstm_apply
        h, _ = cell(params["cell"], L.rmsnorm(params["ln1"], x, eps))
        return x + h, 0.0
    attn_kw = dict(positions=ctx.positions, theta=cfg.rope_theta,
                   causal=(ctx.causal and btype != "enc"),
                   window=ctx.window)
    h = L.rmsnorm(params["ln1"], x, eps)
    a = L.attention_apply(params["attn"], h, **attn_kw)
    if btype == "hybrid":
        m = S.mamba_apply(params["mamba"],
                          L.rmsnorm(params["lnm"], x, eps), cfg.ssm)
        x = x + (a + m) * 0.5
    else:
        x = x + a
    if btype == "cross":
        hx = L.rmsnorm(params["lnx"], x, eps)
        x = x + L.attention_apply(params["xattn"], hx, positions=ctx.positions,
                                  theta=cfg.rope_theta, memory=ctx.memory,
                                  memory_positions=ctx.memory_positions,
                                  use_rope=False)
    h2 = L.rmsnorm(params["ln2"], x, eps)
    y, aux = _ffn(params, h2, cfg, btype)
    return x + y, aux


# ---- caches ---------------------------------------------------------------
def block_make_cache(cfg: ArchConfig, btype: str, batch: int, capacity: int,
                     memory_len: int = 0):
    d, hd, kv = cfg.d_model, cfg.resolved_head_dim, cfg.n_kv_heads
    if btype in ("dense", "moe", "enc"):
        return {"attn": L.make_kv_cache(batch, capacity, kv, hd)}
    if btype == "hybrid":
        return {"attn": L.make_kv_cache(batch, capacity, kv, hd),
                "ssm": S.make_ssm_cache(batch, d, cfg.ssm)}
    if btype == "cross":
        return {"attn": L.make_kv_cache(batch, capacity, kv, hd),
                "xmem": {"k": jnp.zeros((batch, memory_len, kv, hd),
                                        PARAM_DTYPE),
                         "v": jnp.zeros((batch, memory_len, kv, hd),
                                        PARAM_DTYPE)}}
    if btype == "mlstm":
        return {"state": X.make_mlstm_state(batch, cfg.n_heads, d // cfg.n_heads)}
    if btype == "slstm":
        return {"state": X.make_slstm_state(batch, cfg.n_heads, d // cfg.n_heads)}
    raise ValueError(btype)


def block_decode(params, x, btype: str, ctx: Ctx, cache):
    """One-token step.  Returns (x, aux, new_cache)."""
    cfg = ctx.cfg
    eps = cfg.norm_eps
    if btype in ("mlstm", "slstm"):
        cell = X.mlstm_decode if btype == "mlstm" else X.slstm_decode
        h, st = cell(params["cell"], L.rmsnorm(params["ln1"], x, eps),
                     cache["state"])
        return x + h, 0.0, {"state": st}
    h = L.rmsnorm(params["ln1"], x, eps)
    a, kv = L.attention_decode(params["attn"], h, cache["attn"], ctx.pos,
                               theta=cfg.rope_theta, window=ctx.window)
    new_cache = dict(cache)
    new_cache["attn"] = kv
    if btype == "hybrid":
        m, sc = S.mamba_decode(params["mamba"],
                               L.rmsnorm(params["lnm"], x, eps),
                               cache["ssm"], cfg.ssm)
        new_cache["ssm"] = sc
        x = x + (a + m) * 0.5
    else:
        x = x + a
    if btype == "cross":
        hx = L.rmsnorm(params["lnx"], x, eps)
        x = x + L.cross_attention_decode(params["xattn"], hx, cache["xmem"])
    h2 = L.rmsnorm(params["ln2"], x, eps)
    y, aux = _ffn(params, h2, cfg, btype)
    return x + y, aux, new_cache


def block_prefill(params, x, btype: str, ctx: Ctx, capacity: int):
    """Full-sequence forward that also emits the decode cache."""
    cfg = ctx.cfg
    y, aux = block_apply_full(params, x, btype, ctx)
    B, Sq = x.shape[0], x.shape[1]
    if btype in ("mlstm", "slstm"):
        cell = X.mlstm_apply_chunked if btype == "mlstm" else X.slstm_apply
        _, st = cell(params["cell"],
                     L.rmsnorm(params["ln1"], x, cfg.norm_eps))
        return y, aux, {"state": st}
    # KV cache from the (normed) block input — recompute K/V projections
    h = L.rmsnorm(params["ln1"], x, cfg.norm_eps)
    k = jnp.einsum("bsd,dhk->bshk", h, params["attn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, params["attn"]["wv"])
    if "bk" in params["attn"]:
        k = k + params["attn"]["bk"]
        v = v + params["attn"]["bv"]
    k = L.rope(k, ctx.positions, cfg.rope_theta)
    cap = capacity
    tail = min(cap, Sq)
    k_t = k[:, Sq - tail:]
    v_t = v[:, Sq - tail:]
    tail_pos = ctx.positions[Sq - tail:]
    slots = jnp.mod(tail_pos, cap)
    kc = jnp.zeros((B, cap) + k.shape[2:], k.dtype).at[:, slots].set(k_t)
    vc = jnp.zeros((B, cap) + v.shape[2:], v.dtype).at[:, slots].set(v_t)
    sp = jnp.full((cap,), -(2 ** 30), jnp.int32).at[slots].set(tail_pos)
    cache = {"attn": {"k": kc, "v": vc, "slot_pos": sp}}
    if btype == "hybrid":
        x_in, _ = S._precompute(params["mamba"],
                                L.rmsnorm(params["lnm"], x, cfg.norm_eps))
        K = cfg.ssm.conv_dim
        xc = jax.nn.silu(S._causal_conv(x_in, params["mamba"]["conv_w"])
                         .astype(jnp.float32)).astype(x.dtype)
        dt, B_t, C_t = S._dtbc(params["mamba"], xc)
        A = -jnp.exp(params["mamba"]["A_log"])
        h0 = jnp.zeros((B, A.shape[0], A.shape[1]), jnp.float32)
        _, h_last = S._selective_ssm(xc.astype(jnp.float32), dt, B_t, C_t,
                                     A, h0)
        cache["ssm"] = {"h": h_last, "conv": x_in[:, Sq - (K - 1):]}
    if btype == "cross":
        cache["xmem"] = L.project_memory_kv(params["xattn"], ctx.memory)
    return y, aux, cache


# --------------------------------------------------------------------------
# Towers
# --------------------------------------------------------------------------
def tower_init(rng, cfg: ArchConfig, stages):
    params = []
    for (pattern, repeat) in stages:
        r = jax.random.fold_in(rng, len(params))
        def one(k, _pattern=pattern):
            sks = jax.random.split(k, len(_pattern))
            return {f"b{i}": block_init(sks[i], cfg, bt)
                    for i, bt in enumerate(_pattern)}
        params.append(stacked_init(one, r, repeat))
    return params


def tower_make_cache(cfg: ArchConfig, stages, batch: int, capacity: int,
                     memory_len: int = 0):
    caches = []
    for (pattern, repeat) in stages:
        one = {f"b{i}": block_make_cache(cfg, bt, batch, capacity, memory_len)
               for i, bt in enumerate(pattern)}
        caches.append(jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (repeat,) + a.shape).copy(), one))
    return caches


def tower_apply(params, x, cfg: ArchConfig, stages, ctx: Ctx):
    """Train/eval full-sequence forward.  Returns (x, aux)."""
    aux = jnp.float32(0.0)
    for sp, (pattern, repeat) in zip(params, stages):
        def body(carry, p_layer, _pattern=pattern):
            h, a = carry
            h = L.shard_batch_dim(h)   # pin batch sharding in the loop body
            for i, bt in enumerate(_pattern):
                h, ai = block_apply_full(p_layer[f"b{i}"], h, bt, ctx)
                a = a + ai
            return (L.shard_batch_dim(h), a), None
        if ctx.train and ctx.remat:
            body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, aux), sp)
    return x, aux


def tower_prefill(params, x, cfg: ArchConfig, stages, ctx: Ctx,
                  capacity: int):
    aux = jnp.float32(0.0)
    caches = []
    for sp, (pattern, repeat) in zip(params, stages):
        def body(carry, p_layer, _pattern=pattern):
            h, a = carry
            cs = {}
            for i, bt in enumerate(_pattern):
                h, ai, c = block_prefill(p_layer[f"b{i}"], h, bt, ctx,
                                         capacity)
                a = a + ai
                cs[f"b{i}"] = c
            return (h, a), cs
        (x, aux), stage_cache = jax.lax.scan(body, (x, aux), sp)
        caches.append(stage_cache)
    return x, aux, caches


def tower_decode(params, x, cfg: ArchConfig, stages, ctx: Ctx, caches):
    aux = jnp.float32(0.0)
    new_caches = []
    for sp, sc, (pattern, repeat) in zip(params, caches, stages):
        def body(carry, xs, _pattern=pattern):
            h, a = carry
            p_layer, c_layer = xs
            ncs = {}
            for i, bt in enumerate(_pattern):
                h, ai, nc = block_decode(p_layer[f"b{i}"], h, bt, ctx,
                                         c_layer[f"b{i}"])
                a = a + ai
                ncs[f"b{i}"] = nc
            return (h, a), ncs
        (x, aux), nc = jax.lax.scan(body, (x, aux), (sp, sc))
        new_caches.append(nc)
    return x, aux, new_caches
