"""Multi-party CELU-VFL: two or more feature parties (the paper's footnote
1 and §6 explicitly defer this — "our work can be generalized to two or
more Party A's easily ... we would like to leave the extension to
multi-party VFL training as our future work").

This module is now a thin K-party preset over :mod:`repro.core.engine` —
the task/state layout here IS the engine's native layout, so the functions
delegate directly.  Semantics (engine round, K feature parties):

  * every A_i computes and sends Z_i; B returns ∇Z_i  (K uplinks + K
    downlinks — the WAN cost now scales with K, making the paper's
    round-reduction MORE valuable, not less);
  * all parties take the fresh SGD step;
  * each A_i runs R local updates from its OWN workset (cached
    ⟨Z_i, ∇Z_i, X_i⟩), with Algorithm-2 weighting on cos(Z_i^(j), Z_i);
  * B runs R local updates from its workset (cached ⟨{Z_i}, {∇Z_i}, X_B,
    y⟩), weighting each instance by the MINIMUM per-party derivative
    cosine — an instance is only trusted if it is fresh w.r.t. EVERY
    party's cut tensor (conservative composition of the paper's
    heuristic).

The task interface generalizes :class:`repro.core.protocol.VFLTask`:

    forward_a(params_a_i, batch_a_i) -> Z_i           (same fn, per party)
    loss_b(params_b, [Z_1..Z_K], batch_b) -> (per-instance loss, aux)
"""
from __future__ import annotations

from typing import Any, Dict, List

from ..configs.base import CELUConfig
from ..optim import Optimizer
from . import engine

# The K-party task tuple is the engine's native interface.
MultiVFLTask = engine.KPartyTask


def init_state(task: MultiVFLTask, params: Dict[str, Any], opt: Optimizer,
               celu: CELUConfig, batches_a: List[Dict[str, Any]],
               batch_b: Dict[str, Any], transport=None, compression=None):
    """params = {"a": [pa_1..pa_K], "b": pb}."""
    return engine.init_state(task, params, opt, celu, batches_a, batch_b,
                             transport=transport, compression=compression)


def make_round(task: MultiVFLTask, opt: Optimizer, celu: CELUConfig,
               *, local_steps: int = -1, jit: bool = True,
               fused_weighting: bool = True, transport=None,
               compression=None):
    """fn(state, batches_a: list, batch_b, batch_idx) -> (state, metrics).

    ``compression`` names a wire codec (``core.compression.CODEC_SPECS``)
    when no explicit ``transport`` is given."""
    return engine.make_round(task, opt, celu, local_steps=local_steps,
                             transport=transport, compression=compression,
                             fused_weighting=fused_weighting, jit=jit)
