"""Party-to-pod mapping: CELU-VFL as an SPMD program over the multi-pod mesh.

DESIGN §2: the production mesh is (pod=2, data=16, model=16); the slow
inter-pod DCN link plays the paper's WAN.  Party A lives on pod 0, Party B
on pod 1.  The cut-tensor exchange ⟨Z_A, ∇Z_A⟩ is a pair of
``lax.ppermute``s over the ``pod`` axis — the ONLY collectives that cross
the slow link.  Local updates read the device-resident workset table and
produce zero inter-pod traffic, so collective bytes over ``pod`` per model
update drop by ~(R+1)× (verified from the lowered HLO by
benchmarks/roofline.py).

Implementation: both parties' towers are expressed as ONE party-stacked
pytree with a leading party axis sharded over ``pod`` (party p's weights
physically live on pod p).  Each pod computes ITS party's function on its
shard inside ``shard_map``; Party A's head produces Z_A, permuted to pod 1;
pod 1 computes the top model + per-instance loss, takes ∇Z_A, and permutes
it back.  Labels are carried in Party B's feature slot, so pod 0 never sees
them — the information-flow discipline holds at the device-placement level,
not just module level.

The demo task is the paper's WDL DLRM with equal-width towers (field counts
padded to max(F_A, F_B) with a dead field so the stacked shapes agree).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..optim import Optimizer, apply_updates


# --------------------------------------------------------------------------
# Party-stacked WDL: tower params with leading party axis (2, ...)
# --------------------------------------------------------------------------
def stacked_wdl_init(rng, n_fields: int, vocab: int, embed_dim: int,
                     z_dim: int, hidden: int):
    """Both parties' towers in one pytree, leading axis = party (2,)."""
    def one(k):
        ks = jax.random.split(k, 4)
        lim1 = 1.0 / jnp.sqrt(float(n_fields * embed_dim))
        lim2 = 1.0 / jnp.sqrt(float(hidden))
        return {
            "embed": jax.random.normal(
                ks[0], (n_fields, vocab, embed_dim), jnp.float32) * 0.01,
            "w1": jax.random.uniform(ks[1], (n_fields * embed_dim, hidden),
                                     jnp.float32, -lim1, lim1),
            "b1": jnp.zeros((hidden,), jnp.float32),
            "w2": jax.random.uniform(ks[2], (hidden, z_dim), jnp.float32,
                                     -lim2, lim2),
            "b2": jnp.zeros((z_dim,), jnp.float32),
        }
    ka, kb, kt = jax.random.split(rng, 3)
    towers = jax.tree_util.tree_map(
        lambda a, b: jnp.stack([a, b]), one(ka), one(kb))
    lim = 1.0 / jnp.sqrt(float(2 * z_dim))
    # top model: physically Party B's; stacked too (pod 0's copy is dead
    # weight that never receives gradient — keeps the pytree homogeneous)
    top = {
        "w1": jax.random.uniform(kt, (2, 2 * z_dim, z_dim), jnp.float32,
                                 -lim, lim),
        "b1": jnp.zeros((2, z_dim), jnp.float32),
        "w2": jax.random.normal(jax.random.fold_in(kt, 1),
                                (2, z_dim, 1), jnp.float32) * 0.01,
        "b2": jnp.zeros((2, 1), jnp.float32),
    }
    return {"tower": towers, "top": top}


def _tower_fwd(tp, x_fields):
    """tp: un-stacked (per-party) tower params; x_fields: (B, F) int32."""
    F = x_fields.shape[1]
    e = tp["embed"][jnp.arange(F)[None, :], x_fields]     # (B, F, E)
    h = jax.nn.relu(e.reshape(e.shape[0], -1) @ tp["w1"] + tp["b1"])
    return h @ tp["w2"] + tp["b2"]                        # (B, z_dim)


def _top_loss(top, z_a, z_b, y):
    """Per-instance logistic loss at Party B."""
    h = jnp.concatenate([z_a, z_b], axis=-1)
    h = jax.nn.relu(h @ top["w1"] + top["b1"])
    logit = (h @ top["w2"])[:, 0] + top["b2"][0]
    return jnp.maximum(logit, 0) - logit * y + jnp.log1p(
        jnp.exp(-jnp.abs(logit)))


# --------------------------------------------------------------------------
# One communication round inside shard_map
# --------------------------------------------------------------------------
def make_pod_round(mesh: Mesh, opt: Optimizer, *, R: int, cos_xi: float,
                   weighting: bool = True):
    """Build the jitted multi-pod CELU round.

    State pytree (all party-stacked, party axis over ``pod``):
      params:   {"tower": (2,...), "top": (2,...)}
      opt:      AdaGrad accumulators, same structure
      ws:       workset ring buffers (2, W, B_local, ...) — per-party caches
    Batch: x (2, B, F) int32 — party p's features on pod p;
           y (2, B) — labels valid on party 1's slot only.
    """
    def exchange_and_local(params, opt_state, ws, x, y):
        """Runs per-pod (inside shard_map, pod axis size 2).

        Shapes here are the PER-POD view: params leaves (1, ...), x (1,B,F).
        """
        pod = jax.lax.axis_index("pod")
        tower = jax.tree_util.tree_map(lambda a: a[0], params["tower"])
        top = jax.tree_util.tree_map(lambda a: a[0], params["top"])
        xb = x[0]                                   # (B, F)
        yb = y[0]                                   # (B,)

        # ---- fresh exchange (the paper's communication worker) ----------
        z_mine, tower_vjp = jax.vjp(lambda tp: _tower_fwd(tp, xb), tower)
        # Z_A: pod0 -> pod1 (pod0 receives pod1's Z_B slot, unused)
        z_recv = jax.lax.ppermute(z_mine, "pod", [(0, 1), (1, 0)])
        z_a_at_b = z_recv                            # on pod 1: Z_A

        def loss_fn(top_p, z_a):
            li = _top_loss(top_p, z_a, z_mine, yb)
            return jnp.mean(li)
        (loss, (g_top, dz_a)) = (loss_fn(top, z_a_at_b),
                                 jax.grad(loss_fn, argnums=(0, 1))(
                                     top, z_a_at_b))
        # ∇Z_A: pod1 -> pod0 (the symmetric permute)
        dz_back = jax.lax.ppermute(dz_a, "pod", [(1, 0), (0, 1)])

        is_a = (pod == 0)
        # Party A's tower cotangent is the received ∇Z_A; Party B's is its
        # local ∂loss/∂Z_B.  Both computed, selected by pod id.
        dz_b_local = jax.grad(
            lambda z_b: jnp.mean(_top_loss(top, z_a_at_b, z_b, yb)))(z_mine)
        cot = jnp.where(is_a, dz_back, dz_b_local)
        (g_tower,) = tower_vjp(cot)
        g_top = jax.tree_util.tree_map(
            lambda g: jnp.where(is_a, 0.0, g), g_top)

        # ---- update + insert into the device-resident workset -----------
        grads = {"tower": jax.tree_util.tree_map(lambda g: g[None], g_tower),
                 "top": jax.tree_util.tree_map(lambda g: g[None], g_top)}
        upd, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, upd)

        W = ws["z"].shape[1]
        slot = jnp.mod(ws["time"][0], W)
        ws = dict(ws)
        # cache: stale z (own Z for A's weighting / Z_A for B), stale dz,
        # own features (+ labels at B)
        z_cache = jnp.where(is_a, z_mine, z_a_at_b)
        dz_cache = jnp.where(is_a, dz_back, dz_a)
        ws["z"] = jax.lax.dynamic_update_index_in_dim(
            ws["z"], z_cache[None], slot, 1)
        ws["dz"] = jax.lax.dynamic_update_index_in_dim(
            ws["dz"], dz_cache[None], slot, 1)
        ws["x"] = jax.lax.dynamic_update_index_in_dim(
            ws["x"], xb[None], slot, 1)
        ws["y"] = jax.lax.dynamic_update_index_in_dim(
            ws["y"], yb[None], slot, 1)
        ws["time"] = ws["time"] + 1

        # ---- R local updates, round-robin over the workset ---------------
        def local_step(carry, j):
            params, opt_state, cursor = carry
            t = ws["time"][0]
            n_alive = jnp.minimum(t, W)
            slot_j = jnp.mod(cursor, jnp.maximum(n_alive, 1))
            zs = ws["z"][0, slot_j]
            dzs = ws["dz"][0, slot_j]
            xs = ws["x"][0, slot_j]
            ys_ = ws["y"][0, slot_j]
            tower_j = jax.tree_util.tree_map(lambda a: a[0],
                                             params["tower"])
            top_j = jax.tree_util.tree_map(lambda a: a[0], params["top"])

            # Party A: ad-hoc forward, cosine vs stale Z, weighted stale ∇Z
            z_new, vjp_j = jax.vjp(lambda tp: _tower_fwd(tp, xs), tower_j)
            if weighting:
                num = jnp.sum(z_new * zs, axis=1)
                den = jnp.sqrt(jnp.sum(z_new * z_new, axis=1)
                               * jnp.sum(zs * zs, axis=1))
                w_a = num / jnp.maximum(den, 1e-12)
                w_a = jnp.where(w_a < cos_xi, 0.0, w_a)
            else:
                w_a = jnp.ones(z_new.shape[0], jnp.float32)

            # Party B: stale Z_A + ad-hoc own tower; weight by ∇Z_A cosine
            def loss_b(top_p, tower_p, w):
                z_b = _tower_fwd(tower_p, xs)
                li = _top_loss(top_p, zs, z_b, ys_)
                return jnp.mean(w * li)
            dz_new = jax.grad(
                lambda z: jnp.mean(_top_loss(top_j, z,
                                             _tower_fwd(tower_j, xs), ys_))
            )(zs)
            if weighting:
                num = jnp.sum(dz_new * dzs, axis=1)
                den = jnp.sqrt(jnp.sum(dz_new * dz_new, axis=1)
                               * jnp.sum(dzs * dzs, axis=1))
                w_b = num / jnp.maximum(den, 1e-12)
                w_b = jnp.where(w_b < cos_xi, 0.0, w_b)
            else:
                w_b = jnp.ones(dz_new.shape[0], jnp.float32)

            (g_tower_a,) = vjp_j(w_a[:, None] * dzs)
            g_top_b, g_tower_b = jax.grad(loss_b, argnums=(0, 1))(
                top_j, tower_j, w_b)

            is_a_ = (pod == 0)
            g_tower_sel = jax.tree_util.tree_map(
                lambda ga, gb: jnp.where(is_a_, ga, gb)[None],
                g_tower_a, g_tower_b)
            g_top_sel = jax.tree_util.tree_map(
                lambda g: jnp.where(is_a_, 0.0, g)[None], g_top_b)
            grads_j = {"tower": g_tower_sel, "top": g_top_sel}
            upd_j, opt_state = opt.update(grads_j, opt_state, params)
            params = apply_updates(params, upd_j)
            return (params, opt_state, cursor + 1), None

        (params, opt_state, _), _ = jax.lax.scan(
            local_step, (params, opt_state, jnp.int32(0)), None, length=R)
        return params, opt_state, ws, loss[None]

    pp = P("pod")
    specs_state = pp  # every party-stacked leaf shards dim0 over pod
    fn = shard_map(
        exchange_and_local, mesh=mesh,
        in_specs=(pp, pp, pp, pp, pp),
        out_specs=(pp, pp, pp, pp),
        check_rep=False)
    return jax.jit(fn)


def init_pod_state(rng, mesh: Mesh, opt: Optimizer, *, n_fields: int,
                   vocab: int, batch: int, W: int, embed_dim: int = 16,
                   z_dim: int = 64, hidden: int = 128):
    params = stacked_wdl_init(rng, n_fields, vocab, embed_dim, z_dim, hidden)
    opt_state = opt.init(params)
    ws = {
        "z": jnp.zeros((2, W, batch, z_dim), jnp.float32),
        "dz": jnp.zeros((2, W, batch, z_dim), jnp.float32),
        "x": jnp.zeros((2, W, batch, n_fields), jnp.int32),
        "y": jnp.zeros((2, W, batch), jnp.float32),
        "time": jnp.zeros((2,), jnp.int32),
    }
    return params, opt_state, ws
