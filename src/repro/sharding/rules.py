"""Sharding rules: param-name-driven PartitionSpecs with divisibility guards.

Tensor-parallel (Megatron-style) layout over the ``model`` mesh axis,
data-parallel batches over ``data`` (and ``pod`` when the multi-pod mesh is
active — except in the party-to-pod CELU protocol, where ``pod`` carries the
two parties; see core/pod_protocol.py).

Every rule checks divisibility against the actual mesh axis size and falls
back to replication — e.g. GQA archs with n_kv ∈ {5, 8} < 16 replicate the
KV projections (exactly what production Llama-GQA TP does), hymba's 25 query
heads replicate while its d_ff=5504=16·344 shards, and so on.  This keeps
every (arch × mesh) combination lowerable without per-arch special cases.

Name-based rules (leaf key -> which logical dim shards over ``model``):

  embed        (V, d)        -> V          head       (d, V)   -> V
  wq           (d, H, hd)    -> H          wo   (H, hd, d)     -> H
  wk/wv        (d, Kv, hd)   -> Kv         mlp wg/wu  (d, f)   -> f
  mlp wd       (f, d)        -> f          moe  (E, d, f)      -> f ("tp") or E ("ep")
  mamba in_proj(d, 2di)      -> 2di        mamba out_proj (di, d) -> di
  xlstm w_x    (d, 4d)       -> 4d         norms/bias/scalars  -> replicate

Scanned tower stacks carry a leading layer axis (detected via a SequenceKey
in the tree path — stages are list entries), shifting every dim index by 1.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape.get(a, 1) for a in axis]))
    return int(mesh.shape.get(axis, 1))


def _leaf_name(path) -> str:
    for p in reversed(path):
        if isinstance(p, jax.tree_util.DictKey):
            return str(p.key)
    return ""


def _is_scanned(path) -> bool:
    return any(isinstance(p, jax.tree_util.SequenceKey) for p in path)


# rule: name -> (shard_dim_from_end or from_start, ...) handled explicitly
def _param_spec(path, leaf, mesh: Mesh, model_axis: str,
                moe_sharding: str, fsdp_axis: Optional[str]) -> P:
    name = _leaf_name(path)
    msize = _axis_size(mesh, model_axis)
    fsize = _axis_size(mesh, fsdp_axis) if fsdp_axis else 1
    nd = leaf.ndim
    off = 1 if _is_scanned(path) else 0

    def _add_fsdp(parts: list) -> list:
        """ZeRO-3-style second axis: shard the largest remaining divisible
        dim over the data axis (weights all-gather before use; needed for
        the ≥30B archs to fit v5e HBM — see DESIGN §4)."""
        if not fsdp_axis or fsize == 1 or leaf.size < 1 << 20:
            return parts
        cands = sorted(
            (i for i in range(off, nd)
             if parts[i] is None and leaf.shape[i] % fsize == 0
             and leaf.shape[i] >= fsize),
            key=lambda i: -leaf.shape[i])
        if cands:
            parts[cands[0]] = fsdp_axis
        return parts

    def _model_dim(*dims: int) -> Optional[int]:
        """First candidate dim divisible by the model-axis size."""
        for dim in dims:
            if dim < nd and msize > 1 and leaf.shape[dim] % msize == 0 \
                    and leaf.shape[dim] >= msize:
                return dim
        return None

    # which dims to try sharding over `model`, by param name
    if name == "embed":
        cand = (off + 0,)
    elif name == "head":
        cand = (off + 1,)
    elif name == "wq":
        # (d, H, hd): shard heads only.  Sharding head_dim instead would
        # make every attention score a partial sum all-reduced over `model`
        # (measured: 8 GB/step extra collectives on smollm) — replicating,
        # as Megatron does for non-divisible head counts, is strictly better.
        cand = (off + 1,)
    elif name in ("wk", "wv"):
        cand = (off + 1,)
    elif name == "wo":
        cand = (off + 0,)
    elif name in ("wg", "wu"):
        if nd - off == 3:                 # MoE (E, d, f)
            cand = (off + 0,) if moe_sharding == "ep" else (off + 2,)
        else:
            cand = (off + 1,)
    elif name == "wd":
        if nd - off == 3:                 # MoE (E, f, d)
            cand = (off + 0,) if moe_sharding == "ep" else (off + 1,)
        else:
            cand = (off + 0,)
    elif name in ("in_proj", "w_x"):
        cand = (off + 1,)
    elif name == "out_proj":
        cand = (off + 0,)
    elif name in ("proj", "proj1", "proj2", "fuse_proj"):
        cand = (off + 1,)
    else:
        # norms, biases, routers, conv, ssm/xlstm small tensors, scalars
        return P()

    parts: list = [None] * nd
    dim = _model_dim(*cand)
    if dim is not None:
        parts[dim] = model_axis
    return P(*_add_fsdp(parts))


def params_pspecs(params, mesh: Mesh, *, model_axis: str = "model",
                  moe_sharding: str = "tp", fsdp_axis: Optional[str] = None):
    """Pytree of PartitionSpecs matching ``params``.

    ``fsdp_axis``: additionally shard big params over the data axis
    (ZeRO-3-style) — required for the ≥30B archs to fit v5e HBM."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [_param_spec(path, leaf, mesh, model_axis, moe_sharding,
                         fsdp_axis)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_pspec(shape, mesh: Mesh, *, data_axes=("data",),
                model_axis: str = "model") -> P:
    """Shard an input batch leaf: batch dim over the data axes if divisible,
    else (decode with tiny batch) shard the next-largest dim — the
    sequence/capacity dim — over data, else replicate."""
    dsize = _axis_size(mesh, tuple(data_axes))
    ax = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
    nd = len(shape)
    if nd >= 1 and shape[0] % dsize == 0 and shape[0] >= dsize:
        return P(*((ax,) + (None,) * (nd - 1)))
    if nd >= 2 and shape[1] % dsize == 0 and shape[1] >= dsize:
        return P(*((None, ax) + (None,) * (nd - 2)))
    return P()


def tree_pspecs(tree, mesh: Mesh, *, data_axes=("data",)):
    """Batch-like pytrees (batches, caches, workset buffers)."""
    return jax.tree_util.tree_map(
        lambda leaf: batch_pspec(leaf.shape, mesh, data_axes=data_axes), tree)


def workset_pspecs(table, mesh: Mesh, *, data_axes=("data",)):
    """Ring-buffer tables (``core.workset``): every buf leaf carries a
    leading W slot axis — shard the per-instance batch dim (dim 1) over
    data, never the ring axis (a draw reads ONE slot; sharding W would
    turn every gather into a cross-device fetch).  This covers the
    quantized leaves transparently: ``QuantLeaf``/``Quant4Leaf`` codes
    (W, B, F or packed nibbles) and their (W, B) scales shard B the
    same way, so an int4 ring shards identically to the fp32 ring it
    replaces.  Clock vectors (W,) and scalars replicate."""
    dsize = _axis_size(mesh, tuple(data_axes))
    ax = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]

    def spec(leaf) -> P:
        nd = leaf.ndim
        if nd >= 2 and leaf.shape[1] % dsize == 0 and leaf.shape[1] >= dsize:
            return P(*((None, ax) + (None,) * (nd - 2)))
        return P()

    return jax.tree_util.tree_map(spec, table)


def opt_state_pspecs(opt_state, mesh: Mesh, *, data_axes=("data",)):
    """ZeRO-1-style specs for optimizer state, covering the quantized
    layouts (``optim.quantized``): a ``QuantAccum``'s int8 codes (R, C)
    and (R, 1) master scales shard the padded row dim over data (R is a
    multiple of the fused kernel's ROWS tiling, so it divides the usual
    data-axis sizes and every shard keeps whole requant rows — the
    row-max scale never crosses a device); fp32/bf16 accumulators shard
    their leading dim when divisible (the rule dryrun's ZeRO-1 path
    derives from ``params_pspecs``); SM3's factored row/col vectors,
    step counters, and other 1-D/scalar state replicate."""
    dsize = _axis_size(mesh, tuple(data_axes))
    ax = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]

    def spec(leaf) -> P:
        nd = leaf.ndim
        if nd >= 2 and leaf.shape[0] % dsize == 0 and leaf.shape[0] >= dsize:
            return P(*((ax,) + (None,) * (nd - 1)))
        return P()

    return jax.tree_util.tree_map(spec, opt_state)


def _cache_spec(path, leaf, mesh: Mesh, data_axes, model_axis: str) -> P:
    """KV/state cache leaves: stacked (L, B, cap, Kv, hd) etc.  Shard batch
    over data if divisible; shard Kv/heads over model if divisible; for
    B=1 long-context decode, shard the capacity dim over data instead."""
    name = _leaf_name(path)
    dsize = _axis_size(mesh, tuple(data_axes))
    msize = _axis_size(mesh, model_axis)
    ax = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
    nd = leaf.ndim
    parts: list = [None] * nd
    if name in ("k", "v"):          # (L, B, cap, Kv, hd)
        if nd >= 5:
            if leaf.shape[1] % dsize == 0:
                parts[1] = ax
            elif leaf.shape[2] % dsize == 0:
                parts[2] = ax
            if leaf.shape[3] % msize == 0:
                parts[3] = model_axis
            elif parts[2] is None and leaf.shape[2] % msize == 0:
                # GQA kv ∈ {5, 8} < 16 can't shard heads — shard the cache
                # sequence dim over `model` instead (partial-softmax decode,
                # flash-decoding style; XLA inserts the psum combine).
                parts[2] = model_axis
    elif name in ("h", "C", "n", "c", "m", "conv"):   # ssm / xlstm states
        if nd >= 2 and leaf.shape[1] % dsize == 0:
            parts[1] = ax
    return P(*parts)


def cache_pspecs(cache, mesh: Mesh, *, data_axes=("data",),
                 model_axis: str = "model"):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    specs = [_cache_spec(p, l, mesh, data_axes, model_axis) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def make_sharding(mesh: Mesh, pspec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspec_tree,
        is_leaf=lambda x: isinstance(x, P))
