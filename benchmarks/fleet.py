"""Fleet throughput: N CELU-VFL jobs as ONE compiled XLA program vs the
sequential Python-loop baseline -> ``results/BENCH_fleet.json``.

The claim behind ``repro.fleet``: host-side scheduling (one jit dispatch
per stage per round per job) is the tax that keeps a hyper-parameter
sweep from saturating a device, and moving the whole round schedule —
queue fill/merge decisions included — into a single vmapped program
amortizes it across hundreds of jobs.  The table measures, at fleet
sizes {1, 16, 128, 512}:

  * ``jobs_per_sec`` — completed jobs (fixed round budget + queue drain)
    per second of post-compile device wall.  Gated by
    ``benchmarks.compare`` as a wall metric (drift DOWN fails).
  * ``speedup_vs_sequential`` — fleet wall vs the sequential baseline:
    the same jobs run one-at-a-time through the scalar engine's jitted
    round (every distinct lr's round is compiled AND run once untimed
    before the clock starts — the baseline is never charged a compile,
    only per-round host dispatch).  Sequential wall is
    measured on ``SEQ_SAMPLE`` jobs and scaled linearly (the loop is
    embarrassingly job-parallel on the host side, so the extrapolation
    is exact up to allocator noise; the measured count is recorded).
    The ``--check`` gate (CI) requires >= {MIN_SPEEDUP}x at N=128.
  * ``round_wire_bytes`` — exact per-job per-round WAN bytes (the fleet
    must not change what crosses the wire: deterministic, any increase
    fails the gate).
  * ``indicative_compile_s`` — one-off trace+compile wall, excluded from
    the gate by the ``indicative_`` contract.

A ``fleet_depth2_n16`` variant exercises the traced exchange queue
(lax.cond merge/drain) rather than the straight-line depth-0 schedule.

    PYTHONPATH=src python -m benchmarks.fleet [--check] [--shard-smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CELUConfig
from repro.core import engine
from repro.data import synthetic as synth
from repro.fleet import FleetWorkload, JobSpec, run_fleet
from repro.models.tabular import DLRMConfig, make_dlrm
from repro.optim import make_optimizer

from .common import csv_row

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "BENCH_fleet.json")

FLEET_SIZES = (1, 16, 128, 512)
ROUNDS = 8                 # communication rounds per job (+ queue drain)
BATCH = 64
SEQ_SAMPLE = 8             # sequential-baseline jobs actually timed
MIN_SPEEDUP = 1.5          # --check floor on speedup_vs_sequential @ 128
# Why 1.5 and not higher: with the sequential baseline honestly warmed
# (no compiles in the timed loop) the measured win at N=128 is ~2.5x on
# a single-core dev box — the fleet's whole schedule is already ONE
# lax.scan'd program, so what remains is batched-op efficiency, not
# dispatch amortization.  The floor asserts "genuinely faster" with
# headroom for runner variance; the compare gate's 25% drift tolerance
# vs the committed baseline does the fine-grained ratcheting.
BASE = CELUConfig(R=3, W=3, xi_degrees=60.0)


def make_workload():
    """The golden-trace K=1 geometry: small enough that a 512-job fleet
    is a sweep, large enough that a round does real GEMM work."""
    spec = synth.TabularSpec("criteo", fields_a=4, fields_b=3, vocab=32,
                             n_train=2048, n_test=512)
    data = synth.make_tabular(spec, seed=0)
    cfg = DLRMConfig("wdl", 4, 3, vocab=32, embed_dim=4, z_dim=8,
                     hidden=(16, 8))
    init_fn, task, _ = make_dlrm(cfg)
    etask = engine.lift_two_party(task)
    asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}

    def params_for(seed):
        return engine.lift_two_party_params(
            init_fn(jax.random.PRNGKey(seed), cfg))

    def batch_stream():
        for bi, ba, bb in synth.aligned_batches(data["train"], BATCH,
                                                seed=0):
            yield bi, [asj(ba)], asj(bb)

    return FleetWorkload(etask, params_for, batch_stream)


def job_specs(n: int, depth: int = 0):
    """n jobs over a small lr x seed grid — traced knobs only, so the
    whole fleet is ONE cohort/compile."""
    ccfg, nloc = engine.preset_config("celu", BASE)
    lrs = (0.05, 0.03, 0.08, 0.02)
    return [JobSpec(celu=ccfg, local_steps=nloc, lr=lrs[j % len(lrs)],
                    seed=j, depth=depth) for j in range(n)]


def sequential_baseline(workload: FleetWorkload, rounds: int,
                        n_sample: int):
    """Per-job wall of the host-loop baseline: every distinct lr's jitted
    round is compiled AND executed once untimed, so the timed loop pays
    only python dispatch + device time, round by round — never an XLA
    compile."""
    ccfg, nloc = engine.preset_config("celu", BASE)
    specs = job_specs(n_sample)

    sched = []
    it = workload.batch_stream()
    for _ in range(rounds):
        bi, ba, bb = next(it)
        sched.append((bi, ba, bb))

    # lr is baked into the jitted round: a REAL sequential sweep
    # recompiles per distinct lr.  Be generous to the baseline: compile
    # every lr the sample will use and run one untimed warmup round
    # each, so the timed walls below are pure steady-state dispatch.
    rnd_cache = {}
    for spec in specs:
        if spec.lr in rnd_cache:
            continue
        opt = make_optimizer(spec.optimizer, spec.lr)
        rnd = engine.make_round(workload.task, opt, ccfg,
                                local_steps=spec.local_steps)
        state = engine.init_state(workload.task,
                                  workload.params_for(spec.seed), opt,
                                  ccfg, sched[0][1], sched[0][2])
        bi, ba, bb = sched[0]
        state, _ = rnd(state, ba, bb, bi)
        jax.block_until_ready(state)
        rnd_cache[spec.lr] = rnd

    walls = []
    for spec in specs:
        opt = make_optimizer(spec.optimizer, spec.lr)
        rnd = rnd_cache[spec.lr]
        state = engine.init_state(workload.task,
                                  workload.params_for(spec.seed), opt,
                                  ccfg, sched[0][1], sched[0][2])
        t0 = time.perf_counter()
        for bi, ba, bb in sched:
            state, m = rnd(state, ba, bb, bi)
        jax.block_until_ready(state)
        walls.append(time.perf_counter() - t0)
    return float(np.mean(walls))


def run_table(sizes=FLEET_SIZES, rounds=ROUNDS, seq_sample=SEQ_SAMPLE):
    wl = make_workload()
    per_job_seq = sequential_baseline(wl, rounds, seq_sample)
    csv_row(f"# fleet throughput: {rounds} rounds/job, sequential "
            f"baseline {per_job_seq * 1e3:.1f} ms/job "
            f"(measured on {seq_sample} jobs, scaled linearly)")
    csv_row("variant", "n_jobs", "fleet_wall_s", "jobs_per_sec",
            "speedup_vs_sequential", "indicative_compile_s")

    variants = {}

    def one(name, n, depth):
        res = run_fleet(job_specs(n, depth=depth), rounds, workload=wl,
                        mode="vmap")
        seq_wall = per_job_seq * n
        row = {
            "n_jobs": n,
            "rounds": rounds,
            "pipeline_depth": depth,
            "mode": res.mode,
            "n_cohorts": res.n_cohorts,
            "fleet_wall_s": round(res.wall_s, 4),
            "jobs_per_sec": round(n / res.wall_s, 2),
            "sequential_wall_s": round(seq_wall, 4),
            "speedup_vs_sequential": round(seq_wall / res.wall_s, 2),
            "round_wire_bytes": int(res.round_wire_bytes[0]),
            "indicative_compile_s": round(res.compile_s, 2),
        }
        variants[name] = row
        csv_row(name, n, row["fleet_wall_s"], row["jobs_per_sec"],
                f"{row['speedup_vs_sequential']}x",
                row["indicative_compile_s"])
        return row

    for n in sizes:
        one(f"fleet_n{n}", n, depth=0)
    # the traced exchange queue (lax.cond merge + conditional drain)
    one("fleet_depth2_n16", 16, depth=2)

    return {
        "geometry": {"model": "wdl", "dataset": "criteo-golden",
                     "batch": BATCH, "rounds": rounds,
                     "protocol": "celu", "R": BASE.R, "W": BASE.W,
                     "fleet_sizes": list(sizes)},
        "sequential": {"jobs_measured": seq_sample,
                       "per_job_wall_s": round(per_job_seq, 4),
                       "note": "jitted scalar round compiled and warmed "
                               "untimed per distinct lr; wall scaled "
                               "linearly to N"},
        "variants": variants,
    }


def shard_smoke(n: int = 16, rounds: int = 4) -> int:
    """CI fast-lane smoke: an N-job fleet SHARDED over the host's device
    grid (CI sets ``XLA_FLAGS=--xla_force_host_platform_device_count``
    in this step's environment).  Verifies the job axis actually
    distributes: every lane finite, grid size > 1."""
    ndev = len(jax.devices())
    wl = make_workload()
    res = run_fleet(job_specs(n), rounds, workload=wl, mode="vmap",
                    shard=True)
    ok = bool(np.isfinite(res.losses).all())
    csv_row(f"# fleet shard smoke: {n} jobs over {ndev} host devices, "
            f"{rounds} rounds -> {'OK' if ok else 'NON-FINITE LOSSES'}")
    if ndev < 2:
        csv_row("# WARNING: single-device grid — set XLA_FLAGS="
                "--xla_force_host_platform_device_count before python "
                "starts to exercise a real fleet mesh")
        return 1
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", type=int, nargs="+", default=None,
                    help=f"fleet sizes (default {list(FLEET_SIZES)})")
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--check", action="store_true",
                    help=f"exit non-zero if speedup_vs_sequential at "
                         f"N=128 drops below {MIN_SPEEDUP}x")
    ap.add_argument("--shard-smoke", action="store_true",
                    help="run ONLY the sharded fleet smoke (N=16 over "
                         "the current host device grid) and exit")
    args = ap.parse_args(argv)
    if args.shard_smoke:
        return shard_smoke()

    sizes = tuple(args.sizes) if args.sizes else FLEET_SIZES
    out = run_table(sizes=sizes, rounds=args.rounds)
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    csv_row(f"# wrote {os.path.normpath(RESULTS)}")

    if args.check:
        key = "fleet_n128"
        if key not in out["variants"]:
            print(f"[FAIL] --check needs fleet size 128 in --sizes")
            return 1
        sp = out["variants"][key]["speedup_vs_sequential"]
        if sp < MIN_SPEEDUP:
            print(f"[FAIL] {key}.speedup_vs_sequential = {sp}x < "
                  f"{MIN_SPEEDUP}x floor")
            return 1
        print(f"fleet gate: OK ({key} {sp}x >= {MIN_SPEEDUP}x)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
