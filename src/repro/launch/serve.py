"""Serving driver: continuous-batching split-model serving on synthetic
open-loop traffic (see docs/SERVING.md).

Thin CLI over :class:`repro.serve.ServeEngine`: builds the seeded load
(``repro.serve.loadgen``), serves it through the fixed-capacity lane
array with the compressed uplink and the quantized decode activation
ring, and prints the production-shaped numbers — requests/sec,
tokens/sec, p50/p99 token latency, exact wire bytes per token.
Token-aligned (fusion="add") archs run the engine; cross-attention
families (vlm / audio) exchange their memory once at prefill and decode
entirely on Party B, so they fall back to the sequential
:func:`repro.serve.naive_generate` loop (reported as such).

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \\
      --requests 32 --capacity 8 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import vfl
from ..serve import (LoadSpec, ServeConfig, ServeEngine, make_naive_fns,
                     naive_generate, synth_requests)


def _percentiles(comps):
    lats = []
    for c in comps:
        prev = c.arrival
        for t in c.token_times:
            lats.append(t - prev)
            prev = t
    ms = 1e3 * np.asarray(lats)
    return float(np.percentile(ms, 50)), float(np.percentile(ms, 99))


def serve_engine(args, cfg, params):
    scfg = ServeConfig(capacity=args.capacity, prompt_len=args.prompt_len,
                       max_new_tokens=args.gen,
                       compression="" if args.fp32_wire else "int8",
                       cache_dtype=args.cache_dtype,
                       refresh_every=args.refresh_every, seed=args.seed)
    spec = LoadSpec(n_requests=args.requests, rate=args.rate,
                    prompt_len=args.prompt_len, max_new_tokens=args.gen,
                    min_new_tokens=max(1, args.gen // 4), seed=args.seed)
    eng = ServeEngine(params, cfg, scfg)
    t0 = time.perf_counter()
    eng.warm()
    print(f"warm (compile) {time.perf_counter() - t0:.1f} s")
    comps, stats = eng.run(synth_requests(spec, cfg))

    n_tok = stats["total_tokens"]
    dur = stats["virtual_duration_s"]
    p50, p99 = _percentiles(comps)
    up, down = stats["wire_up_bytes"], stats["wire_down_bytes"]
    print(f"arch={cfg.name} capacity={scfg.capacity} "
          f"wire={scfg.compression or 'fp32'} ring={scfg.cache_dtype} "
          f"R={scfg.refresh_every}")
    print(f"{stats['n_requests']} requests, {n_tok} tokens in {dur:.2f} s "
          f"(virtual) -> {stats['n_requests'] / dur:.1f} req/s, "
          f"{n_tok / dur:.0f} tok/s")
    print(f"p50 {p50:.2f} ms/token | p99 {p99:.2f} ms/token")
    print(f"wire: {up} B up + {down} B down = {(up + down) / n_tok:.1f} "
          f"B/token ({eng.step_up_bytes} B per decode uplink row)")
    print("first request's token ids:", comps[0].tokens[:16])
    return comps


def serve_naive(args, cfg, params):
    """Sequential fallback for cross-attn families: the cut memory
    crosses once at prefill; decode is Party-B-local."""
    B, S = 1, args.prompt_len
    rng = np.random.default_rng(args.seed)
    fns = make_naive_fns(cfg, S + args.gen)
    batch0 = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32))}
    if cfg.family == "vlm":
        batch0["patches"] = jnp.asarray(rng.normal(
            size=(B, cfg.n_patches, cfg.d_frontend)).astype(np.float32))
    else:
        batch0["frames"] = jnp.asarray(rng.normal(
            size=(B, S, cfg.d_frontend)).astype(np.float32))
    naive_generate(params, cfg, batch0, args.gen, fns=fns)  # warm
    walls = []
    toks = None
    for _ in range(args.requests):
        t0 = time.perf_counter()
        toks = naive_generate(params, cfg, batch0, args.gen, fns=fns)
        jax.block_until_ready(toks)
        walls.append(time.perf_counter() - t0)
    total = sum(walls)
    print(f"arch={cfg.name} ({cfg.family}): cross-attn family — memory "
          f"crosses once at prefill; sequential naive_generate loop")
    print(f"{args.requests} requests x {args.gen} tokens in {total:.2f} s "
          f"-> {args.requests * args.gen / total:.0f} tok/s, "
          f"{total / args.requests / args.gen * 1e3:.1f} ms/token")
    print("generated token ids (first request):",
          np.asarray(toks)[0][:16])


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="open-loop arrival rate in req/s (0 = closed "
                         "burst)")
    ap.add_argument("--capacity", type=int, default=8,
                    help="concurrent decode lanes")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cache-dtype", default="int8",
                    choices=("float32", "bfloat16", "int8", "int4"),
                    help="decode activation ring at-rest storage")
    ap.add_argument("--refresh-every", type=int, default=1,
                    help="uplink cadence R: exchange every R-th decode "
                         "step, serve Party B from the stale ring row in "
                         "between")
    ap.add_argument("--fp32-wire", action="store_true",
                    help="identity uplink codec (bit-exact vs the "
                         "sequential loop) instead of int8")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="full config (do NOT use on CPU)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    params = vfl.init_all(jax.random.PRNGKey(args.seed), cfg)
    if cfg.vfl_split.fusion == "add":
        serve_engine(args, cfg, params)
    else:
        serve_naive(args, cfg, params)


if __name__ == "__main__":
    main()
