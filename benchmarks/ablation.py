"""Paper Table 2 / Figure 5: ablations over R (local updates), W (workset
size / sampling strategy), and ξ (instance weighting threshold).

Each block reproduces one Table-2 row group: communication rounds required
to reach a shared target AUC, relative to the no-technique baseline.
"""
from __future__ import annotations

from .common import csv_row, default_workload, rounds_to, run_protocol

ROUNDS = 700
LR = 0.003
TARGET_FRACTION = 0.97   # target = frac * best vanilla AUC (self-calibrated)


def _target(data, cfg) -> float:
    base = run_protocol("vanilla", data, cfg, rounds=ROUNDS, lr=LR)
    return TARGET_FRACTION * base["best_auc"], base


def bench_local_update(data, cfg, target, base):
    """Vary R at fixed W=5, ξ=60° (Table 2 block 1).

    Savings are a PROFILE over target quality: on a workload that converges
    ~25x faster than the paper's 41M-row stream, local updates buy the most
    in the far-from-converged region (where the paper's targets sit); near
    this task's saturation AdaGrad's step-count-driven lr decay evens the
    protocols out.  Reported at 88% / 95% / 98.5% of vanilla's best AUC."""
    fracs = (0.88, 0.95, 0.985)
    targets = [f * base["best_auc"] for f in fracs]
    csv_row("# local_update: rounds-to-target profile "
            "(targets = %s of vanilla best)" %
            "/".join(f"{f:.1%}" for f in fracs))
    csv_row("setting", *[f"rounds@{t:.3f}" for t in targets], "final_auc")
    runs = {"vanilla(R=1)": base}
    for R in (3, 5, 8):
        runs[f"celu(R={R})"] = run_protocol(
            "celu", data, cfg, R=R, W=5, xi=60.0, rounds=ROUNDS, lr=LR)
    base_rounds = [rounds_to(base["curve"], t) or ROUNDS for t in targets]
    for name, r in runs.items():
        cells = []
        for t, b in zip(targets, base_rounds):
            rt = rounds_to(r["curve"], t) or ROUNDS
            cells.append(f"{rt} ({100 * (1 - rt / b):+.0f}%)")
        csv_row(name, *cells, f"{r['final_auc']:.4f}")


STRESS_LR = 0.01   # higher lr + R=8: staleness errors actually bite
STRESS_R = 8


def bench_local_sampling(data, cfg, target, base):
    """W=1 consecutive (FedBCD-style) vs round-robin W>1 (Table 2 blk 2).

    Run in the stressed-staleness regime (lr=0.01, R=8) where repetitive
    sampling measurably accumulates variance (paper Fig 3/5b); quality
    metric is best AUC reached (the curves plateau differently)."""
    csv_row(f"# local_sampling: R={STRESS_R}, xi=60, lr={STRESS_LR}")
    csv_row("setting", "best_auc", "final_auc")
    r1 = run_protocol("celu", data, cfg, R=STRESS_R, W=1, xi=60.0,
                      sampling="consecutive", rounds=ROUNDS, lr=STRESS_LR,
                      eval_every=10)
    csv_row("consecutive(W=1)", f"{r1['best_auc']:.4f}",
            f"{r1['final_auc']:.4f}")
    for W in (3, 5, 8):
        r = run_protocol("celu", data, cfg, R=STRESS_R, W=W, xi=60.0,
                         rounds=ROUNDS, lr=STRESS_LR, eval_every=10)
        csv_row(f"round_robin(W={W})", f"{r['best_auc']:.4f}",
                f"{r['final_auc']:.4f}")


def bench_instance_weighting(data, cfg, target, base):
    """No-weights vs ξ ∈ {90°, 60°, 30°} at (W,R)=(5,8), stressed regime
    (Table 2 blk 3 — weighting matters when staleness errors are large)."""
    csv_row(f"# instance_weighting: W=5, R={STRESS_R}, lr={STRESS_LR}")
    csv_row("setting", "best_auc", "final_auc")
    r0 = run_protocol("celu", data, cfg, R=STRESS_R, W=5, weighting=False,
                      rounds=ROUNDS, lr=STRESS_LR, eval_every=10)
    csv_row("no_weights", f"{r0['best_auc']:.4f}", f"{r0['final_auc']:.4f}")
    for xi in (90.0, 60.0, 30.0):
        r = run_protocol("celu", data, cfg, R=STRESS_R, W=5, xi=xi,
                         rounds=ROUNDS, lr=STRESS_LR, eval_every=10)
        csv_row(f"xi={int(xi)}", f"{r['best_auc']:.4f}",
                f"{r['final_auc']:.4f}")


BLOCKS = {
    "local_update": bench_local_update,
    "local_sampling": bench_local_sampling,
    "instance_weighting": bench_instance_weighting,
}


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--block", default="all",
                    choices=("all",) + tuple(BLOCKS),
                    help="run one Table-2 block instead of all three")
    args = ap.parse_args(argv)
    spec, data, cfg = default_workload("wdl", "criteo")
    target, base = _target(data, cfg)
    for name, fn in BLOCKS.items():
        if args.block in ("all", name):
            fn(data, cfg, target, base)


if __name__ == "__main__":
    main()
