"""Two-party VFL protocols: Vanilla, FedBCD, CELU-VFL (paper Section 3).

This module is now a thin two-party preset over :mod:`repro.core.engine` —
the single K-party round engine that owns exchange, workset insert/sample,
Algorithm-2 weighting, and the local-update scan.  The public API
(``VFLTask`` / ``init_state`` / ``make_round`` / ``protocol_config`` /
``exchange_bytes``) and the top-level state structure
(``params/opt/ws/steps`` keyed ``"a"``/``"b"`` with scalar step counters)
are unchanged from the original implementation — only the workset
ring-buffer entry keys moved to the engine's generic schema (``"z"`` /
``"dz"`` instead of ``"z_a"`` / ``"dz_a"``; B's slots hold K-lists).
``tests/test_engine.py`` pins the engine's K=1 path against golden traces
recorded from the pre-engine implementation.

A *task* is the minimal two-party interface (information-flow discipline is
kept at function granularity — no function sees both parties' raw data):

    forward_a(params_a, batch_a) -> Z_A
    loss_b(params_b, z_a, batch_b) -> (per_instance_loss (B,), aux_scalar)

One **communication round** exchanges ⟨Z_A, ∇Z_A⟩ once (also performing a
plain SGD step — the "fresh" update) and then runs up to ``R`` *local
updates* per party from its workset table, with round-robin sampling and
staleness-aware instance weighting (Algorithms 1-2):

  * Vanilla  = rounds with R=0 (exchange every step);
  * FedBCD   = consecutive sampling (W=1 semantics) + no weighting;
  * CELU-VFL = round-robin sampling over W slots + cosine weighting.

Communication accounting: each round moves ``bytes(Z_A) + bytes(∇Z_A)``
across the slow link (``engine.SimWANTransport``); the simulated-WAN
wall-clock model used by the benchmarks is ``t_round = bytes / bandwidth +
2 * latency`` (Section 2.1's 213 ms example reproduces with
bandwidth=300 Mbps).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import CELUConfig
from ..optim import Optimizer
from . import engine


class VFLTask(NamedTuple):
    """Two-party split model interface (see module docstring)."""
    forward_a: Callable[[Any, Dict[str, Any]], jnp.ndarray]
    loss_b: Callable[[Any, jnp.ndarray, Dict[str, Any]],
                     Tuple[jnp.ndarray, jnp.ndarray]]


# --------------------------------------------------------------------------
# State (two-party layout <-> engine K=1 layout)
# --------------------------------------------------------------------------
def _to_engine(state):
    return {
        "params": {"a": [state["params"]["a"]], "b": state["params"]["b"]},
        "opt": {"a": [state["opt"]["a"]], "b": state["opt"]["b"]},
        "ws": {"a": [state["ws"]["a"]], "b": state["ws"]["b"]},
        "steps": {"a": [state["steps"]["a"]], "b": state["steps"]["b"]},
        "comm_rounds": state["comm_rounds"],
        "transport": state.get("transport", {}),
    }


def _from_engine(st):
    return {
        "params": {"a": st["params"]["a"][0], "b": st["params"]["b"]},
        "opt": {"a": st["opt"]["a"][0], "b": st["opt"]["b"]},
        "ws": {"a": st["ws"]["a"][0], "b": st["ws"]["b"]},
        "steps": {"a": st["steps"]["a"][0], "b": st["steps"]["b"]},
        "comm_rounds": st["comm_rounds"],
        "transport": st.get("transport", {}),
    }


def init_state(task: VFLTask, params: Dict[str, Any], opt: Optimizer,
               celu: CELUConfig, batch_a: Dict[str, Any],
               batch_b: Dict[str, Any], transport=None, compression=None):
    """Build the full training state.  ``batch_a/b`` are example (abstract ok)
    batches used to size the workset ring buffers;
    ``transport``/``compression`` must mirror :func:`make_round`'s (error
    feedback residuals live in the state)."""
    st = engine.init_state(engine.lift_two_party(task),
                           engine.lift_two_party_params(params),
                           opt, celu, [batch_a], batch_b,
                           transport=transport, compression=compression)
    return _from_engine(st)


def exchange_bytes(z_shape, dtype_bytes: int = 4,
                   wire_dtype: str = "float32") -> int:
    """Bytes moved per communication round (Z_A + ∇Z_A).  The paper sends
    fp32; the beyond-paper bf16 wire halves it."""
    import numpy as np
    if not wire_dtype:
        return 2 * int(np.prod(z_shape)) * dtype_bytes
    tp = engine.SimWANTransport(CELUConfig(wire_dtype=wire_dtype))
    return tp.round_bytes([z_shape])


# --------------------------------------------------------------------------
# One full communication round (exchange + R local updates per party)
# --------------------------------------------------------------------------
def make_round(task: VFLTask, opt: Optimizer, celu: CELUConfig,
               *, local_steps: int = -1, jit: bool = True,
               fused_weighting: bool = True, transport=None,
               compression=None):
    """fn(state, batch_a, batch_b, batch_idx) -> (state, metrics).

    ``local_steps`` defaults to R (steady state: one fresh insert funds R
    uses).  Vanilla training = ``local_steps=0``.  ``compression`` names a
    wire codec (``core.compression.CODEC_SPECS``) when no explicit
    ``transport`` is given."""
    eng = engine.make_round(engine.lift_two_party(task), opt, celu,
                            local_steps=local_steps, transport=transport,
                            compression=compression,
                            fused_weighting=fused_weighting, jit=False)

    def round_fn(state, batch_a, batch_b, batch_idx):
        st, m = eng(_to_engine(state), [batch_a], batch_b, batch_idx)
        return _from_engine(st), m

    return jax.jit(round_fn, donate_argnums=(0,)) if jit else round_fn


# --------------------------------------------------------------------------
# Named protocol presets (the paper's three competitors)
# --------------------------------------------------------------------------
protocol_config = engine.preset_config
