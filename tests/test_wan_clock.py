"""WANClock edge cases (satellite of the boundary-auditor PR): zero-RTT
links, asymmetric bandwidths, the occupancy-dominated deep-queue regime,
and depth-0/1 schedule continuity."""
import pytest

from repro.configs.base import CELUConfig
from repro.core.engine import make_transport
from repro.launch.wan import (DEFAULT_CLOCK, WANClock,
                              transport_round_updown, wan_seconds)

MB = 1e6


def test_zero_rtt_wire_is_pure_bandwidth():
    clk = WANClock(up_bandwidth=10 * MB, down_bandwidth=10 * MB,
                   latency=0.0)
    assert clk.rtt == 0.0
    assert clk.wire_seconds(10 * MB, 0.0) == pytest.approx(1.0)
    assert clk.wire_seconds(10 * MB, 5 * MB) == pytest.approx(1.5)
    # zero bytes on a zero-latency link costs nothing
    assert clk.wire_seconds(0.0, 0.0) == 0.0


def test_asymmetric_bandwidths_charge_each_leg_separately():
    clk = WANClock(up_bandwidth=1 * MB, down_bandwidth=10 * MB,
                   latency=0.0)
    # same bytes, 10x slower uplink: the up leg dominates
    assert clk.up_seconds(2 * MB) == pytest.approx(2.0)
    assert clk.down_seconds(2 * MB) == pytest.approx(0.2)
    assert clk.wire_seconds(2 * MB, 2 * MB) == pytest.approx(2.2)
    # a symmetric clock at the slow rate would overcharge the downlink
    sym = clk.with_bandwidth(1 * MB)
    assert sym.down_bandwidth == 1 * MB
    assert sym.wire_seconds(2 * MB, 2 * MB) == pytest.approx(4.0)


def test_with_bandwidth_defaults_down_to_up():
    clk = DEFAULT_CLOCK.with_bandwidth(5 * MB, 1 * MB)
    assert clk.up_bandwidth == 5 * MB
    assert clk.down_bandwidth == 1 * MB
    assert clk.latency == DEFAULT_CLOCK.latency   # preserved


def test_occupancy_dominates_deep_queue():
    # big wire, cheap compute, deep queue: amortizing the exchange over
    # D rounds cannot beat the serial link occupancy — each round still
    # pushes one exchange's bytes through the shared link
    clk = WANClock(up_bandwidth=10 * MB, down_bandwidth=10 * MB,
                   latency=0.01)
    up = down = 80 * MB                       # 8 s per leg
    occupancy = 16.0
    for depth in (4, 8, 64):
        r = clk.round_seconds(up, down, exchange_compute_s=0.1,
                              local_compute_s=1.0, pipeline_depth=depth)
        assert r == pytest.approx(occupancy), depth
    # shallow queue: the per-exchange window dominates instead
    r1 = clk.round_seconds(up, down, exchange_compute_s=0.1,
                           local_compute_s=1.0, pipeline_depth=1)
    assert r1 == pytest.approx(0.1 + clk.wire_seconds(up, down))


def test_depth0_depth1_continuity_when_local_is_free():
    # with no local compute and no exchange compute, depth 1 hides
    # nothing: both schedules pay exactly the wire
    clk = WANClock(up_bandwidth=10 * MB, down_bandwidth=10 * MB,
                   latency=0.0)
    up, down = 10 * MB, 10 * MB
    d0 = clk.round_seconds(up, down, pipeline_depth=0)
    d1 = clk.round_seconds(up, down, pipeline_depth=1)
    assert d0 == pytest.approx(d1) == pytest.approx(2.0)


def test_depth1_is_paper_max_of_exchange_and_local():
    clk = WANClock(up_bandwidth=10 * MB, down_bandwidth=10 * MB,
                   latency=0.01)
    for ex, loc in [(0.0, 0.0), (0.5, 0.1), (0.1, 50.0), (2.0, 2.0)]:
        got = clk.round_seconds(MB, MB, exchange_compute_s=ex,
                                local_compute_s=loc, pipeline_depth=1)
        want = max(ex + clk.wire_seconds(MB, MB), loc)
        assert got == pytest.approx(want), (ex, loc)


def test_zero_wire_round_is_pure_compute():
    clk = WANClock(latency=0.0)
    assert clk.round_seconds(0.0, 0.0, exchange_compute_s=0.3,
                             local_compute_s=0.7) == pytest.approx(1.0)
    # depth-D with nothing on the wire: the local worker is the period
    assert clk.round_seconds(0.0, 0.0, exchange_compute_s=0.0,
                             local_compute_s=0.7,
                             pipeline_depth=3) == pytest.approx(0.7)


def test_time_to_target_scales_linearly():
    clk = WANClock(latency=0.0, up_bandwidth=MB, down_bandwidth=MB)
    one = clk.round_seconds(MB, MB, local_compute_s=0.5)
    assert clk.time_to_target(10, MB, MB, local_compute_s=0.5) == \
        pytest.approx(10 * one)


def test_transport_round_updown_matches_round_bytes():
    tp = make_transport(CELUConfig(compression="int8_topk"))
    z_shapes = [(64, 8), (64, 8)]
    up, down = transport_round_updown(tp, z_shapes)
    assert up + down == tp.round_bytes(z_shapes)
    # int8_topk is the asymmetric pair: the split must differ
    assert up != down
    assert wan_seconds(up, down) == DEFAULT_CLOCK.wire_seconds(up, down)
