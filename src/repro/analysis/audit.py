"""The audit orchestrator: build round traces for every supported config
and run the three invariant families over each.

Per audited case this module:

  1. builds a small but structurally faithful K-party task (real
     ``KPartyTask``, real ``init_state``, real ``_make_stages`` with the
     production transport/codec/cache path — only the model is tiny);
  2. composes the stages in the ORDER the schedule under audit executes
     them — depth 0 sequential, depth 1 static-staleness overlap, depth
     D >= 2 as two CHAINED exchange dispatches (the ``PendingExchange``
     queue's residual chain) plus dynamic-staleness scan/merge — and
     traces the composition to one jaxpr under
     :func:`markers.instrumented`;
  3. walks the jaxpr with the taint engine (``taint.py``), reconciles
     the byte ledger (``wire_audit.py``), and lints the engine's fused
     kernel promises at the audited geometry (``kernel_lint.py``).

Input taints: each party's params / optimizer state / raw batch / cached
features are that party's raw sources; workset ``z``/``dz`` rings hold
already-released messages (untainted); error-feedback residuals are raw
to their owner and enter pre-seeded with the ``wire`` stage — they are
differences of wire-cast values by construction (every registered send
path maintains that invariant), and without the seed every stateful
codec path would false-positive on its first re-encode.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Any, Dict, List, Optional, Sequence

from .report import AuditReport, CaseResult, Finding
from .taint import EMPTY, OutTag, Taint, TraceAudit, audit_trace, raw_of

AUDIT_B = 64        # audited batch geometry (fusable: 64 | BLOCK_B)
AUDIT_Z = 8         # cut-layer width
AUDIT_FA = 6        # feature-party input width
AUDIT_FB = 5        # label-party own-feature width


@dataclass(frozen=True)
class AuditCase:
    name: str
    K: int = 1
    depth: int = 0
    compression: str = ""
    cache_dtype: str = "float32"
    dp_sigma: float = 0.0
    wire_dtype: str = "float32"
    # chaos-layer schedule: the first dispatch's wire transfer is LOST
    # and the transport's recover_dropped folds its decoded messages back
    # into the error-feedback residuals before the next dispatch
    # (core/faults.py) — the audit proves the absorbed residuals still
    # clear the boundary theorem on the retransmission
    dropped: bool = False


def default_cases(quick: bool = False) -> List[AuditCase]:
    """The supported-config matrix, factorized so every axis value is
    covered without the full cross product: codec x DP at depth 0, depth
    x K at the heaviest codec, cache dtypes at depth 2, wire dtype."""
    from ..core.compression import CODEC_SPECS

    def mk(**kw):
        kw.setdefault("name", "-".join(
            [f"K{kw.get('K', 1)}", f"d{kw.get('depth', 0)}",
             kw.get("compression") or "wire",
             kw.get("cache_dtype", "float32"),
             f"dp{kw.get('dp_sigma', 0.0):g}",
             ] + ([kw["wire_dtype"]] if kw.get("wire_dtype",
                                               "float32") != "float32"
                  else [])
               + (["drop"] if kw.get("dropped") else [])))
        return AuditCase(**kw)

    if quick:
        return [mk(), mk(compression="topk_int8", dp_sigma=0.3, depth=2,
                         cache_dtype="int8"),
                mk(compression="topk_int8", dp_sigma=0.3, depth=2,
                   cache_dtype="int8", dropped=True),
                mk(depth=2, compression="int8", cache_dtype="int4"),
                mk(compression="int8", wire_dtype="bfloat16")]

    cases = []
    for spec in ("",) + tuple(CODEC_SPECS):
        for dp in (0.0, 0.3):
            cases.append(mk(compression=spec, dp_sigma=dp))
    for K in (1, 3):
        for depth in (0, 1, 2, 4):
            cases.append(mk(K=K, depth=depth, compression="topk_int8",
                            cache_dtype="int8", dp_sigma=0.3))
    for cd in ("float32", "bfloat16", "int8", "int4"):
        cases.append(mk(depth=2, compression="int8", cache_dtype=cd))
    # int4 at-rest rides the packed-nibble fused sample path; cover it at
    # K > 1 and under the chaos drop-absorb schedule too
    cases.append(mk(K=3, depth=2, compression="topk_int8",
                    cache_dtype="int4", dp_sigma=0.3))
    cases.append(mk(depth=2, compression="topk_int8", cache_dtype="int4",
                    dropped=True))
    for spec in ("", "int8"):
        cases.append(mk(compression=spec, wire_dtype="bfloat16"))
    # chaos layer: lost exchange absorbed into the residuals, with and
    # without DP noise riding the dropped messages, at both K widths
    for K in (1, 3):
        cases.append(mk(K=K, depth=2, compression="topk_int8",
                        cache_dtype="int8", dp_sigma=0.3, dropped=True))
    cases.append(mk(depth=2, compression="topk_int8", dropped=True))
    # dedupe (the sweeps overlap at the origin), keep first occurrence
    seen, out = set(), []
    for c in cases:
        if c.name not in seen:
            seen.add(c.name)
            out.append(c)
    return out


# --------------------------------------------------------------------------
# Toy-but-faithful K-party task
# --------------------------------------------------------------------------
def _toy_task(K: int):
    import jax.numpy as jnp

    from ..core import engine as E

    def forward_a(p, batch):
        return jnp.tanh(batch["x"] @ p["w"] + p["b"])

    def loss_b(p, z_list, batch):
        own = jnp.tanh(batch["x"] @ p["w_own"])
        h = jnp.concatenate(list(z_list) + [own], axis=1)
        logits = (h @ p["w_top"])[:, 0]
        y = batch["y"]
        li = jnp.maximum(logits, 0.0) - logits * y + \
            jnp.log1p(jnp.exp(-jnp.abs(logits)))
        return li, jnp.float32(0.0)

    task = E.KPartyTask(forward_a, loss_b)
    params = {
        "a": [{"w": jnp.zeros((AUDIT_FA, AUDIT_Z)),
               "b": jnp.zeros((AUDIT_Z,))} for _ in range(K)],
        "b": {"w_own": jnp.zeros((AUDIT_FB, AUDIT_Z)),
              "w_top": jnp.zeros(((K + 1) * AUDIT_Z, 1))},
    }
    batches_a = [{"x": jnp.zeros((AUDIT_B, AUDIT_FA))} for _ in range(K)]
    batch_b = {"x": jnp.zeros((AUDIT_B, AUDIT_FB)),
               "y": jnp.zeros((AUDIT_B,))}
    return task, params, batches_a, batch_b


# --------------------------------------------------------------------------
# Input / output tag trees
# --------------------------------------------------------------------------
def _const(tree, taint):
    import jax
    return jax.tree_util.tree_map(lambda _: taint, tree)


def _ws_tags(ws, batch_taint: Taint):
    """Workset rings hold RELEASED z/dz messages (untainted) plus the
    owner's raw batch; the clocks are public."""
    tags = {k: _const(v, EMPTY) for k, v in ws.items() if k != "buf"}
    tags["buf"] = {k: _const(sub, batch_taint if k == "batch" else EMPTY)
                   for k, sub in ws["buf"].items()}
    return tags


def _residual_seed(party: str) -> Taint:
    # raw to the owner, pre-seeded with the wire stage (see module doc)
    return Taint(raw=frozenset({party}), san=(("wire", 0),))


def _transport_tags(tstate, K: int):
    tags: Dict[str, Any] = {}
    for d, lst in tstate.items():
        owners = [f"a{i}" for i in range(K)] if d == "up" else ["b"] * K
        tags[d] = [_const(lst[i], _residual_seed(owners[i]))
                   for i in range(len(lst))]
    return tags


def _state_tags(state, K: int):
    A = [raw_of(f"a{i}") for i in range(K)]
    b = raw_of("b")
    return {
        "params": {"a": [_const(state["params"]["a"][i], A[i])
                         for i in range(K)],
                   "b": _const(state["params"]["b"], b)},
        "opt": {"a": [_const(state["opt"]["a"][i], A[i])
                      for i in range(K)],
                "b": _const(state["opt"]["b"], b)},
        "ws": {"a": [_ws_tags(state["ws"]["a"][i], A[i])
                     for i in range(K)],
               "b": _ws_tags(state["ws"]["b"], b)},
        "steps": _const(state["steps"], EMPTY),
        "comm_rounds": EMPTY,
        "transport": _transport_tags(state["transport"], K),
    }


_PUBLIC = frozenset()


def _out_state_tags(st_sds, K: int):
    A = [frozenset({f"a{i}"}) for i in range(K)]
    b = frozenset({"b"})

    def reg(tree, allowed, label):
        import jax
        return jax.tree_util.tree_map(lambda _: OutTag(allowed, label),
                                      tree)

    tp_tags: Dict[str, Any] = {}
    for d, lst in st_sds["transport"].items():
        owners = A if d == "up" else [b] * K
        tp_tags[d] = [reg(lst[i], owners[i], f"state.transport.{d}[{i}]")
                      for i in range(len(lst))]
    return {
        "params": {"a": [reg(st_sds["params"]["a"][i], A[i],
                             f"state.params.a[{i}]") for i in range(K)],
                   "b": reg(st_sds["params"]["b"], b, "state.params.b")},
        "opt": {"a": [reg(st_sds["opt"]["a"][i], A[i],
                          f"state.opt.a[{i}]") for i in range(K)],
                "b": reg(st_sds["opt"]["b"], b, "state.opt.b")},
        "ws": {"a": [reg(st_sds["ws"]["a"][i], A[i], f"state.ws.a[{i}]")
                     for i in range(K)],
               "b": reg(st_sds["ws"]["b"], b, "state.ws.b")},
        "steps": reg(st_sds["steps"], _PUBLIC, "state.steps"),
        "comm_rounds": reg(st_sds["comm_rounds"], _PUBLIC,
                           "state.comm_rounds"),
        "transport": tp_tags,
    }


# w_mean / w_zero_frac aggregate per-party weight statistics across ALL
# parties by design (sim-level diagnostics) — host rule skipped (None).
_METRIC_ALLOWED = {"loss": frozenset({"b"}), "local_steps": _PUBLIC,
                   "w_mean": None, "w_zero_frac": None}


def _out_metric_tags(m_sds):
    import jax
    return {k: jax.tree_util.tree_map(
        lambda _: OutTag(_METRIC_ALLOWED.get(k, None), f"metrics.{k}"),
        v) for k, v in m_sds.items()}


# --------------------------------------------------------------------------
# One case
# --------------------------------------------------------------------------
def _make_celu(case: AuditCase):
    from ..configs.base import CELUConfig
    return CELUConfig(R=2, W=5, compression=case.compression,
                      cache_dtype=case.cache_dtype,
                      dp_sigma=case.dp_sigma,
                      wire_dtype=case.wire_dtype,
                      pipeline_depth=case.depth)


def _compose(case: AuditCase, stages, tp=None):
    """Wire the three stages in the order the schedule under audit runs
    them.  Depth >= 2 chains TWO exchange dispatches through the
    transport-residual state — the PendingExchange queue slots — and
    drives scan/apply with dynamic staleness scalars, exactly like
    ``PipelinedEngine`` does.  ``case.dropped`` (needs ``tp`` and depth
    >= 2) audits the chaos layer's drop-absorb path instead: the first
    dispatch's wire transfer is lost, ``tp.recover_dropped`` folds its
    decoded messages back into the residuals, and only the SECOND
    dispatch is merged — the scan rides stale cached statistics the
    whole time.  Both dispatches still count as wire sends (the bytes
    left the box before the loss)."""
    import jax.numpy as jnp
    compute, apply_, scan = stages
    depth = case.depth

    if case.dropped:
        if depth < 2 or tp is None:
            raise ValueError("dropped cases need depth >= 2 and the "
                             "audited transport")

        def fn(state, batches_a, batch_b, batch_idx):
            f1 = compute(state["params"], state["transport"], batches_a,
                         batch_b, state["comm_rounds"])
            ts = tp.recover_dropped(f1)          # f1's wire is LOST
            f2 = compute(state["params"], ts, batches_a, batch_b,
                         state["comm_rounds"] + 1)
            state, lm = scan(state, jnp.int32(depth))
            state, m = apply_(state, f2, batches_a, batch_b,
                              batch_idx + 1, jnp.int32(depth - 1))
            return state, {**m, **lm}
        return fn, 2

    if depth == 0:
        def fn(state, batches_a, batch_b, batch_idx):
            fresh = compute(state["params"], state["transport"],
                            batches_a, batch_b, state["comm_rounds"])
            state, m = apply_(state, fresh, batches_a, batch_b, batch_idx)
            state, lm = scan(state)
            return state, {**m, **lm}
        return fn, 1

    if depth == 1:
        def fn(state, batches_a, batch_b, batch_idx):
            fresh = compute(state["params"], state["transport"],
                            batches_a, batch_b, state["comm_rounds"])
            state, lm = scan(state)
            state, m = apply_(state, fresh, batches_a, batch_b, batch_idx)
            return state, {**m, **lm}
        return fn, 1

    def fn(state, batches_a, batch_b, batch_idx):
        f1 = compute(state["params"], state["transport"], batches_a,
                     batch_b, state["comm_rounds"])
        f2 = compute(state["params"], f1["tstate"], batches_a, batch_b,
                     state["comm_rounds"] + 1)
        state, lm = scan(state, jnp.int32(depth))
        state, _ = apply_(state, f1, batches_a, batch_b, batch_idx,
                          jnp.int32(depth - 1))
        state, m = apply_(state, f2, batches_a, batch_b, batch_idx + 1,
                          jnp.int32(depth - 1))
        return state, {**m, **lm}
    return fn, 2


def _check_collectives(trace: TraceAudit, case: str,
                       pod_axis: Optional[str] = None) -> List[Finding]:
    """Simulated-WAN traces must contain NO mesh collectives; pod traces
    may only cross the pod axis through marked ppermutes."""
    findings = []
    colls = list(trace.collectives.values())
    if pod_axis is None:
        if colls:
            findings.append(Finding(
                code="taint.unmarked-collective", severity="error",
                where=f"{colls[0][0]}",
                detail=f"simulated-WAN trace contains mesh collective(s) "
                       f"{sorted({c[0] for c in colls})} — cross-device "
                       f"data movement outside the audited transport",
                case=case))
        return findings
    n_pp = 0
    for prim, axes in colls:
        if pod_axis in axes and prim != "ppermute":
            findings.append(Finding(
                code="taint.unmarked-collective", severity="error",
                where=prim,
                detail=f"collective '{prim}' crosses the '{pod_axis}' "
                       f"axis; only the transport's marked ppermute pair "
                       f"may move data over the inter-pod link",
                case=case))
        elif prim == "ppermute" and pod_axis in axes:
            n_pp += 1
    if n_pp != len(trace.boundaries):
        findings.append(Finding(
            code="taint.unmarked-collective", severity="error",
            where="ppermute",
            detail=f"trace contains {n_pp} ppermute(s) over "
                   f"'{pod_axis}' but only {len(trace.boundaries)} "
                   f"transport boundary mark(s) — a raw ppermute "
                   f"bypasses the transport",
            case=case))
    return findings


def trace_case(case: AuditCase, transport=None) -> CaseResult:
    """Trace + audit one configuration.  ``transport`` overrides the
    config-derived inner transport (used by the mutation self-tests)."""
    import jax
    import jax.numpy as jnp

    from ..core import engine as E
    from ..optim import make_optimizer
    from .kernel_lint import lint_engine_fusability
    from .markers import AuditedTransport, instrumented
    from .wire_audit import audit_wire

    celu = _make_celu(case)
    task, params, batches_a, batch_b = _toy_task(case.K)
    opt = make_optimizer("adagrad", 0.1)
    tp_inner = transport if transport is not None \
        else E.make_transport(celu)
    tp = AuditedTransport(tp_inner, celu)

    state = E.init_state(task, params, opt, celu, batches_a, batch_b,
                         transport=tp_inner)
    stages = E._make_stages(
        task, opt, celu, n_local=celu.R, tp=tp, fused=True,
        pipeline_staleness=case.depth,
        lr_damping=celu.pipeline_lr_damping if case.depth >= 2 else 0.0)
    fn, n_computes = _compose(case, stages, tp)
    args = (state, batches_a, batch_b, jnp.int32(3))

    # ONE trace, instrumented, returning the output structure too.  (An
    # uninstrumented jax.eval_shape first would poison the jit trace
    # cache: make_jaxpr on the same fn + avals reuses the cached,
    # mark-free jaxpr and the audit would silently check nothing.)
    tp._counts.clear()                  # fresh party indices per trace
    with instrumented():
        closed, out_sds = jax.make_jaxpr(fn, return_shape=True)(*args)

    in_tags = (_state_tags(state, case.K),
               [_const(batches_a[i], raw_of(f"a{i}"))
                for i in range(case.K)],
               _const(batch_b, raw_of("b")), EMPTY)
    in_leaves = jax.tree_util.tree_leaves(
        in_tags, is_leaf=lambda x: isinstance(x, Taint))
    assert len(in_leaves) == len(closed.jaxpr.invars), \
        (case.name, len(in_leaves), len(closed.jaxpr.invars))

    st_sds, m_sds = out_sds
    out_tags = (_out_state_tags(st_sds, case.K), _out_metric_tags(m_sds))
    out_leaves = jax.tree_util.tree_leaves(
        out_tags, is_leaf=lambda x: isinstance(x, OutTag))

    trace = audit_trace(closed, in_leaves, out_leaves, case=case.name)
    findings = list(trace.findings)
    findings += _check_collectives(trace, case.name)

    z_shapes = [(AUDIT_B, AUDIT_Z)] * case.K
    wire_findings, stats = audit_wire(tp_inner, celu, z_shapes, trace,
                                      n_computes, case.name)
    findings += wire_findings
    findings += lint_engine_fusability(celu, AUDIT_B, case.name)

    if not trace.boundaries:
        findings.append(Finding(
            code="audit.no-boundaries", severity="error",
            where="instrumented trace",
            detail="the trace contains no boundary marks at all — the "
                   "analyzer instrumentation is broken, the audit "
                   "proves nothing", case=case.name))
    if celu.cache_fused and not trace.pallas_calls:
        findings.append(Finding(
            code="audit.no-pallas", severity="warning",
            where="instrumented trace",
            detail="no pallas_call in a cache_fused trace at a fusable "
                   "geometry — the fused path the config promises did "
                   "not trace", case=case.name))

    stats["eqns"] = len(closed.jaxpr.eqns)
    stats["pallas_calls"] = len(trace.pallas_calls)
    return CaseResult(name=case.name, config=asdict(case),
                      findings=findings, stats=stats)


# --------------------------------------------------------------------------
# Fleet (vmapped batched-state) case
# --------------------------------------------------------------------------
AUDIT_JOBS = 2      # fleet width of the batched-state audit trace


def _pending_tags(pending, K: int):
    """Input taints for an adopted steady-state exchange queue.  Queue
    slots hold what a prior compute dispatch produced: RELEASED z/dz
    messages (the boundary mark cleared their raw taint when they crossed
    the wire), each party's own gradient and cached batch (raw to the
    owner — the host rule's PendingExchange theorem), B's loss, and the
    wire-seeded error-feedback residual snapshot."""
    fresh = pending.fresh
    ftags = dict(
        zs=[_const(z, EMPTY) for z in fresh["zs"]],
        dzs=[_const(z, EMPTY) for z in fresh["dzs"]],
        g_as=[_const(fresh["g_as"][i], raw_of(f"a{i}")) for i in range(K)],
        g_b=_const(fresh["g_b"], raw_of("b")),
        loss=raw_of("b"),
        tstate=_transport_tags(fresh["tstate"], K),
    )
    return pending._replace(
        fresh=ftags,
        batches_a=[_const(pending.batches_a[i], raw_of(f"a{i}"))
                   for i in range(K)],
        batch_b=_const(pending.batch_b, raw_of("b")),
        batch_idx=EMPTY, dispatched_at=EMPTY)


def _out_pending_tags(p_sds, K: int):
    """Host rule for the OUTPUT queue: released messages must stay
    public, every private leaf must stay with its owner — a refactor
    that parks a pre-release cut tensor in a queue slot another party
    reads is exactly what this region catches (taint.py module doc)."""
    import jax

    def reg(tree, allowed, label):
        return jax.tree_util.tree_map(lambda _: OutTag(allowed, label),
                                      tree)

    A = [frozenset({f"a{i}"}) for i in range(K)]
    b = frozenset({"b"})
    fresh = p_sds.fresh
    tp_tags = {}
    for d, lst in fresh["tstate"].items():
        owners = A if d == "up" else [b] * K
        tp_tags[d] = [reg(lst[i], owners[i],
                          f"fleet.pending.tstate.{d}[{i}]")
                      for i in range(len(lst))]
    ftags = dict(
        zs=[reg(fresh["zs"][i], _PUBLIC, f"fleet.pending.zs[{i}]")
            for i in range(K)],
        dzs=[reg(fresh["dzs"][i], _PUBLIC, f"fleet.pending.dzs[{i}]")
             for i in range(K)],
        g_as=[reg(fresh["g_as"][i], A[i], f"fleet.pending.g_as[{i}]")
              for i in range(K)],
        g_b=reg(fresh["g_b"], b, "fleet.pending.g_b"),
        loss=OutTag(b, "fleet.pending.loss"),
        tstate=tp_tags,
    )
    return p_sds._replace(
        fresh=ftags,
        batches_a=[reg(p_sds.batches_a[i], A[i],
                       f"fleet.pending.batches_a[{i}]") for i in range(K)],
        batch_b=reg(p_sds.batch_b, b, "fleet.pending.batch_b"),
        batch_idx=OutTag(_PUBLIC, "fleet.pending.batch_idx"),
        dispatched_at=OutTag(_PUBLIC, "fleet.pending.dispatched_at"))


def trace_fleet_case(case: Optional[AuditCase] = None,
                     jobs: int = AUDIT_JOBS, transport=None) -> CaseResult:
    """Audit the vmapped fleet step: ``jobs`` stacked scheduler states
    (engine state + PendingExchange queue + traced phase) driven through
    ONE batched jaxpr, at the heaviest supported config by default
    (depth 2, top-k + int8 codec, DP noise, int8 cache).

    The batched-state theorem this proves: the taint, sanitizer-ordering
    and byte-ledger analyses are invariant under the leading job axis —
    every boundary crossing carries ``(jobs,) + z_shape`` (ONE mark moves
    the fleet's messages), the queue's host rule still separates parties
    per slot, and the per-job wire ledger reconciles unchanged."""
    import jax
    import jax.numpy as jnp

    from ..core import engine as E
    from ..fleet.scheduler import JobHyper, make_fleet_step
    from ..optim import make_optimizer
    from .kernel_lint import lint_engine_fusability
    from .markers import AuditedTransport, instrumented
    from .wire_audit import audit_wire

    if case is None:
        case = AuditCase(name=f"fleet-N{jobs}-K1-d2-topk_int8-int8-dp0.3",
                         K=1, depth=2, compression="topk_int8",
                         cache_dtype="int8", dp_sigma=0.3)
    celu = _make_celu(case)
    task, params, batches_a, batch_b = _toy_task(case.K)
    opt = make_optimizer("adagrad", 0.1)
    tp_inner = transport if transport is not None \
        else E.make_transport(celu)
    tp = AuditedTransport(tp_inner, celu)

    state = E.init_state(task, params, opt, celu, batches_a, batch_b,
                         transport=tp_inner)
    init, step, _ = make_fleet_step(task, celu, depth=case.depth,
                                    transport=tp)
    fs = init(state, batches_a, batch_b)
    # steady-state queue phase: slots adopted as if a prior dispatch
    # filled them, so the traced merge cond sees a live queue
    fs = fs._replace(n_pending=jnp.int32(case.depth))
    stack = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.stack([jnp.asarray(x)] * jobs), t)
    fs_j, hyper_j = stack(fs), stack(JobHyper.for_spec(0.1, 60.0))
    vstep = jax.vmap(step, in_axes=(0, 0, None, None, None))
    args = (fs_j, hyper_j, batches_a, batch_b, jnp.int32(3))

    tp._counts.clear()
    with instrumented():
        closed, out_sds = jax.make_jaxpr(vstep, return_shape=True)(*args)

    K = case.K
    fs_tags = fs._replace(state=_state_tags(state, K),
                          pending=_pending_tags(fs.pending, K),
                          n_pending=EMPTY)
    hyper_tags = JobHyper(lr=EMPTY, cos_xi=EMPTY,
                          keys={k: EMPTY for k in hyper_j.keys})
    in_tags = (fs_tags, hyper_tags,
               [_const(batches_a[i], raw_of(f"a{i}")) for i in range(K)],
               _const(batch_b, raw_of("b")), EMPTY)
    in_leaves = jax.tree_util.tree_leaves(
        in_tags, is_leaf=lambda x: isinstance(x, Taint))
    assert len(in_leaves) == len(closed.jaxpr.invars), \
        (case.name, len(in_leaves), len(closed.jaxpr.invars))

    fs_sds, m_sds = out_sds
    out_tags = (fs_sds._replace(
        state=_out_state_tags(fs_sds.state, K),
        pending=_out_pending_tags(fs_sds.pending, K),
        n_pending=OutTag(_PUBLIC, "fleet.n_pending")),
        _out_metric_tags(m_sds))
    out_leaves = jax.tree_util.tree_leaves(
        out_tags, is_leaf=lambda x: isinstance(x, OutTag))

    trace = audit_trace(closed, in_leaves, out_leaves, case=case.name)
    findings = list(trace.findings)
    findings += _check_collectives(trace, case.name)

    z_shapes = [(AUDIT_B, AUDIT_Z)] * K
    wire_findings, stats = audit_wire(tp_inner, celu, z_shapes, trace,
                                      n_computes=1, case=case.name,
                                      jobs=jobs)
    findings += wire_findings
    findings += lint_engine_fusability(celu, AUDIT_B, case.name)

    if not trace.boundaries:
        findings.append(Finding(
            code="audit.no-boundaries", severity="error",
            where="instrumented fleet trace",
            detail="the vmapped trace contains no boundary marks — the "
                   "mark primitive's batching rule is broken and the "
                   "fleet audit proves nothing", case=case.name))

    stats["eqns"] = len(closed.jaxpr.eqns)
    stats["pallas_calls"] = len(trace.pallas_calls)
    cfg = asdict(case)
    cfg["jobs"] = jobs
    return CaseResult(name=case.name, config=cfg, findings=findings,
                      stats=stats)


# --------------------------------------------------------------------------
# Pod (SPMD) case
# --------------------------------------------------------------------------
def trace_pod_case() -> CaseResult:
    """Audit the shard_map pod round: both ppermute crossings must be the
    transport's marked pair and nothing else may cross the pod axis.
    Party-stacked arrays hold both parties in one leaf, so the per-party
    host rule does not apply here — the collective whitelist is the
    boundary theorem on this path."""
    import numpy as np

    import jax
    import jax.numpy as jnp

    name = "pod-shardmap-d1"
    if len(jax.devices()) < 2:
        return CaseResult(
            name=name, config={"skipped": True},
            findings=[Finding(
                code="audit.pod-skipped", severity="info",
                where="jax.devices()",
                detail="pod audit needs >= 2 devices; run the CLI (it "
                       "forces a 2-device CPU mesh) or set XLA_FLAGS="
                       "--xla_force_host_platform_device_count=2",
                case=name)],
            stats={"skipped": True})

    from jax.sharding import Mesh

    from ..core import engine as E
    from ..optim import make_optimizer
    from .markers import AuditedPodTransport, instrumented

    B, F, Z, W = 16, 6, 8, 4
    mesh = Mesh(np.array(jax.devices()[:2]), ("pod",))
    tp = AuditedPodTransport(E.PodTransport())
    opt = make_optimizer("adagrad", 0.1)

    def tower_fwd(p, x):
        return jnp.tanh(x @ p["w"])

    def top_loss(p, za, zb, y):
        logits = ((za + zb) @ p["w"])[:, 0]
        return jnp.maximum(logits, 0.0) - logits * y + \
            jnp.log1p(jnp.exp(-jnp.abs(logits)))

    params = {"tower": {"w": jnp.zeros((2, F, Z))},
              "top": {"w": jnp.zeros((2, Z, 1))}}
    opt_state = opt.init(params)
    ws = {"z": jnp.zeros((2, W, B, Z)), "dz": jnp.zeros((2, W, B, Z)),
          "x": jnp.zeros((2, W, B, F)), "y": jnp.zeros((2, W, B)),
          "time": jnp.zeros((2,), jnp.int32)}
    x = jnp.zeros((2, B, F))
    y = jnp.zeros((2, B))

    fn = E.make_pod_round(mesh, opt, R=2, cos_xi=0.5,
                          tower_fwd=tower_fwd, top_loss=top_loss,
                          transport=tp, pipeline_depth=1)
    tp._n = 0
    with instrumented():
        closed = jax.make_jaxpr(fn)(params, opt_state, ws, x, y)

    in_leaves = [EMPTY] * len(closed.jaxpr.invars)
    out_leaves = [OutTag(None, "pod")] * len(closed.jaxpr.outvars)
    trace = audit_trace(closed, in_leaves, out_leaves, case=name)
    findings = list(trace.findings)
    findings += _check_collectives(trace, name, pod_axis="pod")
    if len(trace.boundaries) != 2:
        findings.append(Finding(
            code="audit.no-boundaries", severity="error",
            where="pod trace",
            detail=f"expected the up/down ppermute boundary pair, found "
                   f"{len(trace.boundaries)} boundary mark(s)",
            case=name))
    return CaseResult(name=name, config={"K": 1, "depth": 1,
                                         "transport": "PodTransport"},
                      findings=findings,
                      stats={"boundaries": len(trace.boundaries),
                             "eqns": len(closed.jaxpr.eqns)})


# --------------------------------------------------------------------------
# Serving (continuous-batching decode) case
# --------------------------------------------------------------------------
def trace_serve_case(transport=None) -> CaseResult:
    """Audit ONE exchange decode step of the serving engine
    (``repro.serve.engine.make_step_fn`` with ``exchange=True``) at
    reduced smollm-360m geometry.

    The serving boundary theorem: Party A's raw material (embedding
    params, tower KV cache, aux token) may reach Party B's logits — and
    hence the emitted token — ONLY through the uplink boundary (wire +
    codec encode under int8 compression), and the activation ring Party B
    fuses against may hold ONLY released (post-wire) rows.  Concretely
    the output tags require: new ``cache_a`` stays with A, new
    ``cache_b`` / the token stay with B, the ring contents and A's next
    aux token (downlink product) are fully released.  A refactor that
    inserts the pre-wire ``z`` into the ring, or derives ``token_a``
    from the logits without the downlink crossing, fails this case."""
    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..configs.base import CELUConfig
    from ..core import engine as E
    from ..models import vfl
    from ..serve.engine import ServeConfig, ServeEngine, make_step_fn
    from .markers import AuditedTransport, instrumented

    name = "serve-cb2-int8-int8"
    cfg = get_config("smollm-360m").reduced()
    scfg = ServeConfig(capacity=2, prompt_len=4, max_new_tokens=2,
                       compression="int8", cache_dtype="int8",
                       ring_slots=2)
    celu = CELUConfig(compression="int8/identity")
    tp_inner = transport if transport is not None \
        else E.make_transport(celu)
    tp = AuditedTransport(tp_inner, celu)
    params = vfl.init_all(jax.random.PRNGKey(0), cfg)
    # the engine only supplies the stacked state template; the traced fn
    # is the raw (unjitted) exchange step wired to the audited transport
    state = ServeEngine(params, cfg, scfg).state
    step = make_step_fn(cfg, scfg, tp, exchange=True)
    args = (params, state, jax.random.PRNGKey(0))

    tp._counts.clear()
    with instrumented():
        closed, out_sds = jax.make_jaxpr(step, return_shape=True)(*args)

    a, b = raw_of("a0"), raw_of("b")
    in_tags = (
        {"a": _const(params["a"], a), "b": _const(params["b"], b)},
        {"cache_a": _const(state["cache_a"], a),
         "cache_b": _const(state["cache_b"], b),
         # ring rows are RELEASED messages; tokens already crossed the
         # downlink; the schedule vectors are public
         "ws": _const(state["ws"], EMPTY),
         "active": EMPTY, "pos": EMPTY,
         "token": b,            # B's own last emission feeds only B
         "token_a": EMPTY,      # A's aux token is a downlink product
         "remaining": EMPTY},
        EMPTY)                  # rng
    in_leaves = jax.tree_util.tree_leaves(
        in_tags, is_leaf=lambda x: isinstance(x, Taint))
    assert len(in_leaves) == len(closed.jaxpr.invars), \
        (name, len(in_leaves), len(closed.jaxpr.invars))

    A0, B = frozenset({"a0"}), frozenset({"b"})

    def reg(tree, allowed, label):
        return jax.tree_util.tree_map(lambda _: OutTag(allowed, label),
                                      tree)

    st_sds, tok_sds, prod_sds = out_sds
    out_tags = (
        {"cache_a": reg(st_sds["cache_a"], A0, "serve.cache_a"),
         "cache_b": reg(st_sds["cache_b"], B, "serve.cache_b"),
         "ws": reg(st_sds["ws"], _PUBLIC, "serve.ws"),
         "active": OutTag(_PUBLIC, "serve.active"),
         "pos": OutTag(_PUBLIC, "serve.pos"),
         "token": OutTag(B, "serve.token"),
         "token_a": OutTag(_PUBLIC, "serve.token_a"),
         "remaining": OutTag(_PUBLIC, "serve.remaining")},
        reg(tok_sds, B, "serve.tokens"),
        OutTag(_PUBLIC, "serve.produced"))
    out_leaves = jax.tree_util.tree_leaves(
        out_tags, is_leaf=lambda x: isinstance(x, OutTag))

    trace = audit_trace(closed, in_leaves, out_leaves, case=name)
    # Declared exception: the downlink carries a token ID as float32 (the
    # wire dtype) and Party A converts it back with float32->int32.  The
    # cast lint counts every f->i conversion as narrowing, but this one
    # is exact by construction — token ids < 2^24 are exactly
    # representable in float32 — and it sits AFTER the wire mark, so no
    # declared stage can clear it.  Any OTHER cast on any other output
    # still fails the case.
    def _declared_token_cast(f):
        return (f.code == "kernel.unmediated-cast"
                and f.where == "serve.token_a"
                and "float32->int32" in f.detail
                and "bf16" not in f.detail and "int8" not in f.detail)
    declared = [f for f in trace.findings if _declared_token_cast(f)]
    findings = [f for f in trace.findings if not _declared_token_cast(f)]
    findings += _check_collectives(trace, name)

    # one vmapped uplink mark (the C stacked z rows) + one vmapped
    # downlink mark (the C token ids) per exchange step
    ups = [r for r in trace.boundaries.values() if r.direction == "up"]
    downs = [r for r in trace.boundaries.values() if r.direction == "down"]
    if len(ups) != 1 or len(downs) != 1:
        findings.append(Finding(
            code="audit.no-boundaries", severity="error",
            where="serve exchange step",
            detail=f"expected exactly 1 uplink + 1 downlink boundary "
                   f"mark (the vmapped per-lane sends), found "
                   f"{len(ups)} up / {len(downs)} down — a decode "
                   f"release is bypassing the serving wire",
            case=name))
    if not trace.pallas_calls:
        findings.append(Finding(
            code="audit.no-pallas", severity="warning",
            where="serve exchange step",
            detail="int8 ring read did not trace through a fused "
                   "gather→dequant pallas_call", case=name))

    stats = {"eqns": len(closed.jaxpr.eqns),
             "boundaries": len(trace.boundaries),
             "uplink_marks": len(ups), "downlink_marks": len(downs),
             "pallas_calls": len(trace.pallas_calls),
             "declared_token_id_casts": len(declared)}
    return CaseResult(
        name=name,
        config={"capacity": scfg.capacity, "compression": "int8/identity",
                "cache_dtype": scfg.cache_dtype, "arch": "smollm-360m",
                "reduced": True},
        findings=findings, stats=stats)


# --------------------------------------------------------------------------
# Entry point
# --------------------------------------------------------------------------
def run_audit(cases: Optional[Sequence[AuditCase]] = None, *,
              include_pod: bool = True,
              include_fleet: bool = True,
              include_serve: bool = True,
              include_kernel_lint: bool = True) -> AuditReport:
    import jax

    from .kernel_lint import CONTRACTS, DEFAULT_GEOMETRIES, lint_kernels

    if cases is None:
        cases = default_cases()
    results: List[CaseResult] = []
    if include_kernel_lint:
        kf = lint_kernels(DEFAULT_GEOMETRIES)
        results.append(CaseResult(
            name="kernel-contracts",
            config={"geometries": [g.name for g in DEFAULT_GEOMETRIES]},
            findings=kf,
            stats={"contracts": len(CONTRACTS),
                   "geometries": len(DEFAULT_GEOMETRIES)}))
    for case in cases:
        results.append(trace_case(case))
    if include_fleet:
        results.append(trace_fleet_case())
    if include_serve:
        results.append(trace_serve_case())
    if include_pod:
        results.append(trace_pod_case())
    return AuditReport(
        cases=results,
        meta={"jax": jax.__version__, "devices": len(jax.devices()),
              "audited_cases": len(results)})
