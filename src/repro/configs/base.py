"""Architecture / shape / run configuration dataclasses.

Every assigned architecture gets one module in this package exporting
``CONFIG: ArchConfig`` with the exact assigned hyper-parameters (citation in
``source``).  ``ArchConfig.reduced()`` produces the CPU-smoke variant
(<=2 layers, d_model<=512, <=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0        # shared (always-on) experts, llama4-style
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # "ep" shards the expert dim over the model axis (all-to-all dispatch),
    # "tp" shards each expert's FFN over the model axis (no all-to-all).
    sharding: str = "tp"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective SSM (used by hybrid archs)."""
    state_dim: int = 16
    conv_dim: int = 4
    expand: int = 2


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block layout: sLSTM at layer indices i % slstm_every == 0."""
    slstm_every: int = 4
    conv_dim: int = 4


@dataclass(frozen=True)
class VFLConfig:
    """How the backbone is split across the two parties (see DESIGN §3)."""
    layers_a: int            # Party A bottom tower depth
    layers_b: int            # Party B bottom tower depth
    layers_top: int          # Party B top tower depth (+ head)
    fusion: str = "add"      # add | cross_attn
    z_dim: int = 0           # dim of the exchanged Z_A; 0 -> d_model


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str              # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0        # 0 -> d_model // n_heads
    source: str = ""

    # family extras
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    cross_attn_every: int = 0      # vlm: every k-th layer cross-attends
    enc_layers: int = 0            # audio: encoder depth (Party A tower)
    qkv_bias: bool = False         # qwen-style attention bias

    # attention window; 0 = full causal.  long_500k configs override this.
    sliding_window: int = 0

    # modality frontends (stubs; see DESIGN §5)
    n_patches: int = 0             # vlm: patch tokens from the vision stub
    d_frontend: int = 0            # vlm/audio: stub embedding dim
    audio_downsample: int = 4      # audio: frames = seq_len // downsample

    aux_vocab_size: int = 65536    # Party A token stream vocab (text archs)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    vfl: Optional[VFLConfig] = None

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def vfl_split(self) -> VFLConfig:
        if self.vfl is not None:
            return self.vfl
        if self.family == "vlm":
            # Party A = vision owner; bottom_A is the projector, all decoder
            # layers belong to Party B; top = last quarter.
            lt = max(1, self.n_layers // 4)
            return VFLConfig(layers_a=0, layers_b=self.n_layers - lt,
                             layers_top=lt, fusion="cross_attn")
        if self.family == "audio":
            lt = max(1, self.n_layers // 4)
            return VFLConfig(layers_a=self.enc_layers,
                             layers_b=self.n_layers - lt, layers_top=lt,
                             fusion="cross_attn")
        la = max(1, self.n_layers // 4)
        lt = max(1, self.n_layers // 4)
        return VFLConfig(layers_a=la, layers_b=self.n_layers - la - lt,
                         layers_top=lt, fusion="add")

    def with_sliding_window(self, window: int) -> "ArchConfig":
        return dataclasses.replace(self, sliding_window=window)

    def reduced(self) -> "ArchConfig":
        """CPU smoke variant: same family, tiny dims."""
        d = 128
        heads, kv = 4, 2
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2))
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=2,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=d // heads,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            aux_vocab_size=512,
            moe=moe,
            cross_attn_every=2 if self.cross_attn_every else 0,
            enc_layers=2 if self.enc_layers else 0,
            n_patches=16 if self.n_patches else 0,
            d_frontend=32 if self.d_frontend else 0,
            vfl=VFLConfig(
                layers_a=0 if self.family == "vlm" else 1,
                layers_b=1, layers_top=1,
                fusion=self.vfl_split.fusion),
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}

# Window applied to attention archs for the long_500k decode config
# (DESIGN §3 long_500k policy).
LONG_CONTEXT_WINDOW = 8192


def validate_pipeline_depth(depth: int, W: int) -> None:
    """THE pipeline-depth capacity rule, stated once.

    A depth-D exchange queue retires the oldest D workset ring slots early
    (every in-flight exchange owns the slot its merge will overwrite), so
    D must stay < W or every draw is a bubble.  ``CELUConfig.__post_init__``
    and the ``PipelinedEngine`` scheduler both call this — the queue-overflow
    RuntimeErrors at dispatch time derive their capacity from the same
    ``depth`` and need no second copy of the rule."""
    if depth < 0:
        raise ValueError(f"pipeline_depth must be >= 0, got {depth}")
    if depth and depth >= max(W, 1):
        raise ValueError(
            f"pipeline_depth ({depth}) must be < W "
            f"({W}): a depth-D queue retires the oldest D ring "
            f"slots early, so D >= W leaves no valid workset draws")


@dataclass(frozen=True)
class CELUConfig:
    """Hyper-parameters of the paper's technique (Section 3 notation)."""
    R: int = 5               # max local updates per cached batch
    W: int = 5               # workset table capacity (mini-batches)
    xi_degrees: float = 60.0 # weighting threshold ξ (cos ξ floor)
    weighting: bool = True
    # round_robin | consecutive (FedBCD) | uniform (random over alive slots)
    sampling: str = "round_robin"
    # BEYOND-PAPER: wire precision of the exchanged ⟨Z_A, ∇Z_A⟩.  The paper
    # sends fp32; "bfloat16" halves WAN bytes per round (EXPERIMENTS §Perf
    # pair 3 validates convergence parity).
    wire_dtype: str = "float32"
    # BEYOND-PAPER: Gaussian-mechanism DP on the wire (core/privacy.py);
    # sigma = 0 disables.  Noised statistics are what gets cached, so local
    # updates reuse already-released messages at no extra privacy cost.
    dp_sigma: float = 0.0
    dp_clip: float = 1.0
    # BEYOND-PAPER: wire codec spec for the compressed transport
    # (Compressed-VFL-style top-k / low-bit sketches with error feedback).
    # "" = plain SimWANTransport; see core/compression.py CODEC_SPECS for
    # names ("int8", "int4", "topk", "int8_topk", "up/down" pairs, ...).
    compression: str = ""
    # BEYOND-PAPER: at-rest precision of the workset cache (the z/dz
    # subtrees of every ring buffer; core/workset.py storage codec).
    # "float32" stores the statistics verbatim (bit-identical to the
    # historical table — golden-pinned); "bfloat16" halves the footprint;
    # "int8" stores SR-quantized codes + one fp32 scale per instance row
    # (~4x smaller; unbiased through Algorithm-2's cosine — see
    # tests/test_workset_cache.py tolerance sweeps); "int4" nibble-packs
    # two SR codes per byte (levels=7, same per-row scale — ~8x smaller,
    # the at-rest floor that makes full LLM geometry fit; see
    # docs/llm_memory.md).
    cache_dtype: str = "float32"
    # Route party-A local updates through the fused gather→dequant→weight
    # megakernel (kernels/fused_sample.py): the sampled ring rows are read
    # once, in storage precision, straight into the weighting pass — no
    # HBM-side entry copy.  False pins the materializing reference path.
    cache_fused: bool = True
    # Paper §4.1 (Fig. 4), generalized: the exchange-queue depth.  0 =
    # sequential rounds (exchange then local updates, the WAN stall
    # serialized with compute); 1 = the paper's two-worker overlap (round
    # t+1's exchange in flight during round t's local updates); D >= 2 = a
    # D-deep queue of in-flight exchanges (engine.PipelinedEngine) for the
    # high-RTT regime where one exchange cannot hide behind one local
    # scan.  The depth is also the extra staleness every cached entry
    # accrues — it tightens workset validity and attenuates the
    # Algorithm-2 weights (weighting.pipeline_attenuation; per-slot
    # dynamic offsets at D >= 2), so it must stay < W or every draw
    # becomes a bubble (validated below).
    pipeline_depth: int = 0
    # Staleness-aware lr damping for the depth-D queue: local and fresh
    # updates under the pipelined schedule are scaled by 1 / (1 + c * s)
    # where s is the update's pipeline staleness in exchanges and c this
    # coefficient.  Applied only on the dynamic (depth >= 2) schedule —
    # depths 0 and 1 keep the historical golden-pinned numerics (s = 0 at
    # depth 1's merge, so damping would be a no-op there anyway).
    pipeline_lr_damping: float = 0.25

    def __post_init__(self):
        validate_pipeline_depth(self.pipeline_depth, self.W)
        if self.pipeline_lr_damping < 0.0:
            raise ValueError(
                f"pipeline_lr_damping must be >= 0, got "
                f"{self.pipeline_lr_damping}")


@dataclass(frozen=True)
class DropoutSpan:
    """One party's outage: ``party`` ("a0".."a{K-1}" or "b") is down for
    ``rounds`` consecutive scheduler rounds starting at ``start`` (and
    rejoins elastically at ``start + rounds``)."""
    party: str
    start: int
    rounds: int

    def __post_init__(self):
        if not (self.party == "b" or (self.party.startswith("a")
                                      and self.party[1:].isdigit())):
            raise ValueError(
                f"DropoutSpan.party must be 'a<i>' or 'b', got "
                f"{self.party!r}")
        if self.start < 0 or self.rounds <= 0:
            raise ValueError(
                f"DropoutSpan needs start >= 0 and rounds >= 1, got "
                f"start={self.start} rounds={self.rounds}")

    def covers(self, round_idx: int) -> bool:
        return self.start <= round_idx < self.start + self.rounds


@dataclass(frozen=True)
class FaultPlan:
    """Deterministic, seeded fault schedule for the chaos engine
    (``core.faults.ChaosEngine``).  Pure configuration — every fate is a
    function of ``(seed, round_idx)`` alone, so two runs (or a run and
    its checkpoint-restored resumption) see identical faults.

    ``party_clocks`` are per-FEATURE-party heterogeneous WAN links as
    plain ``(up_bandwidth_Bps, down_bandwidth_Bps, latency_s)`` tuples
    (converted lazily to ``launch.wan.WANClock`` — this module stays a
    leaf dependency); the slowest party paces each exchange.
    """
    seed: int = 0
    # per-attempt exchange loss probability; a dropped attempt is retried
    # up to max_retries times with exponential backoff before the round's
    # exchange is abandoned (the error-feedback residual then absorbs the
    # lost update — see docs/FAULTS.md)
    drop_prob: float = 0.0
    max_retries: int = 2
    retry_backoff_s: float = 0.5
    # straggler injection: a delivered exchange arrives this many rounds
    # late with probability straggler_prob (delay uniform on
    # [1, straggler_rounds])
    straggler_prob: float = 0.0
    straggler_rounds: int = 2
    dropouts: Tuple[DropoutSpan, ...] = ()
    party_clocks: Optional[Tuple[Tuple[float, float, float], ...]] = None

    def __post_init__(self):
        if not (0.0 <= self.drop_prob < 1.0):
            raise ValueError(f"drop_prob must be in [0, 1), got "
                             f"{self.drop_prob}")
        if not (0.0 <= self.straggler_prob <= 1.0):
            raise ValueError(f"straggler_prob must be in [0, 1], got "
                             f"{self.straggler_prob}")
        if self.max_retries < 0 or self.straggler_rounds < 1:
            raise ValueError(
                f"need max_retries >= 0 and straggler_rounds >= 1, got "
                f"{self.max_retries} / {self.straggler_rounds}")
        if self.retry_backoff_s < 0.0:
            raise ValueError(f"retry_backoff_s must be >= 0, got "
                             f"{self.retry_backoff_s}")
        object.__setattr__(self, "dropouts", tuple(self.dropouts))
        if self.party_clocks is not None:
            object.__setattr__(
                self, "party_clocks",
                tuple(tuple(float(v) for v in c)
                      for c in self.party_clocks))
            for c in self.party_clocks:
                if len(c) != 3 or c[0] <= 0 or c[1] <= 0 or c[2] < 0:
                    raise ValueError(
                        f"party_clocks entries are (up_Bps, down_Bps, "
                        f"latency_s) with positive bandwidths, got {c}")

    def down_parties(self, round_idx: int) -> Tuple[str, ...]:
        """Parties down at ``round_idx`` (sorted, deduplicated)."""
        return tuple(sorted({d.party for d in self.dropouts
                             if d.covers(round_idx)}))


@dataclass(frozen=True)
class TrainConfig:
    batch_size: int = 256
    lr: float = 0.01
    optimizer: str = "adagrad"      # paper uses AdaGrad
    steps: int = 200
    seed: int = 0
    celu: CELUConfig = field(default_factory=CELUConfig)
