"""Step builders + abstract input specs for every (arch × shape).

``train_step``   — full VFL forward/backward + AdaGrad update (train shapes).
``prefill_step`` — full-context forward emitting decode caches.
``serve_step``   — ONE new token against a seq_len-deep KV/state cache
                   (decode shapes lower THIS, per the assignment).

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStructs for
every model input — the dry-run lowers against these, no allocation.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeConfig
from ..models import vfl
from ..models.initializers import PARAM_DTYPE
from ..optim import Optimizer, apply_updates


# --------------------------------------------------------------------------
# Abstract inputs
# --------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Training/prefill batch for the family (frontends stubbed: patch/frame
    embeddings arrive precomputed — DESIGN §5)."""
    B, S = shape.global_batch, shape.seq_len
    spec: Dict[str, Any] = {"tokens": _sds((B, S), jnp.int32)}
    if shape.kind == "train":
        spec["labels"] = _sds((B, S), jnp.int32)
    if cfg.family == "vlm":
        spec["patches"] = _sds((B, cfg.n_patches, cfg.d_frontend),
                               jnp.float32)
    elif cfg.family == "audio":
        spec["frames"] = _sds((B, S // cfg.audio_downsample, cfg.d_frontend),
                              jnp.float32)
    else:
        spec["tokens_a"] = _sds((B, S), jnp.int32)
    return spec


def decode_specs(cfg: ArchConfig, shape: ShapeConfig
                 ) -> Tuple[Dict[str, Any], Any, Any]:
    """-> (step_batch, caches, pos) ShapeDtypeStructs for serve_step."""
    B, S = shape.global_batch, shape.seq_len
    step: Dict[str, Any] = {"token": _sds((B, 1), jnp.int32)}
    if cfg.family not in ("vlm", "audio"):
        step["token_a"] = _sds((B, 1), jnp.int32)
    mem_len = 0
    if cfg.family == "vlm":
        mem_len = cfg.n_patches
    elif cfg.family == "audio":
        mem_len = S // cfg.audio_downsample
    caches = jax.eval_shape(
        lambda: vfl.make_serve_cache(cfg, B, S, mem_len))
    pos = _sds((), jnp.int32)
    return step, caches, pos


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """All abstract inputs for the shape's step function, keyed by arg."""
    if shape.kind == "decode":
        step, caches, pos = decode_specs(cfg, shape)
        return {"caches": caches, "step_batch": step, "pos": pos}
    return {"batch": batch_specs(cfg, shape)}


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(
        lambda: vfl.init_all(jax.random.PRNGKey(0), cfg))


# --------------------------------------------------------------------------
# Steps
# --------------------------------------------------------------------------
def make_train_step(cfg: ArchConfig, opt: Optimizer, *,
                    microbatches: int = 1, unroll_microbatches: bool = False):
    """(params, opt_state, batch) -> (params, opt_state, loss).

    ``microbatches`` > 1 accumulates gradients over batch slices — live
    remat activations scale with the per-device microbatch, so peak memory
    drops ~N× at the cost of re-reading weights per slice (EXPERIMENTS
    §Perf pair 1).  ``unroll_microbatches`` unrolls the loop instead of
    ``lax.scan``: the scan body appears ONCE in the HLO so static analyses
    (cost_analysis, collective parsing) undercount it N× — the dry-run
    lowers the unrolled form for honest roofline terms, real training uses
    the scan (sequencing = the memory guarantee)."""

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(
                lambda p: vfl.joint_loss(p, cfg, batch, train=True))(params)
        else:
            from ..models.layers import shard_batch_dim
            B = jax.tree_util.tree_leaves(batch)[0].shape[0]
            assert B % microbatches == 0, (B, microbatches)
            mb = B // microbatches
            split = jax.tree_util.tree_map(
                lambda a: a.reshape((microbatches, mb) + a.shape[1:]), batch)

            def one(mbatch):
                mbatch = jax.tree_util.tree_map(shard_batch_dim, mbatch)
                return jax.value_and_grad(
                    lambda p: vfl.joint_loss(p, cfg, mbatch, train=True)
                )(params)

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            if unroll_microbatches:
                loss = jnp.float32(0.0)
                grads = g0
                for i in range(microbatches):
                    mbatch = jax.tree_util.tree_map(lambda a: a[i], split)
                    li, gi = one(mbatch)
                    loss = loss + li
                    grads = jax.tree_util.tree_map(
                        lambda a, g: a + g.astype(jnp.float32), grads, gi)
            else:
                def acc_step(carry, mbatch):
                    loss_acc, g_acc = carry
                    li, gi = one(mbatch)
                    g_acc = jax.tree_util.tree_map(
                        lambda a, g: a + g.astype(jnp.float32), g_acc, gi)
                    return (loss_acc + li, g_acc), None

                (loss, grads), _ = jax.lax.scan(
                    acc_step, (jnp.float32(0.0), g0), split)
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(
                lambda g: g / microbatches, grads)
        upd, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, upd), opt_state, loss

    return train_step


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        return vfl.prefill(params, cfg, batch)
    return prefill_step


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, caches, step_batch, pos):
        return vfl.decode_step(params, cfg, caches, step_batch, pos)
    return serve_step


def make_step(cfg: ArchConfig, shape: ShapeConfig, opt: Optimizer = None, *,
              microbatches: int = 1):
    """The step function a shape lowers, matching input_specs keys."""
    if shape.kind == "train":
        assert opt is not None
        return make_train_step(cfg, opt, microbatches=microbatches)
    if shape.kind == "prefill":
        return make_prefill_step(cfg)
    return make_serve_step(cfg)


# --------------------------------------------------------------------------
# Concrete (host) batches for smoke tests
# --------------------------------------------------------------------------
def concrete_batch(cfg: ArchConfig, shape: ShapeConfig, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = {}
    for k, s in batch_specs(cfg, shape).items():
        if s.dtype == jnp.int32:
            hi = cfg.vocab_size if k != "tokens_a" else cfg.aux_vocab_size
            out[k] = jnp.asarray(
                rng.integers(0, hi, size=s.shape, dtype=np.int32))
        else:
            out[k] = jnp.asarray(
                rng.normal(size=s.shape).astype(np.float32))
    return out
