"""Pallas TPU kernels for the compute hot-spots (validated interpret=True).

  cosine_weight   -- fused Algorithm-2 staleness weighting (VPU, one pass)
  flash_attention -- blockwise online-softmax attention (MXU tiles)
  fused_adagrad   -- optimizer accumulate+scale (memory-bound optimum)

Each has a jit'd wrapper in ops.py and a pure-jnp oracle in ref.py.
"""
