"""Kernel-level microbenchmarks.

On this CPU container the Pallas kernels run in interpret mode (correctness
only — Python-interpreted, meaningless to time), so wall-times are reported
for the pure-jnp oracles (XLA:CPU-compiled) as relative indicators, plus the
analytic VMEM-pass accounting that motivates each fusion (DESIGN §2).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import csv_row


def _time(fn, *args, n=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6   # us


def bench_cosine_weight():
    from repro.kernels import ref
    rng = np.random.default_rng(0)
    B, F = 4096, 256                      # the paper's Z_A geometry
    a = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    s = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    dz = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)

    fused = jax.jit(lambda a_, s_, d_: ref.weighted_cotangent_ref(
        a_, s_, d_, 0.5))
    us = _time(fused, a, s, dz)
    naive = jax.jit(lambda a_, s_, d_: (
        ref.cosine_weight_ref(a_, s_, 0.5)[:, None] * d_))
    us2 = _time(naive, a, s, dz)
    # one fused pass moves 3 inputs + 1 output; the unfused composition
    # re-reads dz and re-materializes w
    bytes_fused = 4 * B * F * 4
    csv_row("cosine_weight(jnp-oracle)", f"{us:.1f}us",
            f"hbm_bytes_one_pass={bytes_fused}")
    csv_row("cosine_weight(naive-2pass)", f"{us2:.1f}us", "")


def bench_flash_oracle():
    from repro.kernels import ref
    rng = np.random.default_rng(0)
    B, S, H, hd = 1, 1024, 4, 64
    q, k, v = (jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
               for _ in range(3))
    dense = jax.jit(lambda *a: ref.flash_attention_ref(*a, causal=True))
    us = _time(dense, q, k, v, n=5)
    csv_row("attention_dense_oracle(B1,S1024,H4,hd64)", f"{us:.1f}us",
            f"score_bytes={B * H * S * S * 4}")

    from repro.models import layers as L
    pos = jnp.arange(S, dtype=jnp.int32)
    blockwise = jax.jit(lambda q_, k_, v_: L._blockwise_sdpa(
        q_, k_, v_, pos, pos, causal=True, window=0))
    us2 = _time(blockwise, q, k, v, n=5)
    csv_row("attention_blockwise(flash-schedule)", f"{us2:.1f}us",
            f"tile_bytes={L.Q_BLOCK * L.KV_BLOCK * 4}")


def bench_adagrad():
    from repro.kernels import ref
    rng = np.random.default_rng(0)
    n = 1 << 20
    g = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    acc = jnp.abs(jnp.asarray(rng.normal(size=(n,)), jnp.float32))
    fn = jax.jit(lambda g_, a_: ref.fused_adagrad_ref(g_, a_, 0.01, 1e-10))
    us = _time(fn, g, acc)
    csv_row("fused_adagrad_oracle(1M params)", f"{us:.1f}us",
            f"stream_bytes={4 * n * 4}")


def bench_protocol_round():
    """Per-round step cost of the engine's protocol presets (CPU wall, WDL
    small).  The celu row runs across the hot-path tiers: fused
    Algorithm-2 weighting (Pallas weighted-cotangent) vs the pure-jnp
    reference, the cache-dtype axis (fp32 / bf16 / int8 at-rest workset),
    and the unfused sample path (materialize-then-weight)."""
    from .common import default_workload, run_protocol
    spec, data, cfg = default_workload("wdl", "criteo")
    for name, proto_name, kw in (
            ("vanilla", "vanilla", {}),
            ("fedbcd", "fedbcd", {"R": 5}),
            ("celu", "celu", {"R": 5, "W": 5}),
            ("celu_ref_weighting", "celu",
             {"R": 5, "W": 5, "fused_weighting": False}),
            ("celu_unfused_sample", "celu",
             {"R": 5, "W": 5, "cache_fused": False}),
            ("celu_bf16_cache", "celu",
             {"R": 5, "W": 5, "cache_dtype": "bfloat16"}),
            ("celu_int8_cache", "celu",
             {"R": 5, "W": 5, "cache_dtype": "int8"})):
        r = run_protocol(proto_name, data, cfg, rounds=30, eval_every=30,
                         **kw)
        csv_row(f"round_wall_{name}",
                f"{r['wall_s'] / 30 * 1e3:.1f}ms",
                f"z_bytes={r['z_bytes_per_round']}",
                f"stat_cache_bytes={r['stat_cache_bytes']}")


def main():
    csv_row("# microbenchmarks (CPU oracles; Pallas kernels are TPU-target)")
    bench_cosine_weight()
    bench_flash_oracle()
    bench_adagrad()
    bench_protocol_round()


if __name__ == "__main__":
    main()
