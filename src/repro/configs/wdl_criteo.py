"""WDL on Criteo field layout — the paper's own Table-1 workload."""
from ..models.tabular import DLRMConfig

CONFIG = DLRMConfig(model="wdl", fields_a=26, fields_b=13,
                    vocab=1024, embed_dim=16, z_dim=256, hidden=(512, 256))
