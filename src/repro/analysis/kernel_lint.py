"""Pallas kernel-contract lint.

Each fused kernel in :mod:`repro.kernels` carries an implicit contract
the engine relies on but nothing enforced statically until now:

  * a **jnp oracle** must exist in ``kernels/ref.py`` (the golden tests
    and the un-fusable fallback paths both depend on it);
  * the **grid/BlockSpec divisibility** rule must hold at the call-site
    geometries the engine actually audits (otherwise the engine silently
    falls back to the reference path — correct but not the perf the
    results tables assume);
  * the kernel's **VMEM residency** (block operands x2 for
    double-buffering) must fit the per-core budget from the Pallas TPU
    guide;
  * the wrapper must **trace** at the audited geometry (``eval_shape``
    probe: shape-rule asserts inside the wrapper surface as findings
    instead of engine-time crashes).

The companion check — no narrowing precision cast outside a declared
wire/encode/cache stage — runs in the taint walk (``taint.py``), where
dataflow context exists; its findings share the ``kernel.`` family.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Sequence, Tuple

from .report import Finding

# Pallas TPU guide: ~16 MiB VMEM per core; keep headroom for the
# compiler's own scratch.
VMEM_BUDGET = 16 * 2 ** 20
VMEM_HEADROOM = 0.75


@dataclass
class Geometry:
    """One audited call-site shape set (engine defaults + stress point)."""
    name: str
    B: int = 64          # batch rows per workset draw
    F: int = 8           # cut-layer width (z_dim)
    W: int = 5           # workset ring depth
    P: int = 4096        # largest flat param block fed to fused_adagrad
    S: int = 2048        # flash-attention sequence length
    H: int = 4           # flash heads
    hd: int = 128        # flash head dim
    T: int = 0           # quantizer tiles; derived from B*F when 0

    def tiles(self, tile: int = 128) -> int:
        n = self.B * self.F
        return self.T or -(-n // tile)


DEFAULT_GEOMETRIES = (
    Geometry("round-default", B=64, F=8),
    Geometry("round-wide", B=4096, F=128),
    Geometry("flash-long", B=2, F=8, S=2048, hd=128),
)


def _f32(*shape):
    import jax
    import jax.numpy as jnp
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def _i8(*shape):
    import jax
    import jax.numpy as jnp
    return jax.ShapeDtypeStruct(tuple(shape), jnp.int8)


def _i32(*shape):
    import jax
    import jax.numpy as jnp
    return jax.ShapeDtypeStruct(tuple(shape), jnp.int32)


def _u8(*shape):
    import jax
    import jax.numpy as jnp
    return jax.ShapeDtypeStruct(tuple(shape), jnp.uint8)


@dataclass
class KernelContract:
    name: str                       # kernels/<name>.py
    oracle: str                     # required symbol in kernels/ref.py
    # (geometry) -> (block div ok?, human rule text); None = self-padding
    divisibility: Any
    # (geometry) -> resident VMEM bytes for one grid step's blocks
    vmem: Callable[[Geometry], int]
    # (geometry) -> (callable, args) eval_shape probe; None to skip
    probe: Any


def _cw_div(g: Geometry):
    from ..kernels.cosine_weight import BLOCK_B
    bb = min(BLOCK_B, g.B)
    return (g.B % bb == 0,
            f"B={g.B} % min(BLOCK_B={BLOCK_B}, B)={bb}")


def _cw_vmem(g: Geometry) -> int:
    from ..kernels.cosine_weight import BLOCK_B
    bb = min(BLOCK_B, g.B)
    # a, s, dz blocks in; w + out blocks out (f32)
    return (3 * bb * g.F + bb * g.F + bb) * 4


def _fs_vmem(g: Geometry) -> int:
    from ..kernels.fused_sample import BLOCK_B
    bb = min(BLOCK_B, g.B)
    # slot + ad_hoc block + one ring slot's z/dz blocks + outputs
    return (bb * g.F * 4 + 2 * bb * g.F * 4 + bb * g.F * 4 + bb * 4 + 4)


def _fs_q8_vmem(g: Geometry) -> int:
    from ..kernels.fused_sample import BLOCK_B
    bb = min(BLOCK_B, g.B)
    # int8 rings + f32 row scales + f32 ad_hoc/out blocks
    return (2 * bb * g.F + 2 * bb * 4 + 2 * bb * g.F * 4 + bb * 4 + 4)


def _fs_q4_vmem(g: Geometry) -> int:
    from ..kernels.fused_sample import BLOCK_B
    bb = min(BLOCK_B, g.B)
    P = -(-g.F // 2)
    # packed uint8 rings + f32 row scales + f32 ad_hoc/out blocks; the
    # unpacked fp32 rows live only in registers/VPU, never as an operand
    return (2 * bb * P + 2 * bb * 4 + 2 * bb * 2 * P * 4 + bb * 4 + 4)


def _ag_q8_vmem(g: Geometry) -> int:
    from ..kernels.fused_adagrad import BLOCK, ROWS
    # grad f32 + codes int8 + scale f32 + uniforms f32 in;
    # update f32 + codes int8 + scale f32 out
    return ROWS * BLOCK * (4 + 1 + 4 + 4 + 1) + 2 * ROWS * 4


def _q_div(g: Geometry):
    from ..kernels.quantize import BLOCK_T
    T = g.tiles()
    bt = min(BLOCK_T, T)
    return (T % bt == 0, f"T={T} % min(BLOCK_T={BLOCK_T}, T)={bt}")


def _q_vmem(g: Geometry) -> int:
    from ..kernels.quantize import BLOCK_T
    T = g.tiles()
    bt = min(BLOCK_T, T)
    # x + u blocks f32 in, q int8 + scale f32 out; tile=128 values
    return bt * 128 * (4 + 4 + 1) + bt * 4


def _fa_div(g: Geometry):
    from ..kernels.flash_attention import BLOCK_Q
    bq = min(BLOCK_Q, g.S)
    return (g.S % bq == 0, f"S={g.S} % min(BLOCK_Q={BLOCK_Q}, S)={bq}")


def _fa_vmem(g: Geometry) -> int:
    from ..kernels.flash_attention import BLOCK_Q
    bq = min(BLOCK_Q, g.S)
    # q block + FULL-length k/v blocks (they ride as (S, hd)) + o block
    # + m/l accumulators
    return (bq * g.hd + 2 * g.S * g.hd + bq * g.hd + 2 * bq) * 4


def _ag_vmem(g: Geometry) -> int:
    from ..kernels.fused_adagrad import BLOCK, ROWS
    # grad + accum in, update + accum out, all f32, self-padded tiles
    return ROWS * BLOCK * 4 * 4


def _probe_cw(g: Geometry):
    from ..kernels import ops
    return ops.cosine_weight, (_f32(g.B, g.F), _f32(g.B, g.F), 0.5)


def _probe_wc(g: Geometry):
    from ..kernels import ops
    return ops.weighted_cotangent, (_f32(g.B, g.F), _f32(g.B, g.F),
                                    _f32(g.B, g.F), 0.5)


def _probe_fs(g: Geometry):
    from ..kernels import ops
    return ops.fused_gather_weight, (_i32(), _f32(g.B, g.F),
                                     _f32(g.W, g.B, g.F),
                                     _f32(g.W, g.B, g.F), 0.5)


def _probe_fs_q8(g: Geometry):
    from ..kernels import ops
    return ops.fused_gather_weight_q8, (_i32(), _f32(g.B, g.F),
                                        _i8(g.W, g.B, g.F),
                                        _f32(g.W, g.B),
                                        _i8(g.W, g.B, g.F),
                                        _f32(g.W, g.B), 0.5)


def _probe_fs_q4(g: Geometry):
    from ..kernels import ops
    P = -(-g.F // 2)
    return ops.fused_gather_weight_q4, (_i32(), _f32(g.B, g.F),
                                        _u8(g.W, g.B, P),
                                        _f32(g.W, g.B),
                                        _u8(g.W, g.B, P),
                                        _f32(g.W, g.B), 0.5)


def _probe_ag_q8(g: Geometry):
    from ..kernels import ops
    from ..kernels.fused_adagrad import BLOCK, ROWS
    return ops.fused_adagrad_q8, (_f32(ROWS, BLOCK), _i8(ROWS, BLOCK),
                                  _f32(ROWS, 1), _f32(ROWS, BLOCK),
                                  0.1, 1e-10)


def _probe_q(g: Geometry):
    from ..kernels import ops
    T = g.tiles()
    return ops.quantize_stochastic, (_f32(T, 128), _f32(T, 128), 127)


def _probe_flash(g: Geometry):
    from ..kernels import ops
    return (lambda q, k, v: ops.flash_attention(q, k, v, causal=True),
            (_f32(2, g.H, g.S, g.hd),) * 3)


def _probe_ag(g: Geometry):
    from ..kernels import ops
    return ops.fused_adagrad, (_f32(g.P), _f32(g.P), 0.1, 1e-10)


CONTRACTS: Tuple[KernelContract, ...] = (
    KernelContract("cosine_weight", "cosine_weight_ref",
                   _cw_div, _cw_vmem, _probe_cw),
    KernelContract("cosine_weight", "weighted_cotangent_ref",
                   _cw_div, _cw_vmem, _probe_wc),
    KernelContract("fused_sample", "fused_sample_ref",
                   _cw_div, _fs_vmem, _probe_fs),
    KernelContract("fused_sample", "fused_sample_q8_ref",
                   _cw_div, _fs_q8_vmem, _probe_fs_q8),
    KernelContract("fused_sample", "fused_sample_q4_ref",
                   _cw_div, _fs_q4_vmem, _probe_fs_q4),
    KernelContract("quantize", "quantize_sr_ref",
                   _q_div, _q_vmem, _probe_q),
    KernelContract("flash_attention", "flash_attention_ref",
                   _fa_div, _fa_vmem, _probe_flash),
    KernelContract("fused_adagrad", "fused_adagrad_ref",
                   None, _ag_vmem, _probe_ag),
    KernelContract("fused_adagrad", "fused_adagrad_q8_ref",
                   None, _ag_q8_vmem, _probe_ag_q8),
)


def lint_kernels(geometries: Sequence[Geometry] = DEFAULT_GEOMETRIES
                 ) -> List[Finding]:
    import jax

    from ..kernels import ref as kref

    findings: List[Finding] = []
    seen_oracles = set()

    for c in CONTRACTS:
        # 1. registered jnp oracle
        if c.oracle not in seen_oracles:
            seen_oracles.add(c.oracle)
            if not callable(getattr(kref, c.oracle, None)):
                findings.append(Finding(
                    code="kernel.missing-oracle", severity="error",
                    where=f"kernels/ref.py::{c.oracle}",
                    detail=f"kernel '{c.name}' has no registered jnp "
                           f"oracle — golden tests and the un-fusable "
                           f"fallback both require it"))
                continue

        for g in geometries:
            # flash has its own geometry axis; round kernels skip it
            if (c.name == "flash_attention") != g.name.startswith("flash"):
                continue

            # 2. grid divisibility at the audited geometry
            if c.divisibility is not None:
                ok, rule = c.divisibility(g)
                if not ok:
                    findings.append(Finding(
                        code="kernel.grid-divisibility", severity="error",
                        where=f"kernels/{c.name} @ {g.name}",
                        detail=f"BlockSpec rule {rule} != 0: the fused "
                               f"Pallas path is DISABLED at this "
                               f"geometry and the engine silently takes "
                               f"the jnp reference fallback — resize the "
                               f"block or the call-site shape"))

            # 3. VMEM residency (x2 for double buffering)
            resident = 2 * c.vmem(g)
            budget = int(VMEM_BUDGET * VMEM_HEADROOM)
            if resident > budget:
                findings.append(Finding(
                    code="kernel.vmem-budget", severity="error",
                    where=f"kernels/{c.name} @ {g.name}",
                    detail=f"double-buffered block residency "
                           f"{resident} B exceeds the {budget} B VMEM "
                           f"budget (16 MiB/core x {VMEM_HEADROOM} "
                           f"headroom) — shrink the block shape"))

            # 4. wrapper traces at the audited geometry
            if c.probe is not None:
                fn, args = c.probe(g)
                try:
                    jax.eval_shape(fn, *args)
                except Exception as e:  # noqa: BLE001 - report, not crash
                    findings.append(Finding(
                        code="kernel.probe-failed", severity="error",
                        where=f"kernels/{c.name} @ {g.name}",
                        detail=f"eval_shape probe raised "
                               f"{type(e).__name__}: {e}"))
    return findings


def lint_engine_fusability(celu, B: int, case: str) -> List[Finding]:
    """The engine promises the fused cache path at the audited batch
    geometry; verify the promise is actually live (mirrors
    ``engine._fusable``)."""
    from ..kernels.cosine_weight import BLOCK_B as CW_B
    from ..kernels.fused_sample import BLOCK_B as FS_B

    findings: List[Finding] = []
    for name, blk in (("cosine_weight", CW_B), ("fused_sample", FS_B)):
        bb = min(blk, B)
        if B % bb != 0:
            findings.append(Finding(
                code="kernel.fused-path-disabled", severity="error",
                where=f"kernels/{name} @ B={B}",
                detail=f"audited round geometry B={B} is not divisible "
                       f"by min(BLOCK_B={blk}, B)={bb}: the fused "
                       f"{name} path the config promises silently "
                       f"degrades to the reference fallback",
                case=case))
    return findings
