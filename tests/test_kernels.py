"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


RNG = np.random.default_rng(42)


def _arr(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), jnp.dtype(dtype))


# --------------------------------------------------------------------------
@pytest.mark.parametrize("B,F", [(128, 64), (128, 256), (256, 96),
                                 (384, 512)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("cos_xi", [0.0, 0.5, 0.866])
def test_cosine_weight(B, F, dtype, cos_xi):
    a, s, dz = _arr((B, F), dtype), _arr((B, F), dtype), _arr((B, F), dtype)
    w = ops.cosine_weight(a, s, cos_xi)
    w_ref = ref.cosine_weight_ref(a, s, cos_xi)
    tol = 2e-5 if dtype == "float32" else 3e-2
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", [(128, 32), (128, 4, 16), (256, 8, 8, 4)])
def test_weighted_cotangent(shape):
    a, s, dz = _arr(shape, "float32"), _arr(shape, "float32"), \
        _arr(shape, "float32")
    w, wdz = ops.weighted_cotangent(a, s, dz, 0.3)
    wdz_ref = ref.weighted_cotangent_ref(a, s, dz, 0.3)
    np.testing.assert_allclose(np.asarray(wdz), np.asarray(wdz_ref),
                               rtol=1e-4, atol=1e-5)


def test_cosine_weight_thresholding_exact_zero():
    a = jnp.ones((128, 8), jnp.float32)
    s = -jnp.ones((128, 8), jnp.float32)          # cos = -1 < any threshold
    w = ops.cosine_weight(a, s, 0.5)
    assert (np.asarray(w) == 0.0).all()


# --------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,hd", [(1, 256, 2, 64), (2, 512, 1, 32),
                                      (1, 1024, 2, 128)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 128),
                                           (False, 0)])
def test_flash_attention(B, S, H, hd, causal, window):
    q, k, v = (_arr((B, S, H, hd), "float32") for _ in range(3))
    o = ops.flash_attention(q, k, v, causal=causal, window=window)
    o_ref = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    q, k, v = (_arr((1, 256, 2, 64), "bfloat16") for _ in range(3))
    o = ops.flash_attention(q, k, v)
    o_ref = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32),
        rtol=5e-2, atol=5e-2)


def test_flash_matches_model_blockwise_path():
    """The kernel and the model's _blockwise_sdpa agree (same oracle)."""
    from repro.models import layers as L
    B, S, H, hd = 1, 512, 2, 64
    q, k, v = (_arr((B, S, H, hd), "float32") for _ in range(3))
    pos = jnp.arange(S, dtype=jnp.int32)
    o_model = L._blockwise_sdpa(q, k, v, pos, pos, causal=True, window=0)
    o_kernel = ops.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o_model), np.asarray(o_kernel),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(7,), (1000,), (33, 17), (4, 5, 6),
                                   (1024, 96)])
@pytest.mark.parametrize("lr", [0.01, 0.1])
def test_fused_adagrad(shape, lr):
    g = _arr(shape, "float32")
    acc = jnp.abs(_arr(shape, "float32"))
    u, a2 = ops.fused_adagrad(g, acc, lr, 1e-10)
    ur, ar = ref.fused_adagrad_ref(g, acc, lr, 1e-10)
    np.testing.assert_allclose(np.asarray(u), np.asarray(ur), rtol=1e-6,
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(a2), np.asarray(ar), rtol=1e-6,
                               atol=1e-7)


def test_fused_adagrad_bf16_grad():
    g = _arr((256, 64), "bfloat16")
    acc = jnp.abs(_arr((256, 64), "float32"))
    u, a2 = ops.fused_adagrad(g, acc, 0.01, 1e-10)
    ur, ar = ref.fused_adagrad_ref(g, acc, 0.01, 1e-10)
    np.testing.assert_allclose(np.asarray(u), np.asarray(ur), rtol=1e-5,
                               atol=1e-6)


def test_optimizer_pallas_path_matches_plain():
    """adagrad(use_pallas=True) == adagrad() on a small pytree."""
    from repro.optim import adagrad, apply_updates
    params = {"w": _arr((64, 32), "float32"), "b": _arr((32,), "float32")}
    grads = {"w": _arr((64, 32), "float32"), "b": _arr((32,), "float32")}
    o1, o2 = adagrad(0.05), adagrad(0.05, use_pallas=True)
    s1, s2 = o1.init(params), o2.init(params)
    u1, s1 = o1.update(grads, s1)
    u2, s2 = o2.update(grads, s2)
    for k in params:
        np.testing.assert_allclose(np.asarray(u1[k]), np.asarray(u2[k]),
                                   rtol=1e-6, atol=1e-7)


# --------------------------------------------------------------------------
# flash attention custom-VJP (forward + backward kernels)
# --------------------------------------------------------------------------
import jax  # noqa: E402


@pytest.mark.parametrize("B,S,H,hd,causal,window",
                         [(1, 256, 2, 64, True, 0),
                          (2, 512, 1, 32, True, 128),
                          (1, 256, 2, 64, False, 0)])
def test_flash_vjp_forward_and_backward(B, S, H, hd, causal, window):
    from repro.kernels.flash_attention_bwd import flash_attention_vjp
    q, k, v = (_arr((B, S, H, hd), "float32") for _ in range(3))
    o = flash_attention_vjp(q, k, v, causal, window, True)
    o_ref = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-4)

    f_k = lambda *a: jnp.sum(jnp.sin(
        flash_attention_vjp(*a, causal, window, True)))
    f_r = lambda *a: jnp.sum(jnp.sin(
        ref.flash_attention_ref(*a, causal=causal, window=window)))
    gk = jax.grad(f_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_r, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gk, gr, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4, err_msg=name)
