"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517]. d_ff=0: the xLSTM
cells replace the FFN (pre-up-projection lives inside the cells)."""
from .base import ArchConfig, XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304,
    xlstm=XLSTMConfig(slstm_every=4),
    source="arXiv:2405.04517",
)
