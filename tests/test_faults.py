"""The unreliable-party chaos layer (core/faults.py).

Contracts under test:
  * ``FaultPlan=None`` defers every decision to the base scheduler —
    bit-identical losses AND final state vs ``PipelinedEngine`` at every
    depth (the golden traces pin the base; this pins the wrapper).
  * The fault schedule is a pure function of ``(seed, round)`` —
    deterministic across instances and call orders, so a restored run
    replays the identical fault sequence.
  * A dropped exchange is ABSORBED, not lost: the transport's
    error-feedback residuals swallow the decoded update (``r'' = x + r``
    telescoping), the local scan keeps running on stale cached
    statistics, and training continues to finite losses.
  * A party dropout span freezes exactly that party (params, opt,
    step counters) while the survivors keep local-updating; the rejoin
    needs no ceremony.
  * Checkpointed recovery: ``save_round_state`` + ``host_state`` restore
    into a FRESH engine bit-consistently — the continued run matches the
    uninterrupted one array-for-array.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs.base import CELUConfig, DropoutSpan, FaultPlan
from repro.core import engine
from repro.core.faults import ChaosEngine, ExchangeFate, FaultSchedule
from repro.data.synthetic import TabularSpec, aligned_batches, make_tabular
from repro.models.tabular import DLRMConfig, make_dlrm
from repro.optim import make_optimizer


def _workload():
    spec = TabularSpec("criteo", fields_a=4, fields_b=3, vocab=32,
                       n_train=2048, n_test=512)
    data = make_tabular(spec, seed=0)
    cfg = DLRMConfig("wdl", 4, 3, vocab=32, embed_dim=4, z_dim=8,
                     hidden=(16, 8))
    return data, cfg


def _build(depth, plan=None, *, chaos=True, compression="topk_int8",
           cache_dtype="float32", seed=0):
    data, cfg = _workload()
    init_fn, task, _ = make_dlrm(cfg)
    base = CELUConfig(R=3, W=3, xi_degrees=60.0, cache_dtype=cache_dtype)
    ccfg, nloc = engine.preset_config("celu", base)
    params = init_fn(jax.random.PRNGKey(seed), cfg)
    opt = make_optimizer("adagrad", 0.05)
    asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
    etask = engine.lift_two_party(task)
    tp = engine.make_transport(ccfg, compression)
    it = aligned_batches(data["train"], 64, seed=seed)
    _, ba, bb = next(it)
    state = engine.init_state(etask, engine.lift_two_party_params(params),
                              opt, ccfg, [asj(ba)], asj(bb), transport=tp)
    if chaos:
        pe = ChaosEngine(etask, opt, ccfg, plan=plan, depth=depth,
                         local_steps=nloc, transport=tp)
    else:
        pe = engine.make_pipeline(etask, opt, ccfg, depth=depth,
                                  local_steps=nloc, transport=tp)
    batches = aligned_batches(data["train"], 64, seed=seed)
    return pe, pe.init(state), batches, asj


def _drive(pe, rs, batches, asj, rounds):
    losses = []
    for _ in range(rounds):
        bi, ba, bb = next(batches)
        rs, m = pe.step(rs, [asj(ba)], asj(bb), bi)
        losses.append(float(np.float32(m["loss"])))
    return rs, losses


def _assert_trees_equal(t0, t1):
    l0, l1 = jax.tree_util.tree_leaves(t0), jax.tree_util.tree_leaves(t1)
    assert len(l0) == len(l1)
    for a, b in zip(l0, l1):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# FaultPlan=None: bit-identical to the base scheduler
# --------------------------------------------------------------------------
@pytest.mark.parametrize("depth", [0, 1, 2])
def test_plan_none_bit_identical(depth):
    pe0, rs0, it0, asj = _build(depth, chaos=False)
    rs0, l0 = _drive(pe0, rs0, it0, asj, 10)
    rs0, _ = pe0.flush(rs0)
    st0 = pe0.finalize(rs0)

    pe1, rs1, it1, asj = _build(depth, plan=None, chaos=True)
    rs1, l1 = _drive(pe1, rs1, it1, asj, 10)
    rs1, _ = pe1.flush(rs1)
    st1 = pe1.finalize(rs1)

    np.testing.assert_array_equal(np.asarray(l0, np.float32),
                                  np.asarray(l1, np.float32))
    _assert_trees_equal(st0, st1)


# --------------------------------------------------------------------------
# Deterministic schedule
# --------------------------------------------------------------------------
def test_fault_schedule_deterministic():
    plan = FaultPlan(seed=11, drop_prob=0.4, max_retries=3,
                     straggler_prob=0.5, straggler_rounds=4)
    a, b = FaultSchedule(plan), FaultSchedule(plan)
    # same (seed, t) -> same fate, regardless of instance or call order
    fates_fwd = [a.exchange_fate(t) for t in range(50)]
    fates_rev = [b.exchange_fate(t) for t in reversed(range(50))][::-1]
    assert fates_fwd == fates_rev
    # a different seed decorrelates
    c = FaultSchedule(dataclasses.replace(plan, seed=12))
    assert fates_fwd != [c.exchange_fate(t) for t in range(50)]
    # attempts bounded by max_retries + 1; delays within the span
    for f in fates_fwd:
        assert 1 <= f.attempts <= 4
        assert 0 <= f.delay_rounds <= 4
        if not f.delivered:
            assert f.attempts == 4 and f.delay_rounds == 0
    # fault-free plan short-circuits to a constant fate
    quiet = FaultSchedule(FaultPlan(seed=0))
    assert quiet.exchange_fate(7) == ExchangeFate(True, 1, 0)


def test_dropout_span_and_mask():
    plan = FaultPlan(dropouts=(DropoutSpan(party="a0", start=3, rounds=2),
                               DropoutSpan(party="b", start=4, rounds=1)))
    sched = FaultSchedule(plan)
    assert sched.down(2) == ()
    assert sched.down(3) == ("a0",)
    assert set(sched.down(4)) == {"a0", "b"}
    assert sched.down(5) == ()
    mask = np.asarray(sched.party_mask(4, K=2))
    np.testing.assert_array_equal(mask, [0.0, 1.0, 0.0])
    assert sched.party_mask(2, K=2) is None
    # "a1" names a feature party a K=1 engine doesn't have — it must NOT
    # silently land on slot 1 (party b's)
    bad = FaultSchedule(FaultPlan(
        dropouts=(DropoutSpan(party="a1", start=0, rounds=1),)))
    with pytest.raises(ValueError, match="K=1"):
        bad.party_mask(0, K=1)


# --------------------------------------------------------------------------
# Drop-absorb: the error-feedback telescoping survives as delay
# --------------------------------------------------------------------------
def test_recover_dropped_absorbs_decoded_update():
    celu = CELUConfig()
    tp = engine.make_transport(celu, "topk_int8")
    z = [jax.random.normal(jax.random.PRNGKey(0), (32, 8))]
    dz = [jax.random.normal(jax.random.PRNGKey(1), (32, 8))]
    ts = tp.init_state(z)
    rng = jax.random.PRNGKey(2)
    assert set(tp.stateful_directions) == {"up", "down"}
    z_wire, r_up = tp.send(rng, z[0], ts["up"][0], "up")
    dz_wire, r_down = tp.send(rng, dz[0], ts["down"][0], "down")
    ts2 = {"up": [r_up], "down": [r_down]}
    fresh = {"tstate": ts2, "zs": [z_wire], "dzs": [dz_wire]}
    rec = tp.recover_dropped(fresh)
    # post-send residual r' = (x + r) - y; absorbing the lost decoded y
    # gives r'' = r' + y = x + r — the NEXT successful send transmits the
    # accumulated signal, so the dropped update is delayed, never lost.
    for d, x in (("up", z[0]), ("down", dz[0])):
        xw = tp._wire_cast(x).astype(jnp.float32)
        np.testing.assert_allclose(
            np.asarray(rec[d][0]), np.asarray(xw + ts[d][0]),
            rtol=1e-6, atol=1e-6)
    # stateless transport: graceful no-op (update simply lost)
    tp_plain = engine.SimWANTransport(celu)
    ts_p = tp_plain.init_state(z)
    assert tp_plain.recover_dropped({"tstate": ts_p}) is ts_p


def test_dropped_exchange_training_continues():
    """Every exchange in the run is lost (seed 3 drops all 6 rounds at
    p=0.95 with no retry).  The scan must keep running on the initial
    cached statistics, states stay finite, and no merge ever lands
    (comm_rounds pinned at 0)."""
    plan = FaultPlan(seed=3, drop_prob=0.95, max_retries=0)
    pe, rs, it, asj = _build(1, plan=plan)
    rs, losses = _drive(pe, rs, it, asj, 6)
    assert all(np.isnan(x) for x in losses)      # no merge -> no loss obs
    assert pe.counters["drops"] == 6
    assert pe.counters["merges"] == 0
    assert pe.counters["wire_attempts"] == 6     # 1 attempt per round
    assert int(rs.comm_rounds) == 0
    for leaf in jax.tree_util.tree_leaves(rs.params):
        assert np.isfinite(np.asarray(leaf)).all()
    rs, _ = pe.flush(rs)
    pe.finalize(rs)                              # drains clean


# --------------------------------------------------------------------------
# Dropout span: freeze exactly the down party, elastic rejoin
# --------------------------------------------------------------------------
def test_dropout_recovery_smoke():
    """One party down for a span mid-training: its tower freezes, the
    survivors keep stepping, and after the rejoin everyone advances
    again.  Cheap — the CI fast lane runs this."""
    span = DropoutSpan(party="a0", start=3, rounds=3)
    plan = FaultPlan(seed=0, dropouts=(span,))
    pe, rs, it, asj = _build(1, plan=plan)
    rs, _ = _drive(pe, rs, it, asj, 3)           # up to the span
    frozen_a = jax.tree_util.tree_map(np.asarray, rs.params["a"][0])
    steps_a = int(rs.steps["a"][0])
    sb_before = int(rs.steps["b"])
    rs, _ = _drive(pe, rs, it, asj, 3)           # the down span
    _assert_trees_equal(frozen_a, rs.params["a"][0])
    assert int(rs.steps["a"][0]) == steps_a      # frozen counter too
    assert int(rs.steps["b"]) > sb_before        # survivor kept stepping
    assert pe.counters["dropout_rounds"] == 3
    rs, losses = _drive(pe, rs, it, asj, 4)      # elastic rejoin
    assert int(rs.steps["a"][0]) > steps_a
    assert any(np.isfinite(x) for x in losses)
    rs, _ = pe.flush(rs)
    st = pe.finalize(rs)
    for leaf in jax.tree_util.tree_leaves(st["params"]):
        assert np.isfinite(np.asarray(leaf)).all()


def test_straggler_defers_merge():
    """Every exchange arrives one round late at depth 1: merges lag the
    schedule (stall rounds appear) but nothing is lost — by the flush,
    every dispatched exchange has merged exactly once."""
    plan = FaultPlan(seed=5, straggler_prob=1.0, straggler_rounds=1)
    pe, rs, it, asj = _build(1, plan=plan)
    rs, _ = _drive(pe, rs, it, asj, 8)
    assert pe.counters["stalls"] > 0
    rs, _ = pe.flush(rs)
    st = pe.finalize(rs)
    assert int(st["comm_rounds"]) == pe.counters["dispatches"]
    assert pe.counters["merges"] == pe.counters["dispatches"]


# --------------------------------------------------------------------------
# Checkpointed recovery: bit-consistent resume into a FRESH engine
# --------------------------------------------------------------------------
def test_chaos_checkpoint_resume_bit_exact(tmp_path):
    plan = FaultPlan(seed=9, drop_prob=0.25, max_retries=1,
                     straggler_prob=0.3, straggler_rounds=2,
                     dropouts=(DropoutSpan(party="a0", start=5, rounds=2),))
    # uninterrupted reference: 12 rounds + flush
    pe0, rs0, it0, asj = _build(2, plan=plan)
    rs0, l0 = _drive(pe0, rs0, it0, asj, 12)
    rs0, _ = pe0.flush(rs0)
    st0 = pe0.finalize(rs0)

    # interrupted run: 7 rounds, checkpoint, DISCARD the engine
    pe1, rs1, it1, asj = _build(2, plan=plan)
    rs1, l1a = _drive(pe1, rs1, it1, asj, 7)
    path = str(tmp_path / "chaos.npz")
    ckpt.save_round_state(path, rs1, extra=pe1.host_state())
    n_pend = len(rs1.pending)
    del pe1, rs1

    # fresh engine; fabricate a reference with n_pend dispatches
    pe2, rs_ref, it_ref, asj = _build(2, plan=plan)
    for _ in range(n_pend):
        bi, ba, bb = next(it_ref)
        rs_ref = pe2.dispatch(rs_ref, [asj(ba)], asj(bb), bi)
    host_ref = {"now": 0, "dispatch_seq": 0, "arrival": [0] * n_pend,
                "dispatch_round": [0] * n_pend, "last_merged_dispatch": 0}
    rs2, host = ckpt.restore_round_state(path, rs_ref,
                                         extra_reference=host_ref)
    pe2.load_host_state(host)

    # replay the consumed batch prefix, then continue 5 more rounds
    it2 = iter(it1)   # it1 is already positioned after round 7
    rs2, l1b = _drive(pe2, rs2, it2, asj, 5)
    rs2, _ = pe2.flush(rs2)
    st2 = pe2.finalize(rs2)

    np.testing.assert_array_equal(np.asarray(l0, np.float32),
                                  np.asarray(l1a + l1b, np.float32))
    _assert_trees_equal(st0, st2)


# --------------------------------------------------------------------------
# Chaos + flush drain after a dropped dispatch
# --------------------------------------------------------------------------
def test_flush_after_drop_drains_clean():
    """A dropped dispatch leaves the depth-2 queue under-filled; flush
    must drain what IS there (merge order by dispatch), never
    double-merge, and finalize."""
    plan = FaultPlan(seed=2, drop_prob=0.35, max_retries=0)
    pe, rs, it, asj = _build(2, plan=plan)
    rs, _ = _drive(pe, rs, it, asj, 9)
    assert pe.counters["drops"] > 0              # seed 2 drops in 9 rounds
    n_pending = len(rs.pending)
    merges_before = pe.counters["merges"]
    rs, _ = pe.flush(rs)
    assert not rs.pending
    assert pe.counters["merges"] == merges_before + n_pending
    st = pe.finalize(rs)
    assert int(st["comm_rounds"]) == pe.counters["merges"]
    assert int(st["comm_rounds"]) == \
        pe.counters["dispatches"] - pe.counters["drops"]
