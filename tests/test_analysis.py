"""Tier-1 tests for the static boundary auditor (src/repro/analysis).

Covers: marker transparency, taint-lattice semantics, a clean audit over
the quick matrix, the pod path, report serialization, and — the part
that keeps the analyzer honest — every seeded mutation being caught with
a finding that names the offender.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.audit import (AuditCase, default_cases, run_audit,
                                  trace_case, trace_pod_case)
from repro.analysis.kernel_lint import lint_kernels
from repro.analysis.markers import (boundary_order, boundary_requirements,
                                    mark)
from repro.analysis.report import AuditReport, CaseResult, Finding
from repro.analysis.selftest import run_selftest
from repro.analysis.taint import EMPTY, Taint, join, raw_of, sanitize
from repro.configs.base import CELUConfig
from repro.core.engine import (CompressedWANTransport, SimWANTransport,
                               make_transport)


# --------------------------------------------------------------------------
# markers
# --------------------------------------------------------------------------
def test_mark_is_identity():
    x = jnp.arange(12.0).reshape(3, 4)
    y = mark({"a": x, "b": [x + 1]}, role="sanitizer", name="wire")
    np.testing.assert_array_equal(np.asarray(y["a"]), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(y["b"][0]), np.asarray(x + 1))


def test_mark_is_identity_under_jit():
    @jax.jit
    def f(x):
        return mark(x, role="sanitizer", name="wire") * 2.0

    np.testing.assert_allclose(f(jnp.ones(4)), 2.0 * np.ones(4))


def test_boundary_requirements_per_transport():
    celu = CELUConfig()
    assert boundary_requirements(SimWANTransport(celu), celu, "up") == \
        ("wire",)
    dp = CELUConfig(dp_sigma=0.3)
    assert boundary_requirements(SimWANTransport(dp), dp, "up") == \
        ("wire", "dp")
    tp = make_transport(CELUConfig(compression="int8"))
    assert boundary_requirements(tp, CELUConfig(compression="int8"),
                                 "up") == ("wire", "encode")
    dp_tp = make_transport(CELUConfig(compression="int8", dp_sigma=0.3))
    cfg = CELUConfig(compression="int8", dp_sigma=0.3)
    assert boundary_requirements(dp_tp, cfg, "up") == \
        ("wire", "encode", "dp")
    # ordering constraint only exists for DP over a LOSSY codec
    assert boundary_order(dp_tp, cfg, "up") == (("encode", "dp"),)
    assert boundary_order(tp, CELUConfig(compression="int8"), "up") == ()
    ident = make_transport(CELUConfig(compression="identity",
                                      dp_sigma=0.3))
    assert isinstance(ident, CompressedWANTransport)
    assert boundary_order(ident, CELUConfig(compression="identity",
                                            dp_sigma=0.3), "up") == ()


# --------------------------------------------------------------------------
# taint lattice
# --------------------------------------------------------------------------
def test_taint_join_unions_raw_and_intersects_san():
    a = sanitize(raw_of("a0"), "wire", 3)
    b = sanitize(sanitize(raw_of("b"), "wire", 5), "encode", 7)
    j = join([a, b])
    assert j.raw == frozenset({"a0", "b"})
    assert j.san_names == frozenset({"wire"})       # encode not shared
    assert j.san_idx("wire") == 3                   # earliest application


def test_taint_join_untainted_inputs_do_not_constrain():
    t = sanitize(raw_of("a0"), "dp", 2)
    j = join([t, EMPTY])
    assert j.san_names == frozenset({"dp"})
    assert join([EMPTY, EMPTY]) == EMPTY


def test_taint_is_hashable_and_frozen():
    t = sanitize(raw_of("a0"), "wire", 1)
    assert isinstance(hash(t), int)
    with pytest.raises(Exception):
        t.raw = frozenset()


# --------------------------------------------------------------------------
# clean audits
# --------------------------------------------------------------------------
def test_quick_matrix_is_clean():
    rep = run_audit(default_cases(quick=True), include_pod=False,
                    include_kernel_lint=True)
    assert rep.passed, rep.render(verbose=True)
    # positive assurance: the traces really contained the boundary marks
    # and the fused pallas kernels, or the audit proved nothing
    traced = [c for c in rep.cases if "boundaries" in c.stats]
    assert traced and all(c.stats["boundaries"] >= 2 for c in traced)
    assert any(c.stats.get("pallas_calls", 0) > 0 for c in traced)


def test_depth_queue_case_audits_two_chained_dispatches():
    r = trace_case(AuditCase(name="d4", K=2, depth=4,
                             compression="topk_int8", cache_dtype="int8",
                             dp_sigma=0.3))
    assert not r.errors, [f.detail for f in r.errors]
    # 2 parties x (up + down) x 2 chained exchange dispatches
    assert r.stats["boundaries"] == 8


def test_fleet_case_audits_batched_state_clean():
    """The vmapped fleet step (jobs stacked on a leading axis) passes the
    same taint / ordering / byte-ledger analyses, with ONE boundary mark
    per direction whose aval carries the job axis."""
    from repro.analysis.audit import AUDIT_B, AUDIT_Z, trace_fleet_case

    r = trace_fleet_case(jobs=3)
    assert not r.errors, [f.detail for f in r.errors]
    assert r.config["jobs"] == 3
    # 1 party x (up + down) x 1 dispatch per step — batched, not unrolled:
    # an unrolled job axis would triple the boundary count
    assert r.stats["boundaries"] == 2
    assert r.stats["jobs"] == 3
    assert r.stats["pallas_calls"] > 0


def test_fleet_case_boundary_shapes_carry_job_axis():
    """audit_wire(jobs=N) must reject a boundary whose aval LOST the job
    axis (the batching rule silently dropping marks would otherwise look
    like a clean, narrower trace)."""
    from repro.analysis.audit import AUDIT_B, AUDIT_Z, trace_fleet_case
    from repro.analysis.taint import BoundaryRecord, TraceAudit
    from repro.analysis.wire_audit import audit_wire
    from repro.configs.base import CELUConfig
    from repro.core import engine as E

    celu = CELUConfig()
    tp = E.make_transport(celu)
    trace = TraceAudit(case="shape-probe")
    for i, d in enumerate(("up", "down")):
        trace.boundaries[i] = BoundaryRecord(
            direction=d, party=0, transport="SimWANTransport",
            shape=(AUDIT_B, AUDIT_Z), dtype="float32", satisfied=True)
    findings, _ = audit_wire(tp, celu, [(AUDIT_B, AUDIT_Z)], trace,
                             n_computes=1, case="shape-probe", jobs=3)
    shape_errs = [f for f in findings if f.code == "wire.boundary-shape"]
    assert len(shape_errs) == 2, [f.detail for f in findings]


def test_pod_case_runs_or_skips_cleanly():
    r = trace_pod_case()
    assert not r.errors, [f.detail for f in r.errors]
    if len(jax.devices()) >= 2:
        assert r.stats["boundaries"] == 2


def test_kernel_contracts_clean_at_default_geometries():
    assert lint_kernels() == []


# --------------------------------------------------------------------------
# seeded mutations: each planted bug must be caught, naming the offender
# --------------------------------------------------------------------------
def test_seeded_mutations_all_caught():
    ok, results = run_selftest()
    missed = [m.name for m in results if not m.caught]
    assert ok, f"analyzer missed planted bug(s): {missed}"
    assert [m.name for m in results] == [
        "raw-send", "under-count", "bad-blockspec", "noise-before-encode",
        "fleet-raw-send"]


def test_raw_send_mutation_names_party_and_direction():
    from repro.analysis.selftest import _mut_raw_send
    m = _mut_raw_send()
    assert m.caught
    assert any("up:0" in e or "down:0" in e for e in m.errors)


# --------------------------------------------------------------------------
# report plumbing
# --------------------------------------------------------------------------
def test_report_json_roundtrip(tmp_path):
    rep = AuditReport(cases=[CaseResult(
        name="c", config={"K": 1},
        findings=[Finding(code="taint.raw-boundary", severity="error",
                          where="x", detail="d", case="c")],
        stats={"boundaries": 2})], meta={"jax": jax.__version__})
    path = tmp_path / "AUDIT.json"
    rep.write_json(str(path))
    d = json.loads(path.read_text())
    assert d["version"] == 1
    assert d["passed"] is False
    assert d["summary"]["error"] == 1
    assert d["cases"][0]["findings"][0]["code"] == "taint.raw-boundary"
    assert not rep.passed
    assert "AUDIT FAILED" in rep.render()


def test_finding_rejects_unknown_severity():
    with pytest.raises(AssertionError):
        Finding(code="x", severity="catastrophic", where="w", detail="d")
