"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.weighting import instance_weights, row_cosine, xi_to_cos
from repro.core.workset import workset_init, workset_insert, workset_sample
from repro.kernels import ref as kref
from repro.models.tabular import auc


# --------------------------------------------------------------------------
# Weighting invariants
# --------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.integers(1, 16), st.integers(2, 32), st.floats(0.0, 0.99),
       st.integers(0, 2 ** 31 - 1))
def test_weights_bounded_and_thresholded(B, F, cos_xi, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    s = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    w = np.asarray(instance_weights(a, s, cos_xi))
    assert ((w == 0.0) | (w >= cos_xi - 1e-6)).all()
    assert (w <= 1.0 + 1e-6).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 8), st.integers(2, 16), st.integers(0, 2 ** 31 - 1),
       st.floats(0.1, 10.0))
def test_cosine_scale_invariant(B, F, seed, scale):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    s = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    c1 = np.asarray(row_cosine(a, s))
    c2 = np.asarray(row_cosine(a * scale, s))
    np.testing.assert_allclose(c1, c2, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.floats(0.0, 180.0))
def test_xi_to_cos_monotone(xi):
    assert -1.0 - 1e-9 <= xi_to_cos(xi) <= 1.0 + 1e-9
    if xi < 90.0:
        assert xi_to_cos(xi) > 0


# --------------------------------------------------------------------------
# Workset invariants under arbitrary op sequences
# --------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(st.integers(1, 6), st.integers(1, 5),
       st.lists(st.booleans(), min_size=1, max_size=40),
       st.sampled_from(["round_robin", "consecutive"]))
def test_workset_never_overuses(W, R, ops, strategy):
    """No entry is ever sampled more than R times, and every sampled entry
    is one of the W most recent inserts."""
    entry = lambda v: {"z_a": jnp.full((1, 2), float(v)),
                       "dz_a": jnp.zeros((1, 2)), "batch": {}}
    ws = workset_init(W, entry(0))
    n_ins = 0
    uses = {}
    for is_insert in ops:
        if is_insert or n_ins == 0:
            ws = workset_insert(ws, entry(n_ins), n_ins)
            n_ins += 1
        else:
            ws, e, bidx, valid = workset_sample(ws, R, strategy)
            if bool(valid):
                b = int(bidx)
                uses[b] = uses.get(b, 0) + 1
                assert b >= n_ins - W, (b, n_ins, W)
                assert uses[b] <= R


# --------------------------------------------------------------------------
# Kernel oracles as algebraic properties
# --------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_flash_ref_softmax_rows_sum_to_one_effect(seed):
    """Attention output lies in the convex hull of V rows (causal)."""
    rng = np.random.default_rng(seed)
    B, S, H, hd = 1, 8, 1, 4
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.uniform(0, 1, size=(B, S, H, hd)), jnp.float32)
    o = np.asarray(kref.flash_attention_ref(q, k, v, causal=True))
    assert (o >= -1e-5).all() and (o <= 1.0 + 1e-5).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 64), st.integers(0, 2 ** 31 - 1))
def test_adagrad_update_opposes_gradient(n, seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    acc = jnp.asarray(np.abs(rng.normal(size=(n,))), jnp.float32)
    u, a2 = kref.fused_adagrad_ref(g, acc, 0.1, 1e-10)
    assert (np.sign(np.asarray(u)) == -np.sign(np.asarray(g))
            )[np.asarray(g) != 0].all()
    assert (np.asarray(a2) >= np.asarray(acc)).all()


# --------------------------------------------------------------------------
# AUC
# --------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.integers(4, 200), st.integers(0, 2 ** 31 - 1))
def test_auc_perfect_and_random(n, seed):
    rng = np.random.default_rng(seed)
    y = (rng.random(n) < 0.5).astype(np.float32)
    if y.sum() in (0, n):
        return
    assert auc(y * 2 - 1, y) == 1.0         # perfectly ranked
    assert auc(-(y * 2 - 1), y) == 0.0      # perfectly anti-ranked
    a = auc(rng.normal(size=n), y)
    assert 0.0 <= a <= 1.0
