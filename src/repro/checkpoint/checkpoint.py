"""Pytree checkpointing to .npz (flat key paths), no external deps.

Per-party checkpoints: in a real deployment each party persists only its own
tower (privacy discipline) — ``save(path, state, party="a")`` selects the
corresponding subtree.  Restore rebuilds into the exact reference pytree, so
shapes/dtypes are validated on load.

Storage rules (all round-trips are BIT-exact):

  * bf16 leaves are stored natively as a ``uint16`` bit-view — the
    historical fp32 detour doubled the bytes and, worse, made
    save→restore a value-preserving but REPRESENTATION-changing trip for
    any downstream consumer that compared serialized forms.  Legacy
    checkpoints with fp32-stored bf16 still restore (value cast).
  * Custom pytree leaves registered without key paths (the workset
    cache's ``QuantLeaf``/``CastLeaf``/``Quant4Leaf``, the quantized
    optimizer's ``QuantAccum``) flatten through ``FlattenedIndexKey`` —
    their int8 codes, packed uint8 nibbles, and fp32 scales land in the
    file unchanged (no fp32 round-trip; an int4 ring checkpoints at int4
    size).
  * Python scalar leaves (host-side counters) are stored as 0-d arrays
    and restored to their reference's python type.

``save_round_state`` / ``restore_round_state`` persist a FULL scheduler
:class:`repro.core.engine.RoundState` — params, optimizer, workset rings,
transport error-feedback residuals, AND the in-flight exchange queue
(``PendingExchange`` slots incl. ``dispatched_at`` and the ride-along
batches) — so a run interrupted mid-pipeline (or killed by the chaos
layer) resumes bit-consistently.  The restore reference must carry the
same queue depth; the file records it so a mismatch fails with a clear
message instead of a missing-key maze.
"""
from __future__ import annotations

import os
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"
_PENDING_META = "__round_state__" + _SEP + "pending_len"


def _key_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return p.name
    if isinstance(p, jax.tree_util.FlattenedIndexKey):
        return str(p.key)
    return str(p)


def _to_numpy(leaf) -> np.ndarray:
    """Host array for one leaf; bf16 as its uint16 bit pattern (numpy has
    no native bf16 and the fp32 detour breaks bit-exactness guarantees
    for consumers comparing serialized forms)."""
    if getattr(leaf, "dtype", None) == jnp.bfloat16:
        return np.asarray(leaf).view(np.uint16)
    return np.asarray(leaf)


def _from_numpy(arr: np.ndarray, ref):
    """Rebuild one leaf into its reference's type/dtype (validated)."""
    ref_arr = np.asarray(ref)
    if tuple(arr.shape) != tuple(ref_arr.shape):
        raise ValueError(f"shape {arr.shape} != {ref_arr.shape}")
    if isinstance(ref, (bool, int, float)):
        return type(ref)(arr.item())
    ref_dtype = getattr(ref, "dtype", ref_arr.dtype)
    if ref_dtype == jnp.bfloat16 and arr.dtype == np.uint16:
        return jnp.asarray(arr).view(jnp.bfloat16)   # native bf16 storage
    return jnp.asarray(arr, dtype=ref_dtype)         # incl. legacy fp32->bf16
    # (value cast; new-format checkpoints round-trip bf16 bit-exactly)


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_SEP.join(_key_str(p) for p in path)] = _to_numpy(leaf)
    return flat


def _unflatten(flat: dict, reference):
    leaves_ref, _ = jax.tree_util.tree_flatten_with_path(reference)
    out = []
    for pathkeys, ref in leaves_ref:
        key = _SEP.join(_key_str(p) for p in pathkeys)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        try:
            out.append(_from_numpy(flat[key], ref))
        except ValueError as e:
            raise ValueError(f"{key}: {e}") from None
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(reference), out)


def save(path: str, tree: Any, party: Optional[str] = None) -> None:
    if party is not None:
        tree = {party: tree[party]} if isinstance(tree, dict) else tree
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def restore(path: str, reference: Any) -> Any:
    """Load into the structure of ``reference`` (shape/dtype checked)."""
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    return _unflatten(flat, reference)


# --------------------------------------------------------------------------
# Full scheduler-state checkpoints (pipeline- and fault-aware)
# --------------------------------------------------------------------------
def save_round_state(path: str, rs, extra: Any = None) -> None:
    """Persist a full :class:`RoundState` — including the in-flight
    ``pending`` exchange queue — plus an optional ``extra`` pytree (e.g.
    ``ChaosEngine.host_state()``)."""
    tree = {"state": rs.as_state(), "pending": tuple(rs.pending)}
    if extra is not None:
        tree["extra"] = extra
    flat = _flatten(tree)
    flat[_PENDING_META] = np.asarray(len(rs.pending))
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **flat)


def peek_pending_len(path: str) -> int:
    """In-flight queue depth recorded in a ``save_round_state`` file —
    read it FIRST, fabricate a reference with that many dispatches, then
    :func:`restore_round_state`."""
    with np.load(path) as data:
        if _PENDING_META not in data.files:
            raise KeyError(
                f"{path} is not a round-state checkpoint (missing "
                f"{_PENDING_META!r})")
        return int(data[_PENDING_META])


def restore_round_state(path: str, reference,
                        extra_reference: Any = None) -> Tuple[Any, Any]:
    """Rebuild a :class:`RoundState` (and the optional extra pytree) from
    a ``save_round_state`` checkpoint.

    ``reference`` must be a RoundState with the SAME in-flight queue
    depth and slot structure — after a restart, fabricate one by driving
    a freshly built engine the same number of dispatches (any batches:
    only structure/shape/dtype matter, every value is overwritten).
    Returns ``(round_state, extra)``; ``extra`` is None when no
    ``extra_reference`` is given."""
    from ..core.engine import RoundState

    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    if _PENDING_META not in flat:
        raise KeyError(
            f"{path} is not a round-state checkpoint (missing "
            f"{_PENDING_META!r}) — use restore() for plain pytrees")
    n = int(flat.pop(_PENDING_META))
    if n != len(reference.pending):
        raise ValueError(
            f"checkpoint holds {n} in-flight exchange(s) but the "
            f"reference RoundState holds {len(reference.pending)} — "
            f"rebuild the reference with {n} dispatch(es) before "
            f"restoring")
    tree = {"state": reference.as_state(),
            "pending": tuple(reference.pending)}
    if extra_reference is not None:
        tree["extra"] = extra_reference
    restored = _unflatten(flat, tree)
    rs = RoundState.from_state(restored["state"],
                               tuple(restored["pending"]))
    return rs, restored.get("extra")
