"""hymba-1.5b — hybrid parallel attn+mamba heads [arXiv:2411.13676]."""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab_size=32001, head_dim=64,
    ssm=SSMConfig(state_dim=16, conv_dim=4, expand=2),
    source="arXiv:2411.13676",
)
