"""Fused stochastic-rounding quantize-with-scale kernel (the compressed
wire's encode hot path).

The naive composition (per-tile absmax reduction, scale division, add
uniform noise, floor, clip, narrow) makes three HBM round-trips over the
(T, L) value tiles.  This kernel fuses all of it into ONE VMEM pass: each
grid step loads a (BLOCK_T, L) block of value tiles plus the matching
pre-drawn uniforms, reduces the per-tile absmax on the VPU, and writes the
int8 codes and the (BLOCK_T,) fp32 scales.

Layout decisions for TPU:
  * quantization tiles on the sublane axis, the L values of a tile on the
    lane axis — the absmax is a lane reduction, natively supported;
  * the uniform noise is an OPERAND, not in-kernel PRNG: the caller draws
    it with ``jax.random`` so the kernel is a deterministic function of
    (x, u) and bit-exact against the pure-jnp oracle
    (``kernels.ref.quantize_sr_ref``) — the parity tests rely on this;
  * ``levels`` rides in as a (1,) operand (127 for int8, 7 for int4), so
    one compiled kernel serves every bit width;
  * fp32 scale math regardless of input dtype (bf16 upcast in VMEM).

Callers flatten/pad to (T, L) tiles (see ``core.compression``); T not
divisible by BLOCK_T falls back to the reference there.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-12
BLOCK_T = 128


def _kernel(x_ref, u_ref, levels_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)           # (BLOCK_T, L)
    u = u_ref[...].astype(jnp.float32)
    levels = levels_ref[0]

    amax = jnp.max(jnp.abs(x), axis=1)           # lane reduction -> (BLOCK_T,)
    scale = jnp.maximum(amax, EPS) / levels
    q = jnp.floor(x / scale[:, None] + u)        # stochastic rounding
    q = jnp.clip(q, -levels, levels)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_sr_2d(x, u, levels, *, interpret: bool = True):
    """x: (T, L) values, u: (T, L) uniforms in [0, 1), levels: scalar max
    code magnitude.  -> (codes int8 (T, L), scales fp32 (T,))."""
    T, L = x.shape
    bt = min(BLOCK_T, T)
    assert T % bt == 0, (T, bt)
    lv = jnp.asarray([levels], jnp.float32)

    return pl.pallas_call(
        _kernel,
        grid=(T // bt,),
        in_specs=[
            pl.BlockSpec((bt, L), lambda i: (i, 0)),
            pl.BlockSpec((bt, L), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bt, L), lambda i: (i, 0)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, L), jnp.int8),
            jax.ShapeDtypeStruct((T,), jnp.float32),
        ],
        interpret=interpret,
    )(x, u, lv)
