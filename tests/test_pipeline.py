"""The pipelined exchange/local-update scheduler (paper §4.1, Fig. 4).

Depth 0 must reproduce the sequential ``make_round`` BIT-FOR-BIT on the
K=1 and K=3 golden traces (the staged stages are the same functions the
fused round composes).  Depth 1 overlaps round t+1's exchange with round
t's local updates: not bit-identical by design (one extra exchange of
staleness), but it must train to the same quality, keep honest step
counters, and respect the pipeline-staleness plumbing (workset validity
window + Algorithm-2 weight attenuation).  The WANClock that prices the
two schedules is tested alongside.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CELUConfig
from repro.core import engine
from repro.core.weighting import pipeline_attenuation
from repro.core.workset import (workset_init, workset_insert,
                                workset_sample)
from repro.data.synthetic import TabularSpec, aligned_batches, make_tabular
from repro.models.tabular import DLRMConfig, make_dlrm
from repro.optim import make_optimizer
from repro.launch.wan import WANClock, transport_round_updown, wan_seconds

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "two_party_trace.json")
GOLDEN3 = os.path.join(os.path.dirname(__file__), "golden",
                       "three_party_trace.json")


def _workload():
    spec = TabularSpec("criteo", fields_a=4, fields_b=3, vocab=32,
                       n_train=2048, n_test=512)
    data = make_tabular(spec, seed=0)
    cfg = DLRMConfig("wdl", 4, 3, vocab=32, embed_dim=4, z_dim=8,
                     hidden=(16, 8))
    return data, cfg


def _run_pipelined(protocol, depth, rounds=20, compression=None):
    """Drive the two-party golden workload through PipelinedEngine and
    return golden-comparable rows (same schema as test_engine._run_trace)."""
    data, cfg = _workload()
    init_fn, task, predict = make_dlrm(cfg)
    base = CELUConfig(R=3, W=3, xi_degrees=60.0)
    ccfg, nloc = engine.preset_config(protocol, base)
    params = init_fn(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adagrad", 0.05)
    it = aligned_batches(data["train"], 64, seed=0)
    _, ba, bb = next(it)
    asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
    kw = {} if compression is None else \
        {"transport": engine.make_transport(ccfg, compression)}
    etask = engine.lift_two_party(task)
    state = engine.init_state(etask, engine.lift_two_party_params(params),
                              opt, ccfg, [asj(ba)], asj(bb), **kw)
    pe = engine.make_pipeline(etask, opt, ccfg, depth=depth,
                              local_steps=nloc, **kw)
    rs = pe.init(state)
    it = aligned_batches(data["train"], 64, seed=0)
    rows = []
    for i in range(rounds):
        bi, ba, bb = next(it)
        rs, m = pe.step(rs, [asj(ba)], asj(bb), bi)
        rows.append({"loss": float(np.float32(m["loss"])),
                     "w_mean": float(np.float32(m["w_mean"])),
                     "w_zero_frac": float(np.float32(m["w_zero_frac"])),
                     "local_steps": int(m["local_steps"])})
    rs, _ = pe.flush(rs)
    st = pe.finalize(rs)
    rows.append({"steps_a": int(st["steps"]["a"][0]),
                 "steps_b": int(st["steps"]["b"]),
                 "comm_rounds": int(st["comm_rounds"])})
    return rows


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def golden3():
    with open(GOLDEN3) as f:
        return json.load(f)


# --------------------------------------------------------------------------
# Depth 0: the staged pipeline IS the sequential round
# --------------------------------------------------------------------------
@pytest.mark.parametrize("protocol", ["vanilla", "fedbcd", "celu"])
def test_depth0_matches_golden_two_party(protocol, golden):
    """dispatch -> merge -> local at depth 0 reproduces the seed
    implementation bit-for-bit on the K=1 golden traces."""
    got = _run_pipelined(protocol, depth=0)
    assert got == golden[protocol]


def test_depth0_matches_golden_two_party_identity_codec(golden):
    got = _run_pipelined("celu", depth=0, compression="identity")
    assert got == golden["celu"]


def test_depth0_matches_golden_three_party(golden3):
    """The K=3 multiparty workload through the depth-0 pipeline equals the
    K=3 golden trace bit-for-bit."""
    from test_engine import _three_party_workload
    task, celu, opt, data, split, params = _three_party_workload()
    it = aligned_batches(data["train"], 64, seed=0)
    _, ba, bb = next(it)
    bas, b = split(ba, bb)
    state = engine.init_state(task, params, opt, celu, bas, b)
    pe = engine.make_pipeline(task, opt, celu, depth=0)
    rs = pe.init(state)
    it = aligned_batches(data["train"], 64, seed=0)
    rows = []
    for i in range(20):
        bi, ba, bb = next(it)
        bas, b = split(ba, bb)
        rs, m = pe.step(rs, bas, b, bi)
        rows.append({"loss": float(np.float32(m["loss"])),
                     "w_mean": float(np.float32(m["w_mean"])),
                     "w_zero_frac": float(np.float32(m["w_zero_frac"])),
                     "local_steps": int(m["local_steps"])})
    st = pe.finalize(rs)
    rows.append({"steps_a": [int(s) for s in st["steps"]["a"]],
                 "steps_b": int(st["steps"]["b"]),
                 "comm_rounds": int(st["comm_rounds"])})
    assert rows == golden3["celu"]


# --------------------------------------------------------------------------
# Depth 1: overlap semantics
# --------------------------------------------------------------------------
def test_depth1_converges_to_depth0_quality():
    """The depth-1 pipeline pays one exchange of extra staleness but must
    reach the same loss region as the sequential schedule."""
    seq = _run_pipelined("celu", depth=0, rounds=40)
    pipe = _run_pipelined("celu", depth=1, rounds=40)
    l_seq = [r["loss"] for r in seq[:-1]]
    l_pipe = [r["loss"] for r in pipe[:-1]]
    assert np.isfinite(l_pipe).all()
    # both fall; the pipelined tail is within 10% of the sequential tail
    assert np.mean(l_pipe[-10:]) < np.mean(l_pipe[:5])
    assert np.mean(l_pipe[-10:]) <= 1.10 * np.mean(l_seq[-10:])


def test_depth1_step_accounting():
    """Every round still funds 1 fresh + up to R local updates; the flush
    drains the last in-flight local scan."""
    rounds, R = 20, 3
    rows = _run_pipelined("celu", depth=1, rounds=rounds)
    tail = rows[-1]
    assert tail["comm_rounds"] == rounds
    assert rounds < tail["steps_a"] <= rounds * (1 + R)
    assert rounds < tail["steps_b"] <= rounds * (1 + R)
    # round 0's local scan runs against an empty workset: a full bubble
    assert rows[0]["local_steps"] == 0


def test_depth1_compressed_transport_in_flight_residuals():
    """Error feedback composes with the pipeline: the lossy wire's
    residuals ride in the in-flight exchange and telescope as usual."""
    rows = _run_pipelined("celu", depth=1, rounds=12,
                          compression="int8_topk")
    losses = [r["loss"] for r in rows[:-1]]
    assert np.isfinite(losses).all()
    assert np.mean(losses[-4:]) < np.mean(losses[:4])


def test_scheduler_stage_protocol_errors():
    """dispatch twice without merge, merge without dispatch, and finalize
    with an exchange in flight are all scheduler bugs — loud ones."""
    data, cfg = _workload()
    init_fn, task, predict = make_dlrm(cfg)
    ccfg = CELUConfig(R=2, W=2)
    params = init_fn(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adagrad", 0.05)
    it = aligned_batches(data["train"], 64, seed=0)
    bi, ba, bb = next(it)
    asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
    etask = engine.lift_two_party(task)
    state = engine.init_state(etask, engine.lift_two_party_params(params),
                              opt, ccfg, [asj(ba)], asj(bb))
    pe = engine.make_pipeline(etask, opt, ccfg, depth=1)
    rs = pe.init(state)
    with pytest.raises(RuntimeError, match="no exchange in flight"):
        pe.merge(rs)
    rs = pe.dispatch(rs, [asj(ba)], asj(bb), bi)
    with pytest.raises(RuntimeError, match="already in flight"):
        pe.dispatch(rs, [asj(ba)], asj(bb), bi)
    with pytest.raises(RuntimeError, match="still in flight"):
        pe.finalize(rs)
    rs, m = pe.merge(rs)
    assert pe.finalize(rs)["comm_rounds"] == 1


def test_invalid_depth_rejected():
    """Negative depths and depths the W-slot ring cannot serve (D >= W
    leaves no valid draws) are rejected up front; D < W is accepted."""
    data, cfg = _workload()
    init_fn, task, _ = make_dlrm(cfg)
    opt = make_optimizer("adagrad", 0.05)
    etask = engine.lift_two_party(task)
    with pytest.raises(ValueError, match="depth"):
        engine.make_pipeline(etask, opt, CELUConfig(), depth=-1)
    with pytest.raises(ValueError, match="depth"):
        engine.make_pipeline(etask, opt, CELUConfig(W=5), depth=5)
    # D = W - 1 is the deepest queue the ring can serve
    pe = engine.make_pipeline(etask, opt, CELUConfig(W=5), depth=4)
    assert pe.depth == 4 and pe.queue_capacity == 4


# --------------------------------------------------------------------------
# Pipeline-staleness plumbing
# --------------------------------------------------------------------------
def _entry(v):
    return {"z": jnp.full((4, 2), float(v)), "dz": jnp.full((4, 2), 1.0)}


def test_pipeline_staleness_tightens_validity_window():
    """At staleness s the oldest s ring slots are retired early: a full
    W-slot table offers only W-s valid draws per cycle."""
    W, R = 4, 8
    ws = workset_init(W, _entry(0))
    for t in range(W):
        ws = workset_insert(ws, _entry(t), t)
    for s, expected in ((0, W), (1, W - 1), (2, W - 2)):
        valid = 0
        w2 = dict(ws)
        for _ in range(W):
            w2, e, _, v = workset_sample(w2, R, "round_robin",
                                         pipeline_staleness=s)
            valid += int(v)
        assert valid == expected, (s, valid)


def test_pipeline_attenuation_properties():
    w = jnp.asarray([0.0, 0.5, 0.9, 1.0], jnp.float32)
    out = np.asarray(pipeline_attenuation(w, 1))
    assert out[0] == 0.0                     # rejected stays rejected
    assert out[3] == 1.0                     # no measured drift: no discount
    assert np.all(out <= np.asarray(w) + 1e-7)   # monotone discount
    np.testing.assert_allclose(out[1], 0.25, rtol=1e-6)
    # staleness 0 is the identity
    np.testing.assert_array_equal(np.asarray(pipeline_attenuation(w, 0)),
                                  np.asarray(w))


def test_weighted_cotangent_staleness_fused_matches_reference():
    """The fused kernel's post-scale composition of the pipeline discount
    equals the reference path."""
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    s = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    dz = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    w_f, cot_f = engine.weighted_cotangent(a, s, dz, 0.5, fused=True,
                                           pipeline_staleness=1)
    w_r, cot_r = engine.weighted_cotangent(a, s, dz, 0.5, fused=False,
                                           pipeline_staleness=1)
    np.testing.assert_allclose(np.asarray(w_f), np.asarray(w_r),
                               rtol=3e-7, atol=3e-7)
    np.testing.assert_allclose(np.asarray(cot_f), np.asarray(cot_r),
                               rtol=3e-6, atol=3e-6)
    # the discounted weight multiplies the cotangent exactly once:
    # cot == w^(1+s) * dz on surviving rows
    alive = np.asarray(w_r) > 0
    np.testing.assert_allclose(
        np.asarray(cot_r)[alive],
        (np.asarray(w_r)[:, None] * np.asarray(dz))[alive],
        rtol=3e-6, atol=3e-6)


# --------------------------------------------------------------------------
# The WANClock (overlap-aware simulated time)
# --------------------------------------------------------------------------
def test_wanclock_per_direction_bandwidth():
    clock = WANClock(up_bandwidth=1e6, down_bandwidth=2e6, latency=0.01)
    assert clock.up_seconds(1e6) == pytest.approx(1.0)
    assert clock.down_seconds(1e6) == pytest.approx(0.5)
    assert clock.wire_seconds(1e6, 1e6) == pytest.approx(1.52)


def test_wanclock_overlap_round_latency():
    clock = WANClock(up_bandwidth=1e6, down_bandwidth=1e6, latency=0.0)
    kw = dict(exchange_compute_s=0.1, local_compute_s=0.9)
    seq = clock.round_seconds(5e5, 5e5, pipeline_depth=0, **kw)
    pipe = clock.round_seconds(5e5, 5e5, pipeline_depth=1, **kw)
    assert seq == pytest.approx(0.1 + 1.0 + 0.9)
    assert pipe == pytest.approx(max(0.1 + 1.0, 0.9))
    assert seq / pipe == pytest.approx(2.0 / 1.1)
    # compute-bound regime: the wire hides entirely behind the local scan
    pipe2 = clock.round_seconds(5e4, 5e4, pipeline_depth=1,
                                exchange_compute_s=0.1,
                                local_compute_s=5.0)
    assert pipe2 == pytest.approx(5.0)


def test_wanclock_paper_geometry_example():
    """Paper §2.1: an 8 MB fp32 exchange over 300 Mbps + gateway latency
    is ~244 ms — the historical 213 ms example plus the modelled RTT."""
    clock = WANClock()
    t = clock.wire_seconds(4096 * 256 * 4, 4096 * 256 * 4)
    assert 0.20 < t < 0.26


def test_wan_seconds_wrapper_and_transport_split():
    celu = CELUConfig()
    tp = engine.make_transport(celu, "int8_topk")
    up, down = transport_round_updown(tp, [(256, 32)])
    assert up == tp.uplink_bytes((256, 32))
    assert down == tp.downlink_bytes((256, 32))
    assert up != down
    clock = WANClock(up_bandwidth=1e6, down_bandwidth=1e6, latency=0.0)
    assert wan_seconds(up, down, clock=clock) == \
        pytest.approx((up + down) / 1e6)
    # both directions are required (the historical 1-arg call shape took
    # the round TOTAL — a silent default would double-count it)
    with pytest.raises(TypeError):
        wan_seconds(1e6, clock=clock)


# --------------------------------------------------------------------------
# Flush/merge drain semantics on a PARTIALLY filled queue
# --------------------------------------------------------------------------
def _build_engine(depth, compression="topk_int8"):
    data, cfg = _workload()
    init_fn, task, _ = make_dlrm(cfg)
    base = CELUConfig(R=3, W=3, xi_degrees=60.0)
    ccfg, nloc = engine.preset_config("celu", base)
    params = init_fn(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adagrad", 0.05)
    asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
    tp = engine.make_transport(ccfg, compression)
    etask = engine.lift_two_party(task)
    it = aligned_batches(data["train"], 64, seed=0)
    _, ba, bb = next(it)
    state = engine.init_state(etask, engine.lift_two_party_params(params),
                              opt, ccfg, [asj(ba)], asj(bb), transport=tp)
    pe = engine.make_pipeline(etask, opt, ccfg, depth=depth,
                              local_steps=nloc, transport=tp)
    return pe, pe.init(state), aligned_batches(data["train"], 64, seed=0), asj


def test_flush_partial_queue_merges_in_dispatch_order():
    """Interrupting a depth-2 run mid-warmup leaves the exchange queue
    partially filled; flush must merge oldest-first (batch_idx order),
    exactly once each, with the in-flight transport-residual chain
    adopted intact."""
    pe, rs, it, asj = _build_engine(2)
    idxs = []
    for _ in range(2):                     # fill by hand: no merges yet
        bi, ba, bb = next(it)
        rs = pe.dispatch(rs, [asj(ba)], asj(bb), bi)
        idxs.append(int(np.asarray(bi)))
    assert [int(np.asarray(p.batch_idx)) for p in rs.pending] == idxs
    with pytest.raises(RuntimeError, match="in flight"):
        pe.dispatch(rs, [asj(ba)], asj(bb), bi)   # queue is at capacity
    # the newest pending slot carries the LIVE residuals; the round-state
    # copy is stale until the merges adopt them
    tail_ts = jax.tree_util.tree_map(np.asarray,
                                     rs.pending[-1].fresh["tstate"])
    merged = []
    orig_merge = pe.merge

    def recording_merge(rs, **kw):
        merged.append(int(np.asarray(rs.pending[0].batch_idx)))
        return orig_merge(rs, **kw)

    pe.merge = recording_merge
    c0 = int(np.asarray(rs.comm_rounds))
    rs, lm = pe.flush(rs)
    assert merged == idxs                       # oldest first, once each
    assert int(np.asarray(rs.comm_rounds)) == c0 + 2
    assert not rs.pending
    assert int(lm["local_steps"]) > 0           # drain scans ran
    for got, want in zip(jax.tree_util.tree_leaves(rs.transport),
                         jax.tree_util.tree_leaves(tail_ts)):
        np.testing.assert_array_equal(np.asarray(got), want)
    with pytest.raises(RuntimeError, match="no exchange in flight"):
        orig_merge(rs)                          # nothing left to merge
    pe.finalize(rs)


def test_flush_partial_queue_single_slot():
    """One step into a depth-2 run the queue holds a single exchange
    (warmup reported a NaN loss, no merge); flush completes exactly that
    one merge and finalize's step counters stay honest."""
    pe, rs, it, asj = _build_engine(2)
    bi, ba, bb = next(it)
    rs, m = pe.step(rs, [asj(ba)], asj(bb), bi)
    assert np.isnan(float(np.float32(m["loss"])))
    assert len(rs.pending) == 1
    c0 = int(np.asarray(rs.comm_rounds))
    rs, _ = pe.flush(rs)
    assert not rs.pending
    st = pe.finalize(rs)
    assert int(np.asarray(st["comm_rounds"])) == c0 + 1
    # one merge -> one deferred insert -> the scans that ran saw it
    assert int(np.asarray(st["steps"]["b"])) > 0
