"""Static boundary auditor for the CELU round engine.

Traces the round/pipeline stage closures to jaxprs (NO execution beyond
tracing) and proves three invariant families per commit:

  * **taint** — cross-party information flow: every value reaching a
    transport send passes the registered wire / codec-encode / DP-noise
    stages, and no stage output hosted at one party carries another
    party's raw taint (raw features, labels, pre-release cut tensors,
    optimizer state) — including error-feedback residuals and the
    pipelined scheduler's ``PendingExchange`` queue slots at every depth;
  * **wire** — static byte accounting: the payload avals a codec's
    ``encode`` produces (via ``jax.eval_shape``) must equal the codec's
    ``wire_bytes()`` and the transport's ``uplink_bytes`` /
    ``downlink_bytes`` counters, and every boundary crossing the jaxpr
    contains must be accounted;
  * **kernel** — Pallas kernel contracts: grid/BlockSpec divisibility at
    the audited call-site geometries, VMEM residency vs budget, a
    registered jnp oracle in ``kernels/ref.py`` per kernel, and no
    narrowing precision cast that is not mediated by a declared
    wire/codec/cache stage.

Run ``python -m repro.analysis`` for the CLI (writes
``results/AUDIT.json``); see ``docs/ANALYSIS.md`` for how to read the
report and how to register new transports/codecs/kernels.

This ``__init__`` stays import-light (no jax): the CLI must be able to
set ``XLA_FLAGS`` for the pod audit before jax is first imported.
"""

__all__ = ["run_audit", "default_cases", "Finding", "AuditReport"]


def __getattr__(name):
    if name in ("run_audit", "default_cases"):
        from . import audit
        return getattr(audit, name)
    if name in ("Finding", "AuditReport"):
        from . import report
        return getattr(report, name)
    raise AttributeError(name)
