"""Fused AdaGrad kernel: accumulate + rsqrt-scale in one VMEM pass.

The unfused optimizer reads grad, reads accum, writes accum, reads accum
again, writes update — with XLA usually fusing *some* of it but still
materializing the fp32 accumulator twice.  The kernel does

    a' = a + g²;  u = -lr * g / (sqrt(a') + eps)

with one load of (g, a) and one store of (u, a') per element — the memory-
bound optimum (3 streams in, 2 out → 2 in, 2 out).

Tiling: inputs are flattened and padded to (N/BLOCK, BLOCK) with BLOCK=1024
lanes — pure element-wise VPU work, no MXU, no cross-lane traffic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024
ROWS = 8


def _kernel(g_ref, a_ref, hyp_ref, u_ref, a_out_ref):
    g = g_ref[...].astype(jnp.float32)
    a = a_ref[...]
    lr = hyp_ref[0]
    eps = hyp_ref[1]
    a_new = a + g * g
    u_ref[...] = -lr * g / (jnp.sqrt(a_new) + eps)
    a_out_ref[...] = a_new


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_adagrad(grad, accum, lr, eps, *, interpret: bool = True):
    """grad: any shape/dtype; accum: same shape fp32.
    -> (update fp32, new_accum fp32), same shape as grad."""
    shape = grad.shape
    n = grad.size
    cols = min(BLOCK, max(n, 1))
    rows_per_block = ROWS
    n_pad = ((n + cols - 1) // cols) * cols
    n_rows = n_pad // cols
    n_rows_pad = ((n_rows + rows_per_block - 1) // rows_per_block) \
        * rows_per_block

    g = jnp.zeros((n_rows_pad * cols,), jnp.float32).at[:n].set(
        grad.reshape(-1).astype(jnp.float32)).reshape(n_rows_pad, cols)
    a = jnp.zeros((n_rows_pad * cols,), jnp.float32).at[:n].set(
        accum.reshape(-1)).reshape(n_rows_pad, cols)
    hyp = jnp.asarray([lr, eps], jnp.float32)

    u, a_new = pl.pallas_call(
        _kernel,
        grid=(n_rows_pad // rows_per_block,),
        in_specs=[
            pl.BlockSpec((rows_per_block, cols), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_block, cols), lambda i: (i, 0)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((rows_per_block, cols), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_block, cols), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_rows_pad, cols), jnp.float32),
            jax.ShapeDtypeStruct((n_rows_pad, cols), jnp.float32),
        ],
        interpret=interpret,
    )(g, a, hyp)
    return (u.reshape(-1)[:n].reshape(shape),
            a_new.reshape(-1)[:n].reshape(shape))
