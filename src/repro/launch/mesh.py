"""Production mesh definitions (TPU v5e target).

Single pod = 16 x 16 = 256 chips, axes (data, model).
Multi-pod  = 2 x 16 x 16 = 512 chips, axes (pod, data, model); the ``pod``
axis is the slow inter-pod link — in the CELU party-to-pod mapping it
carries the two VFL parties (core/pod_protocol.py), in the generic dry-run
it extends data parallelism.

Functions, not module constants: importing this module never touches jax
device state (device count locks on first jax init).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) for the roofline terms
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link

SINGLE_POD_SHAPE = (16, 16)
MULTI_POD_SHAPE = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever fits the current host's devices — for smoke tests."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
