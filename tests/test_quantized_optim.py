"""Quantized optimizer state (optim/quantized.py + the fused q8 kernel).

Covers: fused_adagrad_q8 kernel vs the jnp oracle (multi-tile grids,
narrow-column tilings), the sqrt-space requant staying exact on
row-homogeneous gradients, bf16/int8 AdaGrad tracking the fp32
accumulator within tolerance, SM3's factored state actually shrinking
while still optimizing, state-size accounting, jit/scan pytree
discipline of the QuantAccum leaves, and the ``opt_state_pspecs``
sharding rule over the quantized layouts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.optim import OPT_STATE_DTYPES, adagrad, apply_updates, \
    make_optimizer
from repro.optim.quantized import QuantAccum, adagrad_quantized, \
    opt_state_nbytes, quant_accum_init, sm3

RNG = np.random.default_rng(11)


def _f32(shape, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, jnp.float32)


# --------------------------------------------------------------------------
# Kernel vs oracle
# --------------------------------------------------------------------------
@pytest.mark.parametrize("R,C", [(8, 1024), (32, 1024), (8, 2), (16, 114)])
def test_fused_adagrad_q8_matches_oracle(R, C):
    g = _f32((R, C))
    q = jnp.asarray(RNG.integers(0, 128, size=(R, C)), jnp.int8)
    s = jnp.asarray(RNG.uniform(1e-6, 1e-2, size=(R, 1)), jnp.float32)
    u = jnp.asarray(RNG.uniform(size=(R, C)), jnp.float32)
    upd_k, q_k, s_k = ops.fused_adagrad_q8(g, q, s, u, 0.05, 1e-10)
    upd_r, q_r, s_r = ref.fused_adagrad_q8_ref(g, q, s, u, 0.05, 1e-10)
    np.testing.assert_allclose(np.asarray(upd_k), np.asarray(upd_r),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)


def test_fused_adagrad_q8_zero_state_first_step():
    """From the all-zero init state the first update must equal plain
    AdaGrad's first update exactly (dequant of zero codes is zero)."""
    g = _f32((8, 64))
    q = jnp.zeros((8, 64), jnp.int8)
    s = jnp.zeros((8, 1), jnp.float32)
    u = jnp.zeros((8, 64), jnp.float32)
    upd, _, _ = ops.fused_adagrad_q8(g, q, s, u, 0.1, 1e-10)
    upd_ref, _ = ref.fused_adagrad_ref(g, jnp.zeros_like(g), 0.1, 1e-10)
    np.testing.assert_allclose(np.asarray(upd), np.asarray(upd_ref),
                               rtol=1e-6, atol=0)


# --------------------------------------------------------------------------
# Optimizer-level parity vs the fp32 accumulator
# --------------------------------------------------------------------------
def _run(opt, params, grad_seq):
    st = opt.init(params)
    upd = None
    for g in grad_seq:
        upd, st = opt.update(g, st)
    return upd, st


def test_int8_adagrad_exact_on_row_homogeneous_grads():
    """Constant-magnitude gradients keep every element at the row max, so
    the sqrt-space requant is EXACT and int8 AdaGrad reproduces the fp32
    update to float tolerance across steps."""
    params = {"w": jnp.zeros((16, 64), jnp.float32)}
    signs = RNG.choice([-1.0, 1.0], size=(16, 64))
    grads = [{"w": jnp.asarray(signs * 0.1, jnp.float32)}] * 6
    u32, _ = _run(adagrad(0.05), params, grads)
    u8, _ = _run(adagrad(0.05, state_dtype="int8"), params, grads)
    np.testing.assert_allclose(np.asarray(u8["w"]), np.asarray(u32["w"]),
                               rtol=2e-5, atol=1e-8)


@pytest.mark.parametrize("state_dtype,tol", [("bfloat16", 0.02),
                                             ("int8", 0.35)])
def test_quantized_adagrad_tracks_fp32_within_tolerance(state_dtype, tol):
    """Random gradients: the quantized accumulators stay within a bounded
    relative error of the fp32 update for elements whose accumulator is
    not far below the row max (the 8-bit-optimizer regime; sqrt-space
    codes cover (1/127)^2 of the row max)."""
    params = {"w": jnp.zeros((16, 128), jnp.float32),
              "b": jnp.zeros((37,), jnp.float32)}
    grads = [jax.tree_util.tree_map(
        lambda p, k=k: jnp.asarray(
            np.random.default_rng(k).normal(size=p.shape) * 0.1,
            jnp.float32), params) for k in range(6)]
    u32, _ = _run(adagrad(0.05), params, grads)
    uq, _ = _run(adagrad(0.05, state_dtype=state_dtype), params, grads)
    for k in u32:
        a, b = np.asarray(uq[k]), np.asarray(u32[k])
        # elements still in the representable band of the row scale
        sig = np.abs(b) > 0.25 * np.abs(b).max()
        rel = np.abs(a - b)[sig] / np.abs(b)[sig]
        assert rel.max() <= tol, (k, rel.max())


def test_quantized_adagrad_optimizes_quadratic():
    """End-to-end convergence: minimizing a least-squares objective with
    int8 / bf16 state reaches within 10% of the fp32-state loss."""
    X = _f32((128, 16), 0.5)
    w_true = _f32((16,))
    y = X @ w_true

    def loss(w):
        r = X @ w - y
        return jnp.mean(r * r)

    gfn = jax.grad(loss)
    finals = {}
    for sd in OPT_STATE_DTYPES:
        opt = adagrad(0.5, state_dtype=sd)
        w = {"w": jnp.zeros((16,), jnp.float32)}
        st = opt.init(w)
        for _ in range(60):
            upd, st = opt.update({"w": gfn(w["w"])}, st)
            w = apply_updates(w, upd)
        finals[sd] = float(loss(w["w"]))
    base = finals["float32"]
    assert base < 0.05 * float(jnp.mean(y * y))      # fp32 actually trains
    for sd in ("bfloat16", "int8"):
        assert finals[sd] <= base + 0.1 * abs(base) + 5e-3, finals


def test_int8_adagrad_update_is_deterministic():
    """The requant SR stream is seeded from the step counter: the same
    (grads, state) produce bit-identical updates and codes — the property
    checkpoint resume relies on."""
    params = {"w": jnp.zeros((8, 32), jnp.float32)}
    g = {"w": _f32((8, 32), 0.1)}
    opt = adagrad(0.05, state_dtype="int8")
    st = opt.init(params)
    u1, st1 = opt.update(g, st)
    u2, st2 = opt.update(g, st)
    np.testing.assert_array_equal(np.asarray(u1["w"]), np.asarray(u2["w"]))
    np.testing.assert_array_equal(np.asarray(st1["accum"][0].q),
                                  np.asarray(st2["accum"][0].q))


# --------------------------------------------------------------------------
# SM3
# --------------------------------------------------------------------------
def test_sm3_state_is_factored_and_optimizes():
    params = {"w": jnp.zeros((64, 32), jnp.float32),
              "b": jnp.zeros((32,), jnp.float32)}
    opt = make_optimizer("sm3", 0.5)
    st = opt.init(params)
    # leaf order is the params flatten order ("b" sorts before "w"):
    # full (32,) for the 1-D bias, (64,) row + (32,) col for w — not 64*32
    assert st["accum"][0]["full"].shape == (32,)
    assert st["accum"][1]["row"].shape == (64,)
    assert st["accum"][1]["col"].shape == (32,)
    assert opt_state_nbytes(opt, params) < \
        opt_state_nbytes(adagrad(0.5), params) / 10

    X = _f32((256, 64), 0.5)
    y = X @ _f32((64, 32))

    def loss(w):
        r = X @ w - y
        return jnp.mean(r * r)

    w = {"w": jnp.zeros((64, 32), jnp.float32)}
    st = opt.init(w)
    l0 = float(loss(w["w"]))
    for _ in range(50):
        upd, st = opt.update({"w": jax.grad(loss)(w["w"])}, st)
        w = apply_updates(w, upd)
    assert float(loss(w["w"])) < 0.2 * l0


def test_sm3_cover_upper_bounds_adagrad_sum():
    """SM3's defining invariant: min(row_i, col_j) >= the true
    accumulated g² sum at every cell (row/col are maxima of v, v builds
    on the min of maxima), so steps are never LARGER than AdaGrad's —
    the factored state is conservative, not optimistic."""
    opt = sm3(0.1)
    g = _f32((8, 16), 0.3)
    st = opt.init({"w": jnp.zeros((8, 16))})
    true_sum = np.zeros((8, 16), np.float64)
    for _ in range(4):
        _, st = opt.update({"w": g}, st)
        true_sum += np.asarray(g, np.float64) ** 2
        cover = np.minimum(np.asarray(st["accum"][0]["row"])[:, None],
                           np.asarray(st["accum"][0]["col"])[None, :])
        assert (cover >= true_sum - 1e-5).all()


# --------------------------------------------------------------------------
# State accounting + pytree discipline
# --------------------------------------------------------------------------
def test_state_bytes_ordering():
    """At LLM-ish leaf sizes: int8 < bf16/sm3 < fp32, int8 ~4x smaller
    (per-row fp32 scales amortized over 1024 lanes)."""
    params = {"w": jnp.zeros((2048, 960), jnp.float32),
              "b": jnp.zeros((960,), jnp.float32)}
    b32 = opt_state_nbytes(adagrad(0.1), params)
    b16 = opt_state_nbytes(adagrad(0.1, state_dtype="bfloat16"), params)
    b8 = opt_state_nbytes(adagrad(0.1, state_dtype="int8"), params)
    bs = opt_state_nbytes(make_optimizer("sm3", 0.1), params)
    assert b8 < b16 < b32 and bs < b8
    assert b32 / b8 > 3.5


def test_quant_accum_rides_jit_and_flattens():
    p = jnp.zeros((100,), jnp.float32)
    acc = quant_accum_init(p)
    assert isinstance(acc, QuantAccum)
    leaves, treedef = jax.tree_util.tree_flatten(acc)
    assert [l.dtype for l in leaves] == [jnp.int8, jnp.float32]
    acc2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert acc2.shape == (100,)
    opt = adagrad_quantized(0.05)
    st = opt.init({"w": p})
    u_j, st_j = jax.jit(opt.update)({"w": _f32((100,))}, st)
    assert u_j["w"].shape == (100,)
    assert isinstance(st_j["accum"][0], QuantAccum)


def test_bad_state_dtype_rejected():
    with pytest.raises(ValueError, match="state_dtype"):
        adagrad(0.1, state_dtype="fp16")
    with pytest.raises(ValueError, match="state_dtype"):
        adagrad_quantized(0.1, state_dtype="float32")


# --------------------------------------------------------------------------
# Sharding rules over the quantized state
# --------------------------------------------------------------------------
def test_opt_state_pspecs_quantized_layouts():
    """``sharding.rules.opt_state_pspecs`` shards a QuantAccum's padded
    row dim over data (ZeRO-1-style; R is a multiple of the kernel ROWS
    tiling so a 2-way axis always divides, and every shard keeps whole
    requant rows), replicates the step counter and SM3's factored
    vectors, and the derived specs place + step without error."""
    from types import SimpleNamespace

    from jax.sharding import PartitionSpec as P

    from repro.sharding.rules import make_sharding, opt_state_pspecs

    params = {"w": _f32((16, 24)), "b": _f32((16,))}
    opt = make_optimizer("adagrad", 0.01, state_dtype="int8")
    st = jax.eval_shape(opt.init, params)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    specs = opt_state_pspecs(st, mesh)
    for acc in specs["accum"]:
        assert acc.q == P("data", None)
        assert acc.scale == P("data", None)
    assert specs["t"] == P()
    # R % ROWS == 0 -> a 2-way data axis still shards every leaf
    two_way = opt_state_pspecs(st, SimpleNamespace(shape={"data": 2}))
    for acc in two_way["accum"]:
        assert acc.q == P("data", None)

    # SM3's factored row/col vectors are 1-D: replicate
    sm3_st = jax.eval_shape(make_optimizer("sm3", 0.01).init, params)
    sm3_specs = opt_state_pspecs(sm3_st, mesh)
    for leaf, spec in zip(jax.tree_util.tree_leaves(sm3_st),
                          jax.tree_util.tree_leaves(sm3_specs)):
        if getattr(leaf, "ndim", 0) < 2:
            assert spec == P()

    # derived specs are placeable and the fused update runs on top
    st_c = opt.init(params)
    st_p = jax.device_put(st_c, make_sharding(mesh, specs))
    upd, st2 = opt.update(
        jax.tree_util.tree_map(jnp.ones_like, params), st_p)
    assert int(st2["t"]) == 1
    assert upd["w"].shape == (16, 24)
