"""Core transformer layers: RMSNorm, RoPE, GQA attention, gated MLP.

Pure functions over param pytrees.  Attention supports:
  * full-sequence causal self-attention (optionally sliding-window),
  * blockwise (flash-style, online-softmax) attention for long sequences —
    this doubles as the pure-jnp oracle for ``kernels/flash_attention.py``,
  * cross-attention to a memory,
  * single-token decode against a (ring-buffer) KV cache.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .initializers import PARAM_DTYPE, dense_init, ones_init, zeros_init

# Sequences longer than this use the blockwise path (keeps peak memory of
# the lowered HLO O(S * block) instead of O(S^2)).
BLOCKWISE_THRESHOLD = 2048
Q_BLOCK = 512
KV_BLOCK = 1024
NEG_INF = -1e30

# ---------------------------------------------------------------------------
# Activation-sharding hints.  GSPMD's sharding propagation through while
# bodies (the blockwise-attention and layer scans) can drop the batch
# sharding of loop-local tensors, silently replicating multi-GB score tiles
# (observed on the 16x16 dry-run).  The launch layer installs the data-axis
# names here; ``shard_batch_dim`` then pins dim0 of key activations.  No-op
# outside the dry-run/launch context.
_BATCH_AXES = None
_BATCH_AXES_SIZE = 1
_VOCAB_AXIS = None
_VOCAB_AXIS_SIZE = 1


def set_batch_axes(axes, size: int = 1, vocab_axis=None, vocab_size: int = 1):
    """axes: mesh axis names carrying the batch dim (or None to disable);
    size: their product (passed in so this module never inspects meshes).
    vocab_axis/vocab_size: mesh axis sharding the logits' vocab dim."""
    global _BATCH_AXES, _BATCH_AXES_SIZE, _VOCAB_AXIS, _VOCAB_AXIS_SIZE
    _BATCH_AXES = tuple(axes) if axes else None
    _BATCH_AXES_SIZE = size if axes else 1
    _VOCAB_AXIS = vocab_axis
    _VOCAB_AXIS_SIZE = vocab_size if vocab_axis else 1


def shard_logits(x):
    """Pin (batch, ..., vocab) sharding on the logits tensor so the loss
    never replicates the vocab dim (12.6 GB/device measured otherwise)."""
    if x.ndim == 0:
        return x
    from jax.sharding import PartitionSpec as _P
    parts = [None] * x.ndim
    if _BATCH_AXES is not None and _BATCH_AXES_SIZE > 1 \
            and x.shape[0] % _BATCH_AXES_SIZE == 0 \
            and x.shape[0] >= _BATCH_AXES_SIZE:
        parts[0] = _BATCH_AXES if len(_BATCH_AXES) > 1 else _BATCH_AXES[0]
    if _VOCAB_AXIS is not None and _VOCAB_AXIS_SIZE > 1 \
            and x.shape[-1] % _VOCAB_AXIS_SIZE == 0:
        parts[-1] = _VOCAB_AXIS
    if all(p is None for p in parts):
        return x
    return jax.lax.with_sharding_constraint(x, _P(*parts))


def shard_batch_dim(x):
    if _BATCH_AXES is None or x.ndim == 0 or _BATCH_AXES_SIZE <= 1:
        return x
    if x.shape[0] % _BATCH_AXES_SIZE != 0 or x.shape[0] < _BATCH_AXES_SIZE:
        return x
    from jax.sharding import PartitionSpec as _P
    ax = _BATCH_AXES if len(_BATCH_AXES) > 1 else _BATCH_AXES[0]
    spec = _P(*((ax,) + (None,) * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------
def rmsnorm_init(d: int):
    return {"scale": ones_init((d,))}


def rmsnorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope(x, positions, theta: float = 10000.0):
    """Apply rotary embedding.  x: (B, S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[None, :, None] * freqs[None, None, :]
        ang = ang[:, :, None, :]                      # (1, S, 1, half)
    else:
        ang = positions.astype(jnp.float32)[:, :, None] * freqs[None, None, :]
        ang = ang[:, :, None, :]                      # (B, S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------
class AttnParams(NamedTuple):
    pass  # (documentation only; params are plain dicts)


def attention_init(rng, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   *, kv_input_dim: Optional[int] = None,
                   qkv_bias: bool = False):
    kd = kv_input_dim or d_model
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim)
              .reshape(d_model, n_heads, head_dim),
        "wk": dense_init(ks[1], kd, n_kv * head_dim).reshape(kd, n_kv, head_dim),
        "wv": dense_init(ks[2], kd, n_kv * head_dim).reshape(kd, n_kv, head_dim),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model)
              .reshape(n_heads, head_dim, d_model),
    }
    if qkv_bias:
        p["bq"] = zeros_init((n_heads, head_dim))
        p["bk"] = zeros_init((n_kv, head_dim))
        p["bv"] = zeros_init((n_kv, head_dim))
    return p


def _project_qkv(params, x, kv_src):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_src, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_src, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return q, k, v


def _repeat_kv(k, n_heads):
    """(B, S, Kv, hd) -> (B, S, H, hd) by repeating each KV group."""
    n_kv = k.shape[2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=2)


def _sdpa(q, k, v, mask):
    """q: (B,Q,H,hd) k,v: (B,K,H,hd); mask broadcastable to (B,H,Q,K)."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)


def _causal_mask(q_pos, k_pos, window: int):
    """bool (..., Q, K): True where key visible to query."""
    d = q_pos[..., :, None] - k_pos[..., None, :]
    m = d >= 0
    if window:
        m &= d < window
    return m


def _blockwise_sdpa(q, k, v, q_pos, k_pos, *, causal: bool, window: int):
    """Flash-style online-softmax attention, O(S * KV_BLOCK) memory.

    q: (B,Q,H,hd), k/v: (B,K,H,hd).  Also serves as the Pallas oracle.
    """
    B, Q, H, hd = q.shape
    K = k.shape[1]
    qb = min(Q_BLOCK, Q)
    kb = min(KV_BLOCK, K)
    n_qb, n_kb = Q // qb, K // kb
    assert Q % qb == 0 and K % kb == 0, (Q, K)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    # remat: backward RECOMPUTES the per-block scores instead of saving all
    # (n_qb * n_kb) score tiles as scan residuals (measured 290 GB/device on
    # smollm train_4k without it) — this IS the flash-attention backward.
    @jax.checkpoint
    def q_step(_, qi):
        qs = shard_batch_dim(
            jax.lax.dynamic_slice_in_dim(q, qi * qb, qb, axis=1))
        qp = jax.lax.dynamic_slice_in_dim(q_pos, qi * qb, qb, axis=0)

        def kv_step(carry, ki):
            acc, m, l = carry
            ks = shard_batch_dim(
                jax.lax.dynamic_slice_in_dim(k, ki * kb, kb, axis=1))
            vs = shard_batch_dim(
                jax.lax.dynamic_slice_in_dim(v, ki * kb, kb, axis=1))
            kp = jax.lax.dynamic_slice_in_dim(k_pos, ki * kb, kb, axis=0)
            s = jnp.einsum("bqhd,bkhd->bhqk", qs, ks).astype(jnp.float32)
            s = s * scale
            if causal or window:
                msk = _causal_mask(qp, kp, window) if causal else (
                    (qp[:, None] - kp[None, :]) < window)
                s = jnp.where(msk[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vs.astype(jnp.float32))
            return (acc, m_new, l_new), None

        init = (shard_batch_dim(jnp.zeros((B, H, qb, hd), jnp.float32)),
                shard_batch_dim(jnp.full((B, H, qb), NEG_INF, jnp.float32)),
                shard_batch_dim(jnp.zeros((B, H, qb), jnp.float32)))
        (acc, m, l), _ = jax.lax.scan(kv_step, init, jnp.arange(n_kb))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.transpose(0, 2, 1, 3)  # (B, qb, H, hd)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(n_qb))
    # outs: (n_qb, B, qb, H, hd) -> (B, Q, H, hd)
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Q, H, hd).astype(q.dtype)


def attention_apply(params, x, *, positions, theta: float = 10000.0,
                    causal: bool = True, window: int = 0,
                    memory=None, memory_positions=None,
                    use_rope: bool = True):
    """Full-sequence attention.  If ``memory`` is given -> cross-attention
    (no mask, no rope on memory unless memory_positions given)."""
    n_heads = params["wq"].shape[1]
    kv_src = memory if memory is not None else x
    q, k, v = _project_qkv(params, x, kv_src)
    S = x.shape[1]
    if use_rope:
        q = rope(q, positions, theta)
        if memory is None:
            k = rope(k, positions, theta)
        elif memory_positions is not None:
            k = rope(k, memory_positions, theta)
    k = _repeat_kv(k, n_heads)
    v = _repeat_kv(v, n_heads)
    K = k.shape[1]

    if memory is not None:
        mask = jnp.ones((1, 1, S, K), bool)
        out = _sdpa(q, k, v, mask)
    elif max(S, K) > BLOCKWISE_THRESHOLD:
        k_pos = positions if positions.ndim == 1 else positions[0]
        out = _blockwise_sdpa(q, k, v, k_pos, k_pos,
                              causal=causal, window=window)
    else:
        p = positions if positions.ndim == 1 else positions[0]
        mask = _causal_mask(p, p, window)[None, None] if causal else \
            jnp.ones((1, 1, S, K), bool)
        out = _sdpa(q, k, v, mask)
    return jnp.einsum("bqhd,hdo->bqo", out, params["wo"])


# ---- decode with ring-buffer KV cache -------------------------------------
def make_kv_cache(batch: int, capacity: int, n_kv: int, head_dim: int,
                  dtype=PARAM_DTYPE):
    return {
        "k": jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
        # absolute position held in each slot; very negative = empty
        "slot_pos": jnp.full((capacity,), -(2 ** 30), jnp.int32),
    }


def attention_decode(params, x, cache, pos, *, theta: float = 10000.0,
                     window: int = 0, use_rope: bool = True):
    """One-token decode.  x: (B, 1, d); pos: scalar int32 absolute position.

    The cache is a ring buffer of ``capacity`` slots (capacity == window for
    sliding-window archs, == max context otherwise).  Returns (out, cache).
    """
    n_heads = params["wq"].shape[1]
    q, k_new, v_new = _project_qkv(params, x, x)
    pos_arr = jnp.reshape(pos, (1,))
    if use_rope:
        q = rope(q, pos_arr, theta)
        k_new = rope(k_new, pos_arr, theta)
    cap = cache["k"].shape[1]
    slot = jnp.mod(pos, cap)
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, 1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, 1)
    slot_pos = jax.lax.dynamic_update_slice_in_dim(
        cache["slot_pos"], pos_arr, slot, 0)

    k = _repeat_kv(k_cache, n_heads)
    v = _repeat_kv(v_cache, n_heads)
    dist = pos - slot_pos                                  # (cap,)
    valid = dist >= 0
    if window:
        valid &= dist < window
    mask = valid[None, None, None, :]                      # (1,1,1,cap)
    out = _sdpa(q, k, v, mask)
    out = jnp.einsum("bqhd,hdo->bqo", out, params["wo"])
    return out, {"k": k_cache, "v": v_cache, "slot_pos": slot_pos}


def cross_attention_decode(params, x, memory_kv, *, theta=10000.0):
    """Decode-time cross attention against precomputed memory K/V.

    memory_kv: dict {"k","v"}: (B, S_mem, Kv, hd) (already projected)."""
    n_heads = params["wq"].shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    k = _repeat_kv(memory_kv["k"], n_heads)
    v = _repeat_kv(memory_kv["v"], n_heads)
    mask = jnp.ones((1, 1, 1, k.shape[1]), bool)
    out = _sdpa(q, k, v, mask)
    return jnp.einsum("bqhd,hdo->bqo", out, params["wo"])


def project_memory_kv(params, memory):
    """Precompute cross-attention K/V for decode."""
    k = jnp.einsum("bsd,dhk->bshk", memory, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, params["wv"])
    if "bk" in params:
        k = k + params["bk"]
        v = v + params["bv"]
    return {"k": k, "v": v}


# --------------------------------------------------------------------------
# Gated MLP (llama-style)
# --------------------------------------------------------------------------
def mlp_init(rng, d_model: int, d_ff: int):
    ks = jax.random.split(rng, 3)
    return {
        "wg": dense_init(ks[0], d_model, d_ff),
        "wu": dense_init(ks[1], d_model, d_ff),
        "wd": dense_init(ks[2], d_ff, d_model),
    }


def mlp_apply(params, x):
    g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, params["wg"])
                    .astype(jnp.float32)).astype(x.dtype)
    u = jnp.einsum("bsd,df->bsf", x, params["wu"])
    return jnp.einsum("bsf,fd->bsd", g * u, params["wd"])
