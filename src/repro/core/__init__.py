"""CELU-VFL core: K-party round engine, workset table, instance weighting,
wire compression, protocol presets."""
from . import compression, engine, protocol, weighting, workset  # noqa: F401
from .engine import (CompressedWANTransport, KPartyTask,  # noqa: F401
                     PendingExchange, PipelinedEngine, PodTransport,
                     RoundState, SimWANTransport, make_pipeline,
                     make_transport, preset_config)
from .faults import ChaosEngine, ExchangeFate, FaultSchedule, \
    make_chaos_engine  # noqa: F401
from .protocol import VFLTask, init_state, make_round, protocol_config  # noqa: F401
