"""Split-model serving at production traffic (see docs/SERVING.md).

``repro.serve`` turns the one-shot prefill+decode driver into a real
serving subsystem: a continuous-batching engine with per-request decode
state over the party boundary (``engine.ServeEngine``), the quantized
workset ring repurposed as the cross-party decode activation cache, the
compressed wire on the serving path with exact per-request byte
accounting, and an open-loop synthetic load generator (``loadgen``).
"""
from .engine import (Completion, Request, ServeConfig, ServeEngine,
                     make_naive_fns, naive_generate)
from .loadgen import LoadSpec, synth_requests

__all__ = ["Completion", "Request", "ServeConfig", "ServeEngine",
           "make_naive_fns", "naive_generate", "LoadSpec",
           "synth_requests"]
