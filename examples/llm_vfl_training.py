"""CELU-VFL on an LLM backbone at FULL model geometry: Party A holds an
auxiliary token stream, Party B the main tokens + labels, and the full
protocol stack (workset ring, round-robin sampling, instance weighting,
int4-at-rest cache, int8 optimizer state) runs over the real 32-layer
smollm-360m config — the quantized at-rest storage is what makes that
geometry practical, and the script prints the exact per-party HBM math
(``repro.launch.budget``, the same counters ``results/BENCH_llm.json``
gates) before training.

Defaults are full geometry with a small demo batch; pass ``--reduced``
for the 2-layer CPU smoke variant (the historical quick path).

    PYTHONPATH=src python examples/llm_vfl_training.py
    PYTHONPATH=src python examples/llm_vfl_training.py --reduced \
        --cache-dtype float32 --opt-state-dtype float32
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config  # noqa: E402
from repro.launch import train as T  # noqa: E402
from repro.launch.budget import format_budget, party_hbm_budget  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true",
                    help="2-layer smoke variant instead of full geometry")
    ap.add_argument("--rounds", type=int, default=None,
                    help="default: 3 full-geometry rounds, 12 reduced")
    ap.add_argument("--cache-dtype", default="int4",
                    choices=("float32", "bfloat16", "int8", "int4"))
    ap.add_argument("--opt-state-dtype", default="int8",
                    choices=("float32", "bfloat16", "int8"))
    args = ap.parse_args()

    cfg = get_config(args.arch)
    W = 3
    if args.reduced:
        batch, seq, rounds = 4, 32, args.rounds or 12
    else:
        batch, seq, rounds = 2, 64, args.rounds or 3

    # The per-party device-memory math, before any weight exists: the
    # demo shape actually trained below, then the paper-shape train_4k
    # batch the benchmark gates — where the at-rest ladder decides
    # whether a party fits one device at all.
    shape_cfg = cfg.reduced() if args.reduced else cfg
    demo = party_hbm_budget(shape_cfg, batch_size=batch, seq_len=seq, W=W,
                            cache_dtype=args.cache_dtype,
                            opt_state_dtype=args.opt_state_dtype)
    print(format_budget(f"{shape_cfg.name} (this run: B={batch} S={seq} "
                        f"W={W}, cache {args.cache_dtype}, opt state "
                        f"{args.opt_state_dtype})", demo))
    if not args.reduced:
        for cd, od in (("float32", "float32"),
                       (args.cache_dtype, args.opt_state_dtype)):
            full = party_hbm_budget(cfg, batch_size=256, seq_len=4096, W=5,
                                    cache_dtype=cd, opt_state_dtype=od)
            print(format_budget(f"{cfg.name} (paper-shape train_4k: B=256 "
                                f"S=4096 W=5, cache {cd}, opt state {od})",
                                full))

    argv = ["--arch", args.arch, "--protocol", "celu",
            "--rounds", str(rounds), "--batch-size", str(batch),
            "--seq-len", str(seq), "--R", "3", "--W", str(W),
            "--cache-dtype", args.cache_dtype,
            "--opt-state-dtype", args.opt_state_dtype, "--lr", "0.02"]
    if args.reduced:
        argv.append("--reduced")
    T.main(argv)


if __name__ == "__main__":
    main()
