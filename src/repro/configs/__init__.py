"""Config registry: ``get_config("<arch-id>")`` -> ArchConfig.

Assigned architecture ids use the public pool spelling (dashes); module
names use underscores.
"""
from .base import (ArchConfig, CELUConfig, MoEConfig, ShapeConfig, SSMConfig,
                   TrainConfig, VFLConfig, XLSTMConfig, LONG_CONTEXT_WINDOW,
                   SHAPES)

ARCH_IDS = (
    "hymba-1.5b",
    "deepseek-7b",
    "llama-3.2-vision-90b",
    "granite-moe-3b-a800m",
    "smollm-360m",
    "seamless-m4t-large-v2",
    "llama4-scout-17b-a16e",
    "yi-34b",
    "xlstm-125m",
    "codeqwen1.5-7b",
)

DLRM_IDS = ("wdl-criteo", "dssm-avazu")


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str):
    """ArchConfig for assigned archs; DLRMConfig for the paper's DLRMs."""
    import importlib
    mod = importlib.import_module(f".{_module_name(arch_id)}", __package__)
    return mod.CONFIG


def arch_for_shape(arch_id: str, shape_name: str):
    """Resolve the (possibly sliding-window) variant used for a shape.

    long_500k on attention archs uses the sliding-window variant
    (DESIGN §3 long_500k policy); SSM/hybrid archs decode in O(1) state
    and keep full config."""
    cfg = get_config(arch_id)
    if shape_name == "long_500k" and cfg.family != "ssm":
        return cfg.with_sliding_window(LONG_CONTEXT_WINDOW)
    return cfg
