"""DSSM on Avazu field layout — the paper's own Table-1 workload."""
from ..models.tabular import DLRMConfig

CONFIG = DLRMConfig(model="dssm", fields_a=14, fields_b=8,
                    vocab=1024, embed_dim=16, z_dim=256, hidden=(512, 256))
