"""Paper Figure 6: end-to-end convergence, Vanilla vs FedBCD vs CELU-VFL.

Wall-clock is modelled as  t = rounds * (bytes/round / WAN_bw + 2*latency)
+ measured compute time  (paper §2.1's 300 Mbps / gateway-proxied WAN; this
container has no real WAN).  Speedups are reported on the time-to-target
metric like the paper's 2.65-6.27x table.
"""
from __future__ import annotations

from .common import csv_row, default_workload, rounds_to, run_protocol

ROUNDS = 1200
LR = 0.003
WAN_BW = 300e6 / 8           # bytes/s
WAN_LAT = 0.01               # s/direction


# The convergence dynamics are measured at miniature geometry (Z_A dim 32,
# B=256 — 65 KB/round); the WALL-CLOCK model uses the paper's deployment
# geometry (Z_A dim 256, B=4096 -> 2 x 4 MB = 224 ms/round at 300 Mbps,
# §2.1) with V100-scale compute (a few ms/update, >90% of time is
# communication).  Local updates overlap the in-flight exchange (the
# paper's two-worker design), so only overlap-excess compute is charged.
PAPER_Z_SHAPE = (4096, 256)          # the paper's per-message geometry
PAPER_Z_BYTES = 2 * 4096 * 256 * 4   # the paper's per-round messages
GPU_COMPUTE_PER_UPDATE = 0.005       # s — conservative V100-scale estimate


def paper_round_bytes(compression: str = "") -> int:
    """Per-round wire bytes at the paper's deployment geometry for a given
    wire codec ('' = the plain fp32 wire -> PAPER_Z_BYTES)."""
    from repro.configs.base import CELUConfig
    from repro.core import engine
    tp = engine.make_transport(CELUConfig(), compression)
    return tp.round_bytes([PAPER_Z_SHAPE])


def sim_time(rounds: int, z_bytes: int, local_ratio: float,
             compute_per_round: float = GPU_COMPUTE_PER_UPDATE) -> float:
    """``z_bytes`` is the PAPER-geometry per-round wire size (see
    ``paper_round_bytes`` — compressed wires shrink it)."""
    comm = rounds * (z_bytes / WAN_BW + 2 * WAN_LAT)
    compute = rounds * compute_per_round * (1.0 + local_ratio)
    return comm + max(0.0, compute - comm)


def hard_workload(model: str, dataset: str, seed: int = 0):
    """Far-from-convergence regime like the paper's 41M-row stream: 4x the
    hash vocabulary and 4x the rows, so each embedding row is updated
    rarely and 1200 rounds stay mid-curve."""
    import dataclasses
    from repro.data import synthetic as synth
    from repro.models.tabular import DLRMConfig
    spec = dataclasses.replace(synth.TABULAR_SPECS[dataset], vocab=512,
                               n_train=131072, n_test=8192)
    data = synth.make_tabular(spec, seed=seed)
    cfg = DLRMConfig(model, spec.fields_a, spec.fields_b, vocab=512,
                     embed_dim=8, z_dim=32, hidden=(64, 32))
    return spec, data, cfg


def run_one(dataset: str, model: str, protocols=("vanilla", "fedbcd",
                                                 "celu"), rounds=ROUNDS,
            compression: str = ""):
    """All rounds are constructed through the K-party engine (the vanilla
    baseline always runs — it calibrates the shared target AUC).  With
    ``compression``, a celu run over the compressed wire joins the table:
    its sim-WAN time is charged at the CODEC's paper-geometry bytes, so
    the speedup composes round savings x wire savings."""
    spec, data, cfg = hard_workload(model, dataset)
    base = run_protocol("vanilla", data, cfg, rounds=rounds, lr=LR,
                        eval_every=50)
    target = 0.97 * base["best_auc"]
    csv_row(f"# end_to_end {model}/{dataset}: target AUC {target:.4f}")
    csv_row("protocol", "rounds_to_target", "sim_wan_s", "speedup_vs_vanilla",
            "final_auc")

    rows = {}
    b_rounds = rounds_to(base["curve"], target) or rounds
    zb = paper_round_bytes()
    t_van = sim_time(b_rounds, zb, 0.0)
    rows["vanilla"] = (b_rounds, t_van, base["final_auc"])

    if "fedbcd" in protocols:
        fb = run_protocol("fedbcd", data, cfg, R=5, rounds=rounds, lr=LR,
                          eval_every=50, target_auc=target)
        fb_rounds = fb["rounds_to_target"] or rounds
        rows["fedbcd(R=5)"] = (fb_rounds, sim_time(fb_rounds, zb, 5.0),
                               fb["final_auc"])

    if "celu" in protocols:
        for R in (5, 8):
            ce = run_protocol("celu", data, cfg, R=R, W=5, xi=60.0,
                              rounds=rounds, lr=LR, eval_every=50,
                              target_auc=target)
            ce_rounds = ce["rounds_to_target"] or rounds
            rows[f"celu(R={R})"] = (ce_rounds,
                                    sim_time(ce_rounds, zb, float(R)),
                                    ce["final_auc"])
        if compression:
            cc = run_protocol("celu", data, cfg, R=5, W=5, xi=60.0,
                              rounds=rounds, lr=LR, eval_every=50,
                              target_auc=target, compression=compression)
            cc_rounds = cc["rounds_to_target"] or rounds
            czb = paper_round_bytes(compression)
            rows[f"celu(R=5,{compression})"] = (
                cc_rounds, sim_time(cc_rounds, czb, 5.0), cc["final_auc"])

    for name, (r, t, a) in rows.items():
        csv_row(name, r, f"{t:.1f}", f"{t_van / t:.2f}x", f"{a:.4f}")


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--protocol", default="all",
                    choices=("all", "vanilla", "fedbcd", "celu"))
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--dataset", default="all",
                    choices=("all", "criteo", "avazu"))
    ap.add_argument("--compression", default="", metavar="CODEC",
                    help="also run celu over this wire codec (e.g. "
                         "int8_topk; see repro.core.compression.CODEC_SPECS)")
    args = ap.parse_args(argv)
    protocols = ("vanilla", "fedbcd", "celu") if args.protocol == "all" \
        else (args.protocol,)
    if args.compression and "celu" not in protocols:
        import sys
        sys.exit("--compression measures the celu preset over the "
                 "compressed wire: rerun with --protocol celu (or all)")
    if args.dataset in ("all", "criteo"):
        run_one("criteo", "wdl", protocols, args.rounds, args.compression)
    if args.dataset in ("all", "avazu"):
        run_one("avazu", "dssm", protocols, args.rounds, args.compression)


if __name__ == "__main__":
    main()
