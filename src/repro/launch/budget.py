"""Exact per-party HBM budgets at FULL LLM geometry — without ever
materializing a weight.

Everything here runs under ``jax.eval_shape``: the 3B-param MoE config
is "instantiated" as a tree of ShapeDtypeStructs, so the accounting is
exact (it is the same init/``workset_init``/``opt.init`` code the
training run lowers) yet costs a trace, not tens of GB of host RAM.
Three components per party, the three walls the quantized-at-rest
storage codecs attack:

  * **params** — the party's tower slice (``models.vfl.init_all``);
  * **optimizer state** — the AdaGrad accumulator
    (``optim.quantized.opt_state_nbytes``): fp32 mirrors the params,
    bf16 halves it, int8 stores sqrt-space codes + per-row scales;
  * **workset cache** — the W-deep ring of cut statistics ⟨z, dz⟩ that
    CELU's local updates replay (``core.workset``): at (B, S, d) LLM
    shapes this dwarfs the model, and the fp32→int4 at-rest ladder is
    what brings a real-geometry party back under one device's HBM (the
    numbers land in ``results/BENCH_llm.json`` and docs/llm_memory.md).

Used by ``benchmarks/llm.py`` and ``examples/llm_vfl_training.py`` so
the benchmark table and the example's printed budget cannot drift."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core.workset import QUANT_KEYS, workset_init
from ..models import vfl
from ..optim import make_optimizer
from ..optim.quantized import opt_state_nbytes


def tree_nbytes(shapes) -> int:
    """Total device bytes of a pytree of arrays / ShapeDtypeStructs."""
    return sum(int(leaf.size) * jnp.dtype(leaf.dtype).itemsize
               for leaf in jax.tree_util.tree_leaves(shapes))


def _param_shapes(cfg: ArchConfig):
    return jax.eval_shape(lambda: vfl.init_all(jax.random.PRNGKey(0), cfg))


def _z_struct(cfg: ArchConfig, params_a, batch_size: int, seq_len: int):
    """Abstract cut tensor Z_A: eval_shape through the REAL party-A
    forward so the budget tracks the model code, not a hand-derived
    (B, S, d) guess."""
    batch_a = {"tokens_a": jax.ShapeDtypeStruct((batch_size, seq_len),
                                                jnp.int32)}
    return jax.eval_shape(
        lambda p, b: vfl.forward_a(p, cfg, b, train=True), params_a,
        batch_a)


def _cache_nbytes(z, W: int, cache_dtype: str) -> int:
    """Cut-statistics bytes of ONE W-deep workset ring holding ⟨z, dz⟩
    at ``cache_dtype`` — the exact ``workset_init`` layout (codes +
    scales + packing padding), via eval_shape."""
    table = jax.eval_shape(
        lambda zz: workset_init(W, {"z": zz, "dz": zz},
                                cache_dtype=cache_dtype), z)
    return tree_nbytes({k: table["buf"][k] for k in QUANT_KEYS})


def party_hbm_budget(cfg: ArchConfig, *, batch_size: int, seq_len: int,
                     W: int = 5, cache_dtype: str = "float32",
                     opt_state_dtype: str = "float32",
                     lr: float = 0.01) -> Dict[str, Any]:
    """-> exact per-party HBM bytes at full geometry (flat dict of int
    counters; every key ends in ``_bytes`` so the benchmark-regression
    gate treats them as deterministic)."""
    params = _param_shapes(cfg)
    opt = make_optimizer("adagrad", lr, state_dtype=opt_state_dtype)
    z = _z_struct(cfg, params["a"], batch_size, seq_len)
    cache_b = _cache_nbytes(z, W, cache_dtype)
    row = {
        "params_bytes_a": tree_nbytes(params["a"]),
        "params_bytes_b": tree_nbytes(params["b"]),
        "opt_state_bytes_a": opt_state_nbytes(opt, params["a"]),
        "opt_state_bytes_b": opt_state_nbytes(opt, params["b"]),
        # both parties keep one W-deep ring over the same cut tensor
        # (party B's table holds the K=1 z/dz lists — identical bytes)
        "cache_bytes_a": cache_b,
        "cache_bytes_b": cache_b,
    }
    for p in ("a", "b"):
        row[f"hbm_total_bytes_{p}"] = (row[f"params_bytes_{p}"]
                                       + row[f"opt_state_bytes_{p}"]
                                       + row[f"cache_bytes_{p}"])
    return row


def format_budget(name: str, row: Dict[str, Any]) -> str:
    """Human-readable per-party budget block (the example prints this)."""
    gb = 1024 ** 3
    lines = [f"[hbm] {name}: per-party device-memory budget"]
    for p in ("a", "b"):
        lines.append(
            f"[hbm]   party {p}: params "
            f"{row[f'params_bytes_{p}'] / gb:8.3f} GiB + opt state "
            f"{row[f'opt_state_bytes_{p}'] / gb:8.3f} GiB + workset cache "
            f"{row[f'cache_bytes_{p}'] / gb:8.3f} GiB = "
            f"{row[f'hbm_total_bytes_{p}'] / gb:8.3f} GiB")
    return "\n".join(lines)
