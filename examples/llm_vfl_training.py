"""CELU-VFL on an LLM backbone: Party A holds an auxiliary token stream,
Party B the main tokens + labels.  Runs the full protocol stack (workset
table, round-robin sampling, instance weighting) on a reduced smollm
config — the same code path the production configs lower through.

    PYTHONPATH=src python examples/llm_vfl_training.py [--arch hymba-1.5b]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train as T  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--rounds", type=int, default=12)
    args = ap.parse_args()
    T.main(["--arch", args.arch, "--protocol", "celu",
            "--rounds", str(args.rounds), "--batch-size", "4",
            "--seq-len", "32", "--reduced", "--R", "3", "--W", "3",
            "--lr", "0.02"])


if __name__ == "__main__":
    main()
