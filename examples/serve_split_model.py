"""Serve a split VFL model at production shape: continuous batching over
the party boundary.

Two runs of the serving CLI (repro.launch.serve), both reduced for CPU:

  * smollm-360m (fusion="add"): the ServeEngine path — requests admit
    into a fixed-capacity lane array and evict mid-flight, every decode
    step is ONE compiled program over all lanes, the cut activation
    crosses the int8 uplink and Party B fuses it from the quantized
    activation ring.  Prints requests/sec, p50/p99 token latency, and
    exact wire bytes per token.
  * llama-3.2-vision-90b (cross-attn): the sequential fallback — the
    vision memory crosses once at prefill, decode is Party-B-local, so
    there is no per-token activation to batch over the wire.

    PYTHONPATH=src python examples/serve_split_model.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve as S  # noqa: E402


def main():
    # continuous-batching engine: 12 requests through 4 lanes
    S.main(["--arch", "smollm-360m", "--requests", "12", "--capacity", "4",
            "--prompt-len", "16", "--gen", "8"])
    print()
    # cross-attn family: sequential naive_generate fallback
    S.main(["--arch", "llama-3.2-vision-90b", "--requests", "2",
            "--prompt-len", "16", "--gen", "8"])


if __name__ == "__main__":
    main()
