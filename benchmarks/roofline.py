"""§Roofline reporting + cross-pod collective accounting.

1. Aggregates results/dryrun_baseline.jsonl (written by launch.dryrun) into
   the per-(arch x shape x mesh) roofline table used by EXPERIMENTS.md.
2. Measures the pod-protocol claim: inter-pod ppermute bytes per MODEL
   UPDATE drop ~(R+1)x with CELU local updates (lowering the 2-pod round
   with R=0 vs R=5 and parsing the HLO).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import csv_row

_RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
RESULTS = [os.path.join(_RESULTS_DIR, "dryrun_baseline.jsonl"),
           os.path.join(_RESULTS_DIR, "dryrun_final.jsonl")]
PERF = os.path.join(_RESULTS_DIR, "dryrun_perf2.jsonl")


def report_table(paths=None, tag: str = ""):
    paths = [p for p in (paths or RESULTS) if os.path.exists(p)]
    if not paths:
        csv_row("# roofline: no dryrun results",
                "(run launch.dryrun --all [--multi-pod] first)")
        return []
    seen = {}
    for path in paths:                      # later files take precedence
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                if r.get("tag", "") != tag:
                    continue
                seen[(r["arch"], r["shape"], r["mesh"])] = r   # last wins
    rows = sorted(seen.values(), key=lambda r: (r["arch"], r["shape"],
                                                r["mesh"]))
    csv_row("# roofline terms (seconds/step, per-device HLO)")
    csv_row("arch", "shape", "mesh", "ok", "compute_s", "memory_s",
            "collective_s", "dominant", "useful_flops_frac", "temp_GB")
    for r in rows:
        if not r.get("ok"):
            csv_row(r["arch"], r["shape"], r["mesh"], "FAIL",
                    "-", "-", "-", "-", "-", "-")
            continue
        t = r["roofline"]
        csv_row(r["arch"], r["shape"], r["mesh"], "ok",
                f"{t['compute_s']:.4f}", f"{t['memory_s']:.4f}",
                f"{t['collective_s']:.4f}", r["dominant"],
                f"{r['useful_flops_frac']:.3f}",
                f"{r['memory']['temp_bytes'] / 1e9:.1f}")
    return rows


_POD_MEASURE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp, re, sys
sys.path.insert(0, {src!r})
from repro.core.pod_protocol import make_pod_round, init_pod_state
from repro.optim import adagrad
from repro.launch.dryrun import collective_bytes

mesh = jax.make_mesh((2,), ("pod",))
opt = adagrad(0.05)
for R in (0, 3, 5, 8):
    params, opt_state, ws = init_pod_state(
        jax.random.PRNGKey(0), mesh, opt, n_fields=16, vocab=512, batch=4096,
        W=5, z_dim=256, hidden=256)
    rnd = make_pod_round(mesh, opt, R=max(R, 1), cos_xi=0.5)
    x = jax.ShapeDtypeStruct((2, 4096, 16), jnp.int32)
    y = jax.ShapeDtypeStruct((2, 4096), jnp.float32)
    lowered = rnd.lower(params, opt_state, ws, x, y)
    txt = lowered.compile().as_text()
    coll = collective_bytes(txt)
    # ppermute bytes per ROUND are constant (Z_A + dZ_A, the paper's 2x4MB
    # for B=4096 z=256 fp32); CELU funds 1+R updates with them.
    cp = coll["collective-permute"] if R else coll["collective-permute"]
    updates = 1 + R
    print(f"R={{R}} (vanilla)" if R == 0 else f"R={{R}}        ", end=" ")
    print(f"ppermute_bytes/round={{cp}} updates/round={{updates}} "
          f"bytes/update={{cp/updates:.0f}}")
""".format(src=os.path.join(os.path.dirname(__file__), "..", "src"))


def pod_collective_accounting():
    csv_row("# pod-protocol cross-pod bytes (2-device lowering)")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _POD_MEASURE],
                       capture_output=True, text=True, env=env, timeout=900)
    for line in (r.stdout or "").strip().splitlines():
        csv_row(line)
    if r.returncode != 0:
        csv_row("# pod measurement failed:", r.stderr[-400:])


def report_perf_variants():
    """§Perf iteration results (tagged runs from dryrun_perf.jsonl)."""
    if not os.path.exists(PERF):
        return
    csv_row("# perf-iteration variants (see EXPERIMENTS.md §Perf)")
    csv_row("arch", "shape", "tag", "ok", "compute_s", "memory_s",
            "collective_s", "temp_GB")
    with open(PERF) as f:
        for line in f:
            r = json.loads(line)
            if not r.get("ok"):
                csv_row(r["arch"], r["shape"], r.get("tag", ""), "FAIL",
                        "-", "-", "-", "-")
                continue
            t = r["roofline"]
            csv_row(r["arch"], r["shape"], r.get("tag", ""), "ok",
                    f"{t['compute_s']:.4f}", f"{t['memory_s']:.4f}",
                    f"{t['collective_s']:.4f}",
                    f"{r['memory']['temp_bytes'] / 1e9:.1f}")


def cache_accounting():
    """Workset-cache roofline at the paper's deployment geometry (W=5,
    B=4096, z=256): at-rest bytes of the cut-statistic cache per party and
    the HBM bytes one party-A local-update sample moves, per cache dtype
    and sample path (analytic counters — ``workset.sample_hbm_bytes``)."""
    import jax.numpy as jnp
    from repro.core.workset import QUANT_KEYS, sample_hbm_bytes, \
        workset_init, workset_nbytes

    W, B, F = 5, 4096, 256
    z = jnp.zeros((B, F), jnp.float32)
    entry = {"z": z, "dz": z}
    csv_row("# workset cache roofline (paper geometry W=5 B=4096 z=256; "
            "per party)")
    csv_row("cache_dtype", "cache_MB", "sample_hbm_KB_unfused",
            "sample_hbm_KB_fused")
    for cd in ("float32", "bfloat16", "int8"):
        nb = workset_nbytes(workset_init(W, entry, cache_dtype=cd),
                            QUANT_KEYS)
        csv_row(cd, f"{nb / 1e6:.1f}",
                f"{sample_hbm_bytes(entry, cd, fused=False) / 1e3:.0f}",
                f"{sample_hbm_bytes(entry, cd, fused=True) / 1e3:.0f}")


def main():
    report_table()
    report_perf_variants()
    cache_accounting()
    pod_collective_accounting()


if __name__ == "__main__":
    main()
