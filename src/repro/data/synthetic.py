"""Seeded synthetic datasets, vertically partitioned across two parties.

The real Criteo / Avazu / D3 datasets are not available offline; we keep the
*field layout* of the paper's Table 1 (26/13, 14/8, 25/18 categorical fields
for parties A/B) and plant a random teacher model so that the learning
problem has signal — convergence-curve comparisons between protocols remain
meaningful because all protocols see the identical stream.

Alignment (paper §2.1): instances are generated pre-aligned (PSI is assumed
done, as in the paper) and both parties sample mini-batches with the same
seed, so batch ``i`` is the same instance rows at both parties.

Also provides an aligned token-stream dataset for the LLM-backbone VFL smoke
tests (Party A: auxiliary token stream; Party B: main tokens + next-token
labels).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Tuple

import numpy as np


@dataclass(frozen=True)
class TabularSpec:
    name: str
    fields_a: int
    fields_b: int
    vocab: int = 1024          # per-field hash vocabulary
    n_train: int = 16384
    n_test: int = 4096
    label_noise: float = 0.05  # teacher flip probability


CRITEO = TabularSpec("criteo", fields_a=26, fields_b=13)
AVAZU = TabularSpec("avazu", fields_a=14, fields_b=8)
D3 = TabularSpec("d3", fields_a=25, fields_b=18)
TABULAR_SPECS = {s.name: s for s in (CRITEO, AVAZU, D3)}


def make_tabular(spec: TabularSpec, seed: int = 0
                 ) -> Dict[str, Dict[str, np.ndarray]]:
    """-> {"train": {x_a (N,Fa) i32, x_b (N,Fb) i32, y (N,) f32}, "test": ...}.

    Labels come from a planted teacher: per-(field, value) random effects,
    y = Bernoulli(sigmoid(sum of effects / sqrt(F))) with a small flip rate.
    """
    rng = np.random.default_rng(seed)
    F = spec.fields_a + spec.fields_b
    teacher = rng.normal(0.0, 1.0, size=(F, spec.vocab)).astype(np.float32)

    def gen(n: int):
        x = rng.integers(0, spec.vocab, size=(n, F), dtype=np.int32)
        logit = teacher[np.arange(F)[None, :], x].sum(axis=1) / np.sqrt(F)
        p = 1.0 / (1.0 + np.exp(-2.0 * logit))
        y = (rng.random(n) < p).astype(np.float32)
        flip = rng.random(n) < spec.label_noise
        y = np.where(flip, 1.0 - y, y)
        return {"x_a": x[:, :spec.fields_a],
                "x_b": x[:, spec.fields_a:],
                "y": y.astype(np.float32)}

    return {"train": gen(spec.n_train), "test": gen(spec.n_test)}


def aligned_batches(data: Dict[str, np.ndarray], batch_size: int,
                    seed: int = 0, drop_last: bool = True
                    ) -> Iterator[Tuple[int, Dict[str, np.ndarray],
                                        Dict[str, np.ndarray]]]:
    """Yield (batch_idx, batch_a, batch_b) forever, reshuffling per epoch.

    Both parties use the same seed -> identical permutations (paper §2.1).
    The whole-dataset shuffle also randomizes the order of instances inside
    the workset window (paper §3.2 last paragraph).
    """
    n = data["y"].shape[0]
    rng = np.random.default_rng(seed)
    idx = 0
    while True:
        perm = rng.permutation(n)
        for s in range(0, n - batch_size + 1, batch_size):
            rows = perm[s:s + batch_size]
            yield (idx,
                   {"x_a": data["x_a"][rows]},
                   {"x_b": data["x_b"][rows], "y": data["y"][rows]})
            idx += 1


# --------------------------------------------------------------------------
# Token streams for the LLM-backbone VFL smoke tests
# --------------------------------------------------------------------------
def make_token_stream(n: int, seq_len: int, vocab: int, aux_vocab: int,
                      seed: int = 0) -> Dict[str, np.ndarray]:
    """Aligned (tokens, tokens_a, labels) with a planted bigram structure so
    loss decreases under training."""
    rng = np.random.default_rng(seed)
    # Markov-ish stream: next token correlated with current
    trans = rng.integers(0, vocab, size=(vocab,), dtype=np.int32)
    toks = np.empty((n, seq_len + 1), np.int32)
    toks[:, 0] = rng.integers(0, vocab, size=(n,))
    for t in range(seq_len):
        follow = rng.random((n,)) < 0.7
        toks[:, t + 1] = np.where(follow, trans[toks[:, t]],
                                  rng.integers(0, vocab, size=(n,)))
    tokens = toks[:, :-1]
    labels = toks[:, 1:]
    tokens_a = ((tokens.astype(np.int64) * 2654435761) % aux_vocab
                ).astype(np.int32)
    return {"tokens": tokens, "tokens_a": tokens_a, "labels": labels}


def token_batches(data: Dict[str, np.ndarray], batch_size: int,
                  seed: int = 0):
    n = data["tokens"].shape[0]
    rng = np.random.default_rng(seed)
    idx = 0
    while True:
        perm = rng.permutation(n)
        for s in range(0, n - batch_size + 1, batch_size):
            rows = perm[s:s + batch_size]
            yield (idx,
                   {"tokens_a": data["tokens_a"][rows]},
                   {"tokens": data["tokens"][rows],
                    "labels": data["labels"][rows]})
            idx += 1
