"""Serve a split VFL model: batched prefill + token-by-token decode with the
party boundary kept as a module boundary.  Uses the VLM config (Party A =
vision owner supplying patch embeddings) reduced for CPU.

    PYTHONPATH=src python examples/serve_split_model.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve as S  # noqa: E402


def main():
    S.main(["--arch", "llama-3.2-vision-90b", "--prompt-len", "16",
            "--gen", "8", "--batch", "2"])
    S.main(["--arch", "xlstm-125m", "--prompt-len", "16",
            "--gen", "8", "--batch", "2"])


if __name__ == "__main__":
    main()
