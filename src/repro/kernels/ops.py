"""Public jit'd wrappers for the Pallas kernels.

On this CPU container every kernel runs with ``interpret=True`` (the kernel
body executed in Python by the Pallas interpreter — bit-accurate for
correctness, not for speed).  On a real TPU set
``repro.kernels.ops.INTERPRET = False`` (or the REPRO_PALLAS_COMPILE env
var) to compile to Mosaic.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from . import cosine_weight as _cw
from . import flash_attention as _fa
from . import fused_adagrad as _ag
from . import fused_sample as _fs
from . import quantize as _qz

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "") == ""


def _slot1(slot):
    """Scalar slot index -> the (1,) int32 scalar-prefetch operand."""
    return jnp.asarray(slot, jnp.int32).reshape((1,))


def cosine_weight(ad_hoc, stale, cos_xi):
    """Algorithm-2 InsWeight: -> (B,) float32 weights (weights-only kernel:
    no cotangent operand/result moves through VMEM)."""
    B = ad_hoc.shape[0]
    return _cw.cosine_weights_2d(ad_hoc.reshape(B, -1),
                                 stale.reshape(B, -1),
                                 jnp.float32(cos_xi), interpret=INTERPRET)


def weighted_cotangent(ad_hoc, stale, dz, cos_xi):
    """Fused InsWeight + weights ⊙ ∇Z.  -> (weights (B,), weighted dz)."""
    B = ad_hoc.shape[0]
    shape = dz.shape
    w, out = _cw.cosine_weight_2d(ad_hoc.reshape(B, -1),
                                  stale.reshape(B, -1), dz.reshape(B, -1),
                                  jnp.float32(cos_xi), interpret=INTERPRET)
    return w, out.reshape(shape)


def fused_gather_weight(slot, ad_hoc, z_ring, dz_ring, cos_xi):
    """Fused workset sample over a full-precision (fp32/bf16) ring:
    gather slot → row-cosine vs ad_hoc → threshold → cotangent scale in
    one VMEM pass.  slot: scalar int32; ad_hoc: (B, ...); z_ring/dz_ring:
    (W,) + ad_hoc.shape.  -> (weights (B,) f32, weighted cotangent f32 in
    ad_hoc's shape)."""
    B = ad_hoc.shape[0]
    W = z_ring.shape[0]
    w, cot = _fs.fused_sample_2d(_slot1(slot), ad_hoc.reshape(B, -1),
                                 z_ring.reshape(W, B, -1),
                                 dz_ring.reshape(W, B, -1),
                                 jnp.float32(cos_xi), interpret=INTERPRET)
    return w, cot.reshape(ad_hoc.shape)


def fused_gather_weight_q8(slot, ad_hoc, zq, zscale, dzq, dzscale, cos_xi):
    """Fused workset sample over the int8-at-rest ring (gather → dequant →
    cosine → threshold → cotangent scale, one VMEM pass).  zq/dzq:
    (W, B, F) int8, zscale/dzscale: (W, B) fp32 row scales."""
    B = ad_hoc.shape[0]
    w, cot = _fs.fused_sample_q8_2d(_slot1(slot), ad_hoc.reshape(B, -1),
                                    zq, zscale, dzq, dzscale,
                                    jnp.float32(cos_xi), interpret=INTERPRET)
    return w, cot.reshape(ad_hoc.shape)


def fused_gather_weight_q4(slot, ad_hoc, zq, zscale, dzq, dzscale, cos_xi):
    """Fused workset sample over the int4 nibble-packed ring (gather →
    unpack → dequant → cosine → threshold → cotangent scale, one VMEM
    pass — the packed bytes are the only HBM ring read).  zq/dzq:
    (W, B, ceil(F/2)) packed uint8, zscale/dzscale: (W, B) fp32 row
    scales.  Odd F: the storage codec's pad nibble decodes to zero, so
    the wrapper zero-pads ``ad_hoc`` to the packed width and slices the
    pad column off the cotangent."""
    B = ad_hoc.shape[0]
    a2d = ad_hoc.reshape(B, -1).astype(jnp.float32)
    F = a2d.shape[1]
    Fp = 2 * zq.shape[2]
    if Fp != F:                      # odd row width: one pad column
        a2d = jnp.pad(a2d, ((0, 0), (0, Fp - F)))
    w, cot = _fs.fused_sample_q4_2d(_slot1(slot), a2d, zq, zscale,
                                    dzq, dzscale, jnp.float32(cos_xi),
                                    interpret=INTERPRET)
    return w, cot[:, :F].reshape(ad_hoc.shape)


def fused_gather_dequant_q8(slot, zq, zscale):
    """Gather + dequantize one int8 ring entry (the serving decode-cache
    read: the cached cross-party activation comes straight out of the
    quantized ring, no weighting).  zq: (W, B, F) int8, zscale: (W, B)
    fp32 row scales.  -> (B, F) fp32."""
    return _fs.fused_dequant_q8_2d(_slot1(slot), zq, zscale,
                                   interpret=INTERPRET)


def fused_gather_dequant_q4(slot, zq, zscale, width: int):
    """Gather + unpack + dequantize one int4 nibble-packed ring entry.
    zq: (W, B, ceil(F/2)) packed uint8, zscale: (W, B) fp32 row scales,
    width: the true row width F (the pad nibble of odd rows is sliced
    off).  -> (B, F) fp32."""
    out = _fs.fused_dequant_q4_2d(_slot1(slot), zq, zscale,
                                  interpret=INTERPRET)
    return out[:, :width]


def quantize_stochastic(x, u, levels):
    """Fused per-tile absmax-scale stochastic-rounding quantizer.

    x: (T, L) value tiles, u: (T, L) uniforms in [0, 1), levels: max code
    magnitude (127 = int8, 7 = int4).  -> (codes int8 (T, L), fp32 scales
    (T,)); bit-exact with ``kernels.ref.quantize_sr_ref``."""
    return _qz.quantize_sr_2d(x, u, levels, interpret=INTERPRET)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0):
    """(B, S, H, hd) x3 -> (B, S, H, hd); kv pre-repeated to H heads."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               interpret=INTERPRET)


def fused_adagrad(grad, accum, lr, eps):
    """-> (update fp32, new_accum fp32)."""
    return _ag.fused_adagrad(grad, accum, lr, eps, interpret=INTERPRET)


def fused_adagrad_q8(grad2d, accum_q, accum_scale, u, lr, eps):
    """int8-at-rest AdaGrad step (dequant → accumulate → scale → requant
    in one VMEM pass; the fp32 accumulator never exists in HBM).
    grad2d/u: (R, C) fp32 in the optimizer's padded tiling, accum_q:
    (R, C) int8 codes, accum_scale: (R, 1) fp32 master scales.
    -> (update fp32, new codes int8, new scales)."""
    return _ag.fused_adagrad_q8(grad2d, accum_q, accum_scale, u, lr, eps,
                                interpret=INTERPRET)


def flash_attention_trainable(q, k, v, *, causal: bool = True,
                              window: int = 0):
    """Differentiable flash attention (custom VJP: FlashAttention-2
    backward kernels — dq / dkv recompute score tiles, never materialize
    the softmax)."""
    from .flash_attention_bwd import flash_attention_vjp
    return flash_attention_vjp(q, k, v, causal, window, INTERPRET)
