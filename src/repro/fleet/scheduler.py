"""Device-side round scheduler: the PipelinedEngine schedule as ONE traced
step, batchable over a leading job axis.

``PipelinedEngine`` (core/engine.py) drives its depth-D exchange queue from
the HOST: ``rs.pending`` is a Python tuple, queue fill/merge decisions and
the flush alternation are Python branches, and every round costs several
separately-dispatched jits.  None of that vmaps.  This module re-expresses
the exact same schedule as pure device code:

  * the exchange queue is a FIXED-CAPACITY stacked :class:`PendingExchange`
    (every leaf grows a leading ``depth`` axis) carried in
    :class:`FleetRoundState`;
  * the scheduler phase — the live in-flight count ``n_pending`` — is a
    TRACED ``int32`` carried in the state, and the queue-full merge
    decision (and the flush drain) ride ``lax.cond`` over it instead of
    host branching;
  * per-job hyper-parameters that the scalar engine bakes into closures
    (optimizer lr, the Algorithm-2 ``cos ξ`` threshold, the three PRNG
    base keys) arrive as the traced :class:`JobHyper` argument, so a vmap
    over jobs batches them freely.

One compiled step therefore serves warmup, steady state, and (via
:func:`make_fleet_step`'s flush) the drain — and the whole thing vmaps
over a leading job axis (``repro.fleet.runner``) or lowers per-lane
bit-identically under ``lax.map``.

Bit-exactness contract (the golden gate in tests/test_fleet.py): driven
with the default hyper (``JobHyper.for_spec`` at seed 0), the step at
depth 0/1/2 reproduces ``PipelinedEngine.step``/``flush`` bit-for-bit —
same stage composition, same rng folds, same per-slot staleness charges,
same NaN-loss warmup rows.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import CELUConfig, validate_pipeline_depth
from ..core.engine import (KPartyTask, PendingExchange, _make_stages,
                           _zero_local_metrics, make_transport)
from ..core.weighting import xi_to_cos
from ..optim import make_optimizer

# the scalar engine's fixed PRNG bases (engine._make_stages defaults) —
# a job carrying exactly these keys replays the historical rng chain
ENGINE_RNG_BASES = {"exchange": 17, "insert": 0xCE1, "draw": 29}


class JobHyper(NamedTuple):
    """Per-job TRACED hyper-parameters — everything a fleet batches over
    without recompiling.  Static knobs (depth, codec, cache dtype, W, R,
    sampling...) stay in :class:`~repro.configs.base.CELUConfig` and
    partition the fleet into cohorts instead (see runner.cohort_key)."""
    lr: Any                     # optimizer step size, f32 scalar
    cos_xi: Any                 # Algorithm-2 threshold cos(xi), f32 scalar
    keys: Dict[str, Any]        # {"exchange","insert","draw"} PRNG keys

    @classmethod
    def for_spec(cls, lr: float, xi_degrees: float, seed: int = 0
                 ) -> "JobHyper":
        """Concrete hyper for one job.  ``seed == 0`` keeps the engine's
        fixed PRNG bases (the golden-pinned chain); any other seed folds
        it in for an independent stream per job."""
        keys = {}
        for name, base in ENGINE_RNG_BASES.items():
            k = jax.random.PRNGKey(base)
            keys[name] = k if seed == 0 else jax.random.fold_in(k, seed)
        return cls(lr=jnp.float32(lr),
                   cos_xi=jnp.float32(xi_to_cos(xi_degrees)), keys=keys)


class FleetRoundState(NamedTuple):
    """Batchable scheduler state: the engine's canonical state dict plus
    the device-side exchange queue.

    ``pending`` is a stacked :class:`PendingExchange` — each leaf carries
    a leading ``depth`` axis (slot 0 oldest) — or ``None`` at depths 0/1,
    whose queue never survives a step.  ``n_pending`` is the traced
    scheduler phase: the live in-flight count that drives dispatch
    chaining, per-slot staleness charges, and the ``lax.cond`` merge."""
    state: Dict[str, Any]
    pending: Optional[PendingExchange]
    n_pending: Any


def _at(tree, i):
    """Slice index ``i`` (traced ok) off every leaf's leading axis."""
    return jax.tree_util.tree_map(
        lambda x: jax.lax.dynamic_index_in_dim(x, i, 0, keepdims=False),
        tree)


def _put(tree, value, i):
    """Write ``value`` into slot ``i`` (traced ok) of every leaf."""
    return jax.tree_util.tree_map(
        lambda buf, v: jax.lax.dynamic_update_index_in_dim(buf, v, i, 0),
        tree, value)


def _pop(tree):
    """Shift the queue left: slot 1 -> 0, ...; the vacated tail slot
    holds a stale copy that the occupancy counter guards from reads."""
    return jax.tree_util.tree_map(
        lambda x: jnp.roll(x, -1, axis=0), tree)


def _select(pred, a, b):
    return jax.tree_util.tree_map(
        lambda x, y: jnp.where(pred, x, y), a, b)


def average_flush_metrics(m: Dict[str, Any]) -> Dict[str, Any]:
    """Finish ONE job's flush metrics on the host: sum the per-scan float
    rows eagerly (one IEEE round-to-nearest per add, exactly
    ``PipelinedEngine.flush``'s ``sum(...) / n`` — an in-program XLA
    accumulate fuses the chain and rounds differently) and divide by the
    number of scans that actually ran.  Idle rows are zeros, so including
    them in the sum is exact.  Depth 0/1 metrics pass through unchanged."""
    if "w_mean_scans" not in m:
        return dict(m)
    n = np.float32(np.asarray(m["n_scans"]))
    out = {"local_steps": np.asarray(m["local_steps"])}
    for key in ("w_mean", "w_zero_frac"):
        acc = np.float32(0.0)
        for v in np.asarray(m[key + "_scans"], np.float32):
            acc = np.float32(acc + v)
        out[key] = np.float32(acc / n)
    return out


def make_fleet_step(task: KPartyTask, celu: CELUConfig, *,
                    depth: Optional[int] = None,
                    optimizer: str = "adagrad",
                    opt_kwargs: Optional[Dict[str, Any]] = None,
                    local_steps: int = -1, transport=None,
                    compression: Optional[str] = None,
                    fused_weighting: bool = True):
    """-> ``(init, step, flush)`` — the device-side schedule for ONE job
    (vmap/lax.map over a leading job axis is the caller's move).

      * ``init(state, batches_a, batch_b) -> FleetRoundState`` adopts an
        :func:`~repro.core.engine.init_state` dict and (at depth >= 2)
        allocates the zeroed exchange-queue slots from the payload shapes.
      * ``step(fs, hyper, batches_a, batch_b, batch_idx) -> (fs, metrics)``
        is one communication round — exactly
        :meth:`PipelinedEngine.step`'s composition at this depth, with the
        queue decisions traced (``lax.cond`` over ``fs.n_pending``).
      * ``flush(fs, hyper) -> (fs, metrics)`` drains the queue:
        a static ``depth``-iteration loop of conditional scan+merge pairs
        (no-ops once the queue is empty) plus the final local scan,
        mirroring :meth:`PipelinedEngine.flush`'s alternation.  At
        depth >= 2 the float metrics come back as per-scan rows —
        finish them with :func:`average_flush_metrics`.

    The stages are (re)built inside each trace so ``hyper``'s traced
    lr/cos_xi/rng-keys flow into the optimizer and stage closures."""
    if depth is None:
        depth = celu.pipeline_depth
    validate_pipeline_depth(depth, celu.W)
    dynamic = depth >= 2
    n_local = celu.R if local_steps < 0 else local_steps
    tp = transport if transport is not None \
        else make_transport(celu, compression)

    def _stages(hyper: JobHyper):
        opt = make_optimizer(optimizer, hyper.lr, **(opt_kwargs or {}))
        return _make_stages(
            task, opt, celu, n_local=n_local, tp=tp, fused=fused_weighting,
            pipeline_staleness=depth,
            lr_damping=celu.pipeline_lr_damping if dynamic else 0.0,
            cos_xi=hyper.cos_xi, rng_keys=hyper.keys)

    def init(state: Dict[str, Any], batches_a, batch_b) -> FleetRoundState:
        if not dynamic:
            return FleetRoundState(state, None, jnp.int32(0))
        # size the queue slots from abstract payload shapes — zeros, never
        # read before a dispatch writes them (n_pending guards every read)
        compute, _, _ = _stages(JobHyper.for_spec(1.0, celu.xi_degrees))
        fresh_sd = jax.eval_shape(
            lambda s, ba, bb: compute(s["params"], s["transport"], ba, bb,
                                      s["comm_rounds"]),
            state, batches_a, batch_b)
        slot = PendingExchange(
            fresh=fresh_sd, batches_a=batches_a, batch_b=batch_b,
            batch_idx=jnp.int32(0), dispatched_at=jnp.int32(0))
        pending = jax.tree_util.tree_map(
            lambda x: jnp.zeros((depth,) + jnp.shape(x),
                                jnp.asarray(x).dtype
                                if not hasattr(x, "dtype") else x.dtype),
            slot)
        return FleetRoundState(state, pending, jnp.int32(0))

    def step(fs: FleetRoundState, hyper: JobHyper, batches_a, batch_b,
             batch_idx):
        compute, apply_, scan = _stages(hyper)
        state = fs.state
        if depth == 0:
            # dispatch -> merge -> local: the sequential schedule
            fresh = compute(state["params"], state["transport"],
                            batches_a, batch_b, state["comm_rounds"])
            state, m = apply_(state, fresh, batches_a, batch_b, batch_idx)
            state, lm = scan(state)
            m.update(lm)
            return fs._replace(state=state), m
        if depth == 1:
            # dispatch -> local (overlapped) -> merge; the queue fills and
            # drains within the step, so no cross-step slots are carried
            fresh = compute(state["params"], state["transport"],
                            batches_a, batch_b, state["comm_rounds"])
            state, lm = scan(state)
            state, m = apply_(state, fresh, batches_a, batch_b, batch_idx)
            m.update(lm)
            return fs._replace(state=state), m

        # depth >= 2: device-side queue.  Dispatch chains the transport
        # residuals off the NEWEST in-flight exchange (dispatch-order
        # telescoping — see PipelinedEngine.dispatch) and folds the rng
        # over the dispatch sequence number comm_rounds + n_pending.
        pending, n = fs.pending, fs.n_pending
        newest = _at(pending, n - 1)            # clamped at n=0; masked below
        tstate = _select(n > 0, newest.fresh["tstate"], state["transport"])
        fresh = compute(state["params"], tstate, batches_a, batch_b,
                        state["comm_rounds"] + n)
        slot = PendingExchange(
            fresh=fresh, batches_a=batches_a, batch_b=batch_b,
            batch_idx=jnp.asarray(batch_idx, jnp.int32),
            dispatched_at=jnp.asarray(state["comm_rounds"], jnp.int32))
        pending = _put(pending, slot, n)
        n = n + 1

        # the local scan is charged the live in-flight count
        state, lm = scan(state, n)

        # merge the oldest exchange once the queue holds `depth`; the
        # first depth-1 steps only fill the queue and report a NaN loss
        def _merge(args):
            state, pending, n = args
            oldest = _at(pending, jnp.int32(0))
            s = state["comm_rounds"] - oldest.dispatched_at
            state, m = apply_(state, oldest.fresh, oldest.batches_a,
                              oldest.batch_b, oldest.batch_idx, s)
            return state, _pop(pending), n - 1, m["loss"]

        def _warmup(args):
            state, pending, n = args
            return state, pending, n, jnp.float32(jnp.nan)

        state, pending, n, loss = jax.lax.cond(
            n == depth, _merge, _warmup, (state, pending, n))
        m = {"loss": loss}
        m.update(lm)
        return FleetRoundState(state, pending, n), m

    def flush(fs: FleetRoundState, hyper: JobHyper):
        _, apply_, scan = _stages(hyper)
        if depth == 0:
            return fs, _zero_local_metrics()
        if depth == 1:
            state, lm = scan(fs.state)
            return fs._replace(state=state), lm

        # depth >= 2: alternate scan/merge while the queue drains (the
        # occupancy is traced, so the loop is a static `depth` iterations
        # of conditional pairs), then scan once more over the final
        # inserts.  The float metrics come back as RAW per-scan rows
        # (idle iterations report zeros) for the HOST to average via
        # :func:`average_flush_metrics` — XLA fuses an in-program
        # accumulate-and-divide into a single differently-rounded chain,
        # which breaks bit-parity with PipelinedEngine.flush's eager
        # per-op adds.
        n0 = fs.n_pending
        zeros = _zero_local_metrics()

        def _drain(args):
            state, pending, n = args
            state, lm = scan(state, n)
            oldest = _at(pending, jnp.int32(0))
            s = state["comm_rounds"] - oldest.dispatched_at
            state, _ = apply_(state, oldest.fresh, oldest.batches_a,
                              oldest.batch_b, oldest.batch_idx, s)
            return state, _pop(pending), n - 1, lm

        def _idle(args):
            state, pending, n = args
            return state, pending, n, zeros

        state, pending, n = fs.state, fs.pending, fs.n_pending
        rows = []
        for _ in range(depth):
            state, pending, n, lm = jax.lax.cond(
                n > 0, _drain, _idle, (state, pending, n))
            rows.append(lm)
        state, lm = scan(state, n)              # n == 0: the final scan
        rows.append(lm)
        metrics = {
            "local_steps": sum(r["local_steps"] for r in rows),
            "w_mean_scans": jnp.stack([r["w_mean"] for r in rows]),
            "w_zero_frac_scans": jnp.stack([r["w_zero_frac"]
                                            for r in rows]),
            "n_scans": n0 + 1,
        }
        return FleetRoundState(state, pending, n), metrics

    return init, step, flush
