"""Findings, per-case results, and the AUDIT.json / human renderers.

Finding codes are dotted ``family.rule`` slugs — the family prefix is the
invariant that failed (``taint`` / ``wire`` / ``kernel`` / ``audit``), the
rule names the specific check.  ``where`` names the offending jaxpr value,
codec, transport direction, or kernel so a CI failure reads as a pointer,
not a riddle.
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

SEVERITIES = ("error", "warning", "info")


@dataclass
class Finding:
    code: str                 # "taint.raw-boundary", "wire.bytes-mismatch", ...
    severity: str             # error | warning | info
    where: str                # offending value / kernel / codec / direction
    detail: str               # human sentence, with numbers
    case: str = ""            # audit case id ("" for case-independent lint)

    def __post_init__(self):
        assert self.severity in SEVERITIES, self.severity

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclass
class CaseResult:
    """One audited configuration: its findings plus the audit's evidence
    (what was traced, what crossed the boundary, what bytes we proved)."""
    name: str
    config: Dict[str, Any] = field(default_factory=dict)
    findings: List[Finding] = field(default_factory=list)
    stats: Dict[str, Any] = field(default_factory=dict)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "config": self.config,
                "findings": [f.to_dict() for f in self.findings],
                "stats": self.stats}


@dataclass
class AuditReport:
    cases: List[CaseResult] = field(default_factory=list)
    meta: Dict[str, Any] = field(default_factory=dict)

    @property
    def findings(self) -> List[Finding]:
        return [f for c in self.cases for f in c.findings]

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def passed(self) -> bool:
        return not self.errors

    def to_dict(self) -> Dict[str, Any]:
        sev = {s: sum(1 for f in self.findings if f.severity == s)
               for s in SEVERITIES}
        return {
            "version": 1,
            "passed": self.passed,
            "summary": {"cases": len(self.cases), **sev},
            "meta": self.meta,
            "cases": [c.to_dict() for c in self.cases],
        }

    def write_json(self, path: str) -> None:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as fh:
            json.dump(self.to_dict(), fh, indent=2, sort_keys=False)
            fh.write("\n")

    def render(self, verbose: bool = False) -> str:
        """Human report: one line per case, findings grouped under it."""
        lines = []
        n_err = len(self.errors)
        for c in self.cases:
            errs = c.errors
            status = "FAIL" if errs else "ok"
            stat_bits = []
            if "boundaries" in c.stats:
                stat_bits.append(f"{c.stats['boundaries']} boundary "
                                 f"crossings")
            if "round_bytes" in c.stats:
                stat_bits.append(f"{c.stats['round_bytes']} B/round")
            if "pallas_calls" in c.stats:
                stat_bits.append(f"{c.stats['pallas_calls']} pallas calls")
            suffix = f"  [{', '.join(stat_bits)}]" if stat_bits else ""
            lines.append(f"[{status:4s}] {c.name}{suffix}")
            shown = c.findings if verbose else errs
            for f in shown:
                lines.append(f"    {f.severity.upper():7s} {f.code} "
                             f"@ {f.where}")
                lines.append(f"            {f.detail}")
        lines.append("")
        if n_err:
            lines.append(f"AUDIT FAILED: {n_err} error(s) across "
                         f"{len(self.cases)} case(s)")
        else:
            lines.append(f"AUDIT PASSED: {len(self.cases)} case(s), "
                         f"{len(self.findings)} non-error finding(s)")
        return "\n".join(lines)
