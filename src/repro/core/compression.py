"""Pluggable wire codecs for the compressed K-party transport
(Compressed-VFL, Castiglia et al. — top-k sparsification and low-bit
quantization of the exchanged cut tensors preserve convergence when
combined with the engine's multiple local steps per round).

A codec maps an arbitrary-shape float array to a *payload* (a pytree of
wire arrays) and back:

    encode(rng, x)        -> payload
    decode(payload, like) -> array with ``like``'s shape/dtype
    wire_bytes(shape, dtype) -> int  — EXACT payload size: equals the sum
        of ``leaf.nbytes`` over the payload for an input of that shape
        (tests pin this), so transport byte accounting is honest.
    lossless              -> bool   — lossless codecs skip error feedback.

Codecs here:

  * :class:`IdentityCodec` — the wire as-is;
  * :class:`StochasticQuantCodec` — int8 / int4 quantization with one fp32
    absmax scale per ``tile`` values and stochastic rounding
    (``floor(x/s + u)``, unbiased); int4 codes are nibble-packed two per
    byte.  The encode hot path is the fused Pallas kernel
    ``kernels.ops.quantize_stochastic`` (absmax + scale + round in one
    VMEM pass); tile counts the kernel can't split fall back to the
    bit-identical jnp reference;
  * :class:`TopKCodec` — keep the k = ratio * n largest-magnitude values
    (indices int16 when they fit, else int32).  ``value_codec`` chains a
    second codec over the kept values (top-k + int8 is Compressed-VFL's
    sketch);
  * :class:`ChainCodec` — residual chaining: stage i encodes what stages
    < i failed to reconstruct, the wire carries every stage's payload, and
    decode sums the stage reconstructions (multi-stage quantization:
    ``int4x2`` ~ int8 quality at int8 cost, but each stage tolerates the
    other's outliers).

Error feedback lives in the transport, not the codec
(:class:`repro.core.engine.CompressedWANTransport`): the per-direction
residual ``r`` is carried in the engine round state, the transport sends
``decode(encode(x + r))`` and keeps ``r' = (x + r) - decoded`` — so
compression error is delayed into the next round's message instead of
lost, and the decoded messages telescope to the uncompressed sum.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

TILE = 128          # values per fp32 quantization scale
INT16_MAX = 2 ** 15 - 1


def _nelem(shape) -> int:
    return int(math.prod(int(s) for s in shape))


def payload_nbytes(payload) -> int:
    """Actual wire size of an encoded payload (what wire_bytes must match)."""
    return sum(int(leaf.nbytes) for leaf in jax.tree_util.tree_leaves(payload))


class IdentityCodec:
    """The wire as-is (accounting follows the given dtype — the transport
    passes its wire dtype, so this reproduces the plain SimWAN bytes)."""

    lossless = True
    exact = True      # decode(encode(x)) is x BITWISE -> skippable on send

    def encode(self, rng, x):
        return {"x": x}

    def decode(self, payload, like):
        return payload["x"]

    def wire_bytes(self, shape, dtype) -> int:
        return _nelem(shape) * jnp.dtype(dtype).itemsize


class StochasticQuantCodec:
    """int8 / int4 stochastic-rounding quantization, one fp32 absmax scale
    per ``tile`` consecutive values (the flattened array is zero-padded to
    whole tiles; padding decodes to exact zeros)."""

    lossless = False
    exact = False

    def __init__(self, bits: int = 8, tile: int = TILE):
        assert bits in (4, 8), bits
        assert tile % 2 == 0, tile
        self.bits = bits
        self.tile = tile
        self.levels = (1 << (bits - 1)) - 1      # 127 / 7

    def _tiles(self, n: int) -> int:
        return -(-n // self.tile)

    def _quantize(self, rng, x2d):
        """(T, tile) -> (codes int8, scales f32); fused kernel when the
        Pallas grid can tile T, bit-identical jnp reference otherwise."""
        from ..kernels.quantize import BLOCK_T
        T = x2d.shape[0]
        u = jax.random.uniform(rng, x2d.shape, jnp.float32)
        if T % min(BLOCK_T, T) == 0:
            from ..kernels import ops as kops
            return kops.quantize_stochastic(x2d, u, self.levels)
        from ..kernels.ref import quantize_sr_ref
        return quantize_sr_ref(x2d, u, self.levels)

    def encode(self, rng, x):
        n = _nelem(x.shape)
        T = self._tiles(n)
        flat = jnp.ravel(x).astype(jnp.float32)
        x2d = jnp.pad(flat, (0, T * self.tile - n)).reshape(T, self.tile)
        q, scale = self._quantize(rng, x2d)
        if self.bits == 4:
            b = (q + 8).astype(jnp.uint8)        # [-7, 7] -> [1, 15]
            q = b[:, 0::2] | (b[:, 1::2] << 4)   # two nibbles per byte
        return {"q": q, "scale": scale}

    def decode(self, payload, like):
        q, scale = payload["q"], payload["scale"]
        if self.bits == 4:
            lo = (q & 0xF).astype(jnp.int8) - 8
            hi = (q >> 4).astype(jnp.int8) - 8
            q = jnp.stack([lo, hi], axis=-1).reshape(q.shape[0], -1)
        x2d = q.astype(jnp.float32) * scale[:, None]
        n = _nelem(like.shape)
        return x2d.ravel()[:n].reshape(like.shape).astype(like.dtype)

    def wire_bytes(self, shape, dtype) -> int:
        T = self._tiles(_nelem(shape))
        code_bytes = self.tile if self.bits == 8 else self.tile // 2
        return T * code_bytes + T * 4            # codes + fp32 scales


class PlateauRatioSchedule:
    """Adaptive top-k keep-ratio: loosen sparsity as the loss plateaus.

    Early in training the gradients' energy is concentrated and an
    aggressive sketch is nearly free; near convergence the signal spreads
    out and the sparsification error (even under error feedback, a
    one-round delay) caps the reachable loss.  This host-side control
    plane watches the (smoothed) training loss between jitted rounds:
    when ``patience`` consecutive observations fail to improve the best
    seen loss by ``min_delta``, it steps the keep-ratio up the ``ratios``
    ladder.  Monotone by construction — sparsity only loosens.

    The schedule lives OUTSIDE the jit: a ratio change re-specializes the
    round function (``k`` is a static shape), which is cheap because it
    happens a handful of times per run.  Error-feedback residuals are
    dense fp32 regardless of ratio, so they carry across the change."""

    def __init__(self, ratios: Sequence[float] = (0.0625, 0.125, 0.25, 0.5),
                 patience: int = 3, min_delta: float = 1e-3):
        rs = tuple(float(r) for r in ratios)
        assert rs == tuple(sorted(rs)) and rs, "ratios must ascend"
        self.ratios = rs
        self.patience = patience
        self.min_delta = min_delta
        self.idx = 0
        self.best = float("inf")
        self.stall = 0

    @property
    def ratio(self) -> float:
        return self.ratios[self.idx]

    def update(self, loss) -> Optional[float]:
        """Observe one smoothed loss; return the NEW ratio when the
        plateau rule fires (else None).

        Non-finite observations are IGNORED (no stall tick, no ratio
        step): a depth-D pipeline reports NaN losses for its D-1 warmup
        rounds, and `NaN < best` / `NaN >= patience-threshold` both being
        False used to route NaN into the stall branch — a ratio ladder
        driven entirely by warmup artifacts before the first real loss
        arrived."""
        loss = float(loss)
        if not math.isfinite(loss):
            return None
        if loss < self.best - self.min_delta:
            self.best = loss
            self.stall = 0
            return None
        self.stall += 1
        if self.stall >= self.patience and self.idx + 1 < len(self.ratios):
            self.idx += 1
            self.stall = 0
            self.best = min(self.best, loss)
            return self.ratio
        return None


class TopKCodec:
    """Keep the k = ceil(ratio * n) largest-magnitude values; the rest
    decode to zero.  ``value_codec`` compresses the kept-value vector
    (codec chaining — e.g. top-k indices + int8 values).

    ``ratio_schedule`` (a :class:`PlateauRatioSchedule`-like object) is the
    adaptive-sparsity hook: callers feed it the training loss via
    :meth:`scheduled` between rounds and swap in the returned codec when
    the keep-ratio steps."""

    lossless = False
    exact = False

    def __init__(self, ratio: float = 0.25,
                 value_codec: Optional[object] = None,
                 ratio_schedule: Optional[PlateauRatioSchedule] = None):
        assert 0.0 < ratio <= 1.0, ratio
        self.ratio = ratio
        self.value_codec = value_codec or IdentityCodec()
        self.ratio_schedule = ratio_schedule
        if ratio_schedule is not None and ratio_schedule.ratio != ratio:
            # sync the ladder to the codec's starting ratio, else a fired
            # step could TIGHTEN the wire (monotone-loosening contract)
            if ratio not in ratio_schedule.ratios:
                raise ValueError(
                    f"codec ratio {ratio} not on the schedule ladder "
                    f"{ratio_schedule.ratios}")
            ratio_schedule.idx = ratio_schedule.ratios.index(ratio)

    def with_ratio(self, ratio: float) -> "TopKCodec":
        """Same codec (and schedule hook) at a different keep-ratio."""
        return TopKCodec(ratio, value_codec=self.value_codec,
                         ratio_schedule=self.ratio_schedule)

    def scheduled(self, loss) -> "TopKCodec":
        """Consult the ratio_schedule with one loss observation; returns
        ``self`` unchanged or a re-ratioed clone (caller rebuilds the
        round function around it — error-feedback residuals carry)."""
        if self.ratio_schedule is None:
            return self
        r = self.ratio_schedule.update(loss)
        if r is None or r == self.ratio:
            return self
        return self.with_ratio(r)

    def k_of(self, n: int) -> int:
        return max(1, int(math.ceil(n * self.ratio)))

    @staticmethod
    def _idx_dtype(n: int):
        return jnp.int16 if n - 1 <= INT16_MAX else jnp.int32

    def encode(self, rng, x):
        flat = jnp.ravel(x).astype(jnp.float32)
        n = flat.shape[0]
        k = self.k_of(n)
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        vals = flat[idx]
        vp = self.value_codec.encode(jax.random.fold_in(rng, 1), vals)
        return {"idx": idx.astype(self._idx_dtype(n)), "val": vp}

    def decode(self, payload, like):
        n = _nelem(like.shape)
        k = self.k_of(n)
        vals = self.value_codec.decode(
            payload["val"], jax.ShapeDtypeStruct((k,), jnp.float32))
        flat = jnp.zeros((n,), jnp.float32)
        flat = flat.at[payload["idx"].astype(jnp.int32)].set(vals)
        return flat.reshape(like.shape).astype(like.dtype)

    def wire_bytes(self, shape, dtype) -> int:
        n = _nelem(shape)
        k = self.k_of(n)
        idx_bytes = jnp.dtype(self._idx_dtype(n)).itemsize
        return k * idx_bytes + self.value_codec.wire_bytes((k,), jnp.float32)


class ChainCodec:
    """Residual chaining: ``encode`` runs the stages left to right, each on
    the running reconstruction error; ``decode`` sums the stages."""

    # lossless chains (one ending in identity) reconstruct only to fp32
    # rounding — the transport must still run encode/decode for them
    exact = False

    def __init__(self, stages: Sequence[object]):
        assert stages, "empty chain"
        self.stages = list(stages)

    @property
    def lossless(self) -> bool:
        # ANY lossless stage makes the chain exact: that stage's payload
        # carries the entire remaining residual.
        return any(s.lossless for s in self.stages)

    def encode(self, rng, x):
        e = x.astype(jnp.float32)
        payloads = []
        for i, c in enumerate(self.stages):
            p = c.encode(jax.random.fold_in(rng, i), e)
            e = e - c.decode(p, e)
            payloads.append(p)
        return {"stages": payloads}

    def decode(self, payload, like):
        f32 = jax.ShapeDtypeStruct(like.shape, jnp.float32)
        out = jnp.zeros(like.shape, jnp.float32)
        for c, p in zip(self.stages, payload["stages"]):
            out = out + c.decode(p, f32)
        return out.astype(like.dtype)

    def wire_bytes(self, shape, dtype) -> int:
        return sum(c.wire_bytes(shape, dtype) for c in self.stages)


# --------------------------------------------------------------------------
# Named specs (the `--compression` axis / CELUConfig.compression values)
# --------------------------------------------------------------------------
def make_codec(name: str):
    """One codec by name: identity | int8 | int4 | int4x2 | topk |
    topk_int8 | topk_int4."""
    if name == "identity":
        return IdentityCodec()
    if name == "int8":
        return StochasticQuantCodec(8)
    if name == "int4":
        return StochasticQuantCodec(4)
    if name == "int4x2":
        return ChainCodec([StochasticQuantCodec(4), StochasticQuantCodec(4)])
    if name == "topk":
        return TopKCodec(0.25)
    if name == "topk_int8":
        return TopKCodec(0.25, value_codec=StochasticQuantCodec(8))
    if name == "topk_int4":
        return TopKCodec(0.25, value_codec=StochasticQuantCodec(4))
    raise ValueError(f"unknown codec {name!r}")


# Asymmetric up/down presets: sparse sketches uplink (Z_i), dense low-bit
# downlink (∇Z_i — top-k on derivatives interacts badly with Algorithm-2's
# cosine staleness measure, so the downlink stays dense).
_PAIRS = {
    "int8_topk": ("topk_int8", "int8"),
    "int4_topk": ("topk_int4", "int4"),
}

CODEC_SPECS = ("identity", "int8", "int4", "int4x2", "topk", "topk_int8",
               "topk_int4") + tuple(_PAIRS)


def make_codec_pair(spec: str) -> Tuple[object, object]:
    """Codec spec -> (uplink codec, downlink codec).

    ``"up/down"`` picks each direction explicitly (e.g. ``"topk/int8"``);
    a name from ``_PAIRS`` is a curated asymmetric preset; any single
    codec name is used for both directions."""
    if "/" in spec:
        up, down = spec.split("/", 1)
        return make_codec(up), make_codec(down)
    if spec in _PAIRS:
        up, down = _PAIRS[spec]
        return make_codec(up), make_codec(down)
    return make_codec(spec), make_codec(spec)
