"""Per-architecture smoke tests: REDUCED variant of each assigned family,
one forward/train step + one serve step on CPU, asserting shapes + no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ShapeConfig
from repro.launch.steps import concrete_batch, make_train_step
from repro.models import vfl
from repro.optim import adagrad

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


def _params_and_batch(arch_id):
    cfg = get_config(arch_id).reduced()
    params = vfl.init_all(jax.random.PRNGKey(0), cfg)
    batch = concrete_batch(cfg, SMOKE_SHAPE, seed=1)
    return cfg, params, batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_no_nans(arch_id):
    cfg, params, batch = _params_and_batch(arch_id)
    z_a = vfl.forward_a(params["a"], cfg, batch)
    logits, aux = vfl.forward_b(params["b"], cfg, z_a, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert jnp.isfinite(logits.astype(jnp.float32)).all()
    assert jnp.isfinite(jnp.float32(aux))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step(arch_id):
    cfg, params, batch = _params_and_batch(arch_id)
    opt = adagrad(0.01)
    step = jax.jit(make_train_step(cfg, opt))
    opt_state = opt.init(params)
    params2, opt_state, loss = step(params, opt_state, batch)
    assert jnp.isfinite(loss), loss
    # params actually moved
    diff = jax.tree_util.tree_reduce(
        lambda acc, ab: acc + float(jnp.sum(jnp.abs(
            ab[0].astype(jnp.float32) - ab[1].astype(jnp.float32)))),
        jax.tree_util.tree_map(lambda a, b: (a, b), params, params2),
        0.0, is_leaf=lambda x: isinstance(x, tuple))
    assert diff > 0.0
    # loss positive (cross-entropy) and not exploding
    assert 0.0 < float(loss) < 50.0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_prefill_decode(arch_id):
    cfg, params, batch = _params_and_batch(arch_id)
    B, S = batch["tokens"].shape
    logits, caches = jax.jit(
        lambda p, b: vfl.prefill(p, cfg, b))(params, batch)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert jnp.isfinite(logits).all()

    step_batch = {"token": jnp.argmax(logits[:, -1], -1)[:, None]}
    if cfg.family not in ("vlm", "audio"):
        step_batch["token_a"] = jnp.zeros((B, 1), jnp.int32)
    logits2, caches = jax.jit(
        lambda p, c, sb, pos: vfl.decode_step(p, cfg, c, sb, pos)
    )(params, caches, step_batch, jnp.int32(S))
    assert logits2.shape == (B, 1, cfg.padded_vocab)
    assert jnp.isfinite(logits2).all()


def test_decode_matches_prefill_continuation():
    """Decode with cache == rerunning prefill one token longer (dense)."""
    cfg = get_config("smollm-360m").reduced()
    params = vfl.init_all(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1), np.int32))
    toks_a = jnp.asarray(rng.integers(0, 512, (B, S + 1), np.int32))

    batch_s = {"tokens": toks[:, :S], "tokens_a": toks_a[:, :S]}
    logits_s, caches = vfl.prefill(params, cfg, batch_s, total_len=S + 1)
    step = {"token": toks[:, S:S + 1], "token_a": toks_a[:, S:S + 1]}
    logits_d, _ = vfl.decode_step(params, cfg, caches, step, jnp.int32(S))

    batch_full = {"tokens": toks, "tokens_a": toks_a}
    logits_f, _ = vfl.prefill(params, cfg, batch_full)
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(logits_f[:, -1]),
                               rtol=2e-2, atol=2e-2)
