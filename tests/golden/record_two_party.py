"""Regenerate ``two_party_trace.json`` from the engine's K=1 path.

The trace was originally recorded from the pre-engine seed implementation;
the unified engine reproduces it bit-for-bit, so this recorder (which runs
the engine directly) emits the byte-identical file.  CI's golden-drift
check runs it and ``git diff --exit-code tests/golden/`` — a silent
numeric change to the K=1 round loop shows up as a dirty tree.  Re-record
ONLY when an intentional numeric change invalidates the golden, and say so
in the commit message.

    PYTHONPATH=src python tests/golden/record_two_party.py
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from test_engine import _run_trace  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "two_party_trace.json")


def main():
    trace = {proto: _run_trace(proto, via_shim=False, rounds=20)
             for proto in ("vanilla", "fedbcd", "celu")}
    with open(OUT, "w") as f:
        json.dump(trace, f, indent=1)
    print(f"wrote {OUT}: {len(trace)} protocols x {len(trace['celu']) - 1} "
          f"rounds")
    print("celu tail:", trace["celu"][-1])


if __name__ == "__main__":
    main()
