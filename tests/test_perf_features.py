"""Tests for the §Perf features: microbatched training and bf16 wire."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import CELUConfig, ShapeConfig
from repro.core import protocol as P
from repro.data.synthetic import TabularSpec, aligned_batches, make_tabular
from repro.launch.steps import concrete_batch, make_train_step
from repro.models import vfl
from repro.models.tabular import DLRMConfig, make_dlrm
from repro.optim import adagrad, make_optimizer

SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=4, kind="train")


def test_microbatch_matches_full_batch_loss():
    cfg = get_config("smollm-360m").reduced()
    params = vfl.init_all(jax.random.PRNGKey(0), cfg)
    batch = concrete_batch(cfg, SHAPE, seed=0)
    opt = adagrad(0.01)
    s1 = opt.init(params)
    step1 = jax.jit(make_train_step(cfg, opt, microbatches=1))
    step2 = jax.jit(make_train_step(cfg, opt, microbatches=2))
    p1, _, loss1 = step1(params, s1, batch)
    p2, _, loss2 = step2(params, opt.init(params), batch)
    # mean-of-microbatch losses == full-batch loss (both mean-reduced)
    np.testing.assert_allclose(float(loss1), float(loss2), rtol=2e-2)
    # resulting params close (bf16 params, fp32 accumulators)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0.1, atol=0.05)


def test_bf16_wire_protocol_converges():
    spec = TabularSpec("t", fields_a=4, fields_b=3, vocab=64,
                       n_train=4096, n_test=512)
    data = make_tabular(spec, seed=0)
    cfg = DLRMConfig("wdl", 4, 3, vocab=64, embed_dim=4, z_dim=8,
                     hidden=(16, 8))
    init_fn, task, predict = make_dlrm(cfg)
    finals = {}
    for wire in ("float32", "bfloat16"):
        celu = CELUConfig(R=2, W=2, wire_dtype=wire)
        params = init_fn(jax.random.PRNGKey(0), cfg)
        opt = make_optimizer("adagrad", 0.02)
        it = aligned_batches(data["train"], 64, seed=0)
        _, ba, bb = next(it)
        asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
        state = P.init_state(task, params, opt, celu, asj(ba), asj(bb))
        rnd = P.make_round(task, opt, celu)
        it = aligned_batches(data["train"], 64, seed=0)
        losses = []
        for i in range(25):
            bi, ba, bb = next(it)
            state, m = rnd(state, asj(ba), asj(bb), bi)
            losses.append(float(m["loss"]))
        finals[wire] = np.mean(losses[-5:])
        assert losses[-1] < losses[0], (wire, losses[:3], losses[-3:])
    # parity within 5%
    assert abs(finals["bfloat16"] - finals["float32"]) \
        / finals["float32"] < 0.05, finals


def test_exchange_bytes_wire():
    assert P.exchange_bytes((256, 32), wire_dtype="bfloat16") \
        == P.exchange_bytes((256, 32)) // 2


def test_chunked_mlstm_matches_sequential():
    """The chunkwise-parallel mLSTM is mathematically exact (§Perf)."""
    import jax
    from repro.models import xlstm as X
    rng = jax.random.PRNGKey(3)
    p = X.mlstm_init(rng, 64, 4)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 128, 64),
                          jnp.float32)
    y_seq, st_seq = X.mlstm_apply(p, x)
    y_par, st_par = X.mlstm_apply_chunked(p, x, chunk=32)
    np.testing.assert_allclose(np.asarray(y_seq, np.float32),
                               np.asarray(y_par, np.float32),
                               rtol=1e-4, atol=1e-5)
    for k in ("C", "n", "m"):
        np.testing.assert_allclose(np.asarray(st_seq[k]),
                                   np.asarray(st_par[k]),
                                   rtol=1e-4, atol=1e-5)


def test_chunked_mlstm_grads_finite():
    import jax
    from repro.models import xlstm as X
    rng = jax.random.PRNGKey(4)
    p = X.mlstm_init(rng, 32, 2)
    x = jax.random.normal(rng, (1, 64, 32), jnp.float32)
    g = jax.grad(lambda p_: jnp.sum(
        X.mlstm_apply_chunked(p_, x, chunk=32)[0].astype(jnp.float32)))(p)
    for leaf in jax.tree_util.tree_leaves(g):
        assert jnp.isfinite(leaf.astype(jnp.float32)).all()
