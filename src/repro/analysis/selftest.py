"""Seeded-mutation self-tests: the auditor must CATCH each planted bug,
naming the offender — an analyzer that cannot fail is not a gate.

Five mutations, one per invariant family plus the DP-ordering rule and
the batched fleet path:

  * **raw-send** — a transport whose ``send`` returns the raw tensor
    unencoded: the taint pass must flag the boundary crossing.
  * **under-count** — a codec whose ``wire_bytes`` reports half the
    payload its ``encode`` emits: the byte reconciliation must flag the
    codec and direction.
  * **bad-blockspec** — the fused kernels' ``BLOCK_B`` is patched so the
    audited batch geometry no longer tiles: the kernel lint must flag
    the silently-disabled fused path.
  * **noise-before-encode** — the pre-fix ``CompressedWANTransport``
    behavior (DP noise applied BEFORE the lossy encode, so error
    feedback re-transmits and cancels the mechanism): the sanitizer
    ordering check must flag it.
  * **fleet-raw-send** — the raw-send transport driven through the
    VMAPPED fleet step (``trace_fleet_case``): the taint pass must flag
    the same crossing with the leading job axis on the boundary aval —
    a batched trace that hides planted bugs would make the fleet audit
    case vacuous.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
from typing import List

from .audit import AuditCase, _make_celu, trace_case, trace_fleet_case


@dataclass
class MutationResult:
    name: str
    expected_code: str
    caught: bool
    errors: List[str] = field(default_factory=list)


def _mut_raw_send() -> MutationResult:
    from ..core import compression as C
    from ..core import engine as E

    class RawLeakTransport(E.CompressedWANTransport):
        """Planted bug: releases the raw cut tensor, codec ignored."""

        def send(self, rng, x, res=None, direction: str = "up"):
            return x, res

    case = AuditCase(name="mut-raw-send", compression="int8")
    up, down = C.make_codec_pair("int8")
    r = trace_case(case, transport=RawLeakTransport(_make_celu(case),
                                                    up, down))
    return _grade("raw-send", "taint.raw-boundary", "RawLeakTransport", r)


def _mut_under_count() -> MutationResult:
    from ..core import compression as C
    from ..core import engine as E

    class UnderCountCodec:
        """Planted bug: reports half the bytes its payload occupies."""

        lossless = False
        exact = False

        def __init__(self, inner):
            self._inner = inner

        def encode(self, rng, x):
            return self._inner.encode(rng, x)

        def decode(self, payload, like):
            return self._inner.decode(payload, like)

        def wire_bytes(self, shape, dtype) -> int:
            return self._inner.wire_bytes(shape, dtype) // 2

    case = AuditCase(name="mut-under-count", compression="int8")
    tp = E.CompressedWANTransport(_make_celu(case),
                                  UnderCountCodec(C.make_codec("int8")),
                                  UnderCountCodec(C.make_codec("int8")))
    r = trace_case(case, transport=tp)
    return _grade("under-count", "wire.bytes-mismatch", "UnderCountCodec",
                  r)


@contextlib.contextmanager
def _patched_block(val: int):
    from ..kernels import cosine_weight as cw
    from ..kernels import fused_sample as fs
    o1, o2 = cw.BLOCK_B, fs.BLOCK_B
    cw.BLOCK_B = fs.BLOCK_B = val
    try:
        yield
    finally:
        cw.BLOCK_B, fs.BLOCK_B = o1, o2


def _mut_bad_blockspec() -> MutationResult:
    # B=64 stops tiling once BLOCK_B=48: min(48, 64)=48 and 64 % 48 != 0
    with _patched_block(48):
        r = trace_case(AuditCase(name="mut-bad-blockspec"))
    return _grade("bad-blockspec", "kernel.fused-path-disabled",
                  "cosine_weight", r)


def _mut_noise_before_encode() -> MutationResult:
    import jax
    import jax.numpy as jnp

    from ..core import compression as C
    from ..core import engine as E

    class StatelessClaim:
        """Lossy codec that opts out of error feedback (no residual
        state) — isolates the ORDERING violation below.  With residuals
        the same bug surfaces as ``taint.raw-boundary`` instead: the
        un-noised residual joins the release and dilutes the DP stage
        out of the taint's sanitizer set."""

        lossless = True      # -> no residual slots in the round state
        exact = False

        def __init__(self, inner):
            self._inner = inner

        def encode(self, rng, x):
            return self._inner.encode(rng, x)

        def decode(self, payload, like):
            return self._inner.decode(payload, like)

        def wire_bytes(self, shape, dtype) -> int:
            return self._inner.wire_bytes(shape, dtype)

    class NoiseFirstTransport(E.CompressedWANTransport):
        """Planted bug: the pre-fix DP path — noise rides the value INTO
        the lossy encode instead of the decoded wire value."""

        def send(self, rng, x, res=None, direction: str = "up"):
            codec = self.codecs[direction]
            x, _ = E.SimWANTransport.send(self, rng, x, None, direction)
            e = x.astype(jnp.float32)
            if res is not None:
                e = e + res
            payload = codec.encode(jax.random.fold_in(rng, 1), e)
            y = codec.decode(payload, e)
            return y.astype(x.dtype), None if res is None else e - y

    case = AuditCase(name="mut-noise-before-encode", compression="int8",
                     dp_sigma=0.3)
    tp = NoiseFirstTransport(_make_celu(case),
                             StatelessClaim(C.make_codec("int8")),
                             StatelessClaim(C.make_codec("int8")))
    r = trace_case(case, transport=tp)
    return _grade("noise-before-encode", "taint.sanitizer-order",
                  "NoiseFirstTransport", r)


def _mut_fleet_raw_send() -> MutationResult:
    from ..core import compression as C
    from ..core import engine as E

    class RawLeakTransport(E.CompressedWANTransport):
        """Planted bug: releases the raw cut tensor, codec ignored —
        driven through the vmapped fleet step this time."""

        def send(self, rng, x, res=None, direction: str = "up"):
            return x, res

    case = AuditCase(name="mut-fleet-raw-send", depth=2,
                     compression="int8")
    up, down = C.make_codec_pair("int8")
    r = trace_fleet_case(case, transport=RawLeakTransport(
        _make_celu(case), up, down))
    return _grade("fleet-raw-send", "taint.raw-boundary",
                  "RawLeakTransport", r)


def _grade(name: str, expected_code: str, offender: str,
           result) -> MutationResult:
    hits = [f for f in result.findings
            if f.code == expected_code and offender in f.where]
    return MutationResult(
        name=name, expected_code=expected_code, caught=bool(hits),
        errors=[f"{f.code} @ {f.where}" for f in result.errors])


def run_selftest():
    """-> (all caught?, per-mutation results)."""
    results = [_mut_raw_send(), _mut_under_count(), _mut_bad_blockspec(),
               _mut_noise_before_encode(), _mut_fleet_raw_send()]
    return all(m.caught for m in results), results


def render(results: List[MutationResult]) -> str:
    lines = ["seeded-mutation self-test:"]
    for m in results:
        status = "caught" if m.caught else "MISSED"
        lines.append(f"  [{status:6s}] {m.name} -> {m.expected_code}")
        if not m.caught:
            lines.append(f"           analyzer errors were: "
                         f"{m.errors or ['<none>']}")
    ok = all(m.caught for m in results)
    lines.append("SELFTEST PASSED" if ok else
                 "SELFTEST FAILED: the analyzer missed a planted bug")
    return "\n".join(lines)
