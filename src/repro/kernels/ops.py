"""Public jit'd wrappers for the Pallas kernels.

On this CPU container every kernel runs with ``interpret=True`` (the kernel
body executed in Python by the Pallas interpreter — bit-accurate for
correctness, not for speed).  On a real TPU set
``repro.kernels.ops.INTERPRET = False`` (or the REPRO_PALLAS_COMPILE env
var) to compile to Mosaic.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from . import cosine_weight as _cw
from . import flash_attention as _fa
from . import fused_adagrad as _ag
from . import quantize as _qz

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "") == ""


def cosine_weight(ad_hoc, stale, cos_xi):
    """Algorithm-2 InsWeight: -> (B,) float32 weights (weights-only kernel:
    no cotangent operand/result moves through VMEM)."""
    B = ad_hoc.shape[0]
    return _cw.cosine_weights_2d(ad_hoc.reshape(B, -1),
                                 stale.reshape(B, -1),
                                 jnp.float32(cos_xi), interpret=INTERPRET)


def weighted_cotangent(ad_hoc, stale, dz, cos_xi):
    """Fused InsWeight + weights ⊙ ∇Z.  -> (weights (B,), weighted dz)."""
    B = ad_hoc.shape[0]
    shape = dz.shape
    w, out = _cw.cosine_weight_2d(ad_hoc.reshape(B, -1),
                                  stale.reshape(B, -1), dz.reshape(B, -1),
                                  jnp.float32(cos_xi), interpret=INTERPRET)
    return w, out.reshape(shape)


def quantize_stochastic(x, u, levels):
    """Fused per-tile absmax-scale stochastic-rounding quantizer.

    x: (T, L) value tiles, u: (T, L) uniforms in [0, 1), levels: max code
    magnitude (127 = int8, 7 = int4).  -> (codes int8 (T, L), fp32 scales
    (T,)); bit-exact with ``kernels.ref.quantize_sr_ref``."""
    return _qz.quantize_sr_2d(x, u, levels, interpret=INTERPRET)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0):
    """(B, S, H, hd) x3 -> (B, S, H, hd); kv pre-repeated to H heads."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               interpret=INTERPRET)


def fused_adagrad(grad, accum, lr, eps):
    """-> (update fp32, new_accum fp32)."""
    return _ag.fused_adagrad(grad, accum, lr, eps, interpret=INTERPRET)


def flash_attention_trainable(q, k, v, *, causal: bool = True,
                              window: int = 0):
    """Differentiable flash attention (custom VJP: FlashAttention-2
    backward kernels — dq / dkv recompute score tiles, never materialize
    the softmax)."""
    from .flash_attention_bwd import flash_attention_vjp
    return flash_attention_vjp(q, k, v, causal, window, INTERPRET)
