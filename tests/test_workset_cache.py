"""Quantized workset cache + fused gather→dequant→weight sample path.

Covers: the storage codec (int8 / int4 / bf16 at rest, fp32
bit-exactness), nibble pack/unpack roundtrips at odd row widths,
kernel-vs-oracle parity for the fused sample megakernel (fp32, int8, and
nibble-packed int4 rings; multi-tile grids, the unfusable-batch
fallback, the all-dead-slot edge), Algorithm-2 weight tolerance of the
lossy caches vs the fp32 cache (SR unbiasedness through the cosine),
the ``workset_stats`` pipeline-staleness regression, and the
``workset_pspecs`` sharding rule over quantized rings.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CELUConfig
from repro.core import engine
from repro.core.workset import (QUANT_KEYS, CastLeaf, Quant4Leaf,
                                QuantLeaf, decode_entry, pack_nibbles,
                                sample_hbm_bytes, unpack_nibbles,
                                workset_draw, workset_entry, workset_init,
                                workset_insert, workset_nbytes,
                                workset_sample, workset_stats)
from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def _arr(shape, dtype="float32"):
    return jnp.asarray(RNG.normal(size=shape), jnp.dtype(dtype))


def _entry(B=64, F=8, v=None):
    z = _arr((B, F)) if v is None else jnp.full((B, F), float(v))
    dz = _arr((B, F)) if v is None else jnp.full((B, F), -float(v))
    return {"z": z, "dz": dz, "batch": {"x": jnp.zeros((B, 2), jnp.int32)}}


# --------------------------------------------------------------------------
# Storage codec
# --------------------------------------------------------------------------
def test_fp32_cache_layout_is_the_historical_table():
    """cache_dtype="float32" stores plain arrays — bit-identical layout
    (the golden traces in test_engine.py pin the numerics)."""
    e = _entry()
    ws = workset_init(3, e)
    assert isinstance(ws["buf"]["z"], jnp.ndarray)
    ws = workset_insert(ws, e, 0)
    _, got, _, valid = workset_sample(ws, 2, "consecutive")
    assert bool(valid)
    np.testing.assert_array_equal(np.asarray(got["z"]), np.asarray(e["z"]))
    np.testing.assert_array_equal(np.asarray(got["dz"]), np.asarray(e["dz"]))


@pytest.mark.parametrize("cache_dtype,leaf_cls,max_rel",
                         [("bfloat16", CastLeaf, 1 / 128),
                          ("int8", QuantLeaf, 1 / 64),
                          ("int4", Quant4Leaf, 1 / 6)])
def test_lossy_cache_roundtrip(cache_dtype, leaf_cls, max_rel):
    """Insert + sample through a lossy cache reconstructs the statistics
    to storage precision (int8: one LSB of the per-row absmax scale)."""
    e = _entry(B=64, F=32)
    ws = workset_init(2, e, cache_dtype=cache_dtype)
    assert isinstance(ws["buf"]["z"], leaf_cls)
    assert isinstance(ws["buf"]["batch"]["x"], jnp.ndarray)  # verbatim
    ws = workset_insert(ws, e, 0, rng=jax.random.PRNGKey(0))
    _, got, _, _ = workset_sample(ws, 2, "consecutive")
    assert got["z"].shape == e["z"].shape
    for k in QUANT_KEYS:
        err = np.abs(np.asarray(got[k]) - np.asarray(e[k]))
        amax = np.abs(np.asarray(e[k])).max(axis=1, keepdims=True)
        assert (err <= amax * max_rel + 1e-6).all()


def test_int8_cache_sr_unbiased():
    """E[decode] == value: the stochastic rounding noise averages out
    across insert keys (the property Algorithm-2's tolerance rides on)."""
    e = _entry(B=16, F=8)
    acc = np.zeros((16, 8), np.float64)
    n = 300
    for s in range(n):
        ws = workset_init(1, e, cache_dtype="int8")
        ws = workset_insert(ws, e, 0, rng=jax.random.PRNGKey(s))
        _, got, _, _ = workset_sample(ws, 2, "consecutive")
        acc += np.asarray(got["z"], np.float64)
    scale = np.abs(np.asarray(e["z"])).max(axis=1, keepdims=True) / 127
    bias = np.abs(acc / n - np.asarray(e["z"]))
    # SR residual is U(0,1)-driven: sem ~ scale/sqrt(12 n); 6 sigma margin
    assert (bias <= 6 * scale / np.sqrt(12 * n) + 1e-7).all()


def test_cache_footprint_ratio():
    """The int8 table holds the cut statistics in ~F/(F+4)x4 fewer bytes
    (codes + one fp32 scale per row); int4 nibble-packs two codes per
    byte on top of that."""
    e = _entry(B=256, F=32)
    fp32 = workset_nbytes(workset_init(5, e), QUANT_KEYS)
    int8 = workset_nbytes(workset_init(5, e, cache_dtype="int8"),
                          QUANT_KEYS)
    bf16 = workset_nbytes(workset_init(5, e, cache_dtype="bfloat16"),
                          QUANT_KEYS)
    int4 = workset_nbytes(workset_init(5, e, cache_dtype="int4"),
                          QUANT_KEYS)
    assert fp32 == 2 * 5 * 256 * 32 * 4
    assert int8 == 2 * 5 * 256 * (32 + 4)
    assert bf16 == fp32 // 2
    assert int4 == 2 * 5 * 256 * (32 // 2 + 4)
    assert fp32 / int8 > 3.0
    assert fp32 / int4 > 6.0


def test_int4_pack_roundtrip_odd_widths():
    """pack→unpack is the identity on codes in [-7, 7], with odd widths
    padded by one zero code (the pad nibble decodes to an exact 0)."""
    for B, F in ((4, 8), (3, 7), (5, 33), (2, 1)):
        q = jnp.asarray(RNG.integers(-7, 8, size=(B, F)), jnp.int8)
        qp = jnp.pad(q, ((0, 0), (0, F & 1))) if F & 1 else q
        packed = pack_nibbles(qp)
        assert packed.dtype == jnp.uint8
        assert packed.shape == (B, (F + (F & 1)) // 2)
        back = unpack_nibbles(packed)
        np.testing.assert_array_equal(np.asarray(back[:, :F]), np.asarray(q))
        if F & 1:    # the pad nibble must decode to 0, not garbage
            np.testing.assert_array_equal(np.asarray(back[:, F]),
                                          np.zeros(B, np.int8))


def test_unknown_cache_dtype_rejected():
    with pytest.raises(ValueError, match="cache_dtype"):
        workset_init(2, _entry(), cache_dtype="fp16")


def test_quantized_table_survives_scan_carry():
    """QuantLeaf is a registered pytree node: the table rides a lax.scan
    carry (the engine's local-update loop) untouched."""
    e = _entry(B=8, F=4)
    ws = workset_init(2, e, cache_dtype="int8")
    ws = workset_insert(ws, e, 0)

    def body(carry, _):
        ws = carry
        ws, slot, _, valid = workset_draw(ws, 4, "round_robin")
        return ws, valid

    ws2, valids = jax.lax.scan(body, ws, None, length=3)
    assert isinstance(ws2["buf"]["z"], QuantLeaf)
    assert int(valids.sum()) >= 1


# --------------------------------------------------------------------------
# Fused sample kernel vs oracle
# --------------------------------------------------------------------------
@pytest.mark.parametrize("W,B,F", [(3, 64, 8), (5, 128, 32), (4, 256, 16),
                                   (2, 384, 96)])   # 384 = 3 grid tiles
@pytest.mark.parametrize("cos_xi", [0.0, 0.5])
def test_fused_sample_f32_matches_oracle(W, B, F, cos_xi):
    a = _arr((B, F))
    z_ring, dz_ring = _arr((W, B, F)), _arr((W, B, F))
    for slot in (0, W - 1):
        w, cot = ops.fused_gather_weight(jnp.int32(slot), a, z_ring,
                                         dz_ring, cos_xi)
        w_r, cot_r = ref.fused_sample_ref(slot, a, z_ring, dz_ring, cos_xi)
        tol = dict(rtol=3e-7, atol=3e-7)
        np.testing.assert_allclose(np.asarray(w), np.asarray(w_r), **tol)
        np.testing.assert_allclose(np.asarray(cot), np.asarray(cot_r),
                                   **tol)


@pytest.mark.parametrize("W,B,F", [(3, 64, 8), (4, 256, 16), (2, 384, 96),
                                   (3, 64, 9), (4, 128, 33)])  # odd F
def test_fused_sample_q4_matches_oracle(W, B, F):
    """int4 nibble-packed ring kernel vs the unpack→dequant→cosine oracle
    (multi-tile grids at B=384, odd row widths through the pad nibble)."""
    P = (F + 1) // 2
    a = _arr((B, F))
    zq = jnp.asarray(RNG.integers(0, 256, size=(W, B, P)), jnp.uint8)
    dzq = jnp.asarray(RNG.integers(0, 256, size=(W, B, P)), jnp.uint8)
    if F & 1:   # storage codec invariant: pad nibble holds code 0 (+8)
        zq = (zq & 0x0F) | jnp.uint8(0x80)
        dzq = (dzq & 0x0F) | jnp.uint8(0x80)
    zs = jnp.abs(_arr((W, B))) + 0.01
    dzs = jnp.abs(_arr((W, B))) + 0.01
    for slot in (0, W - 1):
        w, cot = ops.fused_gather_weight_q4(jnp.int32(slot), a, zq, zs,
                                            dzq, dzs, 0.3)
        w_r, cot_r = ref.fused_sample_q4_ref(slot, a, zq, zs, dzq, dzs, 0.3)
        assert cot.shape == a.shape
        np.testing.assert_allclose(np.asarray(w), np.asarray(w_r),
                                   rtol=3e-7, atol=3e-7)
        np.testing.assert_allclose(np.asarray(cot), np.asarray(cot_r),
                                   rtol=3e-6, atol=3e-6)


@pytest.mark.parametrize("W,B,F", [(3, 64, 8), (4, 256, 16), (2, 384, 96)])
def test_fused_sample_q8_matches_oracle(W, B, F):
    a = _arr((B, F))
    zq = jnp.asarray(RNG.integers(-127, 128, size=(W, B, F)), jnp.int8)
    dzq = jnp.asarray(RNG.integers(-127, 128, size=(W, B, F)), jnp.int8)
    zs = jnp.abs(_arr((W, B))) + 0.01
    dzs = jnp.abs(_arr((W, B))) + 0.01
    for slot in (0, W - 1):
        w, cot = ops.fused_gather_weight_q8(jnp.int32(slot), a, zq, zs,
                                            dzq, dzs, 0.3)
        w_r, cot_r = ref.fused_sample_q8_ref(slot, a, zq, zs, dzq, dzs, 0.3)
        np.testing.assert_allclose(np.asarray(w), np.asarray(w_r),
                                   rtol=3e-7, atol=3e-7)
        np.testing.assert_allclose(np.asarray(cot), np.asarray(cot_r),
                                   rtol=3e-6, atol=3e-6)


def test_fused_sample_rank3_statistics():
    """Ranks > 2 flatten per instance exactly like the weighting path."""
    W, B, S, d = 3, 128, 4, 8
    a = _arr((B, S, d))
    z_ring, dz_ring = _arr((W, B, S, d)), _arr((W, B, S, d))
    w, cot = ops.fused_gather_weight(jnp.int32(1), a, z_ring, dz_ring, 0.2)
    assert cot.shape == (B, S, d)
    w_r, cot_r = ref.fused_sample_ref(1, a, z_ring, dz_ring, 0.2)
    np.testing.assert_allclose(np.asarray(cot), np.asarray(cot_r),
                               rtol=3e-7, atol=3e-7)


def test_fused_sample_all_dead_slot_yields_zero():
    """An invalid draw lands on a never-written ring slot (all zeros):
    the kernel's cosine denominator floors at EPS and every weight — and
    the cotangent — is exactly zero, so the masked no-op update costs
    nothing numerically."""
    W, B, F = 3, 64, 8
    a = _arr((B, F))
    zeros = jnp.zeros((W, B, F), jnp.float32)
    w, cot = ops.fused_gather_weight(jnp.int32(2), a, zeros, zeros, 0.5)
    assert (np.asarray(w) == 0.0).all() and (np.asarray(cot) == 0.0).all()
    # int8 ring: zero codes AND zero scales (the empty-table state)
    w, cot = ops.fused_gather_weight_q8(
        jnp.int32(0), a, jnp.zeros((W, B, F), jnp.int8),
        jnp.zeros((W, B), jnp.float32), jnp.zeros((W, B, F), jnp.int8),
        jnp.zeros((W, B), jnp.float32), 0.5)
    assert (np.asarray(w) == 0.0).all() and (np.asarray(cot) == 0.0).all()
    # int4 ring: the empty table is 0x88 bytes (code 0 in both nibbles)
    # with zero scales — decodes to exact zeros
    empty = jnp.full((W, B, F // 2), 0x88, jnp.uint8)
    w, cot = ops.fused_gather_weight_q4(
        jnp.int32(1), a, empty, jnp.zeros((W, B), jnp.float32),
        empty, jnp.zeros((W, B), jnp.float32), 0.5)
    assert (np.asarray(w) == 0.0).all() and (np.asarray(cot) == 0.0).all()


def test_local_grad_a_cached_fused_matches_reference():
    """The engine dispatcher: fused ring sample == materialize-then-weight
    on the same table, for fp32 (bitwise) and int8 (bitwise: the decode is
    the same math) caches — including the odd-batch fallback."""
    def forward(p, batch):
        return batch["x"] @ p

    for cache_dtype in ("float32", "int8", "int4"):
        for B, F in ((64, 8), (37, 8)):        # 37: unfusable, falls back
            p = _arr((4, F))
            e = {"z": _arr((B, F)), "dz": _arr((B, F)),
                 "batch": {"x": _arr((B, 4))}}
            ws = workset_init(3, e, cache_dtype=cache_dtype)
            ws = workset_insert(ws, e, 0, rng=jax.random.PRNGKey(1))
            ws, slot, _, valid = workset_draw(ws, 3, "consecutive")
            kw = dict(weighting=True, fused=True, mask=None,
                      pipeline_staleness=0)
            g_f, w_f = engine.local_grad_a_cached(forward, p, ws, slot, 0.3,
                                                  cache_fused=True, **kw)
            g_r, w_r = engine.local_grad_a_cached(forward, p, ws, slot, 0.3,
                                                  cache_fused=False, **kw)
            np.testing.assert_allclose(np.asarray(w_f), np.asarray(w_r),
                                       rtol=3e-7, atol=3e-7)
            np.testing.assert_allclose(np.asarray(g_f), np.asarray(g_r),
                                       rtol=3e-6, atol=3e-6)


def test_local_grad_a_cached_pipeline_staleness_post_scale():
    """The megakernel composes the depth-s pipeline discount exactly like
    weighted_cotangent: w -> w^(1+s), cotangent scaled once."""
    def forward(p, batch):
        return batch["x"] @ p

    B, F = 64, 8
    p = _arr((4, F))
    e = {"z": _arr((B, F)), "dz": _arr((B, F)), "batch": {"x": _arr((B, 4))}}
    ws = workset_init(2, e)
    ws = workset_insert(ws, e, 0)
    ws, slot, _, _ = workset_draw(ws, 3, "consecutive")
    kw = dict(weighting=True, fused=True, mask=None, pipeline_staleness=1)
    g_f, w_f = engine.local_grad_a_cached(forward, p, ws, slot, 0.3,
                                          cache_fused=True, **kw)
    g_r, w_r = engine.local_grad_a_cached(forward, p, ws, slot, 0.3,
                                          cache_fused=False, **kw)
    np.testing.assert_allclose(np.asarray(w_f), np.asarray(w_r),
                               rtol=3e-7, atol=3e-7)
    np.testing.assert_allclose(np.asarray(g_f), np.asarray(g_r),
                               rtol=3e-6, atol=3e-6)


# --------------------------------------------------------------------------
# Algorithm-2 weights: int8 cache vs fp32 cache tolerance
# --------------------------------------------------------------------------
def _weights_through_cache(z_stale, dz_stale, z_adhoc, cache_dtype, seed):
    e = {"z": z_stale, "dz": dz_stale, "batch": {}}
    ws = workset_init(1, e, cache_dtype=cache_dtype)
    ws = workset_insert(ws, e, 0, rng=jax.random.PRNGKey(seed))
    _, got, _, _ = workset_sample(ws, 4, "consecutive")
    from repro.core.weighting import row_cosine
    return np.asarray(row_cosine(z_adhoc, got["z"]))


@pytest.mark.parametrize("B,F,seed", [(8, 16, 0), (32, 64, 1), (64, 128, 2),
                                      (17, 33, 3)])
def test_int8_cache_weights_within_tolerance_fixed(B, F, seed):
    """Deterministic slice of the hypothesis sweep below (runs even where
    hypothesis is absent)."""
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    a = z + 0.3 * jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    dz = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    c32 = _weights_through_cache(z, dz, a, "float32", seed)
    c8 = _weights_through_cache(z, dz, a, "int8", seed)
    assert np.abs(c8 - c32).max() <= 0.06


@pytest.mark.parametrize("B,F,seed", [(8, 16, 0), (32, 64, 1), (64, 128, 2),
                                      (17, 33, 3)])
def test_int4_cache_weights_within_tolerance_fixed(B, F, seed):
    """int4 at rest: 7 levels per row absmax perturbs elements by up to
    ~14%, so the Algorithm-2 cosine moves more than under int8 — but
    stays bounded, and the SR noise is unbiased (the convergence claim is
    pinned end-to-end by test_lossy_cache_trains and BENCH_llm)."""
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    a = z + 0.3 * jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    dz = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    c32 = _weights_through_cache(z, dz, a, "float32", seed)
    c4 = _weights_through_cache(z, dz, a, "int4", seed)
    assert np.abs(c4 - c32).max() <= 0.25


def test_int8_cache_weights_within_tolerance():
    """Paper Algorithm-2 cosines computed against the int8-at-rest cache
    stay within quantization tolerance of the fp32-cache cosines."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(8, 64), st.integers(16, 128),
           st.integers(0, 2 ** 31 - 1))
    def check(B, F, seed):
        rng = np.random.default_rng(seed)
        z = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
        # ad-hoc statistics drift from the cached ones, like a local step
        drift = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
        a = z + 0.3 * drift
        dz = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
        c32 = _weights_through_cache(z, dz, a, "float32", seed)
        c8 = _weights_through_cache(z, dz, a, "int8", seed)
        # per-row int8 SR perturbs each element by <= 1/127 of the row
        # absmax; the cosine moves by O(that / rms) — generous 6% bound
        assert np.abs(c8 - c32).max() <= 0.06, (B, F, seed)

    check()


# --------------------------------------------------------------------------
# Engine integration: lossy caches train, fp32 stays bit-exact
# --------------------------------------------------------------------------
def _tiny_workload():
    from repro.data.synthetic import TabularSpec, aligned_batches, \
        make_tabular
    from repro.models.tabular import DLRMConfig, make_dlrm
    from repro.optim import make_optimizer
    spec = TabularSpec("criteo", fields_a=4, fields_b=3, vocab=32,
                       n_train=2048, n_test=512)
    data = make_tabular(spec, seed=0)
    cfg = DLRMConfig("wdl", 4, 3, vocab=32, embed_dim=4, z_dim=8,
                     hidden=(16, 8))
    init_fn, task, _ = make_dlrm(cfg)
    return data, init_fn(jax.random.PRNGKey(0), cfg), task, \
        make_optimizer("adagrad", 0.05), aligned_batches


def _trace(cache_dtype, cache_fused, rounds=8):
    data, params, task, opt, aligned_batches = _tiny_workload()
    celu = CELUConfig(R=3, W=3, xi_degrees=60.0, cache_dtype=cache_dtype,
                      cache_fused=cache_fused)
    etask = engine.lift_two_party(task)
    it = aligned_batches(data["train"], 64, seed=0)
    _, ba, bb = next(it)
    asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
    state = engine.init_state(etask, engine.lift_two_party_params(params),
                              opt, celu, [asj(ba)], asj(bb))
    rnd = engine.make_round(etask, opt, celu)
    it = aligned_batches(data["train"], 64, seed=0)
    out = []
    for _ in range(rounds):
        bi, ba, bb = next(it)
        state, m = rnd(state, [asj(ba)], asj(bb), bi)
        out.append((float(np.float32(m["loss"])),
                    float(np.float32(m["w_mean"]))))
    return out


def test_fp32_fused_sample_bitwise_equals_materializing_path():
    """cache_fused=True over the fp32 table is the SAME trace as the
    materializing reference — the megakernel's gather is exact and its
    fp32 body reproduces the weighting kernel bit-for-bit."""
    assert _trace("float32", True) == _trace("float32", False)


@pytest.mark.parametrize("cache_dtype", ["bfloat16", "int8", "int4"])
def test_lossy_cache_trains(cache_dtype):
    rows = _trace(cache_dtype, True, rounds=10)
    losses = [l for l, _ in rows]
    assert np.isfinite(losses).all()
    assert any(w > 0 for _, w in rows)
    # lossy fused == lossy unfused (the kernel IS the decode + weight)
    assert rows == _trace(cache_dtype, False, rounds=10)


# --------------------------------------------------------------------------
# Satellites: stats staleness regression + roofline counters
# --------------------------------------------------------------------------
def test_workset_stats_respects_pipeline_staleness():
    """Regression: stats used to call _valid_mask with no offset, so
    n_alive overcounted by the retired slots under depth-1 pipelining."""
    W = 4
    ws = workset_init(W, _entry(B=2, F=2))
    for t in range(W):
        ws = workset_insert(ws, _entry(B=2, F=2, v=t), t)
    assert int(workset_stats(ws, R=2)["n_alive"]) == W
    for s in (1, 2):
        assert int(workset_stats(ws, R=2,
                                 pipeline_staleness=s)["n_alive"]) == W - s
    # and the count now matches what the sampler will actually serve
    served = 0
    w2 = dict(ws)
    for _ in range(W):
        w2, _, _, v = workset_sample(w2, 2, "round_robin",
                                     pipeline_staleness=1)
        served += int(v)
    assert served == int(workset_stats(ws, R=2,
                                       pipeline_staleness=1)["n_alive"])


def test_sample_hbm_bytes_counters():
    """The roofline counter: fused + int8 moves strictly fewer bytes than
    every other path, unfused fp32 the most."""
    e = _entry(B=256, F=32)
    unfused32 = sample_hbm_bytes(e, "float32", fused=False)
    fused32 = sample_hbm_bytes(e, "float32", fused=True)
    fused8 = sample_hbm_bytes(e, "int8", fused=True)
    fused4 = sample_hbm_bytes(e, "int4", fused=True)
    assert fused4 < fused8 < fused32 < unfused32
    # the fused int8 path moves > 2x fewer bytes than unfused fp32
    assert unfused32 / fused8 > 2.0
    # int4 halves the ring-read bytes again (codes at half a byte)
    assert unfused32 / fused4 > 3.0
    with pytest.raises(ValueError):
        sample_hbm_bytes(e, "fp16")


def test_decode_entry_identity_on_plain_trees():
    e = _entry(B=4, F=4)
    got = decode_entry(e)
    assert got["z"] is e["z"]


# --------------------------------------------------------------------------
# Party-B fused sample path (local_grad_b_cached) + its roofline counter
# --------------------------------------------------------------------------
def _b_entry(B=64, F=8, K=2):
    return {"z": [_arr((B, F)) for _ in range(K)],
            "dz": [_arr((B, F)) for _ in range(K)],
            "batch": {"y": jnp.asarray(RNG.integers(0, 2, B), jnp.float32)}}


def _b_workset(B=64, F=8, K=2, W=3, cache_dtype="float32"):
    ws = workset_init(W, _b_entry(B, F, K), cache_dtype=cache_dtype)
    for t in range(W):
        ws = workset_insert(ws, _b_entry(B, F, K), t)
    return ws


def _loss_b(p, zs, batch):
    logits = sum(z.astype(jnp.float32) @ p["w"] for z in zs) + p["c"]
    li = (jnp.maximum(logits, 0.0) - logits * batch["y"]
          + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return li, 0.0


@pytest.mark.parametrize("cache_dtype", ["float32", "int8", "int4"])
def test_party_b_fused_ring_weights_parity(cache_dtype):
    """The label party's dz-side cosine weighting through the fused
    gather→dequant→weight kernel (never materializing the decoded ∇Z
    list) must agree with the materialize-then-weight reference — bit-
    exactly on the fp32 ring, to storage precision on int8."""
    ws = _b_workset(cache_dtype=cache_dtype)
    p = {"w": _arr((8,)), "c": jnp.float32(0.1)}
    outs = {}
    for cf in (True, False):
        g, w = engine.local_grad_b_cached(_loss_b, p, ws, 1, 0.5,
                                          fused=True, cache_fused=cf)
        outs[cf] = (g, w)
    (g1, w1), (g0, w0) = outs[True], outs[False]
    if cache_dtype == "float32":
        np.testing.assert_array_equal(np.asarray(w1), np.asarray(w0))
        for a, b in zip(jax.tree_util.tree_leaves(g1),
                        jax.tree_util.tree_leaves(g0)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    else:
        np.testing.assert_allclose(np.asarray(w1), np.asarray(w0),
                                   rtol=1e-5, atol=1e-5)
        for a, b in zip(jax.tree_util.tree_leaves(g1),
                        jax.tree_util.tree_leaves(g0)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)
    # weights are the Algorithm-2 cosine gate: in [0, 1]
    assert float(w1.min()) >= 0.0 and float(w1.max()) <= 1.0


def test_sample_hbm_bytes_party_b_accounting():
    """Party B's counter: the decoded Z copy the loss consumes is paid on
    BOTH paths; fusion saves exactly the decoded fp32 ∇Z materialization
    (one f32 z/dz-sized buffer per party)."""
    B, F, K = 256, 32, 2
    e = _b_entry(B, F, K)
    f32 = B * F * 4
    a_fused = sample_hbm_bytes(e, "float32", fused=True, party="a")
    b_fused = sample_hbm_bytes(e, "float32", fused=True, party="b")
    b_unfused = sample_hbm_bytes(e, "float32", fused=False, party="b")
    # the z materialization is party B's unavoidable extra vs party A
    assert b_fused - a_fused == K * f32
    # fusing the dz side skips exactly the decoded dz copies
    assert b_unfused - b_fused == K * f32
    # int8 at rest beats fp32 at rest on either path
    assert sample_hbm_bytes(e, "int8", fused=True, party="b") < b_fused
    with pytest.raises(ValueError, match="party"):
        sample_hbm_bytes(e, "float32", party="c")


# --------------------------------------------------------------------------
# Sharding rules over quantized rings
# --------------------------------------------------------------------------
def test_workset_pspecs_shard_batch_never_ring():
    """``sharding.rules.workset_pspecs`` must shard the per-instance
    batch dim of every ring leaf — including Quant4Leaf's packed codes
    and scales — and never the W slot axis (a draw reads ONE slot)."""
    from types import SimpleNamespace

    from jax.sharding import PartitionSpec as P

    from repro.sharding.rules import make_sharding, workset_pspecs

    z = _arr((8, 16))
    ws = workset_init(5, {"z": z, "dz": z}, cache_dtype="int4")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    specs = workset_pspecs(ws, mesh)
    for k in ("z", "dz"):
        assert specs["buf"][k].q == P(None, "data", None)
        assert specs["buf"][k].scale == P(None, "data")
    for k in ("insert_time", "use_count", "batch_idx", "cursor", "time"):
        assert specs[k] == P()
    # the specs tree must be placeable as-is
    placed = jax.device_put(ws, make_sharding(mesh, specs))
    assert placed["buf"]["z"].q.shape == ws["buf"]["z"].q.shape

    # non-divisible batch replicates — the rule never falls back to W,
    # even when W itself would divide the data axis
    fake = SimpleNamespace(shape={"data": 5})
    bad = workset_pspecs(ws, fake)
    assert bad["buf"]["z"].q == P()
    # a divisible batch shards under the same multi-way axis
    ok = workset_pspecs(ws, SimpleNamespace(shape={"data": 4}))
    assert ok["buf"]["z"].q == P(None, "data", None)
