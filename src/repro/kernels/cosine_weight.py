"""Fused staleness-weighting kernel (paper Algorithm 2 hot path).

The naive composition (row-cosine, threshold, broadcast-multiply into ∇Z)
makes three HBM round-trips over the (B, F) statistics.  This kernel fuses
reduction + threshold + scale into ONE VMEM pass: each grid step loads a
(BLOCK_B, F) tile of (ad_hoc, stale, dz), computes the row cosines on the
VPU, and writes the weighted cotangent tile plus the (BLOCK_B,) weights.

Layout decisions for TPU:
  * rows (instances) on the sublane axis, features on the lane axis — the
    row-reduction is a lane reduction, natively supported by the VPU;
  * the feature dim is NOT tiled: VFL cut tensors are small per instance
    (256 floats in the paper; ≤ d_model * S_block here), so a full row fits
    VMEM comfortably and one-pass reduction avoids a two-phase scheme;
  * fp32 accumulation regardless of input dtype (bf16 inputs upcast in
    VMEM).

Inputs of any rank are flattened to (B, F) by the ops.py wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-12
BLOCK_B = 128


def _row_weights(a_ref, s_ref, thresh_ref):
    """Shared kernel body: row cosines floored at the threshold."""
    a = a_ref[...].astype(jnp.float32)           # (BLOCK_B, F)
    s = s_ref[...].astype(jnp.float32)
    thresh = thresh_ref[0]

    num = jnp.sum(a * s, axis=1)                 # lane reduction -> (BLOCK_B,)
    den = jnp.sqrt(jnp.sum(a * a, axis=1) * jnp.sum(s * s, axis=1))
    w = num / jnp.maximum(den, EPS)
    return jnp.where(w < thresh, 0.0, w)


def _kernel(a_ref, s_ref, dz_ref, thresh_ref, w_ref, out_ref):
    w = _row_weights(a_ref, s_ref, thresh_ref)
    dz = dz_ref[...].astype(jnp.float32)
    w_ref[...] = w
    out_ref[...] = (dz * w[:, None]).astype(out_ref.dtype)


def _kernel_weights_only(a_ref, s_ref, thresh_ref, w_ref):
    w_ref[...] = _row_weights(a_ref, s_ref, thresh_ref)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cosine_weights_2d(ad_hoc, stale, cos_xi, *, interpret: bool = True):
    """Weights-only variant: loads 2 (B, F) operands, writes only the (B,)
    weights — for the label party's InsWeight, where no cotangent scale
    follows (the weighted loss drives the backward pass instead)."""
    B, F = ad_hoc.shape
    bb = min(BLOCK_B, B)
    assert B % bb == 0, (B, bb)
    thresh = jnp.asarray([cos_xi], jnp.float32)

    return pl.pallas_call(
        _kernel_weights_only,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, F), lambda i: (i, 0)),
            pl.BlockSpec((bb, F), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        interpret=interpret,
    )(ad_hoc, stale, thresh)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cosine_weight_2d(ad_hoc, stale, dz, cos_xi, *, interpret: bool = True):
    """ad_hoc, stale, dz: (B, F).  -> (weights (B,) f32, weighted dz)."""
    B, F = ad_hoc.shape
    bb = min(BLOCK_B, B)
    assert B % bb == 0, (B, bb)
    thresh = jnp.asarray([cos_xi], jnp.float32)

    grid = (B // bb,)
    w, out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, F), lambda i: (i, 0)),
            pl.BlockSpec((bb, F), lambda i: (i, 0)),
            pl.BlockSpec((bb, F), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb, F), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B, F), dz.dtype),
        ],
        interpret=interpret,
    )(ad_hoc, stale, dz, thresh)
    return w, out
