"""Fault injection over the pipelined round engine (the chaos layer).

CELU-VFL's premise is hiding a slow, unreliable WAN behind cached local
updates — this module makes the "unreliable" part real.  A seeded
:class:`repro.configs.base.FaultPlan` drives a deterministic
:class:`FaultSchedule` (every fate is a pure function of
``(seed, round_idx)``), and :class:`ChaosEngine` — a
:class:`repro.core.engine.PipelinedEngine` subclass — replays it over the
exchange queue:

  * **Exchange drop w/ bounded retry.**  Each round's exchange is
    attempted up to ``max_retries + 1`` times (exponential backoff priced
    by ``launch.wan.retry_exchange_seconds``); if every attempt drops,
    the exchange is abandoned for the round.  The transport's
    ``recover_dropped`` hook folds the lost decoded messages back into
    the error-feedback residuals (``CompressedWANTransport``: the
    telescoping invariant survives the drop as a delay, not a loss;
    stateless transports degrade gracefully — the update is gone but the
    schedule continues on cached statistics).
  * **Straggler delay.**  A delivered exchange may arrive ``d`` rounds
    late; its merge is deferred until arrival, and while the queue is
    full with an unarrived head, dispatches stall (a lost round, charged
    as staleness).
  * **Party dropout spans + elastic rejoin.**  While any party is down,
    no exchange is dispatched or merged and the down party's local
    updates are frozen via the scan's ``party_mask``; the surviving
    parties keep local-updating off their cached stale statistics.  At
    the span's end the party rejoins with no special ceremony — its
    params/opt state were frozen, its ring kept ticking conservatively.
  * **Staleness accounting.**  The scan is charged
    ``t - dispatch_round(last merged exchange)`` — identical to the
    in-flight count on the fault-free schedule, and growing by one per
    round while faults starve the merge path — so the PR-5 machinery
    (validity-window tightening, ``w^(1+s)`` attenuation,
    ``eta / (1 + c*s)`` lr damping) charges fault-induced extra age with
    no new mechanism.  Merges are charged their true scheduler-round
    age.

``FaultPlan=None`` defers every decision to the base scheduler —
bit-identical to :class:`PipelinedEngine` (the golden traces pin this).

Recovery rides the checkpoint module: ``checkpoint.save_round_state``
persists the FULL :class:`RoundState` (params, opt, rings, transport
residuals, the in-flight queue) plus :meth:`ChaosEngine.host_state`, and
a restored run resumes bit-consistently (``tests/test_faults.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..configs.base import CELUConfig, FaultPlan
from ..optim import Optimizer
from .engine import KPartyTask, PendingExchange, PipelinedEngine, \
    RoundState, _zero_local_metrics


@dataclasses.dataclass(frozen=True)
class ExchangeFate:
    """The deterministic fate of one round's exchange attempt(s)."""
    delivered: bool
    attempts: int       # wire attempts actually made (1..max_retries+1)
    delay_rounds: int   # straggler delay in rounds (0 = on time)


class FaultSchedule:
    """Deterministic fate oracle over a :class:`FaultPlan`.

    Every decision derives from a fresh ``np.random.default_rng((seed,
    round_idx))`` stream — independent of call history, so a
    checkpoint-restored run (or a re-run) sees the identical fault
    sequence without replaying the earlier rounds."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan

    def down(self, round_idx: int) -> Tuple[str, ...]:
        return self.plan.down_parties(round_idx)

    def party_mask(self, round_idx: int, K: int):
        """(K+1,) float32 mask (a_0..a_{K-1}, b) or None when everyone is
        up.  Validates the plan's party names against the actual K."""
        down = self.down(round_idx)
        if not down:
            return None
        mask = np.ones(K + 1, np.float32)
        for p in down:
            idx = K if p == "b" else int(p[1:])
            # feature parties occupy slots 0..K-1; slot K is party b's —
            # an out-of-range "a{K}" must error, not silently mask b
            if p != "b" and idx >= K:
                raise ValueError(
                    f"FaultPlan drops party {p!r} but the engine has "
                    f"only K={K} feature parties (a0..a{K - 1}) plus b")
            mask[idx] = 0.0
        return jnp.asarray(mask)

    def exchange_fate(self, round_idx: int) -> ExchangeFate:
        plan = self.plan
        if plan.drop_prob <= 0.0 and plan.straggler_prob <= 0.0:
            return ExchangeFate(True, 1, 0)
        rng = np.random.default_rng((plan.seed, round_idx))
        attempts, delivered = 0, False
        for _ in range(plan.max_retries + 1):
            attempts += 1
            if rng.random() >= plan.drop_prob:
                delivered = True
                break
        delay = 0
        if delivered and plan.straggler_prob > 0.0 \
                and rng.random() < plan.straggler_prob:
            delay = int(rng.integers(1, plan.straggler_rounds + 1))
        return ExchangeFate(delivered, attempts, delay)


class ChaosEngine(PipelinedEngine):
    """The pipelined scheduler under a seeded fault plan.

    Same ``step``/``flush``/``finalize`` driving contract as
    :class:`PipelinedEngine`; per-round metrics additionally report a NaN
    ``loss`` on rounds whose merge was starved by a fault.  Host-side
    fault bookkeeping (the scheduler clock, per-slot arrival rounds, the
    event log) lives on the engine — persist it with :meth:`host_state`
    next to the ``RoundState`` checkpoint for bit-consistent resume."""

    def __init__(self, task: KPartyTask, opt: Optimizer, celu: CELUConfig,
                 *, plan: Optional[FaultPlan] = None,
                 depth: Optional[int] = None, local_steps: int = -1,
                 transport=None, compression: Optional[str] = None,
                 fused_weighting: bool = True, jit: bool = True):
        super().__init__(
            task, opt, celu, depth=depth, local_steps=local_steps,
            transport=transport, compression=compression,
            fused_weighting=fused_weighting, jit=jit,
            # None plan -> base scheduler, bit-for-bit (golden-pinned)
            dynamic_staleness=True if plan is not None else None)
        self.plan = plan
        self.schedule = None if plan is None else FaultSchedule(plan)
        self.now = 0                    # scheduler rounds elapsed
        self.events: List[Dict[str, Any]] = []
        self._dispatch_seq = 0          # rng stream position (see dispatch)
        self._arrival: List[int] = []   # per pending slot, oldest first
        self._dispatch_round: List[int] = []
        self._last_merged_dispatch = -1
        self.counters = {"dispatches": 0, "drops": 0, "stalls": 0,
                         "stalled_dispatches": 0, "dropout_rounds": 0,
                         "merges": 0, "wire_attempts": 0,
                         "straggler_delay_rounds": 0}

    # ---- host bookkeeping ------------------------------------------------
    def _event(self, t: int, kind: str, **detail):
        self.events.append({"round": t, "kind": kind, **detail})

    def host_state(self) -> Dict[str, Any]:
        """The scheduler's host-side fault bookkeeping as a plain pytree —
        checkpoint it next to the ``RoundState`` for bit-consistent
        resume (``checkpoint.save`` handles the int leaves)."""
        return {"now": self.now, "dispatch_seq": self._dispatch_seq,
                "arrival": list(self._arrival),
                "dispatch_round": list(self._dispatch_round),
                "last_merged_dispatch": self._last_merged_dispatch}

    def load_host_state(self, hs: Dict[str, Any]) -> None:
        self.now = int(hs["now"])
        self._dispatch_seq = int(hs["dispatch_seq"])
        self._arrival = [int(x) for x in hs["arrival"]]
        self._dispatch_round = [int(x) for x in hs["dispatch_round"]]
        self._last_merged_dispatch = int(hs["last_merged_dispatch"])

    def telemetry(self) -> Dict[str, Any]:
        return {"rounds": self.now, **self.counters,
                "events": list(self.events)}

    # ---- faulty stages ---------------------------------------------------
    def dispatch(self, rs: RoundState, batches_a, batch_b,
                 batch_idx) -> RoundState:
        """Under a plan the exchange rng folds over the host DISPATCH
        sequence number instead of ``comm_rounds + len(pending)``: the
        two agree on the fault-free schedule, but after a dropped
        exchange the base expression would repeat — and a retransmission
        must not reuse the dropped release's DP noise draw."""
        if self.plan is None:
            return super().dispatch(rs, batches_a, batch_b, batch_idx)
        if len(rs.pending) >= self.queue_capacity:
            raise RuntimeError(
                f"{len(rs.pending)} exchange(s) already in flight — the "
                f"depth-{self.depth} queue holds at most "
                f"{self.queue_capacity}; merge() the oldest before "
                f"dispatching another")
        tstate = rs.pending[-1].fresh["tstate"] if rs.pending \
            else rs.transport
        fresh = self._compute(rs.params, tstate, batches_a, batch_b,
                              jnp.int32(self._dispatch_seq))
        self._dispatch_seq += 1
        pe = PendingExchange(fresh, batches_a, batch_b, batch_idx,
                             dispatched_at=rs.comm_rounds)
        return rs._replace(pending=rs.pending + (pe,))

    def _absorb_drop(self, rs: RoundState) -> RoundState:
        """Pop the just-dispatched (newest) exchange whose wire transfer
        was lost and park the transport's recovered residual state where
        the NEXT dispatch (and the next merge's residual adoption) will
        read it: the newest surviving pending slot, or ``rs.transport``
        when the queue is empty — both keep the dispatch-ordered residual
        chain unbroken."""
        pe = rs.pending[-1]
        recovered = self.transport.recover_dropped(pe.fresh)
        pending = rs.pending[:-1]
        if pending:
            prev = pending[-1]
            fresh = dict(prev.fresh)
            fresh["tstate"] = recovered
            return rs._replace(
                pending=pending[:-1] + (prev._replace(fresh=fresh),))
        return rs._replace(pending=(), transport=recovered)

    def _scan_staleness(self, t: int) -> int:
        """Rounds since the newest MERGED exchange was dispatched — equal
        to the in-flight count on the fault-free schedule, and growing by
        one per round while faults starve the merge path."""
        return t - self._last_merged_dispatch

    def _chaos_local(self, rs: RoundState, t: int, mask):
        return self.local(rs, staleness=self._scan_staleness(t),
                          party_mask=mask)

    def _try_merge(self, rs: RoundState, t: int, down: Tuple[str, ...]):
        """Merge the oldest exchange if the schedule allows: queue at
        capacity (the base depth-D rule), head arrived, nobody down."""
        if down or len(rs.pending) < self.queue_capacity:
            return rs, None
        if self._arrival and self._arrival[0] > t:
            self.counters["stalls"] += 1
            self._event(t, "stall", arrives=self._arrival[0])
            return rs, None
        dr = self._dispatch_round.pop(0)
        self._arrival.pop(0)
        rs, m = self.merge(rs, staleness=t - dr)
        self._last_merged_dispatch = max(self._last_merged_dispatch, dr)
        self.counters["merges"] += 1
        return rs, m

    # ---- schedules -------------------------------------------------------
    def step(self, rs: RoundState, batches_a, batch_b, batch_idx
             ) -> Tuple[RoundState, Dict[str, Any]]:
        if self.plan is None:
            return super().step(rs, batches_a, batch_b, batch_idx)
        t = self.now
        K = len(rs.params["a"])
        down = self.schedule.down(t)
        mask = self.schedule.party_mask(t, K)
        if down:
            self.counters["dropout_rounds"] += 1
            if any(d.start == t for d in self.plan.dropouts
                   if d.covers(t)):
                self._event(t, "dropout", parties=list(down))
        elif len(rs.pending) < self.queue_capacity:
            fate = self.schedule.exchange_fate(t)
            self.counters["wire_attempts"] += fate.attempts
            rs = self.dispatch(rs, batches_a, batch_b, batch_idx)
            self.counters["dispatches"] += 1
            if fate.delivered:
                self._arrival.append(t + fate.delay_rounds)
                self._dispatch_round.append(t)
                if fate.delay_rounds:
                    self.counters["straggler_delay_rounds"] += \
                        fate.delay_rounds
                    self._event(t, "straggler", delay=fate.delay_rounds,
                                attempts=fate.attempts)
            else:
                rs = self._absorb_drop(rs)
                self.counters["drops"] += 1
                self._event(t, "drop", attempts=fate.attempts)
        else:
            # queue full with an unarrived head blocked the dispatch —
            # the round's batch is skipped (a straggler's real cost)
            self.counters["stalled_dispatches"] += 1
            self._event(t, "stall-dispatch")
        if self.depth == 0:
            rs, m = self._try_merge(rs, t, down)
            rs, lm = self._chaos_local(rs, t, mask)
        else:
            rs, lm = self._chaos_local(rs, t, mask)
            rs, m = self._try_merge(rs, t, down)
        self.now = t + 1
        if m is None:
            m = {"loss": jnp.float32(jnp.nan)}
        m.update(lm)
        return rs, m

    def flush(self, rs: RoundState) -> Tuple[RoundState, Dict[str, Any]]:
        """Drain the queue.  Outstanding merges complete regardless of
        the remaining fault schedule — their transfers already succeeded
        (drops were absorbed at dispatch time); only arrival timing was
        simulated, and shutdown waits it out.  Down parties stay masked
        out of the drain scans."""
        if self.plan is None:
            return super().flush(rs)
        if self.depth == 0 and not rs.pending:
            # sequential schedule, nothing in flight: every merge already
            # got its in-step scan (depth-0 order is merge THEN scan)
            return rs, _zero_local_metrics()
        K = len(rs.params["a"])
        scans = []
        while rs.pending:
            t = self.now
            rs, lm = self._chaos_local(
                rs, t, self.schedule.party_mask(t, K))
            scans.append(lm)
            dr = self._dispatch_round.pop(0) if self._dispatch_round \
                else t
            if self._arrival:
                self._arrival.pop(0)
            rs, _ = self.merge(rs, staleness=t - dr)
            self._last_merged_dispatch = max(
                self._last_merged_dispatch, dr)
            self.counters["merges"] += 1
            self.now = t + 1
        t = self.now
        rs, lm = self._chaos_local(rs, t, self.schedule.party_mask(t, K))
        scans.append(lm)
        if not scans:
            return rs, _zero_local_metrics()
        n = len(scans)
        return rs, {
            "local_steps": sum(s["local_steps"] for s in scans),
            "w_mean": sum(s["w_mean"] for s in scans) / n,
            "w_zero_frac": sum(s["w_zero_frac"] for s in scans) / n,
        }


def make_chaos_engine(task: KPartyTask, opt: Optimizer, celu: CELUConfig,
                      *, plan: Optional[FaultPlan] = None,
                      **kw) -> ChaosEngine:
    """Factory mirroring :func:`repro.core.engine.make_pipeline`;
    ``plan=None`` builds a scheduler bit-identical to the fault-free
    pipeline."""
    return ChaosEngine(task, opt, celu, plan=plan, **kw)
