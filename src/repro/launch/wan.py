"""The simulated WAN clock: per-direction bandwidth + RTT, and the
overlap-aware round latency model for the pipelined engine.

The container has no real WAN, so benchmarks and the training driver model
wall-clock from byte counts (paper §2.1: a 300 Mbps gateway-proxied link;
the 213 ms example for an 8 MB exchange reproduces at the defaults).  Two
fixes over the historical ``wan_seconds(nbytes)``:

  * **Per-direction bandwidth.**  Cross-silo WAN links are routinely
    asymmetric, and so are the engine's wires since the compressed
    transport (sparse top-k sketches up, dense low-bit down) — so the
    clock takes the transport's explicit ``uplink_bytes`` /
    ``downlink_bytes`` split instead of one symmetric total.  Within a
    round the two legs serialize (∇Z_i cannot leave Party B before Z_i
    arrives), so wire time is ``up/bw_up + down/bw_down + 2·latency``.

  * **Overlap-aware round latency.**  The sequential schedule
    (``engine.make_round``) pays ``exchange_compute + wire + local``
    per round; the depth-D pipelined schedule
    (``engine.PipelinedEngine``) hides the wire behind the local scans of
    the D-round in-flight window, so a steady-state round costs
    ``max(local, serial wire occupancy, (exchange_compute + wire) / D)``
    — at depth 1 that is the paper's ``max(exchange + wire, local)``.
    Benchmarks must charge the schedule they actually ran — the historical
    model silently assumed full overlap for every protocol.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class WANClock:
    """Simulated cross-silo WAN link (paper §2.1 defaults: 300 Mbps each
    direction, 10 ms one-way gateway latency)."""
    up_bandwidth: float = 300e6 / 8      # bytes/s, feature party -> label
    down_bandwidth: float = 300e6 / 8    # bytes/s, label party -> feature
    latency: float = 0.01                # s, one way

    @property
    def rtt(self) -> float:
        return 2.0 * self.latency

    def up_seconds(self, nbytes: float) -> float:
        """One uplink leg (Z_i), excluding latency."""
        return nbytes / self.up_bandwidth

    def down_seconds(self, nbytes: float) -> float:
        """One downlink leg (∇Z_i), excluding latency."""
        return nbytes / self.down_bandwidth

    def wire_seconds(self, up_bytes: float, down_bytes: float) -> float:
        """One full exchange: the legs serialize (the downlink cotangent
        depends on the uplinked Z), plus one RTT of gateway latency."""
        return self.up_seconds(up_bytes) + self.down_seconds(down_bytes) \
            + self.rtt

    def round_seconds(self, up_bytes: float, down_bytes: float, *,
                      exchange_compute_s: float = 0.0,
                      local_compute_s: float = 0.0,
                      pipeline_depth: int = 0) -> float:
        """Latency of ONE communication round under the given schedule.

        Sequential (depth 0): the WAN stall serializes with both compute
        phases.  Pipelined (depth D >= 1): up to D exchanges (compute +
        wire) are in flight concurrently with the local updates, so the
        steady-state round period is the slowest of three bounds —

          * the local worker: ``local_compute_s`` per round;
          * the serial wire occupancy: each round must still push one
            exchange's bytes through the link (transfers pipeline, so the
            RTT amortizes across the D in-flight exchanges but bandwidth
            does not multiply);
          * the exchange latency amortized over its D-round window:
            ``(exchange_compute_s + wire) / D`` — an exchange has D rounds
            to complete before its merge is due.

        Depth 1 reduces to the historical ``max(exchange + wire, local)``
        (the single-exchange window dominates its occupancy bound)."""
        wire = self.wire_seconds(up_bytes, down_bytes)
        if pipeline_depth <= 0:
            return exchange_compute_s + wire + local_compute_s
        occupancy = self.up_seconds(up_bytes) + self.down_seconds(down_bytes)
        return max(local_compute_s, occupancy,
                   (exchange_compute_s + wire) / pipeline_depth)

    def time_to_target(self, rounds: int, up_bytes: float,
                       down_bytes: float, **kw) -> float:
        """Overlap-aware simulated wall-clock for ``rounds`` rounds."""
        return rounds * self.round_seconds(up_bytes, down_bytes, **kw)

    def with_bandwidth(self, up: float, down: float = None) -> "WANClock":
        return dataclasses.replace(self, up_bandwidth=up,
                                   down_bandwidth=up if down is None
                                   else down)


DEFAULT_CLOCK = WANClock()


def transport_round_updown(transport, z_shapes):
    """Per-round (uplink, downlink) byte totals for a transport over the K
    cut-tensor shapes — the per-direction split ``round_bytes`` sums."""
    up = sum(transport.uplink_bytes(s) for s in z_shapes)
    down = sum(transport.downlink_bytes(s) for s in z_shapes)
    return up, down


def wan_seconds(up_bytes: float, down_bytes: float, *,
                clock: WANClock = DEFAULT_CLOCK) -> float:
    """Seconds one exchange spends on the wire.  Both directions are
    required — the historical one-argument form took the ROUND TOTAL and
    would silently double-count if it defaulted here."""
    return clock.wire_seconds(up_bytes, down_bytes)


# --------------------------------------------------------------------------
# Heterogeneous / unreliable links (the chaos engine's price model)
# --------------------------------------------------------------------------
def clocks_from_plan(plan, K: int):
    """Per-feature-party :class:`WANClock` list for a
    ``configs.base.FaultPlan``.  ``plan.party_clocks`` holds plain
    ``(up_Bps, down_Bps, latency_s)`` tuples (configs stays a leaf
    module); missing entries (or ``plan=None`` / ``party_clocks=None``)
    fall back to the homogeneous default link, and a shorter tuple than K
    cycles — handy for 'one slow party' plans."""
    tuples = getattr(plan, "party_clocks", None) if plan is not None \
        else None
    if not tuples:
        return [DEFAULT_CLOCK] * K
    return [WANClock(up_bandwidth=tuples[i % len(tuples)][0],
                     down_bandwidth=tuples[i % len(tuples)][1],
                     latency=tuples[i % len(tuples)][2])
            for i in range(K)]


def transport_party_updown(transport, z_shapes):
    """Per-party [(uplink, downlink)] byte pairs — the per-link loads a
    heterogeneous clock set prices individually."""
    return [(transport.uplink_bytes(s), transport.downlink_bytes(s))
            for s in z_shapes]


def hetero_wire_seconds(clocks, party_updown) -> float:
    """One K-party exchange over per-party links: each party's ⟨Z_i, ∇Z_i⟩
    legs ride its OWN link concurrently with the other parties', so the
    exchange completes when the SLOWEST link drains (the merge needs every
    party's statistics)."""
    return max(c.wire_seconds(u, d)
               for c, (u, d) in zip(clocks, party_updown))


def retry_exchange_seconds(clocks, party_updown, *, attempts: int = 1,
                           backoff_s: float = 0.0) -> float:
    """Wall-clock of one exchange delivered on its ``attempts``-th try
    under exponential backoff: every attempt re-pays the full
    heterogeneous wire time (the exchange is retried whole — partial
    per-party redelivery would break the K-party merge atomicity), and
    attempt k+1 waits ``backoff_s * 2**(k-1)`` first."""
    if attempts < 1:
        raise ValueError(f"attempts must be >= 1, got {attempts}")
    wire = hetero_wire_seconds(clocks, party_updown)
    waits = sum(backoff_s * (2.0 ** k) for k in range(attempts - 1))
    return attempts * wire + waits
