"""Beyond-paper optimizations (§Perf pair 3, iterations 2+).

1. **bf16 wire format** for the exchanged ⟨Z_A, ∇Z_A⟩: the paper sends
   fp32.  Validates convergence parity on WDL and reports the combined
   communication reduction (CELU round savings × 2 from the wire).
2. **run_protocol wire sweep** — fp32 vs bf16 at the paper-repro settings.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .common import csv_row, default_workload
from .common import run_protocol as _run


def run_protocol_wire(protocol, data, cfg, wire, **kw):
    """run_protocol with a wire_dtype override."""
    import benchmarks.common as C
    from repro.configs.base import CELUConfig
    from repro.core import protocol as proto
    import jax
    import numpy as np
    import time
    from repro.data import synthetic as synth
    from repro.models.tabular import auc, make_dlrm
    from repro.optim import make_optimizer

    R, W, xi = kw.get("R", 5), kw.get("W", 5), kw.get("xi", 60.0)
    rounds, lr = kw.get("rounds", 700), kw.get("lr", 0.003)
    batch = kw.get("batch", 256)
    init_fn, task, predict = make_dlrm(cfg)
    base = CELUConfig(R=R, W=W, xi_degrees=xi, wire_dtype=wire)
    ccfg, nloc = proto.protocol_config(protocol, base)
    ccfg = dataclasses.replace(ccfg, wire_dtype=wire)
    params = init_fn(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adagrad", lr)
    it = synth.aligned_batches(data["train"], batch, seed=0)
    _, ba, bb = next(it)
    asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
    state = proto.init_state(task, params, opt, ccfg, asj(ba), asj(bb))
    rnd = proto.make_round(task, opt, ccfg, local_steps=nloc)
    it = synth.aligned_batches(data["train"], batch, seed=0)
    te = data["test"]
    tea = {"x_a": jnp.asarray(te["x_a"])}
    teb = {"x_b": jnp.asarray(te["x_b"]), "y": jnp.asarray(te["y"])}
    best = 0.0
    for i in range(rounds):
        bi, ba, bb = next(it)
        state, m = rnd(state, asj(ba), asj(bb), bi)
        if (i + 1) % 50 == 0:
            a = auc(np.asarray(predict(state["params"], cfg, tea, teb)),
                    te["y"])
            best = max(best, a)
    zb = proto.exchange_bytes((batch, cfg.z_dim), wire_dtype=wire)
    return best, zb


def dp_sweep(data, cfg):
    """Privacy/utility: Gaussian DP on the wire (core/privacy.py).  CELU
    releases 1/(1+R) as many messages per update, so the per-update ε
    shrinks the same way the communication does."""
    import jax
    import numpy as np
    from repro.configs.base import CELUConfig
    from repro.core import protocol as proto
    from repro.core.privacy import DPConfig, epsilon_per_release
    from repro.data import synthetic as synth
    from repro.models.tabular import auc, make_dlrm
    from repro.optim import make_optimizer

    csv_row("# beyond-paper: DP-on-the-wire (clip=8, 400 rounds, celu R=5)")
    csv_row("sigma", "eps_per_release", "best_auc")
    init_fn, task, predict = make_dlrm(cfg)
    te = data["test"]
    tea = {"x_a": jnp.asarray(te["x_a"])}
    teb = {"x_b": jnp.asarray(te["x_b"]), "y": jnp.asarray(te["y"])}
    for sigma in (0.0, 0.05, 0.2):
        celu = CELUConfig(R=5, W=5, dp_sigma=sigma, dp_clip=8.0)
        params = init_fn(jax.random.PRNGKey(0), cfg)
        opt = make_optimizer("adagrad", 0.003)
        it = synth.aligned_batches(data["train"], 256, seed=0)
        _, ba, bb = next(it)
        asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
        state = proto.init_state(task, params, opt, celu, asj(ba), asj(bb))
        rnd = proto.make_round(task, opt, celu)
        it = synth.aligned_batches(data["train"], 256, seed=0)
        best = 0.0
        for i in range(400):
            bi, ba, bb = next(it)
            state, m = rnd(state, asj(ba), asj(bb), bi)
            if (i + 1) % 100 == 0:
                best = max(best, auc(np.asarray(
                    predict(state["params"], cfg, tea, teb)), te["y"]))
        eps = epsilon_per_release(DPConfig(clip=8.0, sigma=sigma))
        csv_row(sigma, "inf" if eps == float("inf") else f"{eps:.1f}",
                f"{best:.4f}")


def main():
    csv_row("# beyond-paper: bf16 wire format for the cut-tensor exchange")
    csv_row("setting", "best_auc", "bytes_per_round", "relative_comm")
    spec, data, cfg = default_workload("wdl", "criteo")
    base_auc, base_bytes = run_protocol_wire("vanilla", data, cfg, "float32",
                                             rounds=700)
    csv_row("vanilla fp32-wire", f"{base_auc:.4f}", base_bytes, "1.00x")
    for wire in ("float32", "bfloat16"):
        a, zb = run_protocol_wire("celu", data, cfg, wire, R=5, W=5,
                                  rounds=700)
        # CELU reaches target in ~1/4 the rounds (ablation block); the wire
        # multiplies on top.  Report per-round bytes here.
        csv_row(f"celu {wire}-wire", f"{a:.4f}", zb,
                f"{zb / base_bytes:.2f}x/round")
    dp_sweep(data, cfg)


if __name__ == "__main__":
    main()
