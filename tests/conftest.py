import os
import sys

# Tests run on the host's single CPU device (the 512-device override lives
# ONLY in launch/dryrun.py).  Keep compilation light.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
