"""Fleet-scale training: many CELU-VFL jobs as one compiled XLA program.

``scheduler`` re-expresses the PipelinedEngine's host-side schedule as a
device-side traced step (lax.cond over a traced queue phase) so it
batches over a leading job axis; ``runner`` partitions a list of
:class:`JobSpec` into compiled cohorts and runs each as a single
``jit(scan(vmap(step)))``.  See docs/FLEET.md.
"""
from .runner import (FleetResult, FleetWorkload, JobSpec, cohort_key,
                     run_fleet)
from .scheduler import (ENGINE_RNG_BASES, FleetRoundState, JobHyper,
                        average_flush_metrics, make_fleet_step)

__all__ = [
    "ENGINE_RNG_BASES", "FleetResult", "FleetRoundState", "FleetWorkload",
    "JobHyper", "JobSpec", "average_flush_metrics", "cohort_key",
    "make_fleet_step", "run_fleet",
]
