"""Fleet runner gates: the batched device-side scheduler must be a
bit-exact re-expression of the scalar engine, not a numerical cousin.

  * N=1 vmap fleets (and N=3 ``mode="map"`` fleets) reproduce the K=1 and
    K=3 golden traces bit-for-bit at depth 0, and PipelinedEngine's rows,
    flush metrics, counters and final params bit-for-bit at depths 1/2.
  * N=3 vmap lanes of identical jobs are bit-identical to EACH OTHER
    (CPU XLA's batched GEMMs may sit a ULP off the unbatched program —
    docs/FLEET.md — so cross-checking lanes, not the scalar engine, is
    the right vmap invariant at N > 1).
  * Stacked metrics keep the caller's job order across cohorts, traced
    knobs (lr / xi / seed) batch inside one cohort, static knobs
    partition it.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CELUConfig
from repro.core import engine
from repro.data.synthetic import TabularSpec, aligned_batches, make_tabular
from repro.fleet import (FleetWorkload, JobSpec, average_flush_metrics,
                         cohort_key, run_fleet)
from repro.models.tabular import DLRMConfig, make_dlrm
from repro.optim import make_optimizer

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "two_party_trace.json")
GOLDEN3 = os.path.join(os.path.dirname(__file__), "golden",
                       "three_party_trace.json")
BASE = CELUConfig(R=3, W=3, xi_degrees=60.0)


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def golden3():
    with open(GOLDEN3) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def workload():
    """The exact K=1 workload the two-party golden trace was recorded on,
    lifted to a FleetWorkload (shared batch schedule, per-seed params)."""
    spec = TabularSpec("criteo", fields_a=4, fields_b=3, vocab=32,
                       n_train=2048, n_test=512)
    data = make_tabular(spec, seed=0)
    cfg = DLRMConfig("wdl", 4, 3, vocab=32, embed_dim=4, z_dim=8,
                     hidden=(16, 8))
    init_fn, task, _ = make_dlrm(cfg)
    etask = engine.lift_two_party(task)
    asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}

    def params_for(seed):
        p = init_fn(jax.random.PRNGKey(seed), cfg)
        return engine.lift_two_party_params(p)

    def batch_stream():
        for bi, ba, bb in aligned_batches(data["train"], 64, seed=0):
            yield bi, [asj(ba)], asj(bb)

    return FleetWorkload(etask, params_for, batch_stream)


def _rows(res, j, rounds, k3=False):
    """FleetResult job ``j`` -> golden-comparable rows (same schema as
    tests.test_engine._run_trace)."""
    rows = []
    for t in range(rounds):
        rows.append({"loss": float(np.float32(res.losses[j, t])),
                     "w_mean": float(np.float32(res.w_mean[j, t])),
                     "w_zero_frac": float(np.float32(res.w_zero_frac[j, t])),
                     "local_steps": int(res.local_steps[j, t])})
    sa = res.steps_a[j] if k3 else res.steps_a[j][0]
    rows.append({"steps_a": sa, "steps_b": int(res.steps_b[j]),
                 "comm_rounds": int(res.comm_rounds[j])})
    return rows


# --------------------------------------------------------------------------
# Golden parity: the fleet IS the scalar engine
# --------------------------------------------------------------------------
@pytest.mark.parametrize("protocol", ["vanilla", "fedbcd", "celu"])
def test_fleet_vmap_n1_matches_two_party_golden(protocol, workload, golden):
    """A one-job vmap fleet reproduces the 20-round K=1 golden trace
    bit-for-bit — protocol presets, counters and all."""
    ccfg, nloc = engine.preset_config(protocol, BASE)
    res = run_fleet([JobSpec(celu=ccfg, local_steps=nloc)], 20,
                    workload=workload, mode="vmap")
    assert _rows(res, 0, 20) == golden[protocol]
    assert res.n_cohorts == 1 and res.mode == "vmap"


def test_fleet_map_n3_matches_two_party_golden(workload, golden):
    """A three-job ``mode="map"`` fleet of identical jobs runs the
    UNBATCHED program per lane inside one compiled call: every lane is
    bit-identical to the golden trace at any fleet size."""
    ccfg, nloc = engine.preset_config("celu", BASE)
    res = run_fleet([JobSpec(celu=ccfg, local_steps=nloc)] * 3, 20,
                    workload=workload, mode="map")
    for j in range(3):
        assert _rows(res, j, 20) == golden["celu"], f"lane {j}"


def test_fleet_vmap_n3_lanes_bit_identical(workload):
    """vmap lanes of identical jobs must agree with EACH OTHER bitwise
    (the N>1 vmap invariant; vs-scalar exactness at N>1 is mode="map"'s
    contract, not vmap's — CPU batched GEMMs reassociate)."""
    ccfg, nloc = engine.preset_config("celu", BASE)
    res = run_fleet([JobSpec(celu=ccfg, local_steps=nloc)] * 3, 10,
                    workload=workload, mode="vmap")
    for arr in (res.losses, res.w_mean, res.w_zero_frac, res.local_steps):
        for j in (1, 2):
            np.testing.assert_array_equal(arr[j], arr[0])
    p0 = jax.tree_util.tree_leaves(res.final_state(0)["params"])
    for j in (1, 2):
        pj = jax.tree_util.tree_leaves(res.final_state(j)["params"])
        assert all(np.array_equal(a, b) for a, b in zip(p0, pj))


def test_fleet_vmap_n1_matches_three_party_golden(golden3):
    """The K=3 (two feature parties + B) golden trace survives the fleet
    path bit-for-bit — the job axis composes with the K-party lists."""
    from test_engine import _three_party_workload
    task, celu, opt, data, split, params = _three_party_workload()

    def batch_stream():
        for bi, ba, bb in aligned_batches(data["train"], 64, seed=0):
            bas, b = split(ba, bb)
            yield bi, bas, b

    wl = FleetWorkload(task, lambda seed: params, batch_stream)
    res = run_fleet([JobSpec(celu=celu, lr=0.02)], 20, workload=wl,
                    mode="vmap")
    assert _rows(res, 0, 20, k3=True) == golden3["celu"]


# --------------------------------------------------------------------------
# Pipelined depths: fleet step/flush vs PipelinedEngine, bit for bit
# --------------------------------------------------------------------------
@pytest.mark.parametrize("depth", [1, 2])
def test_fleet_vmap_n1_matches_pipelined_engine(depth, workload):
    """At depths 1/2 the fleet's traced queue must replay
    PipelinedEngine's host schedule exactly: per-round rows (NaN warmup
    included), flush metrics, counters, final params."""
    rounds = 12
    ccfg, nloc = engine.preset_config("celu", BASE)
    opt = make_optimizer("adagrad", 0.05)
    pipe = engine.make_pipeline(workload.task, opt, ccfg, local_steps=nloc,
                                depth=depth)
    it = workload.batch_stream()
    bi0, ba0, bb0 = next(it)
    state = engine.init_state(workload.task, workload.params_for(0), opt,
                              ccfg, ba0, bb0)
    rs = pipe.init(state)
    host_rows = []
    it = workload.batch_stream()
    for _ in range(rounds):
        bi, ba, bb = next(it)
        rs, m = pipe.step(rs, ba, bb, bi)
        host_rows.append({k: np.float32(m[k]) for k in
                          ("loss", "w_mean", "w_zero_frac")}
                         | {"local_steps": int(m["local_steps"])})
    rs, fm = pipe.flush(rs)
    fin = pipe.finalize(rs)

    res = run_fleet([JobSpec(celu=ccfg, local_steps=nloc, depth=depth)],
                    rounds, workload=workload, mode="vmap")
    for t, h in enumerate(host_rows):
        for k in ("loss", "w_mean", "w_zero_frac"):
            got = np.float32(getattr(res, {"loss": "losses"}.get(k, k))[0, t])
            want = h[k]
            assert (np.isnan(want) and np.isnan(got)) or got == want, \
                (t, k, want, got)
        assert int(res.local_steps[0, t]) == h["local_steps"], t
    for k in ("w_mean", "w_zero_frac"):
        assert np.float32(res.flush_metrics[k][0]) == np.float32(fm[k])
    assert int(res.flush_metrics["local_steps"][0]) == int(fm["local_steps"])
    assert int(res.comm_rounds[0]) == int(fin["comm_rounds"])
    assert res.steps_a[0] == [int(s) for s in fin["steps"]["a"]]
    assert int(res.steps_b[0]) == int(fin["steps"]["b"])
    hp = jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(np.asarray, fin["params"]))
    fp = jax.tree_util.tree_leaves(res.final_state(0)["params"])
    assert all(np.array_equal(a, b) for a, b in zip(hp, fp))


# --------------------------------------------------------------------------
# Stacked metrics, cohorts, traced knobs
# --------------------------------------------------------------------------
def test_fleet_traced_knobs_share_one_cohort(workload):
    """lr / xi / seed vary per job WITHOUT recompiling: one cohort, one
    compiled program, lanes genuinely different."""
    ccfg, nloc = engine.preset_config("celu", BASE)
    specs = [JobSpec(celu=ccfg, local_steps=nloc, lr=0.05, seed=0),
             JobSpec(celu=ccfg, local_steps=nloc, lr=0.1, seed=1,
                     xi_degrees=45.0),
             JobSpec(celu=ccfg, local_steps=nloc, lr=0.02, seed=2,
                     xi_degrees=75.0)]
    assert len({cohort_key(s) for s in specs}) == 1
    res = run_fleet(specs, 6, workload=workload, mode="vmap")
    assert res.n_cohorts == 1 and res.cohort_sizes == [3]
    assert res.losses.shape == (3, 6)
    assert np.isfinite(res.losses).all()
    # different lr/seed/xi => different trajectories, lane per lane
    assert not np.array_equal(res.losses[0], res.losses[1])
    assert not np.array_equal(res.losses[1], res.losses[2])
    # one WAN round moves the same bytes for every job in the cohort
    assert (res.round_wire_bytes > 0).all()
    assert len(set(res.round_wire_bytes.tolist())) == 1


def test_fleet_mixed_depths_partition_and_keep_order(workload):
    """Static knobs (here: depth) split the fleet into cohorts, but the
    result rows stay in the CALLER's job order and every job completes
    all its rounds after the drain."""
    rounds = 6
    ccfg, nloc = engine.preset_config("celu", BASE)
    specs = [JobSpec(celu=ccfg, local_steps=nloc, depth=0),
             JobSpec(celu=ccfg, local_steps=nloc, depth=2),
             JobSpec(celu=ccfg, local_steps=nloc, depth=0, lr=0.1)]
    assert len({cohort_key(s) for s in specs}) == 2
    res = run_fleet(specs, rounds, workload=workload, mode="vmap")
    assert res.n_cohorts == 2 and sorted(res.cohort_sizes) == [1, 2]
    assert (res.comm_rounds == rounds).all()   # depth-2 queue drained
    # depth-0 jobs have no warmup NaNs; the depth-2 job has exactly one
    assert np.isfinite(res.losses[0]).all()
    assert np.isfinite(res.losses[2]).all()
    assert np.isnan(res.losses[1, 0]) and np.isfinite(res.losses[1, 1:]).all()
    # order preserved: jobs 0 and 2 differ only by lr
    assert not np.array_equal(res.losses[0], res.losses[2])


def test_average_flush_metrics_passthrough_and_average():
    """Depth 0/1 metrics pass through; per-scan rows average with one
    IEEE rounding per add (PipelinedEngine.flush's eager arithmetic)."""
    m = {"local_steps": np.int32(6), "w_mean": np.float32(0.5),
         "w_zero_frac": np.float32(0.25)}
    assert average_flush_metrics(m) == m
    rows = {"local_steps": jnp.int32(9),
            "w_mean_scans": jnp.asarray([0.3, 0.0, 0.6], jnp.float32),
            "w_zero_frac_scans": jnp.asarray([0.1, 0.0, 0.2], jnp.float32),
            "n_scans": jnp.int32(2)}
    out = average_flush_metrics(rows)
    assert out["local_steps"] == 9
    a, b = np.float32(0.3), np.float32(0.6)
    assert out["w_mean"] == np.float32(
        np.float32(np.float32(np.float32(0.0) + a) + np.float32(0.0) + b)
        / np.float32(2.0))


# --------------------------------------------------------------------------
# Sharded fleet (host-platform device grid) — fresh process, like the
# other multi-device lanes
# --------------------------------------------------------------------------
SHARD_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np, jax.numpy as jnp
from repro.configs.base import CELUConfig
from repro.core import engine
from repro.data.synthetic import TabularSpec, aligned_batches, make_tabular
from repro.fleet import FleetWorkload, JobSpec, run_fleet

assert len(jax.devices()) == 4
spec = TabularSpec("criteo", fields_a=4, fields_b=3, vocab=32,
                   n_train=2048, n_test=512)
data = make_tabular(spec, seed=0)
from repro.models.tabular import DLRMConfig, make_dlrm
cfg = DLRMConfig("wdl", 4, 3, vocab=32, embed_dim=4, z_dim=8, hidden=(16, 8))
init_fn, task, _ = make_dlrm(cfg)
etask = engine.lift_two_party(task)
asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
params_for = lambda seed: engine.lift_two_party_params(
    init_fn(jax.random.PRNGKey(seed), cfg))
def batch_stream():
    for bi, ba, bb in aligned_batches(data["train"], 64, seed=0):
        yield bi, [asj(ba)], asj(bb)
wl = FleetWorkload(etask, params_for, batch_stream)
base = CELUConfig(R=3, W=3, xi_degrees=60.0)
ccfg, nloc = engine.preset_config("celu", base)
specs = [JobSpec(celu=ccfg, local_steps=nloc, seed=s) for s in range(8)]
sharded = run_fleet(specs, 4, workload=wl, mode="vmap", shard=True)
plain = run_fleet(specs, 4, workload=wl, mode="vmap", shard=False)
assert np.isfinite(sharded.losses).all()
assert np.allclose(sharded.losses, plain.losses, rtol=1e-5, atol=1e-6), \\
    np.abs(sharded.losses - plain.losses).max()
print("FLEET_SHARDED_OK")
"""


@pytest.mark.slow
def test_fleet_sharded_over_host_device_grid():
    """An 8-job fleet sharded over a forced 4-device host grid agrees
    with the unsharded run (device boundaries may re-tile GEMMs, so the
    gate is allclose, not bitwise)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SHARD_CODE],
                       capture_output=True, text=True, env=env, timeout=900)
    assert "FLEET_SHARDED_OK" in r.stdout, \
        (r.stdout[-500:], r.stderr[-2000:])
