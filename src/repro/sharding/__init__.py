from .rules import (batch_pspec, cache_pspecs, make_sharding,  # noqa: F401
                    params_pspecs, tree_pspecs)
