"""Benchmark-regression gate: diff a fresh benchmark JSON against its
committed baseline.  Gates four files in CI: ``BENCH_local_scan.json``
(vs ``results/BENCH_baseline.json``), the LLM-geometry memory table
``BENCH_llm.json`` (vs ``results/BENCH_llm_baseline.json``), the
fleet-throughput table ``BENCH_fleet.json`` (vs
``results/BENCH_fleet_baseline.json``) and the serving table
``BENCH_serve.json`` (vs ``results/BENCH_serve_baseline.json``).

Three classes of signal:

  * **Deterministic counters** — the named roofline counters in
    ``EXACT_KEYS`` plus EVERY per-variant key ending in ``_bytes`` (the
    LLM table's per-party params/opt-state/cache budgets, the fleet
    table's per-job wire bytes) are exact functions of the code, not the
    machine.  ANY increase over the baseline fails the gate.
  * **Measured wall** — the ``WALL_KEYS`` metrics are wall measurements
    on a shared CI runner; each may drift up to ``--wall-tol`` (default
    25%) in its BAD direction before the gate trips (``local_step_ms``
    regresses UP, ``speedup_vs_sequential`` regresses DOWN).  A gated
    wall metric that is present in the baseline but missing (or zero)
    in the current run FAILS — a variant cannot dodge the gate by not
    reporting.  Absolute-throughput keys (``INFO_WALL_KEYS``, e.g.
    ``jobs_per_sec``) are reported on >tolerance drift but never gate:
    they track the runner that wrote the baseline, not the code.
  * **Indicative** — any key starting with ``indicative_`` (e.g. the LLM
    table's ``indicative_cpu_tokens_per_sec``: CPU wall through
    interpreted Pallas kernels) is excluded from the gate BY CONTRACT,
    even if it also matches a gated pattern.

A counter that IMPROVED is reported but passes — refresh the baseline
(rerun the producing benchmark and copy the JSON over its
``*_baseline.json``) in the same PR that earns the win, so the gate
ratchets.

    python -m benchmarks.compare \
        --baseline results/BENCH_baseline.json \
        --current results/BENCH_local_scan.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
DEFAULT_BASELINE = os.path.join(RESULTS_DIR, "BENCH_baseline.json")
DEFAULT_CURRENT = os.path.join(RESULTS_DIR, "BENCH_local_scan.json")

# exact per-variant counters: any increase is a regression
EXACT_KEYS = ("cache_bytes", "stat_cache_bytes",
              "sample_hbm_bytes_per_step", "hbm_bytes_per_round")
# measured per-variant wall metrics: (key, bad direction).  Tolerated up
# to --wall-tol relative drift toward "bad".  Only runner-relative
# metrics belong here: the fleet table gates the speedup RATIO (both
# sides measured on the same runner), not absolute throughput, which
# tracks the machine that wrote the baseline, not the code.
WALL_KEYS = (("local_step_ms", "up"), ("speedup_vs_sequential", "down"))
# absolute wall metrics: reported on drift, never gated (not portable
# across runners).  The serve table's latency/throughput keys live here
# for the same reason the fleet table's do: the RATIO
# (speedup_vs_sequential) gates; absolutes track the runner.
INFO_WALL_KEYS = ("jobs_per_sec", "requests_per_sec", "tokens_per_sec",
                  "p50_token_latency_ms", "p99_token_latency_ms")
# keys carrying this prefix are non-claims and never gate
INDICATIVE_PREFIX = "indicative_"


def _exact_keys(base: dict, cur: dict):
    """Deterministic keys of one variant: the named counters plus every
    ``*_bytes`` field (memory budgets are exact by construction).
    ``indicative_*`` keys are excluded by contract."""
    keys = set(EXACT_KEYS)
    for k, v in list(base.items()) + list(cur.items()):
        if k.endswith("_bytes") and isinstance(v, (int, float)):
            keys.add(k)
    return sorted(k for k in keys if not k.startswith(INDICATIVE_PREFIX))


def compare(baseline: dict, current: dict, wall_tol: float = 0.25):
    """-> (failures, notes): lists of human-readable strings.  A failure
    is a regression the gate must reject; a note is an improvement or a
    new variant worth a baseline refresh."""
    failures, notes = [], []
    base_v = baseline.get("variants", {})
    cur_v = current.get("variants", {})
    if baseline.get("geometry") != current.get("geometry"):
        notes.append(f"geometry changed: {baseline.get('geometry')} -> "
                     f"{current.get('geometry')} (wall comparison is "
                     f"apples-to-oranges; counters still gate)")
    for name, base in base_v.items():
        cur = cur_v.get(name)
        if cur is None:
            failures.append(f"variant {name!r} present in baseline but "
                            f"missing from the current run")
            continue
        for k in _exact_keys(base, cur):
            b, c = base.get(k), cur.get(k)
            if b is None or c is None:
                continue
            if c > b:
                failures.append(f"{name}.{k}: {b} -> {c} "
                                f"(+{c - b}; deterministic counter must "
                                f"not regress)")
            elif c < b:
                notes.append(f"{name}.{k}: {b} -> {c} (improved — refresh "
                             f"the baseline to ratchet)")
        for wall_key, bad in WALL_KEYS:
            b, c = base.get(wall_key), cur.get(wall_key)
            if not b:
                continue   # never gated for this variant
            if not c:
                # a gated metric cannot silently vanish or zero out —
                # that's how a broken variant would dodge the gate
                failures.append(
                    f"{name}.{wall_key}: {b} in baseline but "
                    f"{'missing' if c is None else c} in the current "
                    f"run (gated wall metric must keep reporting)")
                continue
            worse = c > b * (1.0 + wall_tol) if bad == "up" \
                else c < b * (1.0 - wall_tol)
            better = c < b * (1.0 - wall_tol) if bad == "up" \
                else c > b * (1.0 + wall_tol)
            if worse:
                failures.append(
                    f"{name}.{wall_key}: {b} -> {c} "
                    f"({abs(c / b - 1) * 100:.0f}% worse > "
                    f"{wall_tol * 100:.0f}% tolerance)")
            elif better:
                notes.append(f"{name}.{wall_key}: {b} -> {c} (improved)")
        for info_key in INFO_WALL_KEYS:
            b, c = base.get(info_key), cur.get(info_key)
            if not b:
                continue
            if not c:
                notes.append(f"{name}.{info_key}: {b} in baseline but "
                             f"{'missing' if c is None else c} in the "
                             f"current run (informational)")
            elif abs(c / b - 1) > wall_tol:
                notes.append(f"{name}.{info_key}: {b} -> {c} "
                             f"({abs(c / b - 1) * 100:.0f}% drift; "
                             f"informational — absolute throughput is "
                             f"not runner-portable)")
    for name in cur_v:
        if name not in base_v:
            notes.append(f"new variant {name!r} not in baseline (not "
                         f"gated; add it on the next baseline refresh)")
    return failures, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--current", default=DEFAULT_CURRENT)
    ap.add_argument("--wall-tol", type=float, default=0.25,
                    help="relative drift tolerated on each WALL_KEYS "
                         "metric in its bad direction (default 0.25 = "
                         "25%%)")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    failures, notes = compare(baseline, current, args.wall_tol)
    for n in notes:
        print(f"[note] {n}")
    for fmsg in failures:
        print(f"[FAIL] {fmsg}")
    if failures:
        print(f"benchmark-regression gate: {len(failures)} failure(s) vs "
              f"{os.path.normpath(args.baseline)}")
        return 1
    print(f"benchmark-regression gate: OK "
          f"({len(baseline.get('variants', {}))} variants vs "
          f"{os.path.normpath(args.baseline)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
