"""Flash attention BACKWARD Pallas kernels + custom-VJP wrapper.

Forward (flash_attention.py) re-exported here with an LSE output; backward
is the standard two-kernel FlashAttention-2 scheme:

  dkv kernel: grid over KV tiles; for each (BLOCK_K, hd) tile, loop the
    query blocks, recompute p = exp(s - lse), accumulate
       dv += pᵀ do
       dp  = do vᵀ ;  ds = p (dp - D)        (D = rowsum(do ∘ o))
       dk += dsᵀ q
  dq kernel: grid over Q tiles; loop KV blocks, accumulate dq += ds k.

All matmuls are MXU-shaped (BLOCK × hd / BLOCK × BLOCK); the softmax is
never materialized beyond one (BLOCK_Q, BLOCK_K) tile in VMEM; causal /
sliding-window masking mirrors the forward with the same block-skipping
bounds.  fp32 accumulation throughout.

``flash_attention_vjp`` is a jax.custom_vjp function validated against
``jax.grad`` of the pure-jnp oracle in tests (interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
BLOCK_Q = 256
BLOCK_K = 256


# --------------------------------------------------------------------------
# forward with LSE residual
# --------------------------------------------------------------------------
def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, block_k: int,
                causal: bool, window: int, seq_len: int):
    qi = pl.program_id(1)
    bq, hd = q_ref.shape
    q = q_ref[...].astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)[:, 0]
    n_kb = seq_len // block_k
    hi = jnp.minimum((qi * bq + bq + block_k - 1) // block_k, n_kb) \
        if causal else n_kb
    lo = jnp.maximum((qi * bq - window) // block_k, 0) if window else 0

    def body(ki, carry):
        acc, m, l = carry
        ks = pl.load(k_ref, (pl.dslice(ki * block_k, block_k),
                             pl.dslice(None))).astype(jnp.float32)
        vs = pl.load(v_ref, (pl.dslice(ki * block_k, block_k),
                             pl.dslice(None))).astype(jnp.float32)
        s = jax.lax.dot_general(q, ks, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)[0]
        d = q_pos[:, None] - k_pos[None, :]
        mask = jnp.ones_like(s, jnp.bool_)
        if causal:
            mask &= d >= 0
        if window:
            mask &= d < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1)
        acc = acc * corr[:, None] + jax.lax.dot_general(
            p, vs, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    init = (jnp.zeros((bq, hd), jnp.float32),
            jnp.full((bq,), NEG_INF, jnp.float32),
            jnp.zeros((bq,), jnp.float32))
    acc, m, l = jax.lax.fori_loop(lo, hi, body, init)
    l_safe = jnp.maximum(l, 1e-30)
    o_ref[...] = (acc / l_safe[:, None]).astype(o_ref.dtype)
    lse_ref[...] = m + jnp.log(l_safe)


# --------------------------------------------------------------------------
# backward kernels
# --------------------------------------------------------------------------
def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, *, block_q: int, causal: bool, window: int,
                seq_len: int):
    ki = pl.program_id(1)
    bk, hd = k_ref.shape
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)[:, 0]
    n_qb = seq_len // block_q
    # causal: only query blocks at/after this kv block see it
    lo = (ki * bk) // block_q if causal else 0
    # window: query blocks beyond k_pos + window see nothing
    hi = jnp.minimum((ki * bk + window + block_q - 1) // block_q + 1,
                     n_qb) if window else n_qb

    def body(qi, carry):
        dk, dv = carry
        qs = pl.load(q_ref, (pl.dslice(qi * block_q, block_q),
                             pl.dslice(None))).astype(jnp.float32)
        dos = pl.load(do_ref, (pl.dslice(qi * block_q, block_q),
                               pl.dslice(None))).astype(jnp.float32)
        lse = pl.load(lse_ref, (pl.dslice(qi * block_q, block_q),))
        delta = pl.load(delta_ref, (pl.dslice(qi * block_q, block_q),))
        s = jax.lax.dot_general(qs, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, 1), 0)[:, 0]
        d = q_pos[:, None] - k_pos[None, :]
        mask = jnp.ones_like(s, jnp.bool_)
        if causal:
            mask &= d >= 0
        if window:
            mask &= d < window
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)  # (bq_, bk)
        dv_new = dv + jax.lax.dot_general(
            p, dos, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(dos, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dk_new = dk + jax.lax.dot_general(
            ds, qs, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return dk_new, dv_new

    init = (jnp.zeros((bk, hd), jnp.float32),
            jnp.zeros((bk, hd), jnp.float32))
    dk, dv = jax.lax.fori_loop(lo, hi, body, init)
    dk_ref[...] = dk.astype(dk_ref.dtype)
    dv_ref[...] = dv.astype(dv_ref.dtype)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               *, block_k: int, causal: bool, window: int, seq_len: int):
    qi = pl.program_id(1)
    bq, hd = q_ref.shape
    q = q_ref[...].astype(jnp.float32)
    do = do_ref[...].astype(jnp.float32)
    lse = lse_ref[...]
    delta = delta_ref[...]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)[:, 0]
    n_kb = seq_len // block_k
    hi = jnp.minimum((qi * bq + bq + block_k - 1) // block_k, n_kb) \
        if causal else n_kb
    lo = jnp.maximum((qi * bq - window) // block_k, 0) if window else 0

    def body(ki, dq):
        ks = pl.load(k_ref, (pl.dslice(ki * block_k, block_k),
                             pl.dslice(None))).astype(jnp.float32)
        vs = pl.load(v_ref, (pl.dslice(ki * block_k, block_k),
                             pl.dslice(None))).astype(jnp.float32)
        s = jax.lax.dot_general(q, ks, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)[0]
        d = q_pos[:, None] - k_pos[None, :]
        mask = jnp.ones_like(s, jnp.bool_)
        if causal:
            mask &= d >= 0
        if window:
            mask &= d < window
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, vs, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        return dq + jax.lax.dot_general(
            ds, ks, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    dq = jax.lax.fori_loop(lo, hi, body, jnp.zeros((bq, hd), jnp.float32))
    dq_ref[...] = dq.astype(dq_ref.dtype)


# --------------------------------------------------------------------------
# custom-vjp wrapper (folded (B*H, S, hd) layout like the forward)
# --------------------------------------------------------------------------
def _fold(x):
    B, S, H, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)


def _unfold(x, B, H):
    BH, S, hd = x.shape
    return x.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


def _fwd(q, k, v, causal, window, interpret):
    B, S, H, hd = q.shape
    bq = min(BLOCK_Q, S)
    bk = min(BLOCK_K, S)
    qf, kf, vf = _fold(q), _fold(k), _fold(v)
    kernel = functools.partial(_fwd_kernel, block_k=bk, causal=causal,
                               window=window, seq_len=S)
    o, lse = pl.pallas_call(
        kernel,
        grid=(B * H, S // bq),
        in_specs=[pl.BlockSpec((None, bq, hd), lambda b, i: (b, i, 0)),
                  pl.BlockSpec((None, S, hd), lambda b, i: (b, 0, 0)),
                  pl.BlockSpec((None, S, hd), lambda b, i: (b, 0, 0))],
        out_specs=[pl.BlockSpec((None, bq, hd), lambda b, i: (b, i, 0)),
                   pl.BlockSpec((None, bq), lambda b, i: (b, i))],
        out_shape=[jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
                   jax.ShapeDtypeStruct((B * H, S), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf)
    return o, lse


def _bwd(q, k, v, o, lse, do, causal, window, interpret):
    B, S, H, hd = q.shape
    bq = min(BLOCK_Q, S)
    bk = min(BLOCK_K, S)
    qf, kf, vf = _fold(q), _fold(k), _fold(v)
    of, dof = _fold(o), _fold(do)
    delta = jnp.sum(of.astype(jnp.float32) * dof.astype(jnp.float32),
                    axis=-1)                       # (BH, S)

    dkv = pl.pallas_call(
        functools.partial(_dkv_kernel, block_q=bq, causal=causal,
                          window=window, seq_len=S),
        grid=(B * H, S // bk),
        in_specs=[pl.BlockSpec((None, S, hd), lambda b, i: (b, 0, 0)),
                  pl.BlockSpec((None, bk, hd), lambda b, i: (b, i, 0)),
                  pl.BlockSpec((None, bk, hd), lambda b, i: (b, i, 0)),
                  pl.BlockSpec((None, S, hd), lambda b, i: (b, 0, 0)),
                  pl.BlockSpec((None, S), lambda b, i: (b, 0)),
                  pl.BlockSpec((None, S), lambda b, i: (b, 0))],
        out_specs=[pl.BlockSpec((None, bk, hd), lambda b, i: (b, i, 0)),
                   pl.BlockSpec((None, bk, hd), lambda b, i: (b, i, 0))],
        out_shape=[jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
                   jax.ShapeDtypeStruct((B * H, S, hd), q.dtype)],
        interpret=interpret,
    )(qf, kf, vf, dof, lse, delta)
    dk, dv = dkv

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, block_k=bk, causal=causal,
                          window=window, seq_len=S),
        grid=(B * H, S // bq),
        in_specs=[pl.BlockSpec((None, bq, hd), lambda b, i: (b, i, 0)),
                  pl.BlockSpec((None, S, hd), lambda b, i: (b, 0, 0)),
                  pl.BlockSpec((None, S, hd), lambda b, i: (b, 0, 0)),
                  pl.BlockSpec((None, bq, hd), lambda b, i: (b, i, 0)),
                  pl.BlockSpec((None, bq), lambda b, i: (b, i)),
                  pl.BlockSpec((None, bq), lambda b, i: (b, i))],
        out_specs=pl.BlockSpec((None, bq, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        interpret=interpret,
    )(qf, kf, vf, dof, lse, delta)
    return (_unfold(dq, B, H), _unfold(dk, B, H), _unfold(dv, B, H))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention_vjp(q, k, v, causal: bool = True, window: int = 0,
                        interpret: bool = True):
    o, _ = _fwd(q, k, v, causal, window, interpret)
    return _unfold(o, q.shape[0], q.shape[2])


def _vjp_fwd(q, k, v, causal, window, interpret):
    o, lse = _fwd(q, k, v, causal, window, interpret)
    return _unfold(o, q.shape[0], q.shape[2]), (q, k, v, o, lse)


def _vjp_bwd(causal, window, interpret, res, g):
    q, k, v, of, lse = res
    o = _unfold(of, q.shape[0], q.shape[2])
    return _bwd(q, k, v, o, lse, g, causal, window, interpret)


flash_attention_vjp.defvjp(_vjp_fwd, _vjp_bwd)
