"""Fused workset-sample kernel: gather-from-ring → dequantize → row-cosine
→ threshold → cotangent-scale in ONE VMEM pass (the local-update hot path,
paper Algorithm 2 over the §3.1 cache).

The unfused composition materializes a full-precision copy of the sampled
ring entry in HBM (``tree_map(lambda b: b[slot], buf)``) and then the
weighting kernel re-reads it — two-plus HBM passes over the cut
statistics, all at fp32.  This kernel reads the sampled rows STRAIGHT out
of the (possibly int8-at-rest) ring and writes only the weights and the
weighted cotangent: one pass, and with the quantized cache over ~4x fewer
bytes.  It runs ``n_local x K`` times per communication round — the
dominant on-device loop once the wire is compressed and pipelined.

Layout decisions for TPU:
  * the dynamic ring slot rides in as a SCALAR-PREFETCH operand
    (``pltpu.PrefetchScalarGridSpec``): the BlockSpec index maps consume it
    before the body runs, so only the selected slot's (BLOCK_B, F) blocks
    are ever DMA'd — the gather happens at the block-fetch level, no
    HBM-side entry copy exists;
  * rows (instances) on the sublane axis, the flattened feature dim on the
    lane axis, NOT tiled (same choice as ``cosine_weight.py``: VFL cut
    tensors are small per instance, a full row fits VMEM) — so the
    int8 cache's one-fp32-scale-per-row dequantizes as a lane broadcast;
  * fp32 compute regardless of storage dtype (int8/bf16 upcast in VMEM);
    the fp32-ring variant reproduces ``cosine_weight._kernel`` bit-for-bit
    (same reduction order over the same blocks — the golden traces pin
    this through the engine).

Oracles: ``kernels.ref.fused_sample_ref`` / ``fused_sample_q8_ref``.
B not divisible by BLOCK_B falls back to the reference composition in the
engine (same rule as the weighting kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .cosine_weight import BLOCK_B, EPS


def _weight_and_scale(a, z, dz, thresh):
    """Shared body: row cosine floored at thresh, cotangent scale.
    All operands (BLOCK_B, F) fp32 in VMEM."""
    num = jnp.sum(a * z, axis=1)             # lane reduction -> (BLOCK_B,)
    den = jnp.sqrt(jnp.sum(a * a, axis=1) * jnp.sum(z * z, axis=1))
    w = num / jnp.maximum(den, EPS)
    w = jnp.where(w < thresh, 0.0, w)
    return w, dz * w[:, None]


def _kernel_f32(slot_ref, a_ref, z_ref, dz_ref, thresh_ref, w_ref, out_ref):
    del slot_ref                             # consumed by the index maps
    a = a_ref[...].astype(jnp.float32)       # (BLOCK_B, F)
    z = z_ref[0].astype(jnp.float32)         # (1, BLOCK_B, F) ring block
    dz = dz_ref[0].astype(jnp.float32)
    w, cot = _weight_and_scale(a, z, dz, thresh_ref[0])
    w_ref[...] = w
    out_ref[...] = cot


def _kernel_q8(slot_ref, a_ref, zq_ref, zs_ref, dzq_ref, dzs_ref,
               thresh_ref, w_ref, out_ref):
    del slot_ref
    a = a_ref[...].astype(jnp.float32)
    z = zq_ref[0].astype(jnp.float32) * zs_ref[0][:, None]    # dequant
    dz = dzq_ref[0].astype(jnp.float32) * dzs_ref[0][:, None]
    w, cot = _weight_and_scale(a, z, dz, thresh_ref[0])
    w_ref[...] = w
    out_ref[...] = cot


def _unpack4(packed):
    """(BLOCK_B, F/2) packed uint8 -> (BLOCK_B, F) fp32 int4 codes, in
    VMEM (byte j: element 2j low nibble, 2j+1 high — the wire codec's
    layout; see ``core.workset.unpack_nibbles``)."""
    lo = (packed & 0xF).astype(jnp.int8) - 8
    hi = (packed >> 4).astype(jnp.int8) - 8
    both = jnp.stack([lo, hi], axis=-1)          # (bb, F/2, 2)
    return both.reshape(packed.shape[0], -1).astype(jnp.float32)


def _kernel_q4(slot_ref, a_ref, zq_ref, zs_ref, dzq_ref, dzs_ref,
               thresh_ref, w_ref, out_ref):
    """int4 ring block: unpack the nibbles in VMEM, dequant against the
    per-row scale, then the shared weight-and-scale body.  No unpacked
    entry ever exists in HBM — the packed bytes are the only ring read."""
    del slot_ref
    a = a_ref[...].astype(jnp.float32)
    z = _unpack4(zq_ref[0]) * zs_ref[0][:, None]
    dz = _unpack4(dzq_ref[0]) * dzs_ref[0][:, None]
    w, cot = _weight_and_scale(a, z, dz, thresh_ref[0])
    w_ref[...] = w
    out_ref[...] = cot


def _call(kernel, slot, operands, ring_specs, B, F, bb, interpret):
    """Common pallas_call plumbing: scalar-prefetch slot + (bb, F) ad-hoc
    blocks + per-ring slot-indexed blocks + (1,) threshold."""
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B // bb,),
        in_specs=[pl.BlockSpec((bb, F), lambda i, s: (i, 0))] + ring_specs +
                 [pl.BlockSpec((1,), lambda i, s: (0,))],
        out_specs=[
            pl.BlockSpec((bb,), lambda i, s: (i,)),
            pl.BlockSpec((bb, F), lambda i, s: (i, 0)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B, F), jnp.float32),
        ],
        interpret=interpret,
    )(slot, *operands)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_sample_2d(slot, ad_hoc, z_ring, dz_ring, cos_xi, *,
                    interpret: bool = True):
    """Full-precision ring.  slot: (1,) int32; ad_hoc: (B, F); z_ring /
    dz_ring: (W, B, F).  -> (weights (B,) f32, weighted cotangent (B, F)
    f32) for the entry at ``slot``."""
    W, B, F = z_ring.shape
    bb = min(BLOCK_B, B)
    assert B % bb == 0, (B, bb)
    thresh = jnp.asarray([cos_xi], jnp.float32)
    ring = [
        pl.BlockSpec((1, bb, F), lambda i, s: (s[0], i, 0)),
        pl.BlockSpec((1, bb, F), lambda i, s: (s[0], i, 0)),
    ]
    return _call(_kernel_f32, slot, (ad_hoc, z_ring, dz_ring, thresh),
                 ring, B, F, bb, interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_sample_q8_2d(slot, ad_hoc, zq, zscale, dzq, dzscale, cos_xi, *,
                       interpret: bool = True):
    """int8-at-rest ring.  zq / dzq: (W, B, F) int8 codes, zscale /
    dzscale: (W, B) fp32 per-row scales.  Same contract as
    :func:`fused_sample_2d`; dequantization happens in VMEM."""
    W, B, F = zq.shape
    bb = min(BLOCK_B, B)
    assert B % bb == 0, (B, bb)
    thresh = jnp.asarray([cos_xi], jnp.float32)
    ring = [
        pl.BlockSpec((1, bb, F), lambda i, s: (s[0], i, 0)),
        pl.BlockSpec((1, bb), lambda i, s: (s[0], i)),
        pl.BlockSpec((1, bb, F), lambda i, s: (s[0], i, 0)),
        pl.BlockSpec((1, bb), lambda i, s: (s[0], i)),
    ]
    return _call(_kernel_q8, slot, (ad_hoc, zq, zscale, dzq, dzscale,
                                    thresh), ring, B, F, bb, interpret)


# --------------------------------------------------------------------------
# Gather → dequant only (no weighting): the serving decode-activation read.
# Same scalar-prefetch gather as the sample kernels — only the selected
# slot's blocks are DMA'd out of the quantized ring — but the body is the
# bare dequant: serving consumes the cached cross-party activation as-is
# (there is no ad-hoc statistic to cosine-weight against at decode time).
# --------------------------------------------------------------------------
def _kernel_dq8(slot_ref, zq_ref, zs_ref, out_ref):
    del slot_ref                             # consumed by the index maps
    out_ref[...] = zq_ref[0].astype(jnp.float32) * zs_ref[0][:, None]


def _kernel_dq4(slot_ref, zq_ref, zs_ref, out_ref):
    del slot_ref
    out_ref[...] = _unpack4(zq_ref[0]) * zs_ref[0][:, None]


def _call_dequant(kernel, slot, operands, ring_specs, B, F, bb, interpret):
    """pallas_call plumbing for the dequant-only kernels: scalar-prefetch
    slot + per-ring slot-indexed blocks -> one (B, F) fp32 output."""
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B // bb,),
        in_specs=ring_specs,
        out_specs=pl.BlockSpec((bb, F), lambda i, s: (i, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, F), jnp.float32),
        interpret=interpret,
    )(slot, *operands)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_dequant_q8_2d(slot, zq, zscale, *, interpret: bool = True):
    """Gather + dequantize ONE int8 ring entry.  slot: (1,) int32; zq:
    (W, B, F) int8 codes, zscale: (W, B) fp32 per-row scales.  -> (B, F)
    fp32 rows of the entry at ``slot``; no full-precision ring copy ever
    exists in HBM."""
    W, B, F = zq.shape
    bb = min(BLOCK_B, B)
    assert B % bb == 0, (B, bb)
    ring = [
        pl.BlockSpec((1, bb, F), lambda i, s: (s[0], i, 0)),
        pl.BlockSpec((1, bb), lambda i, s: (s[0], i)),
    ]
    return _call_dequant(_kernel_dq8, slot, (zq, zscale), ring, B, F, bb,
                         interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_dequant_q4_2d(slot, zq, zscale, *, interpret: bool = True):
    """Gather + unpack + dequantize ONE int4 nibble-packed ring entry.
    zq: (W, B, F // 2) packed uint8, zscale: (W, B) fp32 row scales.
    -> (B, F) fp32 (F = 2 * packed width; the caller slices any pad
    column)."""
    W, B, P = zq.shape
    F = 2 * P
    bb = min(BLOCK_B, B)
    assert B % bb == 0, (B, bb)
    ring = [
        pl.BlockSpec((1, bb, P), lambda i, s: (s[0], i, 0)),
        pl.BlockSpec((1, bb), lambda i, s: (s[0], i)),
    ]
    return _call_dequant(_kernel_dq4, slot, (zq, zscale), ring, B, F, bb,
                         interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_sample_q4_2d(slot, ad_hoc, zq, zscale, dzq, dzscale, cos_xi, *,
                       interpret: bool = True):
    """int4 nibble-packed ring.  zq / dzq: (W, B, F // 2) packed uint8
    (F even — the storage codec pads odd rows; the caller pads ``ad_hoc``
    to match), zscale / dzscale: (W, B) fp32 per-row scales.  Same
    contract as :func:`fused_sample_2d`; unpack + dequant happen in VMEM
    so the packed bytes are the only HBM ring traffic."""
    W, B, P = zq.shape
    F = 2 * P
    assert ad_hoc.shape == (B, F), (ad_hoc.shape, B, F)
    bb = min(BLOCK_B, B)
    assert B % bb == 0, (B, bb)
    thresh = jnp.asarray([cos_xi], jnp.float32)
    ring = [
        pl.BlockSpec((1, bb, P), lambda i, s: (s[0], i, 0)),
        pl.BlockSpec((1, bb), lambda i, s: (s[0], i)),
        pl.BlockSpec((1, bb, P), lambda i, s: (s[0], i, 0)),
        pl.BlockSpec((1, bb), lambda i, s: (s[0], i)),
    ]
    return _call(_kernel_q4, slot, (ad_hoc, zq, zscale, dzq, dzscale,
                                    thresh), ring, B, F, bb, interpret)
