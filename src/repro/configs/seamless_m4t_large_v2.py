"""seamless-m4t-large-v2 — enc-dec multimodal [arXiv:2308.11596].

Read as 24 encoder + 24 decoder layers (DESIGN §3).  The mel+conv audio
codec is a stub: the batch carries precomputed frame embeddings (DESIGN §5).
Party A = audio owner runs the encoder; Party B = text decoder with
per-layer cross-attention."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab_size=256206,
    enc_layers=24, d_frontend=160, audio_downsample=4,
    source="arXiv:2308.11596",
)
