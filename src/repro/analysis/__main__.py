"""CLI: ``python -m repro.analysis`` — run the boundary audit, write
``results/AUDIT.json``, print the human report, exit nonzero on errors.

``XLA_FLAGS`` is set BEFORE jax is first imported (the package
``__init__`` is deliberately jax-free) so the pod audit gets its
2-device CPU mesh even on a single-host runner.
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static boundary audit: information flow, wire "
                    "bytes, kernel contracts.")
    ap.add_argument("--out", default="results/AUDIT.json",
                    help="JSON report path (default: results/AUDIT.json)")
    ap.add_argument("--quick", action="store_true",
                    help="3-case smoke matrix instead of full coverage")
    ap.add_argument("--no-pod", action="store_true",
                    help="skip the 2-device shard_map pod audit")
    ap.add_argument("--selftest", action="store_true",
                    help="run the seeded-mutation self-tests instead of "
                         "the audit (exit 2 if any mutation is missed)")
    ap.add_argument("--verbose", action="store_true",
                    help="print non-error findings too")
    args = ap.parse_args(argv)

    if not args.no_pod and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=2"

    if args.selftest:
        from .selftest import render, run_selftest
        ok, results = run_selftest()
        print(render(results))
        return 0 if ok else 2

    from .audit import default_cases, run_audit
    report = run_audit(default_cases(quick=args.quick),
                       include_pod=not args.no_pod)
    report.write_json(args.out)
    print(report.render(verbose=args.verbose))
    print(f"\nwrote {args.out}")
    return 0 if report.passed else 1


if __name__ == "__main__":
    sys.exit(main())
