"""Mixture-of-Experts FFN with capacity-based dispatch.

TPU-idiomatic dispatch (no ragged ops): top-k routing, position-in-expert via
cumulative one-hot counts, scatter-add into a fixed `(E, C, d)` buffer,
batched-einsum expert FFN, gather-combine.  Everything is per-example
(vmapped over batch) so the dispatch never crosses the `data` sharding axis;
expert weights are sharded according to ``MoEConfig.sharding``:

  * "tp": every device holds a slice of every expert (d_ff/model-axis split);
    dispatch stays local — the baseline strategy, divisible for any E.
  * "ep": experts sharded over the model axis (requires E % mesh_model == 0);
    XLA inserts all-to-all for dispatch/combine — the hillclimb strategy.

The compute is `E*C*d*f` with `E*C ≈ top_k * capacity_factor * S`, i.e.
proportional to *active* experts — keeps MODEL_FLOPS/HLO_FLOPs honest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import MoEConfig
from .initializers import dense_init
from .layers import mlp_init, mlp_apply


def moe_init(rng, d_model: int, d_ff: int, cfg: MoEConfig):
    ks = jax.random.split(rng, 5)
    E = cfg.n_experts
    p = {
        "router": dense_init(ks[0], d_model, E, jnp.float32),
        "wg": jax.vmap(lambda k: dense_init(k, d_model, d_ff))(
            jax.random.split(ks[1], E)),
        "wu": jax.vmap(lambda k: dense_init(k, d_model, d_ff))(
            jax.random.split(ks[2], E)),
        "wd": jax.vmap(lambda k: dense_init(k, d_ff, d_model))(
            jax.random.split(ks[3], E)),
    }
    if cfg.n_shared:
        p["shared"] = mlp_init(ks[4], d_model, d_ff * cfg.n_shared)
    return p


def _capacity(seq: int, cfg: MoEConfig) -> int:
    c = int(cfg.capacity_factor * seq * cfg.top_k / cfg.n_experts)
    return max(4, ((c + 3) // 4) * 4)


def _dispatch_one(x, logits, cfg: MoEConfig, capacity: int):
    """Per-example dispatch.  x: (S, d); logits: (S, E)."""
    S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    gate_logits, idx = jax.lax.top_k(logits, k)            # (S, k)
    gates = jax.nn.softmax(gate_logits, axis=-1)           # renormalized
    # position-in-expert over the flattened (S*k) assignment order
    flat_idx = idx.reshape(-1)                             # (S*k,)
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)  # (S*k, E)
    pos = jnp.cumsum(onehot, axis=0) - 1                   # (S*k, E)
    flat_pos = jnp.take_along_axis(pos, flat_idx[:, None], axis=1)[:, 0]
    keep = flat_pos < capacity
    flat_gates = gates.reshape(-1) * keep
    # scatter tokens into (E, C, d)
    src = jnp.repeat(x, k, axis=0)                         # (S*k, d)
    buf = jnp.zeros((E, capacity, d), x.dtype)
    buf = buf.at[flat_idx, jnp.where(keep, flat_pos, 0)].add(
        src * keep[:, None].astype(x.dtype))
    return buf, flat_idx, flat_pos, flat_gates, keep


def _combine_one(buf_out, flat_idx, flat_pos, flat_gates, keep, S, k):
    y = buf_out[flat_idx, jnp.where(keep, flat_pos, 0)]    # (S*k, d)
    y = y * (flat_gates * keep)[:, None].astype(y.dtype)
    return y.reshape(S, k, -1).sum(axis=1)


def moe_apply(params, x, cfg: MoEConfig):
    """x: (B, S, d) -> (y, aux_loss)."""
    B, S, d = x.shape
    capacity = _capacity(S, cfg)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])

    def one(xb, lb):
        buf, fi, fp, fg, kp = _dispatch_one(xb, lb, cfg, capacity)
        # expert FFN: gated-SiLU per expert
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["wg"])
                        .astype(jnp.float32)).astype(buf.dtype)
        u = jnp.einsum("ecd,edf->ecf", buf, params["wu"])
        out = jnp.einsum("ecf,efd->ecd", g * u, params["wd"])
        return _combine_one(out, fi, fp, fg, kp, S, cfg.top_k)

    y = jax.vmap(one)(x, logits)

    # load-balance auxiliary loss (Switch-style)
    probs = jax.nn.softmax(logits, axis=-1)                # (B,S,E)
    _, top_idx = jax.lax.top_k(logits, cfg.top_k)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_idx, cfg.n_experts, dtype=jnp.float32),
        axis=(0, 1, 2))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = cfg.n_experts * jnp.sum(frac_tokens * mean_prob)

    if "shared" in params:
        y = y + mlp_apply(params["shared"], x)
    return y, cfg.router_aux_coef * aux
