"""Multi-party CELU-VFL: two or more feature parties (the paper's footnote
1 and §6 explicitly defer this — "our work can be generalized to two or
more Party A's easily ... we would like to leave the extension to
multi-party VFL training as our future work").

Setting: K feature parties A_1..A_K (disjoint feature sets, no labels) and
one Party B (features + labels).  Each round:

  * every A_i computes and sends Z_i; B returns ∇Z_i  (K uplinks + K
    downlinks — the WAN cost now scales with K, making the paper's
    round-reduction MORE valuable, not less);
  * all parties take the fresh SGD step;
  * each A_i runs R local updates from its OWN workset (cached
    ⟨Z_i, ∇Z_i, X_i⟩), with Algorithm-2 weighting on cos(Z_i^(j), Z_i);
  * B runs R local updates from its workset (cached ⟨{Z_i}, {∇Z_i}, X_B,
    y⟩), weighting each instance by the MINIMUM per-party derivative
    cosine — an instance is only trusted if it is fresh w.r.t. EVERY
    party's cut tensor (conservative composition of the paper's
    heuristic).

The task interface generalizes :class:`repro.core.protocol.VFLTask`:

    forward_a(params_a_i, batch_a_i) -> Z_i           (same fn, vmapped-by-list)
    loss_b(params_b, [Z_1..Z_K], batch_b) -> (per-instance loss, aux)
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import CELUConfig
from ..optim import Optimizer, apply_updates
from .weighting import instance_weights, xi_to_cos
from .workset import workset_init, workset_insert, workset_sample


class MultiVFLTask(NamedTuple):
    forward_a: Callable[[Any, Dict[str, Any]], jnp.ndarray]
    loss_b: Callable[[Any, Sequence[jnp.ndarray], Dict[str, Any]],
                     Tuple[jnp.ndarray, jnp.ndarray]]


def _bcast(w, like):
    return w.reshape(w.shape + (1,) * (like.ndim - 1)).astype(jnp.float32)


def init_state(task: MultiVFLTask, params: Dict[str, Any], opt: Optimizer,
               celu: CELUConfig, batches_a: List[Dict[str, Any]],
               batch_b: Dict[str, Any]):
    """params = {"a": [pa_1..pa_K], "b": pb}."""
    K = len(params["a"])
    zs = [jax.eval_shape(task.forward_a, params["a"][i], batches_a[i])
          for i in range(K)]
    z_like = [jnp.zeros(z.shape, z.dtype) for z in zs]
    ws_a = [workset_init(celu.W, {"z": z_like[i], "dz": z_like[i],
                                  "batch": batches_a[i]})
            for i in range(K)]
    ws_b = workset_init(celu.W, {"z": z_like, "dz": z_like,
                                 "batch": batch_b})
    return {
        "params": params,
        "opt": {"a": [opt.init(p) for p in params["a"]],
                "b": opt.init(params["b"])},
        "ws": {"a": ws_a, "b": ws_b},
        "comm_rounds": jnp.int32(0),
    }


def make_round(task: MultiVFLTask, opt: Optimizer, celu: CELUConfig,
               *, local_steps: int = -1, jit: bool = True):
    """fn(state, batches_a: list, batch_b, batch_idx) -> (state, metrics)."""
    n_local = celu.R if local_steps < 0 else local_steps
    cos_xi = xi_to_cos(celu.xi_degrees)

    def exchange(state, batches_a, batch_b, batch_idx):
        pas, pb = state["params"]["a"], state["params"]["b"]
        K = len(pas)
        zs, vjps = [], []
        for i in range(K):
            z, vjp = jax.vjp(
                lambda p, i=i: task.forward_a(p, batches_a[i]), pas[i])
            zs.append(z)
            vjps.append(vjp)

        def mean_loss(p, z_list):
            li, aux = task.loss_b(p, z_list, batch_b)
            return jnp.mean(li) + aux
        loss = mean_loss(pb, zs)
        g_b = jax.grad(mean_loss)(pb, zs)
        dzs = jax.grad(lambda z_list: mean_loss(pb, z_list))(zs)

        new_pas, new_opt_a = [], []
        for i in range(K):
            (g_a,) = vjps[i](dzs[i].astype(zs[i].dtype))
            upd, oa = opt.update(g_a, state["opt"]["a"][i], pas[i])
            new_pas.append(apply_updates(pas[i], upd))
            new_opt_a.append(oa)
        upd_b, ob = opt.update(g_b, state["opt"]["b"], pb)

        ws_a = [workset_insert(state["ws"]["a"][i],
                               {"z": zs[i], "dz": dzs[i],
                                "batch": batches_a[i]}, batch_idx)
                for i in range(K)]
        ws_b = workset_insert(state["ws"]["b"],
                              {"z": zs, "dz": dzs, "batch": batch_b},
                              batch_idx)
        state = {
            "params": {"a": new_pas, "b": apply_updates(pb, upd_b)},
            "opt": {"a": new_opt_a, "b": ob},
            "ws": {"a": ws_a, "b": ws_b},
            "comm_rounds": state["comm_rounds"] + 1,
        }
        return state, loss

    def local_step_a(i, pa, oa, ws):
        ws, e, _, valid = workset_sample(ws, celu.R, celu.sampling)
        z_new, vjp = jax.vjp(lambda p: task.forward_a(p, e["batch"]), pa)
        if celu.weighting:
            w = instance_weights(z_new, e["z"], cos_xi)
        else:
            w = jnp.ones((z_new.shape[0],), jnp.float32)
        w = w * valid.astype(jnp.float32)
        (g,) = vjp((_bcast(w, z_new) * e["dz"].astype(jnp.float32))
                   .astype(z_new.dtype))
        upd, oa = opt.update(g, oa, pa)
        upd = jax.tree_util.tree_map(
            lambda u: u * valid.astype(jnp.float32), upd)
        return apply_updates(pa, upd), oa, ws

    def local_step_b(pb, ob, ws):
        ws, e, _, valid = workset_sample(ws, celu.R, celu.sampling)
        zs, dzs, batch_b = e["z"], e["dz"], e["batch"]
        if celu.weighting:
            dz_new = jax.grad(lambda z_list: jnp.mean(
                task.loss_b(pb, z_list, batch_b)[0]))(
                [z.astype(jnp.float32) for z in zs])
            # conservative composition: trust an instance only if it is
            # fresh w.r.t. EVERY party's derivative direction
            w = jnp.ones((zs[0].shape[0],), jnp.float32)
            for i in range(len(zs)):
                w = jnp.minimum(w, instance_weights(dz_new[i], dzs[i],
                                                    cos_xi))
        else:
            w = jnp.ones((zs[0].shape[0],), jnp.float32)
        w = w * valid.astype(jnp.float32)

        def weighted(p):
            li, aux = task.loss_b(p, zs, batch_b)
            return jnp.mean(w * li) + aux
        g = jax.grad(weighted)(pb)
        upd, ob = opt.update(g, ob, pb)
        upd = jax.tree_util.tree_map(
            lambda u: u * valid.astype(jnp.float32), upd)
        return apply_updates(pb, upd), ob, ws

    def round_fn(state, batches_a, batch_b, batch_idx):
        state, loss = exchange(state, batches_a, batch_b, batch_idx)
        K = len(state["params"]["a"])
        for _ in range(n_local):   # unrolled: K small, R small
            pas, oas, wsa = state["params"]["a"], state["opt"]["a"], \
                state["ws"]["a"]
            new = [local_step_a(i, pas[i], oas[i], wsa[i])
                   for i in range(K)]
            pb, ob, wsb = local_step_b(state["params"]["b"],
                                       state["opt"]["b"], state["ws"]["b"])
            state = {
                "params": {"a": [n[0] for n in new], "b": pb},
                "opt": {"a": [n[1] for n in new], "b": ob},
                "ws": {"a": [n[2] for n in new], "b": wsb},
                "comm_rounds": state["comm_rounds"],
            }
        return state, {"loss": loss}

    return jax.jit(round_fn) if jit else round_fn
