"""Chaos lane: celu convergence under the seeded fault matrix.

The claim under test — the whole reason CELU's cached local updates
exist — is that a slow, UNRELIABLE WAN degrades training gracefully:
with one party dropped for 5 consecutive rounds mid-training,
heterogeneous per-party links, and 5% exchange loss (with bounded
retry), the celu preset must still reach the fault-free run's target
loss within ``SLACK_X`` (1.5x) the fault-free rounds-to-target.  The
faulted leg is therefore given ``SLACK_X * rounds`` scheduler rounds —
the budget the gate promises — and rounds-to-target is charged in
*scheduler* rounds, so stalled dispatches (a straggler's lost batches)
count against the faulted run.  The study also
re-checks checkpointed recovery END TO END: a chaos run interrupted at
the midpoint and restored into a fresh engine must finish bit-identical
to the uninterrupted one.

Writes ``results/BENCH_chaos.json``; ``--check`` exits non-zero when the
convergence ratio or the bit-consistency check fails (the nightly CI
gate).
"""
from __future__ import annotations

import json
import os

from repro.configs.base import DropoutSpan, FaultPlan
from repro.core.faults import FaultSchedule
from repro.launch.wan import (clocks_from_plan, hetero_wire_seconds,
                              retry_exchange_seconds,
                              transport_party_updown)

from .common import (csv_row, default_workload, rounds_to_loss,
                     run_protocol, smoothed)
from .end_to_end import LR

ROUNDS = 400
SLACK_X = 1.5           # faulted rounds-to-target budget vs fault-free
BENCH_CHAOS = os.path.join(os.path.dirname(__file__), "..", "results",
                           "BENCH_chaos.json")

# the acceptance fault matrix: 5% per-attempt loss with two retries, a
# light straggler tail, party a0 dark for 5 consecutive rounds at
# mid-training, and a0 on a link ~3x slower than b's side default
FAULT_PLAN = FaultPlan(
    seed=7, drop_prob=0.05, max_retries=2, retry_backoff_s=0.5,
    straggler_prob=0.1, straggler_rounds=2,
    dropouts=(DropoutSpan(party="a0", start=ROUNDS // 2, rounds=5),),
    party_clocks=((12.5e6, 12.5e6, 0.02),),   # 100 Mbps, 20 ms legs
)


def _sched_round(losses, n_finite) -> "int | None":
    """1-based scheduler-round index of the ``n_finite``-th finite loss.

    At depth >= 1 a stalled round reports a non-finite loss (no merge
    ran), which ``smoothed`` drops — so ``rounds_to_loss`` counts
    *merged* rounds.  The gate converts back to the raw schedule
    position to charge stalls at their real cost."""
    import numpy as np
    if n_finite is None:
        return None
    seen = 0
    for i, x in enumerate(losses):
        if np.isfinite(x):
            seen += 1
            if seen == n_finite:
                return i + 1
    return None


def _wire_seconds(plan: FaultPlan, telemetry, transport, z_shapes) -> dict:
    """Price the faulted run on the plan's heterogeneous links: replay
    the deterministic fate sequence and charge every attempt (plus
    backoff waits) at the slowest party's drain rate."""
    K = len(z_shapes)
    clocks = clocks_from_plan(plan, K)
    updown = transport_party_updown(transport, z_shapes)
    sched = FaultSchedule(plan)
    total = 0.0
    for t in range(telemetry["rounds"]):
        if plan.down_parties(t):
            continue                       # no exchange leaves the box
        fate = sched.exchange_fate(t)
        total += retry_exchange_seconds(clocks, updown,
                                        attempts=fate.attempts,
                                        backoff_s=plan.retry_backoff_s)
    return {"wire_seconds": round(total, 2),
            "per_exchange_seconds": round(
                hetero_wire_seconds(clocks, updown), 4)}


def _checkpoint_consistency(plan: FaultPlan, rounds: int = 24) -> bool:
    """Mini end-to-end recovery drill: run the chaos engine, snapshot at
    the midpoint, restore into a FRESH engine, and require the finished
    params to match the uninterrupted run bit-for-bit."""
    import tempfile

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import checkpoint as ckpt
    from repro.configs.base import CELUConfig
    from repro.core import engine
    from repro.core.faults import ChaosEngine
    from repro.data import synthetic as synth
    from repro.models.tabular import make_dlrm
    from repro.optim import make_optimizer

    spec, data, cfg = default_workload("wdl", "criteo")
    init_fn, task, _ = make_dlrm(cfg)
    base = CELUConfig(R=3, W=3, xi_degrees=60.0)
    ccfg, nloc = engine.preset_config("celu", base)
    opt = make_optimizer("adagrad", LR)
    asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
    etask = engine.lift_two_party(task)

    def build():
        params = init_fn(jax.random.PRNGKey(0), cfg)
        tp = engine.make_transport(ccfg, "topk_int8")
        it = synth.aligned_batches(data["train"], 256, seed=0)
        _, ba, bb = next(it)
        state = engine.init_state(
            etask, engine.lift_two_party_params(params), opt, ccfg,
            [asj(ba)], asj(bb), transport=tp)
        pe = ChaosEngine(etask, opt, ccfg, plan=plan, depth=2,
                         local_steps=nloc, transport=tp)
        return pe, pe.init(state), synth.aligned_batches(
            data["train"], 256, seed=0)

    def drive(pe, rs, it, n):
        for _ in range(n):
            bi, ba, bb = next(it)
            rs, _ = pe.step(rs, [asj(ba)], asj(bb), bi)
        return rs

    half = rounds // 2
    pe0, rs0, it0 = build()
    rs0 = drive(pe0, rs0, it0, half)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "chaos.npz")
        ckpt.save_round_state(path, rs0, extra=pe0.host_state())
        rs0 = drive(pe0, rs0, it0, rounds - half)
        rs0, _ = pe0.flush(rs0)
        ref = pe0.finalize(rs0)

        n_pend = ckpt.peek_pending_len(path)
        pe1, rs_ref, it1 = build()
        for _ in range(n_pend):
            bi, ba, bb = next(it1)
            rs_ref = pe1.dispatch(rs_ref, [asj(ba)], asj(bb), bi)
        # NB: a direct dispatch() does not grow the host arrival lists —
        # the extra-reference must be sized to the checkpoint explicitly
        host_ref = {"now": 0, "dispatch_seq": 0,
                    "arrival": [0] * n_pend,
                    "dispatch_round": [0] * n_pend,
                    "last_merged_dispatch": 0}
        rs1, host = ckpt.restore_round_state(
            path, rs_ref, extra_reference=host_ref)
        pe1.load_host_state(host)
        for _ in range(half - n_pend):     # reposition at batch `half`
            next(it1)
        rs1 = drive(pe1, rs1, it1, rounds - half)
        rs1, _ = pe1.flush(rs1)
        got = pe1.finalize(rs1)
    for a, b in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(got)):
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            return False
    return True


def chaos_study(rounds: int = ROUNDS, check: bool = False,
                out: str = BENCH_CHAOS) -> dict:
    import dataclasses
    spec, data, cfg = default_workload("wdl", "criteo")
    plan = dataclasses.replace(
        FAULT_PLAN,
        dropouts=(DropoutSpan(party="a0", start=rounds // 2, rounds=5),))
    csv_row(f"# chaos lane: celu R=5 W=5 on wdl/criteo, {rounds} rounds "
            f"(faulted budget {int(rounds * SLACK_X)}), "
            f"seed={plan.seed} drop={plan.drop_prob} "
            f"retries={plan.max_retries} straggler={plan.straggler_prob} "
            f"dropout=a0@{rounds // 2}x5")
    f_rounds = int(rounds * SLACK_X)   # the budget the gate promises
    clean = run_protocol("celu", data, cfg, R=5, W=5, xi=60.0,
                         rounds=rounds, lr=LR, eval_every=50,
                         pipeline_depth=1)
    faulted = run_protocol("celu", data, cfg, R=5, W=5, xi=60.0,
                           rounds=f_rounds, lr=LR, eval_every=50,
                           pipeline_depth=1, fault_plan=plan)
    base_smooth = smoothed(clean["loss_curve"])
    target = round(base_smooth[-1] * 1.02, 6)
    r_clean = _sched_round(clean["loss_curve"],
                           rounds_to_loss(base_smooth, target))
    r_fault_merged = rounds_to_loss(smoothed(faulted["loss_curve"]),
                                     target)
    r_fault = _sched_round(faulted["loss_curve"], r_fault_merged)
    reached = r_fault is not None and r_clean is not None
    ratio = round(r_fault / r_clean, 3) if reached else None
    tele = dict(faulted["fault_telemetry"])
    events = tele.pop("events")
    wire = _wire_seconds(plan, tele, *_transport_geom(cfg, data))
    ckpt_ok = _checkpoint_consistency(plan)
    csv_row("run", "rounds_to_target", "ratio_vs_clean", "final_auc",
            "drops", "stalls", "stalled_dispatches",
            "ckpt_bit_consistent")
    csv_row("fault-free", r_clean, "1.0x", f"{clean['final_auc']:.4f}",
            0, 0, 0, "-")
    csv_row("faulted", r_fault, f"{ratio}x" if reached else "miss",
            f"{faulted['final_auc']:.4f}", tele["drops"], tele["stalls"],
            tele["stalled_dispatches"], ckpt_ok)
    result = {
        "geometry": {"model": "wdl", "dataset": "criteo", "R": 5, "W": 5,
                     "rounds": rounds, "faulted_rounds": f_rounds,
                     "lr": LR, "batch": 256,
                     "pipeline_depth": 1, "n_train": spec.n_train},
        "fault_plan": {
            "seed": plan.seed, "drop_prob": plan.drop_prob,
            "max_retries": plan.max_retries,
            "retry_backoff_s": plan.retry_backoff_s,
            "straggler_prob": plan.straggler_prob,
            "straggler_rounds": plan.straggler_rounds,
            "dropouts": [[d.party, d.start, d.rounds]
                         for d in plan.dropouts],
            "party_clocks": plan.party_clocks,
        },
        "target_loss": target,
        "clean": {"rounds_to_target": r_clean,
                  "final_auc": round(clean["final_auc"], 4)},
        "faulted": {"rounds_to_target": r_fault,
                    "merged_rounds_to_target": r_fault_merged,
                    "reached_target": reached,
                    "ratio_vs_clean": ratio,
                    "slack_budget": SLACK_X,
                    "final_auc": round(faulted["final_auc"], 4),
                    "bytes_total": faulted["bytes_total"],
                    "telemetry": tele,
                    "n_events": len(events),
                    "wan": wire},
        "checkpoint_bit_consistent": ckpt_ok,
    }
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    csv_row(f"# wrote {os.path.normpath(out)}")
    failures = []
    if not reached:
        failures.append(f"faulted run never reached the fault-free "
                        f"target loss {target}")
    elif ratio > SLACK_X:
        failures.append(f"rounds-to-target ratio {ratio} exceeds the "
                        f"{SLACK_X}x budget")
    if not ckpt_ok:
        failures.append("checkpoint restore diverged from the "
                        "uninterrupted run")
    if failures:
        csv_row("# CHAOS GATE FAILED: " + "; ".join(failures))
        if check:
            raise SystemExit("chaos lane: " + "; ".join(failures))
    return result


def _transport_geom(cfg, data):
    """(transport, z_shapes) the convergence runs used — for pricing."""
    from repro.configs.base import CELUConfig
    from repro.core import engine
    ccfg, _ = engine.preset_config("celu", CELUConfig(R=5, W=5,
                                                      xi_degrees=60.0))
    tp = engine.make_transport(ccfg, None)
    return tp, [(256, cfg.z_dim)]


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when the 1.5x convergence gate "
                         "or the checkpoint bit-consistency drill fails")
    args = ap.parse_args(argv)
    chaos_study(rounds=args.rounds, check=args.check)


if __name__ == "__main__":
    main()
