"""LLM-geometry memory budget + throughput -> ``results/BENCH_llm.json``.

The CELU engine at REAL model geometry: what does one party's device
actually hold?  Three sections:

  * **memory** — exact per-party HBM budgets (params + optimizer state +
    workset cache) at FULL geometry — smollm-360m and the
    granite-moe-3b-a800m MoE at the paper-shape ``train_4k`` batch —
    for the at-rest ladder fp32/fp32 → bf16/bf16 → int8/int8 →
    int4-cache/int8-opt.  Computed by ``launch.budget`` entirely under
    ``jax.eval_shape`` (the 3B MoE is never materialized), so every
    counter is an exact, machine-independent function of the code and
    the benchmark-regression gate (``benchmarks.compare``) fails on ANY
    byte increase.  The headline ratio — combined cache+opt-state fp32
    over int4/int8 — is the PR's claim and must stay >= 2x (``--check``).
  * **throughput** — ``indicative_cpu_tokens_per_sec`` of the reduced
    smollm config through the full protocol stack, fp32/fp32 vs
    int4-cache/int8-opt.  A CPU wall number from interpreted Pallas
    kernels: the ``indicative_`` prefix marks it excluded from the
    ``benchmarks.compare`` regression gate by contract — it is not a
    throughput claim.
  * **convergence** — the paper workload (wdl-criteo, celu preset):
    the int4-cache + int8-opt-state run must reach the fp32-cache run's
    smoothed target loss within the same round budget.  Skipped under
    ``--reduced`` (the CI fast lane); the nightly lane runs it with
    ``--check``.

    PYTHONPATH=src python -m benchmarks.llm [--reduced] [--check]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from .common import csv_row

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "BENCH_llm.json")

ARCHS = ("smollm-360m", "granite-moe-3b-a800m")
# (variant name, cache_dtype, opt_state_dtype) — the at-rest ladder
VARIANTS = (
    ("fp32_fp32", "float32", "float32"),
    ("bf16_bf16", "bfloat16", "bfloat16"),
    ("int8_int8", "int8", "int8"),
    ("int4_int8", "int4", "int8"),
)
# full geometry: the paper-shape train batch (configs.base.TRAIN_4K)
FULL_B, FULL_S, W = 256, 4096, 5
MIN_COMBINED_REDUCTION = 2.0      # the --check floor on cache+opt bytes

# throughput leg (reduced smollm on CPU)
TP_B, TP_S, TP_ROUNDS, TP_WARMUP = 8, 32, 6, 2

# convergence leg (paper workload; nightly)
CONV_ROUNDS, CONV_SLACK = 300, 1.02


# --------------------------------------------------------------------------
# Section 1: exact per-party HBM at full geometry (eval_shape only)
# --------------------------------------------------------------------------
def memory_table():
    from repro.configs import get_config
    from repro.launch.budget import party_hbm_budget

    variants, ratios = {}, {}
    csv_row(f"# per-party HBM at full geometry (B={FULL_B} S={FULL_S} "
            f"W={W}; exact, eval_shape — nothing materialized)")
    csv_row("arch/variant", "params_a_GiB", "opt_a_GiB", "cache_a_GiB",
            "total_a_GiB", "total_b_GiB")
    gb = 1024 ** 3
    for arch in ARCHS:
        cfg = get_config(arch)
        for name, cd, od in VARIANTS:
            row = party_hbm_budget(cfg, batch_size=FULL_B, seq_len=FULL_S,
                                   W=W, cache_dtype=cd, opt_state_dtype=od)
            row["cache_dtype"] = cd
            row["opt_state_dtype"] = od
            variants[f"{arch}/{name}"] = row
            csv_row(f"{arch}/{name}",
                    round(row["params_bytes_a"] / gb, 3),
                    round(row["opt_state_bytes_a"] / gb, 3),
                    round(row["cache_bytes_a"] / gb, 3),
                    round(row["hbm_total_bytes_a"] / gb, 3),
                    round(row["hbm_total_bytes_b"] / gb, 3))
        # the PR claim: combined cache + opt-state bytes, fp32/fp32 over
        # int4-cache/int8-opt (party A — the feature party the paper
        # scales out; party B's ratio is within rounding of it)
        base = variants[f"{arch}/fp32_fp32"]
        best = variants[f"{arch}/int4_int8"]
        num = base["cache_bytes_a"] + base["opt_state_bytes_a"]
        den = best["cache_bytes_a"] + best["opt_state_bytes_a"]
        ratios[f"{arch}_cache_plus_opt_fp32_over_int4_int8"] = \
            round(num / den, 3)
    return variants, ratios


# --------------------------------------------------------------------------
# Section 2: measured tokens/sec (reduced geometry; indicative)
# --------------------------------------------------------------------------
def _throughput_one(cache_dtype: str, opt_state_dtype: str):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.configs.base import CELUConfig
    from repro.core import engine
    from repro.data import synthetic as synth
    from repro.launch.train import llm_task
    from repro.models import vfl
    from repro.optim import make_optimizer

    cfg = get_config("smollm-360m").reduced()
    data = synth.make_token_stream(max(TP_B * 8, 64), TP_S,
                                   cfg.vocab_size, cfg.aux_vocab_size,
                                   seed=0)
    task = llm_task(cfg)
    celu, n_local = engine.preset_config(
        "celu", CELUConfig(R=3, W=3, cache_dtype=cache_dtype))
    params = vfl.init_all(jax.random.PRNGKey(0), cfg)
    opt_kw = {} if opt_state_dtype == "float32" \
        else {"state_dtype": opt_state_dtype}
    opt = make_optimizer("adagrad", 0.01, **opt_kw)
    it = synth.token_batches(data, TP_B, seed=0)
    _, ba0, bb0 = next(it)
    asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
    etask = engine.lift_two_party(task)
    state = engine.init_state(etask, engine.lift_two_party_params(params),
                              opt, celu, [asj(ba0)], asj(bb0))
    rnd = engine.make_round(etask, opt, celu, local_steps=n_local)
    it = synth.token_batches(data, TP_B, seed=0)
    losses, t0 = [], None
    for i in range(TP_WARMUP + TP_ROUNDS):
        bi, ba, bb = next(it)
        state, m = rnd(state, [asj(ba)], asj(bb), bi)
        losses.append(float(m["loss"]))
        if i + 1 == TP_WARMUP:
            t0 = time.time()
    wall = time.time() - t0
    return {
        "cache_dtype": cache_dtype,
        "opt_state_dtype": opt_state_dtype,
        # "indicative_" prefix = benchmarks.compare skips it by contract:
        # a CPU wall number from interpreted Pallas kernels is not a
        # throughput claim and must never gate (or pass for) real tok/s
        "indicative_cpu_tokens_per_sec": round(
            TP_ROUNDS * TP_B * TP_S / wall, 1),
        "round_ms": round(wall / TP_ROUNDS * 1e3, 1),
        "loss_first": round(losses[0], 4),
        "loss_last": round(losses[-1], 4),
    }


def throughput_table():
    csv_row(f"# indicative CPU tokens/sec, reduced smollm (B={TP_B} "
            f"S={TP_S}; CPU wall, Pallas interpreted — NOT a throughput "
            f"claim, excluded from the regression gate)")
    csv_row("variant", "indicative_cpu_tokens_per_sec", "round_ms",
            "loss_first", "loss_last")
    out = {}
    for name, cd, od in (("fp32_fp32", "float32", "float32"),
                         ("int4_int8", "int4", "int8")):
        r = _throughput_one(cd, od)
        out[name] = r
        csv_row(name, r["indicative_cpu_tokens_per_sec"], r["round_ms"],
                r["loss_first"], r["loss_last"])
    return {"geometry": {"arch": "smollm-360m-smoke", "B": TP_B, "S": TP_S,
                         "rounds": TP_ROUNDS}, "variants": out}


# --------------------------------------------------------------------------
# Section 3: convergence on the paper workload (nightly)
# --------------------------------------------------------------------------
def convergence_table(rounds: int = CONV_ROUNDS):
    from .common import default_workload, rounds_to_loss, run_protocol, \
        smoothed

    _, data, cfg = default_workload()
    legs = {}
    for name, cd, od in (("fp32_fp32", "float32", "float32"),
                         ("int4_int8", "int4", "int8")):
        legs[name] = run_protocol("celu", data, cfg, rounds=rounds,
                                  cache_dtype=cd, opt_state_dtype=od)
    base_smooth = smoothed(legs["fp32_fp32"]["loss_curve"])
    target = round(base_smooth[-1] * CONV_SLACK, 6)
    q_smooth = smoothed(legs["int4_int8"]["loss_curve"])
    r2t = rounds_to_loss(q_smooth, target)
    out = {"rounds": rounds, "target_loss": target,
           "fp32_final_smoothed": round(base_smooth[-1], 6),
           "int4_final_smoothed": round(q_smooth[-1], 6),
           "int4_rounds_to_target": r2t,
           "int4_reached_target": r2t is not None,
           "fp32_final_auc": legs["fp32_fp32"]["final_auc"],
           "int4_final_auc": legs["int4_int8"]["final_auc"]}
    csv_row("# convergence (wdl-criteo, celu): int4 cache + int8 opt "
            "state vs the fp32-cache target loss")
    csv_row("target", "fp32_smoothed", "int4_smoothed", "int4_r2t")
    csv_row(target, out["fp32_final_smoothed"], out["int4_final_smoothed"],
            r2t)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reduced", action="store_true",
                    help="CI fast lane: skip the convergence study (the "
                         "memory section is always full-geometry — it is "
                         "analytic and costs a trace)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when the combined cache+opt-state "
                         f"reduction drops below "
                         f"{MIN_COMBINED_REDUCTION}x or (full mode) the "
                         "int4-cache run misses the fp32 target loss")
    args = ap.parse_args(argv)

    variants, ratios = memory_table()
    throughput = throughput_table()
    convergence = None if args.reduced else convergence_table()
    out = {
        "geometry": {"B": FULL_B, "S": FULL_S, "W": W, "archs": list(ARCHS)},
        "variants": variants,
        "ratios": ratios,
        "throughput": throughput,
        "convergence": convergence,
    }
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    csv_row("# ratios: " + ", ".join(f"{k}={v}" for k, v in ratios.items()))
    csv_row(f"# wrote {os.path.normpath(RESULTS)}")

    if args.check:
        fails = [f"{k} = {v} < {MIN_COMBINED_REDUCTION}x"
                 for k, v in ratios.items() if v < MIN_COMBINED_REDUCTION]
        if convergence is not None and not convergence["int4_reached_target"]:
            fails.append(
                f"int4_int8 never reached the fp32 target loss "
                f"{convergence['target_loss']} (final smoothed "
                f"{convergence['int4_final_smoothed']})")
        for fmsg in fails:
            print(f"[FAIL] {fmsg}")
        if fails:
            return 1
        print("llm geometry gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
