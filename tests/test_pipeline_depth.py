"""The depth-D exchange queue (PR 5 tentpole): scheduling, per-slot
staleness plumbing, and staleness-aware damping.

Depths 0 and 1 stay on the static golden-pinned path (covered by
``test_pipeline.py``); everything here exercises the D >= 2 surface —
queue order and merge determinism, the traced per-slot staleness offsets
reaching ``workset_draw``/``workset_sample`` and the fused kernels'
post-scale, the lr-damping schedule ``eta / (1 + c*s)``, and the
capacity/validation guards.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CELUConfig
from repro.core import engine
from repro.core.workset import (workset_draw, workset_init, workset_insert,
                                workset_sample)
from repro.data.synthetic import aligned_batches
from repro.models.tabular import make_dlrm
from repro.optim import make_optimizer

from test_pipeline import _run_pipelined, _workload


def _drive(depth, rounds=20, *, W=5, R=3, damping=0.25, lr=0.05,
           sampling="round_robin", compression=None):
    """Like test_pipeline._run_pipelined but with a W wide enough for deep
    queues and exposed damping/sampling/compression knobs.  Returns
    (metric rows, final engine state)."""
    data, cfg = _workload()
    init_fn, task, _ = make_dlrm(cfg)
    ccfg = CELUConfig(R=R, W=W, xi_degrees=60.0, sampling=sampling,
                      pipeline_lr_damping=damping)
    params = init_fn(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adagrad", lr)
    it = aligned_batches(data["train"], 64, seed=0)
    _, ba, bb = next(it)
    asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
    kw = {} if compression is None else \
        {"transport": engine.make_transport(ccfg, compression)}
    etask = engine.lift_two_party(task)
    state = engine.init_state(etask, engine.lift_two_party_params(params),
                              opt, ccfg, [asj(ba)], asj(bb), **kw)
    pe = engine.make_pipeline(etask, opt, ccfg, depth=depth, **kw)
    rs = pe.init(state)
    it = aligned_batches(data["train"], 64, seed=0)
    rows = []
    for i in range(rounds):
        bi, ba, bb = next(it)
        rs, m = pe.step(rs, [asj(ba)], asj(bb), bi)
        rows.append({"loss": float(np.float32(m["loss"])),
                     "w_mean": float(np.float32(m["w_mean"])),
                     "local_steps": int(m["local_steps"])})
    rs, _ = pe.flush(rs)
    st = pe.finalize(rs)
    rows.append({"steps_a": int(st["steps"]["a"][0]),
                 "steps_b": int(st["steps"]["b"]),
                 "comm_rounds": int(st["comm_rounds"])})
    return rows, st


def _rows_equal(a, b):
    """Row-list equality where NaN == NaN (the warmup losses)."""
    if len(a) != len(b):
        return False
    for ra, rb in zip(a, b):
        if ra.keys() != rb.keys():
            return False
        for k in ra:
            x, y = ra[k], rb[k]
            if isinstance(x, float) and math.isnan(x):
                if not (isinstance(y, float) and math.isnan(y)):
                    return False
            elif x != y:
                return False
    return True


# --------------------------------------------------------------------------
# Scheduling: queue fill, merge order, determinism, accounting
# --------------------------------------------------------------------------
@pytest.mark.parametrize("depth", [2, 4])
def test_depthD_queue_fill_and_step_accounting(depth):
    """The first D-1 steps only fill the queue (NaN loss, no merge); after
    the flush every dispatched exchange has been merged and every funded
    local scan has run."""
    rounds, R = 24, 3
    rows, _ = _drive(depth, rounds=rounds, R=R)
    # warmup: no merge -> NaN loss for exactly the first D-1 rounds
    for i in range(depth - 1):
        assert math.isnan(rows[i]["loss"]), (depth, i)
    assert not math.isnan(rows[depth - 1]["loss"])
    tail = rows[-1]
    assert tail["comm_rounds"] == rounds
    assert rounds < tail["steps_a"] <= rounds * (1 + R)
    assert rounds < tail["steps_b"] <= rounds * (1 + R)
    # the queue starts empty: round 0's scan is a full bubble
    assert rows[0]["local_steps"] == 0


@pytest.mark.parametrize("depth", [2, 4])
def test_depthD_deterministic(depth):
    """Two identical drives produce identical traces — the queue schedule
    (dispatch seq numbers, merge order, per-slot staleness) is pure."""
    a, _ = _drive(depth, rounds=16)
    b, _ = _drive(depth, rounds=16)
    assert _rows_equal(a, b)


def test_merge_consumes_oldest_exchange_first():
    """The queue is FIFO: with two exchanges in flight, merge() adopts the
    first-dispatched one (its batch_idx lands in the workset)."""
    data, cfg = _workload()
    init_fn, task, _ = make_dlrm(cfg)
    ccfg = CELUConfig(R=3, W=5)
    params = init_fn(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adagrad", 0.05)
    it = aligned_batches(data["train"], 64, seed=0)
    _, ba, bb = next(it)
    asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
    etask = engine.lift_two_party(task)
    state = engine.init_state(etask, engine.lift_two_party_params(params),
                              opt, ccfg, [asj(ba)], asj(bb))
    pe = engine.make_pipeline(etask, opt, ccfg, depth=2)
    rs = pe.init(state)
    rs = pe.dispatch(rs, [asj(ba)], asj(bb), 100)
    rs = pe.dispatch(rs, [asj(ba)], asj(bb), 101)
    assert [int(p.batch_idx) for p in rs.pending] == [100, 101]
    rs, _ = pe.merge(rs)
    inserted = np.asarray(rs.ws["a"][0]["batch_idx"])
    assert 100 in inserted and 101 not in inserted
    rs, _ = pe.merge(rs)
    inserted = np.asarray(rs.ws["a"][0]["batch_idx"])
    assert 101 in inserted
    assert pe.finalize(rs)["comm_rounds"] == 2


def test_dispatch_beyond_queue_capacity_rejected():
    """A depth-D queue holds at most D in-flight exchanges; one more
    dispatch is a scheduler bug."""
    data, cfg = _workload()
    init_fn, task, _ = make_dlrm(cfg)
    ccfg = CELUConfig(R=3, W=5)
    params = init_fn(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adagrad", 0.05)
    it = aligned_batches(data["train"], 64, seed=0)
    bi, ba, bb = next(it)
    asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
    etask = engine.lift_two_party(task)
    state = engine.init_state(etask, engine.lift_two_party_params(params),
                              opt, ccfg, [asj(ba)], asj(bb))
    pe = engine.make_pipeline(etask, opt, ccfg, depth=2)
    rs = pe.init(state)
    rs = pe.dispatch(rs, [asj(ba)], asj(bb), bi)
    rs = pe.dispatch(rs, [asj(ba)], asj(bb), bi)
    with pytest.raises(RuntimeError, match="already in flight"):
        pe.dispatch(rs, [asj(ba)], asj(bb), bi)
    with pytest.raises(RuntimeError, match="still in flight"):
        pe.finalize(rs)


def test_depth_exceeding_ring_capacity_rejected():
    """D >= W leaves no valid workset draws — rejected at config AND
    scheduler level."""
    with pytest.raises(ValueError, match="pipeline_depth"):
        CELUConfig(W=5, pipeline_depth=5)
    with pytest.raises(ValueError, match="pipeline_depth"):
        CELUConfig(pipeline_depth=-1)
    with pytest.raises(ValueError, match="pipeline_lr_damping"):
        CELUConfig(pipeline_lr_damping=-0.5)
    # the scheduler revalidates an explicit depth= override
    data, cfg = _workload()
    init_fn, task, _ = make_dlrm(cfg)
    opt = make_optimizer("adagrad", 0.05)
    with pytest.raises(ValueError, match="depth"):
        engine.make_pipeline(engine.lift_two_party(task), opt,
                             CELUConfig(W=3), depth=3)


# --------------------------------------------------------------------------
# Convergence: the damped depth-D schedule still trains
# --------------------------------------------------------------------------
def test_depth2_converges_to_depth0_quality():
    """Two exchanges of queued staleness, damped, must still land in the
    sequential schedule's loss region."""
    seq, _ = _drive(0, rounds=40)
    deep, _ = _drive(2, rounds=40)
    l_seq = [r["loss"] for r in seq[:-1]]
    l_deep = [r["loss"] for r in deep[:-1] if not math.isnan(r["loss"])]
    assert np.isfinite(l_deep).all()
    assert np.mean(l_deep[-10:]) < np.mean(l_deep[:5])
    assert np.mean(l_deep[-10:]) <= 1.15 * np.mean(l_seq[-10:])


def test_lr_damping_shrinks_parameter_drift():
    """eta / (1 + c*s): a larger damping coefficient moves the params less
    over the same depth-2 schedule (the staleness guard is live)."""
    data, cfg = _workload()
    init_fn, _, _ = make_dlrm(cfg)
    p0 = init_fn(jax.random.PRNGKey(0), cfg)

    def drift(damping):
        _, st = _drive(2, rounds=12, damping=damping)
        pa = engine.unlift_params(st["params"])
        return float(sum(
            jnp.sum((a - b.astype(jnp.float32)) ** 2)
            for a, b in zip(jax.tree_util.tree_leaves(pa),
                            jax.tree_util.tree_leaves(p0))) ** 0.5)

    d_undamped = drift(0.0)
    d_damped = drift(5.0)
    assert 0 < d_damped < d_undamped


def test_inflight_residual_chain_follows_dispatch_order():
    """Lossy wire + two exchanges in flight: the second dispatch must
    encode against the FIRST in-flight exchange's error-feedback
    residuals (the chain follows dispatch order and rides the queue),
    not the stale merged-prefix residuals in the round state."""
    data, cfg = _workload()
    init_fn, task, _ = make_dlrm(cfg)
    ccfg = CELUConfig(R=3, W=5)
    tp = engine.make_transport(ccfg, "int8_topk")
    params = init_fn(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adagrad", 0.05)
    it = aligned_batches(data["train"], 64, seed=0)
    bi, ba, bb = next(it)
    asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
    etask = engine.lift_two_party(task)
    state = engine.init_state(etask, engine.lift_two_party_params(params),
                              opt, ccfg, [asj(ba)], asj(bb), transport=tp)
    pe = engine.make_pipeline(etask, opt, ccfg, depth=2, transport=tp)
    rs = pe.init(state)
    rs = pe.dispatch(rs, [asj(ba)], asj(bb), bi)
    bi2, ba2, bb2 = next(it)
    rs = pe.dispatch(rs, [asj(ba2)], asj(bb2), bi2)
    # exchange 1's residuals are live (lossy codec) and distinct from the
    # zero residuals still in the round state
    r1 = np.asarray(rs.pending[0].fresh["tstate"]["up"][0])
    assert np.abs(r1).sum() > 0.0
    # recomputing exchange 2 from exchange 1's transport state (same
    # dispatch seq number) reproduces the dispatched payload exactly...
    expect = pe._compute(rs.params, rs.pending[0].fresh["tstate"],
                         [asj(ba2)], asj(bb2), rs.comm_rounds + 1)
    np.testing.assert_array_equal(
        np.asarray(rs.pending[1].fresh["zs"][0]),
        np.asarray(expect["zs"][0]))
    # ...while the un-chained computation (merged-prefix zero residuals)
    # yields a different wire payload: the chain genuinely engaged
    stale = pe._compute(rs.params, rs.transport, [asj(ba2)], asj(bb2),
                        rs.comm_rounds + 1)
    assert not np.array_equal(np.asarray(rs.pending[1].fresh["zs"][0]),
                              np.asarray(stale["zs"][0]))


def test_depth2_compressed_transport_trains():
    """Error feedback composes with the deep queue: a lossy int8_topk
    wire still converges at depth 2 (residuals telescope through the
    in-flight chain)."""
    rows, st = _drive(2, rounds=14, compression="int8_topk")
    losses = [r["loss"] for r in rows[:-1] if not math.isnan(r["loss"])]
    assert np.isfinite(losses).all()
    assert np.mean(losses[-4:]) < np.mean(losses[:4])
    # the drained state carries live residuals
    assert float(jnp.abs(st["transport"]["up"][0]).sum()) > 0.0


def test_uniform_sampling_depth2_trains():
    """The uniform-draw key chain stays well-defined (and independent
    across same-comm_rounds scans) on the dynamic depth-D path."""
    rows, _ = _drive(2, rounds=16, sampling="uniform")
    losses = [r["loss"] for r in rows[:-1] if not math.isnan(r["loss"])]
    assert np.isfinite(losses).all()
    assert rows[-1]["comm_rounds"] == 16


# --------------------------------------------------------------------------
# Per-slot staleness plumbing: traced offsets through draw + kernels
# --------------------------------------------------------------------------
def _entry(v):
    return {"z": jnp.full((4, 2), float(v)), "dz": jnp.full((4, 2), 1.0)}


def test_traced_staleness_reaches_workset_draw():
    """A traced per-slot offset tightens the validity window exactly like
    the static int: at runtime s the oldest s ring slots are retired."""
    W, R = 4, 8
    ws = workset_init(W, _entry(0))
    for t in range(W):
        ws = workset_insert(ws, _entry(t), t)
    draw = jax.jit(lambda w, s: workset_draw(w, R, "round_robin",
                                             pipeline_staleness=s))
    for s, expected in ((0, W), (1, W - 1), (2, W - 2), (3, W - 3)):
        valid = 0
        w2 = dict(ws)
        for _ in range(W):
            w2, slot, _, v = draw(w2, jnp.int32(s))
            valid += int(v)
        assert valid == expected, (s, valid)


def test_traced_staleness_reaches_workset_sample():
    """workset_sample (the materializing form) accepts the traced offset
    too — one jitted sampler serves every queue occupancy."""
    W, R = 4, 8
    ws = workset_init(W, _entry(0))
    for t in range(W):
        ws = workset_insert(ws, _entry(t), t)
    sample = jax.jit(lambda w, s: workset_sample(w, R, "consecutive",
                                                 pipeline_staleness=s))
    _, e, _, v0 = sample(ws, jnp.int32(0))
    assert bool(v0)
    np.testing.assert_array_equal(np.asarray(e["z"]),
                                  np.asarray(_entry(W - 1)["z"]))
    # the freshest slot dies once the offset eats the whole window
    _, _, _, v_dead = sample(ws, jnp.int32(W))
    assert not bool(v_dead)


@pytest.mark.parametrize("s", [0, 1, 3])
def test_fused_post_scale_traced_staleness_parity(s):
    """The fused kernel's post-scale composition of a TRACED per-slot
    discount equals both the unfused reference and the static-int path."""
    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    st = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    dz = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    fused = jax.jit(lambda s_: engine.weighted_cotangent(
        a, st, dz, 0.5, fused=True, pipeline_staleness=s_))
    ref = jax.jit(lambda s_: engine.weighted_cotangent(
        a, st, dz, 0.5, fused=False, pipeline_staleness=s_))
    w_f, cot_f = fused(jnp.int32(s))
    w_r, cot_r = ref(jnp.int32(s))
    np.testing.assert_allclose(np.asarray(w_f), np.asarray(w_r),
                               rtol=3e-6, atol=3e-7)
    np.testing.assert_allclose(np.asarray(cot_f), np.asarray(cot_r),
                               rtol=3e-6, atol=3e-6)
    # traced == static composition
    w_s, cot_s = engine.weighted_cotangent(a, st, dz, 0.5, fused=True,
                                           pipeline_staleness=s)
    np.testing.assert_allclose(np.asarray(w_f), np.asarray(w_s),
                               rtol=3e-6, atol=3e-7)
    np.testing.assert_allclose(np.asarray(cot_f), np.asarray(cot_s),
                               rtol=3e-6, atol=3e-6)
    # rejected instances stay rejected through the dynamic discount
    assert np.all(np.asarray(w_f)[np.asarray(w_r) == 0.0] == 0.0)


def test_traced_staleness_zero_is_identity():
    """Runtime s = 0 through the dynamic path is bitwise the no-discount
    result — the drain scan's final pass loses nothing."""
    rng = np.random.default_rng(12)
    a = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    st = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    dz = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    dyn = jax.jit(lambda s_: engine.weighted_cotangent(
        a, st, dz, 0.5, fused=True, pipeline_staleness=s_))
    w_d, cot_d = dyn(jnp.int32(0))
    w_0, cot_0 = engine.weighted_cotangent(a, st, dz, 0.5, fused=True,
                                           pipeline_staleness=0)
    np.testing.assert_array_equal(np.asarray(w_d), np.asarray(w_0))
    np.testing.assert_array_equal(np.asarray(cot_d), np.asarray(cot_0))


# --------------------------------------------------------------------------
# Guard rails retained from the static schedules
# --------------------------------------------------------------------------
def test_pod_round_rejects_deep_queue():
    """The single-jit pod round cannot host a D-deep host-side queue."""
    with pytest.raises(ValueError, match="pipeline_depth"):
        engine.make_pod_round(None, make_optimizer("adagrad", 0.01),
                              R=2, cos_xi=0.5, tower_fwd=lambda p, x: x,
                              top_loss=lambda p, a, b, y: y,
                              pipeline_depth=2)
