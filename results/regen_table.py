"""Regenerate the EXPERIMENTS.md §Roofline markdown table from JSONL.

    python results/regen_table.py [results/dryrun_final.jsonl] [--mesh 16x16]
"""
import json
import sys


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_final.jsonl"
    mesh = None
    if "--mesh" in sys.argv:
        mesh = sys.argv[sys.argv.index("--mesh") + 1]
    seen = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r.get("tag"):
                continue
            seen[(r["arch"], r["shape"], r["mesh"])] = r
    print(f"{'arch':24s} {'shape':12s} {'mesh':8s} {'comp_s':>8s} "
          f"{'mem_s':>8s} {'coll_s':>8s} {'dominant':>12s} {'frac':>6s} "
          f"{'tempGB':>7s}")
    n_ok = n = 0
    for (a, s, m), r in sorted(seen.items()):
        if mesh and m != mesh:
            continue
        n += 1
        if not r["ok"]:
            print(f"{a:24s} {s:12s} {m:8s} FAIL {r.get('error', '')[:60]}")
            continue
        n_ok += 1
        t = r["roofline"]
        print(f"{a:24s} {s:12s} {m:8s} {t['compute_s']:8.4f} "
              f"{t['memory_s']:8.4f} {t['collective_s']:8.4f} "
              f"{r['dominant']:>12s} {r['useful_flops_frac']:6.2f} "
              f"{r['memory']['temp_bytes'] / 1e9:7.1f}")
    print(f"# {n_ok}/{n} ok")


if __name__ == "__main__":
    main()
