import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) against
abstract inputs on the production mesh, and extract the roofline terms.

The two lines above MUST run before any jax import (device count locks on
first init) — which is why this module is the only entry point that sees
512 placeholder devices; smoke tests and benches see the host's real 1.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.jsonl
  ... add --multi-pod for the 2x16x16 512-chip mesh.

Per run it records: lowering/compile success, per-device memory analysis,
HLO FLOPs/bytes from cost_analysis, collective bytes parsed from the
partitioned HLO, and the three roofline terms (§Roofline in EXPERIMENTS.md).
"""

import argparse
import json
import re
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import SHAPES, arch_for_shape
from ..configs.base import ArchConfig, ShapeConfig
from ..optim import adagrad
from ..sharding.rules import (batch_pspec, cache_pspecs, params_pspecs)
from .mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16, data_axes,
                   make_production_mesh)
from .steps import abstract_params, input_specs, make_step

P = jax.sharding.PartitionSpec

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred|c64|c128)\[([0-9,]*)\]")


def _cost_dict(cost) -> Dict[str, float]:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions: older
    releases return one dict, JAX 0.4.3x returns a LIST of per-program
    dicts, and some backends return None.  Sum numeric fields across
    programs into a single flat dict."""
    if cost is None:
        return {}
    if isinstance(cost, dict):
        return cost
    merged: Dict[str, float] = {}
    for prog in cost:
        for k, v in (prog or {}).items():
            if isinstance(v, (int, float)):
                merged[k] = merged.get(k, 0.0) + float(v)
    return merged


def _shape_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_COLL_LINE_RE = re.compile(
    r"^%?[\w.\-]+\s*=\s*(\(?[\w\[\],{}\s]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _line_collective(s: str):
    """(op, bytes) for a collective instruction line, else None."""
    m = _COLL_LINE_RE.match(s)
    if not m:
        return None
    result_types, op = m.group(1), m.group(2)
    nbytes = sum(_shape_bytes(sm) for sm in _SHAPE_RE.finditer(result_types))
    gm = _GROUPS_RE.search(s)
    g = int(gm.group(2)) if gm else 1
    if op == "all-gather" and g:
        nbytes //= g
    elif op == "reduce-scatter":
        nbytes *= g
    return op, nbytes


_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(r"\bwhile\(.*?\)\s*,\s*condition=%?([\w.\-]+)\s*,\s*"
                       r"body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"\bconstant\((\d+)\)")


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective, EXECUTION-weighted.

    Post-SPMD HLO prints only the RESULT type inline, so operand bytes come
    from the result shape and replica-group size g
    (``replica_groups=[n,g]<=...``):

      all-reduce / all-to-all / collective-permute : operand = result
      all-gather : result/g        reduce-scatter : result*g

    Collectives inside ``while`` bodies (layer scans, flash-attention
    q-block scans, microbatch accumulation) execute TRIP-COUNT times but
    appear once in the text — this parser walks the computation graph and
    multiplies nested-loop bodies by their trip counts (read as the max
    integer literal in the loop condition, which is the scan bound for all
    jax-emitted loops).
    """
    # 1. split into computations
    comps: Dict[str, list] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        if not line.startswith(" ") and "{" in line:
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line.strip())

    # 2. trip count of a loop-condition computation
    def trip_count(cond_name: str) -> int:
        best = 1
        for s in comps.get(cond_name, ()):
            for cm in _CONST_RE.finditer(s):
                best = max(best, int(cm.group(1)))
        return best

    # 3. execution-weighted bytes per computation (memoized DFS)
    memo: Dict[str, Dict[str, int]] = {}

    def walk(name: str) -> Dict[str, int]:
        if name in memo:
            return memo[name]
        out = {k: 0 for k in COLLECTIVE_OPS}
        memo[name] = out          # break cycles defensively
        for s in comps.get(name, ()):
            lc = _line_collective(s)
            if lc:
                out[lc[0]] += lc[1]
            wm = _WHILE_RE.search(s)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                n = trip_count(cond)
                sub = walk(body)
                for k, v in sub.items():
                    out[k] += n * v
        return out

    if entry is None:             # fall back to flat counting
        out = {k: 0 for k in COLLECTIVE_OPS}
        for line in hlo_text.splitlines():
            lc = _line_collective(line.strip())
            if lc:
                out[lc[0]] += lc[1]
        return out
    return dict(walk(entry))


# --------------------------------------------------------------------------
def _flops_dense(cfg: ArchConfig) -> int:
    """Total (and MoE-active) param counts from abstract shapes."""
    params = abstract_params(cfg)
    total = sum(int(np.prod(x.shape)) for x in
                jax.tree_util.tree_leaves(params))
    active = total
    if cfg.moe is not None:
        expert = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
            keys = [getattr(p, "key", None) for p in path]
            if any(k in ("wg", "wu", "wd") for k in keys) and leaf.ndim >= 3:
                expert += int(np.prod(leaf.shape))
        frac = (cfg.moe.top_k + cfg.moe.n_shared) / cfg.moe.n_experts
        active = total - expert + int(expert * frac)
    return total, active


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """6·N·D for training, 2·N_active per generated token for decode."""
    total, active = _flops_dense(cfg)
    if shape.kind == "train":
        return 6.0 * active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * active * shape.global_batch * shape.seq_len
    return 2.0 * active * shape.global_batch  # one token


# --------------------------------------------------------------------------
def dryrun(arch_id: str, shape_name: str, *, multi_pod: bool = False,
           fsdp: bool = True, moe_sharding: str = "",
           donate: bool = True, extra_tag: str = "",
           microbatches: int = 1, unroll_microbatches: bool = False,
           pure_dp: bool = False, zero1: bool = False,
           moe_capacity: float = 0.0) -> Dict[str, Any]:
    """``pure_dp``: batch over (pod, data, model) — all 256/512 chips data-
    parallel, tower weights replicated (embeddings/head still model-sharded
    via the name rules' divisibility checks being moot doesn't apply — in
    pure-DP we replicate everything but shard the batch).  The right profile
    for archs whose head/expert counts defeat 16-way TP (§Perf pair 2)."""
    cfg = arch_for_shape(arch_id, shape_name)
    if not moe_sharding:   # default: the arch config's choice (§Perf 2.4)
        moe_sharding = cfg.moe.sharding if cfg.moe is not None else "tp"
    if moe_capacity and cfg.moe is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=moe_capacity))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    daxes = data_axes(mesh) + (("model",) if pure_dp else ())
    dsize = int(np.prod([mesh.shape[a] for a in daxes]))
    from ..models.layers import set_batch_axes
    set_batch_axes(daxes, dsize,
                   vocab_axis=None if pure_dp else "model",
                   vocab_size=int(mesh.shape["model"]))
    t0 = time.time()

    params = abstract_params(cfg)
    if pure_dp:
        pspecs = params_pspecs(params, mesh, model_axis="__none__",
                               fsdp_axis="data" if fsdp else None)
    else:
        pspecs = params_pspecs(params, mesh, moe_sharding=moe_sharding,
                               fsdp_axis="data" if fsdp else None)
    shard = lambda t, s: jax.tree_util.tree_map(
        lambda leaf, sp: jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype,
            sharding=jax.sharding.NamedSharding(mesh, sp)),
        t, s, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    specs = input_specs(cfg, shape)
    opt = adagrad(0.01)

    if shape.kind == "train":
        opt_state = jax.eval_shape(opt.init, params)
        if zero1:
            # ZeRO-1: shard ONLY the fp32 accumulators over `data`, keeping
            # params replicated (pairs with pure_dp for awkward-dim archs)
            opt_specs = {"accum": params_pspecs(
                params, mesh,
                model_axis="__none__" if pure_dp else "model",
                moe_sharding=moe_sharding, fsdp_axis="data")}
        else:
            opt_specs = {"accum": pspecs}
        from .steps import make_train_step
        step = make_train_step(cfg, opt, microbatches=microbatches,
                               unroll_microbatches=unroll_microbatches)
        in_shardings = (
            jax.tree_util.tree_map(
                lambda s: jax.sharding.NamedSharding(mesh, s), pspecs,
                is_leaf=lambda x: isinstance(x, P)),
            jax.tree_util.tree_map(
                lambda s: jax.sharding.NamedSharding(mesh, s),
                opt_specs, is_leaf=lambda x: isinstance(x, P)),
            jax.tree_util.tree_map(
                lambda l: jax.sharding.NamedSharding(
                    mesh, batch_pspec(l.shape, mesh, data_axes=daxes)),
                specs["batch"],
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)),
        )
        out_shardings = (in_shardings[0], in_shardings[1],
                         jax.sharding.NamedSharding(mesh, P()))
        args = (shard(params, pspecs),
                {"accum": shard(opt_state["accum"], opt_specs["accum"])},
                specs["batch"])
        fn = jax.jit(step, in_shardings=in_shardings,
                     out_shardings=out_shardings,
                     donate_argnums=(0, 1) if donate else ())
    elif shape.kind == "prefill":
        step = make_step(cfg, shape)
        bspecs = jax.tree_util.tree_map(
            lambda l: jax.sharding.NamedSharding(
                mesh, batch_pspec(l.shape, mesh, data_axes=daxes)),
            specs["batch"],
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        in_shardings = (
            jax.tree_util.tree_map(
                lambda s: jax.sharding.NamedSharding(mesh, s), pspecs,
                is_leaf=lambda x: isinstance(x, P)),
            bspecs)
        fn = jax.jit(step, in_shardings=in_shardings)
        args = (shard(params, pspecs), specs["batch"])
    else:  # decode
        step = make_step(cfg, shape)
        caches = specs["caches"]
        cspecs = cache_pspecs(caches, mesh, data_axes=daxes)
        in_shardings = (
            jax.tree_util.tree_map(
                lambda s: jax.sharding.NamedSharding(mesh, s), pspecs,
                is_leaf=lambda x: isinstance(x, P)),
            jax.tree_util.tree_map(
                lambda s: jax.sharding.NamedSharding(mesh, s), cspecs,
                is_leaf=lambda x: isinstance(x, P)),
            jax.tree_util.tree_map(
                lambda l: jax.sharding.NamedSharding(
                    mesh, batch_pspec(l.shape, mesh, data_axes=daxes)),
                specs["step_batch"],
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)),
            jax.sharding.NamedSharding(mesh, P()),
        )
        fn = jax.jit(step, in_shardings=in_shardings,
                     donate_argnums=(1,) if donate else ())
        args = (shard(params, pspecs),
                jax.tree_util.tree_map(
                    lambda l, sp: jax.ShapeDtypeStruct(
                        l.shape, l.dtype,
                        sharding=jax.sharding.NamedSharding(mesh, sp)),
                    caches, cspecs,
                    is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)),
                specs["step_batch"], specs["pos"])

    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled.cost_analysis())
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    coll_total = sum(coll.values())

    # XLA's flop count uses the M*N*K convention (one per MAC); double it to
    # compare against the 2*M*N*K convention of MODEL_FLOPS = 6*N*D.
    flops = 2.0 * float(cost.get("flops", 0.0))
    # "bytes accessed" sums operand+result bytes over all HLO ops — an
    # un-fused upper bound on HBM traffic (fusion collapses most of it);
    # relative comparisons under the same convention remain meaningful.
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    mf = model_flops(cfg, shape)

    terms = {
        # cost_analysis reports the per-device (partitioned) program
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": bytes_acc / HBM_BW,
        "collective_s": coll_total / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    result = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "fsdp": fsdp, "moe_sharding": moe_sharding,
        "tag": extra_tag,
        "pure_dp": pure_dp, "microbatches": microbatches,
        "ok": True,
        "compile_s": round(time.time() - t0, 1),
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": bytes_acc,
        "collective_bytes_per_dev": coll_total,
        "collectives": coll,
        "model_flops_global": mf,
        "model_flops_per_dev": mf / chips,
        "useful_flops_frac": (mf / chips) / flops if flops else 0.0,
        "roofline": terms,
        "dominant": dominant,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0),
        },
    }
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every assigned arch x shape")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--moe-sharding", default="",
                    choices=("", "tp", "ep"))
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--unroll-microbatch", action="store_true")
    ap.add_argument("--pure-dp", action="store_true")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--moe-capacity", type=float, default=0.0)
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args(argv)

    from ..configs import ARCH_IDS
    pairs = []
    archs = ARCH_IDS if args.all else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                pairs.append((a, s, mp))

    rc = 0
    for a, s, mp in pairs:
        try:
            res = dryrun(a, s, multi_pod=mp, fsdp=not args.no_fsdp,
                         moe_sharding=args.moe_sharding, extra_tag=args.tag,
                         microbatches=args.microbatch,
                         unroll_microbatches=args.unroll_microbatch,
                         pure_dp=args.pure_dp, zero1=args.zero1,
                         moe_capacity=args.moe_capacity)
        except Exception as e:  # noqa: BLE001 — record failures, keep going
            res = {"arch": a, "shape": s,
                   "mesh": "2x16x16" if mp else "16x16", "ok": False,
                   "tag": args.tag, "error": f"{type(e).__name__}: {e}"}
            rc = 1
        line = json.dumps(res)
        print(line, flush=True)
        if args.out:
            import pathlib
            pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
            with open(args.out, "a") as f:
                f.write(line + "\n")
    return rc


if __name__ == "__main__":
    sys.exit(main())
