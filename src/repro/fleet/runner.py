"""Fleet runner: hundreds of CELU-VFL training jobs as one compiled XLA
program per cohort.

``run_fleet(configs, rounds, workload=...)`` takes a list of
:class:`JobSpec` (one per job), groups them into COHORTS by their static
knobs (:func:`cohort_key` — depth, codec, cache dtype, W/R, sampling,
optimizer... anything that changes the traced program), and runs each
cohort as ONE ``jit(lax.scan(step-over-rounds) + flush)`` with the job
axis batched:

  * ``mode="vmap"`` (default) vectorizes the job axis — maximum
    throughput; jobs in a cohort share every op.  A fleet of ONE job is
    bit-identical to the scalar engine (the N=1 golden gate in
    tests/test_fleet.py); at N > 1 the lanes are bit-identical to EACH
    OTHER, but CPU XLA's batched GEMMs may reassociate reductions a ULP
    away from the unbatched program (docs/FLEET.md has the full story).
  * ``mode="map"`` lowers the job axis with ``lax.map`` — lanes execute
    the UNBATCHED program sequentially inside the same single compiled
    call, bit-identical to the scalar engine at ANY fleet size (the N=3
    golden gate).  Host-dispatch savings are identical; vector-unit
    sharing across jobs is given up.

Traced per-job knobs (lr, rng seed, xi threshold) batch freely inside a
cohort via :class:`~repro.fleet.scheduler.JobHyper`; every job shares the
cohort's batch schedule (the sweep-grid / hyper-fleet regime — jobs that
need their own DATA belong in their own cohort).  ``shard=True`` splits
the job axis over the host's device grid (``launch.mesh.make_fleet_mesh``)
— on CPU CI a multi-device grid comes from
``--xla_force_host_platform_device_count`` in a fresh process'
environment.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import CELUConfig
from ..core import engine
from ..optim import make_optimizer
from .scheduler import JobHyper, average_flush_metrics, make_fleet_step


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """One fleet job.  ``celu`` carries the static engine knobs; ``lr``,
    ``seed`` and ``xi_degrees`` are TRACED per-job values (same compiled
    cohort); ``optimizer``/``local_steps``/``compression``/``depth`` are
    static and partition cohorts.  ``seed`` drives both the param init
    (``workload.params_for``) and the engine rng chain (seed 0 = the
    scalar engine's golden-pinned chain)."""
    celu: CELUConfig
    lr: float = 0.05
    seed: int = 0
    optimizer: str = "adagrad"
    local_steps: int = -1
    compression: Optional[str] = None
    depth: Optional[int] = None
    xi_degrees: Optional[float] = None

    def resolved_depth(self) -> int:
        return self.celu.pipeline_depth if self.depth is None else self.depth

    def resolved_xi(self) -> float:
        return self.celu.xi_degrees if self.xi_degrees is None \
            else self.xi_degrees


def cohort_key(spec: JobSpec):
    """Static partition key: two jobs trace the same program iff their
    keys match.  ``xi_degrees`` is normalized OUT of the celu config (it
    is traced via JobHyper); everything else in the config — W, R,
    sampling, weighting, wire/cache dtypes, codec, depth, damping — is
    compile-time structure."""
    celu = dataclasses.replace(spec.celu, xi_degrees=0.0)
    return (celu, spec.optimizer, spec.local_steps, spec.compression,
            spec.resolved_depth())


class FleetWorkload(NamedTuple):
    """What every job in the fleet trains on.  ``params_for(seed)`` builds
    one job's initial params ``{"a": [...], "b": ...}``;
    ``batch_stream()`` returns a fresh iterator of
    ``(batch_idx, batches_a, batch_b)`` — the schedule is stacked once
    and shared by the whole fleet."""
    task: engine.KPartyTask
    params_for: Callable[[int], Dict[str, Any]]
    batch_stream: Callable[[], Any]


@dataclasses.dataclass
class FleetResult:
    """Per-job stacked outcomes, rows in the caller's ``configs`` order.
    Warmup rounds of depth >= 2 jobs report NaN in ``losses`` (exactly the
    scalar pipeline's warmup rows)."""
    losses: np.ndarray            # (n_jobs, rounds) f32
    w_mean: np.ndarray            # (n_jobs, rounds) f32
    w_zero_frac: np.ndarray       # (n_jobs, rounds) f32
    local_steps: np.ndarray       # (n_jobs, rounds) int32
    flush_metrics: Dict[str, np.ndarray]   # each (n_jobs,)
    comm_rounds: np.ndarray       # (n_jobs,) int32, queue drained
    steps_a: List[List[int]]      # per job, one counter per party A_i
    steps_b: np.ndarray           # (n_jobs,) int64
    round_wire_bytes: np.ndarray  # (n_jobs,) exact wire bytes per round
    wall_s: float                 # device wall across cohorts (post-compile)
    compile_s: float              # trace+compile wall across cohorts
    n_cohorts: int
    cohort_sizes: List[int]
    mode: str
    _final: List[Dict[str, Any]] = dataclasses.field(default_factory=list)

    def final_state(self, j: int) -> Dict[str, Any]:
        """Job ``j``'s final engine state dict (numpy leaves) — feed its
        params to eval (AUC etc.)."""
        return self._final[j]


def _stack(trees: Sequence[Any]):
    return jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *trees)


def _unstack(tree, i):
    return jax.tree_util.tree_map(lambda x: x[i], tree)


def _stack_batches(workload: FleetWorkload, rounds: int):
    it = workload.batch_stream()
    bis, bas, bbs = [], [], []
    for _ in range(rounds):
        bi, ba, bb = next(it)
        bis.append(jnp.asarray(bi))
        bas.append(ba)
        bbs.append(bb)
    return _stack(bis), _stack(bas), _stack(bbs)


def run_fleet(configs: Sequence[JobSpec], rounds: int, *,
              workload: FleetWorkload, mode: str = "vmap",
              shard: bool = False, mesh=None) -> FleetResult:
    """Train every job for ``rounds`` communication rounds (plus the
    queue drain) and return stacked per-job metrics.  One compiled XLA
    program per cohort; see the module docstring for ``mode``/``shard``."""
    if mode not in ("vmap", "map"):
        raise ValueError(f"mode must be 'vmap' or 'map', got {mode!r}")
    if not configs:
        raise ValueError("empty fleet")
    n_jobs = len(configs)
    bis, bas, bbs = _stack_batches(workload, rounds)
    ex_ba = _unstack(bas, 0)
    ex_bb = _unstack(bbs, 0)

    # partition into cohorts, preserving first-seen order
    cohorts: Dict[Any, List[int]] = {}
    for j, spec in enumerate(configs):
        cohorts.setdefault(cohort_key(spec), []).append(j)

    losses = np.full((n_jobs, rounds), np.nan, np.float32)
    w_mean = np.zeros((n_jobs, rounds), np.float32)
    w_zero = np.zeros((n_jobs, rounds), np.float32)
    lsteps = np.zeros((n_jobs, rounds), np.int32)
    flush_m = {"local_steps": np.zeros(n_jobs, np.int32),
               "w_mean": np.zeros(n_jobs, np.float32),
               "w_zero_frac": np.zeros(n_jobs, np.float32)}
    commr = np.zeros(n_jobs, np.int32)
    steps_a: List[List[int]] = [[] for _ in range(n_jobs)]
    steps_b = np.zeros(n_jobs, np.int64)
    rbytes = np.zeros(n_jobs, np.int64)
    finals: List[Dict[str, Any]] = [{} for _ in range(n_jobs)]

    wall = 0.0
    compile_wall = 0.0
    for jobs in cohorts.values():
        spec0 = configs[jobs[0]]
        celu, depth = spec0.celu, spec0.resolved_depth()
        tp = engine.make_transport(celu, spec0.compression)
        init_fn, step_fn, flush_fn = make_fleet_step(
            workload.task, celu, depth=depth, optimizer=spec0.optimizer,
            local_steps=spec0.local_steps, transport=tp)

        # per-job scalar init, stacked over the cohort's job axis
        fstates, hypers = [], []
        z_shapes = None
        for j in jobs:
            spec = configs[j]
            params = workload.params_for(spec.seed)
            if z_shapes is None:
                z_shapes = [jax.eval_shape(workload.task.forward_a, p, b)
                            for p, b in zip(params["a"], ex_ba)]
            opt = make_optimizer(spec.optimizer, spec.lr)
            state = engine.init_state(workload.task, params, opt, celu,
                                      ex_ba, ex_bb, transport=tp)
            fstates.append(init_fn(state, ex_ba, ex_bb))
            hypers.append(JobHyper.for_spec(spec.lr, spec.resolved_xi(),
                                            spec.seed))
        fs = _stack(fstates)
        hyper = _stack(hypers)
        per_round = tp.round_bytes([z.shape for z in z_shapes])

        if mode == "vmap":
            step_v = jax.vmap(step_fn, in_axes=(0, 0, None, None, None))
            flush_v = jax.vmap(flush_fn, in_axes=(0, 0))
        else:
            def step_v(fs, hyper, ba, bb, bi, _step=step_fn):
                return jax.lax.map(
                    lambda args: _step(args[0], args[1], ba, bb, bi),
                    (fs, hyper))

            def flush_v(fs, hyper, _flush=flush_fn):
                return jax.lax.map(lambda args: _flush(args[0], args[1]),
                                   (fs, hyper))

        def run(fs, hyper, bis, bas, bbs, _step=step_v, _flush=flush_v):
            def one(carry, xs):
                bi, ba, bb = xs
                carry, m = _step(carry, hyper, ba, bb, bi)
                return carry, m
            fs, ms = jax.lax.scan(one, fs, (bis, bas, bbs))
            fs, fm = _flush(fs, hyper)
            return fs, ms, fm

        if shard:
            from ..launch.mesh import fleet_job_sharding, make_fleet_mesh
            m_ = mesh if mesh is not None else make_fleet_mesh()
            ndev = int(m_.devices.size)
            if len(jobs) % ndev != 0:
                raise ValueError(
                    f"cohort of {len(jobs)} jobs does not divide the "
                    f"{ndev}-device fleet mesh — pad the sweep or pass "
                    f"shard=False")
            sharding = fleet_job_sharding(m_)
            fs = jax.device_put(fs, sharding)
            hyper = jax.device_put(hyper, sharding)

        t0 = time.perf_counter()
        compiled = jax.jit(run).lower(fs, hyper, bis, bas, bbs).compile()
        compile_wall += time.perf_counter() - t0
        t0 = time.perf_counter()
        fs, ms, fm = compiled(fs, hyper, bis, bas, bbs)
        jax.block_until_ready((fs, ms, fm))
        wall += time.perf_counter() - t0

        # scatter cohort lanes back into caller order
        for lane, j in enumerate(jobs):
            losses[j] = np.asarray(ms["loss"][:, lane])
            w_mean[j] = np.asarray(ms["w_mean"][:, lane])
            w_zero[j] = np.asarray(ms["w_zero_frac"][:, lane])
            lsteps[j] = np.asarray(ms["local_steps"][:, lane])
            lane_fm = average_flush_metrics(_unstack(fm, lane))
            for k in flush_m:
                flush_m[k][j] = np.asarray(lane_fm[k])
            st = _unstack(fs.state, lane)
            commr[j] = int(st["comm_rounds"])
            steps_a[j] = [int(s) for s in st["steps"]["a"]]
            steps_b[j] = int(st["steps"]["b"])
            rbytes[j] = per_round
            finals[j] = jax.tree_util.tree_map(np.asarray, st)

    return FleetResult(
        losses=losses, w_mean=w_mean, w_zero_frac=w_zero,
        local_steps=lsteps, flush_metrics=flush_m, comm_rounds=commr,
        steps_a=steps_a, steps_b=steps_b, round_wire_bytes=rbytes,
        wall_s=wall, compile_s=compile_wall, n_cohorts=len(cohorts),
        cohort_sizes=[len(v) for v in cohorts.values()], mode=mode,
        _final=finals)
