"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Each function is the mathematically-plain composition that the fused kernel
must reproduce; tests sweep shapes/dtypes and assert kernel(interpret=True)
against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-12


def cosine_weight_ref(ad_hoc, stale, cos_xi: float):
    """Per-row cosine over flattened non-batch dims, floored at cos_xi.

    -> (B,) float32 weights (Algorithm 2 InsWeight)."""
    B = ad_hoc.shape[0]
    a = ad_hoc.reshape(B, -1).astype(jnp.float32)
    b = stale.reshape(B, -1).astype(jnp.float32)
    num = jnp.sum(a * b, axis=1)
    den = jnp.sqrt(jnp.sum(a * a, axis=1) * jnp.sum(b * b, axis=1))
    w = num / jnp.maximum(den, EPS)
    return jnp.where(w < cos_xi, 0.0, w)


def weighted_cotangent_ref(ad_hoc, stale, dz, cos_xi: float):
    """Fused InsWeight + weights ⊙ ∇Z (the full Algorithm-2 line 7-8 hot
    path): -> weighted cotangent, same shape/dtype as dz."""
    w = cosine_weight_ref(ad_hoc, stale, cos_xi)
    w = w.reshape((w.shape[0],) + (1,) * (dz.ndim - 1))
    return (dz.astype(jnp.float32) * w).astype(dz.dtype)


def fused_sample_ref(slot, ad_hoc, z_ring, dz_ring, cos_xi: float):
    """Gather-from-ring + InsWeight + cotangent scale over a
    full-precision ring (the fused-sample kernel's oracle).

    slot: scalar int; ad_hoc (B, ...); z_ring / dz_ring (W,) + ad_hoc
    shape.  -> (weights (B,) f32, weighted cotangent f32, ad_hoc's
    shape)."""
    B = ad_hoc.shape[0]
    z = z_ring[slot].reshape(B, -1)
    dz = dz_ring[slot].reshape(B, -1).astype(jnp.float32)
    w = cosine_weight_ref(ad_hoc.reshape(B, -1), z, cos_xi)
    return w, (dz * w[:, None]).reshape(ad_hoc.shape)


def fused_sample_q8_ref(slot, ad_hoc, zq, zscale, dzq, dzscale,
                        cos_xi: float):
    """int8-ring oracle: dequantize the sampled rows (codes * per-row
    scale), then the fp32 composition of :func:`fused_sample_ref`."""
    z = zq[slot].astype(jnp.float32) * zscale[slot][:, None]
    dz = dzq[slot].astype(jnp.float32) * dzscale[slot][:, None]
    B = ad_hoc.shape[0]
    w = cosine_weight_ref(ad_hoc.reshape(B, -1), z, cos_xi)
    return w, (dz * w[:, None]).reshape(ad_hoc.shape)


def fused_sample_q4_ref(slot, ad_hoc, zq, zscale, dzq, dzscale,
                        cos_xi: float):
    """int4 nibble-packed ring oracle: unpack the sampled rows' packed
    bytes (two signed codes per byte, wire-codec layout), dequantize by
    the per-row scale, then the fp32 composition of
    :func:`fused_sample_ref`.  The pad nibble (odd row widths) decodes to
    an exact zero, so keeping it in the reductions is harmless; the
    cotangent is sliced back to ad_hoc's width."""
    from ..core.workset import unpack_nibbles
    B = ad_hoc.shape[0]
    a2d = ad_hoc.reshape(B, -1).astype(jnp.float32)
    F = a2d.shape[1]
    Fp = 2 * zq.shape[2]
    if Fp != F:
        a2d = jnp.pad(a2d, ((0, 0), (0, Fp - F)))
    z = unpack_nibbles(zq[slot]).astype(jnp.float32) * zscale[slot][:, None]
    dz = unpack_nibbles(dzq[slot]).astype(jnp.float32) \
        * dzscale[slot][:, None]
    w = cosine_weight_ref(a2d, z, cos_xi)
    return w, (dz * w[:, None])[:, :F].reshape(ad_hoc.shape)


def fused_dequant_q8_ref(slot, zq, zscale):
    """Gather + dequant oracle over the int8 ring (the serving
    decode-cache read): codes * per-row scale at ``slot``.  -> (B, F)
    fp32."""
    return zq[slot].astype(jnp.float32) * zscale[slot][:, None]


def fused_dequant_q4_ref(slot, zq, zscale, width: int):
    """Gather + unpack + dequant oracle over the int4 nibble-packed ring;
    the pad nibble (odd widths) is sliced off.  -> (B, width) fp32."""
    from ..core.workset import unpack_nibbles
    out = unpack_nibbles(zq[slot]).astype(jnp.float32) \
        * zscale[slot][:, None]
    return out[:, :width]


def quantize_sr_ref(x, u, levels):
    """Per-tile absmax scale + stochastic rounding to signed integer codes
    (the compressed-wire encode hot path).

    x, u: (T, L) — T quantization tiles of L values each, u ~ U[0, 1).
    -> (codes int8 (T, L), scales fp32 (T,)); decode is codes * scales[:,
    None].  ``floor(x/s + u)`` is unbiased: E[codes * s] == x."""
    x = x.astype(jnp.float32)
    u = u.astype(jnp.float32)
    levels = jnp.float32(levels)
    amax = jnp.max(jnp.abs(x), axis=1)
    scale = jnp.maximum(amax, EPS) / levels
    q = jnp.clip(jnp.floor(x / scale[:, None] + u), -levels, levels)
    return q.astype(jnp.int8), scale


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """Dense softmax attention oracle.  q,k,v: (B, S, H, hd) (GQA: kv heads
    already repeated).  fp32 softmax internals."""
    B, S, H, hd = q.shape
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    pos = jnp.arange(S)
    d = pos[:, None] - pos[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= d >= 0
    if window:
        mask &= d < window
    scores = jnp.where(mask[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)


def fused_adagrad_ref(grad, accum, lr: float, eps: float):
    """AdaGrad accumulate + scaled update.  -> (update, new_accum)."""
    g = grad.astype(jnp.float32)
    a_new = accum + g * g
    return -lr * g / (jnp.sqrt(a_new) + eps), a_new


def fused_adagrad_q8_ref(grad2d, accum_q, accum_scale, u, lr: float,
                         eps: float):
    """int8-at-rest AdaGrad oracle.  Codes live in SQRT-space (stored
    accumulator value = (code * scale)², the resolution concentrated
    where AdaGrad's 1/sqrt step needs it): dequantize r = codes * scale,
    accumulate r' = sqrt(r² + g²), emit the update, re-derive the row
    scale from the new row max and stochastically requantize
    (``floor(r'/s + u)``, unbiased in r'; codes clipped to [0, 127] —
    the accumulator is non-negative).  grad2d/u: (R, C) fp32; accum_q:
    (R, C) int8; accum_scale: (R, 1) fp32.
    -> (update, new codes, new scales)."""
    g = grad2d.astype(jnp.float32)
    r = accum_q.astype(jnp.float32) * accum_scale
    r_new = jnp.sqrt(r * r + g * g)
    upd = -lr * g / (r_new + eps)
    s_new = jnp.maximum(jnp.max(r_new, axis=1, keepdims=True), EPS) / 127.0
    codes = jnp.clip(jnp.floor(r_new / s_new + u.astype(jnp.float32)),
                     0.0, 127.0).astype(jnp.int8)
    return upd, codes, s_new
