"""Paper Table 2 / Figure 5: ablations over R (local updates), W (workset
size / sampling strategy), and ξ (instance weighting threshold) — plus the
beyond-paper compressed-wire axis (bytes-to-target-loss, Compressed-VFL).

Each block reproduces one Table-2 row group: communication rounds required
to reach a shared target AUC, relative to the no-technique baseline.  The
``compression`` block instead self-calibrates a target LOSS from the
identity-wire celu run and compares bytes spent to reach it.
"""
from __future__ import annotations

import numpy as np

from .common import csv_row, default_workload, rounds_to, run_protocol

ROUNDS = 700
LR = 0.003
TARGET_FRACTION = 0.97   # target = frac * best vanilla AUC (self-calibrated)


def _target(data, cfg) -> float:
    base = run_protocol("vanilla", data, cfg, rounds=ROUNDS, lr=LR)
    return TARGET_FRACTION * base["best_auc"], base


def bench_local_update(data, cfg, target, base):
    """Vary R at fixed W=5, ξ=60° (Table 2 block 1).

    Savings are a PROFILE over target quality: on a workload that converges
    ~25x faster than the paper's 41M-row stream, local updates buy the most
    in the far-from-converged region (where the paper's targets sit); near
    this task's saturation AdaGrad's step-count-driven lr decay evens the
    protocols out.  Reported at 88% / 95% / 98.5% of vanilla's best AUC."""
    fracs = (0.88, 0.95, 0.985)
    targets = [f * base["best_auc"] for f in fracs]
    csv_row("# local_update: rounds-to-target profile "
            "(targets = %s of vanilla best)" %
            "/".join(f"{f:.1%}" for f in fracs))
    csv_row("setting", *[f"rounds@{t:.3f}" for t in targets], "final_auc")
    runs = {"vanilla(R=1)": base}
    for R in (3, 5, 8):
        runs[f"celu(R={R})"] = run_protocol(
            "celu", data, cfg, R=R, W=5, xi=60.0, rounds=ROUNDS, lr=LR)
    base_rounds = [rounds_to(base["curve"], t) or ROUNDS for t in targets]
    for name, r in runs.items():
        cells = []
        for t, b in zip(targets, base_rounds):
            rt = rounds_to(r["curve"], t) or ROUNDS
            cells.append(f"{rt} ({100 * (1 - rt / b):+.0f}%)")
        csv_row(name, *cells, f"{r['final_auc']:.4f}")


STRESS_LR = 0.01   # higher lr + R=8: staleness errors actually bite
STRESS_R = 8


def bench_local_sampling(data, cfg, target, base):
    """W=1 consecutive (FedBCD-style) vs round-robin W>1 (Table 2 blk 2).

    Run in the stressed-staleness regime (lr=0.01, R=8) where repetitive
    sampling measurably accumulates variance (paper Fig 3/5b); quality
    metric is best AUC reached (the curves plateau differently)."""
    csv_row(f"# local_sampling: R={STRESS_R}, xi=60, lr={STRESS_LR}")
    csv_row("setting", "best_auc", "final_auc")
    r1 = run_protocol("celu", data, cfg, R=STRESS_R, W=1, xi=60.0,
                      sampling="consecutive", rounds=ROUNDS, lr=STRESS_LR,
                      eval_every=10)
    csv_row("consecutive(W=1)", f"{r1['best_auc']:.4f}",
            f"{r1['final_auc']:.4f}")
    for W in (3, 5, 8):
        r = run_protocol("celu", data, cfg, R=STRESS_R, W=W, xi=60.0,
                         rounds=ROUNDS, lr=STRESS_LR, eval_every=10)
        csv_row(f"round_robin(W={W})", f"{r['best_auc']:.4f}",
                f"{r['final_auc']:.4f}")


def bench_instance_weighting(data, cfg, target, base):
    """No-weights vs ξ ∈ {90°, 60°, 30°} at (W,R)=(5,8), stressed regime
    (Table 2 blk 3 — weighting matters when staleness errors are large)."""
    csv_row(f"# instance_weighting: W=5, R={STRESS_R}, lr={STRESS_LR}")
    csv_row("setting", "best_auc", "final_auc")
    r0 = run_protocol("celu", data, cfg, R=STRESS_R, W=5, weighting=False,
                      rounds=ROUNDS, lr=STRESS_LR, eval_every=10)
    csv_row("no_weights", f"{r0['best_auc']:.4f}", f"{r0['final_auc']:.4f}")
    for xi in (90.0, 60.0, 30.0):
        r = run_protocol("celu", data, cfg, R=STRESS_R, W=5, xi=xi,
                         rounds=ROUNDS, lr=STRESS_LR, eval_every=10)
        csv_row(f"xi={int(xi)}", f"{r['best_auc']:.4f}",
                f"{r['final_auc']:.4f}")


COMP_ROUNDS = 300
SMOOTH_W = 25            # rounds of training-loss smoothing


def _smooth(losses, w: int = SMOOTH_W):
    """Trailing moving average of the per-round training loss."""
    xs = np.asarray(losses, np.float64)
    c = np.cumsum(np.concatenate([[0.0], xs]))
    n = np.minimum(np.arange(1, len(xs) + 1), w)
    lo = np.arange(1, len(xs) + 1) - n
    return (c[np.arange(1, len(xs) + 1)] - c[lo]) / n


def bench_compression(data, cfg, compression: str = "int8_topk",
                      batch: int = 256):
    """Bytes-to-target-loss: the celu preset over the identity wire vs a
    compressed wire (top-k / low-bit sketches with error feedback).

    Both wires get the SAME WAN byte budget — the compressed wire's
    cheaper rounds buy it proportionally more of them (that is the whole
    trade: a compressed round carries less fresh signal, so convergence
    takes more rounds but fewer bytes).  Target = the identity run's final
    smoothed training loss; the compressed wire 'keeps convergence' when
    it reaches that target inside the shared budget, and the win is
    bytes-to-target."""
    from repro.configs.base import CELUConfig
    from repro.core import engine
    z_shape = (batch, cfg.z_dim)
    wire_bytes = {
        name: engine.make_transport(CELUConfig(), name).round_bytes([z_shape])
        for name in ("identity", compression)}
    budget = COMP_ROUNDS * wire_bytes["identity"]   # equal bytes per wire
    runs = {name: run_protocol("celu", data, cfg, R=5, W=5, xi=60.0,
                               rounds=budget // zb, lr=LR, eval_every=200,
                               batch=batch, compression=name)
            for name, zb in wire_bytes.items()}
    target = float(_smooth(runs["identity"]["loss_curve"])[-1])
    csv_row(f"# compression: celu R=5 W=5 xi=60, equal byte budget "
            f"{budget / 1e6:.1f} MB, target loss {target:.4f} "
            f"(identity final, smoothed over {SMOOTH_W} rounds)")
    csv_row("wire", "bytes_per_round", "round_budget",
            "rounds_to_target_loss", "bytes_to_target", "final_loss",
            "final_auc")
    for name, r in runs.items():
        sm = _smooth(r["loss_curve"])
        hit = np.nonzero(sm <= target)[0]
        rt = int(hit[0]) + 1 if hit.size else None
        zb = r["z_bytes_per_round"]
        csv_row(name, zb, len(sm),
                rt if rt is not None else f">{len(sm)}",
                zb * rt if rt is not None else "-",
                f"{sm[-1]:.4f}", f"{r['final_auc']:.4f}")
    id_b, c_b = wire_bytes["identity"], wire_bytes[compression]
    csv_row(f"# {compression}: {id_b / c_b:.2f}x fewer bytes-per-round "
            f"than identity")


ADAPT_ROUNDS = 300


def bench_adaptive_topk(data, cfg, batch: int = 256):
    """Adaptive top-k ratio scheduling (ROADMAP follow-up): start with an
    aggressive sketch and let the ``PlateauRatioSchedule`` hook loosen the
    keep-ratio as the smoothed training loss plateaus.

    Three celu wires at the same round budget: a fixed tight sketch
    (ratio 0.0625 — cheapest, plateaus highest), a fixed loose sketch
    (ratio 0.25 — the CODEC_SPECS default), and the adaptive wire that
    starts tight and steps 0.0625 -> 0.5 on plateau.  The adaptive wire
    should spend close to the tight wire's bytes while reaching close to
    the loose wire's loss."""
    from repro.configs.base import CELUConfig
    from repro.core import engine
    from repro.core.compression import (PlateauRatioSchedule,
                                        StochasticQuantCodec, TopKCodec)

    ccfg = CELUConfig()

    def transport(ratio, schedule=None):
        up = TopKCodec(ratio, value_codec=StochasticQuantCodec(8),
                       ratio_schedule=schedule)
        return engine.CompressedWANTransport(ccfg, up,
                                             StochasticQuantCodec(8))

    runs = {}
    for name, tp, hook in (
            ("fixed(0.0625)", transport(0.0625), None),
            ("fixed(0.25)", transport(0.25), None),
            ("adaptive(0.0625->0.5)",
             transport(0.0625, PlateauRatioSchedule(
                 ratios=(0.0625, 0.125, 0.25, 0.5), patience=2,
                 min_delta=2e-3)),
             lambda t, loss: t.scheduled(loss))):
        runs[name] = run_protocol("celu", data, cfg, R=5, W=5, xi=60.0,
                                  rounds=ADAPT_ROUNDS, lr=LR, batch=batch,
                                  eval_every=25, transport=tp,
                                  transport_hook=hook)
    target = float(_smooth(runs["fixed(0.25)"]["loss_curve"])[-1])
    csv_row(f"# adaptive_topk: celu R=5 W=5, {ADAPT_ROUNDS} rounds, "
            f"target loss {target:.4f} (fixed(0.25) final, smoothed)")
    csv_row("wire", "final_bytes_per_round", "total_MB",
            "rounds_to_target_loss", "final_loss", "final_auc")
    for name, r in runs.items():
        sm = _smooth(r["loss_curve"])
        hit = np.nonzero(sm <= target)[0]
        rt = int(hit[0]) + 1 if hit.size else f">{len(sm)}"
        csv_row(name, r["z_bytes_per_round"],
                f"{r['bytes_total'] / 1e6:.1f}", rt, f"{sm[-1]:.4f}",
                f"{r['final_auc']:.4f}")


BLOCKS = {
    "local_update": bench_local_update,
    "local_sampling": bench_local_sampling,
    "instance_weighting": bench_instance_weighting,
}


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--block", default=None,
                    choices=("all", "compression", "adaptive_topk")
                    + tuple(BLOCKS),
                    help="run one block instead of all")
    ap.add_argument("--compression", default=None, metavar="CODEC",
                    help="wire codec for the compression block, e.g. "
                         "int8_topk (implies --block compression; see "
                         "repro.core.compression.CODEC_SPECS)")
    args = ap.parse_args(argv)
    if args.compression and args.block not in (None, "all", "compression"):
        ap.error(f"--compression only applies to the compression block, "
                 f"not --block {args.block}")
    block = args.block or ("compression" if args.compression else "all")
    spec, data, cfg = default_workload("wdl", "criteo")
    if block in ("all", "compression"):
        bench_compression(data, cfg, args.compression or "int8_topk")
        if block == "compression":
            return
    if block in ("all", "adaptive_topk"):
        bench_adaptive_topk(data, cfg)
        if block == "adaptive_topk":
            return
    target, base = _target(data, cfg)
    for name, fn in BLOCKS.items():
        if block in ("all", name):
            fn(data, cfg, target, base)


if __name__ == "__main__":
    main()
