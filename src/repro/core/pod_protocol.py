"""Party-to-pod mapping: CELU-VFL as an SPMD program over the multi-pod mesh.

DESIGN §2: the production mesh is (pod=2, data=16, model=16); the slow
inter-pod DCN link plays the paper's WAN.  Party A lives on pod 0, Party B
on pod 1.  The cut-tensor exchange ⟨Z_A, ∇Z_A⟩ is a pair of
``lax.ppermute``s over the ``pod`` axis (``engine.PodTransport``) — the
ONLY collectives that cross the slow link.  Local updates read the
device-resident workset table and produce zero inter-pod traffic, so
collective bytes over ``pod`` per model update drop by ~(R+1)× (verified
from the lowered HLO by benchmarks/roofline.py).

The round itself is built by :func:`repro.core.engine.make_pod_round` —
the same exchange / Algorithm-2 weighting / local-update logic as the
host-sim engine path, specialised to the SPMD party-stacked layout.  This
module keeps the demo model: both parties' towers expressed as ONE
party-stacked pytree with a leading party axis sharded over ``pod``
(party p's weights physically live on pod p).  Each pod computes ITS
party's function on its shard inside ``shard_map``; Party A's head
produces Z_A, permuted to pod 1; pod 1 computes the top model +
per-instance loss, takes ∇Z_A, and permutes it back.  Labels are carried
in Party B's feature slot, so pod 0 never sees them — the
information-flow discipline holds at the device-placement level, not just
module level.

The demo task is the paper's WDL DLRM with equal-width towers (field counts
padded to max(F_A, F_B) with a dead field so the stacked shapes agree).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..optim import Optimizer
from . import engine
from .engine import PodTransport  # re-export (historical import site)


# --------------------------------------------------------------------------
# Party-stacked WDL: tower params with leading party axis (2, ...)
# --------------------------------------------------------------------------
def stacked_wdl_init(rng, n_fields: int, vocab: int, embed_dim: int,
                     z_dim: int, hidden: int):
    """Both parties' towers in one pytree, leading axis = party (2,)."""
    def one(k):
        ks = jax.random.split(k, 4)
        lim1 = 1.0 / jnp.sqrt(float(n_fields * embed_dim))
        lim2 = 1.0 / jnp.sqrt(float(hidden))
        return {
            "embed": jax.random.normal(
                ks[0], (n_fields, vocab, embed_dim), jnp.float32) * 0.01,
            "w1": jax.random.uniform(ks[1], (n_fields * embed_dim, hidden),
                                     jnp.float32, -lim1, lim1),
            "b1": jnp.zeros((hidden,), jnp.float32),
            "w2": jax.random.uniform(ks[2], (hidden, z_dim), jnp.float32,
                                     -lim2, lim2),
            "b2": jnp.zeros((z_dim,), jnp.float32),
        }
    ka, kb, kt = jax.random.split(rng, 3)
    towers = jax.tree_util.tree_map(
        lambda a, b: jnp.stack([a, b]), one(ka), one(kb))
    lim = 1.0 / jnp.sqrt(float(2 * z_dim))
    # top model: physically Party B's; stacked too (pod 0's copy is dead
    # weight that never receives gradient — keeps the pytree homogeneous)
    top = {
        "w1": jax.random.uniform(kt, (2, 2 * z_dim, z_dim), jnp.float32,
                                 -lim, lim),
        "b1": jnp.zeros((2, z_dim), jnp.float32),
        "w2": jax.random.normal(jax.random.fold_in(kt, 1),
                                (2, z_dim, 1), jnp.float32) * 0.01,
        "b2": jnp.zeros((2, 1), jnp.float32),
    }
    return {"tower": towers, "top": top}


def _tower_fwd(tp, x_fields):
    """tp: un-stacked (per-party) tower params; x_fields: (B, F) int32."""
    F = x_fields.shape[1]
    e = tp["embed"][jnp.arange(F)[None, :], x_fields]     # (B, F, E)
    h = jax.nn.relu(e.reshape(e.shape[0], -1) @ tp["w1"] + tp["b1"])
    return h @ tp["w2"] + tp["b2"]                        # (B, z_dim)


def _top_loss(top, z_a, z_b, y):
    """Per-instance logistic loss at Party B."""
    h = jnp.concatenate([z_a, z_b], axis=-1)
    h = jax.nn.relu(h @ top["w1"] + top["b1"])
    logit = (h @ top["w2"])[:, 0] + top["b2"][0]
    return jnp.maximum(logit, 0) - logit * y + jnp.log1p(
        jnp.exp(-jnp.abs(logit)))


# --------------------------------------------------------------------------
# One communication round inside shard_map (delegates to the engine)
# --------------------------------------------------------------------------
def make_pod_round(mesh: Mesh, opt: Optimizer, *, R: int, cos_xi: float,
                   weighting: bool = True,
                   transport: Optional[PodTransport] = None,
                   pipeline_depth: int = 0):
    """Build the jitted multi-pod CELU round over the WDL demo model.
    ``pipeline_depth=1`` issues the cut-tensor ppermute before the local
    scan so the DCN transfer overlaps the R local updates (engine
    docstring has the schedule)."""
    return engine.make_pod_round(mesh, opt, R=R, cos_xi=cos_xi,
                                 weighting=weighting, tower_fwd=_tower_fwd,
                                 top_loss=_top_loss, transport=transport,
                                 pipeline_depth=pipeline_depth)


def init_pod_state(rng, mesh: Mesh, opt: Optimizer, *, n_fields: int,
                   vocab: int, batch: int, W: int, embed_dim: int = 16,
                   z_dim: int = 64, hidden: int = 128,
                   cache_dtype: str = "float32"):
    """``cache_dtype`` sets the at-rest precision of the party-stacked
    z/dz rings ("float32" — bit-identical to the historical pod state —
    or "bfloat16", halving the cache; the round casts on read/write).
    The int8/int4 storage codecs are host-sim-engine only for now — the
    pod ring keeps a plain dtype so it shards as one leaf over the mesh
    (the quantized codecs carry a second scale leaf per ring and a
    packed-byte layout that the collective permutes would have to learn;
    core/workset.py + kernels/fused_sample.py own that path)."""
    if cache_dtype not in ("float32", "bfloat16"):
        raise ValueError(f"pod cache_dtype must be float32|bfloat16 "
                         f"(int8/int4 are host-engine codecs), "
                         f"got {cache_dtype!r}")
    params = stacked_wdl_init(rng, n_fields, vocab, embed_dim, z_dim, hidden)
    opt_state = opt.init(params)
    cd = jnp.dtype(cache_dtype)
    ws = {
        "z": jnp.zeros((2, W, batch, z_dim), cd),
        "dz": jnp.zeros((2, W, batch, z_dim), cd),
        "x": jnp.zeros((2, W, batch, n_fields), jnp.int32),
        "y": jnp.zeros((2, W, batch), jnp.float32),
        "time": jnp.zeros((2,), jnp.int32),
    }
    return params, opt_state, ws
