"""End-to-end VFL training driver.

Two modes:
  * DLRM (the paper's workloads): --arch wdl-criteo | dssm-avazu, trains on
    the synthetic vertically-partitioned stream with the selected protocol
    (vanilla | fedbcd | celu) and reports AUC + communication accounting
    (rounds, bytes, simulated-WAN seconds).
  * LLM backbones: --arch <assigned-id> trains a REDUCED variant on CPU for
    --steps rounds (the full configs are exercised by the dry-run only).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch wdl-criteo \
      --protocol celu --rounds 300 --R 5 --W 5 --xi 60
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --protocol celu --rounds 20 --reduced
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import SHAPES, get_config
from ..configs.base import ArchConfig, CELUConfig
from ..core import engine
from ..core import protocol as proto
from ..data import synthetic as synth
from ..models import vfl
from ..models.tabular import DLRMConfig, auc, make_dlrm
from ..optim import make_optimizer
from .wan import WANClock, transport_round_updown, wan_seconds  # noqa: F401

# Simulated-WAN wall-clock model (paper §2.1: 300 Mbps, gateway latency)
# lives in launch.wan — per-direction bandwidth + RTT, overlap-aware round
# latency.  ``wan_seconds(up_bytes, down_bytes)`` is re-exported above.
DEFAULT_WAN = WANClock()


def _as_jax(d: Dict[str, np.ndarray]):
    return {k: jnp.asarray(v) for k, v in d.items()}


def _fault_plan_from_args(args):
    """Build a ``FaultPlan`` from the --fault-* flags (None when no fault
    axis is set — the scheduler stays golden-identical)."""
    from ..configs.base import DropoutSpan, FaultPlan
    spans = []
    for s in args.fault_dropout or ():
        try:
            party, start, rounds = s.split(":")
            spans.append(DropoutSpan(party=party, start=int(start),
                                     rounds=int(rounds)))
        except ValueError:
            raise SystemExit(
                f"--fault-dropout wants PARTY:START:ROUNDS (e.g. "
                f"a0:40:5), got {s!r}")
    if not (args.fault_drop_prob or args.fault_straggler_prob or spans):
        return None
    return FaultPlan(seed=args.fault_seed,
                     drop_prob=args.fault_drop_prob,
                     max_retries=args.fault_max_retries,
                     straggler_prob=args.fault_straggler_prob,
                     straggler_rounds=args.fault_straggler_rounds,
                     dropouts=tuple(spans))


def _ckpt_extra_ref(n_pending: int, chaos: bool):
    """Structural reference for the checkpoint's extra pytree: the resume
    round, plus (chaos runs only) the scheduler's host bookkeeping with
    one arrival/dispatch entry per in-flight exchange."""
    extra = {"round": 0}
    if chaos:
        extra["host"] = {"now": 0, "dispatch_seq": 0,
                         "arrival": [0] * n_pending,
                         "dispatch_round": [0] * n_pending,
                         "last_merged_dispatch": 0}
    return extra


# --------------------------------------------------------------------------
def llm_task(cfg: ArchConfig, remat: bool = True) -> proto.VFLTask:
    """VFLTask over the LLM backbone split (text archs).  ``remat``
    toggles activation checkpointing of the tower scans (models.backbone
    Ctx.remat)."""
    def forward_a(pa, batch_a):
        return vfl.forward_a(pa, cfg, batch_a, train=True, remat=remat)

    def loss_b(pb, z_a, batch_b):
        return vfl.per_instance_loss(pb, cfg, z_a, batch_b, train=True,
                                     remat=remat)

    return proto.VFLTask(forward_a, loss_b)


def make_opt(args):
    """Optimizer from --optimizer/--lr/--opt-state-dtype; the state dtype
    only routes for adagrad (the paper's optimizer — sgd/adam/sm3 keep
    their native state)."""
    kw = {}
    if args.opt_state_dtype != "float32":
        if args.optimizer != "adagrad":
            raise SystemExit("--opt-state-dtype requires --optimizer "
                             "adagrad (sm3 is already factored; sgd/adam "
                             "keep fp32 state)")
        kw["state_dtype"] = args.opt_state_dtype
    return make_optimizer(args.optimizer, args.lr, **kw)


def train_dlrm(args) -> Dict[str, Any]:
    cfg: DLRMConfig = get_config(args.arch)
    if args.small:
        cfg = dataclasses.replace(cfg, vocab=128, embed_dim=8, z_dim=32,
                                  hidden=(64, 32))
    spec_name = {"wdl-criteo": "criteo", "dssm-avazu": "avazu"}[args.arch]
    spec = dataclasses.replace(synth.TABULAR_SPECS[spec_name],
                               vocab=cfg.vocab, n_train=args.n_train,
                               n_test=args.n_test)
    data = synth.make_tabular(spec, seed=args.seed)
    init_fn, task, predict = make_dlrm(cfg)

    base = CELUConfig(R=args.R, W=args.W, xi_degrees=args.xi,
                      weighting=not args.no_weighting,
                      compression=args.compression,
                      pipeline_depth=args.pipeline_depth,
                      pipeline_lr_damping=args.pipeline_lr_damping,
                      cache_dtype=args.cache_dtype,
                      cache_fused=not args.no_cache_fusion)
    celu_cfg, n_local = engine.preset_config(args.protocol, base)
    params = init_fn(jax.random.PRNGKey(args.seed), cfg)
    opt = make_opt(args)

    it = synth.aligned_batches(data["train"], args.batch_size,
                               seed=args.seed)
    _, ba0, bb0 = next(it)
    etask = engine.lift_two_party(task)
    transport = engine.make_transport(celu_cfg)
    state = engine.init_state(etask, engine.lift_two_party_params(params),
                              opt, celu_cfg, [_as_jax(ba0)], _as_jax(bb0),
                              transport=transport)
    from ..core.workset import QUANT_KEYS, workset_nbytes
    cache_stat_b = sum(workset_nbytes(w, QUANT_KEYS)
                       for w in state["ws"]["a"] + [state["ws"]["b"]])
    cache_total_b = sum(workset_nbytes(w)
                        for w in state["ws"]["a"] + [state["ws"]["b"]])
    print(f"[cache] workset tables: {cache_total_b / 1e6:.2f} MB "
          f"({cache_stat_b / 1e6:.2f} MB cut statistics at "
          f"{celu_cfg.cache_dtype}; fused sample "
          f"{'on' if celu_cfg.cache_fused else 'off'})", flush=True)
    depth = celu_cfg.pipeline_depth
    plan = _fault_plan_from_args(args)
    # chaos, checkpointing, and resume all need the explicit scheduler
    # object (ChaosEngine with plan=None is bit-identical to the base
    # pipeline, so the checkpoint paths reuse it at every depth)
    engineful = bool(depth) or plan is not None or args.checkpoint \
        or args.resume
    if engineful:
        from .. import checkpoint as ckpt
        from ..core.faults import ChaosEngine
        pe = ChaosEngine(etask, opt, celu_cfg, plan=plan, depth=depth,
                         local_steps=n_local, transport=transport)
        rs = pe.init(state)
    else:
        rnd = engine.make_round(etask, opt, celu_cfg, local_steps=n_local,
                                transport=transport, donate=True)
    start_round = 0
    if args.resume:
        n_pend = ckpt.peek_pending_len(args.resume)
        # fabricate a structural reference: same engine, n dispatches
        # (values are irrelevant — every leaf is overwritten)
        it_ref = synth.aligned_batches(data["train"], args.batch_size,
                                       seed=args.seed)
        rs_ref = rs
        for _ in range(n_pend):
            bi_r, ba_r, bb_r = next(it_ref)
            rs_ref = pe.dispatch(rs_ref, [_as_jax(ba_r)], _as_jax(bb_r),
                                 bi_r)
        rs, extra = ckpt.restore_round_state(
            args.resume, rs_ref,
            extra_reference=_ckpt_extra_ref(n_pend, plan is not None))
        start_round = int(extra["round"])
        if plan is not None:
            pe.load_host_state(extra["host"])
        else:   # fabrication advanced the (unused) chaos counters
            pe.load_host_state(_ckpt_extra_ref(0, True)["host"])
        print(f"[resume] {args.resume}: round {start_round}, "
              f"{n_pend} in-flight exchange(s)", flush=True)
    # per-direction wire accounting from the transport's explicit split
    # (asymmetric codecs: sparse sketches up, dense low-bit down)
    z_shapes = [(args.batch_size, cfg.z_dim)]
    up_bytes, down_bytes = transport_round_updown(transport, z_shapes)
    z_bytes = up_bytes + down_bytes

    te = data["test"]
    tea, teb = ({"x_a": jnp.asarray(te["x_a"])},
                {"x_b": jnp.asarray(te["x_b"]), "y": jnp.asarray(te["y"])})
    it = synth.aligned_batches(data["train"], args.batch_size,
                               seed=args.seed)
    for _ in range(start_round):    # deterministic stream: replay the
        next(it)                    # consumed prefix, bit-consistent
    t0 = time.time()
    history = []
    for i in range(start_round, args.rounds):
        bi, ba, bb = next(it)
        if engineful:
            rs, m = pe.step(rs, [_as_jax(ba)], _as_jax(bb), bi)
        else:
            state, m = rnd(state, [_as_jax(ba)], _as_jax(bb), bi)
        if args.checkpoint and (i + 1) % args.checkpoint_every == 0:
            extra = {"round": i + 1}
            if plan is not None:
                extra["host"] = pe.host_state()
            ckpt.save_round_state(args.checkpoint, rs, extra=extra)
        if (i + 1) % max(1, args.rounds // 10) == 0:
            cur = rs.params if engineful else state["params"]
            logits = predict(engine.unlift_params(cur), cfg, tea, teb)
            a = auc(np.asarray(logits), te["y"])
            history.append((i + 1, float(m["loss"]), a))
            print(f"round {i+1:6d} loss {float(m['loss']):.4f} "
                  f"AUC {a:.4f} local_steps {int(m.get('local_steps', 0))} "
                  f"w_mean {float(m.get('w_mean', 0)):.3f}", flush=True)
    if engineful:
        rs, _ = pe.flush(rs)
        state = pe.finalize(rs)
    if plan is not None:
        tel = pe.telemetry()
        print(f"[chaos] {tel['merges']} merges / {tel['dispatches']} "
              f"dispatches over {tel['rounds']} rounds: "
              f"{tel['drops']} drops, {tel['stalls']} stalls, "
              f"{tel['dropout_rounds']} dropout rounds, "
              f"{tel['wire_attempts']} wire attempts", flush=True)
    wall = time.time() - t0
    # overlap-aware simulated wall-clock: split the measured compute into
    # the exchange share (1 fresh update) and the local share (n_local
    # updates); the clock serializes them with the wire at depth 0 and
    # charges max(exchange, local) at depth >= 1
    compute_per_round = wall / max(args.rounds, 1)
    ex_c = compute_per_round / (1 + n_local)
    loc_c = compute_per_round - ex_c
    comm_s = DEFAULT_WAN.time_to_target(
        args.rounds, up_bytes, down_bytes, exchange_compute_s=ex_c,
        local_compute_s=loc_c, pipeline_depth=depth)
    seq_s = DEFAULT_WAN.time_to_target(
        args.rounds, up_bytes, down_bytes, exchange_compute_s=ex_c,
        local_compute_s=loc_c, pipeline_depth=0)
    # chaos runs charge the wire per ATTEMPT (retries re-send; dropout/
    # stall rounds send nothing)
    wire_rounds = pe.counters["wire_attempts"] if plan is not None \
        else args.rounds
    out = {
        "arch": args.arch, "protocol": args.protocol,
        "rounds": args.rounds, "final_auc": history[-1][2] if history else None,
        "comm_bytes": wire_rounds * z_bytes,
        "uplink_bytes": wire_rounds * up_bytes,
        "downlink_bytes": wire_rounds * down_bytes,
        "fault_telemetry": pe.telemetry() if plan is not None else None,
        "sim_wan_s": comm_s, "sim_wan_sequential_s": seq_s,
        "pipeline_depth": depth, "compute_wall_s": wall,
        "history": history,
    }
    pipe_note = (f" (sequential would be {seq_s:.1f}s -> "
                 f"{seq_s / comm_s:.2f}x overlap win)") if depth else ""
    auc_note = "n/a" if out["final_auc"] is None \
        else f"{out['final_auc']:.4f}"
    print(f"[done] {args.protocol}: AUC={auc_note} "
          f"comm={out['comm_bytes']/1e6:.1f}MB "
          f"(up {up_bytes/1e3:.0f}KB/dn {down_bytes/1e3:.0f}KB per round) "
          f"simWAN={comm_s:.1f}s wall={wall:.1f}s{pipe_note}")
    return out


def train_llm(args) -> Dict[str, Any]:
    cfg: ArchConfig = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family in ("vlm", "audio"):
        raise SystemExit("protocol training demo uses text-family archs; "
                         "vlm/audio exercise the serving path "
                         "(launch.serve) and the dry-run")
    B, S = args.batch_size, args.seq_len
    data = synth.make_token_stream(max(B * 8, 64), S, cfg.vocab_size,
                                   cfg.aux_vocab_size, seed=args.seed)
    task = llm_task(cfg, remat=args.remat)
    base = CELUConfig(R=args.R, W=args.W, xi_degrees=args.xi,
                      weighting=not args.no_weighting,
                      compression=args.compression,
                      pipeline_depth=args.pipeline_depth,
                      pipeline_lr_damping=args.pipeline_lr_damping,
                      cache_dtype=args.cache_dtype,
                      cache_fused=not args.no_cache_fusion)
    celu_cfg, n_local = engine.preset_config(args.protocol, base)
    params = vfl.init_all(jax.random.PRNGKey(args.seed), cfg)
    opt = make_opt(args)

    it = synth.token_batches(data, B, seed=args.seed)
    _, ba0, bb0 = next(it)
    etask = engine.lift_two_party(task)
    state = engine.init_state(etask, engine.lift_two_party_params(params),
                              opt, celu_cfg, [_as_jax(ba0)], _as_jax(bb0))
    depth = celu_cfg.pipeline_depth
    if depth:
        pe = engine.make_pipeline(etask, opt, celu_cfg, depth=depth,
                                  local_steps=n_local)
        rs = pe.init(state)
    else:
        rnd = engine.make_round(etask, opt, celu_cfg, local_steps=n_local,
                                donate=True)
    it = synth.token_batches(data, B, seed=args.seed)
    losses = []
    for i in range(args.rounds):
        bi, ba, bb = next(it)
        if depth:
            rs, m = pe.step(rs, [_as_jax(ba)], _as_jax(bb), bi)
        else:
            state, m = rnd(state, [_as_jax(ba)], _as_jax(bb), bi)
        losses.append(float(m["loss"]))
        if (i + 1) % max(1, args.rounds // 10) == 0:
            print(f"round {i+1:4d} loss {losses[-1]:.4f}", flush=True)
    if depth:
        rs, _ = pe.flush(rs)       # drain the last in-flight local scan
        state = pe.finalize(rs)    # train_dlrm pattern: state holds the
                                   # drained model for future extension
    print(f"[done] {args.arch} {args.protocol}: "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return {"arch": args.arch, "losses": losses}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--protocol", default="celu",
                    choices=("vanilla", "fedbcd", "celu"))
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--R", type=int, default=5)
    ap.add_argument("--W", type=int, default=5)
    ap.add_argument("--xi", type=float, default=60.0)
    ap.add_argument("--no-weighting", action="store_true")
    ap.add_argument("--compression", default="", metavar="CODEC",
                    help="wire codec for the simulated WAN (e.g. int8_topk;"
                         " see repro.core.compression.CODEC_SPECS)")
    ap.add_argument("--pipeline-depth", type=int, default=0, metavar="D",
                    help="0 = sequential rounds; 1 = overlap round t+1's "
                         "WAN exchange with round t's local updates "
                         "(paper §4.1 two-worker pipeline); D >= 2 = a "
                         "D-deep queue of in-flight exchanges for "
                         "high-RTT links where one exchange cannot hide "
                         "behind one local scan.  Every cached entry gets "
                         "D exchanges staler, so D >= 2 trades rounds for "
                         "wall-clock: weights are attenuated w -> w^(1+s) "
                         "per slot and updates lr-damped by "
                         "1/(1 + c*s) (see --pipeline-lr-damping); D must "
                         "stay < W")
    ap.add_argument("--pipeline-lr-damping", type=float, default=0.25,
                    metavar="C",
                    help="staleness-aware lr damping coefficient c of the "
                         "eta/(1 + c*s) schedule applied to local and "
                         "fresh updates on the depth-D (D >= 2) pipeline; "
                         "0 disables (depths 0/1 never damp)")
    ap.add_argument("--cache-dtype", default="float32",
                    choices=("float32", "bfloat16", "int8", "int4"),
                    help="at-rest precision of the workset cache (int8 = "
                         "SR-quantized codes + fp32 per-row scales, ~4x "
                         "smaller; int4 nibble-packs two codes per byte, "
                         "~8x smaller; core/workset.py storage codec)")
    ap.add_argument("--no-cache-fusion", action="store_true",
                    help="disable the fused gather→dequant→weight sample "
                         "megakernel (pin the materializing reference "
                         "path)")
    ap.add_argument("--fault-drop-prob", type=float, default=0.0,
                    metavar="P",
                    help="per-attempt exchange drop probability of the "
                         "chaos layer (core/faults.py); any --fault-* "
                         "axis switches the scheduler to the seeded "
                         "ChaosEngine")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed of the deterministic fault schedule")
    ap.add_argument("--fault-max-retries", type=int, default=2,
                    help="wire retries per exchange before the round's "
                         "update is abandoned (residuals absorb it)")
    ap.add_argument("--fault-straggler-prob", type=float, default=0.0,
                    metavar="P",
                    help="probability a delivered exchange arrives late")
    ap.add_argument("--fault-straggler-rounds", type=int, default=2,
                    help="max rounds of straggler delay")
    ap.add_argument("--fault-dropout", action="append", default=[],
                    metavar="PARTY:START:ROUNDS",
                    help="drop a party for a span of rounds (repeatable), "
                         "e.g. a0:40:5 or b:100:10; the survivors keep "
                         "local-updating on cached statistics")
    ap.add_argument("--checkpoint", default="", metavar="PATH",
                    help="save the FULL round state (params, optimizer, "
                         "worksets, transport residuals, in-flight "
                         "exchange queue) to PATH every "
                         "--checkpoint-every rounds; restored runs are "
                         "bit-consistent")
    ap.add_argument("--checkpoint-every", type=int, default=50,
                    metavar="N")
    ap.add_argument("--resume", default="", metavar="PATH",
                    help="resume from a --checkpoint file (bit-exact: "
                         "same flags, same seed)")
    ap.add_argument("--optimizer", default="adagrad",
                    choices=("adagrad", "sgd", "adam", "sm3"))
    ap.add_argument("--opt-state-dtype", default="float32",
                    choices=("float32", "bfloat16", "int8"),
                    help="at-rest precision of the AdaGrad accumulator "
                         "(int8 = sqrt-space codes + fp32 per-row master "
                         "scales through the fused requant kernel, ~4x "
                         "smaller; optim/quantized.py)")
    ap.add_argument("--remat", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="activation-checkpoint the LLM tower scans "
                         "(recompute in backward; --no-remat stores all "
                         "activations)")
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--small", action="store_true",
                    help="smaller DLRM dims for quick CPU runs")
    ap.add_argument("--n-train", type=int, default=32768)
    ap.add_argument("--n-test", type=int, default=8192)
    args = ap.parse_args(argv)

    if args.arch in ("wdl-criteo", "dssm-avazu"):
        return train_dlrm(args)
    return train_llm(args)


if __name__ == "__main__":
    main()
