"""Open-loop synthetic load generator for the serving engine.

Open loop means arrivals are INDEPENDENT of service: requests land on a
seeded Poisson clock (exponential inter-arrivals at ``rate`` req/s)
whether or not the engine keeps up, so queueing delay shows up in the
latency percentiles instead of being hidden by back-pressure — the
standard methodology for serving benchmarks.  Prompts are uniform token
ids at exactly ``prompt_len`` (one compiled admit for every request);
generation lengths draw uniformly from [1, max_new_tokens] so the lane
array actually churns (admit/evict mid-flight), which is the behavior
the continuous-batching claim is about.

Everything derives from ``seed`` — a load is a pure function of its
spec, so benchmark runs and tests replay identical traffic.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from ..configs.base import ArchConfig
from .engine import Request


@dataclass(frozen=True)
class LoadSpec:
    """Synthetic open-loop load: ``n_requests`` arrivals at ``rate``
    req/s (virtual seconds), prompts of ``prompt_len`` tokens, per-request
    generation length uniform in [``min_new_tokens``, ``max_new_tokens``].
    ``rate <= 0`` drops all arrivals to t=0 (a closed burst — the
    throughput-measurement mode)."""
    n_requests: int = 32
    rate: float = 50.0
    prompt_len: int = 16
    max_new_tokens: int = 16
    min_new_tokens: int = 1
    seed: int = 0


def synth_requests(spec: LoadSpec, cfg: ArchConfig) -> List[Request]:
    """-> the seeded request list (sorted by arrival, req_id = arrival
    order)."""
    if spec.min_new_tokens < 1 or spec.max_new_tokens < spec.min_new_tokens:
        raise ValueError("need 1 <= min_new_tokens <= max_new_tokens")
    rng = np.random.default_rng(spec.seed)
    if spec.rate > 0:
        arrivals = np.cumsum(rng.exponential(1.0 / spec.rate,
                                             spec.n_requests))
    else:
        arrivals = np.zeros(spec.n_requests)
    prompts = rng.integers(0, cfg.vocab_size,
                           (spec.n_requests, spec.prompt_len), dtype=np.int32)
    prompts_a = rng.integers(0, cfg.aux_vocab_size,
                             (spec.n_requests, spec.prompt_len),
                             dtype=np.int32)
    gen = rng.integers(spec.min_new_tokens, spec.max_new_tokens + 1,
                       spec.n_requests)
    return [Request(req_id=i, prompt=prompts[i], prompt_a=prompts_a[i],
                    max_new_tokens=int(gen[i]), arrival=float(arrivals[i]))
            for i in range(spec.n_requests)]
