"""Jaxpr markers: how the auditor sees sanitizer stages and boundary
crossings inside a trace WITHOUT touching production numerics.

The engine's transports and codecs are ordinary Python objects whose ops
disappear into an undifferentiated soup of ``mul``/``convert_element_type``
eqns once traced.  To audit them statically we bind an identity primitive
(``audit_mark``) around the values of interest — but ONLY inside
:func:`instrumented`, an analyzer-scoped context manager that monkeypatches
the registered implementations:

  * ``privacy.wire_noise``          -> sanitizer mark ``dp``
  * ``SimWANTransport._wire_cast``  -> sanitizer mark ``wire``
  * every codec class ``encode``    -> sanitizer mark ``encode`` on the
                                       payload leaves
  * ``workset._encode_leaf``        -> sanitizer mark ``cache`` (declares
                                       the at-rest storage casts)
  * ``PodTransport.send_up/down``   -> boundary mark on the ppermute output

and by wrapping the engine-side transport object in
:class:`AuditedTransport`, which marks every ``send`` result as a
``boundary`` crossing carrying the sanitizer requirements the config
implies.  Production code paths never import this module; the golden
traces cannot see the marks.

The split matters for mutation coverage: sanitizer marks live INSIDE the
registered implementations, the boundary mark lives in the engine-side
proxy — so a mutated transport that skips the registered pipeline still
gets its output marked as a boundary, now carrying unsanitized raw taint.
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.interpreters import mlir

try:  # jax >= 0.4.34
    from jax.extend.core import Primitive
except ImportError:  # pragma: no cover - older jax
    from jax.core import Primitive  # type: ignore[no-redef]

# Identity primitive: abstract-eval and lowering both pass the operand
# through, so a marked trace computes exactly what the unmarked one does.
mark_p = Primitive("audit_mark")
mark_p.def_impl(lambda x, **_: x)
mark_p.def_abstract_eval(lambda aval, **_: aval)
mlir.register_lowering(mark_p, lambda ctx, x, **_: [x])

# vmap rule: the mark rides the batched operand unchanged, so sanitizer
# and boundary marks survive a leading fleet/job axis — the batched-state
# audit (audit.trace_fleet_case) traces vmapped schedules through the
# same taint pass, with boundary avals carrying the job axis.
from jax.interpreters import batching  # noqa: E402

batching.primitive_batchers[mark_p] = \
    lambda args, dims, **params: (mark_p.bind(args[0], **params), dims[0])

# Sanitizer names whose marks "declare" a narrowing precision cast (the
# kernel-contract cast lint whitelists casts flowing into these).
DECLARED_CAST_STAGES = ("wire", "encode", "cache")


def _arrayish(v: Any) -> bool:
    import numpy as np
    return isinstance(v, (jax.Array, np.ndarray)) or hasattr(v, "aval")


def mark(x, *, role: str, name: str, meta: Tuple = ()):
    """Bind ``audit_mark`` over every array leaf of ``x`` (identity)."""
    return jax.tree_util.tree_map(
        lambda leaf: mark_p.bind(leaf, role=role, name=name, meta=meta)
        if _arrayish(leaf) else leaf, x)


def boundary_requirements(tp, celu, direction: str) -> Tuple[str, ...]:
    """The sanitizer stages a raw value must pass before THIS transport
    may release it in ``direction`` — the taint pass's required pattern.

    Registering a new transport = teaching this function (and
    :func:`instrumented` below, if it adds new sanitizer stages) what its
    sends promise; see docs/ANALYSIS.md."""
    from ..core.engine import CompressedWANTransport
    req = ["wire"]
    if isinstance(tp, CompressedWANTransport) and \
            not getattr(tp.codecs[direction], "exact", False):
        req.append("encode")
    if celu.dp_sigma > 0.0:
        req.append("dp")
    return tuple(req)


def boundary_order(tp, celu, direction: str) -> Tuple[Tuple[str, str], ...]:
    """(before, after) sanitizer-ordering constraints at this boundary.

    With a lossy codec under DP the noise must be applied AFTER the
    encode/decode round-trip (on the decoded wire value, residual already
    taken) — noising first both wastes wire bits on noise and lets error
    feedback cancel the mechanism across rounds."""
    from ..core.engine import CompressedWANTransport
    if (isinstance(tp, CompressedWANTransport) and celu.dp_sigma > 0.0
            and not getattr(tp.codecs[direction], "exact", False)):
        return (("encode", "dp"),)
    return ()


class AuditedTransport:
    """Transparent engine-side proxy: forwards everything to the wrapped
    transport and boundary-marks each send's released value (and new
    residual) with the party index, direction, and requirements."""

    def __init__(self, tp, celu):
        self._tp = tp
        self._celu = celu
        self._counts: Dict[str, int] = {}

    def __getattr__(self, name):
        return getattr(self._tp, name)

    def send(self, rng, x, res=None, direction: str = "up"):
        y, new_res = self._tp.send(rng, x, res, direction)
        party = self._counts.get(direction, 0)
        self._counts[direction] = party + 1
        meta = (("direction", direction), ("party", party),
                ("require", boundary_requirements(self._tp, self._celu,
                                                 direction)),
                ("order", boundary_order(self._tp, self._celu, direction)),
                ("transport", type(self._tp).__name__))
        y = mark(y, role="boundary", name=f"{direction}:{party}", meta=meta)
        return y, new_res


class AuditedPodTransport:
    """Same idea for the SPMD pod path: the boundary is the ppermute
    output.  The pod link is in-datacenter DCN with no codec/DP stage
    registered yet, so the requirement set is empty — the audit's value
    here is the host rule plus the collective whitelist (taint.py checks
    no OTHER collective crosses the pod axis)."""

    def __init__(self, tp):
        self._tp = tp
        self._n = 0

    def __getattr__(self, name):
        return getattr(self._tp, name)

    def send_up(self, z):
        y = self._tp.send_up(z)
        self._n += 1
        return mark(y, role="boundary", name=f"up:{self._n - 1}",
                    meta=(("direction", "up"), ("party", self._n - 1),
                          ("require", ()), ("order", ()),
                          ("transport", type(self._tp).__name__)))

    def send_down(self, dz):
        y = self._tp.send_down(dz)
        self._n += 1
        return mark(y, role="boundary", name=f"down:{self._n - 1}",
                    meta=(("direction", "down"), ("party", self._n - 1),
                          ("require", ()), ("order", ()),
                          ("transport", type(self._tp).__name__)))


@contextlib.contextmanager
def instrumented():
    """Patch the registered sanitizer implementations to mark their
    outputs, for the duration of an analyzer trace.  Reentrant-unsafe by
    design (asserts on double entry); always restores on exit."""
    from ..core import compression as C
    from ..core import engine as E
    from ..core import privacy as P
    from ..core import workset as W

    patched: list[tuple[Any, str, Any]] = []

    def patch(owner, attr, wrapper):
        orig = getattr(owner, attr)
        patched.append((owner, attr, orig))
        setattr(owner, attr, wrapper(orig))
        return orig

    # privacy: the DP-noise stage.  privatize routes through the module
    # global wire_noise, and the transports look privatize up at call
    # time, so this one patch covers both the plain-SimWAN path and the
    # compressed transport's noise-after-decode path.
    patch(P, "wire_noise",
          lambda orig: lambda rng, y, cfg: mark(
              orig(rng, y, cfg), role="sanitizer", name="dp"))

    # wire stage: the dtype round-trip every send path shares.
    patch(E.SimWANTransport, "_wire_cast",
          lambda orig: lambda self, x: mark(
              orig(self, x), role="sanitizer", name="wire"))

    # codec encodes: the payload leaves are what the wire carries.
    for cls in (C.IdentityCodec, C.StochasticQuantCodec, C.TopKCodec,
                C.ChainCodec):
        patch(cls, "encode",
              lambda orig: lambda self, rng, x: mark(
                  orig(self, rng, x), role="sanitizer", name="encode"))

    # workset storage codec: at-rest narrowing casts are declared here.
    patch(W, "_encode_leaf",
          lambda orig: lambda store, x, rng: mark(
              orig(store, x, rng), role="sanitizer", name="cache"))

    try:
        yield
    finally:
        for owner, attr, orig in reversed(patched):
            setattr(owner, attr, orig)
