"""Local-update hot-path benchmark -> ``results/BENCH_local_scan.json``.

The local scan (the R staleness-weighted updates per party per round) is
the dominant on-device loop once the wire is compressed (PR 2) and
pipelined (PR 3): it executes ``n_local x (K+1)`` model updates per
communication round against the workset cache.  This block measures it in
isolation — the jitted ``local_scan`` stage, not the full round — across
the cache configurations:

  * ``fp32_unfused``  — fp32 table, materialize-then-weight (the PR-3
    hot path: the baseline the megakernel replaces);
  * ``fp32_fused``    — fp32 table through the gather→weight megakernel
    (bit-identical numerics, one HBM pass);
  * ``int8_fused``    — int8-at-rest table through the megakernel
    (one pass over ~4x fewer bytes).

Each variant reports the measured wall per local-scan call (CPU —
indicative only; the Pallas kernels run interpreted here), the table's
actual device bytes (total and cut-statistics-only), and the analytic
roofline counters (``workset.sample_hbm_bytes``): HBM bytes one party-A
sample moves, and per round.  The JSON is emitted so the perf trajectory
is tracked PR-over-PR (CI uploads it next to coverage).
"""
from __future__ import annotations

import json
import os
import time

from .common import csv_row

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "BENCH_local_scan.json")

VARIANTS = (
    ("fp32_unfused", "float32", False),
    ("fp32_fused", "float32", True),
    ("int8_fused", "int8", True),
)

B, Z_DIM, W, R = 256, 32, 5, 5
FILL_ROUNDS = 5          # fill the table before timing the scan alone
TIMED_CALLS = 10


def _bench_one(cache_dtype: str, cache_fused: bool):
    import jax
    import jax.numpy as jnp

    from repro.configs.base import CELUConfig
    from repro.core import engine
    from repro.core.workset import (QUANT_KEYS, sample_hbm_bytes,
                                    workset_nbytes)
    from repro.data import synthetic as synth
    from repro.models.tabular import DLRMConfig, make_dlrm
    from repro.optim import make_optimizer

    import dataclasses
    spec = dataclasses.replace(synth.TABULAR_SPECS["criteo"], vocab=128,
                               n_train=4096, n_test=512)
    data = synth.make_tabular(spec, seed=0)
    cfg = DLRMConfig("wdl", spec.fields_a, spec.fields_b, vocab=128,
                     embed_dim=8, z_dim=Z_DIM, hidden=(64, 32))
    init_fn, task, _ = make_dlrm(cfg)
    celu = CELUConfig(R=R, W=W, cache_dtype=cache_dtype,
                      cache_fused=cache_fused)
    params = init_fn(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adagrad", 0.01)
    etask = engine.lift_two_party(task)
    it = synth.aligned_batches(data["train"], B, seed=0)
    _, ba, bb = next(it)
    asj = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
    tp = engine.make_transport(celu)
    state = engine.init_state(etask, engine.lift_two_party_params(params),
                              opt, celu, [asj(ba)], asj(bb), transport=tp)
    rnd = engine.make_round(etask, opt, celu, transport=tp)
    it = synth.aligned_batches(data["train"], B, seed=0)
    for _ in range(FILL_ROUNDS):
        bi, ba, bb = next(it)
        state, _ = rnd(state, [asj(ba)], asj(bb), bi)

    # the isolated jitted local-scan stage (what the megakernel targets)
    _, _, local_scan = engine._make_stages(etask, opt, celu, n_local=R,
                                           tp=tp, fused=True)
    scan = jax.jit(local_scan)
    out, _ = scan(state)
    jax.block_until_ready(out["params"]["b"])
    t0 = time.time()
    for _ in range(TIMED_CALLS):
        out, _ = scan(state)
    jax.block_until_ready(out["params"]["b"])
    scan_ms = (time.time() - t0) / TIMED_CALLS * 1e3

    tables = list(state["ws"]["a"]) + [state["ws"]["b"]]
    z_like = jnp.zeros((B, Z_DIM), jnp.float32)
    entry = {"z": z_like, "dz": z_like}
    step_bytes = sample_hbm_bytes(entry, cache_dtype, fused=cache_fused)
    # per round: R steps x (party A fused-or-not + party B, which always
    # materializes its entry for the loss)
    b_bytes = sample_hbm_bytes(entry, cache_dtype, fused=False)
    return {
        "cache_dtype": cache_dtype,
        "cache_fused": cache_fused,
        "local_scan_ms": round(scan_ms, 3),
        "local_step_ms": round(scan_ms / (2 * R), 4),   # K+1 = 2 parties
        "cache_bytes": sum(workset_nbytes(w) for w in tables),
        "stat_cache_bytes": sum(workset_nbytes(w, QUANT_KEYS)
                                for w in tables),
        "sample_hbm_bytes_per_step": step_bytes,
        "hbm_bytes_per_round": R * (step_bytes + b_bytes),
    }


def main():
    csv_row("# local_scan hot path (B=%d z=%d W=%d R=%d; CPU wall is"
            " indicative — Pallas interpreted)" % (B, Z_DIM, W, R))
    csv_row("variant", "local_step_ms", "cache_bytes", "stat_cache_bytes",
            "sample_hbm_B/step", "hbm_B/round")
    variants = {}
    for name, cd, fused in VARIANTS:
        r = _bench_one(cd, fused)
        variants[name] = r
        csv_row(name, r["local_step_ms"], r["cache_bytes"],
                r["stat_cache_bytes"], r["sample_hbm_bytes_per_step"],
                r["hbm_bytes_per_round"])
    ratios = {
        "stat_cache_bytes_fp32_over_int8":
            round(variants["fp32_fused"]["stat_cache_bytes"]
                  / variants["int8_fused"]["stat_cache_bytes"], 3),
        "sample_hbm_bytes_unfused_fp32_over_fused_int8":
            round(variants["fp32_unfused"]["sample_hbm_bytes_per_step"]
                  / variants["int8_fused"]["sample_hbm_bytes_per_step"], 3),
        "sample_hbm_bytes_unfused_fp32_over_fused_fp32":
            round(variants["fp32_unfused"]["sample_hbm_bytes_per_step"]
                  / variants["fp32_fused"]["sample_hbm_bytes_per_step"], 3),
    }
    out = {
        "geometry": {"B": B, "z_dim": Z_DIM, "W": W, "R": R, "K": 1,
                     "timed_calls": TIMED_CALLS},
        "variants": variants,
        "ratios": ratios,
    }
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    csv_row("# ratios: " + ", ".join(f"{k}={v}" for k, v in ratios.items()))
    csv_row(f"# wrote {os.path.normpath(RESULTS)}")


if __name__ == "__main__":
    main()
