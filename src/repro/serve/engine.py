"""Continuous-batching split-model serving over the party boundary.

The decode loop IS the paper's exchange pattern, one token at a time:
Party A's tower produces the cut activation ``z`` for the new position,
``z`` crosses the WAN (the serving uplink), Party B fuses it and emits
the next token (the downlink).  This module makes that loop production
shaped:

  * **Continuous batching** — a fixed-capacity lane array (the same
    fixed-shape trick as ``fleet/scheduler.py``'s stacked exchange
    queue): every lane holds one in-flight request's decode state
    (stacked B=1 KV caches, position, last token, tokens remaining),
    requests admit into free lanes and evict mid-flight as they finish,
    and the decode step stays ONE compiled XLA program at every
    occupancy (``jax.vmap`` over lanes — per-lane positions rule out a
    single native batch, whose KV ring cursor is shared across rows).
  * **Cross-party decode activation cache** — the per-step ``z`` rows
    land in a :mod:`repro.core.workset` ring (one row per lane, the
    lane IS the ring's batch dim), stored through the same at-rest
    codecs as training (fp32 / bf16 / int8 ``QuantLeaf`` / int4
    ``Quant4Leaf``) and read back through the fused gather→dequant
    Pallas kernels — Party B's fusion consumes the CACHED activation,
    so with ``refresh_every > 1`` stale ring rows stand in for wire
    exchanges exactly like the paper's cached local updates.
  * **Compressed serving wire** — the uplink ``z`` goes through the PR-2
    codec stack (int8 stochastic rounding by default) per lane row, so
    per-request byte accounting is exact: ``wire_bytes((d,))`` per
    decode token, ``wire_bytes((S, d))`` per prefill.  The downlink is
    one token id (4 bytes, identity by contract — stochastic-rounding a
    categorical id would corrupt it; the down payload is already
    smaller than any code for it).

The engine supports the token-aligned (fusion="add") families, where a
cut activation crosses per decode step.  Cross-attention families (vlm /
audio) exchange their memory once at prefill and decode entirely on
Party B — :func:`naive_generate` serves those; there is no per-step
activation to cache.

Determinism: admissions are FIFO into the lowest free lane, the decode
schedule is a pure function of the request list, and all stochastic
rounding derives from the engine seed — two runs over the same requests
produce identical tokens and ledgers.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, CELUConfig
from ..core import engine as core_engine
from ..core import workset as WS
from ..models import vfl


# --------------------------------------------------------------------------
# Config / request / completion records
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class ServeConfig:
    """Serving knobs.  ``compression`` is the UPLINK codec spec (the
    downlink token id always rides the identity codec — see module
    docstring); ``cache_dtype`` picks the decode activation ring's
    at-rest storage; ``refresh_every`` R sends ``z`` up every R-th decode
    step and serves Party B from the stale ring row in between (R=1 is
    exchange-every-step; R>1 trades greedy fidelity for R-fold fewer
    uplink bytes per token)."""
    capacity: int = 8              # concurrent decode lanes
    prompt_len: int = 16           # fixed prompt length (one compile)
    max_new_tokens: int = 16       # per-request ceiling (sizes KV rings)
    compression: str = "int8"      # uplink codec spec; "" = fp32 wire
    cache_dtype: str = "int8"      # activation ring storage codec
    ring_slots: int = 4            # W slots in the activation ring
    refresh_every: int = 1         # uplink cadence (1 = every step)
    seed: int = 0


@dataclass(frozen=True)
class Request:
    """One serving request.  ``prompt`` / ``prompt_a`` must be exactly
    ``ServeConfig.prompt_len`` tokens (the load generator pads); the
    request completes after ``max_new_tokens`` generated tokens.
    ``arrival`` is the open-loop virtual arrival time in seconds."""
    req_id: int
    prompt: np.ndarray
    prompt_a: np.ndarray
    max_new_tokens: int
    arrival: float = 0.0


@dataclass
class Completion:
    """Per-request ledger: generated tokens, exact wire bytes, and the
    virtual-clock timeline (arrival -> admit -> per-token -> done)."""
    req_id: int
    tokens: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    wire_up_bytes: int = 0
    wire_down_bytes: int = 0
    arrival: float = 0.0
    admitted_at: float = 0.0
    finished_at: float = 0.0
    token_times: List[float] = field(default_factory=list)


# --------------------------------------------------------------------------
# Pure step functions (importable by the boundary auditor)
# --------------------------------------------------------------------------
def _ring_read(buf, width: int):
    """Newest-slot gather + decode of the activation ring's ``z`` store
    -> (C, d) fp32 rows.  Quantized stores go through the fused Pallas
    gather→dequant kernels (no full-precision ring copy in HBM)."""
    def read(slot):
        from ..kernels import ops as kops
        if isinstance(buf, WS.QuantLeaf):
            return kops.fused_gather_dequant_q8(slot, buf.q, buf.scale)
        if isinstance(buf, WS.Quant4Leaf):
            return kops.fused_gather_dequant_q4(slot, buf.q, buf.scale,
                                                width)
        if isinstance(buf, WS.CastLeaf):
            return buf.v[slot].astype(jnp.float32)
        return buf[slot]
    return read


def make_admit_fn(cfg: ArchConfig, scfg: ServeConfig, tp):
    """-> pure ``admit(params, state, lane, tokens, tokens_a, n_new,
    rng)``: B=1 prefill of both parties (the prompt's ``z`` crosses the
    uplink once), first greedy token down, then the request's decode
    state written into lane ``lane`` of the fixed-capacity state."""
    total_len = scfg.prompt_len + scfg.max_new_tokens

    def admit(params, state, lane, tokens, tokens_a, n_new, rng):
        batch = {"tokens": tokens, "tokens_a": tokens_a}
        z, cache_a = vfl.prefill_a(params["a"], cfg, batch, total_len)
        y, _ = tp.send(rng, z[0], None, "up")          # (S, d) crossing
        logits, cache_b = vfl.prefill_b(params["b"], cfg, y[None], batch,
                                        total_len)
        tok = jnp.argmax(logits[0, -1], -1).astype(jnp.int32)
        down, _ = tp.send(jax.random.fold_in(rng, 1),
                          tok.astype(jnp.float32)[None], None, "down")
        tok_a = jnp.mod(down[0].astype(jnp.int32), cfg.aux_vocab_size)

        put = lambda full, one: jax.lax.dynamic_update_index_in_dim(
            full, one, lane, 0)
        new = dict(state)
        new["cache_a"] = jax.tree_util.tree_map(put, state["cache_a"],
                                                cache_a)
        new["cache_b"] = jax.tree_util.tree_map(put, state["cache_b"],
                                                cache_b)
        new["ws"] = _ring_clear_lane(state["ws"], lane)
        new["active"] = state["active"].at[lane].set(n_new > 1)
        new["pos"] = state["pos"].at[lane].set(jnp.int32(scfg.prompt_len))
        new["token"] = state["token"].at[lane].set(tok)
        new["token_a"] = state["token_a"].at[lane].set(tok_a)
        new["remaining"] = state["remaining"].at[lane].set(n_new - 1)
        return new, tok

    return admit


def make_step_fn(cfg: ArchConfig, scfg: ServeConfig, tp, exchange: bool):
    """-> pure ``step(params, state, rng)`` — ONE decode token for every
    lane, as one program.  ``exchange=True``: each lane's fresh ``z`` row
    crosses the uplink and is inserted into the activation ring;
    ``exchange=False``: Party A still advances its KV cache (compute is
    local) but nothing crosses — Party B is served from the newest CACHED
    ring row (the paper's stale-reuse, transplanted to decode).  Either
    way Party B reads the ring through the storage codec, the next token
    goes down the wire, and Party A derives its next aux token from it.

    Returns (new_state, tokens (C,), produced (C,) bool) — ``produced``
    flags the lanes whose token this step is real (active at entry)."""
    C = scfg.capacity
    d = cfg.d_model

    def decode_a(params_a, cache_a, token_a, pos):
        z, new_cache = vfl.decode_step_a(params_a, cfg, cache_a,
                                         token_a.reshape(1, 1), pos)
        return z[0, 0], new_cache                      # (d,)

    def decode_b(params_b, cache_b, token, z_row, pos):
        # the ring decodes to fp32; the model computes in PARAM_DTYPE.
        # bf16 -> f32 -> bf16 is lossless, so the fp32-ring path stays
        # bit-identical to fusing the tower output directly.
        from ..models.initializers import PARAM_DTYPE
        logits, new_cache = vfl.decode_step_b(
            params_b, cfg, cache_b, token.reshape(1, 1),
            z_row.reshape(1, 1, d).astype(PARAM_DTYPE), pos)
        return logits[0, 0], new_cache                 # (V,)

    va = jax.vmap(decode_a, in_axes=(None, 0, 0, 0))
    vb = jax.vmap(decode_b, in_axes=(None, 0, 0, 0, 0))

    def send_row(rng, row):
        y, _ = tp.send(rng, row, None, "up")
        return y

    def step(params, state, rng):
        produced = state["active"]
        z_rows, cache_a = va(params["a"], state["cache_a"],
                             state["token_a"], state["pos"])
        if exchange:
            # per-lane uplink: each (d,) row is encoded independently, so
            # the per-request byte attribution is exact by construction
            y_rows = jax.vmap(send_row)(jax.random.split(rng, C), z_rows)
            ws = WS.workset_insert(state["ws"], {"z": y_rows},
                                   batch_idx=state["ws"]["time"])
        else:
            ws = state["ws"]
        slot = jnp.mod(ws["time"] - 1, scfg.ring_slots)
        z_used = _ring_read(ws["buf"]["z"], d)(slot)   # (C, d) fp32
        logits, cache_b = vb(params["b"], state["cache_b"], state["token"],
                             z_used, state["pos"])
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        down = jax.vmap(
            lambda r, x: tp.send(r, x, None, "down")[0]
        )(jax.random.split(jax.random.fold_in(rng, 1), C),
          tok.astype(jnp.float32)[:, None])
        tok_a = jnp.mod(down[:, 0].astype(jnp.int32), cfg.aux_vocab_size)

        remaining = state["remaining"] - jnp.where(produced, 1, 0)
        new = dict(state)
        new["cache_a"], new["cache_b"], new["ws"] = cache_a, cache_b, ws
        new["active"] = produced & (remaining > 0)
        new["pos"] = state["pos"] + 1
        new["token"], new["token_a"] = tok, tok_a
        new["remaining"] = remaining
        return new, tok, produced

    return step


def _ring_clear_lane(ws: Dict[str, Any], lane):
    """Zero lane ``lane``'s column across every ring slot (scales -> 0 so
    quantized stores decode to exact zeros): a freshly admitted request
    must never read the previous occupant's cached activations."""
    buf = ws["buf"]["z"]
    if isinstance(buf, WS.QuantLeaf):
        nb = WS.QuantLeaf(buf.q.at[:, lane].set(0),
                          buf.scale.at[:, lane].set(0.0),
                          buf.shape, buf.dtype)
    elif isinstance(buf, WS.Quant4Leaf):
        nb = WS.Quant4Leaf(buf.q.at[:, lane].set(0x88),
                           buf.scale.at[:, lane].set(0.0),
                           buf.shape, buf.dtype)
    elif isinstance(buf, WS.CastLeaf):
        nb = WS.CastLeaf(buf.v.at[:, lane].set(0), buf.dtype)
    else:
        nb = buf.at[:, lane].set(0.0)
    new = dict(ws)
    new["buf"] = dict(ws["buf"], z=nb)
    return new


# --------------------------------------------------------------------------
# The engine
# --------------------------------------------------------------------------
class ServeEngine:
    """Continuous-batching serving engine (see module docstring).

    ``params`` is ``vfl.init_all``'s {"a", "b"} tree; ``transport``
    overrides the wire (e.g. the auditor's :class:`AuditedTransport`) —
    by default it is built from ``scfg.compression`` with an identity
    downlink."""

    def __init__(self, params, cfg: ArchConfig, scfg: ServeConfig,
                 transport=None):
        if cfg.vfl_split.fusion != "add":
            raise ValueError(
                f"ServeEngine needs a token-aligned (fusion='add') arch; "
                f"{cfg.name} ({cfg.family}) exchanges its memory once at "
                f"prefill — serve it with naive_generate")
        if scfg.ring_slots < 1 or scfg.refresh_every < 1:
            raise ValueError("ring_slots and refresh_every must be >= 1")
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.celu = CELUConfig(compression=self._wire_spec())
        self.tp = transport if transport is not None else \
            core_engine.make_transport(self.celu)
        self._admit = jax.jit(make_admit_fn(cfg, scfg, self.tp))
        self._step = {
            True: jax.jit(make_step_fn(cfg, scfg, self.tp, True)),
            False: jax.jit(make_step_fn(cfg, scfg, self.tp, False)),
        }
        self._key = jax.random.PRNGKey(scfg.seed)
        self._nstep = 0
        self.state = self._init_state()
        # exact per-message wire bytes (the transport's own accounting)
        S, d = scfg.prompt_len, cfg.d_model
        self.prefill_up_bytes = int(self.tp.uplink_bytes((S, d)))
        self.step_up_bytes = int(self.tp.uplink_bytes((d,)))
        self.token_down_bytes = int(self.tp.downlink_bytes((1,)))

    def _wire_spec(self) -> str:
        spec = self.scfg.compression
        if not spec:
            return ""
        # the downlink carries one token id: identity by contract
        return spec if "/" in spec else f"{spec}/identity"

    def _init_state(self) -> Dict[str, Any]:
        cfg, scfg = self.cfg, self.scfg
        C, S = scfg.capacity, scfg.prompt_len
        total_len = S + scfg.max_new_tokens
        batch = {"tokens": jnp.zeros((1, S), jnp.int32),
                 "tokens_a": jnp.zeros((1, S), jnp.int32)}
        shapes = jax.eval_shape(
            lambda p: vfl.prefill(p, cfg, batch, total_len)[1], self.params)
        zeros = lambda l: jnp.zeros((C,) + l.shape, l.dtype)
        return {
            "cache_a": jax.tree_util.tree_map(zeros, shapes["a"]),
            "cache_b": jax.tree_util.tree_map(
                zeros, {"b": shapes["b"], "top": shapes["top"]}),
            "ws": WS.workset_init(
                scfg.ring_slots,
                {"z": jnp.zeros((C, cfg.d_model), jnp.float32)},
                cache_dtype=scfg.cache_dtype),
            "active": jnp.zeros((C,), bool),
            "pos": jnp.zeros((C,), jnp.int32),
            "token": jnp.zeros((C,), jnp.int32),
            "token_a": jnp.zeros((C,), jnp.int32),
            "remaining": jnp.zeros((C,), jnp.int32),
        }

    def _next_key(self):
        self._nstep += 1
        return jax.random.fold_in(self._key, self._nstep)

    def warm(self):
        """Compile admit + both step variants untimed (one throwaway
        admit into lane 0 and one step each on scratch state — the real
        run is never charged an XLA compile)."""
        S = self.scfg.prompt_len
        scratch, _ = self._admit(
            self.params, self.state, jnp.int32(0),
            jnp.zeros((1, S), jnp.int32), jnp.zeros((1, S), jnp.int32),
            jnp.int32(2), self._key)
        for ex in (True, False):
            out = self._step[ex](self.params, scratch, self._key)
        jax.block_until_ready(out[0]["token"])
        return self

    # ----------------------------------------------------------------
    def run(self, requests: Sequence[Request],
            clock: Optional[Any] = None
            ) -> Tuple[List[Completion], Dict[str, Any]]:
        """Serve ``requests`` to completion.  Open loop: a request is
        admissible once the virtual clock (wall time actually spent
        stepping, fast-forwarded over idle gaps) passes its ``arrival``.
        Returns (completions sorted by req_id, stats) where stats carries
        the per-decode-step walls and total virtual duration."""
        timer = time.perf_counter if clock is None else clock
        pending = sorted(requests, key=lambda r: (r.arrival, r.req_id))
        pending = list(pending)
        lanes: List[Optional[Completion]] = [None] * self.scfg.capacity
        done: List[Completion] = []
        vnow = 0.0
        step_walls: List[float] = []
        phase = 0
        force_exchange = False
        R = self.scfg.refresh_every

        def occupied():
            return [i for i, c in enumerate(lanes) if c is not None]

        while pending or occupied():
            # -- admit FIFO into the lowest free lanes ----------------
            admitted = False
            for lane in range(self.scfg.capacity):
                if lanes[lane] is not None or not pending:
                    continue
                if pending[0].arrival > vnow:
                    break
                req = pending.pop(0)
                t0 = timer()
                self.state, tok = self._admit(
                    self.params, self.state, jnp.int32(lane),
                    jnp.asarray(req.prompt, jnp.int32)[None],
                    jnp.asarray(req.prompt_a, jnp.int32)[None],
                    jnp.int32(req.max_new_tokens), self._next_key())
                tok = int(tok)
                vnow += timer() - t0
                comp = Completion(req.req_id, arrival=req.arrival,
                                  admitted_at=vnow)
                comp.tokens = np.array([tok], np.int32)
                comp.token_times.append(vnow)
                comp.wire_up_bytes += self.prefill_up_bytes
                comp.wire_down_bytes += self.token_down_bytes
                if req.max_new_tokens <= 1:
                    comp.finished_at = vnow
                    done.append(comp)          # lane freed immediately
                else:
                    lanes[lane] = comp
                admitted = True
            if admitted:
                # a fresh lane's ring column is zeroed: the next step
                # must re-exchange so nobody fuses against zeros
                force_exchange = True

            if not occupied():
                if pending:                    # idle: fast-forward
                    vnow = max(vnow, pending[0].arrival)
                    continue
                break

            # -- one decode step for every lane -----------------------
            exchange = force_exchange or R == 1 or phase % R == 0
            t0 = timer()
            self.state, tok, produced = self._step[exchange](
                self.params, self.state, self._next_key())
            tok_np = np.asarray(tok)
            prod_np = np.asarray(produced)
            rem_np = np.asarray(self.state["remaining"])
            dt = timer() - t0
            vnow += dt
            step_walls.append(dt)
            phase += 1
            force_exchange = False

            for lane in occupied():
                if not prod_np[lane]:
                    continue
                comp = lanes[lane]
                comp.tokens = np.append(comp.tokens, tok_np[lane])
                comp.token_times.append(vnow)
                if exchange:
                    comp.wire_up_bytes += self.step_up_bytes
                comp.wire_down_bytes += self.token_down_bytes
                if rem_np[lane] <= 0:          # evict: lane is free
                    comp.finished_at = vnow
                    done.append(comp)
                    lanes[lane] = None

        done.sort(key=lambda c: c.req_id)
        stats = {
            "virtual_duration_s": vnow,
            "decode_steps": len(step_walls),
            "step_walls": step_walls,
            "n_requests": len(done),
            "total_tokens": int(sum(len(c.tokens) for c in done)),
            "wire_up_bytes": int(sum(c.wire_up_bytes for c in done)),
            "wire_down_bytes": int(sum(c.wire_down_bytes for c in done)),
        }
        return done, stats


# --------------------------------------------------------------------------
# Sequential per-request baseline / oracle
# --------------------------------------------------------------------------
def make_naive_fns(cfg: ArchConfig, total_len: int):
    """Jitted (prefill, decode_step) pair for :func:`naive_generate`.
    Build ONCE and pass via ``fns`` when looping over many requests —
    the sequential serving baseline must pay steady-state dispatch, not
    a retrace per request."""
    prefill = jax.jit(lambda p, b: vfl.prefill(p, cfg, b, total_len))
    decode = jax.jit(lambda p, c, sb, pos: vfl.decode_step(p, cfg, c, sb,
                                                           pos))
    return prefill, decode


def naive_generate(params, cfg: ArchConfig, batch: Dict[str, Any],
                   max_new_tokens: int, total_len: int = 0, fns=None):
    """Greedy decode through the monolithic ``vfl.prefill`` /
    ``vfl.decode_step`` — the sequential baseline the engine must beat
    and the bit-exactness oracle it must match (same deterministic aux
    rule: ``token_a = token % aux_vocab``).  Works for every family
    (cross-attn archs decode Party-B-side only).  -> (B, max_new_tokens)
    int32 tokens."""
    S = batch["tokens"].shape[1]
    total_len = total_len or S + max_new_tokens
    prefill, decode = fns if fns is not None else \
        make_naive_fns(cfg, total_len)
    logits, caches = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    out = [tok]
    for i in range(max_new_tokens - 1):
        sb = {"token": tok[:, None]}
        if cfg.family not in ("vlm", "audio"):
            sb["token_a"] = jnp.mod(tok, cfg.aux_vocab_size)[:, None]
        logits, caches = decode(params, caches, sb, jnp.int32(S + i))
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        out.append(tok)
    return jnp.stack(out, axis=1)
